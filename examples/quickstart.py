"""Quickstart: the AIA pipeline in 60 lines.

1. Sample from a non-normalized integer distribution with the KY sampler
   (exact, ≈ H+2 random bits/sample, no normalization pass).
2. Run fixed-point Gibbs over the asia Bayesian network through the full
   compiler chain (quantize → DSatur color → gather plans → jitted sweep).
3. Decode tokens from an LM with the softmax-free KY token sampler.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import entropy_bits, ky_sample, quantize_probs
from repro.configs import get_config
from repro.models.sampling import generate
from repro.models.transformer import init_model
from repro.pgm import compile_bayesnet, networks, run_gibbs

# --- 1. non-normalized Knuth-Yao sampling --------------------------------
p = jnp.asarray([0.5, 0.25, 0.125, 0.125])
weights = quantize_probs(p, k=12)           # int32, never normalized again
res = ky_sample(jax.random.PRNGKey(0), jnp.tile(weights, (100_000, 1)))
freq = np.bincount(np.asarray(res.sample), minlength=4) / 1e5
print(f"[KY] target={np.asarray(p)} measured={freq.round(3)}")
print(f"[KY] bits/sample={float(res.bits_used.mean()):.2f} "
      f"(entropy+2 = {float(entropy_bits(p)) + 2:.2f})")

# --- 2. Bayesian-network Gibbs through the compiler chain ----------------
bn = networks.asia()
prog = compile_bayesnet(bn)                 # quantize + DSatur + plans
print(f"[BN] asia: {bn.n_nodes} nodes -> {prog.n_colors} parallel colors")
_, counts, stats = run_gibbs(jax.random.PRNGKey(1), prog,
                             n_chains=256, n_sweeps=600, burn_in=150)
marg = np.asarray(counts, np.float64)
marg /= marg.sum(-1, keepdims=True)
exact = bn.marginals_exact()
for v in ("smoke", "lung", "dysp"):
    i = bn.names.index(v)
    print(f"[BN] P({v}=yes): gibbs={marg[i,1]:.3f} "
          f"exact={(exact[i]/exact[i].sum())[1]:.3f}")

# --- 3. softmax-free LM decode -------------------------------------------
cfg = get_config("phi4-mini-3.8b", smoke=True)
params = init_model(jax.random.PRNGKey(2), cfg)
prompt = jnp.ones((2, 8), jnp.int32)
tokens, bits = generate(params, cfg, prompt, jax.random.PRNGKey(3),
                        max_new=16, sampler="ky", q_block=8)
print(f"[LM] generated {tokens.shape} tokens via hierarchical KY, "
      f"{int(bits) / tokens.size:.1f} random bits/token")
print("[LM] tokens[0]:", np.asarray(tokens[0]).tolist())
