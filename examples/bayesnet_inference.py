"""Bayesian-network inference through the full AIA compiler chain
(paper §III + Fig. 7): PPL-style model → fixed-point CPT quantization →
moralization + DSatur coloring → gather plans → jitted parallel Gibbs
with the IU-exp → KY-sample pipeline.

  PYTHONPATH=src python examples/bayesnet_inference.py
  PYTHONPATH=src python examples/bayesnet_inference.py --network alarm_scale
  PYTHONPATH=src python examples/bayesnet_inference.py --network sprinkler \
      --evidence wetgrass=1 --query rain      # posterior query (repro.serve)
"""
import argparse
import math
import time

import jax
import numpy as np

from repro.pgm import compile_bayesnet, networks, run_gibbs

ap = argparse.ArgumentParser()
ap.add_argument("--network", default="asia",
                choices=["asia", "sprinkler", "child_scale", "alarm_scale",
                         "hailfinder_scale"])
ap.add_argument("--chains", type=int, default=256)
ap.add_argument("--sweeps", type=int, default=800)
ap.add_argument("--burn-in", type=int, default=200)
ap.add_argument("--no-iu", action="store_true")
ap.add_argument("--evidence", default="",
                help="e.g. wetgrass=1,cloudy=0 — route the run through the "
                     "posterior query engine and condition on these values")
ap.add_argument("--query", default="",
                help="query variables (default: all unobserved)")
args = ap.parse_args()

bn = getattr(networks, args.network)()
print(f"network={args.network}: {bn.n_nodes} nodes, "
      f"cards {min(bn.card)}..{max(bn.card)}")

# --- evidence-conditioned path: the serve engine ---------------------------
if args.evidence:
    from repro.serve import PosteriorEngine, Query, parse_evidence

    evidence = parse_evidence(args.evidence)
    qvars = tuple(v.strip() for v in args.query.split(",") if v.strip())
    engine = PosteriorEngine({args.network: bn}, chains_per_query=args.chains,
                             use_iu=not args.no_iu, burn_in=args.burn_in)
    budget = args.chains * max(args.sweeps - args.burn_in, 1)
    res = engine.answer(Query(args.network, evidence, qvars, n_samples=budget))
    print(f"evidence {evidence}: split-Rhat={res.rhat:.3f} "
          f"converged={res.converged}, {res.n_node_samples} RV samples "
          f"in {res.wall_s:.2f}s "
          f"({res.n_node_samples/res.wall_s/1e6:.2f} MSample/s)")
    oracle = (bn.marginals_exact(evidence)
              if math.prod(bn.card) <= 2_000_000 else None)
    for var, m in res.marginals.items():
        line = f"  P({var:10s} | e) = {np.round(m, 3)}"
        if oracle is not None:
            e = oracle[bn.index(var)]
            line += (f"   exact={np.round(e, 3)}  "
                     f"err={np.abs(m - e).max():.4f}")
        print(line)
    raise SystemExit(0)

# --- the compiler chain ----------------------------------------------------
t0 = time.time()
prog = compile_bayesnet(bn, k=14, quantize_cpt_bits=16)
print(f"compiled in {time.time()-t0:.2f}s: {prog.n_colors} DSatur colors, "
      f"{prog.log_cpt.size} fixed-point CPT entries")
for i, plan in enumerate(prog.plans):
    print(f"  color {i}: {len(plan.nodes)} nodes update in parallel")

# --- run -------------------------------------------------------------------
t0 = time.time()
x, counts, stats = run_gibbs(
    jax.random.PRNGKey(0), prog, n_chains=args.chains,
    n_sweeps=args.sweeps, burn_in=args.burn_in, use_iu=not args.no_iu)
jax.block_until_ready(counts)
dt = time.time() - t0
n_samples = args.chains * args.sweeps * bn.n_nodes
print(f"\n{n_samples} RV samples in {dt:.2f}s "
      f"({n_samples/dt/1e6:.2f} MSample/s on CPU), "
      f"{float(stats.bits_used)/n_samples:.2f} random bits/sample")

marg = np.asarray(counts, np.float64)
marg /= np.clip(marg.sum(-1, keepdims=True), 1, None)
oracle = None
if math.prod(bn.card) <= 2_000_000:
    oracle = bn.marginals_exact()
print("\nposterior marginals:")
for v in range(min(bn.n_nodes, 12)):
    line = f"  P({bn.names[v]:10s}) = {np.round(marg[v, :bn.card[v]], 3)}"
    if oracle is not None:
        e = oracle[v] / oracle[v].sum()
        line += f"   exact={np.round(e, 3)}  err={np.abs(marg[v,:bn.card[v]]-e).max():.4f}"
    print(line)
