"""Interactive MRF segmentation (paper Fig. 7 "Penguin") served end to
end: user *scribbles* clamp pixels to known labels (pixel-mask
evidence), and the posterior engine runs clamped checkerboard Gibbs —
IU-exp → fixed-point → non-normalized KY — returning per-site posterior
marginals.  Clamped sites are provably frozen; everything else is
inferred conditioned on them.

  PYTHONPATH=src python examples/mrf_segmentation.py
  PYTHONPATH=src python examples/mrf_segmentation.py --scribbles 6
  PYTHONPATH=src python examples/mrf_segmentation.py --raw     # unserved
  PYTHONPATH=src python examples/mrf_segmentation.py --mesh 2x2
"""
import argparse
import os
import time

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=float, default=0.15)
ap.add_argument("--sweeps", type=int, default=30)
ap.add_argument("--scribbles", type=int, default=4,
                help="number of user scribble strokes (0 = no evidence)")
ap.add_argument("--budget", type=int, default=2048)
ap.add_argument("--mesh", default="",
                help="RxC: distributed clamped Gibbs via halo exchange")
ap.add_argument("--raw", action="store_true",
                help="direct mrf_gibbs instead of the posterior engine")
args = ap.parse_args()

if args.mesh:
    r, c = (int(x) for x in args.mesh.split("x"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={r * c}")

import jax
import jax.numpy as jnp
import numpy as np

from repro.pgm.gibbs import clamp_labels, init_labels, mrf_gibbs
from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_clamp, shard_mrf
from repro.pgm.networks import penguin_task
from repro.serve.cli import scribble_mask

h, w = int(500 * args.scale), int(333 * args.scale)
mrf, truth = penguin_task(h=h, w=w, beta=2.0)

# -- synthetic user scribbles: strokes whose labels copy the ground
# truth (what a human marking "this is penguin / background" produces)
mask = scribble_mask(h, w, np.random.default_rng(0),
                     n_strokes=args.scribbles)
values = np.where(mask, truth, 0)
print(f"Penguin-like segmentation: {h}x{w}, L=2, "
      f"{int(mask.sum())} scribbled px over {args.scribbles} strokes")

t0 = time.time()
if args.mesh:
    from repro.launch.mesh import make_pgm_mesh

    r, c = (int(x) for x in args.mesh.split("x"))
    mesh = make_pgm_mesh(r, c)
    key = jax.random.PRNGKey(0)
    lab, u, pw, valid, _ = shard_mrf(mesh, mrf, n_chains=2, key=key)
    lab, clamp_dev = shard_clamp(mesh, mask, values, lab)
    step = make_mesh_gibbs_step(mesh, clamped=True)
    bits = 0
    for i in range(args.sweeps):
        key, sub = jax.random.split(key)
        lab, bgrid = step(sub, lab, u, pw, valid, clamp_dev)
        bits += int(np.asarray(bgrid, np.int64).sum())
    final = np.asarray(lab)[0][:h, :w]
    frozen = bool((final[mask] == values[mask]).all())
    n = (h * w - int(mask.sum())) * args.sweeps * 2
    mode = f"{r}x{c} mesh halo-exchange (clamped)"
elif args.raw:
    lab = init_labels(jax.random.PRNGKey(0), mrf, 2)
    lab = clamp_labels(lab, mask, values)
    lab, stats = mrf_gibbs(jax.random.PRNGKey(1), lab,
                           jnp.asarray(mrf.unary), jnp.asarray(mrf.pairwise),
                           n_sweeps=args.sweeps, clamp=jnp.asarray(mask))
    bits = int(stats.bits_used)
    final = np.asarray(lab)[0]
    frozen = bool((final[mask] == values[mask]).all())
    n = (h * w - int(mask.sum())) * args.sweeps * 2
    mode = "single device, direct mrf_gibbs (clamped)"
else:
    # -- the serving path: one MrfQuery through the posterior engine
    # (plan cache keyed by the mask pattern, split-R̂ early stopping)
    from repro.serve import MrfQuery, PosteriorEngine

    engine = PosteriorEngine({"penguin": mrf}, chains_per_query=8,
                             burn_in=16, max_rounds=8)
    res = engine.answer(MrfQuery("penguin", mask, values,
                                 n_samples=args.budget))
    # posterior argmax over every free site; scribbles stay themselves
    final = values.copy()
    for name, m in res.marginals.items():
        r0, c0 = (int(v) for v in name[1:].split(","))
        final[r0, c0] = int(np.argmax(m))
    frozen = True  # clamped sites were never query vars, by construction
    bits = int(res.bits_per_sample * res.n_node_samples)
    n = res.n_node_samples
    mode = (f"served MrfQuery (rhat={res.rhat:.3f}, "
            f"kept={res.n_samples}, cache_hit={res.cache_hit})")
dt = time.time() - t0

acc = (final == truth).mean()
print(f"[{mode}] {n / dt / 1e6:.2f} MSample/s, "
      f"{bits / max(n, 1):.2f} bits/sample, accuracy={acc:.4f}, "
      f"clamped_frozen={frozen}")

# ascii-art the segmentation; scribbles render as 'o'/'O'
step_r, step_c = max(h // 24, 1), max(w // 48, 1)
for i in range(0, h, step_r):
    row = ""
    for j in range(0, w, step_c):
        row += ".#oO"[int(final[i, j]) + 2 * int(mask[i, j])]
    print(row)
