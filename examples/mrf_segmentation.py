"""MRF image segmentation (paper Fig. 7 "Penguin") end to end:
checkerboard block-Gibbs with the IU-exp → fixed-point → KY pipeline,
single-device or distributed with halo exchange (C3).

  PYTHONPATH=src python examples/mrf_segmentation.py
  PYTHONPATH=src python examples/mrf_segmentation.py --mesh 2x2
"""
import argparse
import os
import time

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=float, default=0.25)
ap.add_argument("--sweeps", type=int, default=30)
ap.add_argument("--mesh", default="")
args = ap.parse_args()

if args.mesh:
    r, c = (int(x) for x in args.mesh.split("x"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={r * c}")

import jax
import jax.numpy as jnp
import numpy as np

from repro.pgm.gibbs import init_labels, mrf_gibbs
from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_mrf
from repro.pgm.networks import penguin_task

h, w = int(500 * args.scale), int(333 * args.scale)
mrf, truth = penguin_task(h=h, w=w, beta=2.0)
print(f"Penguin-like segmentation: {h}x{w}, L=2, {args.sweeps} sweeps")

t0 = time.time()
if args.mesh:
    from repro.launch.mesh import make_pgm_mesh

    r, c = (int(x) for x in args.mesh.split("x"))
    mesh = make_pgm_mesh(r, c)
    key = jax.random.PRNGKey(0)
    lab, u, pw, valid, _ = shard_mrf(mesh, mrf, n_chains=2, key=key)
    step = make_mesh_gibbs_step(mesh)
    bits = 0
    for i in range(args.sweeps):
        key, sub = jax.random.split(key)
        lab, bgrid = step(sub, lab, u, pw, valid)
        bits += int(np.asarray(bgrid, np.int64).sum())
    final = np.asarray(lab)[0][:h, :w]
    mode = f"{r}x{c} mesh halo-exchange"
else:
    lab = init_labels(jax.random.PRNGKey(0), mrf, 2)
    lab, stats = mrf_gibbs(jax.random.PRNGKey(1), lab,
                           jnp.asarray(mrf.unary), jnp.asarray(mrf.pairwise),
                           n_sweeps=args.sweeps)
    bits = int(stats.bits_used)
    final = np.asarray(lab)[0]
    mode = "single device"
dt = time.time() - t0

n = h * w * args.sweeps * 2
acc = (final == truth).mean()
print(f"[{mode}] {n / dt / 1e6:.2f} MSample/s, "
      f"{bits / n:.2f} bits/sample, accuracy={acc:.4f}")

# ascii-art the segmentation
step_r, step_c = max(h // 24, 1), max(w // 48, 1)
for row in final[::step_r]:
    print("".join(".#"[int(v)] for v in row[::step_c]))
