"""Softmax-free LM decoding A/B: the paper's non-normalized KY sampler
as the token sampler vs jax.random.categorical.

Shows (a) the distributions agree, (b) the random-bit economy
(≈ entropy+toll bits/token instead of 32+), (c) end-to-end generation
through prefill + KV-cached decode on a smoke model.

  PYTHONPATH=src python examples/lm_decode_ky.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import categorical_baseline, entropy_bits, ky_sample_tokens
from repro.models.sampling import generate
from repro.models.transformer import init_model

# --- A/B on a fixed logit vector ------------------------------------------
v, b = 1024, 100_000
logits = jax.random.normal(jax.random.PRNGKey(0), (v,)) * 3
tiled = jnp.tile(logits[None], (b, 1))
ky = jax.jit(lambda k: ky_sample_tokens(k, tiled))(jax.random.PRNGKey(1))
cat = categorical_baseline(jax.random.PRNGKey(2), tiled)
fk = np.bincount(np.asarray(ky.token), minlength=v) / b
fc = np.bincount(np.asarray(cat), minlength=v) / b
p = np.asarray(jax.nn.softmax(logits))
h = float(entropy_bits(p[None])[0])
print(f"vocab={v}: TV(ky, categorical) = {0.5*np.abs(fk-fc).sum():.4f}")
print(f"entropy={h:.2f} bits -> KY uses {float(ky.bits_used.mean()):.2f} "
      f"random bits/token (two KY stages), categorical needs 32+")

# --- end-to-end generation --------------------------------------------------
cfg = get_config("granite-20b", smoke=True)
params = init_model(jax.random.PRNGKey(3), cfg)
prompt = jax.random.randint(jax.random.PRNGKey(4), (4, 12), 0, cfg.vocab)
for sampler in ("ky", "categorical", "greedy"):
    t0 = time.time()
    toks, bits = generate(params, cfg, prompt, jax.random.PRNGKey(5),
                          max_new=24, sampler=sampler, q_block=4)
    toks.block_until_ready()
    dt = time.time() - t0
    n = toks.size
    extra = f", {int(bits)/n:.1f} bits/token" if sampler == "ky" else ""
    print(f"{sampler:12s}: {n/dt:7.0f} tok/s (incl. compile){extra} "
          f"tokens[0][:8]={np.asarray(toks[0])[:8].tolist()}")
