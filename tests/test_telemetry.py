"""Serving-stack telemetry: metrics registry exports (Prometheus text
exposition, JSON snapshot), Chrome/Perfetto trace shape + per-query
span tiling, null-recorder default, engine.stats(), and the
answer_batch-vs-queued metrics identity."""
import json
import re

import numpy as np
import pytest

from repro.pgm import networks
from repro.serve import (
    AdmissionQueue, PosteriorEngine, Query, Telemetry, lifecycle_breakdown)
from repro.serve.telemetry import (
    NULL, Histogram, MetricsRegistry, NullTelemetry, log_bins)

RESULT_TIMEOUT = 300.0


def _registry():
    return {"sprinkler": networks.sprinkler()}


def _engine(**kw):
    kw.setdefault("chains_per_query", 8)
    kw.setdefault("burn_in", 16)
    kw.setdefault("max_rounds", 4)
    kw.setdefault("seed", 0)
    return PosteriorEngine(_registry(), **kw)


def _traffic(n=4):
    rng = np.random.default_rng(0)
    out = []
    for _ in range(n):
        out.append(Query("sprinkler", {"wetgrass": int(rng.integers(2))},
                         ("rain",), n_samples=256))
    return out


# -- metrics primitives ----------------------------------------------------
class TestMetricsPrimitives:
    def test_log_bins_cover_range_and_are_increasing(self):
        bins = log_bins(1e-3, 1e2, per_decade=4)
        assert bins[0] == pytest.approx(1e-3)
        assert bins[-1] >= 1e2
        assert all(a < b for a, b in zip(bins, bins[1:]))

    def test_log_bins_reject_bad_range(self):
        with pytest.raises(ValueError):
            log_bins(1.0, 1.0)
        with pytest.raises(ValueError):
            log_bins(0.0, 1.0)

    def test_histogram_buckets_le_semantics(self):
        h = Histogram(bins=(1.0, 10.0))
        for v in (0.5, 1.0, 5.0, 100.0):
            h.observe(v)
        # le-semantics: 1.0 lands in the le=1.0 bucket, 100 in +Inf
        assert h.counts == [2, 1, 1]
        assert h.count == 4 and h.sum == pytest.approx(106.5)
        assert 0.0 < h.quantile(0.5) <= 10.0
        assert Histogram(bins=(1.0,)).quantile(0.5) == 0.0  # empty

    def test_registry_label_children_and_kind_clash(self):
        reg = MetricsRegistry()
        reg.counter("retired", reason="a").inc()
        reg.counter("retired", reason="b").inc(2)
        assert reg.counter("retired", reason="b").value == 2
        with pytest.raises(ValueError):
            reg.gauge("retired")
        snap = reg.snapshot()
        assert snap["retired{reason=a}"] == 1
        assert snap["retired{reason=b}"] == 2


PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"               # metric name
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""     # first label
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?"
    r" -?[0-9.e+\-inf]+$")                      # value


class TestPrometheusExposition:
    def test_parses_line_by_line(self):
        reg = MetricsRegistry()
        reg.counter("serve_queries_total", "queries").inc(3)
        reg.gauge("serve_depth").set(2.5)
        h = reg.histogram("serve_wait_seconds", bins=(0.1, 1.0))
        h.observe(0.05)
        h.observe(5.0)
        text = reg.prometheus()
        assert text.endswith("\n")
        kinds = {}
        for line in text.splitlines():
            assert line, "no blank lines in exposition"
            if line.startswith("# HELP"):
                continue
            if line.startswith("# TYPE"):
                _, _, name, kind = line.split()
                kinds[name] = kind
                continue
            assert PROM_LINE.match(line), line
        assert kinds == {"serve_queries_total": "counter",
                         "serve_depth": "gauge",
                         "serve_wait_seconds": "histogram"}

    def test_histogram_buckets_cumulative_with_inf(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", bins=(0.1, 1.0))
        for v in (0.05, 0.5, 2.0):
            h.observe(v)
        lines = reg.prometheus().splitlines()
        buckets = [ln for ln in lines if ln.startswith("lat_bucket")]
        counts = [int(ln.rsplit(" ", 1)[1]) for ln in buckets]
        assert counts == sorted(counts), "cumulative bucket counts"
        assert 'le="+Inf"' in buckets[-1] and counts[-1] == 3
        assert "lat_count 3" in lines
        assert any(ln.startswith("lat_sum") for ln in lines)


# -- tracer ----------------------------------------------------------------
class TestTracer:
    def test_chrome_trace_round_trips_json(self):
        tel = Telemetry()
        tid = tel.track("query-0")
        from repro.serve.telemetry import monotonic
        t0 = monotonic()
        tel.complete("query", tid, t0, t0 + 0.25, reason="rhat+ess")
        tel.complete("wait", tid, t0, t0 + 0.1)
        tel.instant("retired", tid, reason="rhat+ess")
        tel.sample("queue_depth", 3)
        doc = json.loads(json.dumps(tel.chrome_trace()))
        assert doc["displayTimeUnit"] == "ms"
        evs = doc["traceEvents"]
        assert {"X", "i", "C", "M"} <= {e["ph"] for e in evs}
        for e in evs:
            if e["ph"] in ("X", "i", "C"):
                assert e["ts"] >= 0.0
            if e["ph"] == "X":
                assert e["dur"] >= 0.0 and isinstance(e["tid"], int)
        q = next(e for e in evs if e["name"] == "query")
        w = next(e for e in evs if e["name"] == "wait")
        # nesting by time containment on the same track
        assert w["tid"] == q["tid"]
        assert q["ts"] <= w["ts"]
        assert w["ts"] + w["dur"] <= q["ts"] + q["dur"] + 1e-6

    def test_null_recorder_is_inert(self):
        tel = NullTelemetry()
        assert tel.enabled is False and NULL.enabled is False
        assert tel.track("x") == 0
        tel.complete("a", 0, 0.0, 1.0)
        tel.instant("b", 0)
        tel.count("c")
        tel.observe("d", 1.0)
        assert tel.events() == []
        assert tel.chrome_trace()["traceEvents"] == []
        assert tel.metrics_snapshot() == {} and tel.prometheus() == ""

    def test_write_trace_and_metrics(self, tmp_path):
        tel = Telemetry()
        tel.count("serve_q_total", 2)
        tel.write_trace(str(tmp_path / "t.json"))
        tel.write_metrics(str(tmp_path / "m.json"))
        with open(tmp_path / "t.json") as f:
            assert "traceEvents" in json.load(f)
        with open(tmp_path / "m.json") as f:
            assert json.load(f)["serve_q_total"] == 2

    def test_lifecycle_breakdown_attributes_phases(self):
        evs = [{"name": "query", "ph": "X", "ts": 0.0, "dur": 250_000.0},
               {"name": "wait", "ph": "X", "ts": 0.0, "dur": 150_000.0},
               {"name": "plan", "ph": "X", "ts": 150_000.0, "dur": 80_000.0},
               {"name": "service", "ph": "X", "ts": 230_000.0,
                "dur": 20_000.0},
               {"name": "retired", "ph": "i", "ts": 250_000.0}]
        bd = lifecycle_breakdown(evs)
        assert bd["n_queries"] == 1
        assert bd["e2e_p50_ms"] == pytest.approx(250.0)
        assert bd["wait"]["p50_ms"] == pytest.approx(150.0)
        phase_sum = sum(bd[p]["total_s"] for p in ("wait", "plan", "service"))
        assert phase_sum == pytest.approx(bd["e2e_total_s"])


# -- engine integration ----------------------------------------------------
class TestEngineTelemetry:
    def test_default_engine_records_nothing(self):
        engine = _engine()
        engine.answer_batch(_traffic(2))
        assert engine.telemetry is NULL
        assert engine.telemetry.events() == []

    def test_stats_before_any_traffic(self):
        engine = _engine()
        st = engine.stats()
        # hit_rate must be 0.0 (not raise) with zero lookups
        assert st["plan_cache"]["hit_rate"] == 0.0
        assert st["plan_cache"]["hits"] == 0
        assert st["queue"] is None
        assert "metrics" not in st

    def test_spans_tile_e2e_latency(self):
        engine = _engine(telemetry=Telemetry())
        engine.answer_batch(_traffic(4))
        evs = engine.telemetry.events()
        by_tid = {}
        for e in evs:
            if e.get("ph") == "X" and e["name"] in (
                    "query", "wait", "plan", "service"):
                by_tid.setdefault(e["tid"], {})[e["name"]] = e
        queries = [v for v in by_tid.values() if "query" in v]
        assert len(queries) == 4
        for spans in queries:
            assert {"wait", "plan", "service"} <= set(spans)
            total = sum(spans[p]["dur"]
                        for p in ("wait", "plan", "service"))
            e2e = spans["query"]["dur"]
            # acceptance bound is 5%; construction makes it ~exact
            assert total == pytest.approx(e2e, rel=0.05)
            # shared boundaries: spans nest inside the umbrella
            assert spans["wait"]["ts"] == pytest.approx(
                spans["query"]["ts"], abs=1.0)

    def test_retirement_reason_and_metrics(self):
        engine = _engine(telemetry=Telemetry())
        results = engine.answer_batch(_traffic(3))
        evs = engine.telemetry.events()
        retired = [e for e in evs if e["name"] == "retired"]
        assert len(retired) == 3
        valid = {"rhat+ess", "rhat", "max-sweeps", "cancel"}
        assert {e["args"]["reason"] for e in retired} <= valid
        snap = engine.telemetry.metrics_snapshot()
        n_retired = sum(v for k, v in snap.items()
                        if k.startswith("serve_retired_total"))
        assert n_retired == 3
        assert snap["serve_rounds_total"] > 0
        assert "serve_e2e_seconds" not in snap  # no queue attached
        # stats() merges cache + metrics
        st = engine.stats()
        assert st["metrics"] == snap
        assert st["plan_cache"]["misses"] >= 1  # one compile per pattern
        assert all(r.converged or r.n_sweeps > 0 for r in results)

    def test_queued_metrics_match_answer_batch(self):
        """Deterministic counters (groups, rounds, sweeps, retirements)
        are identical whether the same traffic is caller-batched or
        flushed through the admission queue — the queue reroutes
        scheduling, never sampling."""
        traffic = _traffic(4)
        eng_a = _engine(telemetry=Telemetry())
        eng_a.answer_batch(traffic)

        eng_b = _engine(telemetry=Telemetry())
        queue = AdmissionQueue(eng_b, max_wait_ms=3_600_000.0,
                               max_group_lanes=8 * len(traffic))
        try:
            handles = [queue.submit(q) for q in traffic]
            queue.flush()
            for h in handles:
                h.result(timeout=RESULT_TIMEOUT)
        finally:
            queue.close()

        keys = ("serve_groups_total", "serve_rounds_total",
                "serve_sweeps_total", "serve_plan_cache_misses_total")
        snap_a = eng_a.telemetry.metrics_snapshot()
        snap_b = eng_b.telemetry.metrics_snapshot()
        for k in keys:
            assert snap_a[k] == snap_b[k], k
        retired = lambda s: {k: v for k, v in s.items()  # noqa: E731
                             if k.startswith("serve_retired_total")}
        assert retired(snap_a) == retired(snap_b)
        # queue-only counters exist only on the queued side
        assert snap_b["serve_queries_submitted_total"] == len(traffic)
        assert snap_b["serve_queries_finished_total{status=completed}"] \
            == len(traffic)
        assert snap_b["serve_e2e_seconds"]["count"] == len(traffic)
        # and the queue's stats surface through engine.stats()
        st = eng_b.stats()
        assert st["queue"]["submitted"] == len(traffic)
        assert st["queue"]["completed"] == len(traffic)

    def test_queued_trace_has_lifecycle_events(self):
        engine = _engine(telemetry=Telemetry())
        queue = AdmissionQueue(engine, max_wait_ms=50.0)
        try:
            h = queue.submit(_traffic(1)[0])
            h.result(timeout=RESULT_TIMEOUT)
        finally:
            queue.close()
        names = {e["name"] for e in engine.telemetry.events()}
        assert {"submit", "query", "wait", "plan", "service", "round",
                "retired", "deliver"} <= names
        bd = lifecycle_breakdown(engine.telemetry.events())
        assert bd["n_queries"] == 1
        phase_sum = sum(bd[p]["total_s"] for p in ("wait", "plan", "service"))
        assert phase_sum == pytest.approx(bd["e2e_total_s"], rel=0.05)
