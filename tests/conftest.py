import os
import subprocess
import sys

import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# 1 device (task spec). Multi-device tests run via run_subprocess below.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, devices: int = 0, timeout: int = 900):
    """Run a python snippet in a clean interpreter (optionally with fake
    host devices) and return (returncode, output)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if devices:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
    p = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout)
    return p.returncode, p.stdout + p.stderr


@pytest.fixture(scope="session")
def rng_seed():
    return 0


class ManualClock:
    """Deterministic clock for the telemetry ``monotonic`` seam: tests
    advance time explicitly instead of sleeping on wall time."""

    def __init__(self, start: float = 1000.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


@pytest.fixture
def fake_clock():
    """Install a :class:`ManualClock` into ``repro.serve.telemetry``'s
    clock seam (shared by the admission queue's deadline triggers, the
    token buckets, and every telemetry timestamp) and restore the real
    ``time.monotonic`` afterwards.  Scheduler/quota tests drive
    ``fake_clock.advance(...)`` instead of ``time.sleep``."""
    from repro.serve import telemetry

    clock = ManualClock()
    telemetry.set_clock(clock)
    try:
        yield clock
    finally:
        telemetry.set_clock(None)


@pytest.fixture
def event_loop():
    """A fresh, isolated asyncio loop per test (the serving front end's
    coroutines run deterministically via ``event_loop.run_until_complete``
    without touching any ambient/global loop state)."""
    import asyncio

    loop = asyncio.new_event_loop()
    try:
        yield loop
    finally:
        loop.close()
        asyncio.set_event_loop(None)
