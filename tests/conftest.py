import os
import subprocess
import sys

import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# 1 device (task spec). Multi-device tests run via run_subprocess below.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, devices: int = 0, timeout: int = 900):
    """Run a python snippet in a clean interpreter (optionally with fake
    host devices) and return (returncode, output)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    if devices:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
    p = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout)
    return p.returncode, p.stdout + p.stderr


@pytest.fixture(scope="session")
def rng_seed():
    return 0
