"""MAP/MPE + temporal-filtering contracts (``docs/inference_modes.md``).

Covers the two inference modes of the unified :class:`Request` API:

* ``mode="map"`` — the annealed (simulated-annealing β schedule on the
  IU-exp weight path) MAP search must recover the *exact* enumeration
  argmax on every small-net fixture, under both sampler backends, and
  report the matching energy.
* temporal filtering (``stream_id``) — the warm-start contract: same
  seed + same slice stream is deterministic, retained states are
  re-clamped to the new slice's evidence, warm slices skip burn-in, and
  the admission queue never packs two slices of one stream into the
  same dispatch group.
* the versioned JSON request-file schema (v1 auto-upgrade, v2 mode /
  stream_id fields, loud failures on unknown versions and modes).
"""
import doctest
import json
import os

import numpy as np
import pytest

from repro.pgm import networks
from repro.pgm.graph import IsingModel
from repro.serve import (
    AdmissionQueue, IsingQuery, MrfQuery, PosteriorEngine, Query)
from repro.serve.cli import load_requests


def _registry():
    return {"sprinkler": networks.sprinkler(), "asia": networks.asia()}


def _exact_map(bn, evidence):
    """Brute-force joint argmax over the free variables given evidence —
    the oracle the annealed search must match (small nets only)."""
    grids = np.indices(tuple(bn.card)).reshape(bn.n_nodes, -1).T
    ev = bn.normalize_evidence(evidence)
    for v, val in ev.items():
        grids = grids[grids[:, v] == val]
    best = grids[np.argmax(bn.logp(grids))]
    return {bn.names[v]: int(best[v])
            for v in range(bn.n_nodes) if v not in ev}


def _frustrated_triangle() -> IsingModel:
    """Three antiferromagnetic couplings on a 3-cycle — no assignment
    satisfies all edges.  Small fields break the 6-fold ground-state
    degeneracy so the MAP answer is unique."""
    return IsingModel(n=3, edges=[[0, 1], [1, 2], [0, 2]], j=-1.0,
                      h=[0.3, -0.2, 0.1])


class TestAnnealedMap:
    @pytest.mark.parametrize("sampler", ["xla", "pallas"])
    @pytest.mark.parametrize("network,evidence", [
        ("sprinkler", {"wetgrass": 1}),
        ("asia", {"smoke": 1, "dysp": 1}),
    ])
    def test_recovers_exact_argmax(self, network, evidence, sampler):
        """The acceptance bar: annealed MAP == enumeration argmax on
        every small-net fixture, under both sampler backends."""
        bn = _registry()[network]
        eng = PosteriorEngine({network: bn}, chains_per_query=8,
                              burn_in=16, sampler=sampler, seed=0)
        res = eng.answer(Query(network, evidence, mode="map",
                               n_samples=4096))
        assert res.map_assignment == _exact_map(bn, evidence)
        assert res.converged          # retired on assignment stability
        assert res.marginals == {}    # a MAP answer is a point, not a dist
        # reported energy is the joint -log P̃ of (assignment, evidence)
        full = np.zeros(bn.n_nodes, np.int64)
        for name, val in {**res.map_assignment,
                          **{k: v for k, v in evidence.items()}}.items():
            full[bn.names.index(name)] = val
        assert res.map_energy == pytest.approx(-float(bn.logp(full)),
                                               abs=1e-4)

    @pytest.mark.parametrize("sampler", ["xla", "pallas"])
    def test_frustrated_triangle_ground_state(self, sampler):
        """MAP on a frustrated Ising triangle (spin 0 clamped up) finds
        the enumeration ground state of the conditioned model."""
        model = _frustrated_triangle()
        fg = model.to_factor_graph()
        grids = np.indices((2, 2, 2)).reshape(3, -1).T
        grids = grids[grids[:, 0] == 1]          # clamp s0 = +1
        best = grids[np.argmin(fg.energy(grids))]

        eng = PosteriorEngine({"tri": model}, chains_per_query=8,
                              burn_in=16, sampler=sampler, seed=0)
        res = eng.answer(IsingQuery("tri", clamp_sites=((0, +1),),
                                    query_vars=(1, 2), mode="map",
                                    n_samples=2048))
        assert res.map_assignment == {"s1": int(best[1]), "s2": int(best[2])}
        assert res.map_energy == pytest.approx(float(fg.energy(best)),
                                               abs=1e-4)

    def test_marginal_raises_on_map_result(self):
        eng = PosteriorEngine(_registry(), chains_per_query=8, burn_in=16,
                              seed=0)
        res = eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                               mode="map", n_samples=1024))
        with pytest.raises(ValueError, match="mode='map'"):
            res.marginal("rain")

    def test_map_fields_none_on_marginal_result(self):
        eng = PosteriorEngine(_registry(), chains_per_query=8, burn_in=16,
                              max_rounds=4, seed=0)
        res = eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                               n_samples=256))
        assert res.map_assignment is None and res.map_energy is None
        assert res.marginal("rain").shape == (2,)

    def test_mode_validated_at_construction(self):
        with pytest.raises(ValueError, match="unknown inference mode"):
            Query("sprinkler", {"wetgrass": 1}, mode="argmax")

    def test_beta_schedule_monotone_and_saturating(self):
        eng = PosteriorEngine(_registry(), map_beta0=0.5,
                              map_beta_growth=1.3, map_beta_max=8.0)
        betas = [eng.map_beta(t) for t in range(60)]
        assert betas[0] == pytest.approx(0.5)
        assert all(b <= a for a, b in zip(betas[1:], betas))  # non-decreasing
        assert betas[-1] == 8.0                               # saturates
        with pytest.raises(ValueError):
            PosteriorEngine(_registry(), map_beta_growth=0.5)

    def test_mixed_mode_batch_groups_split(self):
        """One batch mixing modes on the same (network, pattern): the
        marginal query still gets marginals, the MAP query an
        assignment — modes never share a group's runner call."""
        eng = PosteriorEngine(_registry(), chains_per_query=8, burn_in=16,
                              max_rounds=8, seed=0)
        r_marg, r_map = eng.answer_batch([
            Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=512),
            Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=512,
                  mode="map"),
        ])
        assert r_marg.map_assignment is None and r_marg.marginals
        assert r_map.map_assignment is not None and r_map.marginals == {}
        # same evidence pattern -> the MAP group still reuses the plan
        assert r_map.cache_hit


class TestTemporalFiltering:
    CHAINS = 8

    @staticmethod
    def _slices(n_slices=3):
        """One sensor re-observing the same pattern with drifting values
        plus a second stream — slice-major, as the admission path sees."""
        vals = [1, 0, 1, 0]
        return [[Query("sprinkler", {"wetgrass": vals[t]}, ("rain",),
                       n_samples=512, stream_id="a"),
                 Query("sprinkler", {"cloudy": vals[t]}, ("rain",),
                       n_samples=512, stream_id="b")]
                for t in range(n_slices)]

    def _engine(self, **kw):
        kw.setdefault("chains_per_query", self.CHAINS)
        kw.setdefault("burn_in", 16)
        kw.setdefault("seed", 3)
        return PosteriorEngine(_registry(), **kw)

    def test_same_seed_stream_is_deterministic(self):
        """T1: two same-seed engines fed the same slice stream produce
        bit-identical results, slice by slice."""
        outs = []
        for _ in range(2):
            eng = self._engine(max_rounds=4)
            outs.append([eng.answer_batch(sl) for sl in self._slices()])
        for slice_a, slice_b in zip(*outs):
            for a, b in zip(slice_a, slice_b):
                assert a.n_samples == b.n_samples and a.rhat == b.rhat
                assert a.warm_start == b.warm_start
                for k in a.marginals:
                    np.testing.assert_array_equal(a.marginals[k],
                                                  b.marginals[k])

    def test_stream_id_does_not_perturb_slice_zero(self):
        """Opting into temporal filtering is a pure opt-in: with nothing
        retained yet, a slice-0 query with a stream_id is bit-identical
        to the same query served cold (stream_id stripped)."""
        import dataclasses

        sl = self._slices(1)[0]
        a = self._engine(max_rounds=4).answer_batch(sl)
        b = self._engine(max_rounds=4).answer_batch(
            [dataclasses.replace(q, stream_id=None) for q in sl])
        for ra, rb in zip(a, b):
            assert ra.n_samples == rb.n_samples and ra.rhat == rb.rhat
            for k in ra.marginals:
                np.testing.assert_array_equal(ra.marginals[k],
                                              rb.marginals[k])

    def test_retained_states_reclamped_per_slice(self):
        """T2: retirement retains each stream's final chain states, and
        the next slice's evidence is re-clamped onto them — the retained
        block always reflects the *current* slice's observed values."""
        bn = _registry()["sprinkler"]
        wet = bn.names.index("wetgrass")
        eng = self._engine(max_rounds=4)
        slices = self._slices()

        r0 = eng.answer_batch(slices[0])
        assert not any(r.warm_start for r in r0)      # nothing retained yet
        blk = eng._retained[("sprinkler", "a")]
        assert blk.shape == (self.CHAINS, bn.n_nodes)
        assert (blk[:, wet] == 1).all()               # slice-0 evidence

        r1 = eng.answer_batch(slices[1])
        assert all(r.warm_start for r in r1)
        assert all(r.cache_hit for r in r1)           # same pattern, same plan
        blk = eng._retained[("sprinkler", "a")]
        assert (blk[:, wet] == 0).all()               # re-clamped to slice 1

        eng.reset_streams()
        assert not eng._retained
        r2 = eng.answer_batch(slices[2])
        assert not any(r.warm_start for r in r2)      # retention dropped

    def test_warm_slices_skip_burn_in(self):
        """T2 accounting: with retirement pinned at min_rounds, a warm
        slice's sweep count is exactly the cold count minus burn-in."""
        burn = 64
        eng = self._engine(burn_in=burn, rhat_target=100.0, ess_target=0.0)
        slices = self._slices(2)
        r0 = eng.answer_batch(slices[0])
        r1 = eng.answer_batch(slices[1])
        for cold, warm in zip(r0, r1):
            assert not cold.warm_start and warm.warm_start
            assert warm.n_sweeps == cold.n_sweeps - burn

    def test_warm_start_needs_fewer_sweeps_under_drift(self):
        """T3: under slowly drifting evidence the warm-started stream
        reaches the retirement targets in fewer total sweeps than the
        same traffic served cold (stream_id stripped)."""
        import dataclasses

        slices = self._slices()
        kw = dict(burn_in=64, ess_target=64.0)
        warm_eng, cold_eng = self._engine(**kw), self._engine(**kw)
        warm = [r for sl in slices for r in warm_eng.answer_batch(sl)]
        cold = [r for sl in slices for r in cold_eng.answer_batch(
            [dataclasses.replace(q, stream_id=None) for q in sl])]
        assert sum(r.n_sweeps for r in warm) < sum(r.n_sweeps for r in cold)
        assert sum(r.warm_start for r in warm) == 4   # slices 1-2, 2 streams

    def test_queue_serializes_same_stream_slices(self):
        """Two slices of one stream submitted together must dispatch in
        separate groups, in order — otherwise slice t+1 could not
        warm-start from slice t's retained states."""
        eng = self._engine(max_rounds=4)
        queue = AdmissionQueue(eng, max_wait_ms=3_600_000.0,
                               max_group_lanes=64)
        try:
            s0, s1 = (Query("sprinkler", {"wetgrass": v}, ("rain",),
                            n_samples=512, stream_id="a") for v in (1, 0))
            h0, h1 = queue.submit(s0), queue.submit(s1)
            queue.flush()
            r0 = h0.result(timeout=300)
            r1 = h1.result(timeout=300)
        finally:
            queue.close()
        assert not r0.warm_start
        assert r1.warm_start       # only possible if s1 ran after s0 retired

    def test_reregister_drops_streams(self):
        """Replacing a model invalidates its retained chain states —
        they were sampled under the old parameters."""
        eng = self._engine(max_rounds=4)
        eng.answer_batch(self._slices()[0])
        assert ("sprinkler", "a") in eng._retained
        eng.register("sprinkler", networks.sprinkler())
        assert ("sprinkler", "a") not in eng._retained


class TestRequestFileSchema:
    @staticmethod
    def _load(tmp_path, payload):
        p = tmp_path / "reqs.json"
        p.write_text(json.dumps(payload))
        return load_requests(str(p))

    def test_v1_auto_upgrades_to_marginals(self, tmp_path):
        qs, _ = self._load(tmp_path, [
            {"network": "sprinkler", "evidence": {"wetgrass": 1}},
        ])
        assert qs[0].mode == "marginals" and qs[0].stream_id is None

    def test_v1_refuses_v2_fields(self, tmp_path):
        for field in ("mode", "stream_id"):
            with pytest.raises(ValueError,
                               match=f"'{field}' requires schema version 2"):
                self._load(tmp_path, [
                    {"network": "sprinkler", field: "map"},
                ])

    def test_unknown_version_rejected(self, tmp_path):
        with pytest.raises(ValueError,
                           match=r"unknown request schema version 3"):
            self._load(tmp_path, [{"v": 3, "network": "sprinkler"}])

    def test_v2_mode_and_stream_id(self, tmp_path):
        qs, _ = self._load(tmp_path, [
            {"v": 2, "network": "sprinkler", "evidence": {"wetgrass": 1},
             "mode": "map"},
            {"v": 2, "network": "sprinkler", "evidence": {"cloudy": 0},
             "stream_id": "sensor3"},
            {"v": 2, "network": "mrf", "mask_sites": [[0, 0, 1]],
             "mode": "map", "stream_id": "cam0"},
        ])
        assert qs[0].mode == "map" and qs[0].stream_id is None
        assert qs[1].mode == "marginals" and qs[1].stream_id == "sensor3"
        assert isinstance(qs[2], MrfQuery)
        assert qs[2].mode == "map" and qs[2].stream_id == "cam0"

    def test_v2_unknown_mode_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="unknown inference mode"):
            self._load(tmp_path, [
                {"v": 2, "network": "sprinkler", "mode": "argmax"},
            ])


def test_docs_doctests():
    """Every ``>>>`` example in docs/inference_modes.md runs and prints
    exactly what the page claims (the schedule values, the sprinkler MAP
    assignment + energy, the warm-start flags)."""
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "docs", "inference_modes.md")
    failures, tests = doctest.testfile(
        path, module_relative=False,
        optionflags=doctest.NORMALIZE_WHITESPACE)
    assert tests > 0, "no doctest examples found in inference_modes.md"
    assert failures == 0


class TestFamilyDispatch:
    def test_family_of_dispatches_on_query_type(self):
        from repro.serve.families import family_of

        assert family_of(
            Query("x", {"a": 1})).__class__.__name__ == "BayesNetFamily"
        assert family_of(
            MrfQuery("x")).__class__.__name__ == "MrfFamily"
        assert family_of(
            IsingQuery("x")).__class__.__name__ == "IsingFamily"
