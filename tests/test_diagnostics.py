"""Convergence-diagnostics subsystem: rank-normalized R̂ / ESS math,
incremental-vs-one-shot agreement, engine retirement wiring, the asia
OR-gate regression (legacy split-R̂ retires early and biased, rank+ESS
keeps sampling to accuracy), and the perf gate's retirement-mode
mismatch handling."""
import numpy as np
import pytest

from repro.pgm.diagnostics import (
    Diagnostics, RunningDiagnostics, compute_diagnostics, ess_bulk,
    ess_mean, ess_tail, folded_rank_rhat, normal_quantile, rank_normalize,
    rank_rhat, split_rhat)


class TestNormalQuantile:
    def test_known_values(self):
        for p, want in [(0.5, 0.0), (0.975, 1.959964), (0.025, -1.959964),
                        (0.841344746, 1.0), (0.001, -3.090232)]:
            assert abs(float(normal_quantile(np.float64(p))) - want) < 1e-5
        assert normal_quantile(np.float64(0.0)) == -np.inf
        assert normal_quantile(np.float64(1.0)) == np.inf

    def test_vectorized_and_symmetric(self):
        p = np.linspace(0.01, 0.99, 99)
        z = normal_quantile(p)
        assert z.shape == p.shape
        assert np.all(np.diff(z) > 0)                    # monotone
        assert np.abs(z + z[::-1]).max() < 1e-9          # antisymmetric


class TestRankNormalize:
    def test_shape_and_pooling(self):
        rng = np.random.default_rng(0)
        draws = rng.normal(size=(4, 10))
        z = rank_normalize(draws)
        assert z.shape == draws.shape
        # z-scores are centered and order-preserving on the pooled draws
        assert abs(z.mean()) < 1e-9
        flat, zf = draws.ravel(), z.ravel()
        order = np.argsort(flat)
        assert np.all(np.diff(zf[order]) > 0)

    def test_monotone_invariance(self):
        """Ranks see through monotone transforms — exp(x) has the same
        rank-R̂ as x (the whole point vs plain split-R̂)."""
        rng = np.random.default_rng(1)
        draws = rng.normal(size=(6, 32))
        assert rank_rhat(draws) == pytest.approx(rank_rhat(np.exp(draws)))


class TestRhat:
    def test_iid_near_one(self):
        rng = np.random.default_rng(0)
        iid = rng.normal(0.5, 0.1, (8, 64))
        assert rank_rhat(iid) < 1.01
        assert folded_rank_rhat(iid) < 1.02

    def test_stuck_chains_blow_up(self):
        """Chains frozen at different levels must inflate rank-R̂ far
        past any sane threshold — with or without measurement noise."""
        rng = np.random.default_rng(0)
        stuck = np.concatenate(
            [np.full((4, 32), 0.1), np.full((4, 32), 0.9)])
        assert rank_rhat(stuck) > 1.5            # ranks separate the modes
        stuck += rng.normal(0, 1e-6, stuck.shape)
        assert rank_rhat(stuck) > 1.5
        assert split_rhat(stuck) > 1.5           # legacy fires here too —
        # its blind spot is *uniform* freezing, which no R̂ can see and
        # only the ESS gate guards (TestAsiaOrGateRegression)

    def test_folded_catches_scale_mismatch(self):
        """Chains agreeing in location but not spread pass rank-R̂ and
        fail folded-R̂ — the tail-behaviour variant."""
        rng = np.random.default_rng(2)
        mix = np.concatenate([rng.normal(0, 0.01, (4, 64)),
                              rng.normal(0, 1.0, (4, 64))])
        assert rank_rhat(mix) < 1.05
        assert folded_rank_rhat(mix) > 1.2

    def test_degenerate_inputs(self):
        assert rank_rhat(np.full((4, 8), 0.3)) == 1.0
        assert rank_rhat(np.zeros((4, 2))) == float("inf")  # too few rounds
        assert rank_rhat(np.zeros((1, 64))) == float("inf")  # one chain


class TestEss:
    def test_ess_bounded_by_total_draws(self):
        rng = np.random.default_rng(0)
        for shape in [(4, 16), (8, 64), (2, 128)]:
            draws = rng.normal(size=shape)
            assert 0 < ess_bulk(draws) <= draws.size
            assert 0 < ess_tail(draws) <= draws.size

    def test_iid_ess_near_total(self):
        rng = np.random.default_rng(0)
        iid = rng.normal(size=(8, 128))
        assert ess_bulk(iid) > 0.5 * iid.size

    def test_autocorrelated_ess_small(self):
        rng = np.random.default_rng(0)
        rho = 0.95
        ar = np.zeros((4, 256))
        x = np.zeros(4)
        for t in range(256):
            x = rho * x + rng.normal(size=4) * np.sqrt(1 - rho * rho)
            ar[:, t] = x
        # theory: ESS/N ~ (1-rho)/(1+rho) ~ 0.026
        assert ess_bulk(ar) < 0.1 * ar.size

    def test_constant_is_full_count(self):
        assert ess_bulk(np.full((4, 16), 0.3)) == 64.0
        assert ess_mean(np.zeros((2, 2))) == 0.0  # too short to estimate

    def test_tail_no_worse_than_bulk_on_heavy_tails(self):
        """Tail-ESS exists because tails mix slower: an AR chain's tail
        indicator must not report more effective draws than the cap."""
        rng = np.random.default_rng(3)
        draws = rng.standard_t(df=2, size=(8, 128))
        assert 0 < ess_tail(draws) <= draws.size


class TestSweepScaling:
    def test_iid_rounds_rescale_to_sweeps(self):
        """Round means of spr iid draws carry spr draws of information:
        the second-moment rescale must recover most of the total sweep
        count (and never exceed it)."""
        rng = np.random.default_rng(0)
        spr, c, r = 16, 8, 32
        draws = (rng.random((c, r, spr)) < 0.3).astype(np.float64)
        means, sqs = draws.mean(-1), (draws ** 2).mean(-1)
        d = compute_diagnostics(means, sqs, sweeps_per_round=spr)
        total = c * r * spr
        assert d.ess_bulk <= total
        assert d.ess_bulk > 0.5 * total
        # without second moments, ESS stays in round units
        d_rounds = compute_diagnostics(means, sweeps_per_round=spr)
        assert d_rounds.ess_bulk <= c * r

    def test_fully_correlated_rounds_do_not_inflate(self):
        """If every sweep in a round is identical (full within-round
        correlation), the rescale must collapse to ~round units, not
        claim spr times more effective draws."""
        rng = np.random.default_rng(1)
        spr, c, r = 16, 8, 32
        per_round = rng.random((c, r))          # one value per round
        means = per_round
        sqs = per_round ** 2                    # x binary-like: E[x^2]=E[x]^2
        d = compute_diagnostics(means, sqs, sweeps_per_round=spr)
        d_rounds = compute_diagnostics(means, sweeps_per_round=spr)
        assert d.ess_bulk <= 2.0 * d_rounds.ess_bulk


class TestIncremental:
    def test_matches_one_shot_exactly(self):
        """RunningDiagnostics fed per round equals compute_diagnostics
        over the pooled history — bit-exact, at every round count."""
        rng = np.random.default_rng(0)
        spr, c, r = 8, 6, 24
        means = rng.random((c, r))
        sqs = means + 0.1 * rng.random((c, r))
        run = RunningDiagnostics(sweeps_per_round=spr)
        for t in range(r):
            run.update(means[:, t], sqs[:, t])
            if t + 1 >= 4:
                assert run.compute() == compute_diagnostics(
                    means[:, :t + 1], sqs[:, :t + 1], sweeps_per_round=spr)
        assert run.rounds == r

    def test_cache_invalidation_and_legacy(self):
        rng = np.random.default_rng(0)
        run = RunningDiagnostics(sweeps_per_round=4)
        assert run.legacy_rhat() == float("inf")
        for t in range(8):
            run.update(rng.random(4), rng.random(4))
        d1 = run.compute()
        assert run.compute() is d1               # cached between updates
        run.update(rng.random(4), rng.random(4))
        assert run.compute() is not d1           # new round invalidates
        assert run.legacy_rhat() == pytest.approx(run.compute().rhat)

    def test_mixed_moment_forms_rejected(self):
        """Both transitions raise: dropping sq_c after supplying it AND
        introducing it after sq-less rounds (either way the mean/sq
        histories would silently misalign and corrupt the rescale)."""
        run = RunningDiagnostics()
        run.update(np.zeros(4), np.zeros(4))
        with pytest.raises(ValueError):
            run.update(np.zeros(4))
        run2 = RunningDiagnostics()
        run2.update(np.zeros(4))
        with pytest.raises(ValueError):
            run2.update(np.zeros(4), np.zeros(4))

    def test_rank_gate_matches_full_compute(self):
        """rank_gate() (the cheap pre-ESS check) must agree with the
        worst_rank_rhat of the full payload at every round count."""
        rng = np.random.default_rng(5)
        run = RunningDiagnostics(sweeps_per_round=4)
        assert run.rank_gate() == float("inf")
        for t in range(10):
            run.update(rng.random(6), rng.random(6))
            if t + 1 >= 4:
                assert run.rank_gate() == pytest.approx(
                    run.compute().worst_rank_rhat)


class TestEngineRetirement:
    def _registry(self):
        from repro.pgm import networks
        return {"sprinkler": networks.sprinkler(),
                "asia": networks.asia()}

    def test_diagnostics_payload_attached(self):
        from repro.serve import PosteriorEngine, Query

        eng = PosteriorEngine(self._registry(), chains_per_query=16,
                              burn_in=16, max_rounds=8)
        res = eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                               n_samples=2048))
        d = res.diagnostics
        assert isinstance(d, Diagnostics)
        assert d.sweeps_used == res.n_sweeps
        assert d.rhat == res.rhat
        assert 0 < d.min_ess <= res.n_sweeps * 16  # <= lanes x sweeps
        assert d.worst_rank_rhat == max(d.rank_rhat, d.folded_rhat)

    def test_bad_retirement_mode_rejected(self):
        from repro.serve import PosteriorEngine

        with pytest.raises(ValueError):
            PosteriorEngine({}, retirement="bogus")

    def test_ess_target_controls_retirement(self):
        """Same query, stricter per-query ess_target -> strictly more
        sweeps (the engine honours the per-query override)."""
        from repro.serve import PosteriorEngine, Query

        kw = dict(chains_per_query=16, burn_in=16, seed=0)
        loose = PosteriorEngine(self._registry(), **kw).answer(
            Query("sprinkler", {"wetgrass": 1}, ("rain",),
                  n_samples=10 ** 6, ess_target=10))
        strict = PosteriorEngine(self._registry(), **kw).answer(
            Query("sprinkler", {"wetgrass": 1}, ("rain",),
                  n_samples=10 ** 6, ess_target=10 ** 9))
        assert loose.n_sweeps < strict.n_sweeps
        assert loose.converged and not strict.converged

    def test_legacy_mode_matches_old_rule(self):
        """retirement="legacy" must reproduce the split-R̂-only rule:
        converged iff worst legacy split-R̂ < target."""
        from repro.serve import PosteriorEngine, Query

        eng = PosteriorEngine(self._registry(), chains_per_query=32,
                              burn_in=32, retirement="legacy", seed=1)
        res = eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                               n_samples=16384))
        assert res.converged == (res.rhat < eng.rhat_target)
        assert res.diagnostics is not None   # payload attached anyway


class TestAsiaOrGateRegression:
    """The ROADMAP failure mode: asia's near-deterministic OR gate.

    Conditioned on dysp=1, `tub` (an input of `either = tub OR lung`)
    is a rare event whose flips are coupled to the gate.  With 16
    chains the round means agree early, so legacy split-R̂ retires at
    the very first check with a biased marginal; the rank+ESS rule
    keeps sampling until the min-ESS gate passes and lands within
    tolerance of the exact answer.  Same configuration as the worked
    example in docs/diagnostics.md.
    """

    def test_legacy_retires_early_and_biased_rank_keeps_sampling(self):
        from repro.pgm import networks
        from repro.serve import PosteriorEngine, Query

        q = Query("asia", {"dysp": 1}, ("tub",), n_samples=10 ** 6)
        kw = dict(chains_per_query=16, burn_in=16, sweeps_per_round=16,
                  max_rounds=48, seed=0)
        legacy = PosteriorEngine({"asia": networks.asia()},
                                 retirement="legacy", **kw).answer(q)
        rank = PosteriorEngine({"asia": networks.asia()},
                               retirement="rank", **kw).answer(q)

        # legacy stopped well before rank did...
        assert legacy.converged
        assert legacy.n_sweeps < rank.n_sweeps
        # ...with an ESS far below the default target
        assert legacy.diagnostics.min_ess < 100
        # rank kept sampling until the ESS gate passed
        assert rank.converged
        assert rank.diagnostics.min_ess >= 100

        exact = networks.asia().marginals_exact({"dysp": 1})
        idx = networks.asia().index("tub")
        err_legacy = float(abs(legacy.marginal("tub") - exact[idx]).max())
        err_rank = float(abs(rank.marginal("tub") - exact[idx]).max())
        # the early retirement kept its bias; the rank answer is exact
        # to tolerance and strictly better
        assert err_rank < 0.02 < err_legacy
        assert err_rank < err_legacy


class TestRegressionGateModes:
    """check_serve_regression: ESS/s in the diff table, retirement-mode
    mismatch = setup error (exit 2), never a silent pass."""

    def _report(self, mode="rank", ess=100.0):
        return {
            "retirement": mode,
            "runs": [{
                "name": "r1",
                "warm": {"queries_per_s": 10.0, "ess_per_s": ess},
            }],
        }

    def test_mode_mismatch_is_setup_error(self):
        from benchmarks.check_serve_regression import check

        failures, setup = check(
            self._report("rank"), self._report("legacy"),
            tolerance=0.3, min_stream_speedup=1.5)
        assert any(f.metric == "retirement" for f in setup)

    def test_matching_modes_pass(self):
        from benchmarks.check_serve_regression import check

        failures, setup = check(
            self._report(), self._report(),
            tolerance=0.3, min_stream_speedup=1.5)
        assert not failures and not setup

    def test_ess_regression_fails_gate(self):
        from benchmarks.check_serve_regression import check

        failures, setup = check(
            self._report(ess=10.0), self._report(ess=100.0),
            tolerance=0.3, min_stream_speedup=1.5)
        assert any(f.metric == "r1.warm.ess_per_s" for f in failures)
        assert not setup

    def test_missing_baseline_ess_is_setup_error(self):
        from benchmarks.check_serve_regression import check

        base = self._report()
        del base["runs"][0]["warm"]["ess_per_s"]
        failures, setup = check(
            self._report(), base, tolerance=0.3, min_stream_speedup=1.5)
        assert any(f.metric == "r1.warm.ess_per_s" for f in setup)

    def test_missing_baseline_stream_ess_is_setup_error(self):
        from benchmarks.check_serve_regression import check

        cur, base = self._report(), self._report()
        for rep in (cur, base):
            rep["stream"] = {"queries_per_s": 50.0, "speedup": 2.0,
                             "identical": True, "ess_per_s": 1000.0}
        del base["stream"]["ess_per_s"]
        failures, setup = check(cur, base, tolerance=0.3,
                                min_stream_speedup=1.5)
        assert any(f.metric == "stream.ess_per_s" for f in setup)
        assert not failures
