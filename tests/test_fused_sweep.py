"""Fused Pallas sweep kernel (kernels/fused_sweep.py): bitwise identity
with the two-stage XLA path — the ``sampler="pallas"`` contract pinned
in docs/kernels.md — plus ragged batches, per-lane cardinalities, the
jnp.exp fallback, and the k-cap guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fixedpoint import DEFAULT_K
from repro.core.interp import exp_table, masked_exp_weights
from repro.core.ky import ky_sample
from repro.kernels.fused_sweep import (
    MAX_FUSED_K, fused_gibbs_sample, fused_gibbs_sample_ref)


def _logw(seed, b, n):
    p = jax.random.dirichlet(jax.random.PRNGKey(seed), jnp.ones(n), (b,))
    return jnp.log(jnp.clip(p, 1e-7, None))


def _assert_identical(got, want):
    """All four KYResult fields: sample, bits_used, attempts, ok."""
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def _two_stage(key, logw, card, k, use_iu=True):
    return ky_sample(key, masked_exp_weights(logw, card, k, use_iu=use_iu))


class TestFusedBitwise:
    @pytest.mark.parametrize("b,n", [(64, 4), (256, 16), (300, 5), (7, 3)])
    def test_matches_two_stage_xla(self, b, n):
        """The kernel, its pure-XLA ref twin, and the literal two-stage
        path agree bit for bit — non-multiple-of-block_b batches and
        non-multiple-of-128 label counts exercise the padding."""
        logw = _logw(b * 100 + n, b, n)
        key = jax.random.PRNGKey(b + n)
        xla = _two_stage(key, logw, jnp.int32(n), DEFAULT_K)
        fused = fused_gibbs_sample(key, logw, n, k=DEFAULT_K)
        ref = fused_gibbs_sample_ref(key, logw, n, k=DEFAULT_K)
        _assert_identical(fused, xla)
        _assert_identical(ref, xla)
        assert bool(fused.ok.all())

    def test_per_lane_cardinality(self):
        """Lanes with card < n mask their high labels to weight zero —
        the sparse factor-graph family's mixed-cardinality case."""
        b, n = 96, 6
        logw = _logw(7, b, n)
        card = jnp.asarray([(i % (n - 1)) + 2 for i in range(b)], jnp.int32)
        key = jax.random.PRNGKey(3)
        fused = fused_gibbs_sample(key, logw, card, k=DEFAULT_K)
        _assert_identical(fused, _two_stage(key, logw, card, DEFAULT_K))
        assert bool((fused.sample < card).all())

    def test_use_iu_false_jnp_exp_path(self):
        logw = _logw(11, 40, 4)
        key = jax.random.PRNGKey(5)
        fused = fused_gibbs_sample(key, logw, 4, k=DEFAULT_K, use_iu=False)
        _assert_identical(
            fused, _two_stage(key, logw, jnp.int32(4), DEFAULT_K,
                              use_iu=False))

    def test_explicit_table_and_k_at_cap(self):
        """A caller-supplied LUT and the largest legal k both hold the
        identity (k = MAX_FUSED_K is where masked labels are closest to
        quantizing to a nonzero weight)."""
        tab = exp_table()
        logw = _logw(13, 64, 8)
        key = jax.random.PRNGKey(9)
        fused = fused_gibbs_sample(key, logw, 8, k=MAX_FUSED_K, table=tab)
        xla = ky_sample(key, masked_exp_weights(
            logw, jnp.int32(8), MAX_FUSED_K, table=tab))
        _assert_identical(fused, xla)

    def test_k_above_cap_rejected(self):
        """k > MAX_FUSED_K would let masked labels quantize to nonzero
        weight, silently breaking the mask — refused up front."""
        with pytest.raises(ValueError, match="fused sampler requires"):
            fused_gibbs_sample(jax.random.PRNGKey(0), _logw(0, 8, 4), 4,
                               k=MAX_FUSED_K + 1)

    def test_block_b_invariance(self):
        """Results are independent of the launch geometry: the bit words
        are generated at the true lane count, so re-tiling cannot change
        the stream (the threefry counter-pairing hazard)."""
        logw = _logw(17, 100, 4)
        key = jax.random.PRNGKey(21)
        a = fused_gibbs_sample(key, logw, 4, k=DEFAULT_K, block_b=32)
        b = fused_gibbs_sample(key, logw, 4, k=DEFAULT_K, block_b=256)
        _assert_identical(a, b)
