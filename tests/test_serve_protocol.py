"""Golden protocol-conformance tests for the v2 wire schema.

Two committed fixture sets under ``tests/golden/``:

* ``wire_requests.json`` — the parse contract: wire objects that must
  parse to a specific query family (and survive an encode/parse round
  trip), plus malformed objects that must be rejected with a specific
  error.  Editing it is an API change.
* ``serve_batch.json`` — request/response pairs actually served over
  HTTP by a fresh single-worker server (seed 0).  Served responses
  must match the fixture on every field outside
  ``NONDETERMINISTIC_FIELDS``, and marginals must additionally be
  **bitwise identical** to an in-process ``answer_batch`` of the same
  parsed queries on the same seed (floats survive JSON bit-exactly via
  shortest-round-trip encoding).

Regenerate ``serve_batch.json`` after an intentional sampler/protocol
change with::

    PYTHONPATH=src python tests/test_serve_protocol.py --regen

The remaining tests drive the HTTP/WS error paths (v1 and unknown
fields rejected loudly), quota shedding (429 + Retry-After),
backpressure (503), and the observability endpoints.
"""
from __future__ import annotations

import json
import os
import sys
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serve.client import ServeClient, ServeHTTPError
from repro.serve.protocol import (
    NONDETERMINISTIC_FIELDS, WIRE_VERSION, WireError, parse_wire_request,
    request_to_wire)

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")

# config of every golden server AND the in-process identity engine —
# they must agree or the bitwise check is meaningless
ENGINE_KW = dict(chains_per_query=2, burn_in=8, seed=0)
ISING_SIDE = 6

# the committed served batch: one /v2/batch call on a fresh server
# (insertion order matters — it fixes the group layout and PRNG stream)
BATCH_REQUESTS = [
    {"v": 2, "id": "a1", "network": "asia", "evidence": {"smoke": 1},
     "query_vars": ["lung", "bronc"], "n_samples": 256},
    {"v": 2, "id": "a2", "network": "asia", "evidence": {"4": 1},
     "query_vars": ["dysp"], "n_samples": 256},
    {"v": 2, "id": "m1", "network": "asia", "evidence": {"smoke": 0},
     "query_vars": ["lung"], "mode": "map", "n_samples": 256},
    {"v": 2, "id": "i1", "network": "ising_torus",
     "clamp_sites": [[0, 1], [5, -1]], "query_vars": [1, 2, 3],
     "n_samples": 256},
]


def _load(name: str) -> dict:
    with open(os.path.join(GOLDEN, name)) as f:
        return json.load(f)


def _strip(resp: dict) -> dict:
    return {k: v for k, v in resp.items()
            if k not in NONDETERMINISTIC_FIELDS}


def _registry():
    from repro.pgm import networks
    return {"asia": networks.asia(),
            "ising_torus": networks.ising_torus(ISING_SIDE, beta=0.35)}


def _fresh_server():
    """A fresh single-worker server; fresh matters — the engine PRNG
    advances with traffic, so identity holds only for the first batch."""
    from repro.serve.engine import PosteriorEngine
    from repro.serve.server import start_in_thread
    from repro.serve.worker import WorkerPool

    registry = _registry()
    pool = WorkerPool(
        lambda name: PosteriorEngine(registry, **ENGINE_KW), 1,
        queue_kwargs={"max_wait_ms": 5.0})
    fe = start_in_thread(pool, port=0)
    return pool, fe


# -- parse contract (jax-free) ---------------------------------------------

def test_golden_wire_requests_conform():
    cases = _load("wire_requests.json")["cases"]
    assert cases, "empty golden fixture"
    for case in cases:
        wire = case["wire"]
        if "error" in case:
            with pytest.raises(WireError) as exc:
                parse_wire_request(wire)
            assert case["error"] in str(exc.value), (case, str(exc.value))
            assert exc.value.code == 400
            assert exc.value.body["v"] == WIRE_VERSION
            assert case["error"] in exc.value.body["error"]
        else:
            q, rid = parse_wire_request(wire)
            assert type(q).__name__ == case["family"], case
            assert rid == wire.get("id")
            # digit-string JSON keys decode back to integer node indices
            for k in case.get("int_keys", ()):
                assert k in q.evidence, (case, q.evidence)
            # encode/parse round trip is lossless
            q2, rid2 = parse_wire_request(
                json.loads(json.dumps(request_to_wire(q, id=rid))))
            assert q2 == q and rid2 == rid


# -- served golden batch ----------------------------------------------------

def test_golden_batch_matches_fixture_and_in_process_bitwise():
    fixture = _load("serve_batch.json")
    assert fixture["requests"] == BATCH_REQUESTS, \
        "fixture out of date: regenerate with --regen (see module doc)"
    pool, fe = _fresh_server()
    try:
        client = ServeClient("127.0.0.1", fe.port)
        responses = client.query_batch(BATCH_REQUESTS)
    finally:
        fe.stop_thread()
        pool.close(drain=False, timeout=10.0)

    # 1) protocol conformance vs the committed fixture
    assert len(responses) == len(fixture["responses"])
    for got, want, req in zip(responses, fixture["responses"],
                              BATCH_REQUESTS):
        assert got["id"] == req["id"]
        assert _strip(got) == _strip(want), req["id"]

    # 2) bitwise identity vs in-process answer_batch on the same seed
    from repro.serve.engine import PosteriorEngine
    from repro.serve.protocol import wire_marginals

    queries = [parse_wire_request(w)[0] for w in BATCH_REQUESTS]
    results = PosteriorEngine(_registry(), **ENGINE_KW).answer_batch(queries)
    for wire_r, r in zip(responses, results):
        if r.map_assignment is not None:
            assert wire_r["map_assignment"] == \
                {str(k): v for k, v in r.map_assignment.items()}
            assert wire_r["map_energy"] == r.map_energy
            continue
        served = wire_marginals(wire_r)
        assert set(served) == {str(k) for k in r.marginals}
        for name, m in r.marginals.items():
            assert np.array_equal(
                served[str(name)], np.asarray(m, np.float64)), \
                f"marginal {name!r} not bitwise identical over the wire"


# -- HTTP/WS behaviour on a shared warm server ------------------------------

@pytest.fixture(scope="module")
def served():
    pool, fe = _fresh_server()
    client = ServeClient("127.0.0.1", fe.port)
    client.wait_ready(30.0)
    yield SimpleNamespace(pool=pool, fe=fe, client=client)
    fe.stop_thread()
    pool.close(drain=False, timeout=10.0)


def test_v1_rejected_loudly_over_http(served):
    with pytest.raises(ServeHTTPError) as exc:
        served.client.query({"v": 1, "network": "asia",
                             "evidence": {"smoke": 1}})
    assert exc.value.status == 400
    assert "v1 is not accepted" in exc.value.body["error"]
    assert exc.value.body["v"] == WIRE_VERSION


def test_unknown_field_rejected_loudly_over_http(served):
    with pytest.raises(ServeHTTPError) as exc:
        served.client.query({"v": 2, "network": "asia",
                             "evidnce": {"smoke": 1}})
    assert exc.value.status == 400
    assert "'evidnce'" in exc.value.body["error"]


def test_unknown_network_is_a_400_not_a_dropped_connection(served):
    with pytest.raises(ServeHTTPError) as exc:
        served.client.query({"v": 2, "network": "nope",
                             "evidence": {"x": 0}})
    assert exc.value.status == 400
    assert "nope" in exc.value.body["error"]


def test_ws_stream_echoes_ids_and_answers_bad_frames(served):
    reqs = [
        {"v": 2, "id": "s0", "network": "asia",
         "evidence": {"smoke": 1}, "query_vars": ["lung"],
         "n_samples": 64},
        {"v": 2, "id": "bad", "network": "asia", "evidnce": {}},
        {"v": 2, "id": "s2", "network": "asia",
         "evidence": {"smoke": 0}, "query_vars": ["lung"],
         "n_samples": 64},
    ]
    out = served.client.stream(reqs)
    assert [r["id"] for r in out] == ["s0", "bad", "s2"]
    assert out[0]["status"] == 200 and out[2]["status"] == 200
    assert out[0]["marginals"] and out[2]["marginals"]
    # the malformed frame gets an error *response*, not a hung id
    assert out[1]["status"] == 400
    assert "'evidnce'" in out[1]["error"]


def test_quota_shed_is_429_with_retry_after(served):
    from repro.serve.server import start_in_thread

    # second front end over the same (warm) pool: 1 token, refilled at
    # a rate far slower than the test, so request #2 must shed
    fe = start_in_thread(served.pool, port=0, quota_qps=0.001,
                         quota_burst=1)
    try:
        client = ServeClient("127.0.0.1", fe.port)
        ok = client.query({"v": 2, "network": "asia",
                           "evidence": {"smoke": 1},
                           "query_vars": ["lung"], "n_samples": 64,
                           "tenant": "acme"})
        assert ok["converged"] in (True, False)
        with pytest.raises(ServeHTTPError) as exc:
            client.query({"v": 2, "network": "asia",
                          "evidence": {"smoke": 1},
                          "query_vars": ["lung"], "n_samples": 64,
                          "tenant": "acme"})
        assert exc.value.status == 429
        assert "'acme'" in exc.value.body["error"]
        assert exc.value.retry_after is not None
        assert exc.value.retry_after > 0
        # other tenants have their own bucket
        other = client.query({"v": 2, "network": "asia",
                              "evidence": {"smoke": 1},
                              "query_vars": ["lung"], "n_samples": 64,
                              "tenant": "zeta"})
        assert other["v"] == WIRE_VERSION
        assert client.stats()["shed"]["quota"] == 1
    finally:
        fe.stop_thread()


def test_backpressure_shed_is_503_with_retry_after(served):
    from repro.serve.server import start_in_thread

    fe = start_in_thread(served.pool, port=0, max_pending=0)
    try:
        client = ServeClient("127.0.0.1", fe.port)
        with pytest.raises(ServeHTTPError) as exc:
            client.query({"v": 2, "network": "asia",
                          "evidence": {"smoke": 1}, "n_samples": 64})
        assert exc.value.status == 503
        assert "backpressure" in exc.value.body["error"]
        assert exc.value.retry_after is not None
        assert client.stats()["shed"]["backpressure"] == 1
    finally:
        fe.stop_thread()


def test_observability_endpoints(served):
    assert served.client.healthz()["ok"] is True
    stats = served.client.stats()
    assert stats["v"] == WIRE_VERSION
    assert set(stats) >= {"pending", "served", "shed", "workers"}
    assert "w0" in stats["workers"]
    metrics = served.client.metrics()
    assert "serve_" in metrics


def test_docs_serving_doctests():
    """Every ``>>>`` example in docs/serving.md runs and prints what it
    claims — including the "Running as a service" section, which starts
    a real front end on an ephemeral port."""
    import doctest

    path = os.path.join(os.path.dirname(GOLDEN), os.pardir, "docs",
                        "serving.md")
    failures, tests = doctest.testfile(
        path, module_relative=False,
        optionflags=doctest.NORMALIZE_WHITESPACE)
    assert tests > 0, "no doctest examples found in serving.md"
    assert failures == 0


# -- fixture regeneration ---------------------------------------------------

def _regen() -> None:
    pool, fe = _fresh_server()
    try:
        responses = ServeClient(
            "127.0.0.1", fe.port).query_batch(BATCH_REQUESTS)
    finally:
        fe.stop_thread()
        pool.close(drain=False, timeout=10.0)
    out = os.path.join(GOLDEN, "serve_batch.json")
    with open(out, "w") as f:
        json.dump({
            "_comment": [
                "Golden served /v2/batch pairs: a fresh single-worker",
                "server (ENGINE_KW in tests/test_serve_protocol.py,",
                "seed 0) serving BATCH_REQUESTS.  Regenerate with:",
                "  PYTHONPATH=src python tests/test_serve_protocol.py "
                "--regen",
            ],
            "engine": {**ENGINE_KW, "ising_side": ISING_SIDE},
            "requests": BATCH_REQUESTS,
            "responses": responses,
        }, f, indent=1)
        f.write("\n")
    print(f"wrote {out} ({len(responses)} responses)")


if __name__ == "__main__":
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
