"""Masked-MRF serving: clamp-mask correctness (single-device and mesh
Gibbs), masked marginals vs the exact conditional, served-vs-direct and
queued-vs-batched identity, mask-pattern plan caching, and the sharded
MRF serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pgm import (
    clamp_labels, compile_mrf, init_labels, init_mrf_states, mask_of,
    mrf_gibbs, networks)
from repro.pgm.graph import MRFGrid
from repro.serve import (
    AdmissionQueue, MrfQuery, PosteriorEngine, plan_key)
from repro.serve.plan_cache import pattern_key


def _two_site() -> MRFGrid:
    """1x2 grid whose conditionals are enumerable by hand."""
    unary = np.zeros((1, 2, 2), np.float32)
    unary[0, 0] = [0.0, 1.0]   # site 0 prefers label 0
    unary[0, 1] = [0.5, 0.0]   # site 1 prefers label 1
    return MRFGrid.potts(unary, beta=0.7)


def _scribble(h, w, seed=0, frac=0.15):
    rng = np.random.default_rng(seed)
    mask = rng.random((h, w)) < frac
    values = rng.integers(0, 2, (h, w))
    return mask, values


class TestClampMask:
    def test_clamped_sites_never_flip(self):
        """The headline invariant: under a clamp mask, observed pixels
        keep their pinned labels through every sweep while free pixels
        do get resampled."""
        mrf, truth = networks.penguin_task(h=12, w=10)
        mask, _ = _scribble(12, 10, seed=1, frac=0.3)
        values = np.where(mask, truth, 0)
        lab0 = clamp_labels(
            init_labels(jax.random.PRNGKey(0), mrf, 4), mask, values)
        lab, _ = mrf_gibbs(
            jax.random.PRNGKey(1), lab0, jnp.asarray(mrf.unary),
            jnp.asarray(mrf.pairwise), n_sweeps=25, clamp=jnp.asarray(mask))
        out = np.asarray(lab)
        assert (out[:, mask] == values[mask]).all()
        free0, free = np.asarray(lab0)[:, ~mask], out[:, ~mask]
        assert (free0 != free).any()  # the sampler did visit free sites

    def test_clamp_excluded_from_bit_accounting(self):
        """Clamped sites draw no random bits: a heavier mask must spend
        strictly fewer bits over the same sweeps."""
        mrf, _ = networks.penguin_task(h=16, w=16)
        lab = init_labels(jax.random.PRNGKey(0), mrf, 2)
        mask, values = _scribble(16, 16, seed=2, frac=0.5)
        _, s_clamped = mrf_gibbs(
            jax.random.PRNGKey(1), clamp_labels(lab, mask, values),
            jnp.asarray(mrf.unary), jnp.asarray(mrf.pairwise),
            n_sweeps=5, clamp=jnp.asarray(mask))
        _, s_free = mrf_gibbs(
            jax.random.PRNGKey(1), lab, jnp.asarray(mrf.unary),
            jnp.asarray(mrf.pairwise), n_sweeps=5)
        assert int(s_clamped.bits_used) < int(s_free.bits_used)

    def test_clamped_neighbours_feel_the_evidence(self):
        """A clamped site must keep contributing pairwise energy: on a
        strong ferromagnetic Potts grid with uniform unaries, clamping
        one site drags its free neighbour to the same label."""
        h = w = 3
        mrf = MRFGrid.potts(np.zeros((h, w, 2), np.float32), beta=3.0)
        mask = np.zeros((h, w), bool)
        mask[1, 1] = True
        values = np.ones((h, w), np.int64)
        eng = PosteriorEngine({"g": mrf}, chains_per_query=32, burn_in=32,
                              max_rounds=8)
        res = eng.answer(MrfQuery("g", mask, values,
                                  query_sites=((1, 0),), n_samples=8192))
        assert res.marginal("s1,0")[1] > 0.8  # pulled toward the clamp

    def test_compile_mrf_validation(self):
        mrf = _two_site()
        with pytest.raises(ValueError):
            compile_mrf(mrf, observed=(0, 1))       # all sites clamped
        with pytest.raises(ValueError):
            compile_mrf(mrf, observed=(5,))         # outside the lattice
        prog = compile_mrf(mrf, observed=(1,))
        assert mask_of(prog).tolist() == [[False, True]]
        assert (prog.n_sites, prog.n_free) == (2, 1)
        with pytest.raises(ValueError):
            init_mrf_states(jax.random.PRNGKey(0), prog, 2)  # no values


class TestMaskedMarginals:
    def test_two_site_matches_exact_conditional(self):
        """Masked 2-site grid: the served marginal of the free site
        equals the hand-enumerated conditional P(x1 | x0 = v)."""
        mrf = _two_site()
        eng = PosteriorEngine({"tiny": mrf}, chains_per_query=64,
                              burn_in=32, max_rounds=16)
        for v0 in (0, 1):
            mask = np.array([[True, False]])
            values = np.array([[v0, 0]])
            res = eng.answer(MrfQuery("tiny", mask, values,
                                      query_sites=((0, 1),),
                                      n_samples=30_000))
            e = mrf.unary[0, 1] + mrf.pairwise[:, v0]
            p = np.exp(-e)
            p /= p.sum()
            assert np.abs(res.marginal("s0,1") - p).max() < 0.03, (v0, p)

    def test_served_matches_direct_clamped_gibbs(self):
        """Engine marginals agree with a long direct ``mrf_gibbs`` run
        under the same clamp mask — the two code paths sample the same
        conditional distribution."""
        mrf, truth = networks.penguin_task(h=6, w=6, beta=1.0)
        mask = np.zeros((6, 6), bool)
        mask[0, :] = True
        values = np.where(mask, truth, 0)
        site = (3, 3)

        eng = PosteriorEngine({"p": mrf}, chains_per_query=64, burn_in=64,
                              max_rounds=32)
        res = eng.answer(MrfQuery("p", mask, values, query_sites=(site,),
                                  n_samples=60_000))

        lab = clamp_labels(
            init_labels(jax.random.PRNGKey(0), mrf, 256), mask, values)
        counts = np.zeros(2)
        key = jax.random.PRNGKey(1)
        for i in range(80):
            key, sub = jax.random.split(key)
            lab, _ = mrf_gibbs(sub, lab, jnp.asarray(mrf.unary),
                               jnp.asarray(mrf.pairwise), n_sweeps=1,
                               clamp=jnp.asarray(mask))
            if i >= 20:
                s = np.asarray(lab)[:, site[0], site[1]]
                counts += np.bincount(s, minlength=2)
        direct = counts / counts.sum()
        assert np.abs(res.marginal(f"s{site[0]},{site[1]}") - direct).max() \
            < 0.05, (res.marginal(f"s{site[0]},{site[1]}"), direct)

    def test_unmasked_query_serves_prior(self):
        """No mask at all is legal: the engine samples the unconditioned
        grid (pattern = ())."""
        mrf = _two_site()
        eng = PosteriorEngine({"tiny": mrf}, chains_per_query=32,
                              burn_in=32, max_rounds=8)
        res = eng.answer(MrfQuery("tiny", n_samples=4096))
        assert set(res.marginals) == {"s0,0", "s0,1"}
        for m in res.marginals.values():
            assert abs(m.sum() - 1.0) < 1e-9


class TestMrfQueryNormalization:
    def test_bad_queries_fail_fast(self):
        mrf, _ = networks.penguin_task(h=4, w=4)
        eng = PosteriorEngine({"p": mrf})
        mask = np.zeros((4, 4), bool)
        mask[0, 0] = True
        with pytest.raises(ValueError):   # mask without values
            eng.normalize(MrfQuery("p", mask))
        with pytest.raises(ValueError):   # label outside [0, L)
            eng.normalize(MrfQuery("p", mask, np.full((4, 4), 7)))
        with pytest.raises(ValueError):   # wrong mask shape
            eng.normalize(MrfQuery("p", np.zeros((3, 3), bool)))
        with pytest.raises(ValueError):   # query site is observed
            eng.normalize(MrfQuery("p", mask, np.zeros((4, 4)),
                                   query_sites=((0, 0),)))
        with pytest.raises(KeyError):     # query site outside lattice
            eng.normalize(MrfQuery("p", query_sites=((9, 9),)))
        with pytest.raises(ValueError):   # conflicting sparse evidence
            eng.normalize(MrfQuery("p", mask_sites=((0, 0, 1), (0, 0, 0))))
        with pytest.raises(ValueError):   # col == w must not alias (1, 0)
            eng.normalize(MrfQuery("p", mask_sites=((0, 4, 1),)))
        with pytest.raises(ValueError):   # everything clamped
            eng.normalize(MrfQuery("p", np.ones((4, 4), bool),
                                   np.zeros((4, 4))))

    def test_sparse_and_dense_masks_share_a_pattern(self):
        """mask_sites triples and a dense mask describing the same
        pixels normalize to the same evidence pattern (and therefore
        the same plan-cache entry and queue bucket)."""
        mrf, _ = networks.penguin_task(h=4, w=4)
        eng = PosteriorEngine({"p": mrf})
        mask = np.zeros((4, 4), bool)
        mask[1, 2] = mask[3, 0] = True
        values = np.zeros((4, 4), np.int64)
        values[1, 2] = 1
        _, ev_d, _, pat_d = eng.normalize(MrfQuery("p", mask, values))
        _, ev_s, _, pat_s = eng.normalize(
            MrfQuery("p", mask_sites=((1, 2, 1), (3, 0, 0))))
        assert ev_d == ev_s and pat_d == pat_s


class TestMrfPlanCache:
    def test_same_mask_hits_different_mask_misses(self):
        mrf, _ = networks.penguin_task(h=6, w=6)
        eng = PosteriorEngine({"p": mrf}, chains_per_query=8, burn_in=16,
                              max_rounds=4)
        mask, values = _scribble(6, 6, seed=0, frac=0.2)
        q = MrfQuery("p", mask, values, query_sites=_free_sites(mask, 2),
                     n_samples=256)
        eng.answer(q)
        assert eng.cache.stats.misses == 1
        # same mask, different observed labels -> hit, no recompile
        eng.answer(MrfQuery("p", mask, 1 - values,
                            query_sites=_free_sites(mask, 2), n_samples=256))
        assert (eng.cache.stats.hits, eng.cache.stats.misses) == (1, 1)
        mask2, values2 = _scribble(6, 6, seed=9, frac=0.2)
        eng.answer(MrfQuery("p", mask2, values2,
                            query_sites=_free_sites(mask2, 2), n_samples=256))
        assert (eng.cache.stats.hits, eng.cache.stats.misses) == (1, 2)

    def test_long_patterns_fold_to_digest(self):
        """Kilo-pixel masks make bounded-size cache keys, and distinct
        masks never share one."""
        a = tuple(range(1000))
        b = tuple(range(1, 1001))
        ka, kb = pattern_key(a), pattern_key(b)
        assert ka != kb and len(ka) == 3 and ka[0] == "sha1"
        assert pattern_key((1, 2, 3)) == (1, 2, 3)  # short stays verbatim
        kw = dict(k=12, use_iu=True, quantize_cpt_bits=16,
                  sweeps_per_round=16, thin=1)
        assert plan_key("m", a, **kw) != plan_key("m", b, **kw)


def _free_sites(mask, n):
    rs, cs = np.nonzero(~mask)
    return tuple((int(rs[i]), int(cs[i])) for i in range(n))


class TestMrfQueueServing:
    def test_streamed_identical_to_answer_batch(self):
        """The acceptance bit: masked-MRF queries served through the
        admission queue (bucketed by mask pattern, packed into one
        GroupRun) are bit-identical to ``answer_batch`` over the same
        traffic with the same seed."""
        mrf, _ = networks.penguin_task(h=8, w=8)
        mask_a, values = _scribble(8, 8, seed=0, frac=0.2)
        mask_b, _ = _scribble(8, 8, seed=1, frac=0.2)
        traffic = [
            MrfQuery("p", mask_a, values, _free_sites(mask_a, 2),
                     n_samples=2048),
            MrfQuery("p", mask_b, values, _free_sites(mask_b, 1),
                     n_samples=1024),
            MrfQuery("p", mask_a, 1 - values, _free_sites(mask_a, 2),
                     n_samples=2048),
        ]
        kw = dict(chains_per_query=8, burn_in=16, max_rounds=8)
        ref = PosteriorEngine({"p": mrf}, **kw, seed=11).answer_batch(traffic)
        eng = PosteriorEngine({"p": mrf}, **kw, seed=11)
        queue = AdmissionQueue(eng, max_wait_ms=3_600_000.0,
                               max_group_lanes=len(traffic) * 8)
        try:
            handles = [queue.submit(q) for q in traffic]
            queue.flush()
            streamed = [h.result(timeout=600) for h in handles]
        finally:
            queue.close()
        # two mask_a queries share one bucket/plan; mask_b gets its own
        assert eng.cache.stats.misses == 2
        for a, b in zip(ref, streamed):
            assert a.n_samples == b.n_samples and a.rhat == b.rhat
            assert set(a.marginals) == set(b.marginals)
            for k in a.marginals:
                assert np.array_equal(a.marginals[k], b.marginals[k])

    def test_mixed_family_batch(self):
        """One batch spanning a BayesNet and an MRF comes back in
        request order with the right marginal namespaces."""
        from repro.serve import Query

        mrf, _ = networks.penguin_task(h=6, w=6)
        registry = {"sprinkler": networks.sprinkler(), "p": mrf}
        eng = PosteriorEngine(registry, chains_per_query=8, burn_in=16,
                              max_rounds=4)
        mask, values = _scribble(6, 6, seed=3, frac=0.2)
        res = eng.answer_batch([
            Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=512),
            MrfQuery("p", mask, values, _free_sites(mask, 2), n_samples=512),
        ])
        assert set(res[0].marginals) == {"rain"}
        assert all(name.startswith("s") for name in res[1].marginals)
        assert eng.cache.stats.misses == 2


@pytest.mark.slow
class TestMeshClamp:
    def test_mesh_clamped_sites_frozen_and_conditioned(self):
        """Distributed clamped Gibbs: observed pixels never flip across
        halo-exchange sweeps (including tile-boundary pixels), and the
        clamp conditions neighbours exactly like the single-device
        sampler — checked on a non-tile-multiple grid so the clamp mask
        composes with the pad-validity mask."""
        from conftest import run_subprocess

        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_pgm_mesh
from repro.pgm.graph import MRFGrid
from repro.pgm.gibbs import clamp_labels, init_labels, mrf_gibbs
from repro.pgm.mesh_gibbs import (
    make_mesh_gibbs_step, shard_clamp, shard_mrf)
h, w, beta = 11, 9, 2.5   # pads to 12x10 on a 2x2 mesh
mrf = MRFGrid.potts(np.zeros((h, w, 2), np.float32), beta=beta)
rng = np.random.default_rng(0)
mask = rng.random((h, w)) < 0.2
mask[5, :] = True          # a stroke crossing the tile boundary
values = np.ones((h, w), np.int64)   # clamp everything observed to 1
mesh = make_pgm_mesh(2, 2)
key = jax.random.PRNGKey(0)
lab, u, pw, valid, _ = shard_mrf(mesh, mrf, n_chains=32, key=key)
lab, clamp_dev = shard_clamp(mesh, mask, values, lab)
step = make_mesh_gibbs_step(mesh, clamped=True)
burn, keep = 30, 90
freq = np.zeros((h, w))
for i in range(burn + keep):
    key, sub = jax.random.split(key)
    lab, _ = step(sub, lab, u, pw, valid, clamp_dev)
    out = np.asarray(lab)[:, :h, :w]
    assert (out[:, mask] == 1).all(), f"clamp broke at sweep {i}"
    if i >= burn:
        freq += (out == 1).mean(0)
freq /= keep
# ferromagnetic pull: free sites lean to the clamped label, strongly so
# next to the stroke
assert freq[~mask].mean() > 0.6, freq[~mask].mean()
assert freq[4, :].mean() > 0.8, freq[4, :].mean()
# single-device clamped reference agrees sitewise
lab1 = clamp_labels(init_labels(jax.random.PRNGKey(5), mrf, 32),
                    mask, values)
ref = np.zeros((h, w))
k2 = jax.random.PRNGKey(6)
for i in range(burn + keep):
    k2, sub = jax.random.split(k2)
    lab1, _ = mrf_gibbs(sub, lab1, jnp.asarray(mrf.unary),
                        jnp.asarray(mrf.pairwise), n_sweeps=1,
                        clamp=jnp.asarray(mask))
    if i >= burn:
        ref += (np.asarray(lab1) == 1).mean(0)
ref /= keep
assert np.abs(freq - ref)[~mask].max() < 0.15
print("OK", freq[~mask].mean(), ref[~mask].mean())
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out

    def test_sharded_mrf_serve_matches_single_device(self):
        """The mesh serve path for MRF queries: a forced-host 4-device
        batch mesh returns bit-identical marginals to the single-device
        engine (same seeds, lane axis sharded over "batch")."""
        from conftest import run_subprocess

        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.launch.mesh import make_serve_mesh
from repro.pgm import networks
from repro.serve import MrfQuery, PosteriorEngine
mrf, _ = networks.penguin_task(h=8, w=8)
rng = np.random.default_rng(0)
mask = rng.random((8, 8)) < 0.2
values = rng.integers(0, 2, (8, 8))
rs, cs = np.nonzero(~mask)
sites = tuple((int(rs[i]), int(cs[i])) for i in range(3))
qs = [MrfQuery("p", mask, values, sites, n_samples=4096),
      MrfQuery("p", mask, 1 - values, sites, n_samples=4096)]
kw = dict(chains_per_query=8, burn_in=32, max_rounds=8, seed=3)
mesh = make_serve_mesh((4,))
sharded = PosteriorEngine({"p": mrf}, mesh=mesh, **kw).answer_batch(qs)
single = PosteriorEngine({"p": mrf}, **kw).answer_batch(qs)
for rs_, r1 in zip(sharded, single):
    assert set(rs_.marginals) == set(r1.marginals)
    for var in rs_.marginals:
        np.testing.assert_allclose(rs_.marginal(var), r1.marginal(var),
                                   atol=1e-12)
print("OK")
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out
