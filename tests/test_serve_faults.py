"""Fault-injection tests for the serving stack.

ISSUE hardening targets:

* kill a worker mid-group — in-flight queries fail *loudly* with
  ``WorkerDied(resubmit=False)``, never-dispatched ones with
  ``resubmit=True`` (safe to replay on another worker), and no
  ``QueryHandle`` future is ever left hanging;
* the pool resubmits the resubmittable kind on a surviving worker;
* cancelling a ``stream_id`` query after dispatch but before
  retirement invalidates the stream's retained chains instead of
  leaking stale state to the next slice.

Worker-death tests run against fake engines/group-runs patched in at
the queue's ``_group_run`` seam (scheduling logic is real, sampling is
not); the stream-invalidation tests drive the real engine on a tiny
network.
"""
from __future__ import annotations

import itertools
import threading
import time

import pytest

from repro.serve.query import Query, QueryCancelled, QueryStatus
from repro.serve.worker import Worker, WorkerDied, WorkerPool


class FakeEngine:
    chains_per_query = 1
    mesh = None

    def __init__(self):
        from repro.serve.telemetry import NULL
        self.telemetry = NULL
        self._query_seq = itertools.count()

    def normalize(self, query):
        return (None, dict(query.evidence), tuple(query.query_vars),
                tuple(sorted(query.evidence)))

    def stats(self):
        return {}


class _Slot:
    def __init__(self, entry):
        self.entry, self.done = entry, False


class EndlessRun:
    """Never retires: each round is a short sleep, so an abort is
    honoured at the next round boundary within milliseconds."""

    def __init__(self, batch, started):
        self.slots = [_Slot(e) for e in batch]
        self._started = started

    @property
    def active(self):
        return any(not s.done for s in self.slots)

    def free_slots(self):
        return 0

    def predicted_remaining_rounds(self):
        return 1 << 20

    def cancel(self, entry):
        for s in self.slots:
            if s.entry is entry and not s.done:
                s.done = True
                return True
        return False

    def admit(self, entry):
        raise AssertionError("free_slots()=0, admit must not be called")

    def step(self):
        self._started.set()
        time.sleep(0.005)
        return []


class OneShotRun(EndlessRun):
    """Retires everything on the first step."""

    def step(self):
        retired = []
        for s in self.slots:
            if not s.done:
                s.done = True
                s.entry.result = object()
                retired.append(s.entry)
        return retired


def _patch_runs(worker, run_cls, started=None):
    ev = started or threading.Event()
    worker.queue._group_run = \
        lambda name, pattern, batch: run_cls(batch, ev)
    return ev


def test_worker_kill_mid_group_fails_loudly_no_hung_futures():
    w = Worker("w0", FakeEngine(),
               queue_kwargs={"max_wait_ms": 1.0, "max_group_lanes": 1})
    started = _patch_runs(w, EndlessRun)
    inflight = w.submit(Query("net", {"a": 0}, ("x",)))
    assert started.wait(10.0), "group never dispatched"
    # same bucket, dispatcher busy: stays pending on the dead worker
    pending = w.submit(Query("net", {"a": 0}, ("x",)))

    w.kill("chaos-monkey", timeout=30.0)

    assert not w.queue._thread.is_alive(), "dispatcher hung after kill"
    for h in (inflight, pending):
        assert h.done(), "kill left a QueryHandle hanging"
        assert h.status is QueryStatus.FAILED
    with pytest.raises(WorkerDied) as exc:
        inflight.result(timeout=0)
    assert exc.value.resubmit is False, \
        "mid-group work may have streamed effects: must not auto-replay"
    with pytest.raises(WorkerDied) as exc:
        pending.result(timeout=0)
    assert exc.value.resubmit is True, \
        "never-dispatched queries are safe to replay elsewhere"
    # killing twice is a no-op, and submitting to a corpse fails fast
    w.kill("again")
    with pytest.raises(WorkerDied):
        w.submit(Query("net", {"a": 0}, ("x",)))


def test_pool_resubmits_on_surviving_worker():
    pool = WorkerPool(lambda name: FakeEngine(), 2,
                      queue_kwargs={"max_wait_ms": 1.0})
    for w in pool.workers.values():
        _patch_runs(w, OneShotRun)
    q = Query("net", {"a": 0}, ("x",))
    routed, h = pool.submit(q)
    assert h.result(timeout=30.0) is not None

    pool.kill(routed.name, "chaos-monkey")
    survivor, h2 = pool.submit(q)            # same plan key, rerouted
    assert survivor.name != routed.name
    assert h2.result(timeout=30.0) is not None
    assert pool.stats()[routed.name]["dead"] is True

    pool.kill(survivor.name, "total outage")
    with pytest.raises(WorkerDied):
        pool.submit(q)
    pool.close(drain=False, timeout=10.0)


def test_cancelled_stream_slice_invalidates_retained_state():
    """GroupRun.cancel on a stream slice must drop the stream's
    retained chains: the cancelled slice already warm-started from
    them, so letting the *next* slice warm-start from the same
    pre-cancel state would silently rewind the stream."""
    from repro.pgm import networks
    from repro.serve.engine import GroupEntry, GroupRun, PosteriorEngine

    eng = PosteriorEngine({"sprinkler": networks.sprinkler()},
                          chains_per_query=2, burn_in=2, seed=0)
    key = ("sprinkler", "cam")
    [r1] = eng.answer_batch([Query(
        "sprinkler", {"cloudy": 1}, ("rain",), n_samples=32,
        stream_id="cam")])
    assert key in eng._retained, "slice 1 must retain its chains"

    q2 = Query("sprinkler", {"cloudy": 0}, ("rain",), n_samples=32,
               stream_id="cam")
    _, ev, qvars, pattern = eng.normalize(q2)
    entry = GroupEntry(q2, ev, qvars)
    run = GroupRun(eng, "sprinkler", pattern, [entry])
    assert run.cancel(entry) is True
    assert key not in eng._retained, \
        "cancelled slice leaked stale retained stream state"
    # idempotent: invalidating an absent stream reports False
    assert eng.invalidate_stream("sprinkler", "cam") is False


def test_stream_cancel_after_dispatch_via_queue():
    """End-to-end mid-flight path: cancel lands after dispatch, the
    handle resolves CANCELLED (not hung, not DONE), and no stream
    state is retained for the cancelled slice."""
    from repro.pgm import networks
    from repro.serve.engine import PosteriorEngine
    from repro.serve.queue import AdmissionQueue

    # unreachable ESS target: the slice cannot retire before its cap,
    # so the cancel reliably lands mid-flight
    eng = PosteriorEngine({"sprinkler": networks.sprinkler()},
                          chains_per_query=2, burn_in=2, seed=0)
    q = AdmissionQueue(eng, max_wait_ms=2.0)
    h = q.submit(Query("sprinkler", {"cloudy": 1}, ("rain",),
                       n_samples=8192, ess_target=1e9, stream_id="cam"))
    deadline = time.monotonic() + 60.0
    while h.status is not QueryStatus.RUNNING:
        assert h.status is QueryStatus.QUEUED, h.status
        assert time.monotonic() < deadline, "query never dispatched"
        time.sleep(0.002)
    h.cancel()
    with pytest.raises(QueryCancelled):
        h.result(timeout=60.0)
    q.close(drain=True, timeout=30.0)
    assert ("sprinkler", "cam") not in eng._retained
    assert q.stats.cancelled_in_flight == 1
