"""Per-arch smoke tests (reduced configs, one fwd/train step, shape +
finite checks) and model-level correctness: prefill/decode consistency,
SSD chunked-vs-recurrent, MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ModelConfig
from repro.models.layers import unembed
from repro.models.moe import apply_moe, init_moe
from repro.models.sampling import generate
from repro.models.ssm import apply_ssm, init_ssm, init_ssm_state
from repro.models.transformer import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_model,
    loss_fn,
    prefill_cross_cache,
)


def _smoke_batch(cfg, b=2, s=16):
    # Varied tokens with labels != tokens: a constant batch whose label
    # equals its input saturates the tied-embedding softmax (gold logit
    # wins by >16 nats) and the xent gradient rounds to exactly 0 in
    # fp32, which falsely fails the gradient-flow check on SSM archs.
    k1, k2 = jax.random.split(jax.random.PRNGKey(42))
    batch = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab, jnp.int32),
             "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "vlm":
        batch["frontend"] = jnp.zeros((b, cfg.frontend_tokens, cfg.d_model),
                                      jnp.float32)
    if cfg.family in ("encdec", "audio"):
        batch["src_embeds"] = jnp.zeros((b, cfg.enc_seq_len, cfg.d_model),
                                        jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_config(arch, smoke=True)
        params = init_model(jax.random.PRNGKey(0), cfg)
        batch = _smoke_batch(cfg)
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch, 8)
        assert np.isfinite(float(loss)), arch
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gn) and gn > 0, arch

    def test_decode_step(self, arch):
        cfg = get_config(arch, smoke=True)
        params = init_model(jax.random.PRNGKey(0), cfg)
        b = 2
        cache = init_cache(cfg, b, 32)
        if cfg.family in ("encdec", "audio"):
            src = jnp.zeros((b, cfg.enc_seq_len, cfg.d_model), jnp.float32)
            eo = encode(params, cfg, src.astype(cfg.dtype), 8)
            cache = prefill_cross_cache(params, cfg, eo, cache)
        logits, cache2 = decode_step(
            params, cfg, jnp.ones((b, 1), jnp.int32), jnp.int32(0), cache)
        assert logits.shape == (b, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


class TestConsistency:
    @pytest.mark.parametrize("arch", ["qwen1.5-32b", "granite-20b",
                                      "mamba2-130m", "hymba-1.5b",
                                      "grok-1-314b"])
    def test_prefill_decode_agree(self, arch):
        # exact agreement with a bf16->f32 cache (int8 checked separately)
        cfg = get_config(arch, smoke=True).replace(
            dtype="float32", cache_dtype="bfloat16")
        params = init_model(jax.random.PRNGKey(1), cfg)
        b, s = 1, 8
        toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
        h = forward(params, cfg, toks, q_block=8)
        full = unembed(params["embed"], cfg, h)
        cache = init_cache(cfg, b, s)
        outs = []
        for t in range(s):
            lg, cache = decode_step(params, cfg, toks[:, t:t + 1],
                                    jnp.int32(t), cache)
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        err = float(jnp.max(jnp.abs(full - dec)))
        assert err < 5e-3, (arch, err)

    def test_int8_cache_decode_close(self):
        """The adopted int8 KV cache (§Perf A) stays within 5% relative
        logit error of the exact prefill."""
        cfg = get_config("qwen1.5-32b", smoke=True).replace(
            dtype="float32", cache_dtype="int8")
        params = init_model(jax.random.PRNGKey(1), cfg)
        b, s = 1, 8
        toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
        full = unembed(params["embed"], cfg, forward(params, cfg, toks, q_block=8))
        cache = init_cache(cfg, b, s)
        outs = []
        for t in range(s):
            lg, cache = decode_step(params, cfg, toks[:, t:t + 1],
                                    jnp.int32(t), cache)
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        rel = float(jnp.max(jnp.abs(full - dec)) / jnp.max(jnp.abs(full)))
        assert rel < 0.05, rel

    def test_generate_ky_runs(self):
        cfg = get_config("phi4-mini-3.8b", smoke=True)
        params = init_model(jax.random.PRNGKey(0), cfg)
        prompt = jnp.ones((2, 4), jnp.int32)
        toks, bits = generate(params, cfg, prompt, jax.random.PRNGKey(1),
                              max_new=8, sampler="ky", q_block=4)
        assert toks.shape == (2, 8)
        # untrained nets can emit near-deterministic logits, for which the
        # sampler's deterministic bypass legitimately uses 0 random bits
        assert int(bits) >= 0
        assert (np.asarray(toks) < cfg.vocab).all()


class TestMoE:
    def test_capacity_and_drops(self):
        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                          n_heads=2, n_kv=1, d_head=16, d_ff=64, vocab=64,
                          n_experts=4, top_k=2, moe_d_ff=64,
                          capacity_factor=1.0)
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
        y, aux = apply_moe(p, cfg, x)
        assert y.shape == x.shape
        assert 0.0 <= float(aux["drop_frac"]) < 0.5
        assert float(aux["load_balance"]) >= 1.0 - 1e-3  # >= 1 by Jensen

    def test_top1_routes_to_single_expert(self):
        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                          n_heads=2, n_kv=1, d_head=8, d_ff=32, vocab=64,
                          n_experts=2, top_k=1, moe_d_ff=32,
                          capacity_factor=2.0)
        p = init_moe(jax.random.PRNGKey(2), cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, 16))
        y, aux = apply_moe(p, cfg, x)
        assert float(aux["drop_frac"]) == 0.0  # cf=2, top-1: no drops


class TestSSM:
    def test_chunked_matches_recurrence(self):
        cfg = get_config("mamba2-130m", smoke=True).replace(ssm_chunk=8)
        p = init_ssm(jax.random.PRNGKey(0), cfg)
        b, s = 2, 32
        u = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5
        y_chunk, _ = apply_ssm(p, cfg, u)
        st = init_ssm_state(cfg, b)
        ys = []
        for t in range(s):
            yt, st = apply_ssm(p, cfg, u[:, t:t + 1], state=st)
            ys.append(yt)
        y_rec = jnp.concatenate(ys, axis=1)
        err = float(jnp.max(jnp.abs(y_chunk - y_rec)))
        assert err < 1e-3, err

    def test_state_carries_context(self):
        """An SSM decode with state differs from one without — the state
        actually carries information (long-context mechanism)."""
        cfg = get_config("mamba2-130m", smoke=True)
        p = init_ssm(jax.random.PRNGKey(0), cfg)
        u = jax.random.normal(jax.random.PRNGKey(1), (1, 1, cfg.d_model))
        st0 = init_ssm_state(cfg, 1)
        y0, _ = apply_ssm(p, cfg, u, state=st0)
        warm = {k: v + 1.0 for k, v in st0.items()}
        y1, _ = apply_ssm(p, cfg, u, state=warm)
        assert float(jnp.max(jnp.abs(y0 - y1))) > 1e-6
