"""Hypothesis import shim.

The suite's property tests use a small slice of hypothesis
(``st.integers``, ``st.lists``, ``@given``, ``@settings``).  When the
real library is installed we re-export it untouched; otherwise a
deterministic mini-runner stands in, drawing ``max_examples`` pseudo-
random examples from the same strategies with a fixed seed — weaker than
hypothesis (no shrinking, no example database) but it keeps the property
tests meaningful in minimal environments instead of failing collection.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:  # pragma: no cover - exercised without hypothesis
    import functools
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(r):
                n = r.randint(min_size, max_size)
                return [elements.draw(r) for _ in range(n)]

            return _Strategy(draw)

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(wrapper, "_max_examples", 10)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)

            # pytest follows __wrapped__ when inspecting signatures and
            # would treat the drawn parameters as missing fixtures.
            del wrapper.__wrapped__
            wrapper._max_examples = getattr(fn, "_max_examples", 10)
            return wrapper

        return deco

__all__ = ["given", "settings", "st"]
