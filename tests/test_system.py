"""End-to-end behaviour tests for the whole system: the AIA pipeline from
model IR to samples, the serving path, and the dry-run artifact contract."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_IDS, MCMC_CONFIGS, SHAPES, cell_runnable,
                           get_config, input_specs)

REPORTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "reports", "dryrun")


class TestPipelineEndToEnd:
    def test_mrf_energy_pipeline(self):
        """Full AIA pipeline on an MRF: energies -> IU-exp -> fixed-point
        -> KY sampling converges to a low-energy labeling."""
        from repro.pgm.gibbs import init_labels, mrf_gibbs
        from repro.pgm.networks import penguin_task

        mrf, truth = penguin_task(h=40, w=30)
        lab = init_labels(jax.random.PRNGKey(0), mrf, 1)
        out, stats = mrf_gibbs(jax.random.PRNGKey(1), lab,
                               jnp.asarray(mrf.unary),
                               jnp.asarray(mrf.pairwise), n_sweeps=25)
        assert (np.asarray(out[0]) == truth).mean() > 0.9
        assert int(stats.bits_used) > 0

    def test_lm_serving_pipeline(self):
        """Prefill + cached decode + hierarchical KY sampling end to end."""
        from repro.models.sampling import generate
        from repro.models.transformer import init_model

        cfg = get_config("granite-20b", smoke=True)
        params = init_model(jax.random.PRNGKey(0), cfg)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                    cfg.vocab)
        toks, bits = generate(params, cfg, prompt, jax.random.PRNGKey(2),
                              max_new=12, sampler="ky", q_block=4)
        assert toks.shape == (2, 12)
        assert (np.asarray(toks) >= 0).all()
        assert int(bits) > 0

    def test_mcmc_config_registry(self):
        assert "aia-mrf-penguin" in MCMC_CONFIGS
        assert "aia-bn-asia" in MCMC_CONFIGS
        assert MCMC_CONFIGS["aia-mrf-penguin"].height == 500  # paper size


class TestCellContract:
    def test_all_archs_registered(self):
        assert len(ARCH_IDS) == 10

    def test_40_cells_accounted(self):
        """10 archs × 4 shapes: every cell is either runnable or a
        documented long-context skip."""
        runnable = skipped = 0
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in SHAPES:
                ok, why = cell_runnable(cfg, shape)
                if ok:
                    runnable += 1
                else:
                    assert "long_500k" in why
                    skipped += 1
        assert runnable + skipped == 40
        assert runnable == 32 and skipped == 8

    def test_input_specs_shapes(self):
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in SHAPES:
                specs = input_specs(cfg, shape)
                b = shape.global_batch
                if shape.kind == "decode":
                    assert specs["tokens"].shape == (b, 1)
                else:
                    assert specs["tokens"].shape == (b, shape.seq_len)
                if cfg.family in ("encdec", "audio"):
                    assert "src_embeds" in specs  # stub frontend per task

    @pytest.mark.skipif(not os.path.isdir(REPORTS),
                        reason="run launch.dryrun first")
    def test_dryrun_artifacts_green(self):
        """Every produced dry-run JSON is ok/skipped — never error — and
        ok cells carry memory + roofline + collective evidence."""
        files = [f for f in os.listdir(REPORTS) if f.endswith(".json")]
        assert len(files) >= 40
        for f in files:
            with open(os.path.join(REPORTS, f)) as fh:
                r = json.load(fh)
            assert r["status"] in ("ok", "skipped"), (f, r.get("error"))
            if r["status"] == "ok":
                assert r["memory"]["total_per_chip"] > 0
                assert 0 < r["roofline"]["roofline_fraction"] <= 1.0
                assert r["roofline"]["bottleneck"] in (
                    "compute", "memory", "collective")

    def test_param_counts_sane(self):
        expect = {"qwen1.5-32b": 32e9, "nemotron-4-340b": 340e9,
                  "phi4-mini-3.8b": 3.8e9, "granite-20b": 20e9,
                  "grok-1-314b": 314e9, "mamba2-130m": 130e6}
        for arch, n in expect.items():
            got = get_config(arch).param_count()
            assert 0.6 * n < got < 1.6 * n, (arch, got, n)
