"""Sparse factor-graph compile layer: undirected coloring invariants,
degree-bucketed gather plans, bitwise grid-lowering regression, Ising
convergence vs exact results, and the served Ising family."""
import json

import jax
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.pgm import (
    FactorGraph,
    color_bayesnet,
    color_graph,
    compile_factor_graph,
    compile_mrf,
    dsatur,
    fg_metropolis,
    init_fg_states,
    networks,
    run_fg_gibbs,
    site_weights_sparse,
    sparse_plan,
    verify_coloring,
)
from repro.pgm.coloring import _mis_groups
from repro.pgm.gibbs import site_weights
from repro.pgm.mrf_compile import mask_of
from repro.serve import IsingQuery, PosteriorEngine, family_of, plan_key
from repro.serve.cli import load_requests


def _pairs(flat):
    """Fold a flat int list into (i, j) edge pairs, dropping self-loops
    and duplicates (the hypothesis shim has no tuple strategy)."""
    seen, out = set(), []
    for a, b in zip(flat[::2], flat[1::2]):
        i, j = min(a, b), max(a, b)
        if i != j and (i, j) not in seen:
            seen.add((i, j))
            out.append((i, j))
    return np.asarray(out, np.int64).reshape(-1, 2)


def _groups_valid(n, edges, groups):
    """Every node exactly once; no edge inside one group."""
    allv = np.concatenate([np.asarray(g) for g in groups]) if groups else \
        np.zeros(0, np.int64)
    assert sorted(allv.tolist()) == list(range(n))
    color = np.zeros(n, np.int64)
    for c, g in enumerate(groups):
        color[np.asarray(g)] = c
    for i, j in edges:
        assert color[i] != color[j], (i, j)


def _small_fg(seed=0):
    """5-var cyclic factor graph with mixed cards (2s and a 3)."""
    rng = np.random.default_rng(seed)
    card = np.array([2, 2, 3, 2, 2], np.int64)
    edges = np.array([[0, 1], [1, 2], [2, 3], [3, 4], [0, 4]], np.int64)
    unary = rng.normal(size=(5, 3)).astype(np.float64)
    pair = rng.normal(size=(5, 3, 3)).astype(np.float64)
    return FactorGraph(card=card, edges=edges, unary=unary, pair=pair)


class TestColorGraph:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(4, 40), st.lists(st.integers(0, 39), min_size=0,
                                        max_size=60))
    def test_random_graphs_valid_and_bounded(self, n, flat):
        edges = _pairs([v % n for v in flat])
        for method in ("dsatur", "parallel"):
            groups = color_graph(n, edges, method=method, validate=True)
            _groups_valid(n, edges, groups)
            maxdeg = 0
            if len(edges):
                maxdeg = int(np.bincount(edges.ravel(), minlength=n).max())
            assert len(groups) <= maxdeg + 1, method

    def test_empty_and_singleton(self):
        groups = color_graph(1, np.zeros((0, 2), np.int64))
        assert len(groups) == 1 and groups[0].tolist() == [0]
        groups = color_graph(4, np.zeros((0, 2), np.int64))
        assert len(groups) == 1 and sorted(groups[0].tolist()) == [0, 1, 2, 3]

    def test_even_torus_is_bipartite(self):
        model = networks.ising_torus(4)
        groups = color_graph(model.n, model.edges, method="dsatur",
                             validate=True)
        assert len(groups) == 2

    def test_skip_removes_nodes(self):
        edges = np.array([[0, 1], [1, 2]], np.int64)
        groups = color_graph(3, edges, skip=frozenset({1}))
        allv = np.concatenate(groups).tolist()
        assert sorted(allv) == [0, 2]

    def test_parallel_mis_groups_cover_once(self):
        model = networks.random_sparse_ising(200, avg_degree=4.0, seed=3)
        # _mis_groups wants each undirected edge in both directions
        src = np.concatenate([model.edges[:, 0], model.edges[:, 1]])
        dst = np.concatenate([model.edges[:, 1], model.edges[:, 0]])
        groups = _mis_groups(model.n, src, dst, np.ones(model.n, bool))
        _groups_valid(model.n, model.edges, groups)

    def test_color_bayesnet_validate_flag(self):
        bn = networks.asia()
        groups = color_bayesnet(bn, validate=True)
        assert verify_coloring(bn.moralized(), groups)
        # validate=False returns the same grouping (dsatur is deterministic)
        fast = color_bayesnet(bn)
        assert [g.tolist() for g in fast] == [g.tolist() for g in groups]

    def test_dsatur_original_ids_preserved(self):
        import networkx as nx
        g = nx.Graph()
        g.add_nodes_from(range(5))
        g.add_edges_from([(0, 1), (3, 4)])
        coloring = dsatur(g)
        assert set(coloring) == set(range(5))


class TestGraphIR:
    def test_factor_graph_validation(self):
        with pytest.raises(ValueError):
            _small_fg().__class__(
                card=np.array([2, 2]), edges=np.array([[0, 0]]),
                unary=np.zeros((2, 2)), pair=np.zeros((1, 2, 2)))

    def test_canonical_edge_orientation(self):
        """Edges given as (j, i) with i < j are flipped and their
        tables transposed — energies are orientation-independent."""
        fg = _small_fg()
        flipped = FactorGraph(
            card=fg.card, edges=fg.edges[:, ::-1].copy(),
            unary=fg.unary, pair=np.transpose(fg.pair, (0, 2, 1)).copy())
        x = np.array([0, 1, 2, 0, 1])
        assert np.allclose(fg.energy(x), flipped.energy(x))

    def test_ising_round_trip_energy(self):
        model = networks.ising_torus(3, beta=0.7, h=0.2)
        fg = model.to_factor_graph()
        rng = np.random.default_rng(0)
        for _ in range(4):
            x = rng.integers(0, 2, size=model.n)
            s = 2 * x - 1
            e = -(model.j * s[model.edges[:, 0]]
                  * s[model.edges[:, 1]]).sum() - (model.h * s).sum()
            assert np.allclose(fg.energy(x), e)

    def test_evidence_normalization_aliases(self):
        model = networks.ising_torus(3)
        assert model.normalize_evidence({0: -1, 1: 1}) == {0: 0, 1: 1}
        fg = model.to_factor_graph()
        assert fg.normalize_evidence({"s2": -1}) == {2: 0}
        with pytest.raises(ValueError):
            fg.normalize_evidence({0: 5})


class TestSparseCompile:
    def test_grid_lowering_bitwise_equals_dense(self):
        """Acceptance gate: the checkerboard grid lowered through the
        sparse gather-plan path produces bit-identical int32 KY weights
        to the dense rolled-lattice kernel, clamps included."""
        mrf, truth = networks.penguin_task(h=12, w=10, beta=0.8)
        mask = np.zeros((12, 10), bool)
        mask[0, :] = True
        mask[5, 3:6] = True
        observed = tuple(int(v) for v in np.flatnonzero(mask.ravel()))
        dense = compile_mrf(mrf, observed=observed)
        prog = sparse_plan(dense)
        assert prog.n_colors == 2

        labels = np.where(mask, truth, 0).astype(np.int32)
        rng = np.random.default_rng(0)
        labels = np.where(mask, labels,
                          rng.integers(0, 2, size=mask.shape)).astype(np.int32)
        x_grid = jax.numpy.asarray(labels)[None]
        x_flat = jax.numpy.asarray(labels.reshape(1, -1))

        w_dense = np.asarray(site_weights(
            x_grid, jax.numpy.asarray(mrf.unary),
            jax.numpy.asarray(mrf.pairwise))).reshape(1, -1, 2)
        w_sparse = np.asarray(site_weights_sparse(prog, x_flat))
        free = ~mask.ravel()
        assert (w_dense[:, free] == w_sparse[:, free]).all()

    def test_dense_mrf_serving_path_untouched(self):
        """mask_of on the dense program is unchanged by the refactor."""
        mrf, _ = networks.penguin_task(h=4, w=4, beta=0.5)
        prog = compile_mrf(mrf, observed=(0, 5))
        assert mask_of(prog).sum() == 2

    def test_small_fg_matches_brute_force(self):
        fg = _small_fg()
        prog = compile_factor_graph(fg, validate=True)
        _, counts, stats = run_fg_gibbs(
            jax.random.PRNGKey(0), prog, n_chains=64, n_sweeps=600,
            burn_in=150)
        marg = np.asarray(counts, np.float64)
        marg /= np.maximum(marg.sum(-1, keepdims=True), 1.0)
        exact = fg.marginals_exact()
        for v in range(fg.n_vars):
            c = int(fg.card[v])
            assert np.abs(marg[v, :c] - exact[v][:c]).max() < 0.03, v
        assert int(stats.bits_used) > 0

    def test_evidence_conditioning(self):
        fg = _small_fg(seed=1)
        prog = compile_factor_graph(fg, observed=(2,))
        ev = np.zeros(1, np.int32) + 2  # clamp var 2 to label 2
        _, counts, _ = run_fg_gibbs(
            jax.random.PRNGKey(1), prog, n_chains=64, n_sweeps=600,
            burn_in=150, evidence=ev)
        marg = np.asarray(counts, np.float64)
        marg /= np.maximum(marg.sum(-1, keepdims=True), 1.0)
        exact = fg.marginals_exact(evidence={2: 2})
        for v in prog.free_nodes:
            c = int(fg.card[v])
            assert np.abs(marg[v, :c] - exact[v][:c]).max() < 0.04, v

    def test_compile_validation(self):
        fg = _small_fg()
        with pytest.raises(ValueError):
            compile_factor_graph(fg, observed=tuple(range(5)))
        with pytest.raises(KeyError):
            compile_factor_graph(fg, observed=("nope",))
        prog = compile_factor_graph(fg, observed=("s1",))
        assert prog.observed == (1,)
        assert prog.n_free == 4
        with pytest.raises(ValueError):
            init_fg_states(jax.random.PRNGKey(0), prog, 2)  # needs values

    @pytest.mark.slow
    def test_torus_matches_onsager(self):
        """2D-torus ferromagnet at beta=0.6 (well below T_c) reproduces
        the exact Onsager spontaneous magnetization."""
        beta = 0.6
        model = networks.ising_torus(16, beta=beta)
        prog = compile_factor_graph(model)
        x0 = np.ones((48, model.n), np.int32)  # ordered start: all up
        x, _, _ = run_fg_gibbs(
            jax.random.PRNGKey(2), prog, n_chains=48, n_sweeps=150,
            burn_in=0, x0=jax.numpy.asarray(x0))
        m = float(np.mean(2.0 * np.asarray(x) - 1.0))
        exact = (1.0 - np.sinh(2.0 * beta) ** -4) ** 0.125
        assert abs(m - exact) < 0.03, (m, exact)


class TestFgMetropolis:
    def test_matches_brute_force(self):
        fg = _small_fg(seed=2)
        prog = compile_factor_graph(fg)
        x0 = init_fg_states(jax.random.PRNGKey(0), prog, 128)
        x, stats = fg_metropolis(jax.random.PRNGKey(1), x0, prog,
                                 n_sweeps=800)
        x = np.asarray(x)
        exact = fg.marginals_exact()
        for v in range(fg.n_vars):
            c = int(fg.card[v])
            emp = np.bincount(x[:, v], minlength=c)[:c] / x.shape[0]
            assert np.abs(emp - exact[v][:c]).max() < 0.08, v
        acc = float(stats.accept_rate)
        assert 0.1 < acc <= 1.0


class TestIsingServing:
    def _engine(self, side=4, beta=0.5):
        model = networks.ising_torus(side, beta=beta, h=0.1)
        eng = PosteriorEngine({"t": model}, chains_per_query=64,
                              burn_in=32, max_rounds=16)
        return model, eng

    def test_served_marginals_match_exact(self):
        model, eng = self._engine()
        res = eng.answer(IsingQuery("t", clamp_sites=((0, 1), (5, -1)),
                                    query_vars=("s3", "s10"),
                                    n_samples=30_000))
        exact = model.to_factor_graph().marginals_exact(
            evidence={0: 1, 5: 0})
        for v in (3, 10):
            assert np.abs(res.marginal(f"s{v}") - exact[v]).max() < 0.05, v

    def test_shared_pattern_hits_plan_cache(self):
        _, eng = self._engine()
        q1 = IsingQuery("t", clamp_sites=((1, 1),), query_vars=("s2",))
        q2 = IsingQuery("t", clamp_sites=((1, -1),), query_vars=("s2",))
        eng.answer_batch([q1, q2])  # same pattern → one plan
        s = eng.stats()["plan_cache"]
        assert s["misses"] == 1
        eng.answer(q1)
        assert eng.stats()["plan_cache"]["hits"] >= 1

    def test_graph_salt_keys_plans_by_content(self):
        model, eng = self._engine()
        key1 = eng._plan_key("t", ())
        eng.register("t", networks.ising_torus(4, beta=0.9))
        key2 = eng._plan_key("t", ())
        assert key1 != key2  # same name, different couplings
        fam = family_of(model)
        assert fam.plan_salt(model) == fam.plan_salt(model)  # cached/stable

    def test_plan_key_model_salt_default(self):
        base = dict(k=14, use_iu=True, quantize_cpt_bits=None,
                    sweeps_per_round=16, thin=1, mesh_fingerprint=None)
        assert plan_key("n", (), **base) == plan_key("n", (), **base,
                                                     model_salt=None)
        assert plan_key("n", (), **base) != plan_key("n", (), **base,
                                                     model_salt="x")

    def test_conflicting_clamps_rejected(self):
        _, eng = self._engine()
        with pytest.raises(ValueError):
            eng.answer(IsingQuery("t", clamp_sites=((0, 1), (0, -1)),
                                  query_vars=("s1",)))
        with pytest.raises(ValueError):
            eng.answer(IsingQuery("t", clamp_sites=((0, 2),),
                                  query_vars=("s1",)))

    def test_load_requests_round_trip(self, tmp_path):
        p = tmp_path / "reqs.json"
        p.write_text(json.dumps([
            {"network": "t", "clamp_sites": [[0, 1], [9, -1]],
             "query_vars": ["s3"], "n_samples": 512},
            {"network": "t", "evidence": {}, "query_vars": []},
        ]))
        reqs, times = load_requests(str(p))
        assert times is None
        assert isinstance(reqs[0], IsingQuery)
        assert reqs[0].clamp_sites == ((0, 1), (9, -1))
        assert reqs[0].query_vars == ("s3",)
        assert not isinstance(reqs[1], IsingQuery)

    def test_family_dispatch(self):
        assert family_of(networks.ising_torus(3)).kind == "ising"
        assert family_of(_small_fg()).kind == "ising"
        with pytest.raises(TypeError):
            family_of(object())
