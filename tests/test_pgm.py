"""PGM substrate: coloring invariants, Gibbs convergence to exact
marginals, compiler-chain correctness, MRF energy descent."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.pgm import (
    BNSweepStats,
    checkerboard,
    color_bayesnet,
    compile_bayesnet,
    init_labels,
    mrf_gibbs,
    networks,
    run_gibbs,
    sum_sweep_stats,
    verify_coloring,
)


class TestColoring:
    def test_checkerboard_two_colors(self):
        c = checkerboard(10, 7)
        assert set(np.unique(c)) == {0, 1}
        assert (c[1:, :] != c[:-1, :]).all()
        assert (c[:, 1:] != c[:, :-1]).all()

    @settings(max_examples=20, deadline=None)
    @given(st.integers(3, 30), st.integers(0, 10_000))
    def test_dsatur_valid_on_random_nets(self, n, seed):
        bn = networks.random_bayesnet(n, seed=seed)
        groups = color_bayesnet(bn)
        assert verify_coloring(bn.moralized(), groups)

    def test_mrf_checkerboard_is_blockgibbs(self):
        """The paper's claim: lattice MRFs need exactly 2 colors."""
        assert checkerboard(8, 8).max() == 1


class TestBNGibbs:
    def test_asia_converges_to_exact(self):
        bn = networks.asia()
        prog = compile_bayesnet(bn)
        _, counts, stats = run_gibbs(
            jax.random.PRNGKey(0), prog, n_chains=256, n_sweeps=800,
            burn_in=200)
        marg = np.asarray(counts, np.float64)
        marg /= marg.sum(-1, keepdims=True)
        exact = bn.marginals_exact()
        for v in range(bn.n_nodes):
            e = exact[v] / exact[v].sum()
            assert np.abs(marg[v, :2] - e).max() < 0.03, (bn.names[v],)

    def test_sprinkler_converges(self):
        bn = networks.sprinkler()
        prog = compile_bayesnet(bn)
        _, counts, _ = run_gibbs(
            jax.random.PRNGKey(1), prog, n_chains=256, n_sweeps=800,
            burn_in=200)
        marg = np.asarray(counts, np.float64)
        marg /= marg.sum(-1, keepdims=True)
        exact = bn.marginals_exact()
        for v in range(bn.n_nodes):
            e = exact[v] / exact[v].sum()
            assert np.abs(marg[v, :2] - e).max() < 0.03

    def test_exact_exp_and_iu_agree(self):
        bn = networks.asia()
        prog = compile_bayesnet(bn)
        _, c1, _ = run_gibbs(jax.random.PRNGKey(2), prog, n_chains=128,
                             n_sweeps=500, burn_in=100, use_iu=True)
        _, c2, _ = run_gibbs(jax.random.PRNGKey(2), prog, n_chains=128,
                             n_sweeps=500, burn_in=100, use_iu=False)
        m1 = np.asarray(c1, np.float64); m1 /= m1.sum(-1, keepdims=True)
        m2 = np.asarray(c2, np.float64); m2 /= m2.sum(-1, keepdims=True)
        assert np.abs(m1 - m2).max() < 0.05  # IU quantization is negligible

    def test_forward_sampling_oracle(self):
        """Gibbs marginals on a random net match ancestral sampling."""
        bn = networks.random_bayesnet(12, seed=7, max_card=3)
        prog = compile_bayesnet(bn)
        _, counts, _ = run_gibbs(jax.random.PRNGKey(3), prog, n_chains=256,
                                 n_sweeps=600, burn_in=150)
        marg = np.asarray(counts, np.float64)
        marg /= marg.sum(-1, keepdims=True)
        fwd = bn.sample_forward(np.random.default_rng(0), 200_000)
        for v in range(bn.n_nodes):
            f = np.bincount(fwd[:, v], minlength=prog.max_card) / len(fwd)
            assert np.abs(marg[v] - f).max() < 0.04, v


class TestMRFGibbs:
    def test_energy_decreases_and_segmentation_accurate(self):
        mrf, truth = networks.penguin_task(h=48, w=32)
        labels = init_labels(jax.random.PRNGKey(0), mrf, 2)
        e0 = mrf.energy(np.asarray(labels[0]))
        out, stats = mrf_gibbs(
            jax.random.PRNGKey(1), labels, jnp.asarray(mrf.unary),
            jnp.asarray(mrf.pairwise), n_sweeps=30)
        e1 = mrf.energy(np.asarray(out[0]))
        assert e1 < e0
        acc = (np.asarray(out[0]) == truth).mean()
        assert acc > 0.9, acc

    def test_stereo_truncated_linear(self):
        mrf, truth = networks.art_task(h=32, w=40, n_labels=8)
        labels = init_labels(jax.random.PRNGKey(2), mrf, 1)
        out, _ = mrf_gibbs(
            jax.random.PRNGKey(3), labels, jnp.asarray(mrf.unary),
            jnp.asarray(mrf.pairwise), n_sweeps=30)
        err = np.abs(np.asarray(out[0]).astype(int) - truth).mean()
        assert err < 1.0, err  # mean disparity error below one level

    def test_bits_per_sample_tracked(self):
        mrf, _ = networks.penguin_task(h=16, w=16)
        labels = init_labels(jax.random.PRNGKey(4), mrf, 1)
        _, stats = mrf_gibbs(
            jax.random.PRNGKey(5), labels, jnp.asarray(mrf.unary),
            jnp.asarray(mrf.pairwise), n_sweeps=5)
        n_samples = 16 * 16 * 5
        bits = float(stats.bits_used) / n_samples
        assert 1.0 < bits < 8.0  # binary labels: H+2 <= 3ish


class TestSweepStatsOverflow:
    def test_sum_sweep_stats_survives_int32_wrap_magnitudes(self):
        """Totals that wrapped the old int32 scan carry stay exact: the
        old path accumulated bits/attempts in an int32 carry across all
        sweeps, so 8 sweeps of 2**30 bits summed to 2**33 mod 2**32 = 0
        (and long real runs went negative)."""
        per_sweep = BNSweepStats(
            bits_used=np.full(8, 2**30, np.int32),
            attempts=np.full(8, 2**30, np.int32))
        with np.errstate(over="ignore"):
            wrapped = per_sweep.bits_used.sum(dtype=np.int32)
        assert wrapped == 0  # what the old carry produced
        tot = sum_sweep_stats(per_sweep)
        assert tot.bits_used.dtype == np.int64
        assert int(tot.bits_used) == 8 * 2**30
        assert int(tot.attempts) == 8 * 2**30

    def test_run_gibbs_stats_are_host_int64_totals(self):
        from repro.pgm.compile import _run_gibbs_device

        bn = networks.sprinkler()
        prog = compile_bayesnet(bn)
        _, _, stats = run_gibbs(jax.random.PRNGKey(0), prog, n_chains=8,
                                n_sweeps=10, burn_in=2)
        assert stats.bits_used.dtype == np.int64
        assert int(stats.bits_used) > 0 and int(stats.attempts) > 0
        # totals equal the per-sweep device stats, which stay int32-sized
        _, _, per_sweep = _run_gibbs_device(
            jax.random.PRNGKey(0), prog, n_chains=8, n_sweeps=10, burn_in=2)
        assert per_sweep.bits_used.shape == (10,)
        assert (int(np.asarray(per_sweep.bits_used, np.int64).sum())
                == int(stats.bits_used))


class TestCompilerChain:
    def test_gather_plan_matches_direct_conditional(self):
        """The compiled gather-plan conditional equals the brute-force
        Markov-blanket conditional on random nets."""
        bn = networks.random_bayesnet(8, seed=3, max_card=3)
        prog = compile_bayesnet(bn, quantize_cpt_bits=None)
        from repro.pgm.compile import _color_update

        rng = np.random.default_rng(0)
        x = np.array([[rng.integers(0, c) for c in bn.card]])
        log_cpt = jnp.asarray(prog.log_cpt)

        for plan in prog.plans:
            # conditional from the plan (force argmax by sampling many)
            for gi, v in enumerate(plan.nodes):
                v = int(v)
                # brute force P(v | rest)
                logw = np.zeros(bn.card[v])
                for l in range(bn.card[v]):
                    xx = x[0].copy()
                    xx[v] = l
                    logw[l] = bn.logp(xx)
                pw = np.exp(logw - logw.max())
                pw /= pw.sum()
                # plan-based: run many samples of this color from state x
                b = 4000
                xs = jnp.asarray(np.tile(x, (b, 1)), jnp.int32)
                x2, _ = _color_update(
                    jax.random.PRNGKey(v), xs, plan, log_cpt,
                    prog.max_card, prog.k, False)
                samples = np.asarray(x2[:, v])
                f = np.bincount(samples, minlength=bn.card[v]) / b
                assert np.abs(f - pw).max() < 0.06, (v, f, pw)

    def test_quantization_error_bounded(self):
        bn = networks.asia()
        prog16 = compile_bayesnet(bn, quantize_cpt_bits=16)
        prog_f = compile_bayesnet(bn, quantize_cpt_bits=None)
        d = np.abs(prog16.log_cpt - prog_f.log_cpt).max()
        assert d < 1e-2


class TestMetropolis:
    def test_mh_converges_like_gibbs(self):
        """MH-within-checkerboard reaches comparable segmentation quality
        (paper: AIA accelerates 'Gibbs, MH, etc.')."""
        import jax
        from repro.pgm.metropolis import mrf_metropolis

        mrf, truth = networks.penguin_task(h=40, w=30)
        labels = init_labels(jax.random.PRNGKey(0), mrf, 2)
        out, stats = mrf_metropolis(
            jax.random.PRNGKey(1), labels, jnp.asarray(mrf.unary),
            jnp.asarray(mrf.pairwise), n_sweeps=60)
        acc = (np.asarray(out[0]) == truth).mean()
        assert acc > 0.9, acc
        assert 0.05 < float(stats.accept_rate) <= 1.0

    def test_mh_detailed_balance_statistically(self):
        """On a tiny 2-site chain, MH and Gibbs agree with the exact
        Boltzmann marginal."""
        import jax
        from repro.pgm.graph import MRFGrid
        from repro.pgm.metropolis import mrf_metropolis

        unary = np.zeros((1, 2, 2), np.float32)
        unary[0, 0] = [0.0, 1.0]   # site 0 prefers label 0
        unary[0, 1] = [0.5, 0.0]   # site 1 prefers label 1
        mrf = MRFGrid.potts(unary, beta=0.7)
        # exact marginal of site 0 by enumeration
        zs = []
        for a in (0, 1):
            for bb in (0, 1):
                e = unary[0, 0, a] + unary[0, 1, bb] + 0.7 * (a != bb)
                zs.append((a, np.exp(-e)))
        z = sum(w for _, w in zs)
        p0 = sum(w for a, w in zs if a == 0) / z
        chains = 4000
        labels = init_labels(jax.random.PRNGKey(2), mrf, chains)
        out, _ = mrf_metropolis(
            jax.random.PRNGKey(3), labels, jnp.asarray(mrf.unary),
            jnp.asarray(mrf.pairwise), n_sweeps=40)
        f0 = float((np.asarray(out[:, 0, 0]) == 0).mean())
        assert abs(f0 - p0) < 0.04, (f0, p0)
