"""Multi-device integration tests (subprocess with fake host devices —
smoke tests and benches keep seeing 1 device, per the task spec).

Covers: mesh Gibbs halo-exchange vs all-gather equivalence + collective
bytes, MRF pad-site masking on non-tile-multiple grids, the sharded
posterior query service, sharded train-step parity with single-device,
dry-run builders on a small mesh, checkpoint restore-with-reshard
(elastic restart).

The PGM/serve mesh layers run on any jax with shard_map/NamedSharding;
the training meshes target the explicit-sharding API (AxisType,
jax.set_mesh) and are gated on jax >= 0.6.
"""

import jax
import pytest

from conftest import run_subprocess

requires_explicit_mesh = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="needs jax.sharding.AxisType / explicit-mesh API (jax >= 0.6)")


@pytest.mark.slow
class TestMeshGibbs:
    def test_halo_vs_allgather_and_bytes(self):
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, re
from repro.launch.mesh import make_pgm_mesh
from repro.pgm.networks import penguin_task
from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_mrf
mesh = make_pgm_mesh(2, 2)
mrf, truth = penguin_task(h=32, w=24, beta=2.0)
key = jax.random.PRNGKey(0)
lab, u, pw, valid, _ = shard_mrf(mesh, mrf, n_chains=2, key=key)
step = make_mesh_gibbs_step(mesh, comm="halo")
for i in range(25):
    key, sub = jax.random.split(key)
    lab, bits = step(sub, lab, u, pw, valid)
acc = (np.asarray(lab)[0][:32,:24] == truth).mean()
assert acc > 0.9, acc

def cbytes(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    tot = {}
    for line in txt.splitlines():
        for p in ("all-gather", "collective-permute"):
            if f" {p}(" in line or f"{p}-start" in line:
                m = re.findall(r"(s32|u32|f32)\\[([\\d,]*)\\]", line.split("=",1)[1])
                if m:
                    dt, dims = m[0]
                    sz = 4
                    for d in dims.split(","):
                        if d: sz *= int(d)
                    tot[p] = tot.get(p, 0) + sz
    return tot
halo = cbytes(step, key, lab, u, pw, valid)
ag = cbytes(make_mesh_gibbs_step(mesh, comm="allgather"), key, lab, u, pw, valid)
assert halo.get("collective-permute", 0) > 0
assert ag.get("all-gather", 0) > 5 * halo.get("collective-permute", 1)
print("HALO_BYTES", json.dumps(halo) if (json := __import__("json")) else 0)
print("OK")
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out
        assert "OK" in out

    def test_mesh_matches_single_device_stats(self):
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_pgm_mesh
from repro.pgm.networks import penguin_task
from repro.pgm.gibbs import mrf_gibbs, init_labels
from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_mrf
mesh = make_pgm_mesh(2, 2)
mrf, truth = penguin_task(h=24, w=24)
key = jax.random.PRNGKey(0)
lab, u, pw, valid, _ = shard_mrf(mesh, mrf, n_chains=2, key=key)
step = make_mesh_gibbs_step(mesh)
for i in range(20):
    key, sub = jax.random.split(key)
    lab, _ = step(sub, lab, u, pw, valid)
acc_mesh = (np.asarray(lab)[0] == truth).mean()
lab1 = init_labels(jax.random.PRNGKey(5), mrf, 2)
lab1, _ = mrf_gibbs(jax.random.PRNGKey(6), lab1, jnp.asarray(mrf.unary),
                    jnp.asarray(mrf.pairwise), n_sweeps=20)
acc_sd = (np.asarray(lab1)[0] == truth).mean()
assert abs(acc_mesh - acc_sd) < 0.08, (acc_mesh, acc_sd)
print("OK", acc_mesh, acc_sd)
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out

    def test_pad_sites_do_not_bias_boundary_marginals(self):
        """Regression: on a grid that is NOT a tile multiple, pad sites
        are pinned to label 0 — without the validity mask they leak
        label-0 pairwise energy into real boundary sites.  A symmetric
        MRF (uniform unary + Potts) has exact marginal 0.5 everywhere;
        the old code pushed the boundary row/col to ~0.76 and the corner
        to ~0.85."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_pgm_mesh
from repro.pgm.graph import MRFGrid
from repro.pgm.gibbs import mrf_gibbs, init_labels
from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_mrf
h, w, beta = 17, 13, 0.6   # 17x13 on a 2x2 mesh -> pads to 18x14
mrf = MRFGrid.potts(np.zeros((h, w, 2), np.float32), beta=beta)
mesh = make_pgm_mesh(2, 2)
key = jax.random.PRNGKey(0)
lab, u, pw, valid, _ = shard_mrf(mesh, mrf, n_chains=64, key=key)
step = make_mesh_gibbs_step(mesh)
burn, keep = 40, 120
freq = np.zeros((h, w))
for i in range(burn + keep):
    key, sub = jax.random.split(key)
    lab, _ = step(sub, lab, u, pw, valid)
    if i >= burn:
        freq += (np.asarray(lab)[:, :h, :w] == 0).mean(0)
freq /= keep
# exact symmetric answer: 0.5 at every site incl. the padded boundary
assert abs(freq[-1, -1] - 0.5) < 0.06, freq[-1, -1]       # corner
assert abs(freq[-1, :].mean() - 0.5) < 0.05, freq[-1, :].mean()
assert abs(freq[:, -1].mean() - 0.5) < 0.05, freq[:, -1].mean()
# and the single-device reference agrees on the same boundary sites
lab1 = init_labels(jax.random.PRNGKey(5), mrf, 64)
ref = np.zeros((h, w))
k2 = jax.random.PRNGKey(6)
for i in range(burn + keep):
    k2, sub = jax.random.split(k2)
    lab1, _ = mrf_gibbs(sub, lab1, jnp.asarray(mrf.unary),
                        jnp.asarray(mrf.pairwise), n_sweeps=1)
    if i >= burn:
        ref += (np.asarray(lab1) == 0).mean(0)
ref /= keep
assert np.abs(freq - ref)[-1, :].max() < 0.06
assert np.abs(freq - ref)[:, -1].max() < 0.06
print("OK", freq[-1, -1], ref[-1, -1])
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out


@pytest.mark.slow
class TestShardedServe:
    def test_sharded_engine_matches_single_device_and_exact(self):
        """The tentpole acceptance check: on a forced-host 4-device mesh
        the engine's posterior answers equal the single-device engine's
        (same seeds -> identical lane streams) and match exact
        enumeration; the lane-padding path (chains not divisible by the
        mesh) stays within statistical tolerance of the oracle."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro.launch.mesh import make_serve_mesh
from repro.pgm import networks
from repro.serve import PosteriorEngine, Query
mesh = make_serve_mesh((4,))
registry = {"sprinkler": networks.sprinkler(), "asia": networks.asia()}
kw = dict(chains_per_query=32, burn_in=64, seed=3)
qs = [Query("sprinkler", {"wetgrass": 1}, ("rain", "sprinkler"),
            n_samples=32768),
      Query("asia", {"smoke": 1}, ("lung", "bronc"), n_samples=32768)]
sharded = PosteriorEngine(registry, mesh=mesh, **kw).answer_batch(qs)
single = PosteriorEngine(registry, **kw).answer_batch(qs)
for rs, r1, q in zip(sharded, single, qs):
    bn = registry[q.network]
    exact = bn.marginals_exact(q.evidence)
    for var in rs.marginals:
        np.testing.assert_allclose(rs.marginal(var), r1.marginal(var),
                                   atol=1e-12)  # same seeds, same draws
        assert np.abs(rs.marginal(var) - exact[bn.index(var)]).max() < 0.04
# lane padding: 2 queries x 6 chains = 12 lanes -> padded to 12+4k
pe = PosteriorEngine(registry, mesh=mesh, chains_per_query=6, burn_in=64,
                     max_rounds=48, seed=7)
rp = pe.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                     n_samples=16384))
exact = registry["sprinkler"].marginals_exact({"wetgrass": 1})
assert np.abs(rp.marginal("rain") - exact[2]).max() < 0.05
print("OK")
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out

    def test_mesh_shape_2d_and_cli(self):
        """2D ("batch", "model") serve mesh + the CLI flags end to end."""
        code = """
from repro.serve.cli import main
main(["--network", "sprinkler", "--queries", "4", "--patterns", "2",
      "--chains", "8", "--budget", "256", "--burn-in", "16", "--show", "0",
      "--force-host-devices", "4", "--mesh-shape", "2x2"])
"""
        rc, out = run_subprocess(code)
        assert rc == 0, out
        assert "warm/cold speedup" in out and "serve mesh" in out


@pytest.mark.slow
@requires_explicit_mesh
class TestShardedTraining:
    def test_sharded_step_matches_single_device(self):
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.transformer import init_model
from repro.sharding.specs import param_specs, batch_specs, named
from repro.training.train_step import init_train_state, make_train_step
from repro.training.data import TokenDataset, DataConfig

cfg = get_config("granite-20b", smoke=True).replace(dtype="float32")
params = init_model(jax.random.PRNGKey(0), cfg)
ds = TokenDataset(DataConfig(cfg.vocab, 16, 8))
batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
step_fn, _ = make_train_step(cfg, q_block=8)

# single device
s1 = init_train_state(cfg, params)
s1, m1 = jax.jit(step_fn)(s1, batch)

# sharded 4x2
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     devices=jax.devices()[:8],
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
with jax.set_mesh(mesh):
    ps = param_specs(cfg, params, mesh)
    pp = jax.device_put(params, named(mesh, ps))
    s2 = init_train_state(cfg, pp)
    bs = batch_specs(cfg, mesh, batch)
    b2 = jax.device_put(batch, named(mesh, bs))
    s2, m2 = jax.jit(step_fn)(s2, b2)
d1 = float(m1["loss"]); d2 = float(m2["loss"])
assert abs(d1 - d2) < 1e-4, (d1, d2)
diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)))),
    s1.params, jax.device_get(s2.params))
md = max(jax.tree.leaves(diffs))
assert md < 5e-4, md
print("OK", d1, d2, md)
"""
        rc, out = run_subprocess(code, devices=8)
        assert rc == 0, out

    def test_restore_with_reshard(self):
        """Checkpoint saved on one mesh restores onto another (elastic)."""
        code = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.transformer import init_model
from repro.sharding.specs import param_specs, named
from repro.training import save, restore

cfg = get_config("phi4-mini-3.8b", smoke=True)
params = init_model(jax.random.PRNGKey(0), cfg)
mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                       devices=jax.devices()[:8],
                       axis_types=(jax.sharding.AxisType.Auto,)*2)
mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                       devices=jax.devices()[:4],
                       axis_types=(jax.sharding.AxisType.Auto,)*2)
pa = jax.device_put(params, named(mesh_a, param_specs(cfg, params, mesh_a)))
with tempfile.TemporaryDirectory() as d:
    save(d, 1, pa)
    sh_b = named(mesh_b, param_specs(cfg, params, mesh_b))
    pb, step = restore(d, params, shardings=sh_b)
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("OK")
"""
        rc, out = run_subprocess(code, devices=8)
        assert rc == 0, out


@pytest.mark.slow
@requires_explicit_mesh
class TestDryrunSmall:
    def test_builders_compile_on_small_mesh(self):
        """The cell builders lower+compile on a 2x2 mesh for one arch of
        each step kind (full 16x16/512-dev sweep runs via launch.dryrun)."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.launch.builders import build_cell
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = get_config("granite-20b", smoke=True).replace(microbatch=2)
for shape in (ShapeCfg("t", 64, 8, "train"), ShapeCfg("p", 64, 4, "prefill"),
              ShapeCfg("d", 64, 4, "decode")):
    fn, args, insh, outsh, donate = build_cell(cfg, mesh, shape)
    with jax.set_mesh(mesh):
        c = jax.jit(fn, in_shardings=insh, out_shardings=outsh
                    ).lower(*args).compile()
        assert c.memory_analysis().temp_size_in_bytes >= 0
    print("ok", shape.kind)
print("OK")
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out
