"""Multi-device integration tests (subprocess with fake host devices —
smoke tests and benches keep seeing 1 device, per the task spec).

Covers: mesh Gibbs halo-exchange vs all-gather equivalence + collective
bytes, sharded train-step parity with single-device, dry-run builders on
a small mesh, checkpoint restore-with-reshard (elastic restart).
"""
import json

import jax
import pytest

from conftest import run_subprocess

# The mesh layer targets the explicit-sharding API (jax.sharding.AxisType,
# jax.set_mesh).  On older jax the subprocesses would die at import — gate
# the whole module rather than fail on an environment mismatch.
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType"),
    reason="needs jax.sharding.AxisType / explicit-mesh API (jax >= 0.6)")


@pytest.mark.slow
class TestMeshGibbs:
    def test_halo_vs_allgather_and_bytes(self):
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, re
from repro.pgm.networks import penguin_task
from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_mrf
mesh = jax.make_mesh((2,2), ("row","col"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
mrf, truth = penguin_task(h=32, w=24, beta=2.0)
key = jax.random.PRNGKey(0)
lab, u, pw, _ = shard_mrf(mesh, mrf, n_chains=2, key=key)
step = make_mesh_gibbs_step(mesh, comm="halo")
for i in range(25):
    key, sub = jax.random.split(key)
    lab, bits = step(sub, lab, u, pw)
acc = (np.asarray(lab)[0][:32,:24] == truth).mean()
assert acc > 0.9, acc

def cbytes(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    tot = {}
    for line in txt.splitlines():
        for p in ("all-gather", "collective-permute"):
            if f" {p}(" in line or f"{p}-start" in line:
                m = re.findall(r"(s32|u32|f32)\\[([\\d,]*)\\]", line.split("=",1)[1])
                if m:
                    dt, dims = m[0]
                    sz = 4
                    for d in dims.split(","):
                        if d: sz *= int(d)
                    tot[p] = tot.get(p, 0) + sz
    return tot
halo = cbytes(step, key, lab, u, pw)
ag = cbytes(make_mesh_gibbs_step(mesh, comm="allgather"), key, lab, u, pw)
assert halo.get("collective-permute", 0) > 0
assert ag.get("all-gather", 0) > 5 * halo.get("collective-permute", 1)
print("HALO_BYTES", json.dumps(halo) if (json := __import__("json")) else 0)
print("OK")
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out
        assert "OK" in out

    def test_mesh_matches_single_device_stats(self):
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.pgm.networks import penguin_task
from repro.pgm.gibbs import mrf_gibbs, init_labels
from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_mrf
mesh = jax.make_mesh((2,2), ("row","col"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
mrf, truth = penguin_task(h=24, w=24)
key = jax.random.PRNGKey(0)
lab, u, pw, _ = shard_mrf(mesh, mrf, n_chains=2, key=key)
step = make_mesh_gibbs_step(mesh)
for i in range(20):
    key, sub = jax.random.split(key)
    lab, _ = step(sub, lab, u, pw)
acc_mesh = (np.asarray(lab)[0] == truth).mean()
lab1 = init_labels(jax.random.PRNGKey(5), mrf, 2)
lab1, _ = mrf_gibbs(jax.random.PRNGKey(6), lab1, jnp.asarray(mrf.unary),
                    jnp.asarray(mrf.pairwise), n_sweeps=20)
acc_sd = (np.asarray(lab1)[0] == truth).mean()
assert abs(acc_mesh - acc_sd) < 0.08, (acc_mesh, acc_sd)
print("OK", acc_mesh, acc_sd)
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out


@pytest.mark.slow
class TestShardedTraining:
    def test_sharded_step_matches_single_device(self):
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.transformer import init_model
from repro.sharding.specs import param_specs, batch_specs, named
from repro.training.train_step import init_train_state, make_train_step
from repro.training.data import TokenDataset, DataConfig

cfg = get_config("granite-20b", smoke=True).replace(dtype="float32")
params = init_model(jax.random.PRNGKey(0), cfg)
ds = TokenDataset(DataConfig(cfg.vocab, 16, 8))
batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
step_fn, _ = make_train_step(cfg, q_block=8)

# single device
s1 = init_train_state(cfg, params)
s1, m1 = jax.jit(step_fn)(s1, batch)

# sharded 4x2
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     devices=jax.devices()[:8],
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
with jax.set_mesh(mesh):
    ps = param_specs(cfg, params, mesh)
    pp = jax.device_put(params, named(mesh, ps))
    s2 = init_train_state(cfg, pp)
    bs = batch_specs(cfg, mesh, batch)
    b2 = jax.device_put(batch, named(mesh, bs))
    s2, m2 = jax.jit(step_fn)(s2, b2)
d1 = float(m1["loss"]); d2 = float(m2["loss"])
assert abs(d1 - d2) < 1e-4, (d1, d2)
diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)))),
    s1.params, jax.device_get(s2.params))
md = max(jax.tree.leaves(diffs))
assert md < 5e-4, md
print("OK", d1, d2, md)
"""
        rc, out = run_subprocess(code, devices=8)
        assert rc == 0, out

    def test_restore_with_reshard(self):
        """Checkpoint saved on one mesh restores onto another (elastic)."""
        code = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.transformer import init_model
from repro.sharding.specs import param_specs, named
from repro.training import save, restore

cfg = get_config("phi4-mini-3.8b", smoke=True)
params = init_model(jax.random.PRNGKey(0), cfg)
mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                       devices=jax.devices()[:8],
                       axis_types=(jax.sharding.AxisType.Auto,)*2)
mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                       devices=jax.devices()[:4],
                       axis_types=(jax.sharding.AxisType.Auto,)*2)
pa = jax.device_put(params, named(mesh_a, param_specs(cfg, params, mesh_a)))
with tempfile.TemporaryDirectory() as d:
    save(d, 1, pa)
    sh_b = named(mesh_b, param_specs(cfg, params, mesh_b))
    pb, step = restore(d, params, shardings=sh_b)
    for x, y in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
print("OK")
"""
        rc, out = run_subprocess(code, devices=8)
        assert rc == 0, out


@pytest.mark.slow
class TestDryrunSmall:
    def test_builders_compile_on_small_mesh(self):
        """The cell builders lower+compile on a 2x2 mesh for one arch of
        each step kind (full 16x16/512-dev sweep runs via launch.dryrun)."""
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.launch.builders import build_cell
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = get_config("granite-20b", smoke=True).replace(microbatch=2)
for shape in (ShapeCfg("t", 64, 8, "train"), ShapeCfg("p", 64, 4, "prefill"),
              ShapeCfg("d", 64, 4, "decode")):
    fn, args, insh, outsh, donate = build_cell(cfg, mesh, shape)
    with jax.set_mesh(mesh):
        c = jax.jit(fn, in_shardings=insh, out_shardings=outsh
                    ).lower(*args).compile()
        assert c.memory_analysis().temp_size_in_bytes >= 0
    print("ok", shape.kind)
print("OK")
"""
        rc, out = run_subprocess(code, devices=4)
        assert rc == 0, out
