"""Admission queue: deadline/size dispatch triggers, per-query
cancellation (pre-dispatch + mid-flight), per-query early retirement
with identity against synchronous ``answer_batch``, lane backfill, and
FIFO fairness across evidence patterns."""
import time

import numpy as np
import pytest

from repro.pgm import networks
from repro.serve import (
    AdmissionQueue, PosteriorEngine, Query, QueryCancelled, QueryStatus)

# generous: CI runners pay an XLA compile inside the dispatcher thread
RESULT_TIMEOUT = 300.0


def _registry():
    return {"sprinkler": networks.sprinkler(), "asia": networks.asia()}


def _engine(**kw):
    kw.setdefault("chains_per_query", 8)
    kw.setdefault("burn_in", 16)
    kw.setdefault("max_rounds", 4)
    return PosteriorEngine(_registry(), **kw)


def _wait_status(handle, status, timeout=60.0):
    t0 = time.time()
    while handle.status is not status and time.time() - t0 < timeout:
        time.sleep(0.005)
    return handle.status is status


class TestDispatchTriggers:
    def test_deadline_flush(self):
        """A partial bucket dispatches once its oldest query has waited
        max_wait_ms — no size trigger needed."""
        queue = AdmissionQueue(_engine(), max_wait_ms=200.0,
                               max_group_lanes=1024 * 8)
        try:
            hs = [queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                     n_samples=256)) for _ in range(2)]
            rs = [h.result(timeout=RESULT_TIMEOUT) for h in hs]
        finally:
            queue.close()
        assert all(abs(r.marginal("rain").sum() - 1.0) < 1e-9 for r in rs)
        # both flushed as one deadline-triggered group
        assert list(queue.stats.dispatch_log) == [("sprinkler", (3,), 2)]

    def test_size_trigger_flush_at_lane_capacity(self):
        """A bucket dispatches the moment its queries fill
        max_group_lanes chain lanes, long before any deadline."""
        eng = _engine()
        queue = AdmissionQueue(eng, max_wait_ms=3_600_000.0,
                               max_group_lanes=2 * eng.chains_per_query)
        try:
            hs = [queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                     n_samples=256)) for _ in range(2)]
            # the hour-long deadline would time this out if the size
            # trigger didn't fire
            rs = [h.result(timeout=RESULT_TIMEOUT) for h in hs]
        finally:
            queue.close()
        assert len(rs) == 2
        assert list(queue.stats.dispatch_log) == [("sprinkler", (3,), 2)]

    def test_fifo_across_two_evidence_patterns(self):
        """Buckets dispatch oldest-arrival first: pattern A (submitted
        first) must run before pattern B."""
        queue = AdmissionQueue(_engine(), max_wait_ms=150.0,
                               max_group_lanes=1024 * 8)
        try:
            ha = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                    n_samples=256))
            time.sleep(0.01)
            hb = queue.submit(Query("sprinkler", {"cloudy": 0}, ("rain",),
                                    n_samples=256))
            ha.result(timeout=RESULT_TIMEOUT)
            hb.result(timeout=RESULT_TIMEOUT)
        finally:
            queue.close()
        patterns = [pat for (_, pat, _) in queue.stats.dispatch_log]
        assert patterns == [(3,), (0,)]  # wetgrass bucket, then cloudy

    def test_submit_validates_immediately(self):
        queue = AdmissionQueue(_engine(), max_wait_ms=10.0)
        try:
            with pytest.raises(KeyError):
                queue.submit(Query("nope", {}, ()))
            with pytest.raises(ValueError):
                queue.submit(Query("sprinkler", {"rain": 1}, ("rain",)))
        finally:
            queue.close()

    def test_close_rejects_new_submissions(self):
        queue = AdmissionQueue(_engine(), max_wait_ms=10.0)
        queue.close()
        with pytest.raises(RuntimeError):
            queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",)))


class TestCancellation:
    def test_cancel_pre_dispatch(self):
        queue = AdmissionQueue(_engine(), max_wait_ms=3_600_000.0)
        try:
            h = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",)))
            assert h.cancel() is True
            assert h.status is QueryStatus.CANCELLED
            with pytest.raises(QueryCancelled):
                h.result(timeout=1.0)
            assert queue.pending() == 0
            assert queue.stats.cancelled_pending == 1
        finally:
            queue.close()

    def test_cancel_mid_flight_frees_the_group(self):
        """rhat_target=0 never converges and the cap is effectively
        unbounded, so only the mid-flight cancellation path can end the
        run (result(timeout=...) would fail the test otherwise)."""
        eng = _engine(rhat_target=0.0, max_rounds=10**6, sweeps_per_round=4)
        queue = AdmissionQueue(eng, max_wait_ms=5.0)
        try:
            h = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                   n_samples=10**9))
            assert _wait_status(h, QueryStatus.RUNNING, timeout=120.0)
            assert h.cancel() is True
            with pytest.raises(QueryCancelled):
                h.result(timeout=RESULT_TIMEOUT)
            assert queue.stats.cancelled_in_flight == 1
        finally:
            queue.close()

    def test_close_without_drain_cancels_in_flight(self):
        """close(drain=False) must not block on a slow-converging group
        running out its cap — in-flight queries get cancel_requested and
        the dispatcher bails at the next round boundary."""
        eng = _engine(rhat_target=0.0, max_rounds=10**6, sweeps_per_round=4)
        queue = AdmissionQueue(eng, max_wait_ms=5.0)
        h = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                               n_samples=10**9))
        assert _wait_status(h, QueryStatus.RUNNING, timeout=120.0)
        queue.close(drain=False, timeout=240.0)
        with pytest.raises(QueryCancelled):
            h.result(timeout=1.0)

    def test_cancel_after_done_returns_false(self):
        queue = AdmissionQueue(_engine(), max_wait_ms=5.0)
        try:
            h = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                   n_samples=256))
            r = h.result(timeout=RESULT_TIMEOUT)
            assert h.cancel() is False
            assert h.status is QueryStatus.DONE
            assert r.marginal("rain").shape == (2,)
        finally:
            queue.close()


class TestRetirementAndBackfill:
    def test_queued_identical_to_answer_batch(self):
        """Same traffic, same seeds: streamed dispatch must produce
        bit-identical results to synchronous answer_batch — the queue
        reroutes scheduling, not sampling."""
        qs = [
            Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=2048),
            Query("sprinkler", {"wetgrass": 0}, ("rain",), n_samples=2048),
            Query("asia", {"smoke": 1}, ("lung",), n_samples=1024),
            Query("sprinkler", {"wetgrass": 1}, ("sprinkler",),
                  n_samples=2048),
        ]
        ref = PosteriorEngine(_registry(), chains_per_query=8, burn_in=16,
                              seed=11).answer_batch(qs)
        eng = PosteriorEngine(_registry(), chains_per_query=8, burn_in=16,
                              seed=11)
        queue = AdmissionQueue(eng, max_wait_ms=3_600_000.0)
        try:
            hs = [queue.submit(q) for q in qs]
            queue.flush()
            got = [h.result(timeout=RESULT_TIMEOUT) for h in hs]
        finally:
            queue.close()
        for a, b in zip(ref, got):
            assert a.n_samples == b.n_samples
            assert a.rhat == b.rhat
            assert set(a.marginals) == set(b.marginals)
            for k in a.marginals:
                assert np.array_equal(a.marginals[k], b.marginals[k])

    def test_early_retirement_backfills_freed_lanes(self):
        """With per-query retirement, a small-budget query frees its
        lanes mid-flight and a waiting query of the same plan is
        admitted into them — one dispatched group serves three
        queries."""
        eng = _engine(rhat_target=0.0, min_rounds=4, max_rounds=16)
        queue = AdmissionQueue(eng, max_wait_ms=3_600_000.0,
                               max_group_lanes=2 * eng.chains_per_query)
        try:
            ha = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                    n_samples=1))        # cap: min_rounds=4
            hb = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                    n_samples=10**9))    # cap: max_rounds=16
            # same (network, pattern) bucket; waits for a freed slot
            hc = queue.submit(Query("sprinkler", {"wetgrass": 0}, ("rain",),
                                    n_samples=1))
            ra = ha.result(timeout=RESULT_TIMEOUT)
            rb = hb.result(timeout=RESULT_TIMEOUT)
            rc = hc.result(timeout=RESULT_TIMEOUT)
        finally:
            queue.close()
        # per-query retirement: the small budget retired early
        assert ra.n_sweeps < rb.n_sweeps
        # the third query rode freed lanes: one group, one backfill
        assert queue.stats.dispatched_groups == 1
        assert queue.stats.backfilled == 1
        # and its answer is a real posterior for ITS evidence
        exact = networks.sprinkler().marginals_exact({"wetgrass": 0})[2]
        assert abs(rc.marginal("rain").sum() - 1.0) < 1e-9
        assert np.abs(rc.marginal("rain") - exact).max() < 0.15

    def test_vacant_pow2_pad_slots_accept_backfill(self):
        """pow2 shape bucketing leaves vacant slots in odd-sized groups;
        a late query of the same plan backfills one instead of waiting
        for a whole new dispatch."""
        eng = _engine(rhat_target=0.0, min_rounds=4, max_rounds=12)
        queue = AdmissionQueue(eng, max_wait_ms=3_600_000.0,
                               max_group_lanes=3 * eng.chains_per_query)
        try:
            hs = [queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                     n_samples=10**9)) for _ in range(3)]
            # dispatches now (size trigger: 3 queries), padded to 4 slots
            assert _wait_status(hs[0], QueryStatus.RUNNING, timeout=120.0)
            hl = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                                    n_samples=1))
            rs = [h.result(timeout=RESULT_TIMEOUT) for h in hs + [hl]]
        finally:
            queue.close()
        assert queue.stats.dispatched_groups == 1
        assert queue.stats.backfilled == 1
        assert all(abs(r.marginal("rain").sum() - 1.0) < 1e-9 for r in rs)
