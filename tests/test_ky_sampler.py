"""Core non-normalized Knuth-Yao sampler: exactness, bit economy,
bit-exact agreement with the single-lane reference, property-based
invariants (paper §II-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    cdf_sample,
    dequantize,
    entropy_bits,
    ky_sample,
    ky_sample_ref,
    quantize_probs,
)
from repro.core import rng as rng_lib


def _freqs(samples, n):
    return np.bincount(np.asarray(samples).ravel(), minlength=n) / samples.size


class TestExactness:
    @pytest.mark.parametrize("probs", [
        [0.5, 0.25, 0.125, 0.125],
        [1 / 3, 1 / 3, 1 / 3],
        [0.9, 0.05, 0.03, 0.02],
        [0.25] * 4,
    ])
    def test_frequencies_match(self, probs):
        p = jnp.asarray(probs)
        w = quantize_probs(p, 12)
        b = 100_000
        res = jax.jit(ky_sample)(jax.random.PRNGKey(0), jnp.tile(w, (b, 1)))
        assert bool(res.ok.all())
        f = _freqs(res.sample, len(probs))
        expect = np.asarray(dequantize(w))
        # 5-sigma bound on each frequency
        tol = 5 * np.sqrt(expect * (1 - expect) / b) + 1e-3
        assert (np.abs(f - expect) < tol).all(), (f, expect)

    def test_bits_used_entropy_bound(self):
        """Bit economy: per attempt ≈ H+2 (Knuth-Yao); with rejection
        restarts the FLDR bound E[bits] ≤ H + 6 applies."""
        for probs in ([0.5, 0.25, 0.125, 0.125], [1 / 3] * 3, [0.85, 0.15]):
            p = jnp.asarray(probs)
            w = quantize_probs(p, 12)
            res = ky_sample(jax.random.PRNGKey(1), jnp.tile(w, (50_000, 1)))
            mean_bits = float(res.bits_used.mean())
            h = float(entropy_bits(p))
            assert mean_bits < h + 6.0, (probs, mean_bits, h)
            assert mean_bits > h, (probs, mean_bits, h)

    def test_paper_fig4a_example(self):
        """Paper Fig. 4(a): sampling P_x = 1/3 consumes ~3 bits/sample."""
        w = jnp.asarray([[1, 1, 1]], jnp.int32)
        res = ky_sample(jax.random.PRNGKey(7), jnp.tile(w, (100_000, 1)))
        bits = float(res.bits_used.mean())
        assert 2.0 < bits <= 3.2, bits

    def test_rejection_restarts(self):
        # weights summing to just over a power of two -> pad mass ~ 1/2
        w = jnp.asarray([[129, 130]], jnp.int32)  # sum=259, K=9, rej=253
        res = ky_sample(jax.random.PRNGKey(2), jnp.tile(w, (20_000, 1)))
        assert float(res.attempts.mean()) > 1.5  # heavy rejection regime
        f = _freqs(res.sample, 2)
        assert abs(f[0] - 129 / 259) < 0.02

    def test_deterministic_single_outcome(self):
        w = jnp.zeros((64, 8), jnp.int32).at[:, 3].set(77)
        res = ky_sample(jax.random.PRNGKey(3), w)
        assert (np.asarray(res.sample) == 3).all()


class TestBitExact:
    def test_vs_reference_many_cases(self):
        rng = np.random.default_rng(0)
        for _ in range(100):
            n = int(rng.integers(2, 10))
            w = rng.integers(0, 200, n)
            w[rng.integers(0, n)] += 1
            bits = rng.integers(0, 2, 2048)
            ref_s, ref_b = ky_sample_ref(w.tolist(), bits.tolist())
            words = np.zeros(64, np.uint32)
            for i, b in enumerate(bits):
                words[i // 32] |= np.uint32(b) << np.uint32(i % 32)
            r = ky_sample(None, jnp.asarray(w[None, :], jnp.int32),
                          bit_words=jnp.asarray(words[None, :]))
            assert int(r.sample[0]) == ref_s
            assert int(r.bits_used[0]) == ref_b

    def test_lfsr_bitstream_compatible(self):
        """The sampler is bit-source-agnostic: LFSR bits (HW reference)
        drive it identically to threefry bits."""
        bits = np.asarray(rng_lib.lfsr_bits(0xACE1, 2048))
        w = np.array([10, 20, 30, 40])
        ref_s, ref_b = ky_sample_ref(w.tolist(), bits.tolist())
        words = np.zeros(64, np.uint32)
        for i, b in enumerate(bits):
            words[i // 32] |= np.uint32(int(b)) << np.uint32(i % 32)
        r = ky_sample(None, jnp.asarray(w[None, :], jnp.int32),
                      bit_words=jnp.asarray(words[None, :]))
        assert int(r.sample[0]) == ref_s and int(r.bits_used[0]) == ref_b


class TestProperty:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(0, 1000), min_size=2, max_size=12),
           st.integers(0, 2 ** 31 - 1))
    def test_support_and_termination(self, weights, seed):
        """Samples always land on positive-weight outcomes; walk always
        terminates within budget."""
        if sum(weights) == 0:
            weights[0] = 1
        w = jnp.asarray([weights] * 32, jnp.int32)
        res = ky_sample(jax.random.PRNGKey(seed), w)
        s = np.asarray(res.sample)
        wa = np.asarray(weights)
        assert (wa[s] > 0).all()
        assert bool(res.ok.all())

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
    def test_cdf_and_ky_agree_distributionally(self, n, seed):
        key = jax.random.PRNGKey(seed)
        p = jax.random.dirichlet(key, jnp.ones(n))
        w = quantize_probs(p, 10)
        b = 20_000
        kr = ky_sample(jax.random.PRNGKey(seed + 1), jnp.tile(w, (b, 1)))
        cr = cdf_sample(jax.random.PRNGKey(seed + 2), jnp.tile(w, (b, 1)))
        fk = _freqs(kr.sample, n)
        fc = _freqs(cr.sample, n)
        assert np.abs(fk - fc).max() < 0.05
