"""Posterior query service: evidence-conditioned marginals vs exact
enumeration, clamp invariance, thinning/accounting arithmetic,
plan-cache behaviour (incl. mesh fingerprints, on-disk persistence),
CLI smoke."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pgm import (
    compile_bayesnet, compile_factor_graph, compile_mrf, init_fg_states,
    init_mrf_states, init_states, make_sweep, networks, run_gibbs)
from repro.pgm.graph import MRFGrid
from repro.serve import (
    AdmissionQueue, PlanCache, PosteriorEngine, Query, load_compiled,
    make_fg_round_runner, make_mrf_round_runner, make_round_runner,
    parse_evidence, persisted_plan_path, save_compiled, split_rhat)


def _registry():
    return {"sprinkler": networks.sprinkler(), "asia": networks.asia()}


class TestEvidenceConditioning:
    def test_clamped_node_never_changes(self):
        """Evidence nodes are excluded from every gather plan, so a sweep
        can never resample them — the clamp is structural, not masked."""
        bn = networks.asia()
        prog = compile_bayesnet(bn, observed=("smoke", "xray"))
        for plan in prog.plans:
            assert not (set(plan.nodes.tolist()) & set(prog.observed))
        sweep = make_sweep(prog)
        ev = np.array([[1, 0]] * 8, np.int32)
        x = init_states(jax.random.PRNGKey(0), prog, 8, ev)
        for i in range(20):
            x, _ = sweep(jax.random.PRNGKey(i), x)
        x = np.asarray(x)
        assert (x[:, bn.index("smoke")] == 1).all()
        assert (x[:, bn.index("xray")] == 0).all()

    def test_run_gibbs_posterior_matches_enumeration(self):
        bn = networks.sprinkler()
        prog = compile_bayesnet(bn, observed=("wetgrass",))
        _, counts, _ = run_gibbs(
            jax.random.PRNGKey(0), prog, n_chains=256, n_sweeps=600,
            burn_in=150, evidence=(1,))
        marg = np.asarray(counts, np.float64)
        marg /= marg.sum(-1, keepdims=True)
        exact = bn.marginals_exact({"wetgrass": 1})
        for v in prog.free_nodes:
            assert np.abs(marg[v, :2] - exact[v]).max() < 0.03, bn.names[v]

    def test_all_observed_rejected(self):
        bn = networks.sprinkler()
        with pytest.raises(ValueError):
            compile_bayesnet(bn, observed=tuple(range(bn.n_nodes)))

    def test_conditional_oracle_consistency(self):
        """P(v) == sum_e P(v|e) P(e) — the oracle obeys total probability."""
        bn = networks.sprinkler()
        prior = bn.marginals_exact()
        w = bn.marginals_exact()[3]  # P(wetgrass)
        mixed = sum(
            w[e] * bn.marginals_exact({"wetgrass": e})[2] for e in (0, 1))
        assert np.abs(mixed - prior[2]).max() < 1e-9


class TestEngine:
    def test_sprinkler_posterior_matches_enumeration(self):
        eng = PosteriorEngine(_registry(), chains_per_query=64, burn_in=64)
        res = eng.answer(Query("sprinkler", {"wetgrass": 1},
                               ("rain", "sprinkler"), n_samples=32768))
        exact = networks.sprinkler().marginals_exact({"wetgrass": 1})
        assert np.abs(res.marginal("rain") - exact[2]).max() < 0.03
        assert np.abs(res.marginal("sprinkler") - exact[1]).max() < 0.03
        assert res.converged and res.rhat < 1.05

    def test_asia_posterior_matches_enumeration(self):
        eng = PosteriorEngine(_registry(), chains_per_query=64,
                              burn_in=256, sweeps_per_round=64)
        res = eng.answer(Query("asia", {"smoke": 1, "dysp": 1},
                               ("bronc", "lung"), n_samples=300_000))
        exact = networks.asia().marginals_exact({"smoke": 1, "dysp": 1})
        bn = networks.asia()
        assert np.abs(res.marginal("bronc") - exact[bn.index("bronc")]).max() < 0.04
        assert np.abs(res.marginal("lung") - exact[bn.index("lung")]).max() < 0.04

    def test_batch_mixed_patterns_and_networks(self):
        """One batch spanning two networks and two evidence patterns comes
        back in request order with per-query evidence respected."""
        eng = PosteriorEngine(_registry(), chains_per_query=32, burn_in=32)
        qs = [
            Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=16384),
            Query("asia", {"smoke": 0}, ("bronc",), n_samples=8192),
            Query("sprinkler", {"wetgrass": 0}, ("rain",), n_samples=16384),
        ]
        res = eng.answer_batch(qs)
        assert [r.query is q for r, q in zip(res, qs)] == [True] * 3
        spr = networks.sprinkler()
        e1 = spr.marginals_exact({"wetgrass": 1})[2]
        e0 = spr.marginals_exact({"wetgrass": 0})[2]
        assert np.abs(res[0].marginal("rain") - e1).max() < 0.04
        assert np.abs(res[2].marginal("rain") - e0).max() < 0.04
        # the two sprinkler queries share a pattern -> same compiled plan
        assert eng.cache.stats.misses == 2  # one per (network, pattern) pair

    def test_query_var_cannot_be_observed(self):
        eng = PosteriorEngine(_registry())
        with pytest.raises(ValueError):
            eng.answer(Query("sprinkler", {"rain": 1}, ("rain",)))

    def test_unknown_network_rejected(self):
        with pytest.raises(KeyError):
            PosteriorEngine({}).answer(Query("nope", {}, ()))

    def test_split_rhat_behaviour(self):
        rng = np.random.default_rng(0)
        mixed = rng.normal(0.5, 0.1, (8, 32))
        assert split_rhat(mixed) < 1.1
        stuck = np.concatenate(
            [np.full((4, 32), 0.1), np.full((4, 32), 0.9)])
        stuck += rng.normal(0, 1e-3, stuck.shape)
        assert split_rhat(stuck) > 2.0
        assert split_rhat(np.full((4, 8), 0.3)) == 1.0
        assert split_rhat(np.zeros((4, 2))) == float("inf")  # too few rounds


class TestThinning:
    def test_per_lane_offsets_match_scalar(self):
        """A uniform per-lane offset vector keeps every lane on the same
        thinning schedule as the scalar form (the vector form exists so
        backfilled slots can restart their phase mid-group)."""
        prog = compile_bayesnet(networks.sprinkler())
        runner = make_round_runner(
            prog, sweeps_per_round=16, thin=3, use_iu=True)
        x = init_states(jax.random.PRNGKey(0), prog, 4)
        _, c_scalar, _, _, _ = runner(jax.random.PRNGKey(1), x, jnp.int32(16))
        _, c_vec, _, _, _ = runner(
            jax.random.PRNGKey(1), x, jnp.full((4,), 16, jnp.int32))
        assert np.array_equal(np.asarray(c_scalar), np.asarray(c_vec))
        # mixed offsets: lanes 2,3 run a fresh phase (6 kept in [0,16))
        # while lanes 0,1 continue an old one (5 kept in [16,32))
        _, c_mix, _, _, _ = runner(
            jax.random.PRNGKey(1), x, jnp.asarray([16, 16, 0, 0], jnp.int32))
        kept = np.asarray(c_mix).sum(-1)[:, 0]
        assert kept.tolist() == [5, 5, 6, 6]

    def test_round_runner_uses_global_offset(self):
        """Draws are kept on *global* post-burn-in sweep indices that are
        multiples of ``thin`` — a round-relative phase (the old bug) kept
        ceil(spr/thin) draws every round regardless of alignment."""
        prog = compile_bayesnet(networks.sprinkler())
        runner = make_round_runner(
            prog, sweeps_per_round=16, thin=3, use_iu=True)
        x = init_states(jax.random.PRNGKey(0), prog, 4)
        x, counts, _, _, _ = runner(jax.random.PRNGKey(1), x, jnp.int32(0))
        # kept global sweeps in [0, 16): 0, 3, 6, 9, 12, 15
        assert int(np.asarray(counts).sum(-1)[0, 0]) == 6
        x, counts, _, _, _ = runner(jax.random.PRNGKey(2), x, jnp.int32(16))
        # kept global sweeps in [16, 32): 18, 21, 24, 27, 30 — the
        # round-relative restart kept 6 with the wrong spacing
        assert int(np.asarray(counts).sum(-1)[0, 0]) == 5

    def test_engine_kept_count_accounting(self):
        """Result.n_samples equals lanes x (global multiples of thin in
        the sampled sweep range), not lanes x rounds x ceil(spr/thin)."""
        eng = PosteriorEngine(
            _registry(), chains_per_query=8, burn_in=16, sweeps_per_round=16,
            thin=3, rhat_target=0.0, min_rounds=4, max_rounds=4)
        res = eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                               n_samples=10**6))
        # 4 rounds x 16 sweeps: multiples of 3 in [0, 64) -> 22 per lane
        assert res.n_samples == 8 * 22  # old accounting claimed 8 * 24
        assert abs(res.marginal("rain").sum() - 1.0) < 1e-9

    def test_thin_one_unchanged(self):
        eng = PosteriorEngine(
            _registry(), chains_per_query=8, burn_in=16, sweeps_per_round=16,
            rhat_target=0.0, min_rounds=4, max_rounds=4)
        res = eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                               n_samples=10**6))
        assert res.n_samples == 8 * 64


class TestPlanCache:
    def test_hit_miss_and_eviction(self):
        cache = PlanCache(capacity=2)
        a, hit = cache.get("a", lambda: "A")
        assert (a, hit) == ("A", False)
        a, hit = cache.get("a", lambda: "A2")
        assert (a, hit) == ("A", True)  # no rebuild on hit
        cache.get("b", lambda: "B")
        cache.get("c", lambda: "C")  # evicts "a" (LRU)
        _, hit = cache.get("a", lambda: "A3")
        assert not hit
        assert cache.stats.hits == 1 and cache.stats.evictions == 2

    def test_same_pattern_hits_different_pattern_misses(self):
        eng = PosteriorEngine(_registry(), chains_per_query=8,
                              burn_in=16, max_rounds=4)
        eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                         n_samples=256))
        assert eng.cache.stats.misses == 1
        # same pattern, different observed value -> hit, no recompile
        eng.answer(Query("sprinkler", {"wetgrass": 0}, ("rain",),
                         n_samples=256))
        assert (eng.cache.stats.hits, eng.cache.stats.misses) == (1, 1)
        # different pattern -> miss
        eng.answer(Query("sprinkler", {"cloudy": 1}, ("rain",),
                         n_samples=256))
        assert (eng.cache.stats.hits, eng.cache.stats.misses) == (1, 2)

    def test_mesh_and_single_device_plan_keys_never_collide(self):
        """A runner jitted with sharding constraints for one mesh layout
        must not be served to an engine on another: keys carry the mesh
        fingerprint (shape + axis names + device ids), None for
        single-device."""
        from repro.launch.mesh import make_serve_mesh, mesh_fingerprint

        cache = PlanCache()
        e1 = PosteriorEngine(_registry(), chains_per_query=8, burn_in=16,
                             max_rounds=4, cache=cache)
        e2 = PosteriorEngine(_registry(), chains_per_query=8, burn_in=16,
                             max_rounds=4, cache=cache,
                             mesh=make_serve_mesh((1,)))
        assert mesh_fingerprint(e2.mesh) == (
            (1,), ("batch",), (jax.devices()[0].id,))
        assert (e1._plan_key("sprinkler", (3,))
                != e2._plan_key("sprinkler", (3,)))
        q = Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=256)
        e1.answer(q)
        e2.answer(q)  # same pattern, different mesh -> must MISS
        assert (cache.stats.hits, cache.stats.misses) == (0, 2)
        e2.answer(q)  # same mesh -> hit
        assert (cache.stats.hits, cache.stats.misses) == (1, 2)

    def test_reregister_invalidates_cached_plans(self):
        """Replacing a network must not keep serving its old CPTs."""
        eng = PosteriorEngine(_registry(), chains_per_query=8,
                              burn_in=16, max_rounds=4)
        eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                         n_samples=256))
        assert len(eng.cache) == 1
        eng.register("sprinkler", networks.sprinkler())  # fresh object
        assert len(eng.cache) == 0
        eng.register("asia", eng.networks["asia"])  # same object -> no-op
        # re-registering did not clear unrelated stats bookkeeping
        eng.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",),
                         n_samples=256))
        assert eng.cache.stats.misses == 2


class TestPlanPersistence:
    def test_save_load_roundtrip(self, tmp_path):
        """Every tensor of a CompiledBN survives the .npz round-trip."""
        bn = networks.asia()
        prog = compile_bayesnet(bn, observed=("smoke",))
        path = persisted_plan_path(
            str(tmp_path), "asia", prog.observed, bn, k=prog.k,
            quantize_cpt_bits=16)
        save_compiled(path, prog)
        loaded = load_compiled(path, bn)
        assert loaded is not None
        assert np.array_equal(loaded.log_cpt, prog.log_cpt)
        assert (loaded.max_card, loaded.k) == (prog.max_card, prog.k)
        assert loaded.observed == prog.observed
        assert len(loaded.plans) == len(prog.plans)
        for a, b in zip(loaded.plans, prog.plans):
            for f in ("nodes", "card", "self_base_off", "self_pa",
                      "self_pa_stride", "ch_off", "ch_vstride", "ch_self",
                      "ch_self_stride", "ch_pa", "ch_pa_stride"):
                assert np.array_equal(getattr(a, f), getattr(b, f)), f

    def test_warm_start_skips_compiler_chain(self, tmp_path, monkeypatch):
        """Second engine over the same cache dir must never reach
        compile_bayesnet — the persisted plans stand in for the whole
        compiler chain."""
        kw = dict(chains_per_query=8, burn_in=16, max_rounds=4, seed=5)
        q = Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=256)
        e1 = PosteriorEngine(_registry(), plan_cache_dir=str(tmp_path), **kw)
        r1 = e1.answer(q)
        assert any(f.endswith(".npz") for f in os.listdir(tmp_path))

        import repro.serve.families as families_mod

        def boom(*a, **k):
            raise AssertionError("compiler chain ran despite persisted plan")

        monkeypatch.setattr(families_mod, "compile_bayesnet", boom)
        e2 = PosteriorEngine(_registry(), plan_cache_dir=str(tmp_path), **kw)
        r2 = e2.answer(q)
        # same seed, same plan -> bit-identical marginals
        assert np.array_equal(r1.marginal("rain"), r2.marginal("rain"))

    def test_content_fingerprint_keys_the_file(self, tmp_path):
        """A renamed/retrained network must not collide with a stale
        persisted plan: the path folds in the CPT content hash."""
        spr, asia = networks.sprinkler(), networks.asia()
        p1 = persisted_plan_path(str(tmp_path), "net", (0,), spr,
                                 k=12, quantize_cpt_bits=16)
        p2 = persisted_plan_path(str(tmp_path), "net", (0,), asia,
                                 k=12, quantize_cpt_bits=16)
        assert p1 != p2

    def test_corrupt_file_degrades_to_recompile(self, tmp_path):
        path = os.path.join(str(tmp_path), "plan_bad.npz")
        with open(path, "wb") as f:
            f.write(b"not an npz")
        assert load_compiled(path, networks.sprinkler()) is None


class TestParseEvidence:
    def test_parse_and_errors(self):
        assert parse_evidence("smoke=1,dysp=0") == {"smoke": 1, "dysp": 0}
        assert parse_evidence("") == {}
        with pytest.raises(ValueError):
            parse_evidence("smoke")
        with pytest.raises(ValueError):
            parse_evidence("smoke=yes")


class TestServeCLI:
    @pytest.mark.slow
    def test_cli_smoke(self, tmp_path):
        from conftest import run_subprocess

        code = (
            "from repro.serve.cli import main\n"
            "main(['--network', 'sprinkler', '--queries', '6',\n"
            "      '--patterns', '2', '--chains', '8', '--budget', '512',\n"
            "      '--burn-in', '16', '--show', '1'])\n"
        )
        rc, out = run_subprocess(code)
        assert rc == 0, out
        assert "warm/cold speedup" in out and "queries/s" in out

    @pytest.mark.slow
    def test_cli_stream_smoke(self, tmp_path):
        """--stream replays open-loop through the admission queue and
        --plan-cache-dir persists compiled plans on the way."""
        from conftest import run_subprocess

        cache_dir = str(tmp_path / "plans")
        code = (
            "from repro.serve.cli import main\n"
            "main(['--network', 'sprinkler', '--queries', '8',\n"
            "      '--patterns', '2', '--chains', '8', '--budget', '256',\n"
            "      '--burn-in', '16', '--stream', '--rate', '200',\n"
            f"      '--max-wait-ms', '50', '--plan-cache-dir', {cache_dir!r}])\n"
        )
        rc, out = run_subprocess(code)
        assert rc == 0, out
        assert "stream:" in out and "p50" in out and "speedup" in out
        import os
        assert any(f.endswith(".npz") for f in os.listdir(cache_dir)), out


class TestPallasSampler:
    """``sampler="pallas"`` ≡ ``sampler="xla"`` bit for bit, at every
    layer the flag reaches: the three family round runners and the
    queued serving path (docs/kernels.md pins the contract)."""

    @staticmethod
    def _assert_rounds_identical(run_xla, run_pallas, key, x, offset):
        out_x = run_xla(key, x, offset)
        out_p = run_pallas(key, x, offset)
        for a, b in zip(jax.tree_util.tree_leaves(out_x),
                        jax.tree_util.tree_leaves(out_p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bn_round_runner_bitwise(self):
        prog = compile_bayesnet(networks.asia())
        mk = lambda s: make_round_runner(
            prog, sweeps_per_round=4, thin=1, use_iu=True, sampler=s)
        x = init_states(jax.random.PRNGKey(0), prog, 4)
        self._assert_rounds_identical(
            mk("xla"), mk("pallas"), jax.random.PRNGKey(1), x, jnp.int32(0))

    def test_mrf_round_runner_bitwise(self):
        rng = np.random.default_rng(0)
        mrf_prog = compile_mrf(MRFGrid.potts(
            rng.normal(0, 1, (6, 6, 3)).astype(np.float32), beta=0.6))
        mk = lambda s: make_mrf_round_runner(
            mrf_prog, sweeps_per_round=4, thin=1, use_iu=True, sampler=s)
        x = init_mrf_states(jax.random.PRNGKey(0), mrf_prog, 2)
        self._assert_rounds_identical(
            mk("xla"), mk("pallas"), jax.random.PRNGKey(2), x, jnp.int32(0))

    def test_ising_round_runner_bitwise(self):
        prog = compile_factor_graph(networks.ising_torus(4, beta=0.4))
        mk = lambda s: make_fg_round_runner(
            prog, sweeps_per_round=4, thin=1, use_iu=True, sampler=s)
        x = init_fg_states(jax.random.PRNGKey(0), prog, 4)
        self._assert_rounds_identical(
            mk("xla"), mk("pallas"), jax.random.PRNGKey(3), x, jnp.int32(0))

    def test_engine_marginals_bitwise(self):
        """End to end through answer_batch: identical marginals, counts,
        and diagnostics for the same seed."""
        kw = dict(chains_per_query=4, burn_in=8, sweeps_per_round=8,
                  max_rounds=4, seed=11)
        qs = [Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=256),
              Query("asia", {"smoke": 1}, ("lung",), n_samples=256)]
        rx = PosteriorEngine(_registry(), sampler="xla", **kw).answer_batch(qs)
        rp = PosteriorEngine(
            _registry(), sampler="pallas", **kw).answer_batch(qs)
        for a, b in zip(rx, rp):
            assert a.n_samples == b.n_samples
            for var in a.marginals:
                np.testing.assert_array_equal(a.marginal(var),
                                              b.marginal(var))

    @pytest.mark.slow
    def test_queued_identical_to_answer_batch_under_pallas(self):
        """The queue reroutes scheduling, never sampling — so streamed
        dispatch under the pallas sampler still matches answer_batch."""
        kw = dict(chains_per_query=4, burn_in=8, sweeps_per_round=8,
                  max_rounds=4, sampler="pallas", seed=11)
        qs = [Query("sprinkler", {"wetgrass": 1}, ("rain",), n_samples=256),
              Query("sprinkler", {"wetgrass": 0}, ("rain",), n_samples=256)]
        ref = PosteriorEngine(_registry(), **kw).answer_batch(qs)
        queue = AdmissionQueue(PosteriorEngine(_registry(), **kw),
                               max_wait_ms=3_600_000.0)
        try:
            hs = [queue.submit(q) for q in qs]
            queue.flush()
            got = [h.result(timeout=300.0) for h in hs]
        finally:
            queue.close()
        for a, b in zip(ref, got):
            assert a.n_samples == b.n_samples
            for var in a.marginals:
                np.testing.assert_array_equal(a.marginal(var),
                                              b.marginal(var))
