"""Property tests for AdmissionQueue scheduling invariants.

Randomized arrival/cancel/deadline interleavings (via the
``tests/_hyp.py`` hypothesis shim) are driven through a *fake* group
run substituted at the queue's ``_group_run`` seam, so the invariants
are checked against the real dispatcher/bucket/backfill/preemption
logic without paying for compilation or sampling.  The telemetry
clock seam replaces wall time — nothing here sleeps.

Invariants (ISSUE: the queue's contract under streaming traffic):

* buckets are served FIFO by their oldest arrival (no evidence pattern
  starves) and a dispatch batch never mixes ``(network, pattern,
  mode)`` buckets — neither at dispatch nor via backfill;
* slices of one ``stream_id`` are serialized: never two in flight at
  once, and never out of arrival order (slice ``t+1`` warm-starts from
  ``t``'s retained chains);
* every submitted handle resolves terminally exactly once — DONE,
  CANCELLED, or FAILED — under any interleaving of cancels, flushes,
  deadlines, and EDF preemption.
"""
from __future__ import annotations

import itertools
import threading
from collections import defaultdict

from _hyp import given, settings, st
from conftest import ManualClock

from repro.serve import telemetry
from repro.serve.query import Query, QueryStatus
from repro.serve.queue import AdmissionQueue

TERMINAL = {QueryStatus.DONE, QueryStatus.CANCELLED, QueryStatus.FAILED}


class FakeEngine:
    """The engine surface AdmissionQueue actually touches."""

    chains_per_query = 1
    mesh = None
    telemetry = telemetry.NULL

    def __init__(self):
        self._query_seq = itertools.count()

    def normalize(self, query):
        pattern = tuple(sorted(query.evidence))
        return None, dict(query.evidence), tuple(query.query_vars), pattern


class _FakeSlot:
    def __init__(self, entry):
        self.entry = entry
        self.done = False
        self.rounds = 0


class FakeRun:
    """Same step/cancel/admit/slots surface as GroupRun; each entry
    retires after a deterministic number of rounds.  Invariant
    violations are *recorded* (the dispatcher catches exceptions and
    would convert an assert into a handle failure)."""

    def __init__(self, harness, queue, name, pattern, entries):
        self.h = harness
        self.name, self.pattern = name, pattern
        self.mode = getattr(entries[0].query, "mode", "marginals")
        self.capacity = queue.max_group_queries
        self.slots = []
        self.h.on_batch(self, entries)
        for e in entries:
            self._place(e, via="dispatch")

    def _place(self, entry, via):
        self.h.on_take(self, entry, via)
        self.slots.append(_FakeSlot(entry))

    @property
    def active(self):
        return any(not s.done for s in self.slots)

    def free_slots(self):
        return self.capacity - sum(1 for s in self.slots if not s.done)

    def admit(self, entry):
        self._place(entry, via="backfill")

    def cancel(self, entry):
        for s in self.slots:
            if s.entry is entry and not s.done:
                s.done = True
                self.h.on_release(entry)
                return True
        return False

    def step(self):
        retired = []
        for s in self.slots:
            if s.done:
                continue
            s.rounds += 1
            if s.rounds >= self.h.need(s.entry):
                s.done = True
                s.entry.result = object()
                self.h.on_release(s.entry)
                retired.append(s.entry)
        return retired

    def predicted_remaining_rounds(self):
        return max((self.h.need(s.entry) - s.rounds
                    for s in self.slots if not s.done), default=0)


class Harness:
    """Shared invariant checker across every run the queue creates."""

    def __init__(self):
        self.lock = threading.Lock()
        self.active_streams: dict[str, int] = {}   # sid -> seq in flight
        self.last_released: dict[str, int] = defaultdict(lambda: -1)
        self.batch_heads: list[int] = []           # oldest seq per dispatch
        self.violations: list[str] = []

    @staticmethod
    def seq(entry) -> int:
        return entry.query.n_samples - 1000       # seq rides on n_samples

    @staticmethod
    def need(entry) -> int:
        return 1 + Harness.seq(entry) % 3          # 1..3 rounds to retire

    def make_run(self, queue, name, pattern, entries):
        return FakeRun(self, queue, name, pattern, entries)

    def on_batch(self, run, entries):
        with self.lock:
            self.batch_heads.append(min(self.seq(e) for e in entries))

    def on_take(self, run, entry, via):
        q = entry.query
        with self.lock:
            key = (q.network, tuple(sorted(q.evidence)),
                   getattr(q, "mode", "marginals"))
            if key != (run.name, run.pattern, run.mode):
                self.violations.append(
                    f"{via} mixed buckets: {key} into "
                    f"{(run.name, run.pattern, run.mode)}")
            sid = getattr(q, "stream_id", None)
            if sid is not None:
                if sid in self.active_streams:
                    self.violations.append(
                        f"{via} of stream {sid!r} slice {self.seq(entry)} "
                        f"while slice {self.active_streams[sid]} in flight")
                elif self.seq(entry) <= self.last_released[sid]:
                    self.violations.append(
                        f"{via} of stream {sid!r} slice {self.seq(entry)} "
                        f"after slice {self.last_released[sid]} retired")
                self.active_streams[sid] = self.seq(entry)

    def on_release(self, entry):
        sid = getattr(entry.query, "stream_id", None)
        if sid is not None:
            with self.lock:
                self.active_streams.pop(sid, None)
                self.last_released[sid] = max(
                    self.last_released[sid], self.seq(entry))

    def on_preempt(self, run):
        # a vacated run's live entries go back to the bucket: their
        # streams are no longer in flight and the slice may re-dispatch
        for s in run.slots:
            if not s.done and s.entry is not None:
                sid = getattr(s.entry.query, "stream_id", None)
                if sid is not None:
                    with self.lock:
                        self.active_streams.pop(sid, None)


class HarnessQueue(AdmissionQueue):
    def __init__(self, harness, *args, **kw):
        self.h = harness
        super().__init__(*args, **kw)

    def _group_run(self, name, pattern, batch):
        return self.h.make_run(self, name, pattern, batch)

    def _preempt_run(self, key, run):
        vacated = super()._preempt_run(key, run)
        if vacated:
            self.h.on_preempt(run)
        return vacated


def _drive(ops, scheduler):
    """Decode one drawn interleaving and run it against the queue."""
    clock = ManualClock()
    telemetry.set_clock(clock)
    resolved = defaultdict(int)
    try:
        h = Harness()
        q = HarnessQueue(h, FakeEngine(), max_wait_ms=10_000.0,
                         max_group_lanes=3, scheduler=scheduler)
        handles = []
        for i, v in enumerate(ops):
            clock.advance(0.001)  # strictly increasing t_submit
            action, arg = v % 8, v // 8
            if action == 6 and handles:       # cancel an earlier handle
                handles[arg % len(handles)].cancel()
            elif action == 7:
                q.flush()
            else:                              # submit
                pattern = f"p{arg % 3}"
                kw = {"n_samples": 1000 + i}
                if action in (3, 4):           # temporal-stream slice —
                    # a stream is one sensor re-observed, so its slices
                    # share an evidence pattern (and hence a bucket)
                    kw["stream_id"] = f"s{arg % 2}"
                    pattern = f"ps{arg % 2}"
                if scheduler == "deadline" and action in (2, 4):
                    kw["deadline_ms"] = 50.0 + (arg % 90)  # SLO query
                handle = q.submit(
                    Query("net", {pattern: 0}, ("x",), **kw))
                handle.add_done_callback(
                    lambda _h, k=len(handles): resolved.__setitem__(
                        k, resolved[k] + 1))
                handles.append(handle)
        q.close(drain=True, timeout=60.0)
        assert not q._thread.is_alive(), "dispatcher failed to drain"
        assert h.violations == [], h.violations
        for k, handle in enumerate(handles):
            assert handle.done(), f"handle {k} never resolved"
            assert handle.status in TERMINAL, (k, handle.status)
            assert resolved[k] == 1, \
                f"handle {k} resolved {resolved[k]} times"
        s = q.stats
        assert (s.completed + s.failed + s.cancelled_pending
                + s.cancelled_in_flight) == len(handles)
        assert s.failed == 0, "no fault injected, nothing may fail"
    finally:
        telemetry.set_clock(None)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=40))
def test_fifo_interleavings_hold_invariants(ops):
    _drive(ops, scheduler="fifo")


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=40))
def test_deadline_interleavings_hold_invariants(ops):
    _drive(ops, scheduler="deadline")


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=4, max_size=24))
def test_fifo_dispatches_oldest_bucket_first(ops):
    """With no cancels/streams and one big flush, batches must leave in
    oldest-arrival order and each batch is one bucket's prefix."""
    clock = ManualClock()
    telemetry.set_clock(clock)
    try:
        h = Harness()
        q = HarnessQueue(h, FakeEngine(), max_wait_ms=10_000.0,
                         max_group_lanes=4, backfill=False,
                         scheduler="fifo")
        handles = []
        for i, v in enumerate(ops):
            clock.advance(0.001)
            handles.append(q.submit(Query(
                "net", {f"p{v % 3}": 0}, ("x",), n_samples=1000 + i)))
        q.flush()
        q.close(drain=True, timeout=60.0)
        assert h.violations == [], h.violations
        assert all(hd.status is QueryStatus.DONE for hd in handles)
        # FIFO across patterns: each pop takes the bucket whose head is
        # the oldest remaining -> heads are seen in increasing order
        assert h.batch_heads == sorted(h.batch_heads), h.batch_heads
    finally:
        telemetry.set_clock(None)
