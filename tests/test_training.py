"""Training substrate: optimizer math, memorization, checkpoint
roundtrip + reshard-on-restore, resumable data, fault-tolerance hooks."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import init_model
from repro.training import (
    AdamW,
    Adafactor,
    AsyncCheckpointer,
    DataConfig,
    StepGuard,
    StragglerDetector,
    TokenDataset,
    latest_step,
    restore,
    save,
)
from repro.training.train_step import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen1.5-32b", smoke=True).replace(microbatch=2)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestOptimizers:
    def test_adamw_quadratic(self):
        opt = AdamW(lr=0.1, wd=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        st = opt.init(params)
        for _ in range(200):
            g = {"w": 2 * params["w"]}
            params, st, _ = opt.update(g, st, params)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_adafactor_quadratic(self):
        opt = Adafactor(lr=0.1)
        params = {"w": jnp.ones((4, 4)) * 3.0}
        st = opt.init(params)
        for _ in range(300):
            g = {"w": 2 * params["w"]}
            params, st, _ = opt.update(g, st, params)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_bf16_states(self):
        opt = AdamW(state_dtype=jnp.bfloat16)
        params = {"w": jnp.ones((8,))}
        st = opt.init(params)
        assert st.m["w"].dtype == jnp.bfloat16
        _, st, _ = opt.update({"w": jnp.ones((8,))}, st, params)
        assert st.v["w"].dtype == jnp.bfloat16

    def test_grad_clip(self):
        opt = AdamW(grad_clip=1.0, lr=1e-3)
        params = {"w": jnp.zeros((4,))}
        st = opt.init(params)
        _, _, gnorm = opt.update({"w": jnp.full((4,), 1e6)}, st, params)
        assert float(gnorm) > 1e5  # reported norm is pre-clip


class TestTrainLoop:
    def test_memorizes_fixed_batch(self, tiny):
        cfg, params = tiny
        state = init_train_state(cfg, params)
        step_fn, _ = make_train_step(cfg, q_block=8)
        step_fn = jax.jit(step_fn)
        ds = TokenDataset(DataConfig(cfg.vocab, 16, 4))
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
        losses = []
        for _ in range(25):
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.75 * losses[0]

    def test_microbatch_equals_full_batch_grads(self):
        cfg = get_config("granite-20b", smoke=True).replace(dtype="float32")
        params = init_model(jax.random.PRNGKey(1), cfg)
        ds = TokenDataset(DataConfig(cfg.vocab, 8, 4))
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
        s_full = init_train_state(cfg, params)
        s_mb = init_train_state(cfg.replace(microbatch=2), params)
        f_full, _ = make_train_step(cfg, q_block=8)
        f_mb, _ = make_train_step(cfg.replace(microbatch=2), q_block=8)
        s_full, m1 = jax.jit(f_full)(s_full, batch)
        s_mb, m2 = jax.jit(f_mb)(s_mb, batch)
        # same data, same update (microbatch mean == full-batch mean)
        d = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))),
            s_full.params, s_mb.params)
        assert max(jax.tree.leaves(d)) < 5e-5


class TestCheckpoint:
    def test_roundtrip_and_latest(self, tiny):
        cfg, params = tiny
        state = init_train_state(cfg, params)
        with tempfile.TemporaryDirectory() as d:
            save(d, 3, state)
            save(d, 7, state)
            assert latest_step(d) == 7
            restored, step = restore(d, state)
            assert step == 7
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_commit_ignores_tmp(self, tiny):
        cfg, params = tiny
        state = init_train_state(cfg, params)
        with tempfile.TemporaryDirectory() as d:
            save(d, 1, state)
            os.makedirs(os.path.join(d, "step_00000009.tmp"))
            assert latest_step(d) == 1  # in-flight save never visible

    def test_async_writer(self, tiny):
        cfg, params = tiny
        state = init_train_state(cfg, params)
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer(d, keep=2)
            for s in (1, 2, 3):
                ck.save(s, state)
            ck.wait()
            assert latest_step(d) == 3
            steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
            assert len(steps) == 2  # GC keeps last 2


class TestData:
    def test_deterministic_resume(self):
        ds = TokenDataset(DataConfig(1000, 32, 4, seed=9))
        b5 = ds.batch_at(5)
        it = ds.iterate(start_step=5)
        b5b = next(it)
        np.testing.assert_array_equal(b5["tokens"], b5b["tokens"])

    def test_labels_shifted(self):
        ds = TokenDataset(DataConfig(1000, 32, 2))
        b = ds.batch_at(0)
        assert b["tokens"].shape == (2, 32)
        assert b["labels"].shape == (2, 32)


class TestFaultTolerance:
    def test_step_guard_retries_then_reloads(self):
        calls = {"n": 0}

        def flaky(state, batch):
            calls["n"] += 1
            if calls["n"] < 3:
                raise jax.errors.JaxRuntimeError("injected fault")
            return state, {"loss": jnp.float32(1.0)}

        reloaded = {"n": 0}

        def reload():
            reloaded["n"] += 1
            return "fresh"

        g = StepGuard(max_retries=2, reload_fn=reload)
        out = g.run(flaky, "state", None)
        assert out[1]["loss"] == 1.0
        assert g.retries == 2

    def test_straggler_detection(self):
        sd = StragglerDetector(threshold=4.0)
        for i in range(32):
            assert not sd.record(i, 1.0 + 0.02 * (i % 3))
        assert sd.record(99, 8.0)
        assert sd.flagged[-1][0] == 99

    def test_elastic_mesh_degrades(self):
        from repro.training.elastic import elastic_mesh
        m = elastic_mesh(model_parallel=8, devices=jax.devices())  # 1 device
        assert m.devices.size == 1
