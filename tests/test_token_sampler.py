"""Hierarchical KY token sampling: exactness of the two-level
decomposition, TV distance to the true softmax, categorical agreement."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import (
    categorical_baseline,
    dequantize,
    ky_sample_tokens,
    ky_sample_weights_hier,
    quantize_logits,
    tv_distance,
    vocab_k,
)


class TestHierarchical:
    def test_two_level_exact_on_quantized(self):
        """Hierarchical sampling is exact: P(i) = w_i / sum(w)."""
        n, b = 1000, 200_000
        key = jax.random.PRNGKey(0)
        w = jnp.asarray(
            np.random.default_rng(0).integers(0, 100, (1, n)), jnp.int32)
        res = jax.jit(lambda k: ky_sample_weights_hier(
            k, jnp.tile(w, (b, 1)), chunk=128))(key)
        assert bool(res.ok.all())
        f = np.bincount(np.asarray(res.token), minlength=n) / b
        expect = np.asarray(dequantize(w))[0]
        # sampling-noise floor: E[TV] ≈ sqrt(n/(2πB)) ≈ 0.028 here
        tv = 0.5 * np.abs(f - expect).sum()
        assert tv < 0.045, tv

    def test_zero_weight_never_sampled(self):
        w = jnp.zeros((1, 512), jnp.int32).at[0, 100].set(5).at[0, 400].set(5)
        res = ky_sample_weights_hier(
            jax.random.PRNGKey(1), jnp.tile(w, (10_000, 1)), chunk=64)
        s = set(np.unique(np.asarray(res.token)).tolist())
        assert s <= {100, 400}

    @settings(max_examples=10, deadline=None)
    @given(st.integers(100, 3000), st.integers(0, 10_000))
    def test_tokens_in_range_and_ok(self, n, seed):
        logits = jax.random.normal(jax.random.PRNGKey(seed), (16, n)) * 2
        res = ky_sample_tokens(jax.random.PRNGKey(seed + 1), logits)
        t = np.asarray(res.token)
        assert ((t >= 0) & (t < n)).all()
        assert bool(res.ok.all())


class TestVsSoftmax:
    def test_single_scale_quantization_tv(self):
        """Single-scale quantization is fine at small vocab; at 152k it
        truncates tail mass (>1% TV) — the documented motivation for the
        two-scale path below."""
        logits = jax.random.normal(jax.random.PRNGKey(1000), (4, 1000)) * 4
        w = quantize_logits(logits, k=vocab_k(1000))
        tv = np.asarray(tv_distance(jax.nn.softmax(logits, -1), dequantize(w)))
        assert (tv < 0.01).all()
        big = jax.random.normal(jax.random.PRNGKey(7), (2, 152_064)) * 4
        wb = quantize_logits(big, k=vocab_k(152_064))
        tvb = np.asarray(tv_distance(jax.nn.softmax(big, -1), dequantize(wb)))
        assert (tvb > 0.01).all()  # the failure mode two-scale fixes

    def test_two_scale_quantization_tv_small(self):
        """The two-scale (per-chunk max) quantizer keeps TV < 0.5% even
        at 152k-vocab, computed analytically from the quantized masses."""
        chunk = 512
        for v in (32_000, 152_064):
            logits = jax.random.normal(jax.random.PRNGKey(v), (2, v)) * 4
            z = np.asarray(logits, np.float64)
            pad = (-v) % chunk
            zp = np.pad(z, ((0, 0), (0, pad)), constant_values=-np.inf)
            zc = zp.reshape(2, -1, chunk)
            zc = zc - zc.max(axis=(-2, -1), keepdims=True)
            m_c = zc.max(axis=-1, keepdims=True)
            w2 = np.floor(np.exp(zc - m_c) * (2 ** 14 - 1))
            w2[~np.isfinite(zc)] = 0.0
            mass = np.exp(m_c[..., 0]) * w2.sum(-1)
            w1 = np.floor(mass / mass.max(-1, keepdims=True) * (2 ** 14 - 1))
            p_hat = (w1 / w1.sum(-1, keepdims=True))[..., None] * (
                w2 / np.clip(w2.sum(-1, keepdims=True), 1, None))
            p_hat = p_hat.reshape(2, -1)[:, :v]
            p_true = np.asarray(jax.nn.softmax(logits, -1), np.float64)
            tv = 0.5 * np.abs(p_hat - p_true).sum(-1)
            assert (tv < 0.005).all(), (v, tv)

    def test_agreement_with_categorical(self):
        v, b = 512, 100_000
        logits = jax.random.normal(jax.random.PRNGKey(3), (v,)) * 3
        tiled = jnp.tile(logits[None], (b, 1))
        ky = jax.jit(lambda k: ky_sample_tokens(k, tiled))(jax.random.PRNGKey(4))
        cat = categorical_baseline(jax.random.PRNGKey(5), tiled)
        fk = np.bincount(np.asarray(ky.token), minlength=v) / b
        fc = np.bincount(np.asarray(cat), minlength=v) / b
        assert 0.5 * np.abs(fk - fc).sum() < 0.02

    def test_temperature(self):
        logits = jnp.asarray([[0.0, 1.0, 2.0, 5.0]])
        b = 50_000
        cold = ky_sample_tokens(jax.random.PRNGKey(6),
                                jnp.tile(logits, (b, 1)), temperature=0.25)
        hot = ky_sample_tokens(jax.random.PRNGKey(7),
                               jnp.tile(logits, (b, 1)), temperature=4.0)
        f_cold = np.bincount(np.asarray(cold.token), minlength=4) / b
        f_hot = np.bincount(np.asarray(hot.token), minlength=4) / b
        assert f_cold[3] > 0.99
        assert f_hot[3] < 0.6
