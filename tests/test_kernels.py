"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, bit-exactness
for the sampler, allclose for the interpolation unit (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exp_table, quantize_probs, sigmoid_table
from repro.core import rng as rng_lib
from repro.kernels import ref as ref_lib
from repro.kernels.interp_lut import interp_pallas
from repro.kernels.ky_sampler import ky_sampler_pallas
from repro.kernels.ops import interp_kernel, ky_sample_kernel


class TestKYKernel:
    @pytest.mark.parametrize("b,n", [(256, 4), (256, 16), (512, 64),
                                     (256, 128), (512, 5)])
    def test_bit_exact_vs_ref(self, b, n):
        key = jax.random.PRNGKey(b * 1000 + n)
        p = jax.random.dirichlet(key, jnp.ones(n), (b,))
        w = quantize_probs(p, 12)
        npad = -n % 128
        wp = jnp.pad(w, ((0, 0), (0, npad)))
        words = rng_lib.random_bit_words(jax.random.PRNGKey(1), (b,), 31 * 32)
        klvl, rej = ref_lib.ky_prep(wp)
        out_k, bits_k, ok_k = ky_sampler_pallas(
            wp, words, klvl, rej, block_b=256, budget=31 * 32)
        out_r, bits_r, ok_r = ref_lib.ky_ref(wp, words, budget=31 * 32)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        np.testing.assert_array_equal(np.asarray(bits_k), np.asarray(bits_r))
        assert bool(ok_k.all())

    def test_wrapper_handles_ragged_shapes(self):
        # batch/outcome sizes that need padding inside ops.py
        w = quantize_probs(
            jax.random.dirichlet(jax.random.PRNGKey(0), jnp.ones(7), (133,)),
            10)
        res = ky_sample_kernel(jax.random.PRNGKey(1), w)
        assert res.sample.shape == (133,)
        assert bool(res.ok.all())
        assert (np.asarray(res.sample) < 7).all()

    def test_distribution_matches_core(self):
        w = quantize_probs(jnp.asarray([0.6, 0.3, 0.1]), 10)
        b = 50_000
        res = ky_sample_kernel(jax.random.PRNGKey(2), jnp.tile(w, (b, 1)))
        f = np.bincount(np.asarray(res.sample), minlength=3) / b
        assert np.abs(f - [0.6, 0.3, 0.1]).max() < 0.02


class TestFlashAttention:
    @pytest.mark.parametrize("bh,s,dh,causal,blk", [
        (4, 128, 64, True, 64), (2, 256, 128, True, 128),
        (2, 256, 64, False, 64), (8, 64, 32, True, 32),
        (1, 512, 64, True, 128),
    ])
    def test_vs_dense_oracle(self, bh, s, dh, causal, blk):
        from repro.kernels.flash_attention import flash_attention
        from repro.kernels.ref import mha_ref
        ks = jax.random.split(jax.random.PRNGKey(s + dh), 3)
        q = jax.random.normal(ks[0], (bh, s, dh), jnp.float32)
        k = jax.random.normal(ks[1], (bh, s, dh), jnp.float32)
        v = jax.random.normal(ks[2], (bh, s, dh), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, q_block=blk,
                              kv_block=blk)
        ref = mha_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)

    def test_gqa_wrapper_matches_blockwise(self):
        from repro.kernels.flash_attention import flash_mha
        from repro.models.attention import attend_blockwise
        b, s, h, kv, dh = 2, 128, 8, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.float32)
        o1 = flash_mha(q, k, v, q_block=64, kv_block=64)
        o2 = attend_blockwise(q, k, v, q_block=64, kv_block=64)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-5, rtol=1e-4)

    def test_bf16_dtype(self):
        from repro.kernels.flash_attention import flash_attention
        from repro.kernels.ref import mha_ref
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (2, 128, 64), jnp.bfloat16)
        k = jax.random.normal(ks[1], (2, 128, 64), jnp.bfloat16)
        v = jax.random.normal(ks[2], (2, 128, 64), jnp.bfloat16)
        out = flash_attention(q, k, v, q_block=64, kv_block=64)
        ref = mha_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=3e-2, rtol=3e-2)


class TestInterpKernel:
    @pytest.mark.parametrize("table_fn,fn,lo,hi", [
        (exp_table, np.exp, -16.0, 0.0),
        (sigmoid_table, lambda x: 1 / (1 + np.exp(-x)), -8.0, 8.0),
    ])
    def test_matches_ref_and_exact(self, table_fn, fn, lo, hi):
        t = table_fn()
        x = jax.random.uniform(jax.random.PRNGKey(0), (64, 256),
                               minval=lo, maxval=hi)
        y_k = interp_kernel(x, t.table, lo=t.lo, hi=t.hi)
        y_r = ref_lib.interp_ref(x, t.table, t.lo, t.hi)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   atol=1e-6, rtol=1e-5)
        exact = fn(np.asarray(x, np.float64))
        assert np.max(np.abs(exact - np.asarray(y_k))) < 2e-3

    @pytest.mark.parametrize("shape", [(8, 100), (256, 512), (1, 1000),
                                       (37, 64)])
    def test_shape_sweep(self, shape):
        t = exp_table()
        x = jax.random.uniform(jax.random.PRNGKey(1), shape,
                               minval=-16.0, maxval=0.0)
        y_k = interp_kernel(x, t.table, lo=t.lo, hi=t.hi)
        y_r = ref_lib.interp_ref(x, t.table, t.lo, t.hi)
        assert y_k.shape == shape
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   atol=1e-6, rtol=1e-5)

    def test_block_tiling_paths(self):
        # exercise the explicit pallas grid with multiple blocks
        t = exp_table()
        x = jax.random.uniform(jax.random.PRNGKey(2), (512, 1024),
                               minval=-16.0, maxval=0.0)
        y = interp_pallas(x, t.table, lo=t.lo, hi=t.hi,
                          block_b=256, block_n=512)
        y_r = ref_lib.interp_ref(x, t.table, t.lo, t.hi)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                                   atol=1e-6, rtol=1e-5)
