"""Deterministic, resumable data pipeline.

Sources: synthetic token streams (seeded, shape-exact) or a memory-mapped
token file.  The iterator state is a single integer ``step`` — restoring
a checkpoint restores the exact batch sequence (required for elastic
restart: a resumed run consumes identical data regardless of mesh shape,
since sharding happens after host-level batch assembly).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig, ShapeCfg


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: str | None = None     # token .bin (uint32) for file-backed mode


class TokenDataset:
    """step -> {tokens, labels} batches; O(1) state = the step index."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.path and os.path.exists(cfg.path):
            self._mm = np.memmap(cfg.path, dtype=np.uint32, mode="r")

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        if self._mm is not None:
            need = c.global_batch * (c.seq_len + 1)
            total = len(self._mm) - need
            rng = np.random.default_rng(c.seed + step)
            start = int(rng.integers(0, max(total, 1)))
            flat = np.asarray(self._mm[start : start + need], np.int32)
            arr = flat.reshape(c.global_batch, c.seq_len + 1) % c.vocab
        else:
            rng = np.random.default_rng(c.seed + step)
            arr = rng.integers(
                0, c.vocab, (c.global_batch, c.seq_len + 1), dtype=np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch(cfg: ModelConfig, shape: ShapeCfg, seed: int = 0) -> dict:
    """One concrete host batch matching ``input_specs`` (for smoke runs)."""
    ds = TokenDataset(DataConfig(cfg.vocab, shape.seq_len, shape.global_batch,
                                 seed))
    batch = ds.batch_at(0)
    rng = np.random.default_rng(seed + 1)
    if cfg.family == "vlm" and cfg.frontend_tokens:
        batch["frontend"] = rng.normal(
            0, 1, (shape.global_batch, cfg.frontend_tokens, cfg.d_model)
        ).astype(np.float32)
    if cfg.family in ("encdec", "audio"):
        batch["src_embeds"] = rng.normal(
            0, 1, (shape.global_batch, cfg.enc_seq_len or 128, cfg.d_model)
        ).astype(np.float32)
    return batch


def write_token_file(path: str, n_tokens: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, vocab, n_tokens, dtype=np.uint32)
    arr.tofile(path)
    with open(path + ".json", "w") as f:
        json.dump({"n_tokens": n_tokens, "vocab": vocab}, f)
