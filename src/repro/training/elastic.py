"""Fault-tolerance runtime: retries, straggler detection, elastic restart.

* :class:`StepGuard` — wraps the train step with bounded retry +
  checkpoint-reload recovery (transient device errors / preemption
  signals re-enter from the last committed step).
* :class:`StragglerDetector` — per-step wall-time ring buffer with
  median-absolute-deviation outlier flagging; at scale the flag feeds the
  scheduler's drain/requeue hook (here: a callback).
* :func:`elastic_mesh` — rebuilds the largest usable ``(data, model)``
  mesh from the devices that are still healthy, in concert with
  checkpoint restore-with-reshard (restarts may lose a pod).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable

import jax
import numpy as np


class StragglerDetector:
    def __init__(self, window: int = 64, threshold: float = 4.0,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.times: deque[float] = deque(maxlen=window)
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is an outlier vs the recent window."""
        is_out = False
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            mad = float(np.median(np.abs(np.asarray(self.times) - med))) + 1e-9
            if dt > med + self.threshold * 1.4826 * mad and dt > 1.5 * med:
                is_out = True
                self.flagged.append((step, dt))
                if self.on_straggler:
                    self.on_straggler(step, dt)
        self.times.append(dt)
        return is_out


class StepGuard:
    """Run a step with bounded retries; reload from checkpoint on failure."""

    def __init__(self, max_retries: int = 2,
                 reload_fn: Callable[[], object] | None = None):
        self.max_retries = max_retries
        self.reload_fn = reload_fn
        self.retries = 0
        self.reloads = 0

    def run(self, step_fn, state, batch):
        for attempt in range(self.max_retries + 1):
            try:
                out = step_fn(state, batch)
                # block so device-side faults surface here, not later
                jax.block_until_ready(out[1]["loss"])
                return out
            except jax.errors.JaxRuntimeError:
                self.retries += 1
                if attempt == self.max_retries:
                    if self.reload_fn is None:
                        raise
                    state = self.reload_fn()
                    self.reloads += 1
                    out = step_fn(state, batch)
                    jax.block_until_ready(out[1]["loss"])
                    return out
        raise AssertionError("unreachable")


def elastic_mesh(model_parallel: int, devices=None):
    """Largest (data, model) mesh buildable from the healthy device set."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    mp = model_parallel
    while mp > 1 and (n % mp != 0):
        mp //= 2
    data = n // mp
    arr = np.array(devices[: data * mp]).reshape(data, mp)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


class Heartbeat:
    """Wall-clock liveness probe; at scale this is the per-host agent that
    the coordinator polls. ``healthy()`` is cheap enough to call per step."""

    def __init__(self, timeout_s: float = 300.0):
        self.timeout = timeout_s
        self.last = time.monotonic()

    def beat(self):
        self.last = time.monotonic()

    def healthy(self) -> bool:
        return (time.monotonic() - self.last) < self.timeout
