from repro.training.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.training.data import DataConfig, TokenDataset, make_batch
from repro.training.elastic import Heartbeat, StepGuard, StragglerDetector, elastic_mesh
from repro.training.optimizer import AdamW, Adafactor, cosine_lr, global_norm, make_optimizer
from repro.training.train_step import TrainState, init_train_state, make_train_step

__all__ = [
    "AsyncCheckpointer", "latest_step", "restore", "save",
    "DataConfig", "TokenDataset", "make_batch",
    "Heartbeat", "StepGuard", "StragglerDetector", "elastic_mesh",
    "AdamW", "Adafactor", "cosine_lr", "global_norm", "make_optimizer",
    "TrainState", "init_train_state", "make_train_step",
]
