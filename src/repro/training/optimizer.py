"""Optimizers built from scratch: AdamW and Adafactor.

Large-scale memory features (DESIGN.md §6):

* ``state_dtype`` — keep Adam moments in bf16 (halves optimizer HBM;
  nemotron-340b at 256 chips does not fit fp32 moments: 16 B/param ×
  340e9 / 256 = 21 GB/chip > 16 GB, bf16 moments + bf16 params = 8 B →
  10.6 GB ✓).
* Adafactor — factored second moment (rows+cols instead of full matrix),
  the standard choice for ≥100B dense training.
* ZeRO sharding of the state is applied by the caller via
  ``sharding.specs.zero_extend``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row accumulators (or full v for <2D leaves)
    vc: Any   # col accumulators (None-like zeros for <2D leaves)


def _tree_zeros(params, dtype):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


class AdamW:
    def __init__(self, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd=0.1,
                 state_dtype=jnp.float32, grad_clip=1.0):
        self.lr, self.b1, self.b2, self.eps, self.wd = lr, b1, b2, eps, wd
        self.state_dtype = state_dtype
        self.grad_clip = grad_clip

    def init(self, params) -> AdamWState:
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=_tree_zeros(params, self.state_dtype),
            v=_tree_zeros(params, self.state_dtype),
        )

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v2 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mh = m2 / (1 - self.b1 ** step.astype(jnp.float32))
            vh = v2 / (1 - self.b2 ** step.astype(jnp.float32))
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.wd * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - self.lr * delta
            return (p2.astype(p.dtype), m2.astype(self.state_dtype),
                    v2.astype(self.state_dtype))

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        p2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v2 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return p2, AdamWState(step=step, m=m2, v=v2), gnorm


class Adafactor:
    """Factored RMS optimizer (Shazeer & Stern 2018), relative step off."""

    def __init__(self, lr=1e-3, eps=1e-30, decay=0.8, wd=0.0, grad_clip=1.0):
        self.lr, self.eps, self.decay, self.wd = lr, eps, decay, wd
        self.grad_clip = grad_clip

    def init(self, params) -> AdafactorState:
        def vr_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree.map(vr_init, params),
            vc=jax.tree.map(vc_init, params),
        )

    def update(self, grads, state: AdafactorState, params):
        step = state.step + 1
        beta = 1.0 - step.astype(jnp.float32) ** (-self.decay)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32) * scale
            g2 = g * g + self.eps
            if p.ndim >= 2:
                vr2 = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc2 = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr2 / jnp.clip(jnp.mean(vr2, axis=-1, keepdims=True), 1e-30)
                precond = jax.lax.rsqrt(r[..., None]) * jax.lax.rsqrt(
                    jnp.clip(vc2[..., None, :], 1e-30))
            else:
                vr2 = beta * vr + (1 - beta) * g2
                vc2 = vc
                precond = jax.lax.rsqrt(jnp.clip(vr2, 1e-30))
            u = g * precond
            # update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            p2 = p.astype(jnp.float32) - self.lr * (
                u + self.wd * p.astype(jnp.float32))
            return p2.astype(p.dtype), vr2, vc2

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        p2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        vr = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        vc = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return p2, AdafactorState(step=step, vr=vr, vc=vc), gnorm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def make_optimizer(cfg) -> AdamW | Adafactor:
    if cfg.optimizer == "adafactor":
        return Adafactor()
    if cfg.optimizer == "adamw_bf16":
        return AdamW(state_dtype=jnp.bfloat16)
    return AdamW()


def cosine_lr(step, *, base=3e-4, warmup=1000, total=100_000, floor=0.1):
    s = step.astype(jnp.float32)
    warm = s / warmup
    import numpy as np
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return base * jnp.where(s < warmup, warm, cos)
