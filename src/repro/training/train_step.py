"""Train step: grad accumulation, compression hook, fused update.

``make_train_step`` builds the jitted function the launcher and the
dry-run lower: microbatch ``lax.scan`` accumulation (keeps the activation
peak at one microbatch), optional int8 error-feedback gradient
compression before the cross-replica reduction, optimizer update.

Under pjit, gradients of data-parallel params are reduced automatically;
the compression hook demonstrates the bytes-level trick explicitly for
the cross-pod path (it quantizes the gradient leaves to int8 with a
per-tensor scale, which XLA then all-reduces in int8 width).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import loss_fn
from repro.training.optimizer import make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array


def compress_grads_int8(grads):
    """int8 quantize-dequantize with per-leaf scale (error feedback is
    carried implicitly by requantizing fresh grads each step)."""

    def q(g):
        if g.dtype == jnp.int32 or g.size <= 1024:
            return g
        a = jnp.max(jnp.abs(g)) + 1e-12
        qg = jnp.clip(jnp.round(g / a * 127.0), -127, 127).astype(jnp.int8)
        return qg.astype(jnp.float32) * (a / 127.0)

    return jax.tree.map(q, grads)


def make_train_step(cfg: ModelConfig, *, compress: bool = False,
                    q_block: int = 512):
    opt = make_optimizer(cfg)

    def split_microbatches(batch, n):
        def f(x):
            b = x.shape[0]
            return x.reshape((n, b // n) + x.shape[1:])
        return jax.tree.map(f, batch)

    def train_step(state: TrainState, batch: dict):
        nmb = cfg.microbatch if cfg.microbatch > 1 else 1

        if nmb > 1:
            mbs = split_microbatches(batch, nmb)
            acc_dt = jnp.dtype(cfg.accum_dtype)

            def accum(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(
                    state.params, cfg, mb, q_block)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.float32(0.0), zeros), mbs)
            loss = loss / nmb
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / nmb, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(
                state.params, cfg, batch, q_block)

        if compress:
            grads = compress_grads_int8(grads)
        params, opt_state, gnorm = opt.update(grads, state.opt, state.params)
        new_state = TrainState(params=params, opt=opt_state,
                               step=state.step + 1)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_state, metrics

    return train_step, opt


def init_train_state(cfg: ModelConfig, params) -> TrainState:
    opt = make_optimizer(cfg)
    return TrainState(params=params, opt=opt.init(params),
                      step=jnp.zeros((), jnp.int32))
