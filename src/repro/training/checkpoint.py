"""Sharded, atomic, async checkpointing with restore-time resharding.

Layout: ``<dir>/step_<N>/`` holds one ``.npz`` per host (this process
saves the addressable shards of every array) plus ``manifest.json`` with
the pytree structure, global shapes and dtypes.  Commit protocol: write
into ``step_<N>.tmp`` then ``os.rename`` — a crashed save can never be
mistaken for a complete checkpoint (restart-safety).

Restore never assumes the saving mesh: arrays are rebuilt host-side from
the manifest and ``device_put`` against the *current* sharding — restarts
may change pod count / mesh shape (elastic scaling).

``AsyncCheckpointer`` moves serialization+IO off the training thread
(standard straggler/jitter mitigation for large-scale runs).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = np.dtype(jnp.bfloat16)


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)

    flat = _flatten(tree)
    manifest = {}
    arrays = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if arr.dtype == _BF16:  # npz has no bf16: store the raw bits
            arr = arr.view(np.uint16)
        arrays[key.replace("/", "__")] = arr
        manifest[key] = {"shape": list(arr.shape), "dtype": dtype_name}
    host = jax.process_index() if jax.process_count() > 1 else 0
    np.savez(os.path.join(tmp, f"host_{host}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, *, step: int | None = None,
            shardings=None):
    """Rebuild ``tree_like``-structured state; reshard onto ``shardings``
    (a matching pytree of NamedSharding) if given."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    data = {}
    for fn in os.listdir(d):
        if fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                for k in z.files:
                    key = k.replace("__", "/")
                    arr = z[k]
                    if manifest.get(key, {}).get("dtype") == "bfloat16":
                        arr = arr.view(_BF16)
                    data[key] = arr

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (path, like), shard in zip(flat, shard_flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key].astype(like.dtype) if hasattr(like, "dtype") else data[key]
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Background-thread writer; ``wait()`` joins the in-flight save."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
