"""Fixed-point quantization of probability distributions (paper §III).

The AIA compiler chain quantizes all model probabilities to integer
("non-normalized") weights before they ever reach the sampler unit; the
Knuth-Yao sampler then works directly on the integer weights without a
normalization pass.  This module is the JAX equivalent of that Statheros-
style quantization stage [Laurel et al., DAC'21].

Conventions
-----------
A quantized distribution over ``n`` outcomes is a vector of non-negative
``int32`` weights ``w`` with ``sum(w) <= 2**k_max``.  The *implicit*
rejection mass is ``2**K - sum(w)`` where ``K = ceil(log2(sum(w)))`` is
chosen per-distribution by the sampler so that the rejection probability
is < 1/2 (expected #attempts < 2, as in the paper's rejection-restart
sampler and in FLDR [Saad et al. 2020]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Default weight precision: probabilities are quantized onto a 2**DEFAULT_K
# grid. 14 bits keeps int32 column sums safe up to n = 2**17 outcomes
# (vocab-scale) and matches the paper's "negligible accuracy loss" regime.
DEFAULT_K = 14
MAX_K = 30  # int32 safety bound for single-distribution total mass


def quantize_probs(p: jax.Array, k: int = DEFAULT_K) -> jax.Array:
    """Quantize a (batch of) probability vector(s) to int32 KY weights.

    ``p`` is non-negative (need not be normalized — that is the point).
    Weights are ``floor(p / max(p) * (2**k - 1))`` with the guarantee that
    at least one weight is non-zero: the argmax always maps to 2**k - 1.
    Normalization is never required downstream.
    """
    p = jnp.asarray(p)
    scale = (2.0 ** k - 1.0) / jnp.clip(
        jnp.max(p, axis=-1, keepdims=True), 1e-30, None
    )
    w = jnp.floor(p * scale).astype(jnp.int32)
    return w


def quantize_logits(
    logits: jax.Array,
    k: int = DEFAULT_K,
    temperature: float = 1.0,
) -> jax.Array:
    """Quantize ``exp(logits/T)`` to integer KY weights *without* a softmax.

    This is the "softmax-free" decode path: subtract the per-row max (a
    max-reduction, not a sum), exponentiate, and floor onto the 2**k grid.
    No normalizing sum over the vocabulary is ever computed; the KY sampler
    consumes the non-normalized weights directly.
    """
    logits = jnp.asarray(logits, jnp.float32) / jnp.maximum(temperature, 1e-6)
    z = logits - jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    w = jnp.floor(jnp.exp(z) * (2.0 ** k - 1.0)).astype(jnp.int32)
    return w


def dequantize(w: jax.Array) -> jax.Array:
    """Normalized float distribution represented by integer weights."""
    w = jnp.asarray(w, jnp.float32)
    return w / jnp.clip(jnp.sum(w, axis=-1, keepdims=True), 1.0, None)


def tv_distance(p: jax.Array, q: jax.Array) -> jax.Array:
    """Total-variation distance between two (batches of) distributions."""
    p = p / jnp.clip(jnp.sum(p, axis=-1, keepdims=True), 1e-30, None)
    q = q / jnp.clip(jnp.sum(q, axis=-1, keepdims=True), 1e-30, None)
    return 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)


def ceil_log2(x: jax.Array) -> jax.Array:
    """ceil(log2(x)) for positive int32 x, elementwise; 0 -> 0."""
    x = jnp.asarray(x, jnp.int32)
    nbits = 32 - jax.lax.clz(jnp.maximum(x - 1, 0).astype(jnp.int32))
    return jnp.where(x <= 1, 0, nbits).astype(jnp.int32)


def entropy_bits(p: jax.Array) -> jax.Array:
    """Shannon entropy in bits (the paper's Schmoo sweep variable)."""
    p = p / jnp.clip(jnp.sum(p, axis=-1, keepdims=True), 1e-30, None)
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.clip(p, 1e-30, None)), 0.0), axis=-1)


class Quantizer:
    """Fixed-point quantization config bundled for the compiler chain."""

    def __init__(self, k: int = DEFAULT_K, log_domain: bool = False):
        if not 1 <= k <= MAX_K:
            raise ValueError(f"k={k} outside [1, {MAX_K}]")
        self.k = k
        self.log_domain = log_domain

    def __call__(self, p: jax.Array) -> jax.Array:
        if self.log_domain:
            return quantize_logits(p, self.k)
        return quantize_probs(p, self.k)

    def error(self, p: jax.Array) -> jax.Array:
        """TV distance introduced by this quantizer on distribution(s) p."""
        return tv_distance(p, dequantize(self(p)))
