"""Random-bit streams for the Knuth-Yao sampler.

The AIA SoC feeds its sampler units from LFSRs — a free-running stream of
single bits.  On TPU the idiomatic equivalent is a counter-based PRNG
(threefry via ``jax.random``): we pre-generate a budget of uint32 words
per sampler lane and index single bits out of them with shift/mask, which
is exactly the bit-plane access pattern the VPU is good at.

A software LFSR (Fibonacci x^32+x^22+x^2+x+1) is also provided, both as a
reference for the hardware behaviour and for bit-exact reproduction tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bit_budget_words(max_bits: int) -> int:
    """uint32 words needed to hold ``max_bits`` bits per lane."""
    return (max_bits + 31) // 32


def random_bit_words(key: jax.Array, shape: tuple, max_bits: int) -> jax.Array:
    """(*, words) uint32 random words supplying ``max_bits`` bits per lane."""
    words = bit_budget_words(max_bits)
    return jax.random.bits(key, shape + (words,), dtype=jnp.uint32)


def get_bit(words: jax.Array, idx: jax.Array) -> jax.Array:
    """Extract bit ``idx`` (0-based) from the per-lane word stream.

    ``words``: (..., W) uint32;  ``idx``: (...,) int32 broadcastable.
    Returns int32 in {0, 1}.
    """
    word_ix = idx // 32
    bit_ix = idx % 32
    w = jnp.take_along_axis(words, word_ix[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return ((w >> bit_ix.astype(jnp.uint32)) & jnp.uint32(1)).astype(jnp.int32)


# ----------------------------------------------------------------------------
# Reference LFSR (matches a 32-bit Fibonacci LFSR; taps 32,22,2,1)
# ----------------------------------------------------------------------------
_LFSR_TAPS = (31, 21, 1, 0)  # 0-based bit positions of taps


def lfsr_step(state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One LFSR step. Returns (new_state, output_bit). state: uint32 != 0."""
    state = jnp.asarray(state, jnp.uint32)
    fb = jnp.zeros_like(state)
    for t in _LFSR_TAPS:
        fb = fb ^ ((state >> jnp.uint32(t)) & jnp.uint32(1))
    new = (state >> jnp.uint32(1)) | (fb << jnp.uint32(31))
    return new, (state & jnp.uint32(1)).astype(jnp.int32)


def lfsr_bits(seed: int, n: int) -> jax.Array:
    """n LFSR output bits from a scalar seed (reference implementation)."""

    def body(state, _):
        state, bit = lfsr_step(state)
        return state, bit

    seed = jnp.uint32(seed if seed != 0 else 0xDEADBEEF)
    _, bits = jax.lax.scan(body, seed, None, length=n)
    return bits
