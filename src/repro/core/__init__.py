"""AIA core: non-normalized Knuth-Yao sampling, LUT interpolation,
fixed-point quantization — the paper's contribution as composable JAX
modules (DESIGN.md §1-§2)."""
from repro.core.cdf import CDFResult, cdf_sample
from repro.core.fixedpoint import (
    DEFAULT_K,
    Quantizer,
    dequantize,
    entropy_bits,
    quantize_logits,
    quantize_probs,
    tv_distance,
)
from repro.core.interp import (
    InterpTable,
    exp_table,
    iu_exp_weights,
    iu_log,
    log_table,
    sigmoid_table,
    softplus_table,
)
from repro.core.ky import KYResult, ky_sample, ky_sample_ref
from repro.core.token_sampler import (
    TokenSample,
    categorical_baseline,
    ky_sample_tokens,
    ky_sample_weights_hier,
    vocab_k,
)

__all__ = [
    "CDFResult", "cdf_sample", "DEFAULT_K", "Quantizer", "dequantize",
    "entropy_bits", "quantize_logits", "quantize_probs", "tv_distance",
    "InterpTable", "exp_table", "iu_exp_weights", "log_table",
    "sigmoid_table", "softplus_table", "iu_log", "KYResult", "ky_sample",
    "ky_sample_ref", "TokenSample", "categorical_baseline",
    "ky_sample_tokens", "ky_sample_weights_hier", "vocab_k",
]
