"""LUT-based interpolation unit (paper §II-B, C2).

The AIA IU evaluates ``exp``, ``log`` … in a single cycle by piecewise
linear interpolation on a small LUT held in registers:

    y = LUT[idx] + frac * (LUT[idx+1] - LUT[idx])

where ``idx`` is the top bits and ``frac`` the residual of a fixed-point
input.  The JAX module keeps the same structure — a 2**m-entry table that
lives in VMEM on TPU (see ``kernels/interp_lut.py``), a shift/mask index
split, and one fused multiply-add — so the cost model carries over:
one small gather + one FMA per element instead of a transcendental.

``InterpTable.build`` constructs a table for an arbitrary scalar function
over a range; pre-built tables for exp/log/sigmoid/softplus cover the
distribution-generation pipeline of Gibbs sampling (energies -> weights).

:func:`masked_exp_weights` is the shared distribution-generation tail of
every Gibbs family (label mask → max-subtract → LUT-exp → fixed-point
floor).  It is deliberately plain ``jnp`` so the fused Pallas sweep
kernel (``kernels/fused_sweep.py``) can run the *same function* inside
the kernel body — that, together with ``core/ky.py::ky_walk`` and a
shared bit stream, is what makes ``sampler="pallas"`` bitwise-identical
to the ``sampler="xla"`` path (contract spelled out in
``docs/kernels.md``).  The Pallas wrapper around the bare LUT lives in
``kernels/interp_lut.py``; both it and the fused kernel accept
``interpret=True`` to run on CPU (the CI escape hatch).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class InterpTable:
    """Piecewise-linear LUT over [lo, hi] with 2**m segments."""

    table: jax.Array      # (2**m + 1,) float32 node values
    lo: float
    hi: float
    m: int                # log2 #segments

    @staticmethod
    def build(fn: Callable, lo: float, hi: float, m: int = 8) -> "InterpTable":
        xs = np.linspace(lo, hi, (1 << m) + 1, dtype=np.float64)
        tab = jnp.asarray(np.asarray(fn(xs), dtype=np.float32))
        return InterpTable(table=tab, lo=float(lo), hi=float(hi), m=m)

    def __call__(self, x: jax.Array) -> jax.Array:
        """Interpolate fn(x); inputs are clamped to [lo, hi]."""
        x = jnp.asarray(x, jnp.float32)
        n = 1 << self.m
        scale = n / (self.hi - self.lo)
        t = jnp.clip((x - self.lo) * scale, 0.0, float(n))
        idx = jnp.minimum(t.astype(jnp.int32), n - 1)  # "IU.address"
        frac = t - idx.astype(jnp.float32)             # "offset"
        y0 = jnp.take(self.table, idx, mode="clip")
        y1 = jnp.take(self.table, idx + 1, mode="clip")
        return y0 + frac * (y1 - y0)           # single FMA, as in the IU

    def max_abs_error(self, fn: Callable, probe: int = 65536) -> float:
        xs = np.linspace(self.lo, self.hi, probe).astype(np.float32)
        exact = np.asarray(fn(xs.astype(np.float64)))
        approx = np.asarray(jax.jit(self.__call__)(xs))
        return float(np.max(np.abs(exact - approx)))


# Pytree registration: the node values are traced data, the range/shape
# metadata is static — so an InterpTable can cross a jit boundary (e.g.
# as the `table` argument of kernels.fused_sweep.fused_gibbs_sample).
jax.tree_util.register_pytree_node(
    InterpTable,
    lambda t: ((t.table,), (t.lo, t.hi, t.m)),
    lambda aux, ch: InterpTable(table=ch[0], lo=aux[0], hi=aux[1], m=aux[2]),
)


# Pre-built tables used by the Gibbs distribution-generation stage.
# exp over negative energies: exp(x) for x in [-16, 0] covers weights down
# to ~1e-7 — below quantization resolution for k<=24.
def exp_table(m: int = 10) -> InterpTable:
    return InterpTable.build(np.exp, -16.0, 0.0, m)


def log_table(m: int = 10) -> InterpTable:
    """LUT over the mantissa range [1, 2) — see ``iu_log``."""
    return InterpTable.build(np.log, 1.0, 2.0, m)


def iu_log(x: jax.Array, table: InterpTable | None = None) -> jax.Array:
    """log(x) via mantissa/exponent split + PWL LUT (the HW-idiomatic form).

    ``x = mant * 2**e`` with ``mant in [1, 2)``; ``log x = LUT(mant) +
    e*ln2``.  Uniform relative accuracy over the full positive range,
    unlike a single uniform table near 0.
    """
    table = table or _LOG_DEFAULT
    x = jnp.asarray(x, jnp.float32)
    mant, e = jnp.frexp(jnp.clip(x, 1e-30, None))  # mant in [0.5, 1)
    return table(mant * 2.0) + (e - 1).astype(jnp.float32) * jnp.float32(np.log(2.0))


def sigmoid_table(m: int = 10) -> InterpTable:
    return InterpTable.build(lambda x: 1.0 / (1.0 + np.exp(-x)), -8.0, 8.0, m)


def softplus_table(m: int = 10) -> InterpTable:
    return InterpTable.build(lambda x: np.log1p(np.exp(x)), -8.0, 8.0, m)


def iu_exp_weights(energies: jax.Array, k: int, table: InterpTable | None = None) -> jax.Array:
    """Energies -> non-normalized KY weights through the IU (fused path).

    ``w = floor(iu_exp(e - max(e)) * (2**k - 1))`` — the AIA distribution
    generation pipeline: subtract max (no sum-normalization), LUT-exp,
    fixed-point floor.  Output feeds ``ky_sample`` directly.
    """
    table = table or _EXP_DEFAULT
    e = jnp.asarray(energies, jnp.float32)
    z = e - jnp.max(e, axis=-1, keepdims=True)
    y = table(z)
    return jnp.floor(y * (2.0 ** k - 1.0)).astype(jnp.int32)


# Labels at or beyond a lane's cardinality are masked to this log-weight
# before the max-subtract: 4x the compiler chain's per-entry CPT floor
# (pgm.compile._NEG = -60), far below any reachable real energy, and deep
# under the exp-LUT's lo clamp so the masked weight quantizes to 0 for
# every k <= 23 (exp(-16) * (2**23 - 1) < 1).
MASK_NEG = -240.0


def masked_exp_weights(
    logw: jax.Array,
    card: jax.Array,
    k: int,
    *,
    use_iu: bool = True,
    table: "InterpTable | None" = None,
    mask_value: float = MASK_NEG,
) -> jax.Array:
    """Shared Gibbs distribution-generation tail: log-weights -> KY weights.

    ``w = floor(exp(logw - max logw) * (2**k - 1))`` with labels
    ``>= card`` first masked to ``mask_value`` (they quantize to weight 0
    for ``k <= 23``), and ``exp`` evaluated through the IU LUT when
    ``use_iu``.  ``logw`` is (..., L); ``card`` broadcasts against the
    batch axes (per-node cardinalities for BN/sparse plans, a scalar L
    for dense grids).  This exact function runs both in the XLA sampler
    path (via ``pgm.compile.ky_weights``) and *inside* the fused Pallas
    kernel, so the two are bitwise-comparable by construction.
    """
    ls = jnp.arange(logw.shape[-1], dtype=jnp.int32)
    logw = jnp.where(ls < card[..., None], logw, mask_value)
    z = logw - jnp.max(logw, axis=-1, keepdims=True)
    if use_iu:
        y = (table or _EXP_DEFAULT)(z)
    else:
        y = jnp.exp(z)
    return jnp.floor(y * (2.0 ** k - 1.0)).astype(jnp.int32)


_EXP_DEFAULT = exp_table()
_LOG_DEFAULT = log_table()
