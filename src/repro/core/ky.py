"""Non-normalized Knuth-Yao sampling with rejection (paper §II-B, C1).

The sampler draws exact samples from *integer, non-normalized* weight
vectors ``w`` (shape ``(..., n)``) by walking the Knuth-Yao discrete
distribution generating (DDG) tree with single random bits.  The pad mass
``r = 2**K - sum(w)`` (with ``K = ceil(log2(sum(w)))``) is treated as an
implicit rejection outcome: reaching it restarts the walk, exactly as in
the AIA sampler unit and FLDR [Saad et al. 2020].  Because
``2**(K-1) < sum(w) <= 2**K``, the rejection probability is < 1/2 and the
expected number of restarts is < 2.

TPU adaptation (see DESIGN.md §2): instead of one branchy scalar walk per
sample, a whole batch of lanes walks DDG *levels* in lock-step inside a
``lax.while_loop``.  Per level the bit-plane column of the weight matrix
is extracted with shift/mask (the vector-register analogue of the AIA
register file's column-wise read port), a cumulative sum over outcomes
locates the leaf, and rejected lanes restart in place while finished
lanes idle.  The walk is short — ≈ entropy + 2 levels — so lock-step
masking wastes little work.

The expected number of random bits consumed per sample is ≈ H(p) + 2
(the paper's headline efficiency metric); ``KYResult.bits_used`` exposes
the exact per-lane count.

Bit-stream contract (see ``docs/kernels.md``): this module uses a
**per-lane bit cursor** — lane ``i`` reads bit ``t_i`` of its own uint32
word row, and ``t_i`` advances only while lane ``i`` is still walking.
:func:`ky_walk` is the cursor-and-walk core, factored out so the fused
Pallas sweep kernel (``kernels/fused_sweep.py``) can run the *identical*
code on the identical pre-generated words — which is what makes the
engine's ``sampler="pallas"`` path bitwise-interchangeable with
``sampler="xla"``.  The standalone KY kernel/oracle pair
(``kernels/ky_sampler.py`` / ``kernels/ref.py::ky_ref``) instead shares a
**global** bit cursor (every lane reads bit ``it`` of its own stream at
loop iteration ``it``); the two disciplines consume different bit
positions and are *not* bit-comparable with each other.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import rng as rng_lib
from repro.core.fixedpoint import ceil_log2


class KYResult(NamedTuple):
    sample: jax.Array      # (...,) int32 outcome indices
    bits_used: jax.Array   # (...,) int32 random bits consumed
    attempts: jax.Array    # (...,) int32 DDG walks started (>=1)
    ok: jax.Array          # (...,) bool: terminated within budget


def max_levels(k: int, n: int) -> int:
    """Upper bound on DDG depth for n outcomes of k-bit weights."""
    return int(k + max(int(jnp.ceil(jnp.log2(max(n, 2)))), 1) + 1)


def ky_walk(flat: jax.Array, bit_words: jax.Array) -> KYResult:
    """Lock-step DDG walk over pre-generated per-lane bit streams.

    Args:
      flat: (b, n) non-negative int32 weight rows.
      bit_words: (b, W) uint32; lane ``i`` consumes bits of row ``i``
        under the per-lane cursor (bit ``t_i``, advanced only while the
        lane is active).  The walk budget is ``W * 32`` bits per lane.

    This is the sampling core behind :func:`ky_sample`; the fused Pallas
    sweep kernel (``kernels/fused_sweep.py``) calls it verbatim inside
    the kernel body, so both consume identical bit positions and return
    bitwise-identical results for identical inputs.  Returns a
    :class:`KYResult` with (b,) fields.
    """
    flat = jnp.asarray(flat, jnp.int32)
    b, n = flat.shape
    budget = int(bit_words.shape[-1]) * 32

    total = jnp.sum(flat, axis=-1)
    # Defensive: an all-zero row would hang the walk; force outcome 0.
    flat = jnp.where((total == 0)[:, None] & (jnp.arange(n) == 0)[None, :], 1, flat)
    total = jnp.maximum(total, 1)

    k_lvl = jnp.maximum(ceil_log2(total), 1)      # per-lane K (>=1)
    reject_w = (jnp.int32(1) << k_lvl) - total    # pad mass (may be 0)

    def cond(state):
        done, _, _, _, t, _ = state
        return (~jnp.all(done)) & (jnp.max(jnp.where(done, 0, t)) < budget - 1)

    def body(state):
        done, d, c, res, t, att = state
        active = ~done
        bit = rng_lib.get_bit(bit_words, jnp.minimum(t, budget - 1))
        d2 = 2 * d + (1 - bit)
        # Bit-plane column at level c: MSB-first bit of each weight.
        shift = (k_lvl - 1 - c)[:, None]
        col = jnp.where(shift >= 0, (flat >> shift) & 1, 0)
        rcol = jnp.where(shift[:, 0] >= 0, (reject_w >> shift[:, 0]) & 1, 0)
        cum = jnp.cumsum(col, axis=-1)
        colsum = cum[:, -1] + rcol
        hit = d2 < colsum
        # first index with cum == d2+1; if none (leaf is the rejection pad),
        # sel lands past the real outcomes.
        ge = cum >= (d2 + 1)[:, None]
        sel = jnp.argmax(ge, axis=-1)
        is_real = hit & ge[jnp.arange(b), sel]
        is_rej = hit & ~is_real
        # level overflow can't occur with exact pad mass, but guard anyway
        overflow = (~hit) & (c + 1 >= k_lvl)
        restart = (is_rej | overflow) & active
        finish = is_real & active
        done2 = done | finish
        res2 = jnp.where(finish, sel.astype(jnp.int32), res)
        d3 = jnp.where(restart, 0, jnp.where(hit, d, d2 - colsum))
        c2 = jnp.where(restart, 0, jnp.where(hit, c, c + 1))
        t2 = t + active.astype(jnp.int32)
        att2 = att + restart.astype(jnp.int32)
        return done2, d3, c2, res2, t2, att2

    # Degenerate rows where one outcome carries the whole mass are
    # deterministic: p = total/2^K = 1.0 has no fractional DDG expansion
    # (hypothesis-found corner, e.g. w = [0, 2]); resolve them up front
    # with zero random bits, exactly like the hardware's bypass path.
    argmax0 = jnp.argmax(flat, axis=-1).astype(jnp.int32)
    deterministic = jnp.max(flat, axis=-1) == total

    # Derive the carry init from the inputs so it inherits any varying
    # manual axes when called inside shard_map (JAX >= 0.7 VMA rules).
    zeros = flat[:, 0] * 0 + (bit_words[:, 0] * 0).astype(jnp.int32)
    state = (deterministic, zeros, zeros,
             jnp.where(deterministic, argmax0, 0), zeros, zeros + 1)
    done, _, _, res, t, att = jax.lax.while_loop(cond, body, state)
    # Fallback for (astronomically unlikely) budget exhaustion.
    res = jnp.where(done, res, jnp.argmax(flat, axis=-1).astype(jnp.int32))
    return KYResult(sample=res, bits_used=t, attempts=att, ok=done)


def ky_sample(
    key: jax.Array,
    weights: jax.Array,
    *,
    max_attempts: int = 32,
    bit_words: jax.Array | None = None,
) -> KYResult:
    """Draw one exact sample per lane from non-normalized int32 weights.

    Args:
      key: PRNG key (ignored if ``bit_words`` given).
      weights: (..., n) non-negative int32; rows must not be all-zero.
      max_attempts: restart budget; non-terminating lanes (prob < 2**-32)
        fall back to argmax and are flagged ``ok=False``.
      bit_words: optional pre-generated (..., W) uint32 bit stream — used
        by tests for bit-exact comparison with the reference/LFSR path.

    Returns KYResult with ``sample`` shaped like ``weights[..., 0]``.
    The bit stream is read with the per-lane cursor of :func:`ky_walk`;
    ``kernels/fused_sweep.py`` draws from the same stream for the same
    ``key``, which is what the engine's ``sampler=`` flag relies on.
    """
    w = jnp.asarray(weights, jnp.int32)
    batch_shape = w.shape[:-1]
    n = w.shape[-1]
    flat = w.reshape((-1, n))
    b = flat.shape[0]

    k_static = 31  # static per-attempt level cap (int32 weights)
    if bit_words is None:
        bit_words = rng_lib.random_bit_words(key, (b,), k_static * max_attempts)
    else:
        bit_words = bit_words.reshape((b, -1))

    r = ky_walk(flat, bit_words)
    return KYResult(
        sample=r.sample.reshape(batch_shape),
        bits_used=r.bits_used.reshape(batch_shape),
        attempts=r.attempts.reshape(batch_shape),
        ok=r.ok.reshape(batch_shape),
    )


def ky_sample_ref(weights, bits) -> tuple[int, int]:
    """Pure-Python single-lane reference (mirrors the AIA SU microcode).

    ``weights``: list[int]; ``bits``: iterable of 0/1.  Returns
    (outcome, bits_consumed).  Used as the oracle in bit-exact tests.
    """
    import math

    w = list(int(x) for x in weights)
    total = sum(w)
    assert total > 0
    if max(w) == total:  # deterministic-row bypass (p = 1.0, no DDG walk)
        return w.index(max(w)), 0
    k = max(1, math.ceil(math.log2(total))) if total > 1 else 1
    if (1 << k) < total:
        k += 1
    rej = (1 << k) - total
    wall = w + [rej]
    it = iter(bits)
    used = 0
    d = 0
    c = 0
    while True:
        b = next(it)
        used += 1
        d = 2 * d + (1 - int(b))
        col = [(x >> (k - 1 - c)) & 1 if k - 1 - c >= 0 else 0 for x in wall]
        s = 0
        hit = -1
        for i, bit_i in enumerate(col):
            s += bit_i
            if s == d + 1 and hit < 0:
                hit = i
        if d < s:
            if hit < len(w):
                return hit, used
            d = 0
            c = 0  # rejection: restart
            continue
        d -= s
        c += 1
