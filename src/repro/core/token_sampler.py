"""KY token sampling for LM decode — the paper's sampler as a first-class
feature of the serving path (DESIGN.md §3).

Sampling a token is sampling from a discrete distribution over the
vocabulary — exactly the workload of the AIA sampler unit.  The softmax-
free pipeline is:

    logits --(max-subtract, exp, fixed-point floor)--> int32 weights
           --(two-level Knuth-Yao with rejection)--> token id

No normalizing sum over the vocabulary is computed anywhere.  The vocab
is folded into ``n/chunk`` chunks; stage 1 KY-samples a chunk from the
exact integer chunk sums, stage 2 KY-samples within the chosen chunk.
The composition is *exact* on the quantized weights:
``P(i) = S_c/S * w_i/S_c = w_i/S``.

The weight precision is automatically capped at ``k <= 30 - log2(n)`` so
int32 chunk/total sums cannot overflow; for a 256k vocab that is k=12,
i.e. weights below ``max_p * 2**-12`` truncate to zero (an implicit
top-p-style cut far below sampling noise — measured in tests).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import DEFAULT_K
from repro.core.ky import ky_sample


class TokenSample(NamedTuple):
    token: jax.Array      # (...,) int32
    bits_used: jax.Array  # (...,) int32 total random bits (both stages)
    ok: jax.Array         # (...,) bool


def vocab_k(n_vocab: int, k: int = DEFAULT_K) -> int:
    """Largest safe weight precision for an n_vocab-way distribution."""
    return max(4, min(k, 30 - math.ceil(math.log2(max(n_vocab, 2)))))


def ky_sample_weights_hier(
    key: jax.Array, weights: jax.Array, *, chunk: int = 512
) -> TokenSample:
    """Exact two-level KY sample from (..., n) int32 weights."""
    w = jnp.asarray(weights, jnp.int32)
    batch_shape = w.shape[:-1]
    n = w.shape[-1]
    flat = w.reshape((-1, n))
    b = flat.shape[0]
    pad = (-n) % chunk
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    c = flat.shape[-1] // chunk
    chunked = flat.reshape((b, c, chunk))
    sums = jnp.sum(chunked, axis=-1)  # (b, c) exact int32 chunk sums

    k1, k2 = jax.random.split(key)
    stage1 = ky_sample(k1, sums)
    sel = jnp.take_along_axis(chunked, stage1.sample[:, None, None], axis=1)[:, 0, :]
    stage2 = ky_sample(k2, sel)
    token = stage1.sample * chunk + stage2.sample
    return TokenSample(
        token=token.reshape(batch_shape),
        bits_used=(stage1.bits_used + stage2.bits_used).reshape(batch_shape),
        ok=(stage1.ok & stage2.ok).reshape(batch_shape),
    )


def ky_sample_tokens(
    key: jax.Array,
    logits: jax.Array,
    *,
    temperature: float = 1.0,
    k: int = DEFAULT_K,
    chunk: int = 512,
) -> TokenSample:
    """Softmax-free token sampling from (..., vocab) logits.

    Two-scale quantization (beyond-paper improvement, DESIGN.md §5): each
    chunk is quantized against its OWN max — so tail chunks keep ~k bits
    of relative precision instead of truncating at ``p_max·2^-k`` — and
    stage-1 KY samples the quantized *chunk masses*.  Both KY stages stay
    exact on their integer weights; total TV error is O(2^-k) uniformly,
    and no sum over the vocabulary is ever normalized.
    """
    t = jnp.maximum(temperature, 1e-6)
    z = jnp.asarray(logits, jnp.float32) / t
    batch_shape = z.shape[:-1]
    n = z.shape[-1]
    flat = z.reshape((-1, n))
    pad = (-n) % chunk
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    b = flat.shape[0]
    c = flat.shape[-1] // chunk
    zc = flat.reshape((b, c, chunk))
    zc = zc - jax.lax.stop_gradient(jnp.max(zc, axis=(-2, -1), keepdims=True))

    m_c = jnp.max(zc, axis=-1, keepdims=True)              # per-chunk max
    kk = min(k, 22)  # chunk sums: 512 * 2^22 < 2^31
    w2 = jnp.floor(jnp.exp(zc - m_c) * (2.0 ** kk - 1.0)).astype(jnp.int32)
    w2 = jnp.where(jnp.isfinite(zc), w2, 0)
    # true chunk masses (float), quantized to stage-1 integer weights
    mass = jnp.exp(m_c[..., 0]) * jnp.sum(w2, axis=-1).astype(jnp.float32)
    w1 = jnp.floor(
        mass / jnp.clip(jnp.max(mass, axis=-1, keepdims=True), 1e-30)
        * (2.0 ** DEFAULT_K - 1.0)).astype(jnp.int32)

    k1, k2 = jax.random.split(key)
    stage1 = ky_sample(k1, w1)
    sel = jnp.take_along_axis(w2, stage1.sample[:, None, None], axis=1)[:, 0, :]
    stage2 = ky_sample(k2, sel)
    token = stage1.sample * chunk + stage2.sample
    return TokenSample(
        token=token.reshape(batch_shape),
        bits_used=(stage1.bits_used + stage2.bits_used).reshape(batch_shape),
        ok=(stage1.ok & stage2.ok).reshape(batch_shape),
    )


def categorical_baseline(key: jax.Array, logits: jax.Array, temperature: float = 1.0):
    """jax.random.categorical baseline (full softmax) for comparison."""
    t = jnp.maximum(temperature, 1e-6)
    return jax.random.categorical(key, jnp.asarray(logits, jnp.float32) / t, axis=-1)
