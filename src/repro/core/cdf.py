"""Inverse-CDF sampler — the baseline AIA is compared against (§II-B).

MSSE [Tambe et al.] and SPU [Bashizade et al.] use cumulative-distribution
(CDF) samplers: accumulate the weights, draw a full-width uniform, binary
search.  We implement it on the same non-normalized int32 weights so the
KY-vs-CDF benchmark is apples-to-apples: the CDF path needs a full-width
cumulative pass over all n outcomes and a 32-bit uniform per sample; the
KY path touches ≈ H(p)+2 bit-plane columns and ≈ H(p)+2 random bits.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CDFResult(NamedTuple):
    sample: jax.Array
    bits_used: jax.Array  # always 32 per sample (full-width uniform)


def cdf_sample(key: jax.Array, weights: jax.Array) -> CDFResult:
    """Inverse-CDF sample from (..., n) non-normalized int32 weights."""
    w = jnp.asarray(weights, jnp.int32)
    batch_shape = w.shape[:-1]
    cum = jnp.cumsum(w, axis=-1)
    total = cum[..., -1:]
    # u ~ Uniform{0, ..., total-1}, via rejection-free modulo on 32 random
    # bits (modulo bias < 2**-(32-k) — negligible for k <= 24 and matches
    # what CDF-sampler ASICs actually do).
    u = jax.random.bits(key, batch_shape, dtype=jnp.uint32)
    u = (u % jnp.maximum(total[..., 0], 1).astype(jnp.uint32)).astype(jnp.int32)
    sample = jnp.sum((cum <= u[..., None]).astype(jnp.int32), axis=-1)
    bits = jnp.full(batch_shape, 32, jnp.int32)
    return CDFResult(sample=sample, bits_used=bits)
