"""Serving-stack telemetry: query-lifecycle tracing + metrics registry.

The AIA chip justifies its headline numbers (1277 MSample/s, 20
GSample/s/W) with per-core counters that attribute every cycle to
sample generation, interpolation, or transfer; this module is the
serving stack's equivalent.  It has two halves:

* a **span tracer** recording the full query lifecycle — submit →
  bucket wait → admit → plan-cache lookup/compile → per-round sweep
  steps (lane occupancy, backfill, the ESS trajectory the retirement
  rule already computes) → retirement (with reason) → delivery — as
  structured events with monotonic timestamps, exportable as
  Chrome/Perfetto trace-event JSON (:meth:`Telemetry.chrome_trace`,
  load it at https://ui.perfetto.dev);
* a **metrics registry** of counters, gauges, and fixed log-spaced-bin
  histograms fed from :class:`repro.serve.engine.PosteriorEngine`,
  :class:`repro.serve.engine.GroupRun`, :class:`repro.serve.queue.
  AdmissionQueue` and the plan cache, exportable as Prometheus text
  exposition (:meth:`Telemetry.prometheus`) and as a JSON snapshot
  (:meth:`Telemetry.metrics_snapshot`) that ``benchmarks.bench_serve``
  merges into its report.

Telemetry is a **no-op by default**: the engine holds the shared
:data:`NULL` instance (the null-recorder pattern), every hot-path call
site guards on ``telemetry.enabled``, and CI gates the enabled-recorder
overhead at ≤ 5% ESS/s (``benchmarks/check_serve_regression.py``).

Clock discipline: span math uses ``time.monotonic()`` exclusively
(wall clocks step under NTP and would corrupt durations and deadline
math); wall-clock time appears only once, as the human-readable
``trace_start_iso`` metadata stamp.

Worked examples live in ``docs/observability.md`` (doctest-checked).
"""
from __future__ import annotations

import json
import math
import threading
import time
from bisect import bisect_left
from typing import Iterable

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL",
    "NullTelemetry", "Telemetry", "lifecycle_breakdown", "log_bins",
    "monotonic", "set_clock",
]

# One shared monotonic clock for every duration/deadline in the serving
# stack (queue deadlines, slot timing, spans).  time.time() is reserved
# for human-readable timestamps.
#
# The clock is a *seam*: scheduler/quota tests install a fake clock via
# ``set_clock`` so deadline and token-bucket behaviour is tested by
# advancing virtual time instead of sleeping on the wall clock (the
# ``fake_clock`` fixture in tests/conftest.py).  Every serving module
# imports ``monotonic`` by name, so the indirection must live *inside*
# the function — rebinding ``telemetry.monotonic`` would not reach the
# already-imported references.
_clock = time.monotonic


def monotonic() -> float:
    """Seconds on the serving stack's shared monotonic clock."""
    return _clock()


def set_clock(clock=None) -> None:
    """Install a replacement clock callable (None restores the real
    ``time.monotonic``).  Test seam only — production code never calls
    this."""
    global _clock
    _clock = clock if clock is not None else time.monotonic


# -- metrics ---------------------------------------------------------------
def log_bins(lo: float, hi: float, per_decade: int = 4) -> tuple[float, ...]:
    """Fixed log-spaced histogram bin edges covering [lo, hi].

    ``per_decade`` edges per power of ten; the edges are the bucket
    upper bounds (Prometheus ``le`` semantics — a final +Inf bucket is
    implicit).  Fixed bins keep ``observe`` O(log bins) with zero
    allocation, the property that lets the recorder sit on the round
    loop.
    """
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    n = int(math.ceil(per_decade * math.log10(hi / lo)))
    edges = [lo * 10.0 ** (i / per_decade) for i in range(n + 1)]
    return tuple(round(e, 12) for e in edges)


# Default bins: 100 µs .. 1000 s, 4 buckets per decade — wide enough
# for compile storms, fine enough to read a p99 off.
DEFAULT_SECONDS_BINS = log_bins(1e-4, 1e3)
# Round/sweep-count bins: 1 .. 4096, 4 per decade.
DEFAULT_COUNT_BINS = log_bins(1.0, 4096.0)


class Counter:
    """Monotonically increasing count (Prometheus ``counter``)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (Prometheus ``gauge``)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed log-spaced-bin histogram (Prometheus ``histogram``).

    ``bins`` are bucket *upper bounds*; observations above the last
    edge land in the implicit +Inf bucket.  :meth:`quantile` reads an
    estimate off the cumulative bucket counts (linear within a bucket),
    which is what the metrics snapshot reports as p50/p99.
    """

    __slots__ = ("bins", "counts", "count", "sum")

    def __init__(self, bins: tuple[float, ...] = DEFAULT_SECONDS_BINS):
        self.bins = tuple(float(b) for b in bins)
        self.counts = [0] * (len(self.bins) + 1)  # last = +Inf bucket
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bins, v)] += 1
        self.count += 1
        self.sum += v

    def quantile(self, q: float) -> float:
        """Bin-interpolated quantile estimate (0 when empty)."""
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if seen + c >= target and c:
                lo = self.bins[i - 1] if i else 0.0
                hi = self.bins[i] if i < len(self.bins) else self.bins[-1]
                return lo + (hi - lo) * (target - seen) / c
            seen += c
        return self.bins[-1]


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Family:
    """All label-children of one metric name, plus its metadata."""

    __slots__ = ("kind", "help", "children")

    def __init__(self, kind: str, help: str):
        self.kind, self.help = kind, help
        self.children: dict[tuple, Counter | Gauge | Histogram] = {}


class MetricsRegistry:
    """Named counters/gauges/histograms with Prometheus + JSON export.

    Accessors are get-or-create and thread-safe (the admission queue's
    dispatcher and client threads both record), so call sites never
    pre-declare metrics::

        reg = MetricsRegistry()
        reg.counter("serve_queries_submitted_total").inc()
        reg.histogram("serve_wait_seconds").observe(0.012)
        reg.counter("serve_retired_total", reason="max-sweeps").inc()
    """

    def __init__(self):
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind: str, help: str, labels: dict, make):
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(kind, help)
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}")
            key = _label_key(labels)
            child = fam.children.get(key)
            if child is None:
                child = fam.children[key] = make()
            return child

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(name, "gauge", help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  bins: tuple[float, ...] = DEFAULT_SECONDS_BINS,
                  **labels) -> Histogram:
        return self._get(name, "histogram", help, labels,
                         lambda: Histogram(bins))

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able dump: counters/gauges by labelled name, histograms
        as count/sum/p50/p99 (+ the raw cumulative buckets)."""
        out: dict = {}
        with self._lock:
            for name, fam in sorted(self._families.items()):
                for key, child in sorted(fam.children.items()):
                    label = name + "".join(f"{{{k}={v}}}" for k, v in key)
                    if fam.kind == "histogram":
                        cum, acc = [], 0
                        for c in child.counts:
                            acc += c
                            cum.append(acc)
                        out[label] = {
                            "count": child.count, "sum": child.sum,
                            "p50": child.quantile(0.50),
                            "p99": child.quantile(0.99),
                            "buckets": cum}
                    else:
                        out[label] = child.value
        return out

    def prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        with self._lock:
            for name, fam in sorted(self._families.items()):
                if fam.help:
                    lines.append(f"# HELP {name} {fam.help}")
                lines.append(f"# TYPE {name} {fam.kind}")
                for key, child in sorted(fam.children.items()):
                    base = dict(key)
                    if fam.kind == "histogram":
                        acc = 0
                        for i, c in enumerate(child.counts):
                            acc += c
                            le = ("+Inf" if i == len(child.bins)
                                  else repr(child.bins[i]))
                            lines.append(
                                f"{name}_bucket"
                                f"{_fmt_labels({**base, 'le': le})} {acc}")
                        lines.append(
                            f"{name}_sum{_fmt_labels(base)} {child.sum}")
                        lines.append(
                            f"{name}_count{_fmt_labels(base)} {child.count}")
                    else:
                        lines.append(
                            f"{name}{_fmt_labels(base)} {child.value}")
        return "\n".join(lines) + "\n"


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


# -- tracer ----------------------------------------------------------------
class Telemetry:
    """Live recorder: span tracer + metrics registry, one per engine.

    Tracks are Chrome-trace ``tid`` lanes — one per query and one per
    dispatched group — so spans on the same track nest by time
    containment when the trace is opened in Perfetto.  All record calls
    are thread-safe and cheap enough for the round loop; when tracing
    is off (``Telemetry(trace=False)``) the metrics half still runs.

    Timestamps: :func:`monotonic` seconds in, microseconds relative to
    the tracer's birth out (the trace-event ``ts`` contract).
    """

    enabled = True

    def __init__(self, *, trace: bool = True, metrics: bool = True):
        self.metrics = MetricsRegistry() if metrics else None
        self._trace = bool(trace)
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._tids: dict[str, int] = {}
        self._t0 = monotonic()
        self.trace_start_iso = time.strftime(
            "%Y-%m-%dT%H:%M:%S%z", time.localtime())

    # -- track / event recording ------------------------------------------
    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def track(self, name: str) -> int:
        """tid of the named track, creating it (and its Perfetto
        thread-name metadata event) on first use."""
        if not self._trace:
            return 0
        with self._lock:
            tid = self._tids.get(name)
            if tid is None:
                tid = self._tids[name] = len(self._tids) + 1
                self._events.append({
                    "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                    "args": {"name": name}})
            return tid

    def complete(self, name: str, tid: int, t0: float, t1: float,
                 **args) -> None:
        """One finished span [t0, t1] (monotonic seconds) on a track."""
        if not self._trace:
            return
        ev = {"name": name, "cat": "serve", "ph": "X", "pid": 1, "tid": tid,
              "ts": self._us(t0), "dur": max((t1 - t0) * 1e6, 0.0)}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, tid: int, **args) -> None:
        if not self._trace:
            return
        ev = {"name": name, "cat": "serve", "ph": "i", "s": "t", "pid": 1,
              "tid": tid, "ts": self._us(monotonic())}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def sample(self, name: str, value: float) -> None:
        """Counter-track sample (Chrome ``ph: "C"``): queue depth, lanes
        busy — rendered as a stepped area chart in Perfetto."""
        if not self._trace:
            return
        ev = {"name": name, "cat": "serve", "ph": "C", "pid": 1,
              "ts": self._us(monotonic()), "args": {name: value}}
        with self._lock:
            self._events.append(ev)

    # -- metrics shorthands -----------------------------------------------
    def count(self, name: str, n: int | float = 1, help: str = "",
              **labels) -> None:
        if self.metrics is not None:
            self.metrics.counter(name, help, **labels).inc(n)

    def gauge_set(self, name: str, v: float, help: str = "",
                  **labels) -> None:
        if self.metrics is not None:
            self.metrics.gauge(name, help, **labels).set(v)

    def observe(self, name: str, v: float, help: str = "",
                bins: tuple[float, ...] = DEFAULT_SECONDS_BINS,
                **labels) -> None:
        if self.metrics is not None:
            self.metrics.histogram(name, help, bins, **labels).observe(v)

    # -- export ------------------------------------------------------------
    def events(self) -> list[dict]:
        """Snapshot of the recorded trace events (copy, thread-safe)."""
        with self._lock:
            return list(self._events)

    def chrome_trace(self) -> dict:
        """Chrome/Perfetto trace-event JSON (the ``traceEvents`` form).

        Load at https://ui.perfetto.dev or chrome://tracing.  ``ts`` and
        ``dur`` are microseconds on the shared monotonic clock; the only
        wall-clock field is the human-readable ``trace_start_iso``.
        """
        with self._lock:
            events = list(self._events)
        return {
            "traceEvents": [
                {"name": "process_name", "ph": "M", "pid": 1,
                 "args": {"name": "repro.serve"}}] + events,
            "displayTimeUnit": "ms",
            "otherData": {"trace_start_iso": self.trace_start_iso},
        }

    def metrics_snapshot(self) -> dict:
        return {} if self.metrics is None else self.metrics.snapshot()

    def prometheus(self) -> str:
        return "" if self.metrics is None else self.metrics.prometheus()

    def write_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    def write_metrics(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.metrics_snapshot(), f, indent=2)


class NullTelemetry(Telemetry):
    """The default recorder: every operation is a no-op.

    Hot paths additionally guard on ``telemetry.enabled`` so the
    disabled engine never even builds event-args dicts — the overhead
    CI gates is the cost of *this* class, i.e. nothing.
    """

    enabled = False

    def __init__(self):  # no registry, no event buffer, no lock
        self.metrics = None
        self._trace = False

    def track(self, name: str) -> int:
        return 0

    def complete(self, *a, **k) -> None:
        pass

    def instant(self, *a, **k) -> None:
        pass

    def sample(self, *a, **k) -> None:
        pass

    def count(self, *a, **k) -> None:
        pass

    def gauge_set(self, *a, **k) -> None:
        pass

    def observe(self, *a, **k) -> None:
        pass

    def events(self) -> list[dict]:
        return []

    def chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms", "otherData": {}}

    def metrics_snapshot(self) -> dict:
        return {}

    def prometheus(self) -> str:
        return ""


#: Shared no-op recorder — the engine default.  Stateless, so one
#: instance serves every engine in the process.
NULL = NullTelemetry()


# -- trace post-processing -------------------------------------------------
_PHASES = ("wait", "plan", "service")


def lifecycle_breakdown(events: Iterable[dict]) -> dict:
    """Attribute per-query end-to-end latency to lifecycle phases.

    Scans a trace (``Telemetry.events()`` or a loaded ``traceEvents``
    list) for the per-query ``wait`` / ``plan`` / ``service`` spans the
    engine emits and returns, per phase, total seconds plus p50/p99
    milliseconds across queries — the component view ``bench_serve``'s
    stream report uses instead of opaque end-to-end numbers.  The
    ``query`` umbrella spans are returned too so callers can verify the
    phases tile the lifecycle (they sum to the umbrella by
    construction; see docs/observability.md).
    """
    per_phase: dict[str, list[float]] = {p: [] for p in _PHASES}
    totals: list[float] = []
    for ev in events:
        if ev.get("ph") != "X":
            continue
        dur_s = ev.get("dur", 0.0) / 1e6
        if ev.get("name") in per_phase:
            per_phase[ev["name"]].append(dur_s)
        elif ev.get("name") == "query":
            totals.append(dur_s)

    def pct(xs: list[float], q: float) -> float:
        if not xs:
            return 0.0
        xs = sorted(xs)
        i = min(int(q * len(xs)), len(xs) - 1)
        return xs[i]

    out: dict = {"n_queries": len(totals),
                 "e2e_total_s": float(sum(totals)),
                 "e2e_p50_ms": pct(totals, 0.50) * 1e3,
                 "e2e_p99_ms": pct(totals, 0.99) * 1e3}
    for p, xs in per_phase.items():
        out[p] = {"total_s": float(sum(xs)),
                  "p50_ms": pct(xs, 0.50) * 1e3,
                  "p99_ms": pct(xs, 0.99) * 1e3}
    return out
