"""Posterior query service driver — synthetic traffic or a request file.

  PYTHONPATH=src python -m repro.serve.cli --network asia --queries 64
  PYTHONPATH=src python -m repro.serve.cli --network sprinkler --queries 32 \
      --patterns 2 --chains 16
  PYTHONPATH=src python -m repro.serve.cli --requests reqs.json
  # shard query groups over 4 devices (forced-host CPU recipe)
  PYTHONPATH=src python -m repro.serve.cli --network asia \
      --force-host-devices 4 --mesh-shape 4

Request-file format: a JSON list of objects
  {"network": "asia", "evidence": {"smoke": 1}, "query_vars": ["lung"],
   "n_samples": 8192}

Reports queries/s and MSample/s for a cold pass (empty plan cache, XLA
compiles on the critical path) and a warm pass (same traffic replayed
through the populated cache) — the speedup is the point of the plan
cache.

``--mesh-shape N`` (or RxC) builds a serve mesh and shards each query
group's chain-lane axis over its "batch" axis; ``--force-host-devices``
splits the CPU into fake devices (set before first jax use, so it works
from this CLI without exporting XLA_FLAGS).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

# NOTE: jax-touching imports (engine, networks) happen lazily inside the
# functions below — importing the sampling stack initializes the XLA
# backend, which must not happen before --force-host-devices takes effect.
from repro.serve.query import Query

NETWORKS = ("asia", "sprinkler", "child_scale", "alarm_scale",
            "hailfinder_scale")


def build_registry(names=NETWORKS):
    from repro.pgm import networks as _networks
    return {name: getattr(_networks, name)() for name in names}


def synthetic_traffic(
    bn, network: str, n_queries: int, n_patterns: int, rng: np.random.Generator,
    n_samples: int,
) -> list[Query]:
    """Zipf-free but repetitive traffic: queries cycle through a small set
    of evidence patterns (as real sensor traffic does) with fresh observed
    values and query variables each time."""
    n = bn.n_nodes
    max_obs = max(1, min(2, n - 2))
    patterns = []
    for _ in range(n_patterns):
        size = int(rng.integers(1, max_obs + 1))
        patterns.append(tuple(sorted(
            rng.choice(n, size=size, replace=False).tolist())))
    out = []
    for i in range(n_queries):
        pat = patterns[i % len(patterns)]
        evidence = {int(v): int(rng.integers(bn.card[v])) for v in pat}
        free = [v for v in range(n) if v not in evidence]
        n_q = int(rng.integers(1, min(3, len(free)) + 1))
        qvars = tuple(int(v) for v in rng.choice(free, n_q, replace=False))
        out.append(Query(network, evidence, qvars, n_samples=n_samples))
    return out


def load_requests(path: str) -> list[Query]:
    with open(path) as f:
        reqs = json.load(f)
    return [
        Query(r["network"], r.get("evidence", {}),
              tuple(r.get("query_vars", ())),
              n_samples=int(r.get("n_samples", 8192)))
        for r in reqs
    ]


def _pass(engine, traffic: list[Query], label: str):
    t0 = time.perf_counter()
    results = engine.answer_batch(traffic)
    dt = time.perf_counter() - t0
    samples = sum(r.n_node_samples for r in results)
    bits = np.mean([r.bits_per_sample for r in results]) if results else 0.0
    conv = sum(r.converged for r in results)
    print(f"{label}: {len(traffic)} queries in {dt:.2f}s -> "
          f"{len(traffic)/dt:.1f} queries/s, "
          f"{samples/dt/1e6:.2f} MSample/s, "
          f"{bits:.2f} bits/sample, converged {conv}/{len(traffic)}")
    return dt, results


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--network", default="asia", choices=NETWORKS)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--patterns", type=int, default=4,
                    help="distinct evidence patterns in synthetic traffic")
    ap.add_argument("--requests", default="",
                    help="JSON request file (overrides synthetic traffic)")
    ap.add_argument("--chains", type=int, default=32)
    ap.add_argument("--budget", type=int, default=4096,
                    help="sample budget per query")
    ap.add_argument("--burn-in", type=int, default=64)
    ap.add_argument("--rhat", type=float, default=1.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-iu", action="store_true")
    ap.add_argument("--mesh-shape", default="",
                    help="serve mesh, e.g. 4 or 2x2 — shard chain lanes "
                         "over devices")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="split the CPU into N fake devices "
                         "(XLA_FLAGS recipe, applied before first jax use)")
    ap.add_argument("--show", type=int, default=3,
                    help="print marginals of the first N queries")
    args = ap.parse_args(argv)

    if args.force_host_devices:
        from repro.launch.mesh import force_host_devices
        force_host_devices(args.force_host_devices)
    from repro.serve.engine import PosteriorEngine

    mesh = None
    if args.mesh_shape:
        import jax

        from repro.launch.mesh import make_serve_mesh, parse_mesh_shape
        mesh = make_serve_mesh(parse_mesh_shape(args.mesh_shape))
        print(f"serve mesh {dict(mesh.shape)} over "
              f"{mesh.devices.size}/{len(jax.devices())} devices")

    registry = build_registry()
    engine = PosteriorEngine(
        registry, chains_per_query=args.chains, burn_in=args.burn_in,
        rhat_target=args.rhat, use_iu=not args.no_iu, mesh=mesh,
        seed=args.seed)

    if args.requests:
        traffic = load_requests(args.requests)
        print(f"loaded {len(traffic)} requests from {args.requests}")
    else:
        rng = np.random.default_rng(args.seed)
        bn = registry[args.network]
        traffic = synthetic_traffic(
            bn, args.network, args.queries, args.patterns, rng, args.budget)
        print(f"network={args.network}: {bn.n_nodes} nodes, "
              f"{args.queries} queries over {args.patterns} evidence patterns")

    cold_dt, _ = _pass(engine, traffic, "cold")
    warm_dt, results = _pass(engine, traffic, "warm")
    s = engine.cache.stats
    print(f"warm/cold speedup: {cold_dt/warm_dt:.1f}x   "
          f"plan cache: {s.hits} hits / {s.misses} misses "
          f"(hit rate {s.hit_rate:.0%}, {len(engine.cache)} plans)")

    for r in results[:args.show]:
        bn = registry[r.query.network]
        ev = {bn.names[bn.index(k)]: v for k, v in r.query.evidence.items()}
        print(f"  {r.query.network} | evidence {ev}: "
              f"rhat={r.rhat:.3f} kept={r.n_samples}")
        for var, m in r.marginals.items():
            print(f"    P({var} | e) = {np.round(m, 3)}")


if __name__ == "__main__":
    main()
