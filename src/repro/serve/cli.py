"""Posterior query service driver — synthetic traffic or a request file.

  PYTHONPATH=src python -m repro.serve.cli --network asia --queries 64
  PYTHONPATH=src python -m repro.serve.cli --network sprinkler --queries 32 \
      --patterns 2 --chains 16
  PYTHONPATH=src python -m repro.serve.cli --requests reqs.json
  # streaming: replay timestamped traffic through the admission queue
  PYTHONPATH=src python -m repro.serve.cli --network asia --stream \
      --rate 50 --max-wait-ms 20
  # masked-MRF serving: scribble-mask evidence over a Potts grid
  PYTHONPATH=src python -m repro.serve.cli --network mrf_penguin \
      --mrf-shape 24x24 --queries 16
  # persist compiled plans so warm process starts skip the compiler chain
  PYTHONPATH=src python -m repro.serve.cli --network asia \
      --plan-cache-dir /tmp/aia-plans
  # shard query groups over 4 devices (forced-host CPU recipe)
  PYTHONPATH=src python -m repro.serve.cli --network asia \
      --force-host-devices 4 --mesh-shape 4
  # run as a service: HTTP/WebSocket front end over a worker pool
  PYTHONPATH=src python -m repro.serve.cli --serve :8080 --workers 2 \
      --scheduler deadline --quota-qps 50 --plan-cache-dir /tmp/aia-plans
  # ...and drive it from another process (client mode, jax-free)
  PYTHONPATH=src python -m repro.serve.cli --connect :8080 --stream \
      --network asia --queries 32

Request-file format: a JSON list of objects, schema-versioned by an
optional ``"v"`` field (1 = the historical marginals-only schema, the
default; 2 adds ``"mode"`` and ``"stream_id"``):
  {"v": 2, "network": "asia", "evidence": {"smoke": 1},
   "query_vars": ["lung"], "mode": "map", "n_samples": 8192, "t": 0.125}
MRF requests use the sparse pixel-mask form instead of ``evidence``:
  {"network": "mrf_penguin", "mask_sites": [[2, 3, 1], [4, 0, 0]],
   "query_sites": [[0, 0], [5, 5]], "n_samples": 4096}
Sparse-Ising requests use a spin clamp mask (``(site, ±1-spin)`` pairs):
  {"network": "ising_torus", "clamp_sites": [[0, 1], [37, -1]],
   "query_vars": [5, 6], "n_samples": 4096}
(``mask_sites`` are (row, col, observed-label) triples; ``t`` — the
arrival timestamp in seconds, optional — is only used by ``--stream``,
which replays the file open-loop at those offsets.)  Any form may
carry per-query retirement overrides ``"rhat_target"`` /
``"ess_target"`` — see docs/serving.md for the full schema table.

Batch mode reports queries/s and MSample/s for a cold pass (empty plan
cache, XLA compiles on the critical path) and a warm pass (same traffic
replayed through the populated cache) — the speedup is the point of the
plan cache.  Stream mode replays the same traffic open-loop through
:class:`repro.serve.queue.AdmissionQueue` and reports p50/p99 latency
and queries/s against a one-query-at-a-time synchronous baseline — the
speedup there is the point of admission-queue micro-batching.

``--mesh-shape N`` (or RxC) builds a serve mesh and shards each query
group's chain-lane axis over its "batch" axis; ``--force-host-devices``
splits the CPU into fake devices (set before first jax use, so it works
from this CLI without exporting XLA_FLAGS).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.serve.telemetry import Telemetry, lifecycle_breakdown, monotonic

# NOTE: jax-touching imports (engine, queue) happen lazily inside the
# functions below — importing the sampling stack initializes the XLA
# backend, which must not happen before --force-host-devices takes
# effect.  repro.pgm.graph / networks are jax-free and safe to import.
from repro.serve.query import MODES, IsingQuery, MrfQuery, Query

# JSON request-file schema versions this CLI can parse (see
# docs/serving.md): 1 = the historical marginals-only form, 2 adds
# "mode" and "stream_id"
SCHEMA_VERSIONS = (1, 2)

NETWORKS = ("asia", "sprinkler", "child_scale", "alarm_scale",
            "hailfinder_scale")
# Served MRF models (pixel-mask evidence); built at --mrf-shape size.
MRF_NETWORKS = ("mrf_penguin",)
# Served sparse-Ising models (spin clamp-mask evidence); --ising-side.
ISING_NETWORKS = ("ising_torus",)


def build_registry(names=NETWORKS + MRF_NETWORKS + ISING_NETWORKS, *,
                   mrf_shape=(24, 24), ising_side=16):
    from repro.pgm import networks as _networks
    reg = {}
    for name in names:
        if name == "mrf_penguin":
            reg[name] = _networks.penguin_task(*mrf_shape)[0]
        elif name == "ising_torus":
            # subcritical β: fast mixing, still strongly coupled
            reg[name] = _networks.ising_torus(ising_side, beta=0.35)
        else:
            reg[name] = getattr(_networks, name)()
    return reg


def synthetic_traffic(
    bn, network: str, n_queries: int, n_patterns: int, rng: np.random.Generator,
    n_samples: int,
) -> list[Query]:
    """Zipf-free but repetitive traffic: queries cycle through a small set
    of evidence patterns (as real sensor traffic does) with fresh observed
    values and query variables each time."""
    n = bn.n_nodes
    max_obs = max(1, min(2, n - 2))
    patterns = []
    for _ in range(n_patterns):
        size = int(rng.integers(1, max_obs + 1))
        patterns.append(tuple(sorted(
            rng.choice(n, size=size, replace=False).tolist())))
    out = []
    for i in range(n_queries):
        pat = patterns[i % len(patterns)]
        evidence = {int(v): int(rng.integers(bn.card[v])) for v in pat}
        free = [v for v in range(n) if v not in evidence]
        n_q = int(rng.integers(1, min(3, len(free)) + 1))
        qvars = tuple(int(v) for v in rng.choice(free, n_q, replace=False))
        out.append(Query(network, evidence, qvars, n_samples=n_samples))
    return out


def synthetic_stream_traffic(
    bn, network: str, n_streams: int, n_slices: int,
    rng: np.random.Generator, n_samples: int, drift: float = 0.25,
) -> list[Query]:
    """Streaming-sensor traffic for temporal (dynamic-BN) filtering:
    ``n_streams`` independent sensors each own a fixed evidence pattern
    and query set, re-observed ``n_slices`` times; per slice each
    observed value re-randomizes with probability ``drift`` (slow
    drift), so consecutive slices are *nearby* evidence sets — the
    regime where warm-starting slice ``t+1`` from slice ``t``'s
    retained chains pays.  Slices are emitted slice-major (slice 0 of
    every stream, then slice 1, …) and each carries its sensor's
    ``stream_id``; one pattern per stream means every slice after the
    first is a plan-cache hit by construction."""
    n = bn.n_nodes
    streams = []
    for _ in range(n_streams):
        size = int(rng.integers(1, max(1, min(2, n - 2)) + 1))
        pat = tuple(sorted(rng.choice(n, size=size, replace=False).tolist()))
        vals = {int(v): int(rng.integers(bn.card[v])) for v in pat}
        free = [v for v in range(n) if v not in pat]
        n_q = int(rng.integers(1, min(3, len(free)) + 1))
        qvars = tuple(int(v) for v in rng.choice(free, n_q, replace=False))
        streams.append((pat, vals, qvars))
    out = []
    for t in range(n_slices):
        for i, (pat, vals, qvars) in enumerate(streams):
            if t:
                for v in pat:
                    if rng.random() < drift:
                        vals[v] = int(rng.integers(bn.card[v]))
            out.append(Query(network, dict(vals), qvars,
                             n_samples=n_samples, stream_id=f"sensor{i}"))
    return out


def scribble_mask(h: int, w: int, rng: np.random.Generator,
                  n_strokes: int = 3) -> np.ndarray:
    """A synthetic interactive-segmentation scribble: a few straight
    strokes of clamped pixels on an (h, w) canvas."""
    mask = np.zeros((h, w), bool)
    for _ in range(n_strokes):
        r, c = int(rng.integers(h)), int(rng.integers(w))
        length = int(rng.integers(2, max(3, min(h, w) // 2) + 1))
        if rng.integers(2):  # horizontal stroke
            mask[r, c:min(c + length, w)] = True
        else:
            mask[r:min(r + length, h), c] = True
    return mask


def synthetic_mrf_traffic(
    mrf, network: str, n_queries: int, n_patterns: int,
    rng: np.random.Generator, n_samples: int,
) -> list[MrfQuery]:
    """Scribble-mask traffic: queries cycle a small set of mask
    *patterns* (interactive segmentation re-sends the same strokes while
    the user iterates) with fresh observed labels and query sites each
    time — the MRF mirror of :func:`synthetic_traffic`."""
    h, w = mrf.shape
    masks = [scribble_mask(h, w, rng) for _ in range(n_patterns)]
    out = []
    for i in range(n_queries):
        mask = masks[i % len(masks)]
        values = rng.integers(0, mrf.n_labels, (h, w))
        free_r, free_c = np.nonzero(~mask)
        n_q = int(rng.integers(1, 4))
        pick = rng.choice(len(free_r), size=min(n_q, len(free_r)),
                         replace=False)
        sites = tuple((int(free_r[p]), int(free_c[p])) for p in pick)
        out.append(MrfQuery(network, mask, values, query_sites=sites,
                            n_samples=n_samples))
    return out


def synthetic_ising_traffic(
    model, network: str, n_queries: int, n_patterns: int,
    rng: np.random.Generator, n_samples: int,
) -> list[IsingQuery]:
    """Spin clamp-mask traffic: queries cycle a small set of clamp
    *patterns* (the same boundary spins get pinned while the free bulk
    is queried) with fresh ±1 values and query spins each time — the
    sparse-graph mirror of :func:`synthetic_traffic`."""
    n = model.n_vars
    max_clamp = max(1, min(4, n - 2))
    patterns = []
    for _ in range(n_patterns):
        size = int(rng.integers(1, max_clamp + 1))
        patterns.append(tuple(sorted(
            rng.choice(n, size=size, replace=False).tolist())))
    out = []
    for i in range(n_queries):
        pat = patterns[i % len(patterns)]
        clamp = tuple((int(v), int(rng.choice((-1, 1)))) for v in pat)
        free = [v for v in range(n) if v not in pat]
        n_q = int(rng.integers(1, min(3, len(free)) + 1))
        qvars = tuple(int(v) for v in rng.choice(free, n_q, replace=False))
        out.append(IsingQuery(network, clamp_sites=clamp, query_vars=qvars,
                              n_samples=n_samples))
    return out


def load_requests(path: str) -> tuple[list[Query], list[float] | None]:
    """Parse a JSON request file; arrival timestamps (``"t"``) come back
    as a second list when every request carries one, else None."""
    with open(path) as f:
        reqs = json.load(f)

    def parse(r):
        v = int(r.get("v", 1))
        if v not in SCHEMA_VERSIONS:
            raise ValueError(
                f"unknown request schema version {v} (accepted: "
                f"{', '.join(str(s) for s in SCHEMA_VERSIONS)})")
        if v < 2:
            # v1 predates inference modes: auto-upgrade to marginals,
            # and refuse v2-only fields rather than silently ignore them
            for field in ("mode", "stream_id"):
                if field in r:
                    raise ValueError(
                        f"{field!r} requires schema version 2 "
                        f'(add "v": 2 to the request)')
            mode, stream_id = "marginals", None
        else:
            mode = str(r.get("mode", "marginals"))
            if mode not in MODES:
                raise ValueError(
                    f"unknown inference mode {mode!r} "
                    f"(accepted: {', '.join(MODES)})")
            stream_id = (None if r.get("stream_id") is None
                         else str(r["stream_id"]))
        # per-query retirement overrides (None = engine defaults)
        common = dict(
            n_samples=int(r.get("n_samples", 8192)),
            mode=mode, stream_id=stream_id,
            rhat_target=(None if r.get("rhat_target") is None
                         else float(r["rhat_target"])),
            ess_target=(None if r.get("ess_target") is None
                        else float(r["ess_target"])))
        if "mask_sites" in r:  # MRF pixel-mask request (sparse form)
            return MrfQuery(
                r["network"],
                mask_sites=tuple(tuple(int(x) for x in t)
                                 for t in r["mask_sites"]),
                query_sites=tuple(tuple(int(x) for x in t)
                                  for t in r.get("query_sites", ())),
                **common)
        if "clamp_sites" in r:  # sparse-Ising spin clamp request
            return IsingQuery(
                r["network"],
                clamp_sites=tuple(tuple(int(x) for x in t)
                                  for t in r["clamp_sites"]),
                query_vars=tuple(r.get("query_vars", ())),
                **common)
        return Query(r["network"], r.get("evidence", {}),
                     tuple(r.get("query_vars", ())), **common)

    queries = [parse(r) for r in reqs]
    arrivals = None
    n_stamped = sum("t" in r for r in reqs)
    if reqs and n_stamped == len(reqs):
        arrivals = [float(r["t"]) for r in reqs]
    elif n_stamped:
        raise ValueError(
            f"request file is partially timestamped ({n_stamped}/{len(reqs)} "
            f"entries carry 't') — give every request a timestamp or none")
    return queries, arrivals


def measure_stream(engine, sync_engine, traffic: list[Query],
                   arrivals: list[float] | None = None, *,
                   rate_qps: float = 0.0, rate_multiplier: float = 4.0,
                   max_wait_ms: float = 20.0, timeout: float = 600.0):
    """The streaming measurement protocol, shared by the CLI and
    ``benchmarks.bench_serve`` so the two entry points can never drift:

    1. warm both plan caches off the clock (the sync engine at its only
       lane shape, the queued engine over the pow2 group-shape ladder),
    2. time one-query-at-a-time synchronous serving of ``traffic``,
    3. replay the same traffic open-loop through an admission queue at
       ``rate_qps`` (or ``rate_multiplier`` x the measured sync rate,
       keeping the load regime machine-relative), at the given
       ``arrivals`` offsets when the traffic is timestamped.

    Returns ``(metrics, results)``: a JSON-able metrics dict (rates,
    p50/p99 ms, speedup, queue stats) and the per-query results in
    submission order.
    """
    from repro.serve.queue import AdmissionQueue

    import dataclasses

    queue = AdmissionQueue(engine, max_wait_ms=max_wait_ms)
    seen: dict[tuple, Query] = {}
    for q in traffic:
        _, _, _, pattern = engine.normalize(q)
        # streamless probe: warm-up must not retain chains that would
        # warm-start the measured replay's first slices
        seen.setdefault((q.network, pattern,
                         getattr(q, "mode", "marginals")),
                        dataclasses.replace(q, stream_id=None))
    sync_engine.answer_batch(list(seen.values()))
    queue.warm(traffic)

    t0 = monotonic()
    for q in traffic:
        sync_engine.answer(q)
    sync_qps = len(traffic) / (monotonic() - t0)

    if arrivals is None:
        rate = rate_qps if rate_qps > 0 else rate_multiplier * sync_qps
        arrivals = [i / rate for i in range(len(traffic))]
    else:
        rate = len(traffic) / max(arrivals[-1], 1e-9)
    # events recorded so far belong to the off-the-clock warm-up; the
    # latency breakdown must only see the measured replay's spans
    ev0 = len(engine.telemetry.events()) if engine.telemetry.enabled else 0
    try:
        results, lat, wall = replay_stream(
            queue, traffic, arrivals, timeout=timeout)
    finally:
        queue.close()
    qps = len(traffic) / wall
    p50, p99 = np.percentile(np.asarray(lat) * 1e3, [50, 99])
    st = queue.stats
    metrics = {
        "n_queries": len(traffic),
        "rate_qps": rate,
        "sync_queries_per_s": sync_qps,
        "queries_per_s": qps,
        "speedup": qps / sync_qps,
        "p50_ms": float(p50),
        "p99_ms": float(p99),
        "converged": int(sum(r.converged for r in results)),
        # raw and effective throughput side by side: MSample/s is the
        # paper's headline unit, ESS/s the honest mixing-adjusted one
        "msample_per_s": sum(r.n_node_samples for r in results) / wall / 1e6,
        "ess_per_s": ess_total(results) / wall,
        "dispatched_groups": st.dispatched_groups,
        "backfilled": st.backfilled,
        "submitted": st.submitted,
        # temporal filtering: slices whose lanes were seeded from their
        # stream's previous slice (0 for streamless traffic)
        "warm_started": int(sum(r.warm_start for r in results)),
    }
    # with a live recorder the end-to-end latency decomposes into its
    # lifecycle phases (wait / plan / service) straight from the spans
    if engine.telemetry.enabled:
        metrics["latency_breakdown"] = lifecycle_breakdown(
            engine.telemetry.events()[ev0:])
    return metrics, results


def replay_stream(queue, traffic: list[Query], arrivals: list[float],
                  *, timeout: float = 600.0):
    """Open-loop replay: submit each query at its arrival offset
    (seconds from the replay start), regardless of completions — the
    arrival process never waits for the server, which is what makes the
    measured latency an honest open-loop number.

    Returns ``(results, latencies_s, wall_s)``: per-query results in
    submission order, per-query latency (completion − *scheduled*
    arrival), and the wall clock from start to last completion.
    """
    t0 = monotonic()
    handles = []
    for q, t_arr in zip(traffic, arrivals):
        lag = t_arr - (monotonic() - t0)
        if lag > 0:
            time.sleep(lag)
        handles.append(queue.submit(q))
    results = [h.result(timeout=timeout) for h in handles]
    lat = [(h.t_done - t0) - t_arr for h, t_arr in zip(handles, arrivals)]
    wall = max(h.t_done for h in handles) - t0
    return results, lat, wall


def ess_total(results) -> float:
    """Sum of per-query worst-case ESS (min of bulk and tail over the
    query variables) — divided by wall time this is ESS/s, the honest
    throughput analogue of the paper's MSample/s: raw-sample rates
    reward slow mixing, effective-sample rates don't."""
    return float(sum(
        r.diagnostics.min_ess for r in results if r.diagnostics is not None))


def _pass(engine, traffic: list[Query], label: str):
    t0 = monotonic()
    results = engine.answer_batch(traffic)
    dt = monotonic() - t0
    samples = sum(r.n_node_samples for r in results)
    bits = np.mean([r.bits_per_sample for r in results]) if results else 0.0
    conv = sum(r.converged for r in results)
    print(f"{label}: {len(traffic)} queries in {dt:.2f}s -> "
          f"{len(traffic)/dt:.1f} queries/s, "
          f"{samples/dt/1e6:.2f} MSample/s, "
          f"{ess_total(results)/dt:.0f} ESS/s, "
          f"{bits:.2f} bits/sample, converged {conv}/{len(traffic)}")
    return dt, results


def _run_batch(args, engine, registry, traffic):
    cold_dt, _ = _pass(engine, traffic, "cold")
    warm_dt, results = _pass(engine, traffic, "warm")
    s = engine.cache.stats
    print(f"warm/cold speedup: {cold_dt/warm_dt:.1f}x   "
          f"plan cache: {s.hits} hits / {s.misses} misses "
          f"(hit rate {s.hit_rate:.0%}, {len(engine.cache)} plans)")

    for r in results[:args.show]:
        if isinstance(r.query, Query):
            bn = registry[r.query.network]
            ev = {bn.names[bn.index(k)]: v
                  for k, v in r.query.evidence.items()}
        elif isinstance(r.query, IsingQuery):  # spin clamp mask
            n_sp = len(r.query.clamp_sites or ())
            ev = f"{n_sp} clamped spins" if n_sp else "no clamps"
        else:  # MRF: report the scribble size, not a node dict
            n_px = len(r.query.mask_sites or ())
            if r.query.mask is not None:
                n_px += int(np.asarray(r.query.mask).sum())
            ev = f"{n_px} clamped px" if n_px else "no mask"
        d = r.diagnostics
        print(f"  {r.query.network} | evidence {ev}: "
              f"rhat={r.rhat:.3f} rank_rhat={d.worst_rank_rhat:.3f} "
              f"ess={d.min_ess:.0f} sweeps={d.sweeps_used} "
              f"kept={r.n_samples}")
        if r.map_assignment is not None:
            shown = dict(list(r.map_assignment.items())[:6])
            print(f"    MAP {shown} (energy {r.map_energy:.3f} nats)")
        for var, m in list(r.marginals.items())[:6]:
            print(f"    P({var} | e) = {np.round(m, 3)}")


def _run_stream(args, engine, sync_engine, traffic, arrivals):
    m, _ = measure_stream(
        engine, sync_engine, traffic, arrivals,
        rate_qps=args.rate, max_wait_ms=args.max_wait_ms)
    print(f"stream: {m['n_queries']} queries arriving at "
          f"{m['rate_qps']:.1f}/s -> {m['queries_per_s']:.1f} queries/s, "
          f"{m['ess_per_s']:.0f} ESS/s, "
          f"p50 {m['p50_ms']:.0f} ms, p99 {m['p99_ms']:.0f} ms, "
          f"converged {m['converged']}/{m['n_queries']}")
    print(f"  sync one-at-a-time baseline: "
          f"{m['sync_queries_per_s']:.1f} queries/s "
          f"-> queued speedup {m['speedup']:.2f}x")
    print(f"  {m['dispatched_groups']} groups "
          f"(avg {m['submitted']/max(m['dispatched_groups'],1):.1f} "
          f"queries), {m['backfilled']} backfilled into freed lanes")
    if m["warm_started"]:
        print(f"  temporal filtering: {m['warm_started']}/{m['n_queries']} "
              f"slices warm-started from retained stream chains")
    bd = m.get("latency_breakdown")
    if bd:
        parts = " + ".join(
            f"{bd[k]['p50_ms']:.0f} {k}" for k in ("wait", "plan", "service")
            if k in bd)
        print(f"  latency breakdown (p50 ms): {parts} "
              f"vs {bd['e2e_p50_ms']:.0f} e2e")


def _parse_addr(spec: str, *, default_host: str = "127.0.0.1"):
    """``[HOST:]PORT`` -> ``(host, port)`` (``":8080"`` binds default)."""
    host, _, port = spec.rpartition(":")
    try:
        return (host or default_host), int(port)
    except ValueError:
        raise SystemExit(
            f"bad address {spec!r}: expected [HOST:]PORT") from None


def _parse_mrf_shape(args) -> tuple[int, int]:
    try:
        mrf_shape = tuple(int(s) for s in args.mrf_shape.lower().split("x"))
    except ValueError:
        mrf_shape = ()
    if len(mrf_shape) != 2 or any(s < 2 for s in mrf_shape):
        raise SystemExit(f"bad --mrf-shape {args.mrf_shape!r}: expected HxW")
    return mrf_shape


def _engine_kwargs(args, mesh=None) -> dict:
    return dict(
        chains_per_query=args.chains, burn_in=args.burn_in,
        rhat_target=args.rhat, ess_target=args.ess_target,
        retirement=args.retirement, use_iu=not args.no_iu, mesh=mesh,
        plan_cache_dir=args.plan_cache_dir or None, seed=args.seed)


def build_traffic(args, registry):
    """The CLI's traffic source: a request file or synthetic queries
    against ``registry`` — returns ``(queries, arrivals-or-None)``.
    jax-free, so client mode (``--connect``) can build the same traffic
    without initializing an engine."""
    arrivals = None
    if args.requests:
        traffic, arrivals = load_requests(args.requests)
        print(f"loaded {len(traffic)} requests from {args.requests}"
              + (" (timestamped)" if arrivals else ""))
    else:
        from repro.pgm.graph import FactorGraph, IsingModel, MRFGrid

        rng = np.random.default_rng(args.seed)
        model = registry[args.network]
        if isinstance(model, MRFGrid):
            traffic = synthetic_mrf_traffic(
                model, args.network, args.queries, args.patterns, rng,
                args.budget)
            h, w = model.shape
            print(f"network={args.network}: {h}x{w} grid "
                  f"(L={model.n_labels}), {args.queries} queries over "
                  f"{args.patterns} scribble-mask patterns")
        elif isinstance(model, (IsingModel, FactorGraph)):
            traffic = synthetic_ising_traffic(
                model, args.network, args.queries, args.patterns, rng,
                args.budget)
            print(f"network={args.network}: {model.n_vars} spins, "
                  f"{len(model.edges)} couplings, {args.queries} queries "
                  f"over {args.patterns} clamp patterns")
        elif args.stream:
            # the streaming-sensor scenario: each pattern is a sensor
            # re-observed over drifting time slices (temporal filtering)
            n_slices = args.slices or max(
                2, args.queries // max(args.patterns, 1))
            traffic = synthetic_stream_traffic(
                model, args.network, args.patterns, n_slices, rng,
                args.budget)
            print(f"network={args.network}: {model.n_nodes} nodes, "
                  f"{args.patterns} sensor streams x {n_slices} time "
                  f"slices ({len(traffic)} queries)")
        else:
            traffic = synthetic_traffic(
                model, args.network, args.queries, args.patterns, rng,
                args.budget)
            print(f"network={args.network}: {model.n_nodes} nodes, "
                  f"{args.queries} queries over {args.patterns} "
                  f"evidence patterns")

    if args.mode != "marginals":
        import dataclasses
        traffic = [dataclasses.replace(q, mode=args.mode) for q in traffic]
    return traffic, arrivals


def _run_serve(args, registry, engine_kw) -> None:
    """``--serve``: run the HTTP/WebSocket front end on this thread's
    event loop until interrupted.  One engine per worker; all workers
    share the persisted plan-cache dir (compiles are written atomically,
    so whoever compiles first persists for everyone)."""
    import asyncio

    from repro.serve.engine import PosteriorEngine
    from repro.serve.server import ServeFrontEnd
    from repro.serve.worker import WorkerPool

    host, port = _parse_addr(args.serve)
    want_tel = bool(args.trace_out or args.metrics_json)

    def factory(name: str) -> PosteriorEngine:
        # one recorder per worker (Telemetry tracks are engine-local)
        return PosteriorEngine(
            registry, telemetry=Telemetry() if want_tel else None,
            **engine_kw)

    pool = WorkerPool(
        factory, args.workers,
        queue_kwargs={"max_wait_ms": args.max_wait_ms,
                      "scheduler": args.scheduler})
    fe = ServeFrontEnd(
        pool, host=host, port=port,
        quota_qps=args.quota_qps or None,
        quota_burst=args.quota_burst or None,
        max_pending=args.max_pending)

    async def _serve() -> None:
        await fe.start()
        quota = (f", quota {args.quota_qps:g} qps/tenant"
                 if args.quota_qps else "")
        print(f"serving {len(registry)} networks on http://{host}:{fe.port}"
              f" ({args.workers} workers, {args.scheduler} scheduler"
              f"{quota}, max_pending {args.max_pending}) — Ctrl-C to stop",
              flush=True)
        await fe._stopping.wait()
        await fe.stop()

    try:
        asyncio.run(_serve())
    except KeyboardInterrupt:
        print("interrupt: shutting down")
    finally:
        pool.close(drain=False, timeout=10.0)


def _run_connect(args) -> None:
    """``--connect``: drive a running front end as a client.  jax-free
    unless ``--identity-check`` (which replays the same batch through an
    in-process engine for the bitwise comparison)."""
    from repro.serve.client import ServeClient, ServeHTTPError

    host, port = _parse_addr(args.connect)
    registry = build_registry(mrf_shape=_parse_mrf_shape(args),
                              ising_side=args.ising_side)
    traffic, arrivals = build_traffic(args, registry)
    client = ServeClient(host, port)
    client.wait_ready(timeout=120.0)

    if args.identity_check:
        # bitwise identity needs a *fresh* server (PRNG state advances
        # with traffic) and one routed worker — /v2/batch guarantees the
        # latter; run this before any other traffic.
        served = client.query_batch(traffic)
        if args.force_host_devices:
            from repro.launch.mesh import force_host_devices
            force_host_devices(args.force_host_devices)
        from repro.serve.engine import PosteriorEngine
        from repro.serve.protocol import wire_marginals
        ref = PosteriorEngine(registry, **_engine_kwargs(args)) \
            .answer_batch(traffic)
        total = mismatched = 0
        for wire_r, r in zip(served, ref):
            if "error" in wire_r:
                raise SystemExit(f"server error: {wire_r['error']}")
            if r.map_assignment is not None:
                total += 1
                mismatched += wire_r.get("map_assignment") != r.map_assignment
                continue
            wm = wire_marginals(wire_r)
            for name, arr in r.marginals.items():
                total += 1
                mismatched += not np.array_equal(
                    wm[str(name)], np.asarray(arr, np.float64))
        verdict = ("BITWISE-IDENTICAL to" if not mismatched
                   else f"MISMATCHED ({mismatched}/{total}) vs")
        print(f"identity: {len(served)} served results, {total} marginals "
              f"{verdict} in-process answer_batch (seed {args.seed})")
        if mismatched:
            raise SystemExit(1)
        return

    t0 = monotonic()
    if args.stream:
        responses = client.stream(traffic, arrivals)
    else:
        responses = []
        for q in traffic:
            try:
                responses.append(client.query(q))
            except ServeHTTPError as exc:
                if exc.status not in (429, 503):
                    raise
                responses.append(dict(exc.body, shed=True,
                                      retry_after=exc.retry_after))
    wall = monotonic() - t0
    ok = [r for r in responses if "error" not in r]
    shed = [r for r in responses if r.get("shed")]
    failed = len(responses) - len(ok) - len(shed)
    print(f"client: {len(ok)}/{len(responses)} served in {wall:.1f}s "
          f"({len(ok) / max(wall, 1e-9):.1f} queries/s), "
          f"{len(shed)} shed, {failed} failed")
    stats = client.stats()
    print(f"  server: served_total={stats.get('served')} "
          f"shed={stats.get('shed')} pending={stats.get('pending')}")
    if failed:
        for r in responses:
            if "error" in r and not r.get("shed"):
                print(f"  error: {r['error']}")
        raise SystemExit(1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--network", default="asia",
                    choices=NETWORKS + MRF_NETWORKS + ISING_NETWORKS)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--patterns", type=int, default=4,
                    help="distinct evidence patterns in synthetic traffic "
                         "(scribble-mask patterns for MRF networks)")
    ap.add_argument("--mrf-shape", default="24x24",
                    help="HxW lattice size of the served MRF models")
    ap.add_argument("--ising-side", type=int, default=16,
                    help="side of the served ising_torus lattice "
                         "(side² spins)")
    ap.add_argument("--requests", default="",
                    help="JSON request file (overrides synthetic traffic)")
    ap.add_argument("--mode", default="marginals", choices=MODES,
                    help="inference mode for synthetic traffic: posterior "
                         "marginals (default) or annealed MAP/MPE search")
    ap.add_argument("--slices", type=int, default=0,
                    help="time slices per sensor stream in the --stream "
                         "scenario (0 = queries/patterns); BN traffic "
                         "becomes temporal-filtering slice traffic")
    ap.add_argument("--chains", type=int, default=32)
    ap.add_argument("--budget", type=int, default=4096,
                    help="sample budget per query")
    ap.add_argument("--burn-in", type=int, default=64)
    ap.add_argument("--rhat", type=float, default=1.05)
    ap.add_argument("--ess-target", type=float, default=100.0,
                    help="min effective sample size (bulk and tail) a "
                         "query needs before rank-mode retirement")
    ap.add_argument("--retirement", default="rank",
                    choices=("rank", "legacy"),
                    help="retirement rule: rank-normalized R-hat + ESS "
                         "(default) or the legacy plain split-R-hat")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-iu", action="store_true")
    ap.add_argument("--stream", action="store_true",
                    help="replay traffic open-loop through the admission "
                         "queue; report p50/p99 latency + queries/s vs the "
                         "one-query-at-a-time synchronous baseline")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop arrival rate (queries/s) for --stream; "
                         "0 = 4x the measured synchronous rate")
    ap.add_argument("--serve", default="", metavar="[HOST:]PORT",
                    help="run the HTTP/WebSocket serving front end "
                         "(e.g. ':8080') instead of replaying traffic "
                         "in-process; see docs/serving.md")
    ap.add_argument("--connect", default="", metavar="[HOST:]PORT",
                    help="client mode: send this CLI's traffic to a "
                         "running --serve front end (WebSocket stream "
                         "with --stream, per-query POSTs otherwise)")
    ap.add_argument("--workers", type=int, default=2,
                    help="worker engines behind the --serve front end "
                         "(consistent-hash routed on the plan key)")
    ap.add_argument("--scheduler", default="fifo",
                    choices=("fifo", "deadline"),
                    help="admission-queue scheduler for --serve: fifo or "
                         "earliest-deadline-first with ESS-trajectory "
                         "preemption (see docs/serving.md)")
    ap.add_argument("--quota-qps", type=float, default=0.0,
                    help="per-tenant admission quota for --serve "
                         "(queries/s; 0 = unlimited); over-quota "
                         "requests get 429 + Retry-After")
    ap.add_argument("--quota-burst", type=float, default=0.0,
                    help="token-bucket burst for --quota-qps "
                         "(0 = max(1, qps))")
    ap.add_argument("--max-pending", type=int, default=256,
                    help="backpressure cap on in-flight queries for "
                         "--serve; beyond it requests get 503")
    ap.add_argument("--identity-check", action="store_true",
                    help="client mode: send the traffic as one /v2/batch "
                         "to a FRESH server and verify the served "
                         "marginals are bitwise-identical to an "
                         "in-process answer_batch on the same seed")
    ap.add_argument("--max-wait-ms", type=float, default=20.0,
                    help="admission-queue deadline trigger")
    ap.add_argument("--plan-cache-dir", default="",
                    help="persist compiled plans here (.npz per plan-key); "
                         "warm process starts skip the compiler chain")
    ap.add_argument("--mesh-shape", default="",
                    help="serve mesh, e.g. 4 or 2x2 — shard chain lanes "
                         "over devices")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="split the CPU into N fake devices "
                         "(XLA_FLAGS recipe, applied before first jax use)")
    ap.add_argument("--show", type=int, default=3,
                    help="print marginals of the first N queries")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "run here (enables the telemetry recorder)")
    ap.add_argument("--metrics-json", default="",
                    help="write the engine.stats() snapshot (plan cache, "
                         "queue, metrics registry) here as JSON; also "
                         "enables the telemetry recorder")
    args = ap.parse_args(argv)

    if args.ising_side < 3:
        raise SystemExit(
            f"bad --ising-side {args.ising_side}: the torus needs >= 3")
    if args.serve and args.connect:
        raise SystemExit("--serve and --connect are mutually exclusive")
    if args.connect:
        # client mode never initializes jax (unless --identity-check)
        _run_connect(args)
        return

    if args.force_host_devices:
        from repro.launch.mesh import force_host_devices
        force_host_devices(args.force_host_devices)
    from repro.serve.engine import PosteriorEngine

    mesh = None
    if args.mesh_shape:
        import jax

        from repro.launch.mesh import make_serve_mesh, parse_mesh_shape
        mesh = make_serve_mesh(parse_mesh_shape(args.mesh_shape))
        print(f"serve mesh {dict(mesh.shape)} over "
              f"{mesh.devices.size}/{len(jax.devices())} devices")

    registry = build_registry(mrf_shape=_parse_mrf_shape(args),
                              ising_side=args.ising_side)
    engine_kw = _engine_kwargs(args, mesh=mesh)

    if args.serve:
        _run_serve(args, registry, engine_kw)
        return

    # The recorder goes on the engine under measurement (the queued one
    # in stream mode); the sync baseline engine stays on the shared
    # no-op recorder so its rate is an honest telemetry-free number.
    tel = Telemetry() if (args.trace_out or args.metrics_json) else None
    engine = PosteriorEngine(registry, telemetry=tel, **engine_kw)

    traffic, arrivals = build_traffic(args, registry)

    if args.stream:
        sync_engine = PosteriorEngine(registry, **engine_kw)
        _run_stream(args, engine, sync_engine, traffic, arrivals)
    else:
        _run_batch(args, engine, registry, traffic)

    if args.trace_out:
        engine.telemetry.write_trace(args.trace_out)
        print(f"trace written to {args.trace_out} "
              f"({len(engine.telemetry.events())} events; load at "
              f"https://ui.perfetto.dev)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(engine.stats(), f, indent=2)
        print(f"metrics snapshot written to {args.metrics_json}")


if __name__ == "__main__":
    main()
