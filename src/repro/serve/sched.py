"""SLO scheduling policy: token-bucket quotas + ESS-based predictions.

The scale-out front end (:mod:`repro.serve.server`) admits traffic from
many tenants onto a fixed sampling capacity — the serving analogue of
AIA's RISC-V host deciding which programs reach the 16-core mesh.  This
module holds the *policy* pieces, deliberately free of any engine or
asyncio dependency so they are unit-testable on a fake clock
(``tests/conftest.py``'s ``fake_clock`` fixture drives the
``repro.serve.telemetry.monotonic`` seam):

* :class:`TokenBucket` — the per-tenant admission quota.  Overload is
  *shed* at the front door (HTTP 429 + Retry-After) instead of queueing
  without bound: under 2x-capacity offered load the admitted subset
  keeps a bounded p99 while the excess gets an immediate, honest
  rejection (``benchmarks.bench_serve.run_overload`` measures exactly
  this).
* :func:`predict_remaining_rounds` — how much service a *running* query
  still needs, extrapolated from its ESS trajectory: the incremental
  :class:`repro.pgm.diagnostics.RunningDiagnostics` payloads the
  retirement rule already computes show ESS growing ~linearly in
  rounds for a mixing chain, so ``(ess_target - ess_now) / ess_rate``
  rounds is the natural estimate (capped by the query's budget cap).
* :func:`deadline_order` — earliest-deadline-first sort key used by
  ``AdmissionQueue(scheduler="deadline")`` for dispatch and backfill
  order; deadline-free queries keep FIFO order among themselves behind
  every deadline-carrying one.
"""
from __future__ import annotations

import threading

from repro.serve.telemetry import monotonic

__all__ = ["TokenBucket", "deadline_order", "predict_remaining_rounds"]


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` cap.

    ``try_take()`` returns 0.0 on admission, else the seconds until a
    token will be available (the Retry-After hint).  Thread-safe; time
    comes from the shared serving clock so tests refill it by advancing
    a fake clock instead of sleeping.

    >>> from repro.serve import telemetry
    >>> t = [100.0]; telemetry.set_clock(lambda: t[0])
    >>> b = TokenBucket(rate=2.0, burst=2)
    >>> b.try_take(), b.try_take()          # burst admits two...
    (0.0, 0.0)
    >>> b.try_take() > 0                    # ...then sheds with a hint
    True
    >>> t[0] += 0.5                         # half a second refills one
    >>> b.try_take()
    0.0
    >>> telemetry.set_clock(None)
    """

    def __init__(self, rate: float, burst: float):
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate/burst must be > 0, got ({rate}, {burst})")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._t_last = monotonic()
        self._lock = threading.Lock()

    def _refill_locked(self, now: float) -> None:
        self._tokens = min(
            self.burst, self._tokens + (now - self._t_last) * self.rate)
        self._t_last = now

    def try_take(self, n: float = 1.0) -> float:
        """Take ``n`` tokens if available (returns 0.0), else leave the
        bucket untouched and return the retry-after seconds."""
        with self._lock:
            now = monotonic()
            self._refill_locked(now)
            if self._tokens >= n:
                self._tokens -= n
                return 0.0
            return (n - self._tokens) / self.rate

    def available(self) -> float:
        with self._lock:
            self._refill_locked(monotonic())
            return self._tokens


def predict_remaining_rounds(ess_now: float | None, rounds_done: int,
                             ess_target: float, cap_rounds: int) -> int:
    """Rounds a running query still needs before ESS retirement, from
    its trajectory so far.

    A mixing chain's bulk/tail ESS grows roughly linearly in rounds, so
    the rate observed over ``rounds_done`` rounds extrapolates the rest;
    the estimate is clamped to the query's remaining budget cap, which
    also covers the cases where the trajectory is useless (no ESS yet,
    zero rate, MAP-mode chains that never mix).

    >>> predict_remaining_rounds(50.0, 5, 100.0, 64)   # 10/round -> 5 more
    5
    >>> predict_remaining_rounds(None, 5, 100.0, 8)    # no trajectory yet
    3
    >>> predict_remaining_rounds(400.0, 5, 100.0, 64)  # already past target
    0
    """
    remaining_cap = max(cap_rounds - rounds_done, 0)
    if ess_now is None or rounds_done <= 0 or ess_now <= 0:
        return remaining_cap
    if ess_now >= ess_target:
        return 0
    rate = ess_now / rounds_done
    need = -(-(ess_target - ess_now) // rate)  # ceil division
    return int(min(remaining_cap, max(need, 1)))


def deadline_order(handle, now: float | None = None) -> tuple:
    """Sort key for earliest-deadline-first scheduling over
    :class:`repro.serve.query.QueryHandle`-likes: deadline-carrying
    queries first (by absolute deadline), best-effort ones after (by
    arrival) — so an SLO query never waits behind best-effort work, and
    best-effort work keeps FIFO fairness among itself."""
    d = handle.deadline
    if d is None:
        return (1, handle.t_submit)
    return (0, d)
