"""Request/response types of the posterior query service.

A :class:`Query` is the unit of traffic: "given this network and these
observations, what are the posterior marginals of these variables?"
Nodes may be referred to by name (``"rain"``) or id; the engine
normalizes both.  A :class:`Result` carries the marginals plus the
diagnostics a serving stack needs (convergence, sample counts, cache
behaviour, throughput accounting).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np


def parse_evidence(spec: str) -> dict[str, int]:
    """Parse a CLI evidence string ``"smoke=1,dysp=0"`` into a dict.

    Shared by every driver that accepts ``--evidence`` (run_mcmc, the
    bayesnet example); node-name validation happens later against the
    network via :meth:`BayesNet.normalize_evidence`.
    """
    out: dict[str, int] = {}
    for pair in filter(None, (p.strip() for p in spec.split(","))):
        name, sep, val = pair.partition("=")
        if not sep or not name:
            raise ValueError(
                f"bad evidence {pair!r}: expected name=value")
        try:
            out[name.strip()] = int(val)
        except ValueError:
            raise ValueError(
                f"bad evidence value in {pair!r}: expected an integer") from None
    return out


@dataclass
class Query:
    """One posterior-marginal request.

    ``n_samples`` is the *target* sample budget: roughly how many kept
    (post burn-in, thinned) draws to accumulate for this query across all
    of its chains.  The engine may stop earlier on split-R̂ convergence,
    and may overshoot — rounds are quantized, a micro-batched group runs
    to its largest member's budget, and the engine's ``max_rounds`` caps
    the total.  ``Result.n_samples`` reports what was actually kept.
    ``query_vars`` empty means "all unobserved variables".
    """

    network: str
    evidence: Mapping[str | int, int] = field(default_factory=dict)
    query_vars: Sequence[str | int] = ()
    n_samples: int = 8192


@dataclass
class Result:
    """Answer to one :class:`Query`."""

    query: Query
    marginals: dict[str, np.ndarray]   # node name -> posterior P(v | e)
    n_samples: int                     # kept draws actually accumulated
    n_sweeps: int                      # total sweeps incl. burn-in
    n_node_samples: int                # free-node RV draws spent (throughput)
    rhat: float                        # worst split-R̂ over query vars
    converged: bool
    cache_hit: bool                    # plan served from the cache
    wall_s: float                      # wall time of the micro-batch group
    bits_per_sample: float = 0.0       # random bits per free-node draw

    def marginal(self, var: str) -> np.ndarray:
        try:
            return self.marginals[var]
        except KeyError:
            raise KeyError(
                f"{var!r} was not a query variable of this request "
                f"(have: {sorted(self.marginals)})") from None
