"""Request/response types of the posterior query service.

A :class:`Query` is the unit of traffic: "given this network and these
observations, what are the posterior marginals of these variables?"
Nodes may be referred to by name (``"rain"``) or id; the engine
normalizes both.  A :class:`Result` carries the marginals plus the
diagnostics a serving stack needs (convergence, sample counts, cache
behaviour, throughput accounting).

Streaming submission (:mod:`repro.serve.queue`) wraps each query in a
:class:`QueryHandle` — a future supporting blocking :meth:`QueryHandle.
result`, status inspection, and per-query :meth:`QueryHandle.cancel`
both before dispatch and mid-flight.
"""
from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Mapping, Sequence

import numpy as np

from repro.serve.telemetry import monotonic

if TYPE_CHECKING:  # jax-free import discipline: importing this module
    # must not trigger repro.pgm's package __init__ (and with it the
    # XLA backend) before the CLI's --force-host-devices handling runs
    from repro.pgm.diagnostics import Diagnostics


def parse_evidence(spec: str) -> dict[str, int]:
    """Parse a CLI evidence string ``"smoke=1,dysp=0"`` into a dict.

    Shared by every driver that accepts ``--evidence`` (run_mcmc, the
    bayesnet example); node-name validation happens later against the
    network via :meth:`BayesNet.normalize_evidence`.
    """
    out: dict[str, int] = {}
    for pair in filter(None, (p.strip() for p in spec.split(","))):
        name, sep, val = pair.partition("=")
        if not sep or not name:
            raise ValueError(
                f"bad evidence {pair!r}: expected name=value")
        try:
            out[name.strip()] = int(val)
        except ValueError:
            raise ValueError(
                f"bad evidence value in {pair!r}: expected an integer") from None
    return out


MODES = ("marginals", "map")


@dataclass
class Request:
    """Shared base of every query family — the fields the engine reads
    regardless of how the evidence payload is shaped.

    ``n_samples`` is the *target* sample budget: roughly how many kept
    (post burn-in, thinned) draws to accumulate for this query across all
    of its chains.  The engine may stop earlier on convergence, and may
    overshoot — rounds are quantized, a micro-batched group runs to its
    largest member's budget, and the engine's ``max_rounds`` caps the
    total.  ``Result.n_samples`` reports what was actually kept.
    ``rhat_target`` / ``ess_target`` override the engine's retirement
    thresholds for this query alone (None = engine default): a latency-
    critical caller can loosen them, an accuracy-critical one can demand
    more effective samples — see ``docs/diagnostics.md``.

    ``mode`` selects the inference mode (``docs/inference_modes.md``):

    * ``"marginals"`` (default) — posterior marginals per query var,
      retired on the R̂/ESS diagnostics.
    * ``"map"`` — MAP/MPE: a simulated-annealing temperature schedule
      sharpens the sweep toward the posterior mode, retirement is by
      *assignment stability*, and the :class:`Result` carries
      ``map_assignment`` / ``map_energy`` instead of marginals.

    ``stream_id`` opts the query into temporal filtering: queries
    sharing a ``stream_id`` are treated as successive *time slices* of
    one evidence stream, and each slice's chains warm-start from the
    previous slice's retained states (same plan, burn-in skipped) —
    see the warm-start contract in ``docs/inference_modes.md``.

    ``deadline_ms`` declares an SLO: the caller wants the result within
    this many milliseconds of submission.  It is *scheduling advice*,
    not a hard timeout — an ``AdmissionQueue(scheduler="deadline")``
    orders dispatch and backfill earliest-deadline-first and may preempt
    deadline-free work for an at-risk query, but a missed deadline still
    returns a (late) result.  ``tenant`` names the quota bucket the
    serving front end (:mod:`repro.serve.server`) charges this query
    against; in-process callers can ignore both.

    All shared fields except ``network`` are keyword-only, so each
    subclass keeps its historical positional payload signature.
    """

    network: str
    n_samples: int = field(default=8192, kw_only=True)
    rhat_target: float | None = field(default=None, kw_only=True)
    ess_target: float | None = field(default=None, kw_only=True)
    mode: str = field(default="marginals", kw_only=True)
    stream_id: str | None = field(default=None, kw_only=True)
    deadline_ms: float | None = field(default=None, kw_only=True)
    tenant: str | None = field(default=None, kw_only=True)

    def __post_init__(self) -> None:
        if self.mode not in MODES:
            raise ValueError(
                f"unknown inference mode {self.mode!r} "
                f"(accepted: {', '.join(MODES)})")
        if self.deadline_ms is not None and not self.deadline_ms > 0:
            raise ValueError(
                f"deadline_ms must be positive, got {self.deadline_ms!r}")


@dataclass
class Query(Request):
    """One posterior request over a registered Bayesian network.

    ``query_vars`` empty means "all unobserved variables"; nodes may be
    referred to by name or id.  Budget/retirement/mode fields are the
    shared :class:`Request` contract.

    Example::

        Query("asia", {"smoke": 1, "dysp": 1}, ("lung", "bronc"),
              n_samples=8192, ess_target=400)
    """

    evidence: Mapping[str | int, int] = field(default_factory=dict)
    query_vars: Sequence[str | int] = ()


@dataclass
class MrfQuery(Request):
    """One posterior request over a registered MRF grid.

    Evidence is a *pixel mask*: ``mask`` ((H, W) bool-like, True =
    observed) with the observed labels read out of ``values`` ((H, W)
    int-like) wherever the mask is set — the interactive-segmentation
    scribble contract.  ``mask_sites`` is the sparse alternative (and
    the JSON request-file form): ``(row, col, label)`` triples, merged
    with the dense mask when both are given.  Queries sharing the same
    mask *pattern* share one compiled sweep program and can pack into
    one micro-batched group, whatever their observed labels.

    ``query_sites``: ``(row, col)`` pairs to report marginals for
    (empty = every unclamped site — fine for small grids, prefer an
    explicit subset on big ones: convergence is judged over the query
    sites, so fewer sites also means cheaper retirement checks).
    Budget/retirement/mode fields are the shared :class:`Request`
    contract.

    Example::

        mask = np.zeros((24, 24), bool); mask[12, 4:20] = True
        MrfQuery("penguin", mask, values, query_sites=((10, 10),))
    """

    mask: object = None
    values: object = None
    query_sites: Sequence[tuple[int, int]] = ()
    mask_sites: Sequence[tuple[int, int, int]] = ()


@dataclass
class IsingQuery(Request):
    """One posterior request over a registered sparse Ising model (or
    arbitrary factor graph).

    Evidence is a *clamp mask* over spins: ``clamp_sites`` lists
    ``(site, spin)`` pairs, with spins in ``{-1, +1}`` (or ``{0, 1}``
    labels — ``-1`` and ``0`` both mean spin-down).  The sorted site
    tuple is the evidence pattern: queries sharing a clamp pattern
    share one compiled sparse sweep program and can pack into one
    micro-batched group, whatever the clamped spin values — exactly the
    BN-evidence / MRF-scribble contract on an irregular graph.

    ``query_vars``: spin ids (or ``"s<id>"`` names) to report marginals
    for; empty = every unclamped spin — fine for small graphs, prefer
    an explicit subset on big ones (convergence is judged per query
    var).  Budget/retirement/mode fields are the shared
    :class:`Request` contract.

    Example::

        IsingQuery("ising_torus", clamp_sites=[(0, +1), (5, -1)],
                   query_vars=(1, 2), n_samples=4096)
    """

    clamp_sites: Sequence[tuple[int, int]] = ()
    query_vars: Sequence[str | int] = ()


@dataclass
class Result:
    """Answer to one :class:`Request` (any family, any mode).

    ``rhat`` is the worst plain split-R̂ over the query variables (kept
    in both retirement modes so results stay comparable across modes);
    ``converged`` reflects whichever retirement rule the engine ran.
    ``diagnostics`` is the full convergence payload
    (:class:`repro.pgm.diagnostics.Diagnostics`: rank/folded R̂,
    bulk/tail ESS in sweep units, sweeps used) — ``diagnostics.ess_bulk
    / wall_s`` is the honest per-query throughput number (effective
    samples per second, vs the raw MSample/s the paper quotes).

    Mode awareness: a ``mode="marginals"`` result fills ``marginals``
    and leaves ``map_assignment`` / ``map_energy`` as None; a
    ``mode="map"`` result does the reverse, ``converged`` means the
    annealed assignment went stable, and :meth:`marginal` raises —
    a MAP answer is an assignment, not a distribution.

    Example::

        res = engine.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",)))
        res.marginal("rain")              # np.ndarray, sums to 1
        res.diagnostics.min_ess           # worst-case effective draws
    """

    query: "Query | MrfQuery | IsingQuery"
    marginals: dict[str, np.ndarray]   # node name -> posterior P(v | e)
    n_samples: int                     # kept draws actually accumulated
    n_sweeps: int                      # total sweeps incl. burn-in
    n_node_samples: int                # free-node RV draws spent (throughput)
    rhat: float                        # worst split-R̂ over query vars
    converged: bool
    cache_hit: bool                    # plan served from the cache
    wall_s: float                      # wall time of the micro-batch group
    bits_per_sample: float = 0.0       # random bits per free-node draw
    diagnostics: "Diagnostics | None" = None  # rank-R̂/ESS payload
    map_assignment: dict[str, int] | None = None  # mode="map": var -> label
    map_energy: float | None = None    # mode="map": -log P̃(assignment, e)
    warm_start: bool = False           # temporal: lanes seeded from a
    #                                    previous slice's retained states

    def marginal(self, var: str) -> np.ndarray:
        if self.map_assignment is not None:
            raise ValueError(
                f"this is a mode='map' result — it carries a point "
                f"assignment (map_assignment/map_energy), not marginal "
                f"distributions; asked for marginal({var!r})")
        try:
            return self.marginals[var]
        except KeyError:
            raise KeyError(
                f"{var!r} was not a query variable of this request "
                f"(have: {sorted(self.marginals)})") from None


class QueryCancelled(RuntimeError):
    """Raised by :meth:`QueryHandle.result` for a cancelled query."""


class QueryStatus(enum.Enum):
    QUEUED = "queued"        # admitted, waiting for a dispatch trigger
    RUNNING = "running"      # packed into a live group (incl. burn-in)
    DONE = "done"            # result available
    CANCELLED = "cancelled"  # cancelled pre-dispatch or mid-flight
    FAILED = "failed"        # dispatch raised; result() re-raises


class QueryHandle:
    """Future for one streamed query.

    Thread-safe: the admission queue's dispatcher resolves it, any
    thread may :meth:`result`/:meth:`cancel`.  ``cancel`` before
    dispatch removes the query from its bucket immediately; mid-flight
    it is honoured at the next round boundary, freeing the query's
    chain lanes for a waiting query.  Cancellation after completion is
    a no-op returning False.
    """

    def __init__(self, query: Request, *, on_cancel=None):
        self.query = query
        # monotonic, not wall-clock: deadline/wait math must never see a
        # stepped clock (repro.serve.telemetry owns the clock choice)
        self.t_submit = monotonic()
        self.t_done: float | None = None
        self._status = QueryStatus.QUEUED
        self._result: Result | None = None
        self._error: BaseException | None = None
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._on_cancel = on_cancel       # queue callback: pre-dispatch unlink
        self._callbacks: list = []        # run once, at terminal resolution
        self.cancel_requested = False     # dispatcher polls at round edges

    @property
    def status(self) -> QueryStatus:
        return self._status

    @property
    def deadline(self) -> float | None:
        """Absolute monotonic deadline (``t_submit + deadline_ms``), or
        None for best-effort queries — the number deadline scheduling
        sorts on."""
        d = getattr(self.query, "deadline_ms", None)
        return None if d is None else self.t_submit + d / 1e3

    def done(self) -> bool:
        return self._event.is_set()

    def add_done_callback(self, fn) -> None:
        """Run ``fn(handle)`` exactly once when the handle resolves
        terminally (done/cancelled/failed) — immediately if it already
        has.  Callbacks fire on the resolving thread (the queue's
        dispatcher), outside the handle lock; the asyncio front end uses
        this to bridge results onto the event loop without burning a
        waiter thread per request."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def cancel(self) -> bool:
        """Request cancellation; True if the query will not produce a
        result (already-finished queries return False)."""
        with self._lock:
            if self._event.is_set():
                return False
            self.cancel_requested = True
        if self._on_cancel is not None:
            self._on_cancel(self)
        return True

    def result(self, timeout: float | None = None) -> Result:
        """Block for the result.  Raises :class:`QueryCancelled` on
        cancellation, the original exception on dispatch failure, and
        TimeoutError if ``timeout`` elapses first."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query not finished within {timeout}s "
                f"(status={self._status.value})")
        if self._status is QueryStatus.CANCELLED:
            raise QueryCancelled(f"query {self.query} was cancelled")
        if self._error is not None:
            raise self._error
        return self._result  # type: ignore[return-value]

    # -- dispatcher-side transitions (queue internal) ----------------------
    def _mark_running(self) -> None:
        with self._lock:
            if not self._event.is_set():
                self._status = QueryStatus.RUNNING

    def _requeue(self) -> None:
        """Preemption path: the dispatcher reclaimed this query's lanes
        and put it back in its bucket — status returns to QUEUED (the
        future stays unresolved; the query will run again)."""
        with self._lock:
            if not self._event.is_set():
                self._status = QueryStatus.QUEUED

    def _finish(self, status: QueryStatus, *, result: Result | None = None,
                error: BaseException | None = None) -> QueryStatus | None:
        """Resolve the future; returns the status actually applied (None
        if already resolved).  A DONE racing a cancel() that has already
        returned True resolves CANCELLED — cancel's promise ("will not
        produce a result") is kept atomically under the handle lock."""
        with self._lock:
            if self._event.is_set():
                return None
            if status is QueryStatus.DONE and self.cancel_requested:
                status, result = QueryStatus.CANCELLED, None
            self._status = status
            self._result, self._error = result, error
            self.t_done = monotonic()
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)
        return status
