"""Strict v2 wire schema of the serving front end.

This is the *over-the-wire* contract of :mod:`repro.serve.server` —
deliberately a separate, stricter parser than the request-**file**
loader (:func:`repro.serve.cli.load_requests`):

* the file loader stays lenient for operators (``"v"`` defaults to 1,
  v1 auto-upgrades, stray fields are the operator's own file);
* the wire rejects anything it does not fully understand, loudly —
  a remote client's typo (``"evidnce"``) silently dropping evidence
  would serve a *wrong posterior* with a 200 status.  So: ``"v": 2``
  is required (v1 and missing-``v`` are errors with an upgrade hint),
  unknown fields are errors naming the offender and the accepted set,
  and every field is type-checked before a query object is built.

Bitwise identity over JSON: marginals are float64; Python's ``json``
emits the shortest round-tripping decimal for a float, so a served
marginal parsed back with ``float()`` is *bit-identical* to the
in-process value — the golden conformance tests
(``tests/test_serve_protocol.py``) and the overload bench's identity
check both lean on this.

Functions raise :class:`WireError`, which carries the HTTP status code
and a JSON-able error body; everything here is jax-free (safe to import
before ``--force-host-devices`` handling).

>>> q, rid = parse_wire_request({"v": 2, "network": "asia",
...     "evidence": {"smoke": 1}, "query_vars": ["lung"], "id": 7})
>>> q.network, q.evidence, rid
('asia', {'smoke': 1}, 7)
>>> parse_wire_request({"v": 1, "network": "asia"})
Traceback (most recent call last):
  ...
repro.serve.protocol.WireError: schema v1 is not accepted over the \
wire: set "v": 2 (the request-file loader still auto-upgrades v1 files)
>>> parse_wire_request({"v": 2, "network": "asia", "evidnce": {}})
... # doctest: +ELLIPSIS
Traceback (most recent call last):
  ...
repro.serve.protocol.WireError: unknown field(s) 'evidnce' ...
"""
from __future__ import annotations

import numpy as np

from repro.serve.query import (
    MODES, IsingQuery, MrfQuery, Query, Request, Result)

WIRE_VERSION = 2

# fields every family accepts; "id" is an opaque client correlation tag
# echoed in the response (required on WebSocket streams, where responses
# arrive in completion order, not submission order)
COMMON_FIELDS = frozenset({
    "v", "id", "network", "n_samples", "rhat_target", "ess_target",
    "mode", "stream_id", "deadline_ms", "tenant"})
BN_FIELDS = frozenset({"evidence", "query_vars"})
MRF_FIELDS = frozenset({"mask_sites", "query_sites"})
ISING_FIELDS = frozenset({"clamp_sites", "query_vars"})
ALL_FIELDS = COMMON_FIELDS | BN_FIELDS | MRF_FIELDS | ISING_FIELDS

# response fields that legitimately differ between two runs of the same
# query (wall clock, group co-tenancy) — golden conformance tests and
# the identity checks compare everything else
NONDETERMINISTIC_FIELDS = ("wall_s", "bits_per_sample", "n_sweeps",
                           "n_samples", "n_node_samples", "diagnostics",
                           "rhat", "cache_hit", "warm_start")

__all__ = [
    "WIRE_VERSION", "WireError", "parse_wire_request", "request_to_wire",
    "result_to_wire", "wire_marginals", "error_body",
    "NONDETERMINISTIC_FIELDS"]


class WireError(ValueError):
    """A request the wire schema refuses; carries the HTTP status and a
    JSON-able error body (``{"error": ..., "v": 2}``)."""

    def __init__(self, message: str, *, code: int = 400, **extra):
        super().__init__(message)
        self.code = int(code)
        self.body = {"error": message, "v": WIRE_VERSION, **extra}


def _require(cond: bool, message: str, **extra) -> None:
    if not cond:
        raise WireError(message, **extra)


def _as_int(obj: dict, field: str, default=None):
    v = obj.get(field, default)
    if v is None or v is default and field not in obj:
        return default
    _require(isinstance(v, int) and not isinstance(v, bool),
             f"field {field!r} must be an integer, got {v!r}")
    return v


def _as_num(obj: dict, field: str):
    v = obj.get(field)
    if v is None:
        return None
    _require(isinstance(v, (int, float)) and not isinstance(v, bool),
             f"field {field!r} must be a number, got {v!r}")
    return float(v)


def _as_str(obj: dict, field: str):
    v = obj.get(field)
    if v is None:
        return None
    _require(isinstance(v, str), f"field {field!r} must be a string, "
             f"got {v!r}")
    return v


def _pairs(obj: dict, field: str, arity: int):
    v = obj.get(field, [])
    _require(isinstance(v, (list, tuple)),
             f"field {field!r} must be a list of {arity}-item lists")
    out = []
    for t in v:
        _require(isinstance(t, (list, tuple)) and len(t) == arity
                 and all(isinstance(x, int) and not isinstance(x, bool)
                         for x in t),
                 f"field {field!r} must be a list of {arity}-item "
                 f"integer lists, got element {t!r}")
        out.append(tuple(t))
    return tuple(out)


def parse_wire_request(obj) -> tuple[Request, object]:
    """One wire object -> ``(query, request_id)``.  Strict: see the
    module docstring for what is rejected and why."""
    _require(isinstance(obj, dict),
             f"request must be a JSON object, got {type(obj).__name__}")
    if "v" not in obj:
        raise WireError(
            'missing required field "v": the wire accepts schema v2 '
            'only (set "v": 2)')
    if obj["v"] != WIRE_VERSION:
        raise WireError(
            f'schema v{obj["v"]} is not accepted over the wire: set '
            '"v": 2 (the request-file loader still auto-upgrades v1 '
            'files)')
    unknown = sorted(set(obj) - ALL_FIELDS)
    if unknown:
        raise WireError(
            f"unknown field(s) {', '.join(repr(f) for f in unknown)} "
            f"(accepted: {', '.join(sorted(ALL_FIELDS))})")
    network = obj.get("network")
    _require(isinstance(network, str) and network,
             'field "network" is required and must be a non-empty string')
    mode = obj.get("mode", "marginals")
    _require(mode in MODES,
             f"unknown inference mode {mode!r} "
             f"(accepted: {', '.join(MODES)})")
    common = dict(
        n_samples=_as_int(obj, "n_samples", 8192),
        rhat_target=_as_num(obj, "rhat_target"),
        ess_target=_as_num(obj, "ess_target"),
        mode=mode,
        stream_id=_as_str(obj, "stream_id"),
        deadline_ms=_as_num(obj, "deadline_ms"),
        tenant=_as_str(obj, "tenant"))

    is_mrf = "mask_sites" in obj or "query_sites" in obj
    is_ising = "clamp_sites" in obj
    _require(not (is_mrf and is_ising),
             "request mixes MRF fields (mask_sites/query_sites) with "
             "Ising fields (clamp_sites) — pick one family")
    _require(not ((is_mrf or is_ising) and "evidence" in obj),
             'field "evidence" is the Bayesian-network form; MRF uses '
             '"mask_sites", Ising uses "clamp_sites"')
    try:
        if is_mrf:
            _require("query_vars" not in obj,
                     'MRF requests report sites: use "query_sites", '
                     'not "query_vars"')
            query: Request = MrfQuery(
                network, mask_sites=_pairs(obj, "mask_sites", 3),
                query_sites=_pairs(obj, "query_sites", 2), **common)
        elif is_ising:
            query = IsingQuery(
                network, clamp_sites=_pairs(obj, "clamp_sites", 2),
                query_vars=_qvars(obj), **common)
        else:
            ev = obj.get("evidence", {})
            _require(isinstance(ev, dict) and all(
                isinstance(k, (str, int)) and not isinstance(k, bool)
                and isinstance(v, int) and not isinstance(v, bool)
                for k, v in ev.items()),
                'field "evidence" must map node names to integer values')
            query = Query(network, {_node_key(k): v for k, v in ev.items()},
                          _qvars(obj), **common)
    except WireError:
        raise
    except ValueError as exc:  # Request.__post_init__ validation
        raise WireError(str(exc)) from None
    return query, obj.get("id")


def _node_key(k):
    """JSON object keys are always strings, but the in-process API also
    accepts integer node *indices* as evidence keys — so an all-digit
    key decodes back to the index it was before ``json.dumps`` turned
    ``{4: 1}`` into ``{"4": 1}``.  (Named nodes are never all-digit.)

    >>> q, _ = parse_wire_request({"v": 2, "network": "asia",
    ...     "evidence": {"4": 1, "smoke": 0}})
    >>> sorted(q.evidence.items(), key=str)
    [('smoke', 0), (4, 1)]
    """
    return int(k) if isinstance(k, str) and k.isdigit() else k


def _qvars(obj: dict):
    v = obj.get("query_vars", [])
    _require(isinstance(v, (list, tuple)) and all(
        isinstance(x, (str, int)) and not isinstance(x, bool) for x in v),
        'field "query_vars" must be a list of node names or ids')
    return tuple(v)


def request_to_wire(query: Request, *, id=None) -> dict:
    """Inverse of :func:`parse_wire_request` — the client-side encoder.

    >>> q = Query("asia", {"smoke": 1}, ("lung",), n_samples=512)
    >>> w = request_to_wire(q)
    >>> parse_wire_request(w)[0] == q
    True
    """
    out: dict = {"v": WIRE_VERSION, "network": query.network,
                 "n_samples": query.n_samples}
    if id is not None:
        out["id"] = id
    for f in ("rhat_target", "ess_target", "stream_id", "deadline_ms",
              "tenant"):
        v = getattr(query, f)
        if v is not None:
            out[f] = v
    if query.mode != "marginals":
        out["mode"] = query.mode
    if isinstance(query, MrfQuery):
        out["mask_sites"] = [list(t) for t in query.mask_sites]
        if query.query_sites:
            out["query_sites"] = [list(t) for t in query.query_sites]
    elif isinstance(query, IsingQuery):
        out["clamp_sites"] = [list(t) for t in query.clamp_sites]
        if query.query_vars:
            out["query_vars"] = list(query.query_vars)
    else:
        out["evidence"] = dict(query.evidence)
        if query.query_vars:
            out["query_vars"] = list(query.query_vars)
    return out


def result_to_wire(result: Result, *, id=None) -> dict:
    """One :class:`repro.serve.query.Result` as a JSON-able response
    object.  Marginals go out as float lists — bit-exact through JSON
    (shortest-round-trip float encoding)."""
    d = result.diagnostics
    out = {
        "v": WIRE_VERSION,
        "network": result.query.network,
        "mode": getattr(result.query, "mode", "marginals"),
        "marginals": ({name: np.asarray(m, np.float64).tolist()
                       for name, m in result.marginals.items()}
                      if result.map_assignment is None else None),
        "map_assignment": result.map_assignment,
        "map_energy": result.map_energy,
        "n_samples": result.n_samples,
        "n_sweeps": result.n_sweeps,
        "n_node_samples": result.n_node_samples,
        "rhat": float(result.rhat),
        "converged": bool(result.converged),
        "cache_hit": bool(result.cache_hit),
        "warm_start": bool(result.warm_start),
        "wall_s": float(result.wall_s),
        "bits_per_sample": float(result.bits_per_sample),
        "diagnostics": None if d is None else {
            "rhat": float(d.rhat), "rank_rhat": float(d.rank_rhat),
            "folded_rhat": float(d.folded_rhat),
            "ess_bulk": float(d.ess_bulk), "ess_tail": float(d.ess_tail),
            "sweeps_used": int(d.sweeps_used)},
    }
    if id is not None:
        out["id"] = id
    return out


def wire_marginals(response: dict) -> dict[str, np.ndarray]:
    """A wire response's marginals back as float64 arrays — bit-exact
    vs the serving process (see module docstring)."""
    m = response.get("marginals")
    if m is None:
        raise WireError("response carries no marginals (mode="
                        f"{response.get('mode')!r})")
    return {name: np.asarray(v, np.float64) for name, v in m.items()}


def error_body(exc: BaseException) -> dict:
    """JSON error body for any exception (WireError keeps its own)."""
    if isinstance(exc, WireError):
        return exc.body
    return {"error": f"{type(exc).__name__}: {exc}", "v": WIRE_VERSION}
