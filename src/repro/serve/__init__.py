"""Posterior query service — evidence-conditioned, batched PGM inference.

Turns the compiler chain + Gibbs substrate of :mod:`repro.pgm` into a
*query engine*: callers submit (network, evidence, query vars, budget)
requests and get posterior marginals back.  Compiled sweep programs are
cached by evidence *pattern* so repeat traffic never recompiles (and
persist to disk via ``plan_cache_dir`` so warm process starts skip the
compiler chain), and compatible queries are micro-batched across chain
lanes of one jitted sweep — the TPU analogue of AIA mapping many
independent chains onto its cores (paper §III).  With a serve mesh the
lane axis additionally shards across devices
(:func:`repro.launch.mesh.make_serve_mesh`).

Three PGM families are served (:mod:`repro.serve.families`):
:class:`Query` clamps Bayesian-network *nodes*, :class:`MrfQuery`
clamps MRF grid *pixels* (scribble masks for interactive segmentation),
and :class:`IsingQuery` clamps *spins* of a sparse Ising model /
factor graph — same engine, same plan cache, same queue.  All three
share the :class:`Request` base (network, budget, retirement targets,
``mode``, ``stream_id``): ``mode="map"`` switches a query to annealed
MAP/MPE search (a point assignment + energy instead of marginals), and
``stream_id`` opts it into temporal filtering — each slice of a stream
warm-starts from the previous slice's retained chains.  See
``docs/inference_modes.md``.

Streaming traffic goes through :class:`AdmissionQueue`
(:mod:`repro.serve.queue`): per-plan buckets dispatch on a deadline or
size trigger, each submission gets a cancellable :class:`QueryHandle`,
and queries retire individually on convergence so freed chain lanes
backfill mid-flight.  Retirement is judged by the rank-normalized
split-R̂ + ESS diagnostics of :mod:`repro.pgm.diagnostics` by default
(``retirement="legacy"`` selects the plain split-R̂ rule); every
:class:`Result` carries the full :class:`Diagnostics` payload.

Observability (:mod:`repro.serve.telemetry`): pass ``telemetry=
Telemetry()`` to the engine to record the full query lifecycle as
Chrome/Perfetto trace spans plus a Prometheus-exportable metrics
registry — a no-op :class:`NullTelemetry` by default, and
:meth:`PosteriorEngine.stats` snapshots the plan-cache/queue counters
either way.  See ``docs/observability.md``.

Scale-out serving (:mod:`repro.serve.server` / ``worker`` /
``protocol`` / ``client``): an asyncio HTTP + WebSocket front end over
a pool of engines, consistent-hash routed on the plan key, with
per-tenant token-bucket quotas, ``max_pending`` backpressure, and an
optional deadline (EDF) scheduler that preempts using per-query ESS
trajectories (:mod:`repro.serve.sched`).  Start one with
``python -m repro.serve.cli --serve :8080``; see ``docs/serving.md``.

The engine (and with it jax) is imported lazily: the CLI must be able to
apply ``--force-host-devices`` before the XLA backend initializes.
"""
from repro.serve.plan_cache import (
    CacheStats, PlanCache, graph_fingerprint, load_compiled,
    network_fingerprint, persisted_plan_path, plan_key, save_compiled)
from repro.serve.protocol import (
    WIRE_VERSION, WireError, parse_wire_request, request_to_wire,
    result_to_wire, wire_marginals)
from repro.serve.query import (
    MODES, IsingQuery, MrfQuery, Query, QueryCancelled, QueryHandle,
    QueryStatus, Request, Result, parse_evidence)
from repro.serve.sched import (
    TokenBucket, deadline_order, predict_remaining_rounds)
from repro.serve.telemetry import (
    MetricsRegistry, NullTelemetry, Telemetry, lifecycle_breakdown)

# Diagnostics types route through the lazy table too: repro.pgm's
# package __init__ imports jax, which must not initialize before the
# CLI's --force-host-devices handling runs
_LAZY = {
    "PosteriorEngine": "repro.serve.engine",
    "GroupRun": "repro.serve.engine",
    "RETIREMENT_MODES": "repro.serve.engine",
    "Diagnostics": "repro.pgm.diagnostics",
    "RunningDiagnostics": "repro.pgm.diagnostics",
    "compute_diagnostics": "repro.pgm.diagnostics",
    "split_rhat": "repro.serve.engine",
    "make_round_runner": "repro.serve.families",
    "make_mrf_round_runner": "repro.serve.families",
    "make_fg_round_runner": "repro.serve.families",
    "IsingFamily": "repro.serve.families",
    "family_of": "repro.serve.families",
    "AdmissionQueue": "repro.serve.queue",
    "QueueStats": "repro.serve.queue",
    # server/worker pull in queue -> engine -> jax, so they stay lazy
    # (protocol/sched are jax-free and imported eagerly above; the
    # client is jax-free too but stays lazy to keep import light)
    "ServeFrontEnd": "repro.serve.server",
    "start_in_thread": "repro.serve.server",
    "HashRing": "repro.serve.worker",
    "Worker": "repro.serve.worker",
    "WorkerDied": "repro.serve.worker",
    "WorkerPool": "repro.serve.worker",
    "ServeClient": "repro.serve.client",
    "ServeHTTPError": "repro.serve.client",
}

__all__ = [
    "AdmissionQueue", "CacheStats", "Diagnostics", "GroupRun",
    "HashRing", "IsingFamily", "IsingQuery", "MODES", "MetricsRegistry",
    "MrfQuery", "NullTelemetry", "PlanCache", "PosteriorEngine", "Query",
    "QueryCancelled", "QueryHandle", "QueryStatus", "QueueStats",
    "RETIREMENT_MODES", "Request", "Result", "RunningDiagnostics",
    "ServeClient", "ServeFrontEnd", "ServeHTTPError", "Telemetry",
    "TokenBucket", "WIRE_VERSION", "WireError", "Worker", "WorkerDied",
    "WorkerPool",
    "compute_diagnostics", "deadline_order", "family_of",
    "graph_fingerprint", "lifecycle_breakdown", "load_compiled",
    "make_fg_round_runner", "make_mrf_round_runner", "make_round_runner",
    "network_fingerprint", "parse_evidence", "parse_wire_request",
    "persisted_plan_path", "plan_key", "predict_remaining_rounds",
    "request_to_wire", "result_to_wire", "save_compiled", "split_rhat",
    "start_in_thread", "wire_marginals",
]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
