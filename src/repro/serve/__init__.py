"""Posterior query service — evidence-conditioned, batched PGM inference.

Turns the compiler chain + Gibbs substrate of :mod:`repro.pgm` into a
*query engine*: callers submit (network, evidence, query vars, budget)
requests and get posterior marginals back.  Compiled sweep programs are
cached by evidence *pattern* so repeat traffic never recompiles, and
compatible queries are micro-batched across chain lanes of one jitted
sweep — the TPU analogue of AIA mapping many independent chains onto its
cores (paper §III).
"""
from repro.serve.engine import PosteriorEngine, split_rhat
from repro.serve.plan_cache import CacheStats, PlanCache
from repro.serve.query import Query, Result, parse_evidence

__all__ = [
    "CacheStats", "PlanCache", "PosteriorEngine", "Query", "Result",
    "parse_evidence", "split_rhat",
]
