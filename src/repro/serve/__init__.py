"""Posterior query service — evidence-conditioned, batched PGM inference.

Turns the compiler chain + Gibbs substrate of :mod:`repro.pgm` into a
*query engine*: callers submit (network, evidence, query vars, budget)
requests and get posterior marginals back.  Compiled sweep programs are
cached by evidence *pattern* so repeat traffic never recompiles, and
compatible queries are micro-batched across chain lanes of one jitted
sweep — the TPU analogue of AIA mapping many independent chains onto its
cores (paper §III).  With a serve mesh the lane axis additionally shards
across devices (:func:`repro.launch.mesh.make_serve_mesh`).

The engine (and with it jax) is imported lazily: the CLI must be able to
apply ``--force-host-devices`` before the XLA backend initializes.
"""
from repro.serve.plan_cache import CacheStats, PlanCache, plan_key
from repro.serve.query import Query, Result, parse_evidence

_LAZY = ("PosteriorEngine", "split_rhat", "make_round_runner")

__all__ = [
    "CacheStats", "PlanCache", "PosteriorEngine", "Query", "Result",
    "make_round_runner", "parse_evidence", "plan_key", "split_rhat",
]


def __getattr__(name: str):
    if name in _LAZY:
        from repro.serve import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
