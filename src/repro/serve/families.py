"""Model-family adapters: one serving engine, three PGM families.

The AIA fabric runs MRF grids and Bayesian networks on the same 16
Gibbs cores (paper Fig. 7); the serving analogue is one
:class:`repro.serve.engine.PosteriorEngine` whose family-specific
surface — how a query normalizes to an evidence pattern, how a pattern
compiles to a sweep program, how a round runner advances the packed
lane state — lives behind the small adapter objects here.  Everything
else (lane packing, per-query split-R̂ retirement, plan caching,
admission-queue bucketing, mesh sharding, backfill) is family-agnostic
because every adapter presents the same *flat variable space* to the
engine:

* a state tensor with a leading chain-lane axis,
* per-round ``counts (B, M, L)`` / ``xmean (B, M)`` over M flat
  variables (BN: nodes; MRF: ``H*W`` sites; Ising/factor graph: graph
  nodes),
* an evidence pattern that is a sorted tuple of flat variable ids
  (BN: observed nodes; MRF: clamped ``r * W + c`` pixel indices;
  Ising: clamped spin ids), with per-lane evidence *values* packed
  ``(B, O)`` in pattern order.

``family_of(model)`` dispatches on the registered model's type.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.pgm.compile import (
    BNSweepStats, _color_update, compile_bayesnet, init_states)
from repro.pgm.gibbs import SweepStats, checkerboard_halfstep
from repro.pgm.graph import BayesNet, FactorGraph, IsingModel, MRFGrid
from repro.pgm.mrf_compile import CompiledMRF, compile_mrf, init_mrf_states
from repro.pgm.sparse_compile import (
    CompiledFactorGraph, _sparse_color_update, compile_factor_graph,
    init_fg_states)
from repro.serve.plan_cache import (
    graph_fingerprint, load_compiled, persisted_plan_path, save_compiled)
from repro.sharding.specs import (
    serve_cpt_spec, serve_fg_state_spec, serve_mrf_state_spec,
    serve_state_spec)


# -- round runners ---------------------------------------------------------
def make_round_runner(prog, *, sweeps_per_round: int, thin: int,
                      use_iu: bool, sampler: str = "xla", mesh=None):
    """Jitted ``(key, x, offset[, beta]) -> (x, counts, xmean, xsq,
    stats)`` per round (Bayesian-network family).

    ``beta`` (traced float32, scalar or per-lane ``(B,)``; default None
    = ordinary Gibbs) is the inverse temperature of the simulated-
    annealing MAP mode: every color update scales its log-weights by it
    before the IU-exp tail, so one compiled round program serves both
    inference modes — and any point of an annealing schedule — without
    retracing.  Per-lane values let annealed (MAP) and β=1 (marginal)
    slots share one micro-batched group.

    ``offset`` (traced int32, scalar or per-lane ``(B,)``) is the global
    post-burn-in sweep index of the round's first sweep: draws are kept
    where the *global* index is a multiple of ``thin``.  A round-relative
    ``i % thin`` would restart the phase every round, so for
    ``sweeps_per_round % thin != 0`` the kept-draw spacing (and every
    downstream sample count) drifted.  The per-lane form lets one round
    serve lanes at *different* points of their thinning schedule — slots
    backfilled mid-flight by ``GroupRun.admit`` restart their own phase
    at 0 while their group mates keep counting.

    ``counts``: (B, n, L) thinned one-hot draw counts this round.
    ``xmean``:  (B, n) mean state over the round — per-lane scalar
    statistics for the convergence diagnostics (for a binary node this
    is its running posterior-probability estimate).
    ``xsq``:    (B, n) mean of x² over the round — the extra per-round
    moment :mod:`repro.pgm.diagnostics` needs to rescale round-unit ESS
    to sweep units (both moments accumulate inside the same fused scan,
    so diagnostics cost zero extra dispatches).
    ``stats``:  per-sweep (sweeps_per_round,) int32 arrays — summed
    host-side in int64 by the engine (int32 carries wrapped on long
    runs; see :class:`repro.pgm.compile.BNSweepStats`).

    With ``mesh`` the lane (batch) axis of ``x``/``counts`` is held to a
    NamedSharding over the mesh's "batch" axis and the log-CPT bank is
    placed per ``serve_cpt_spec`` — one compile per (plan, mesh).
    """
    log_cpt = jnp.asarray(prog.log_cpt)
    state_sharding = None
    if mesh is not None:
        log_cpt = jax.device_put(
            log_cpt, NamedSharding(mesh, serve_cpt_spec(mesh, log_cpt.size)))
        state_sharding = NamedSharding(mesh, serve_state_spec(mesh))
    L = prog.max_card

    def round_fn(key: jax.Array, x: jax.Array, offset: jax.Array,
                 beta: jax.Array | None = None):
        if state_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, state_sharding)

        def body(carry, i):
            key, x, counts, xsum, xsqsum = carry
            key, sub = jax.random.split(key)
            bits, att = jnp.int32(0), jnp.int32(0)
            for plan in prog.plans:
                sub, s2 = jax.random.split(sub)
                x, st = _color_update(
                    s2, x, plan, log_cpt, L, prog.k, use_iu, sampler,
                    beta)
                bits, att = bits + st.bits_used, att + st.attempts
            onehot = (x[..., None] == jnp.arange(L)).astype(jnp.int32)
            kept = ((offset + i) % thin) == 0
            if kept.ndim:  # per-lane offsets: broadcast over (node, label)
                kept = kept[:, None, None]
            counts = counts + jnp.where(kept, onehot, 0)
            xf = x.astype(jnp.float32)
            xsum = xsum + xf
            xsqsum = xsqsum + xf * xf
            return (key, x, counts, xsum, xsqsum), BNSweepStats(bits, att)

        counts0 = jnp.zeros(x.shape + (L,), jnp.int32)
        xsum0 = jnp.zeros(x.shape, jnp.float32)
        (key, x, counts, xsum, xsqsum), per_sweep = jax.lax.scan(
            body, (key, x, counts0, xsum0, xsum0),
            jnp.arange(sweeps_per_round))
        if state_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, state_sharding)
        return (x, counts, xsum / sweeps_per_round,
                xsqsum / sweeps_per_round, per_sweep)

    return jax.jit(round_fn)


def make_mrf_round_runner(prog: CompiledMRF, *, sweeps_per_round: int,
                          thin: int, use_iu: bool, sampler: str = "xla",
                          mesh=None):
    """Jitted ``(key, x, offset[, beta]) -> (x, counts, xmean, xsq,
    stats)`` per round (MRF family) — same contract as
    :func:`make_round_runner` (including the traced annealing ``beta``),
    over the flat site space.

    ``x`` is the (B, H, W) label field; the clamp mask compiled into
    ``prog`` is baked as a constant (the mask IS the plan — one XLA
    program per mask pattern, exactly one per BN evidence pattern).
    ``counts`` come back flattened (B, H*W, L) and ``xmean`` (B, H*W)
    so the engine's slot bookkeeping is family-blind.  With ``mesh``
    the lane axis shards over "batch" (``serve_mrf_state_spec``); the
    unary/pairwise fields are replicated — they are the gather operands
    of every lane's checkerboard update.
    """
    from repro.pgm.mrf_compile import mask_of

    unary = jnp.asarray(prog.mrf.unary)
    pairwise = jnp.asarray(prog.mrf.pairwise)
    clamp = jnp.asarray(mask_of(prog)) if prog.observed else None
    state_sharding = None
    if mesh is not None:
        rep = NamedSharding(mesh, P())
        unary, pairwise = jax.device_put(unary, rep), jax.device_put(pairwise, rep)
        if clamp is not None:
            clamp = jax.device_put(clamp, rep)
        state_sharding = NamedSharding(mesh, serve_mrf_state_spec(mesh))
    h, w = prog.shape
    L = prog.n_labels

    def round_fn(key: jax.Array, x: jax.Array, offset: jax.Array,
                 beta: jax.Array | None = None):
        if state_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, state_sharding)
        b = x.shape[0]

        def body(carry, i):
            key, x, counts, xsum, xsqsum = carry
            key, k0, k1 = jax.random.split(key, 3)
            x, s0 = checkerboard_halfstep(
                k0, x, unary, pairwise, jnp.int32(0), clamp=clamp,
                k=prog.k, use_iu=use_iu, sampler=sampler, beta=beta)
            x, s1 = checkerboard_halfstep(
                k1, x, unary, pairwise, jnp.int32(1), clamp=clamp,
                k=prog.k, use_iu=use_iu, sampler=sampler, beta=beta)
            flat = x.reshape(b, h * w)
            onehot = (flat[..., None] == jnp.arange(L)).astype(jnp.int32)
            kept = ((offset + i) % thin) == 0
            if kept.ndim:  # per-lane offsets: broadcast over (site, label)
                kept = kept[:, None, None]
            counts = counts + jnp.where(kept, onehot, 0)
            ff = flat.astype(jnp.float32)
            xsum = xsum + ff
            xsqsum = xsqsum + ff * ff
            return (key, x, counts, xsum, xsqsum), SweepStats(
                s0.bits_used + s1.bits_used, s0.attempts + s1.attempts)

        counts0 = jnp.zeros((b, h * w, L), jnp.int32)
        xsum0 = jnp.zeros((b, h * w), jnp.float32)
        (key, x, counts, xsum, xsqsum), per_sweep = jax.lax.scan(
            body, (key, x, counts0, xsum0, xsum0),
            jnp.arange(sweeps_per_round))
        if state_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, state_sharding)
        return (x, counts, xsum / sweeps_per_round,
                xsqsum / sweeps_per_round, per_sweep)

    return jax.jit(round_fn)


def make_fg_round_runner(prog: CompiledFactorGraph, *,
                         sweeps_per_round: int, thin: int, use_iu: bool,
                         sampler: str = "xla", mesh=None):
    """Jitted ``(key, x, offset[, beta]) -> (x, counts, xmean, xsq,
    stats)`` per round (sparse factor-graph / Ising family) — same
    contract as :func:`make_round_runner` (including the traced
    annealing ``beta``), over the graph's flat node space.

    ``x`` is the (B, n) node-state tensor; the compiled color plans and
    degree buckets are baked as constants (the plan IS the program —
    one XLA build per (graph, clamp pattern), like one per BN evidence
    pattern).  With ``mesh`` the lane axis shards over "batch" and —
    for million-site graphs — the site axis additionally shards over
    "model" (``serve_fg_state_spec``); the unary/table banks are
    replicated (they are the gather operands of every lane's sweep).
    """
    unary = jnp.asarray(prog.unary)
    tables_flat = jnp.asarray(prog.tables).reshape(-1)
    card = jnp.asarray(prog.fg.card, jnp.int32)
    state_sharding = None
    if mesh is not None:
        rep = NamedSharding(mesh, P())
        unary = jax.device_put(unary, rep)
        tables_flat = jax.device_put(tables_flat, rep)
        card = jax.device_put(card, rep)
        state_sharding = NamedSharding(
            mesh, serve_fg_state_spec(mesh, prog.n_vars))
    L = prog.max_card

    def round_fn(key: jax.Array, x: jax.Array, offset: jax.Array,
                 beta: jax.Array | None = None):
        if state_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, state_sharding)

        def body(carry, i):
            key, x, counts, xsum, xsqsum = carry
            key, sub = jax.random.split(key)
            bits, att = jnp.int32(0), jnp.int32(0)
            for plan in prog.plans:
                sub, s2 = jax.random.split(sub)
                x, st = _sparse_color_update(
                    s2, x, plan, unary, tables_flat, card, L, prog.k,
                    use_iu, sampler, beta)
                bits, att = bits + st.bits_used, att + st.attempts
            onehot = (x[..., None] == jnp.arange(L)).astype(jnp.int32)
            kept = ((offset + i) % thin) == 0
            if kept.ndim:  # per-lane offsets: broadcast over (node, label)
                kept = kept[:, None, None]
            counts = counts + jnp.where(kept, onehot, 0)
            xf = x.astype(jnp.float32)
            xsum = xsum + xf
            xsqsum = xsqsum + xf * xf
            return (key, x, counts, xsum, xsqsum), BNSweepStats(bits, att)

        counts0 = jnp.zeros(x.shape + (L,), jnp.int32)
        xsum0 = jnp.zeros(x.shape, jnp.float32)
        (key, x, counts, xsum, xsqsum), per_sweep = jax.lax.scan(
            body, (key, x, counts0, xsum0, xsum0),
            jnp.arange(sweeps_per_round))
        if state_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, state_sharding)
        return (x, counts, xsum / sweeps_per_round,
                xsqsum / sweeps_per_round, per_sweep)

    return jax.jit(round_fn)


# -- family adapters -------------------------------------------------------
class BayesNetFamily:
    """Engine adapter for :class:`repro.pgm.graph.BayesNet` models."""

    kind = "bayesnet"

    def normalize(self, model: BayesNet, query):
        """``(evidence-by-flat-id, query-var ids, pattern)``; raises on
        bad evidence or query vars that are observed."""
        ev = model.normalize_evidence(query.evidence)
        qvars = tuple(model.index(v) for v in query.query_vars) or tuple(
            v for v in range(model.n_nodes) if v not in ev)
        clash = [model.names[v] for v in qvars if v in ev]
        if clash:
            raise ValueError(f"query vars {clash} are observed")
        return ev, qvars, tuple(sorted(ev))

    def compile(self, model, pattern, *, k, quantize_cpt_bits):
        return compile_bayesnet(
            model, k=k, quantize_cpt_bits=quantize_cpt_bits,
            observed=pattern)

    def make_runner(self, prog, *, sweeps_per_round, thin, use_iu,
                    sampler="xla", mesh=None):
        return make_round_runner(
            prog, sweeps_per_round=sweeps_per_round, thin=thin,
            use_iu=use_iu, sampler=sampler, mesh=mesh)

    def init_states(self, key, prog, n_lanes, evidence_values):
        return init_states(key, prog, n_lanes, evidence_values)

    def clamp_states(self, prog, x, evidence_values):
        """Re-pin the evidence columns of *existing* states — the
        temporal warm start: retained chains from the previous slice,
        this slice's observations."""
        if not prog.observed:
            return x
        ev = jnp.asarray(evidence_values, jnp.int32)
        if ev.ndim == 1:
            ev = jnp.broadcast_to(ev[None], (x.shape[0], len(prog.observed)))
        return x.at[:, jnp.asarray(prog.observed, jnp.int32)].set(ev)

    def assignment_energy(self, model, assignment) -> float:
        """-log P(x) (nats) of a full assignment over every node — the
        MAP objective the annealed mode minimizes."""
        e = 0.0
        for v in range(model.n_nodes):
            idx = tuple(int(assignment[p]) for p in model.parents[v])
            p = float(model.cpt[v][idx + (int(assignment[v]),)])
            e -= float(np.log(max(p, 1e-26)))
        return e

    def state_spec(self, mesh):
        return serve_state_spec(mesh)

    def n_vars(self, prog) -> int:
        return prog.bn.n_nodes

    def max_card(self, prog) -> int:
        return prog.max_card

    def var_card(self, prog, v: int) -> int:
        return prog.bn.card[v]

    def var_name(self, model, v: int) -> str:
        return model.names[v]

    def n_free(self, prog) -> int:
        return len(prog.free_nodes)

    def plan_salt(self, model):
        """BN plans are fully determined by (name, pattern, knobs)."""
        return None

    # -- plan persistence (compiler chain is worth skipping for BNs) ------
    def persisted_path(self, directory, name, pattern, model, *,
                       k, quantize_cpt_bits):
        return persisted_plan_path(
            directory, name, pattern, model, k=k,
            quantize_cpt_bits=quantize_cpt_bits)

    def load_persisted(self, path, model):
        return load_compiled(path, model)

    def save_persisted(self, path, prog):
        save_compiled(path, prog)


class MrfFamily:
    """Engine adapter for :class:`repro.pgm.graph.MRFGrid` models.

    Flat variable ids are ``r * W + c``; evidence is a pixel mask plus
    observed labels (:class:`repro.serve.query.MrfQuery`).
    """

    kind = "mrf"

    def normalize(self, model: MRFGrid, query):
        import numpy as np

        h, w = model.shape
        ev: dict[int, int] = {}
        if query.mask is not None:
            mask = np.asarray(query.mask, bool)
            if mask.shape != (h, w):
                raise ValueError(
                    f"mask shape {mask.shape} != grid shape {(h, w)}")
            if mask.any():
                if query.values is None:
                    raise ValueError("mask given without values")
                values = np.asarray(query.values)
                if values.shape != (h, w):
                    raise ValueError(
                        f"values shape {values.shape} != grid shape {(h, w)}")
                rs, cs = np.nonzero(mask)
                for r, c in zip(rs.tolist(), cs.tolist()):
                    ev[r * w + c] = int(values[r, c])
        for site in getattr(query, "mask_sites", ()) or ():
            r, c, val = (int(s) for s in site)
            # per-coordinate check: a flat r*w+c range test would let an
            # out-of-range column alias onto a different pixel's row
            if not (0 <= r < h and 0 <= c < w):
                raise ValueError(f"clamped site ({r}, {c}) outside the "
                                 f"{(h, w)} lattice")
            if ev.get(r * w + c, val) != val:
                raise ValueError(f"conflicting evidence at site ({r}, {c})")
            ev[r * w + c] = val
        for v, val in ev.items():
            if not 0 <= val < model.n_labels:
                raise ValueError(
                    f"observed label {val} at site {divmod(v, w)} outside "
                    f"[0, {model.n_labels})")
        if len(ev) == h * w:
            raise ValueError("all sites clamped — nothing to infer")
        if query.query_sites:
            qvars = []
            for r, c in query.query_sites:
                r, c = int(r), int(c)
                if not (0 <= r < h and 0 <= c < w):
                    raise KeyError(f"query site ({r}, {c}) outside the "
                                   f"{(h, w)} lattice")
                qvars.append(r * w + c)
            clash = [divmod(v, w) for v in qvars if v in ev]
            if clash:
                raise ValueError(f"query sites {clash} are observed")
            qvars = tuple(qvars)
        else:
            qvars = tuple(v for v in range(h * w) if v not in ev)
        return ev, qvars, tuple(sorted(ev))

    def compile(self, model, pattern, *, k, quantize_cpt_bits):
        # quantize_cpt_bits is a CPT-bank knob; grids carry energies, not
        # CPTs, so it does not apply here (it still keys the plan cache)
        return compile_mrf(model, k=k, observed=pattern)

    def make_runner(self, prog, *, sweeps_per_round, thin, use_iu,
                    sampler="xla", mesh=None):
        return make_mrf_round_runner(
            prog, sweeps_per_round=sweeps_per_round, thin=thin,
            use_iu=use_iu, sampler=sampler, mesh=mesh)

    def init_states(self, key, prog, n_lanes, evidence_values):
        return init_mrf_states(key, prog, n_lanes, evidence_values)

    def clamp_states(self, prog, x, evidence_values):
        """Re-pin the clamped pixels of existing (B, H, W) label fields
        (temporal warm start)."""
        if not prog.observed:
            return x
        b = x.shape[0]
        h, w = prog.shape
        ev = jnp.asarray(evidence_values, jnp.int32)
        if ev.ndim == 1:
            ev = jnp.broadcast_to(ev[None], (b, len(prog.observed)))
        flat = x.reshape(b, h * w)
        flat = flat.at[:, jnp.asarray(prog.observed, jnp.int32)].set(ev)
        return flat.reshape(b, h, w)

    def assignment_energy(self, model, assignment) -> float:
        """Grid energy (unary + each lattice edge once) of a full
        assignment over every site — the MAP objective."""
        h, w = model.shape
        x = np.array([[int(assignment[r * w + c]) for c in range(w)]
                      for r in range(h)])
        unary = np.asarray(model.unary)
        pw = np.asarray(model.pairwise)
        e = float(unary[np.arange(h)[:, None], np.arange(w)[None, :], x].sum())
        e += float(pw[x[:, :-1], x[:, 1:]].sum())   # horizontal edges
        e += float(pw[x[:-1, :], x[1:, :]].sum())   # vertical edges
        return e

    def state_spec(self, mesh):
        return serve_mrf_state_spec(mesh)

    def n_vars(self, prog) -> int:
        return prog.n_sites

    def max_card(self, prog) -> int:
        return prog.n_labels

    def var_card(self, prog, v: int) -> int:
        return prog.n_labels

    def var_name(self, model, v: int) -> str:
        r, c = divmod(v, model.shape[1])
        return f"s{r},{c}"

    def n_free(self, prog) -> int:
        return prog.n_free

    def plan_salt(self, model):
        """MRF plans are fully determined by (name, pattern, knobs)."""
        return None

    # -- plan persistence: compiling an MRF plan is O(1), nothing to skip
    def persisted_path(self, directory, name, pattern, model, *,
                       k, quantize_cpt_bits):
        return None

    def load_persisted(self, path, model):  # pragma: no cover - unused
        return None

    def save_persisted(self, path, prog):  # pragma: no cover - unused
        pass


class IsingFamily:
    """Engine adapter for sparse :class:`repro.pgm.graph.IsingModel` /
    :class:`repro.pgm.graph.FactorGraph` models.

    Flat variable ids are graph node ids; evidence is a clamp mask over
    spins (:class:`repro.serve.query.IsingQuery` ``clamp_sites`` pairs —
    ``±1`` spins or ``{0, 1}`` labels), or a plain :class:`Query`-style
    evidence mapping for general factor graphs.  Queries sharing a
    clamp *pattern* share one compiled sparse sweep program
    (:class:`repro.pgm.sparse_compile.CompiledFactorGraph`) whatever
    their clamped values.
    """

    kind = "ising"

    def normalize(self, model, query):
        clamp = getattr(query, "clamp_sites", None)
        if clamp is not None:
            raw = {}
            for site, spin in clamp:
                v, spin = int(site), int(spin)
                if raw.get(v, spin) != spin:
                    raise ValueError(
                        f"conflicting evidence for spin {v}")
                raw[v] = spin
            ev = model.normalize_evidence(raw)
        else:
            ev = model.normalize_evidence(query.evidence)
        qvars = tuple(model.index(v) for v in query.query_vars) or tuple(
            v for v in range(model.n_vars) if v not in ev)
        clash = [model.var_name(v) for v in qvars if v in ev]
        if clash:
            raise ValueError(f"query vars {clash} are observed")
        return ev, qvars, tuple(sorted(ev))

    def compile(self, model, pattern, *, k, quantize_cpt_bits):
        # quantize_cpt_bits is a CPT-bank knob; factor graphs carry
        # energies, not CPTs (it still keys the plan cache)
        return compile_factor_graph(model, k=k, observed=pattern)

    def make_runner(self, prog, *, sweeps_per_round, thin, use_iu,
                    sampler="xla", mesh=None):
        return make_fg_round_runner(
            prog, sweeps_per_round=sweeps_per_round, thin=thin,
            use_iu=use_iu, sampler=sampler, mesh=mesh)

    def init_states(self, key, prog, n_lanes, evidence_values):
        return init_fg_states(key, prog, n_lanes, evidence_values)

    def clamp_states(self, prog, x, evidence_values):
        """Re-pin the clamped spins of existing (B, n) states (temporal
        warm start)."""
        if not prog.observed:
            return x
        ev = jnp.asarray(evidence_values, jnp.int32)
        if ev.ndim == 1:
            ev = jnp.broadcast_to(ev[None], (x.shape[0], len(prog.observed)))
        return x.at[:, jnp.asarray(prog.observed, jnp.int32)].set(ev)

    def assignment_energy(self, model, assignment) -> float:
        """Factor-graph energy (unary + each edge's directed table once)
        of a full assignment over every node — the MAP objective; for an
        Ising model this is the Hamiltonian up to its constant."""
        fg = (model.to_factor_graph()
              if isinstance(model, IsingModel) else model)
        x = np.array([int(assignment[v]) for v in range(fg.n_vars)])
        e = float(np.asarray(fg.unary)[np.arange(fg.n_vars), x].sum())
        if len(fg.edges):
            a, b = fg.edges[:, 0], fg.edges[:, 1]
            e += float(np.asarray(fg.pair)[
                np.arange(len(fg.edges)), x[a], x[b]].sum())
        return e

    def state_spec(self, mesh):
        return serve_fg_state_spec(mesh)

    def n_vars(self, prog) -> int:
        return prog.n_vars

    def max_card(self, prog) -> int:
        return prog.max_card

    def var_card(self, prog, v: int) -> int:
        return int(prog.fg.card[v])

    def var_name(self, model, v: int) -> str:
        return model.var_name(v)

    def n_free(self, prog) -> int:
        return prog.n_free

    def plan_salt(self, model):
        """Sparse plans are shaped by the graph itself (coloring, degree
        buckets), so the cache key folds a content fingerprint — a
        re-registered graph under the same name must miss.  Cached on
        the model object: hashing a million-spin graph once is fine,
        once per query is not."""
        salt = getattr(model, "_plan_salt", None)
        if salt is None:
            salt = graph_fingerprint(model)
            model._plan_salt = salt
        return salt

    # -- plan persistence: packing plans is cheap numpy, nothing to skip
    def persisted_path(self, directory, name, pattern, model, *,
                       k, quantize_cpt_bits):
        return None

    def load_persisted(self, path, model):  # pragma: no cover - unused
        return None

    def save_persisted(self, path, prog):  # pragma: no cover - unused
        pass


BAYESNET_FAMILY = BayesNetFamily()
MRF_FAMILY = MrfFamily()
ISING_FAMILY = IsingFamily()


def family_of(model):
    """The adapter serving a registered model — or a request.

    Dispatches on the model's type, or, for a :class:`repro.serve.query.
    Request`, on the *evidence payload*: a scribble mask
    (:class:`MrfQuery`) routes to the MRF family, a spin clamp
    (:class:`IsingQuery`) to the sparse Ising family, and a node-
    evidence mapping (:class:`Query`) to the Bayesian-network family —
    the same convention the JSON request-file parser uses.

    Example::

        family_of(networks.asia()).kind          # 'bayesnet'
        family_of(networks.penguin_task(8, 8)[0]).kind   # 'mrf'
        family_of(networks.ising_torus(8)).kind          # 'ising'
        family_of(MrfQuery("penguin")).kind              # 'mrf'
    """
    if isinstance(model, BayesNet):
        return BAYESNET_FAMILY
    if isinstance(model, MRFGrid):
        return MRF_FAMILY
    if isinstance(model, (IsingModel, FactorGraph)):
        return ISING_FAMILY
    from repro.serve.query import IsingQuery, MrfQuery, Query
    if isinstance(model, MrfQuery):
        return MRF_FAMILY
    if isinstance(model, IsingQuery):
        return ISING_FAMILY
    if isinstance(model, Query):
        return BAYESNET_FAMILY
    raise TypeError(
        f"no serving family for {type(model).__name__!r} "
        f"(expected BayesNet, MRFGrid, IsingModel, FactorGraph, or a "
        f"Query/MrfQuery/IsingQuery request)")
