"""Stdlib client for the serving front end (tests, bench, CLI).

:class:`ServeClient` speaks the strict v2 wire schema to a
:class:`repro.serve.server.ServeFrontEnd` over plain ``http.client``
plus a minimal RFC 6455 WebSocket (raw socket) for ``/v2/stream`` —
the replay side of ``--stream`` traffic and the service smoke job in
CI.  Responses come back as plain JSON dicts; :class:`ServeHTTPError`
carries shed/validation error bodies (status, ``Retry-After``).
"""
from __future__ import annotations

import base64
import http.client
import json
import os
import select
import socket
import struct
import time

from repro.serve.protocol import request_to_wire

__all__ = ["ServeClient", "ServeHTTPError"]


class ServeHTTPError(RuntimeError):
    """Non-2xx response; carries the status, parsed error body, and the
    ``Retry-After`` hint (seconds, None if absent)."""

    def __init__(self, status: int, body: dict,
                 retry_after: float | None = None):
        super().__init__(
            f"HTTP {status}: {body.get('error', body)}")
        self.status = int(status)
        self.body = body
        self.retry_after = retry_after


class ServeClient:
    """Synchronous client; one instance per thread.

    ``query``/``query_batch`` accept either wire dicts or
    :class:`repro.serve.query.Request` objects (encoded via
    :func:`repro.serve.protocol.request_to_wire`).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 8080, *,
                 timeout: float = 300.0):
        self.host, self.port = host, int(port)
        self.timeout = timeout

    # -- plumbing ----------------------------------------------------------
    def _request(self, method: str, path: str, obj=None):
        conn = http.client.HTTPConnection(
            self.host, self.port, timeout=self.timeout)
        try:
            body = None if obj is None else json.dumps(obj)
            conn.request(method, path, body=body,
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            raw = resp.read()
            if resp.getheader("Content-Type", "").startswith(
                    "application/json"):
                payload = json.loads(raw.decode())
            else:
                payload = raw.decode()
            if resp.status >= 400:
                ra = resp.getheader("Retry-After")
                raise ServeHTTPError(
                    resp.status,
                    payload if isinstance(payload, dict)
                    else {"error": payload},
                    retry_after=None if ra is None else float(ra))
            return payload
        finally:
            conn.close()

    @staticmethod
    def _wire(req) -> dict:
        return req if isinstance(req, dict) else request_to_wire(req)

    def wait_ready(self, timeout: float = 60.0) -> dict:
        """Poll ``/healthz`` until the server answers (connection
        retries swallowed) — the startup handshake for subprocess
        servers in CI."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                return self.healthz()
            except (ConnectionError, OSError, ServeHTTPError):
                if time.monotonic() >= deadline:
                    raise
                time.sleep(0.1)

    # -- endpoints ---------------------------------------------------------
    def query(self, req) -> dict:
        return self._request("POST", "/v2/query", self._wire(req))

    def query_batch(self, reqs) -> list[dict]:
        out = self._request("POST", "/v2/batch", {
            "v": 2, "requests": [self._wire(r) for r in reqs]})
        return out["results"]

    def flush(self) -> dict:
        return self._request("POST", "/v2/flush", {})

    def healthz(self) -> dict:
        return self._request("GET", "/healthz")

    def stats(self) -> dict:
        return self._request("GET", "/stats")

    def metrics(self) -> str:
        return self._request("GET", "/metrics")

    # -- WebSocket streaming ----------------------------------------------
    def stream(self, reqs, arrivals=None, *,
               timeout: float | None = None) -> list[dict]:
        """Replay ``reqs`` over one ``/v2/stream`` WebSocket — open-loop
        at ``arrivals`` offsets (seconds, monotone) when given, as fast
        as possible otherwise — then collect every response.  Requests
        are tagged with sequential ``"id"``s; the returned list is in
        *request* order (responses arrive in completion order and are
        re-sorted by id)."""
        wires = [dict(self._wire(r)) for r in reqs]
        for i, w in enumerate(wires):
            w.setdefault("id", i)
        sock = socket.create_connection(
            (self.host, self.port), timeout=timeout or self.timeout)
        try:
            self._ws_handshake(sock)
            responses: dict[object, dict] = {}
            t0 = time.monotonic()
            for i, w in enumerate(wires):
                if arrivals is not None:
                    delay = t0 + arrivals[i] - time.monotonic()
                    while delay > 0:
                        # drain early completions while we wait
                        got = self._ws_poll(sock, min(delay, 0.05))
                        if got is not None:
                            responses[got.get("id")] = got
                        delay = t0 + arrivals[i] - time.monotonic()
                self._ws_send(sock, json.dumps(w).encode())
            while len(responses) < len(wires):
                got = self._ws_recv_json(sock)
                if got is None:
                    raise ConnectionError(
                        f"stream closed with {len(wires) - len(responses)}"
                        " responses outstanding")
                responses[got.get("id")] = got
            self._ws_send(sock, b"", opcode=0x8)
            return [responses[w["id"]] for w in wires]
        finally:
            sock.close()

    def _ws_handshake(self, sock) -> None:
        key = base64.b64encode(os.urandom(16)).decode()
        sock.sendall((
            f"GET /v2/stream HTTP/1.1\r\nHost: {self.host}:{self.port}\r\n"
            "Upgrade: websocket\r\nConnection: Upgrade\r\n"
            f"Sec-WebSocket-Key: {key}\r\n"
            "Sec-WebSocket-Version: 13\r\n\r\n").encode())
        buf = b""
        while b"\r\n\r\n" not in buf:
            chunk = sock.recv(4096)
            if not chunk:
                raise ConnectionError("server closed during WS handshake")
            buf += chunk
        status = buf.split(b"\r\n", 1)[0]
        if b"101" not in status:
            raise ConnectionError(f"WS handshake refused: {status!r}")

    @staticmethod
    def _ws_send(sock, payload: bytes, *, opcode: int = 0x1) -> None:
        # client->server frames must be masked (RFC 6455 §5.1)
        mask = os.urandom(4)
        head = bytes([0x80 | opcode])
        n = len(payload)
        if n < 126:
            head += bytes([0x80 | n])
        elif n < (1 << 16):
            head += bytes([0x80 | 126]) + struct.pack(">H", n)
        else:
            head += bytes([0x80 | 127]) + struct.pack(">Q", n)
        body = bytes(c ^ mask[i % 4] for i, c in enumerate(payload))
        sock.sendall(head + mask + body)

    @staticmethod
    def _read_exact(sock, n: int) -> bytes:
        buf = b""
        while len(buf) < n:
            chunk = sock.recv(n - len(buf))
            if not chunk:
                raise ConnectionError("server closed mid-frame")
            buf += chunk
        return buf

    def _ws_recv_json(self, sock) -> dict | None:
        """One server message as JSON; None on close frame."""
        message = b""
        while True:
            b0, b1 = self._read_exact(sock, 2)
            opcode, fin = b0 & 0x0F, b0 & 0x80
            length = b1 & 0x7F
            if length == 126:
                (length,) = struct.unpack(">H", self._read_exact(sock, 2))
            elif length == 127:
                (length,) = struct.unpack(">Q", self._read_exact(sock, 8))
            payload = self._read_exact(sock, length)
            if opcode == 0x8:
                return None
            if opcode in (0x9, 0xA):       # ping/pong — ignore
                continue
            message += payload
            if fin:
                return json.loads(message.decode())

    def _ws_poll(self, sock, timeout: float) -> dict | None:
        """A response if one arrives within ``timeout``, else None.
        Readability is tested with ``select`` so an empty wait never
        leaves the stream desynced mid-frame."""
        readable, _, _ = select.select([sock], [], [], max(timeout, 0.0))
        if not readable:
            return None
        return self._ws_recv_json(sock)
