"""Micro-batching posterior engine: packs queries onto chain lanes.

The serving analogue of AIA's core scheduler (paper §III): queries that
share a model and an evidence *pattern* are compatible — they run the
same compiled sweep program — so the engine packs them side by side
along the chain (batch) axis of one jitted sweep, each query owning
``chains_per_query`` lanes initialized with *its* evidence values.  One
XLA dispatch then advances every query in the group.

Both PGM families ride the same lifecycle: Bayesian networks clamp
evidence *nodes* (pattern = observed node ids), MRF grids clamp
evidence *pixels* (pattern = flat clamped-site indices from a scribble
mask) — the per-family surface lives in :mod:`repro.serve.families`,
and the engine only ever sees flat variable ids.

Sampling proceeds in rounds of ``sweeps_per_round`` sweeps.  After the
burn-in rounds, each round accumulates thinned one-hot counts per lane
(the online marginal estimate) and a per-lane mean state (the scalar
statistic for convergence).  Convergence is judged *per query*: after
every round each query's own chains get a split-R̂, and a query retires
— its Result finalized — the moment its chains converge, independent of
its group mates.  Budget left over is simply not spent, which is where
the paper's "approximate inference" throughput comes from; a retired
query's lane block is also free real estate that :class:`GroupRun.admit`
can hand to a waiting query of the same plan mid-flight (how the
admission queue in :mod:`repro.serve.queue` backfills under streaming
traffic).

Multi-device serving: give the engine a mesh from
``repro.launch.mesh.make_serve_mesh`` and each group's lane axis
``(n_queries * chains_per_query, n_nodes)`` is sharded over the mesh's
"batch" axis (the multicore analogue of the paper's 16 cores on one
chip: one XLA dispatch advances every device's slice of the lanes).
The flat log-CPT bank is replicated per device — or sharded over a 2D
mesh's "model" axis for very large networks — so the ``_color_update``
gathers stay local (``repro.sharding.specs``).  Lane counts are padded
up to a mesh multiple with throwaway replicas of the first query;
plans/runners are cached per (pattern, mesh fingerprint) so single- and
multi-device programs never collide.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.fixedpoint import DEFAULT_K
from repro.launch.mesh import mesh_fingerprint
from repro.pgm.compile import sum_sweep_stats
from repro.pgm.graph import BayesNet
from repro.serve.families import family_of
from repro.serve.plan_cache import PlanCache, plan_key
from repro.serve.query import MrfQuery, Query, Result
from repro.sharding.specs import serve_lane_multiple


def split_rhat(draws: np.ndarray) -> float:
    """Split-R̂ of per-chain draw sequences (chains, rounds).

    Each chain's sequence is split in half (dropping the odd round, if
    any) and the halves treated as separate chains — the standard
    Gelman–Rubin split variant.  Returns 1.0 for degenerate (constant)
    statistics, inf when between-chain variance dominates a vanishing
    within-chain variance.
    """
    draws = np.asarray(draws, np.float64)
    c, r = draws.shape
    half = r // 2
    if c < 2 or half < 2:
        return float("inf")  # not enough draws to judge — keep sampling
    seqs = np.concatenate([draws[:, :half], draws[:, half:2 * half]], axis=0)
    w = float(seqs.var(axis=1, ddof=1).mean())
    b = float(half * seqs.mean(axis=1).var(ddof=1))
    if w < 1e-12:
        return 1.0 if b < 1e-12 else float("inf")
    var_plus = (half - 1) / half * w + b / half
    return float(np.sqrt(var_plus / w))


@dataclass
class GroupEntry:
    """One normalized query inside a (network, pattern) group.

    ``ev`` maps flat variable ids (BN nodes / MRF sites) to observed
    values; ``qvars`` are flat variable ids to report.  ``handle`` is
    the admission queue's :class:`repro.serve.query.QueryHandle` when
    the entry arrived via streaming submission, None for the synchronous
    ``answer_batch`` path.  ``result`` is filled in at retirement.
    """

    query: "Query | MrfQuery"
    ev: dict[int, int]
    qvars: tuple[int, ...]
    handle: object | None = None
    result: Result | None = None


@dataclass
class _Slot:
    """Bookkeeping of one lane block [j*c, (j+1)*c) of a running group.

    ``entry`` is None for a *vacant* slot: a lane block that exists only
    because the group's slot count was padded up to a shape bucket.  A
    vacant slot is born ``done`` — it samples throwaway replicas of
    query 0 until :meth:`GroupRun.admit` backfills it.
    """

    entry: GroupEntry | None
    j: int                      # slot index (lane block)
    cap: int                    # retirement round cap (budget/max_rounds)
    burn_left: int              # burn-in rounds still owed by this slot
    t0: float                   # admission wall-clock (perf_counter)
    rounds: int = 0             # post-burn-in rounds accumulated
    counts: np.ndarray | None = None       # (n, L) int64, lane-summed
    means: np.ndarray | None = None        # (c, n, cap) R̂ statistics
    rhat: float = float("inf")
    done: bool = False
    cancelled: bool = False


class GroupRun:
    """Incremental run of one plan-compatible micro-batched group.

    Owns the device state of a group and advances it one round per
    :meth:`step` call, retiring queries individually as they converge or
    exhaust their budget.  ``answer_batch`` drives the same lifecycle to
    completion synchronously, so the admission queue's streamed dispatch
    is numerically identical to a synchronous ``answer_batch`` over the
    same groups (same PRNG stream, same draws).

    A retired slot's lane block can be handed to a *new* query of the
    same plan via :meth:`admit`: its lanes are re-initialized with the
    newcomer's evidence, it burns in privately (its counts/means are
    discarded host-side for ``burn_rounds`` rounds), then counts on its
    own thinning phase via the runner's per-lane ``offset``.
    """

    def __init__(self, engine: "PosteriorEngine", name: str,
                 pattern: tuple[int, ...], entries: list[GroupEntry]):
        if not entries:
            raise ValueError("empty group")
        t0 = time.perf_counter()
        self.engine = engine
        self.name, self.pattern = name, pattern
        self.prog, self.runner, self.cache_hit = engine._plan(name, pattern)
        self.model = engine._network(name)
        self.family = family_of(self.model)
        self.c = engine.chains_per_query
        self.spr = engine.sweeps_per_round
        self.burn_rounds = math.ceil(engine.burn_in / self.spr)
        self.n_free = self.family.n_free(self.prog)
        self.n_vars = self.family.n_vars(self.prog)
        nq = len(entries)
        # shape bucketing: pad the slot count up to a power of two so
        # streaming traffic only ever compiles O(log max_group) distinct
        # lane shapes instead of one per group size (XLA re-jits per
        # shape; a compile storm would eat the micro-batching win).  Pad
        # blocks are *vacant slots* — free real estate for ``admit``.
        shape_q = 1 << (nq - 1).bit_length() if engine.pow2_group_shapes else nq
        b = shape_q * self.c
        # mesh path: additionally pad the lane axis to a batch-shard
        # multiple; pad lanes replicate query 0 and are sliced off every
        # host read.
        self.bt = b + (-b) % serve_lane_multiple(engine.mesh)

        ev_vals = np.zeros((self.bt, len(pattern)), np.int32)
        for j, e in enumerate(entries):
            ev_vals[j * self.c:(j + 1) * self.c] = [e.ev[v] for v in pattern]
        ev_vals[nq * self.c:] = ev_vals[:1]
        engine._key, init_key, self._run_key = jax.random.split(engine._key, 3)
        x = self.family.init_states(init_key, self.prog, self.bt,
                                    jnp.asarray(ev_vals) if pattern else None)
        if engine.mesh is not None:
            x = jax.device_put(x, NamedSharding(
                engine.mesh, self.family.state_spec(engine.mesh)))
        self.x = x
        self.slots = [self._fresh_slot(e, j, t0) for j, e in enumerate(entries)]
        self.slots += [
            _Slot(entry=None, j=j, cap=0, burn_left=0, t0=t0, done=True)
            for j in range(nq, self.bt // self.c)
        ]
        self.bits = 0         # cumulative random bits, incl. burn-in (int64)
        self.sweeps_done = 0  # group sweeps so far, incl. burn-in

    def _fresh_slot(self, entry: GroupEntry, j: int, t0: float) -> _Slot:
        cap = self._cap(entry.query)
        L = self.family.max_card(self.prog)
        return _Slot(
            entry=entry, j=j, cap=cap, burn_left=self.burn_rounds, t0=t0,
            counts=np.zeros((self.n_vars, L), np.int64),
            means=np.empty((self.c, self.n_vars, cap), np.float32))

    def _cap(self, q: Query) -> int:
        """Smallest round count whose kept-draw total (global multiples
        of ``thin`` in [0, rounds*spr), times c lanes) covers the
        query's budget, clamped to [min_rounds, max_rounds]."""
        eng = self.engine
        kept_needed = max(1, math.ceil(q.n_samples / self.c))
        budget_rounds = math.ceil(((kept_needed - 1) * eng.thin + 1) / self.spr)
        return min(max(budget_rounds, eng.min_rounds), eng.max_rounds)

    # -- lifecycle ---------------------------------------------------------
    @property
    def active(self) -> bool:
        return any(not s.done for s in self.slots)

    def free_slots(self) -> int:
        return sum(s.done for s in self.slots)

    def step(self) -> list[GroupEntry]:
        """Advance the whole group one round; returns entries that
        retired this round (their ``result`` is filled in, or left None
        if cancelled)."""
        eng = self.engine
        offsets = np.zeros(self.bt, np.int32)
        for s in self.slots:
            if not s.done and not s.burn_left:
                offsets[s.j * self.c:(s.j + 1) * self.c] = s.rounds * self.spr
        self._run_key, sub = jax.random.split(self._run_key)
        self.x, rc, xmean, st = self.runner(sub, self.x, jnp.asarray(offsets))
        self.bits += int(sum_sweep_stats(st).bits_used)
        self.sweeps_done += self.spr

        rc_np = xmean_np = None  # host transfer only if a slot counts
        retired: list[GroupEntry] = []
        for s in self.slots:
            if s.done:
                continue
            if s.burn_left:
                s.burn_left -= 1
                continue
            if rc_np is None:
                rc_np = np.asarray(rc, np.int64)
                xmean_np = np.asarray(xmean)
            sl = slice(s.j * self.c, (s.j + 1) * self.c)
            s.counts += rc_np[sl].sum(axis=0)
            s.means[..., s.rounds] = xmean_np[sl]
            s.rounds += 1
            if s.rounds >= eng.min_rounds:
                s.rhat = max(
                    split_rhat(s.means[:, v, :s.rounds])
                    for v in s.entry.qvars)
            if ((s.rounds >= eng.min_rounds and s.rhat < eng.rhat_target)
                    or s.rounds >= s.cap):
                self._retire(s)
                retired.append(s.entry)
        return retired

    def run_to_completion(self) -> None:
        while self.active:
            self.step()

    def cancel(self, entry: GroupEntry) -> bool:
        """Mid-flight cancellation: free the entry's slot without a
        result.  Returns False if the entry already retired."""
        for s in self.slots:
            if s.entry is entry and not s.done:
                s.done = s.cancelled = True
                return True
        return False

    def admit(self, entry: GroupEntry) -> None:
        """Backfill a waiting query of the same plan into a freed slot:
        re-initialize its lane block with the newcomer's evidence and
        give it a private burn-in before it starts counting."""
        slot = next((s for s in self.slots if s.done), None)
        if slot is None:
            raise RuntimeError("no free slot to admit into")
        c = self.c
        ev = None
        if self.pattern:
            ev = jnp.asarray(np.tile(
                np.array([entry.ev[v] for v in self.pattern], np.int32),
                (c, 1)))
        self.engine._key, init_key = jax.random.split(self.engine._key)
        x0 = self.family.init_states(init_key, self.prog, c, ev)
        self.x = self.x.at[slot.j * c:(slot.j + 1) * c].set(x0)
        self.slots[slot.j] = self._fresh_slot(
            entry, slot.j, time.perf_counter())

    def _retire(self, s: _Slot) -> None:
        s.done = True
        eng, fam = self.engine, self.family
        marginals = {}
        for v in s.entry.qvars:
            m = s.counts[v, :fam.var_card(self.prog, v)].astype(np.float64)
            marginals[fam.var_name(self.model, v)] = m / max(m.sum(), 1.0)
        # kept draws per lane: global sweep indices in [0, rounds*spr)
        # that are multiples of ``thin``
        kept_total = (s.rounds * self.spr + eng.thin - 1) // eng.thin
        total_sweeps = (self.burn_rounds + s.rounds) * self.spr
        group_node_samples = self.bt * self.n_free * self.sweeps_done
        s.entry.result = Result(
            query=s.entry.query,
            marginals=marginals,
            n_samples=int(self.c * kept_total),
            n_sweeps=total_sweeps,
            n_node_samples=int(self.c * self.n_free * total_sweeps),
            rhat=float(s.rhat),
            converged=bool(s.rhat < eng.rhat_target),
            cache_hit=self.cache_hit,
            wall_s=time.perf_counter() - s.t0,
            bits_per_sample=(
                self.bits / group_node_samples if group_node_samples else 0.0),
        )


class PosteriorEngine:
    """Answers batches of posterior queries over registered networks.

    Parameters mirror a serving config: ``chains_per_query`` lanes per
    query, ``sweeps_per_round`` sweeps per scheduling quantum, burn-in
    and thinning in sweeps, and a split-R̂ target for early stopping.
    ``mesh`` (from :func:`repro.launch.mesh.make_serve_mesh`) shards each
    group's chain-lane axis over the mesh's "batch" axis; ``None`` keeps
    the single-device path.  ``plan_cache_dir`` persists compiled plans
    (the ColorPlan tensors, not the jitted HLO) as ``.npz`` files so warm
    process starts skip the compiler chain.  ``pow2_group_shapes`` pads
    each group's slot count to a power of two — streaming traffic then
    compiles O(log max-group) distinct lane shapes instead of one per
    observed group size, and the pad blocks double as backfill targets.
    """

    def __init__(
        self,
        networks: "Mapping[str, BayesNet | object] | None" = None,
        *,
        chains_per_query: int = 32,
        sweeps_per_round: int = 16,
        burn_in: int = 64,
        thin: int = 1,
        rhat_target: float = 1.05,
        min_rounds: int = 4,
        max_rounds: int = 64,
        k: int = DEFAULT_K,
        use_iu: bool = True,
        quantize_cpt_bits: int | None = 16,
        cache: PlanCache | None = None,
        mesh=None,
        plan_cache_dir: str | None = None,
        pow2_group_shapes: bool = True,
        seed: int = 0,
    ):
        # "networks" kept for API continuity; values may be any model a
        # family adapter exists for (BayesNet, MRFGrid)
        self.networks: dict[str, object] = dict(networks or {})
        self.chains_per_query = int(chains_per_query)
        self.sweeps_per_round = int(sweeps_per_round)
        self.burn_in = int(burn_in)
        self.thin = int(thin)
        self.rhat_target = float(rhat_target)
        self.min_rounds = max(int(min_rounds), 4)  # split-R̂ needs >= 4
        self.max_rounds = int(max_rounds)
        self.k = k
        self.use_iu = use_iu
        self.quantize_cpt_bits = quantize_cpt_bits
        self.cache = cache if cache is not None else PlanCache()
        self.mesh = mesh
        self.plan_cache_dir = plan_cache_dir
        self.pow2_group_shapes = bool(pow2_group_shapes)
        self._key = jax.random.PRNGKey(seed)

    # -- registry ----------------------------------------------------------
    def register(self, name: str, model) -> None:
        """Register (or replace) a model (BayesNet or MRFGrid).
        Replacing drops the name's cached plans — they were compiled
        from the old model's parameters."""
        if self.networks.get(name) is not model:
            self.cache.invalidate(lambda key: key[0] == name)
        self.networks[name] = model

    def _network(self, name: str):
        try:
            return self.networks[name]
        except KeyError:
            raise KeyError(
                f"network {name!r} not registered "
                f"(have: {sorted(self.networks)})") from None

    # -- plan lookup -------------------------------------------------------
    def _plan_key(self, name: str, pattern: tuple[int, ...]) -> tuple:
        return plan_key(
            name, pattern, k=self.k, use_iu=self.use_iu,
            quantize_cpt_bits=self.quantize_cpt_bits,
            sweeps_per_round=self.sweeps_per_round, thin=self.thin,
            mesh_fingerprint=mesh_fingerprint(self.mesh))

    def _plan(self, name: str, pattern: tuple[int, ...]):
        """(compiled program, round_runner, was_cache_hit) for one
        (model, pattern); the program/runner builders come from the
        model's family adapter."""

        def build():
            model = self._network(name)
            fam = family_of(model)
            prog = None
            path = None
            if self.plan_cache_dir is not None:
                path = fam.persisted_path(
                    self.plan_cache_dir, name, pattern, model, k=self.k,
                    quantize_cpt_bits=self.quantize_cpt_bits)
            if path is not None:
                prog = fam.load_persisted(path, model)
            if prog is None:
                prog = fam.compile(
                    model, pattern, k=self.k,
                    quantize_cpt_bits=self.quantize_cpt_bits)
                if path is not None:
                    fam.save_persisted(path, prog)
            runner = fam.make_runner(
                prog, sweeps_per_round=self.sweeps_per_round,
                thin=self.thin, use_iu=self.use_iu, mesh=self.mesh)
            return prog, runner

        (prog, runner), hit = self.cache.get(
            self._plan_key(name, pattern), build)
        return prog, runner, hit

    # -- serving -----------------------------------------------------------
    def normalize(self, query: "Query | MrfQuery"):
        """Resolve a query against its model: ``(model, evidence-by-flat-
        id, query-var ids, evidence pattern)``.  Raises on unknown
        models, bad evidence, or query vars that are observed — the
        admission queue calls this at submit time so bad requests fail
        fast."""
        model = self._network(query.network)
        ev, qvars, pattern = family_of(model).normalize(model, query)
        return model, ev, qvars, pattern

    def answer(self, query: "Query | MrfQuery") -> Result:
        return self.answer_batch([query])[0]

    def answer_batch(self, queries: "list[Query | MrfQuery]") -> list[Result]:
        """Answer a batch; compatible queries share one jitted sweep."""
        groups: dict[tuple, list[GroupEntry]] = {}
        entries = []
        for q in queries:
            _, ev, qvars, pattern = self.normalize(q)
            e = GroupEntry(q, ev, qvars)
            entries.append(e)
            groups.setdefault((q.network, pattern), []).append(e)
        for (name, pattern), group in groups.items():
            GroupRun(self, name, pattern, group).run_to_completion()
        return [e.result for e in entries]  # type: ignore[return-value]
