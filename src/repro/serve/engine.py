"""Micro-batching posterior engine: packs queries onto chain lanes.

The serving analogue of AIA's core scheduler (paper §III): queries that
share a network and an evidence *pattern* are compatible — they run the
same compiled sweep program — so the engine packs them side by side
along the chain (batch) axis of one jitted sweep, each query owning
``chains_per_query`` lanes initialized with *its* evidence values.  One
XLA dispatch then advances every query in the group.

Sampling proceeds in rounds of ``sweeps_per_round`` sweeps.  After the
burn-in rounds, each round accumulates thinned one-hot counts per lane
(the online marginal estimate) and a per-lane mean state (the scalar
statistic for convergence).  After every round the engine computes the
split-R̂ of each query's chains and retires queries early once all of a
group's queries converge — budget left over is simply not spent, which
is where the paper's "approximate inference" throughput comes from.

Multi-device serving: give the engine a mesh from
``repro.launch.mesh.make_serve_mesh`` and each group's lane axis
``(n_queries * chains_per_query, n_nodes)`` is sharded over the mesh's
"batch" axis (the multicore analogue of the paper's 16 cores on one
chip: one XLA dispatch advances every device's slice of the lanes).
The flat log-CPT bank is replicated per device — or sharded over a 2D
mesh's "model" axis for very large networks — so the ``_color_update``
gathers stay local (``repro.sharding.specs``).  Lane counts are padded
up to a mesh multiple with throwaway replicas of the first query;
plans/runners are cached per (pattern, mesh fingerprint) so single- and
multi-device programs never collide.
"""
from __future__ import annotations

import math
import time
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.fixedpoint import DEFAULT_K
from repro.launch.mesh import mesh_fingerprint
from repro.pgm.compile import (
    BNSweepStats, CompiledBN, _color_update, compile_bayesnet, init_states)
from repro.pgm.graph import BayesNet
from repro.serve.plan_cache import PlanCache, plan_key
from repro.serve.query import Query, Result
from repro.sharding.specs import (
    serve_cpt_spec, serve_lane_multiple, serve_state_spec)


def split_rhat(draws: np.ndarray) -> float:
    """Split-R̂ of per-chain draw sequences (chains, rounds).

    Each chain's sequence is split in half (dropping the odd round, if
    any) and the halves treated as separate chains — the standard
    Gelman–Rubin split variant.  Returns 1.0 for degenerate (constant)
    statistics, inf when between-chain variance dominates a vanishing
    within-chain variance.
    """
    draws = np.asarray(draws, np.float64)
    c, r = draws.shape
    half = r // 2
    if c < 2 or half < 2:
        return float("inf")  # not enough draws to judge — keep sampling
    seqs = np.concatenate([draws[:, :half], draws[:, half:2 * half]], axis=0)
    w = float(seqs.var(axis=1, ddof=1).mean())
    b = float(half * seqs.mean(axis=1).var(ddof=1))
    if w < 1e-12:
        return 1.0 if b < 1e-12 else float("inf")
    var_plus = (half - 1) / half * w + b / half
    return float(np.sqrt(var_plus / w))


def make_round_runner(prog: CompiledBN, *, sweeps_per_round: int, thin: int,
                      use_iu: bool, mesh=None):
    """Jitted ``(key, x, offset) -> (x, counts, xmean, stats)`` per round.

    ``offset`` (traced int32 scalar) is the global post-burn-in sweep
    index of the round's first sweep: draws are kept where the *global*
    index is a multiple of ``thin``.  A round-relative ``i % thin`` would
    restart the phase every round, so for ``sweeps_per_round % thin != 0``
    the kept-draw spacing (and every downstream sample count) drifted.

    ``counts``: (B, n, L) thinned one-hot draw counts this round.
    ``xmean``:  (B, n) mean state over the round — per-lane scalar
    statistics for split-R̂ (for a binary node this is its running
    posterior-probability estimate).
    ``stats``:  per-sweep (sweeps_per_round,) int32 arrays — summed
    host-side in int64 by the engine (int32 carries wrapped on long
    runs; see :class:`repro.pgm.compile.BNSweepStats`).

    With ``mesh`` the lane (batch) axis of ``x``/``counts`` is held to a
    NamedSharding over the mesh's "batch" axis and the log-CPT bank is
    placed per ``serve_cpt_spec`` — one compile per (plan, mesh).
    """
    log_cpt = jnp.asarray(prog.log_cpt)
    state_sharding = None
    if mesh is not None:
        log_cpt = jax.device_put(
            log_cpt, NamedSharding(mesh, serve_cpt_spec(mesh, log_cpt.size)))
        state_sharding = NamedSharding(mesh, serve_state_spec(mesh))
    n, L = prog.bn.n_nodes, prog.max_card

    def round_fn(key: jax.Array, x: jax.Array, offset: jax.Array):
        if state_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, state_sharding)

        def body(carry, i):
            key, x, counts, xsum = carry
            key, sub = jax.random.split(key)
            bits, att = jnp.int32(0), jnp.int32(0)
            for plan in prog.plans:
                sub, s2 = jax.random.split(sub)
                x, st = _color_update(
                    s2, x, plan, log_cpt, L, prog.k, use_iu)
                bits, att = bits + st.bits_used, att + st.attempts
            onehot = (x[..., None] == jnp.arange(L)).astype(jnp.int32)
            counts = counts + jnp.where(((offset + i) % thin) == 0, onehot, 0)
            xsum = xsum + x.astype(jnp.float32)
            return (key, x, counts, xsum), BNSweepStats(bits, att)

        counts0 = jnp.zeros(x.shape + (L,), jnp.int32)
        xsum0 = jnp.zeros(x.shape, jnp.float32)
        (key, x, counts, xsum), per_sweep = jax.lax.scan(
            body, (key, x, counts0, xsum0), jnp.arange(sweeps_per_round))
        if state_sharding is not None:
            x = jax.lax.with_sharding_constraint(x, state_sharding)
        return x, counts, xsum / sweeps_per_round, per_sweep

    return jax.jit(round_fn)


class PosteriorEngine:
    """Answers batches of posterior queries over registered networks.

    Parameters mirror a serving config: ``chains_per_query`` lanes per
    query, ``sweeps_per_round`` sweeps per scheduling quantum, burn-in
    and thinning in sweeps, and a split-R̂ target for early stopping.
    ``mesh`` (from :func:`repro.launch.mesh.make_serve_mesh`) shards each
    group's chain-lane axis over the mesh's "batch" axis; ``None`` keeps
    the single-device path.
    """

    def __init__(
        self,
        networks: Mapping[str, BayesNet] | None = None,
        *,
        chains_per_query: int = 32,
        sweeps_per_round: int = 16,
        burn_in: int = 64,
        thin: int = 1,
        rhat_target: float = 1.05,
        min_rounds: int = 4,
        max_rounds: int = 64,
        k: int = DEFAULT_K,
        use_iu: bool = True,
        quantize_cpt_bits: int | None = 16,
        cache: PlanCache | None = None,
        mesh=None,
        seed: int = 0,
    ):
        self.networks: dict[str, BayesNet] = dict(networks or {})
        self.chains_per_query = int(chains_per_query)
        self.sweeps_per_round = int(sweeps_per_round)
        self.burn_in = int(burn_in)
        self.thin = int(thin)
        self.rhat_target = float(rhat_target)
        self.min_rounds = max(int(min_rounds), 4)  # split-R̂ needs >= 4
        self.max_rounds = int(max_rounds)
        self.k = k
        self.use_iu = use_iu
        self.quantize_cpt_bits = quantize_cpt_bits
        self.cache = cache if cache is not None else PlanCache()
        self.mesh = mesh
        self._key = jax.random.PRNGKey(seed)

    # -- registry ----------------------------------------------------------
    def register(self, name: str, bn: BayesNet) -> None:
        """Register (or replace) a network.  Replacing drops the name's
        cached plans — they were compiled from the old network's CPTs."""
        if self.networks.get(name) is not bn:
            self.cache.invalidate(lambda key: key[0] == name)
        self.networks[name] = bn

    def _network(self, name: str) -> BayesNet:
        try:
            return self.networks[name]
        except KeyError:
            raise KeyError(
                f"network {name!r} not registered "
                f"(have: {sorted(self.networks)})") from None

    # -- plan lookup -------------------------------------------------------
    def _plan_key(self, name: str, pattern: tuple[int, ...]) -> tuple:
        return plan_key(
            name, pattern, k=self.k, use_iu=self.use_iu,
            quantize_cpt_bits=self.quantize_cpt_bits,
            sweeps_per_round=self.sweeps_per_round, thin=self.thin,
            mesh_fingerprint=mesh_fingerprint(self.mesh))

    def _plan(self, name: str, pattern: tuple[int, ...]):
        """(CompiledBN, round_runner, was_cache_hit) for one pattern."""

        def build():
            prog = compile_bayesnet(
                self._network(name), k=self.k,
                quantize_cpt_bits=self.quantize_cpt_bits, observed=pattern)
            runner = make_round_runner(
                prog, sweeps_per_round=self.sweeps_per_round,
                thin=self.thin, use_iu=self.use_iu, mesh=self.mesh)
            return prog, runner

        (prog, runner), hit = self.cache.get(
            self._plan_key(name, pattern), build)
        return prog, runner, hit

    # -- serving -----------------------------------------------------------
    def answer(self, query: Query) -> Result:
        return self.answer_batch([query])[0]

    def answer_batch(self, queries: list[Query]) -> list[Result]:
        """Answer a batch; compatible queries share one jitted sweep."""
        groups: dict[tuple, list[int]] = {}
        normed = []
        for i, q in enumerate(queries):
            bn = self._network(q.network)
            ev = bn.normalize_evidence(q.evidence)
            qvars = tuple(bn.index(v) for v in q.query_vars) or tuple(
                v for v in range(bn.n_nodes) if v not in ev)
            clash = [bn.names[v] for v in qvars if v in ev]
            if clash:
                raise ValueError(f"query vars {clash} are observed")
            pattern = tuple(sorted(ev))
            normed.append((q, bn, ev, qvars))
            groups.setdefault((q.network, pattern), []).append(i)

        results: list[Result | None] = [None] * len(queries)
        for (name, pattern), idxs in groups.items():
            self._answer_group(name, pattern, idxs, normed, results)
        return results  # type: ignore[return-value]

    def _answer_group(self, name, pattern, idxs, normed, results) -> None:
        t0 = time.perf_counter()
        prog, runner, hit = self._plan(name, pattern)
        bn = self._network(name)
        c = self.chains_per_query
        spr = self.sweeps_per_round
        nq = len(idxs)
        b = nq * c
        # mesh path: pad the lane axis to a batch-shard multiple; pad
        # lanes replicate query 0 and are sliced off every host read.
        bt = b + (-b) % serve_lane_multiple(self.mesh)
        n_free = len(prog.free_nodes)

        # per-lane evidence values: query j owns lanes [j*c, (j+1)*c)
        ev_vals = np.zeros((bt, len(pattern)), np.int32)
        for j, i in enumerate(idxs):
            ev = normed[i][2]
            ev_vals[j * c:(j + 1) * c] = [ev[v] for v in pattern]
        ev_vals[b:] = ev_vals[:1]

        self._key, init_key, run_key = jax.random.split(self._key, 3)
        x = init_states(init_key, prog, bt,
                        jnp.asarray(ev_vals) if pattern else None)
        if self.mesh is not None:
            x = jax.device_put(x, NamedSharding(
                self.mesh, serve_state_spec(self.mesh)))

        burn_rounds = math.ceil(self.burn_in / spr)
        # smallest round count whose kept-draw total (global multiples of
        # ``thin`` in [0, rounds*spr), times c lanes) covers the budget
        kept_needed = max(
            math.ceil(normed[i][0].n_samples / c) for i in idxs)
        budget_rounds = math.ceil(((kept_needed - 1) * self.thin + 1) / spr)
        cap = min(max(budget_rounds, self.min_rounds), self.max_rounds)

        bits = 0
        for _ in range(burn_rounds):
            run_key, sub = jax.random.split(run_key)
            x, _, _, st = runner(sub, x, jnp.int32(0))
            # burn-in draws spend bits too; int64 host accumulation
            bits += int(np.asarray(st.bits_used, np.int64).sum())

        counts = np.zeros((b, bn.n_nodes, prog.max_card), np.int64)
        means = np.zeros((b, bn.n_nodes, cap), np.float32)  # R̂ statistics
        rounds_run = 0
        rhats = {i: float("inf") for i in idxs}
        while rounds_run < cap:
            run_key, sub = jax.random.split(run_key)
            x, rc, xmean, st = runner(sub, x, jnp.int32(rounds_run * spr))
            counts += np.asarray(rc, np.int64)[:b]
            means[..., rounds_run] = np.asarray(xmean)[:b]
            bits += int(np.asarray(st.bits_used, np.int64).sum())
            rounds_run += 1
            if rounds_run < self.min_rounds:
                continue
            for j, i in enumerate(idxs):
                qvars = normed[i][3]
                lanes = means[j * c:(j + 1) * c, :, :rounds_run]  # (C, n, r)
                rhats[i] = max(
                    split_rhat(lanes[:, v, :]) for v in qvars)
            if all(r < self.rhat_target for r in rhats.values()):
                break

        jax.block_until_ready(x)
        wall = time.perf_counter() - t0
        total_sweeps = (burn_rounds + rounds_run) * spr
        n_node_samples = bt * n_free * total_sweeps
        bps = bits / n_node_samples if n_node_samples else 0.0
        # kept draws per lane: global sweep indices in [0, rounds*spr)
        # that are multiples of ``thin``
        kept_total = (rounds_run * spr + self.thin - 1) // self.thin

        for j, i in enumerate(idxs):
            q, _, _, qvars = normed[i]
            qc = counts[j * c:(j + 1) * c].sum(axis=0)   # (n, L)
            marginals = {}
            for v in qvars:
                m = qc[v, :bn.card[v]].astype(np.float64)
                marginals[bn.names[v]] = m / max(m.sum(), 1.0)
            results[i] = Result(
                query=q,
                marginals=marginals,
                n_samples=int(c * kept_total),
                n_sweeps=total_sweeps,
                n_node_samples=int(c * n_free * total_sweeps),
                rhat=float(rhats[i]),
                converged=bool(rhats[i] < self.rhat_target),
                cache_hit=hit,
                wall_s=wall,
                bits_per_sample=bps,
            )
