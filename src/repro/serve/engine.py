"""Micro-batching posterior engine: packs queries onto chain lanes.

The serving analogue of AIA's core scheduler (paper §III): queries that
share a network and an evidence *pattern* are compatible — they run the
same compiled sweep program — so the engine packs them side by side
along the chain (batch) axis of one jitted sweep, each query owning
``chains_per_query`` lanes initialized with *its* evidence values.  One
XLA dispatch then advances every query in the group.

Sampling proceeds in rounds of ``sweeps_per_round`` sweeps.  After the
burn-in rounds, each round accumulates thinned one-hot counts per lane
(the online marginal estimate) and a per-lane mean state (the scalar
statistic for convergence).  After every round the engine computes the
split-R̂ of each query's chains and retires queries early once all of a
group's queries converge — budget left over is simply not spent, which
is where the paper's "approximate inference" throughput comes from.
"""
from __future__ import annotations

import math
import time
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import DEFAULT_K
from repro.pgm.compile import (
    BNSweepStats, CompiledBN, _color_update, compile_bayesnet, init_states)
from repro.pgm.graph import BayesNet
from repro.serve.plan_cache import PlanCache
from repro.serve.query import Query, Result


def split_rhat(draws: np.ndarray) -> float:
    """Split-R̂ of per-chain draw sequences (chains, rounds).

    Each chain's sequence is split in half (dropping the odd round, if
    any) and the halves treated as separate chains — the standard
    Gelman–Rubin split variant.  Returns 1.0 for degenerate (constant)
    statistics, inf when between-chain variance dominates a vanishing
    within-chain variance.
    """
    draws = np.asarray(draws, np.float64)
    c, r = draws.shape
    half = r // 2
    if c < 2 or half < 2:
        return float("inf")  # not enough draws to judge — keep sampling
    seqs = np.concatenate([draws[:, :half], draws[:, half:2 * half]], axis=0)
    w = float(seqs.var(axis=1, ddof=1).mean())
    b = float(half * seqs.mean(axis=1).var(ddof=1))
    if w < 1e-12:
        return 1.0 if b < 1e-12 else float("inf")
    var_plus = (half - 1) / half * w + b / half
    return float(np.sqrt(var_plus / w))


def make_round_runner(prog: CompiledBN, *, sweeps_per_round: int, thin: int,
                      use_iu: bool):
    """Jitted ``(key, x) -> (x, counts, xmean, stats)`` for one round.

    ``counts``: (B, n, L) thinned one-hot draw counts this round.
    ``xmean``:  (B, n) mean state over the round — per-lane scalar
    statistics for split-R̂ (for a binary node this is its running
    posterior-probability estimate).
    """
    log_cpt = jnp.asarray(prog.log_cpt)
    n, L = prog.bn.n_nodes, prog.max_card

    def round_fn(key: jax.Array, x: jax.Array):
        def body(carry, i):
            key, x, counts, xsum, bits, att = carry
            key, sub = jax.random.split(key)
            for plan in prog.plans:
                sub, s2 = jax.random.split(sub)
                x, st = _color_update(
                    s2, x, plan, log_cpt, L, prog.k, use_iu)
                bits, att = bits + st.bits_used, att + st.attempts
            onehot = (x[..., None] == jnp.arange(L)).astype(jnp.int32)
            counts = counts + jnp.where((i % thin) == 0, onehot, 0)
            xsum = xsum + x.astype(jnp.float32)
            return (key, x, counts, xsum, bits, att), None

        counts0 = jnp.zeros(x.shape + (L,), jnp.int32)
        xsum0 = jnp.zeros(x.shape, jnp.float32)
        (key, x, counts, xsum, bits, att), _ = jax.lax.scan(
            body, (key, x, counts0, xsum0, jnp.int32(0), jnp.int32(0)),
            jnp.arange(sweeps_per_round))
        return x, counts, xsum / sweeps_per_round, BNSweepStats(bits, att)

    return jax.jit(round_fn)


class PosteriorEngine:
    """Answers batches of posterior queries over registered networks.

    Parameters mirror a serving config: ``chains_per_query`` lanes per
    query, ``sweeps_per_round`` sweeps per scheduling quantum, burn-in
    and thinning in sweeps, and a split-R̂ target for early stopping.
    """

    def __init__(
        self,
        networks: Mapping[str, BayesNet] | None = None,
        *,
        chains_per_query: int = 32,
        sweeps_per_round: int = 16,
        burn_in: int = 64,
        thin: int = 1,
        rhat_target: float = 1.05,
        min_rounds: int = 4,
        max_rounds: int = 64,
        k: int = DEFAULT_K,
        use_iu: bool = True,
        quantize_cpt_bits: int | None = 16,
        cache: PlanCache | None = None,
        seed: int = 0,
    ):
        self.networks: dict[str, BayesNet] = dict(networks or {})
        self.chains_per_query = int(chains_per_query)
        self.sweeps_per_round = int(sweeps_per_round)
        self.burn_in = int(burn_in)
        self.thin = int(thin)
        self.rhat_target = float(rhat_target)
        self.min_rounds = max(int(min_rounds), 4)  # split-R̂ needs >= 4
        self.max_rounds = int(max_rounds)
        self.k = k
        self.use_iu = use_iu
        self.quantize_cpt_bits = quantize_cpt_bits
        self.cache = cache if cache is not None else PlanCache()
        self._key = jax.random.PRNGKey(seed)

    # -- registry ----------------------------------------------------------
    def register(self, name: str, bn: BayesNet) -> None:
        """Register (or replace) a network.  Replacing drops the name's
        cached plans — they were compiled from the old network's CPTs."""
        if self.networks.get(name) is not bn:
            self.cache.invalidate(lambda key: key[0] == name)
        self.networks[name] = bn

    def _network(self, name: str) -> BayesNet:
        try:
            return self.networks[name]
        except KeyError:
            raise KeyError(
                f"network {name!r} not registered "
                f"(have: {sorted(self.networks)})") from None

    # -- plan lookup -------------------------------------------------------
    def _plan(self, name: str, pattern: tuple[int, ...]):
        """(CompiledBN, round_runner, was_cache_hit) for one pattern."""
        key = (name, pattern, self.k, self.use_iu, self.quantize_cpt_bits,
               self.sweeps_per_round, self.thin)

        def build():
            prog = compile_bayesnet(
                self._network(name), k=self.k,
                quantize_cpt_bits=self.quantize_cpt_bits, observed=pattern)
            runner = make_round_runner(
                prog, sweeps_per_round=self.sweeps_per_round,
                thin=self.thin, use_iu=self.use_iu)
            return prog, runner

        (prog, runner), hit = self.cache.get(key, build)
        return prog, runner, hit

    # -- serving -----------------------------------------------------------
    def answer(self, query: Query) -> Result:
        return self.answer_batch([query])[0]

    def answer_batch(self, queries: list[Query]) -> list[Result]:
        """Answer a batch; compatible queries share one jitted sweep."""
        groups: dict[tuple, list[int]] = {}
        normed = []
        for i, q in enumerate(queries):
            bn = self._network(q.network)
            ev = bn.normalize_evidence(q.evidence)
            qvars = tuple(bn.index(v) for v in q.query_vars) or tuple(
                v for v in range(bn.n_nodes) if v not in ev)
            clash = [bn.names[v] for v in qvars if v in ev]
            if clash:
                raise ValueError(f"query vars {clash} are observed")
            pattern = tuple(sorted(ev))
            normed.append((q, bn, ev, qvars))
            groups.setdefault((q.network, pattern), []).append(i)

        results: list[Result | None] = [None] * len(queries)
        for (name, pattern), idxs in groups.items():
            self._answer_group(name, pattern, idxs, normed, results)
        return results  # type: ignore[return-value]

    def _answer_group(self, name, pattern, idxs, normed, results) -> None:
        t0 = time.perf_counter()
        prog, runner, hit = self._plan(name, pattern)
        bn = self._network(name)
        c = self.chains_per_query
        nq = len(idxs)
        b = nq * c
        n_free = len(prog.free_nodes)
        kept_per_round = math.ceil(self.sweeps_per_round / self.thin)

        # per-lane evidence values: query j owns lanes [j*c, (j+1)*c)
        ev_vals = np.zeros((b, len(pattern)), np.int32)
        for j, i in enumerate(idxs):
            ev = normed[i][2]
            ev_vals[j * c:(j + 1) * c] = [ev[v] for v in pattern]

        self._key, init_key, run_key = jax.random.split(self._key, 3)
        x = init_states(init_key, prog, b,
                        jnp.asarray(ev_vals) if pattern else None)

        burn_rounds = math.ceil(self.burn_in / self.sweeps_per_round)
        budget_rounds = max(
            math.ceil(normed[i][0].n_samples / (c * kept_per_round))
            for i in idxs)
        cap = min(max(budget_rounds, self.min_rounds), self.max_rounds)

        bits = 0
        for _ in range(burn_rounds):
            run_key, sub = jax.random.split(run_key)
            x, _, _, st = runner(sub, x)
            bits += int(st.bits_used)  # burn-in draws spend bits too

        counts = np.zeros((b, bn.n_nodes, prog.max_card), np.int64)
        means = np.zeros((b, bn.n_nodes, cap), np.float32)  # R̂ statistics
        rounds_run = 0
        rhats = {i: float("inf") for i in idxs}
        while rounds_run < cap:
            run_key, sub = jax.random.split(run_key)
            x, rc, xmean, st = runner(sub, x)
            counts += np.asarray(rc, np.int64)
            means[..., rounds_run] = np.asarray(xmean)
            bits += int(st.bits_used)
            rounds_run += 1
            if rounds_run < self.min_rounds:
                continue
            for j, i in enumerate(idxs):
                qvars = normed[i][3]
                lanes = means[j * c:(j + 1) * c, :, :rounds_run]  # (C, n, r)
                rhats[i] = max(
                    split_rhat(lanes[:, v, :]) for v in qvars)
            if all(r < self.rhat_target for r in rhats.values()):
                break

        jax.block_until_ready(x)
        wall = time.perf_counter() - t0
        total_sweeps = (burn_rounds + rounds_run) * self.sweeps_per_round
        n_node_samples = b * n_free * total_sweeps
        bps = bits / n_node_samples if n_node_samples else 0.0

        for j, i in enumerate(idxs):
            q, _, _, qvars = normed[i]
            qc = counts[j * c:(j + 1) * c].sum(axis=0)   # (n, L)
            marginals = {}
            for v in qvars:
                m = qc[v, :bn.card[v]].astype(np.float64)
                marginals[bn.names[v]] = m / max(m.sum(), 1.0)
            results[i] = Result(
                query=q,
                marginals=marginals,
                n_samples=int(c * kept_per_round * rounds_run),
                n_sweeps=total_sweeps,
                n_node_samples=int(c * n_free * total_sweeps),
                rhat=float(rhats[i]),
                converged=bool(rhats[i] < self.rhat_target),
                cache_hit=hit,
                wall_s=wall,
                bits_per_sample=bps,
            )
