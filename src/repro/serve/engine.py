"""Micro-batching posterior engine: packs queries onto chain lanes.

The serving analogue of AIA's core scheduler (paper §III): queries that
share a model and an evidence *pattern* are compatible — they run the
same compiled sweep program — so the engine packs them side by side
along the chain (batch) axis of one jitted sweep, each query owning
``chains_per_query`` lanes initialized with *its* evidence values.  One
XLA dispatch then advances every query in the group.

Both PGM families ride the same lifecycle: Bayesian networks clamp
evidence *nodes* (pattern = observed node ids), MRF grids clamp
evidence *pixels* (pattern = flat clamped-site indices from a scribble
mask) — the per-family surface lives in :mod:`repro.serve.families`,
and the engine only ever sees flat variable ids.

Sampling proceeds in rounds of ``sweeps_per_round`` sweeps.  After the
burn-in rounds, each round accumulates thinned one-hot counts per lane
(the online marginal estimate) and per-lane first/second moment
statistics (the inputs to the convergence diagnostics).  Convergence is
judged *per query* from :mod:`repro.pgm.diagnostics`: under the default
``retirement="rank"`` rule a query retires the moment its rank-
normalized split-R̂ (including the folded tail variant) drops below
``rhat_target`` **and** its min-ESS (bulk and tail effective sample
size) exceeds ``ess_target`` — both overridable per query.
``retirement="legacy"`` keeps the PR-3 plain split-R̂-only rule for
baseline comparability.  Either way retirement is independent of the
query's group mates: budget left over is simply not spent, which is
where the paper's "approximate inference" throughput comes from, and a
retired query's lane block is free real estate that
:class:`GroupRun.admit` can hand to a waiting query of the same plan
mid-flight (how the admission queue in :mod:`repro.serve.queue`
backfills under streaming traffic).

Two extensions ride the same lifecycle (PR 9).  **MAP/MPE mode**
(``Request.mode="map"``): the group's round runner receives a traced
per-lane inverse temperature ``beta`` that follows a geometric
simulated-annealing schedule (``map_beta0 * map_beta_growth**round``,
capped at ``map_beta_max``), sharpening the IU-exp weight path toward
the argmax; such slots retire on *assignment stability* — the per-round
argmax assignment unchanged for ``map_stable_rounds`` consecutive
rounds — instead of R̂/ESS, and their result carries
``map_assignment``/``map_energy`` instead of marginals.  **Temporal
filtering** (``Request.stream_id``): when a slot retires, its final
lane states are retained host-side keyed ``(network, stream_id)``; the
next slice on the same stream warm-starts from them (evidence
re-clamped via the family's ``clamp_states``) and skips burn-in — the
dynamic-BN filtering move, with the plan cache already making the
compile side free across slices.

Multi-device serving: give the engine a mesh from
``repro.launch.mesh.make_serve_mesh`` and each group's lane axis
``(n_queries * chains_per_query, n_nodes)`` is sharded over the mesh's
"batch" axis (the multicore analogue of the paper's 16 cores on one
chip: one XLA dispatch advances every device's slice of the lanes).
The flat log-CPT bank is replicated per device — or sharded over a 2D
mesh's "model" axis for very large networks — so the ``_color_update``
gathers stay local (``repro.sharding.specs``).  Lane counts are padded
up to a mesh multiple with throwaway replicas of the first query;
plans/runners are cached per (pattern, mesh fingerprint) so single- and
multi-device programs never collide.
"""
from __future__ import annotations

import itertools
import math
import os
from dataclasses import dataclass
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.fixedpoint import DEFAULT_K
from repro.launch.mesh import mesh_fingerprint
from repro.pgm.compile import sum_sweep_stats
from repro.pgm.diagnostics import (
    Diagnostics, RunningDiagnostics, split_rhat)
from repro.pgm.graph import BayesNet
from repro.serve.families import family_of
from repro.serve.plan_cache import PlanCache, plan_key
from repro.serve.query import IsingQuery, MrfQuery, Query, Request, Result
from repro.serve.sched import predict_remaining_rounds
from repro.serve.telemetry import (
    DEFAULT_COUNT_BINS, NULL, Telemetry, monotonic)
from repro.sharding.specs import serve_lane_multiple

# retirement rules: "rank" = rank-normalized split-R̂ + min-ESS gate
# (repro.pgm.diagnostics, the default), "legacy" = PR-3 plain split-R̂
# over round means (kept selectable so perf baselines stay comparable)
RETIREMENT_MODES = ("rank", "legacy")

__all__ = ["GroupEntry", "GroupRun", "PosteriorEngine", "RETIREMENT_MODES",
           "split_rhat"]


@dataclass
class GroupEntry:
    """One normalized query inside a (network, pattern) group.

    ``ev`` maps flat variable ids (BN nodes / MRF sites) to observed
    values; ``qvars`` are flat variable ids to report.  ``handle`` is
    the admission queue's :class:`repro.serve.query.QueryHandle` when
    the entry arrived via streaming submission, None for the synchronous
    ``answer_batch`` path.  ``result`` is filled in at retirement.
    """

    query: "Query | MrfQuery | IsingQuery"
    ev: dict[int, int]
    qvars: tuple[int, ...]
    handle: object | None = None
    result: Result | None = None
    tel_tid: int = 0                  # telemetry track id (0 = untracked)


@dataclass
class _Slot:
    """Bookkeeping of one lane block [j*c, (j+1)*c) of a running group.

    ``entry`` is None for a *vacant* slot: a lane block that exists only
    because the group's slot count was padded up to a shape bucket.  A
    vacant slot is born ``done`` — it samples throwaway replicas of
    query 0 until :meth:`GroupRun.admit` backfills it.

    ``diags`` holds one incremental :class:`repro.pgm.diagnostics.
    RunningDiagnostics` per query variable, fed the slot's per-round
    moment statistics; ``rhat_target``/``ess_target`` are the slot's
    resolved retirement thresholds (query override or engine default).
    """

    entry: GroupEntry | None
    j: int                      # slot index (lane block)
    cap: int                    # retirement round cap (budget/max_rounds)
    burn_left: int              # burn-in rounds still owed by this slot
    t0: float                   # admission time (monotonic clock)
    t_service0: float = 0.0     # sampling start (after plan/state init)
    backfilled: bool = False    # admitted mid-flight into a freed slot
    rounds: int = 0             # post-burn-in rounds accumulated
    counts: np.ndarray | None = None       # (n, L) int64, lane-summed
    diags: dict[int, RunningDiagnostics] | None = None  # per query var
    rhat_target: float = 0.0
    ess_target: float = 0.0
    rhat: float = float("inf")             # worst legacy split-R̂ so far
    converged: bool = False                # active rule satisfied
    done: bool = False
    cancelled: bool = False
    mode: str = "marginals"                # inference mode (Request.mode)
    anneal_rounds: int = 0      # rounds on the annealing schedule (incl. burn)
    map_last: np.ndarray | None = None     # last round's argmax, (n_vars,)
    map_stable: int = 0         # consecutive rounds map_last was unchanged
    warm: bool = False          # lanes seeded from a previous slice's states


class GroupRun:
    """Incremental run of one plan-compatible micro-batched group.

    Owns the device state of a group and advances it one round per
    :meth:`step` call, retiring queries individually as they converge or
    exhaust their budget.  ``answer_batch`` drives the same lifecycle to
    completion synchronously, so the admission queue's streamed dispatch
    is numerically identical to a synchronous ``answer_batch`` over the
    same groups (same PRNG stream, same draws).

    A retired slot's lane block can be handed to a *new* query of the
    same plan via :meth:`admit`: its lanes are re-initialized with the
    newcomer's evidence, it burns in privately (its counts/means are
    discarded host-side for ``burn_rounds`` rounds), then counts on its
    own thinning phase via the runner's per-lane ``offset``.
    """

    def __init__(self, engine: "PosteriorEngine", name: str,
                 pattern: tuple[int, ...], entries: list[GroupEntry]):
        if not entries:
            raise ValueError("empty group")
        t0 = monotonic()
        self.engine = engine
        self.name, self.pattern = name, pattern
        tel = self.tel = engine.telemetry
        if tel.enabled:
            self.tel_tid = tel.track(
                f"group#{next(engine._group_seq)} {name}")
            for e in entries:
                if not e.tel_tid:
                    e.tel_tid = tel.track(
                        f"query#{next(engine._query_seq)} {name}")
            tel.count("serve_groups_total",
                      help="micro-batched groups started")
        else:
            self.tel_tid = 0
        t_plan0 = monotonic()
        self.prog, self.runner, self.cache_hit = engine._plan(name, pattern)
        t_plan1 = monotonic()
        self._plan_span = (t_plan0, t_plan1)
        if tel.enabled:
            tel.complete("plan", self.tel_tid, t_plan0, t_plan1,
                         cache_hit=self.cache_hit, network=name)
            if self.cache_hit:
                tel.count("serve_plan_cache_hits_total",
                          help="plan-cache lookups served from memory")
            else:
                tel.count("serve_plan_cache_misses_total",
                          help="plan-cache lookups that ran the compiler")
                tel.observe("serve_compile_seconds", t_plan1 - t_plan0,
                            help="compiler-chain seconds per plan miss")
        self.model = engine._network(name)
        self.family = family_of(self.model)
        self.c = engine.chains_per_query
        self.spr = engine.sweeps_per_round
        self.burn_rounds = math.ceil(engine.burn_in / self.spr)
        self.n_free = self.family.n_free(self.prog)
        self.n_vars = self.family.n_vars(self.prog)
        # groups are mode-homogeneous: ``answer_batch`` and the admission
        # queue fold the mode into the group key, so one group is either
        # all-marginal (runner called without beta — the pre-MAP trace,
        # byte-identical) or all-MAP (per-lane annealed beta)
        self.mode = getattr(entries[0].query, "mode", "marginals")
        if self.mode == "map":
            fam, prog = self.family, self.prog
            cards = np.array(
                [fam.var_card(prog, v) for v in range(self.n_vars)])
            self._card_mask = (
                np.arange(fam.max_card(prog))[None, :] < cards[:, None])
        nq = len(entries)
        # shape bucketing: pad the slot count up to a power of two so
        # streaming traffic only ever compiles O(log max_group) distinct
        # lane shapes instead of one per group size (XLA re-jits per
        # shape; a compile storm would eat the micro-batching win).  Pad
        # blocks are *vacant slots* — free real estate for ``admit``.
        shape_q = 1 << (nq - 1).bit_length() if engine.pow2_group_shapes else nq
        b = shape_q * self.c
        # mesh path: additionally pad the lane axis to a batch-shard
        # multiple; pad lanes replicate query 0 and are sliced off every
        # host read.
        self.bt = b + (-b) % serve_lane_multiple(engine.mesh)

        ev_vals = np.zeros((self.bt, len(pattern)), np.int32)
        for j, e in enumerate(entries):
            ev_vals[j * self.c:(j + 1) * self.c] = [e.ev[v] for v in pattern]
        ev_vals[nq * self.c:] = ev_vals[:1]
        engine._key, init_key, self._run_key = jax.random.split(engine._key, 3)
        x = self.family.init_states(init_key, self.prog, self.bt,
                                    jnp.asarray(ev_vals) if pattern else None)
        if engine.mesh is not None:
            x = jax.device_put(x, NamedSharding(
                engine.mesh, self.family.state_spec(engine.mesh)))
        self.x = x
        self.slots = [self._fresh_slot(e, j, t0) for j, e in enumerate(entries)]
        self.slots += [
            _Slot(entry=None, j=j, cap=0, burn_left=0, t0=t0, done=True)
            for j in range(nq, self.bt // self.c)
        ]
        # temporal filtering: slots on a known stream warm-start from the
        # previous slice's retained chains (this slice's evidence
        # re-clamped) and skip burn-in — the states are already near the
        # posterior of a nearby evidence set
        for j, e in enumerate(entries):
            blk = engine._retained_block(name, e.query)
            if blk is None or blk.shape != (self.c,) + self.x.shape[1:]:
                continue
            x0 = jnp.asarray(blk)
            if pattern:
                x0 = self.family.clamp_states(
                    self.prog, x0,
                    jnp.asarray(ev_vals[j * self.c:(j + 1) * self.c]))
            self.x = self.x.at[j * self.c:(j + 1) * self.c].set(x0)
            self.slots[j].warm = True
            self.slots[j].burn_left = 0
            if tel.enabled:
                tel.instant("warm-start", self.tel_tid, slot=j)
                tel.count("serve_warm_starts_total",
                          help="slots seeded from retained stream states")
        # service starts at plan-end: the per-query wait/plan/service
        # spans share boundary timestamps, so they tile [submit, retire]
        # exactly (state init is the head of the service phase)
        for s in self.slots[:nq]:
            s.t_service0 = t_plan1
        if tel.enabled:
            tel.complete("init", self.tel_tid, t_plan1, monotonic(),
                         n_queries=nq, lanes=self.bt)
        self.bits = 0         # cumulative random bits, incl. burn-in (int64)
        self.sweeps_done = 0  # group sweeps so far, incl. burn-in

    def _fresh_slot(self, entry: GroupEntry, j: int, t0: float) -> _Slot:
        cap = self._cap(entry.query)
        L = self.family.max_card(self.prog)
        q = entry.query
        eng = self.engine
        rhat_target = getattr(q, "rhat_target", None)
        ess_target = getattr(q, "ess_target", None)
        return _Slot(
            entry=entry, j=j, cap=cap, burn_left=self.burn_rounds, t0=t0,
            mode=getattr(q, "mode", "marginals"),
            counts=np.zeros((self.n_vars, L), np.int64),
            diags={v: RunningDiagnostics(self.spr) for v in entry.qvars},
            rhat_target=(eng.rhat_target if rhat_target is None
                         else float(rhat_target)),
            ess_target=(eng.ess_target if ess_target is None
                        else float(ess_target)))

    def _cap(self, q: Query) -> int:
        """Smallest round count whose kept-draw total (global multiples
        of ``thin`` in [0, rounds*spr), times c lanes) covers the
        query's budget, clamped to [min_rounds, max_rounds]."""
        eng = self.engine
        kept_needed = max(1, math.ceil(q.n_samples / self.c))
        budget_rounds = math.ceil(((kept_needed - 1) * eng.thin + 1) / self.spr)
        return min(max(budget_rounds, eng.min_rounds), eng.max_rounds)

    # -- lifecycle ---------------------------------------------------------
    @property
    def active(self) -> bool:
        return any(not s.done for s in self.slots)

    def free_slots(self) -> int:
        return sum(s.done for s in self.slots)

    def step(self) -> list[GroupEntry]:
        """Advance the whole group one round; returns entries that
        retired this round (their ``result`` is filled in, or left None
        if cancelled)."""
        eng = self.engine
        tel = self.tel
        t_round0 = monotonic()
        busy = sum(not s.done for s in self.slots)
        offsets = np.zeros(self.bt, np.int32)
        for s in self.slots:
            if not s.done and not s.burn_left:
                offsets[s.j * self.c:(s.j + 1) * self.c] = s.rounds * self.spr
        self._run_key, sub = jax.random.split(self._run_key)
        if self.mode == "map":
            # per-lane annealed inverse temperature: each slot walks the
            # geometric schedule from its own admission round (backfilled
            # slots restart at beta0), so one traced runner serves every
            # point of every lane's schedule without retracing
            betas = np.ones(self.bt, np.float32)
            for s in self.slots:
                if not s.done:
                    betas[s.j * self.c:(s.j + 1) * self.c] = eng.map_beta(
                        s.anneal_rounds)
                    s.anneal_rounds += 1
            self.x, rc, xmean, xsq, st = self.runner(
                sub, self.x, jnp.asarray(offsets), jnp.asarray(betas))
        else:
            # marginal groups keep the 3-arg call: beta=None traces the
            # exact pre-annealing program (bitwise-pinned baselines)
            self.x, rc, xmean, xsq, st = self.runner(
                sub, self.x, jnp.asarray(offsets))
        self.bits += int(sum_sweep_stats(st).bits_used)
        self.sweeps_done += self.spr

        rc_np = xmean_np = xsq_np = None  # host transfer only if needed
        retired: list[GroupEntry] = []
        for s in self.slots:
            if s.done:
                continue
            if s.burn_left:
                s.burn_left -= 1
                continue
            if rc_np is None:
                rc_np = np.asarray(rc, np.int64)
                xmean_np = np.asarray(xmean)
                xsq_np = np.asarray(xsq)
            sl = slice(s.j * self.c, (s.j + 1) * self.c)
            rd = rc_np[sl].sum(axis=0)        # this round's counts (n, L)
            s.counts += rd
            for v, d in s.diags.items():
                d.update(xmean_np[sl, v], xsq_np[sl, v])
            s.rounds += 1
            if s.mode == "map":
                # assignment-stability retirement: the annealed chains'
                # per-round argmax must sit still for map_stable_rounds
                # consecutive rounds (rd can be all-zero when thin > spr
                # leaves a round with no kept draw — skip those rounds)
                if rd.any():
                    assign = np.where(
                        self._card_mask, rd, -1).argmax(axis=1)
                    if (s.map_last is not None
                            and np.array_equal(assign, s.map_last)):
                        s.map_stable += 1
                    else:
                        s.map_stable = 1
                    s.map_last = assign
                if s.rounds >= eng.min_rounds:
                    s.converged = s.map_stable >= eng.map_stable_rounds
            elif s.rounds >= eng.min_rounds:
                if eng.retirement == "rank":
                    # staged check: the cheap R̂ gate first, the
                    # O(rounds²) ESS estimators only once every
                    # variable's R̂ passes — slow-mixing rounds never
                    # pay for ESS they can't use (both all()s
                    # short-circuit on the first failing variable)
                    s.converged = all(
                        d.rank_gate() < s.rhat_target
                        for d in s.diags.values()) and all(
                        d.compute().min_ess >= s.ess_target
                        for d in s.diags.values())
                else:  # legacy: plain split-R̂ over round means only
                    s.rhat = max(
                        d.legacy_rhat() for d in s.diags.values())
                    s.converged = s.rhat < s.rhat_target
            if s.converged or s.rounds >= s.cap:
                reason = ("max-sweeps" if not s.converged
                          else "map-stable" if s.mode == "map"
                          else "rhat+ess" if eng.retirement == "rank"
                          else "rhat")
                self._retire(s, reason)
                retired.append(s.entry)
        if tel.enabled:
            t_round1 = monotonic()
            # ESS trajectory, read for free: only slots whose retirement
            # check already paid for the full O(rounds²) payload this
            # round have a cached Diagnostics — never computed here
            ess = {}
            for s in self.slots:
                if s.entry is None or s.diags is None or s.burn_left:
                    continue
                ds = [d.cached() for d in s.diags.values()]
                if ds and all(d is not None for d in ds):
                    ess[f"slot{s.j}"] = round(
                        min(d.min_ess for d in ds), 1)
            now_busy = sum(not s.done for s in self.slots)
            tel.complete(
                "round", self.tel_tid, t_round0, t_round1,
                sweeps=self.spr, lanes_busy=busy * self.c,
                lanes_vacant=(len(self.slots) - busy) * self.c,
                retired=len(retired), **({"ess": ess} if ess else {}))
            tel.sample("lanes_busy", now_busy * self.c)
            tel.count("serve_rounds_total", help="scheduling rounds run")
            tel.count("serve_sweeps_total", self.spr,
                      help="Gibbs sweeps run (all groups, incl. burn-in)")
            tel.gauge_set("serve_lanes_busy", now_busy * self.c,
                          help="chain lanes owned by live queries")
            tel.gauge_set(
                "serve_lanes_vacant", (len(self.slots) - now_busy) * self.c,
                help="padded/retired lanes available for backfill")
        return retired

    def run_to_completion(self) -> None:
        while self.active:
            self.step()

    def cancel(self, entry: GroupEntry) -> bool:
        """Mid-flight cancellation: free the entry's slot without a
        result.  Returns False if the entry already retired.

        A cancelled *stream* slice also invalidates the stream's
        retained chains: slice ``t+1`` dying before retirement breaks
        the temporal chain, so slice ``t+2`` must cold-start rather
        than silently warm-start from slice ``t``'s now-stale states
        (which would also leak them for the stream's lifetime)."""
        for s in self.slots:
            if s.entry is entry and not s.done:
                s.done = s.cancelled = True
                sid = getattr(entry.query, "stream_id", None)
                if sid is not None:
                    self.engine.invalidate_stream(self.name, sid)
                if self.tel.enabled:
                    self._record_query_spans(s, "cancel")
                return True
        return False

    def predicted_remaining_rounds(self) -> int:
        """Worst-case rounds this group still needs, per-slot from the
        ESS trajectory the retirement rule already computes (see
        :func:`repro.serve.sched.predict_remaining_rounds`).  Slots with
        no usable trajectory — MAP mode, still burning in, or R̂ gate
        not yet passed so no cached ESS — fall back to their remaining
        budget cap, which makes the estimate conservative (it can only
        overestimate, so deadline preemption fires no later than it
        should).  Multiply by ``sweeps_per_round`` for sweeps."""
        worst = 0
        for s in self.slots:
            if s.done or s.entry is None:
                continue
            if s.mode != "marginals" or s.diags is None:
                worst = max(worst, s.cap - s.rounds + s.burn_left)
                continue
            ds = [d.cached() for d in s.diags.values()]
            ess = (min(d.min_ess for d in ds)
                   if ds and all(d is not None for d in ds) else None)
            worst = max(worst, s.burn_left + predict_remaining_rounds(
                ess, s.rounds, s.ess_target, s.cap))
        return worst

    def admit(self, entry: GroupEntry) -> None:
        """Backfill a waiting query of the same plan into a freed slot:
        re-initialize its lane block with the newcomer's evidence and
        give it a private burn-in before it starts counting."""
        slot = next((s for s in self.slots if s.done), None)
        if slot is None:
            raise RuntimeError("no free slot to admit into")
        c = self.c
        ev = None
        if self.pattern:
            ev = jnp.asarray(np.tile(
                np.array([entry.ev[v] for v in self.pattern], np.int32),
                (c, 1)))
        self.engine._key, init_key = jax.random.split(self.engine._key)
        blk = self.engine._retained_block(self.name, entry.query)
        warm = blk is not None and blk.shape == (c,) + self.x.shape[1:]
        if warm:
            # temporal filtering through backfill: seed the freed block
            # from the stream's retained chains instead of fresh noise
            x0 = jnp.asarray(blk)
            if ev is not None:
                x0 = self.family.clamp_states(self.prog, x0, ev)
        else:
            x0 = self.family.init_states(init_key, self.prog, c, ev)
        self.x = self.x.at[slot.j * c:(slot.j + 1) * c].set(x0)
        t_admit = monotonic()
        fresh = self._fresh_slot(entry, slot.j, t_admit)
        fresh.t_service0, fresh.backfilled = t_admit, True
        if warm:
            fresh.warm = True
            fresh.burn_left = 0
        self.slots[slot.j] = fresh
        tel = self.tel
        if tel.enabled:
            if not entry.tel_tid:
                entry.tel_tid = tel.track(
                    f"query#{next(self.engine._query_seq)} {self.name}")
            tel.instant("backfill", self.tel_tid, slot=slot.j)
            tel.count("serve_backfilled_total",
                      help="queries admitted into freed lanes mid-flight")

    def _record_query_spans(self, s: _Slot, reason: str) -> None:
        """Per-query lifecycle spans, emitted once at retirement (or
        cancellation) on the query's own trace track.  ``wait`` /
        ``plan`` / ``service`` tile [submit, retire] by construction —
        shared boundary timestamps — so the trace's per-query phase sum
        always matches the end-to-end latency (the acceptance check)."""
        tel, entry = self.tel, s.entry
        now = monotonic()
        tid = entry.tel_tid
        t_submit = getattr(entry.handle, "t_submit", None)
        if t_submit is None:
            t_submit = s.t0
        t_wait1 = s.t0 if s.backfilled else self._plan_span[0]
        tel.complete("query", tid, t_submit, now,
                     network=self.name, reason=reason)
        tel.complete("wait", tid, t_submit, t_wait1)
        if not s.backfilled:
            tel.complete("plan", tid, *self._plan_span,
                         cache_hit=self.cache_hit)
        tel.complete("service", tid, s.t_service0, now,
                     rounds=s.rounds, sweeps=self.sweeps_done)
        tel.instant("retired", tid, reason=reason, rounds=s.rounds)
        tel.count("serve_retired_total", help="queries retired, by reason",
                  reason=reason)
        tel.observe("serve_wait_seconds", max(t_wait1 - t_submit, 0.0),
                    help="submit-to-admission wait per query")
        tel.observe("serve_service_seconds", now - s.t_service0,
                    help="sampling (rounds) seconds per query")
        tel.observe("serve_rounds_per_query", max(s.rounds, 1),
                    help="post-burn-in rounds a query consumed",
                    bins=DEFAULT_COUNT_BINS)

    def _retire(self, s: _Slot, reason: str = "max-sweeps") -> None:
        s.done = True
        eng, fam = self.engine, self.family
        marginals: dict = {}
        map_assignment = map_energy = None
        if s.mode == "map":
            # annealed counts are argmax evidence, not posterior mass —
            # report the point assignment (and its energy), no marginals
            full = (np.where(self._card_mask, s.counts, -1).argmax(axis=1)
                    if s.map_last is None else s.map_last.copy())
            for v, val in s.entry.ev.items():
                full[v] = val
            map_assignment = {
                fam.var_name(self.model, v): int(full[v])
                for v in s.entry.qvars}
            map_energy = float(fam.assignment_energy(self.model, full))
        else:
            for v in s.entry.qvars:
                m = s.counts[v, :fam.var_card(self.prog, v)].astype(
                    np.float64)
                marginals[fam.var_name(self.model, v)] = m / max(m.sum(), 1.0)
        sid = getattr(s.entry.query, "stream_id", None)
        if sid is not None:
            # retain the slot's final chains for the stream's next slice
            sl = slice(s.j * self.c, (s.j + 1) * self.c)
            eng._retained[(self.name, sid)] = np.asarray(self.x[sl])
        # kept draws per lane: global sweep indices in [0, rounds*spr)
        # that are multiples of ``thin``
        kept_total = (s.rounds * self.spr + eng.thin - 1) // eng.thin
        # warm (temporal) slots skipped burn-in — count only what ran
        total_sweeps = ((0 if s.warm else self.burn_rounds) + s.rounds) \
            * self.spr
        group_node_samples = self.bt * self.n_free * self.sweeps_done
        # diagnostics payload: worst-case R̂s / smallest ESS over the
        # query variables, computed once at retirement (cached per
        # round, so this is free when the retirement rule already
        # evaluated them).  Result.rhat is the worst legacy split-R̂ in
        # both modes — rank-mode rounds skip it on the hot path, so it
        # is finalized here from the same cached computes.
        ds = [d.compute() for d in s.diags.values()]
        s.rhat = max(d.rhat for d in ds)
        diag = Diagnostics(
            rhat=float(s.rhat),
            rank_rhat=max(d.rank_rhat for d in ds),
            folded_rhat=max(d.folded_rhat for d in ds),
            ess_bulk=min(d.ess_bulk for d in ds),
            ess_tail=min(d.ess_tail for d in ds),
            sweeps_used=total_sweeps)
        s.entry.result = Result(
            query=s.entry.query,
            marginals=marginals,
            n_samples=int(self.c * kept_total),
            n_sweeps=total_sweeps,
            n_node_samples=int(self.c * self.n_free * total_sweeps),
            rhat=float(s.rhat),
            converged=bool(s.converged),
            cache_hit=self.cache_hit,
            wall_s=monotonic() - s.t0,
            bits_per_sample=(
                self.bits / group_node_samples if group_node_samples else 0.0),
            diagnostics=diag,
            map_assignment=map_assignment,
            map_energy=map_energy,
            warm_start=s.warm,
        )
        if self.tel.enabled:
            self._record_query_spans(s, reason)


class PosteriorEngine:
    """Answers batches of posterior queries over registered networks.

    Parameters mirror a serving config: ``chains_per_query`` lanes per
    query, ``sweeps_per_round`` sweeps per scheduling quantum, burn-in
    and thinning in sweeps, and the retirement (early-stopping) rule.
    ``retirement="rank"`` (default) retires a query once its worst
    rank-normalized split-R̂ — ``max(rank_rhat, folded_rhat)`` over the
    query variables — is below ``rhat_target`` *and* its smallest
    bulk/tail ESS exceeds ``ess_target``; ``"legacy"`` keeps the plain
    split-R̂-only rule (comparable to pre-diagnostics perf baselines).
    Both thresholds are engine defaults that individual queries may
    override (``Query.rhat_target`` / ``Query.ess_target``).

    ``Request.mode="map"`` switches a query to annealed MAP/MPE search:
    ``map_beta0``/``map_beta_growth``/``map_beta_max`` set the geometric
    inverse-temperature schedule and ``map_stable_rounds`` the number of
    consecutive rounds the per-round argmax assignment must hold for the
    query to retire (reason ``"map-stable"``).  ``Request.stream_id``
    opts a query into temporal filtering: each retired slice's chains
    are retained and the stream's next slice warm-starts from them,
    skipping burn-in (``reset_streams`` forgets them).

    ``mesh`` (from :func:`repro.launch.mesh.make_serve_mesh`) shards each
    group's chain-lane axis over the mesh's "batch" axis; ``None`` keeps
    the single-device path.  ``plan_cache_dir`` persists compiled plans
    (the ColorPlan tensors, not the jitted HLO) as ``.npz`` files so warm
    process starts skip the compiler chain.  ``pow2_group_shapes`` pads
    each group's slot count to a power of two — streaming traffic then
    compiles O(log max-group) distinct lane shapes instead of one per
    observed group size, and the pad blocks double as backfill targets.

    Example::

        from repro.pgm import networks
        from repro.serve import PosteriorEngine, Query

        engine = PosteriorEngine({"sprinkler": networks.sprinkler()})
        res = engine.answer(Query("sprinkler", {"wetgrass": 1}, ("rain",)))
        res.marginal("rain")          # posterior P(rain | wetgrass=1)
        res.diagnostics.ess_bulk      # effective sample size behind it
    """

    def __init__(
        self,
        networks: "Mapping[str, BayesNet | object] | None" = None,
        *,
        chains_per_query: int = 32,
        sweeps_per_round: int = 16,
        burn_in: int = 64,
        thin: int = 1,
        rhat_target: float = 1.05,
        ess_target: float = 100.0,
        retirement: str = "rank",
        min_rounds: int = 4,
        max_rounds: int = 64,
        map_beta0: float = 0.5,
        map_beta_growth: float = 1.3,
        map_beta_max: float = 8.0,
        map_stable_rounds: int = 3,
        k: int = DEFAULT_K,
        use_iu: bool = True,
        sampler: str | None = None,
        quantize_cpt_bits: int | None = 16,
        cache: PlanCache | None = None,
        mesh=None,
        plan_cache_dir: str | None = None,
        pow2_group_shapes: bool = True,
        telemetry: Telemetry | None = None,
        seed: int = 0,
    ):
        # "networks" kept for API continuity; values may be any model a
        # family adapter exists for (BayesNet, MRFGrid)
        self.networks: dict[str, object] = dict(networks or {})
        self.chains_per_query = int(chains_per_query)
        self.sweeps_per_round = int(sweeps_per_round)
        self.burn_in = int(burn_in)
        self.thin = int(thin)
        self.rhat_target = float(rhat_target)
        self.ess_target = float(ess_target)
        if retirement not in RETIREMENT_MODES:
            raise ValueError(
                f"retirement {retirement!r} not in {RETIREMENT_MODES}")
        self.retirement = retirement
        self.min_rounds = max(int(min_rounds), 4)  # split-R̂ needs >= 4
        self.max_rounds = int(max_rounds)
        # MAP-mode annealing schedule: beta(t) = beta0 * growth^t, capped
        # at beta_max (= the IU-exp LUT's greedy-saturation point: any
        # label whose unscaled gap from the argmax exceeds 16/beta_max
        # quantizes to weight 0)
        if map_beta0 <= 0 or map_beta_growth < 1.0 or map_beta_max <= 0:
            raise ValueError(
                "map_beta0/map_beta_max must be > 0 and "
                "map_beta_growth >= 1.0")
        self.map_beta0 = float(map_beta0)
        self.map_beta_growth = float(map_beta_growth)
        self.map_beta_max = float(map_beta_max)
        self.map_stable_rounds = max(int(map_stable_rounds), 1)
        self.k = k
        self.use_iu = use_iu
        # sampler backend: "xla" (two-stage weights + KY) or "pallas"
        # (fused sweep kernel, bitwise-identical); None defers to the
        # REPRO_SAMPLER env var (the CI matrix knob), then "xla".
        sampler = sampler or os.environ.get("REPRO_SAMPLER") or "xla"
        if sampler not in ("xla", "pallas"):
            raise ValueError(
                f"sampler {sampler!r} not in ('xla', 'pallas')")
        self.sampler = sampler
        self.quantize_cpt_bits = quantize_cpt_bits
        self.cache = cache if cache is not None else PlanCache()
        self.mesh = mesh
        self.plan_cache_dir = plan_cache_dir
        self.pow2_group_shapes = bool(pow2_group_shapes)
        # telemetry is a no-op by default (the shared NULL recorder);
        # pass Telemetry() to record traces/metrics — repro.serve.telemetry
        self.telemetry = telemetry if telemetry is not None else NULL
        self._group_seq = itertools.count()
        self._query_seq = itertools.count()
        self._attached_queue = None  # set by AdmissionQueue for stats()
        # temporal filtering: final lane states of retired stream slots,
        # keyed (network, stream_id) — the warm-start seed for the
        # stream's next slice (host-side numpy, device-agnostic)
        self._retained: dict[tuple[str, str], np.ndarray] = {}
        self._key = jax.random.PRNGKey(seed)

    # -- MAP annealing / temporal filtering --------------------------------
    def map_beta(self, t: int) -> float:
        """Inverse temperature after ``t`` rounds of the geometric
        simulated-annealing schedule (see ``docs/inference_modes.md``)."""
        return min(self.map_beta_max,
                   self.map_beta0 * self.map_beta_growth ** t)

    def _retained_block(self, name: str, query) -> np.ndarray | None:
        """Retained lane states for a query's stream, or None when the
        query is streamless / the stream has no retired slice yet."""
        sid = getattr(query, "stream_id", None)
        if sid is None:
            return None
        return self._retained.get((name, sid))

    def invalidate_stream(self, network: str, stream_id: str) -> bool:
        """Drop one stream's retained chains (a cancelled or failed
        slice broke the temporal chain — later slices must cold-start).
        Returns True if there was state to drop."""
        return self._retained.pop((network, stream_id), None) is not None

    def reset_streams(self, network: str | None = None) -> None:
        """Drop retained temporal-filtering states (all streams, or one
        network's) — subsequent slices cold-start again."""
        if network is None:
            self._retained.clear()
        else:
            for key in [k for k in self._retained if k[0] == network]:
                del self._retained[key]

    # -- registry ----------------------------------------------------------
    def register(self, name: str, model) -> None:
        """Register (or replace) a model (BayesNet or MRFGrid).
        Replacing drops the name's cached plans — they were compiled
        from the old model's parameters."""
        if self.networks.get(name) is not model:
            self.cache.invalidate(lambda key: key[0] == name)
            self.reset_streams(name)  # retained chains came from the old model
        self.networks[name] = model

    def _network(self, name: str):
        try:
            return self.networks[name]
        except KeyError:
            raise KeyError(
                f"network {name!r} not registered "
                f"(have: {sorted(self.networks)})") from None

    # -- plan lookup -------------------------------------------------------
    def _plan_key(self, name: str, pattern: tuple[int, ...]) -> tuple:
        # sparse families fold a graph-content fingerprint into the key
        # (plans are shaped by the graph structure itself); name-keyed
        # families return None — see ``plan_key``'s model_salt contract
        model = self.networks.get(name)
        salt = None if model is None else family_of(model).plan_salt(model)
        return plan_key(
            name, pattern, k=self.k, use_iu=self.use_iu,
            sampler=self.sampler,
            quantize_cpt_bits=self.quantize_cpt_bits,
            sweeps_per_round=self.sweeps_per_round, thin=self.thin,
            mesh_fingerprint=mesh_fingerprint(self.mesh),
            model_salt=salt)

    def _plan(self, name: str, pattern: tuple[int, ...]):
        """(compiled program, round_runner, was_cache_hit) for one
        (model, pattern); the program/runner builders come from the
        model's family adapter."""

        def build():
            model = self._network(name)
            fam = family_of(model)
            prog = None
            path = None
            if self.plan_cache_dir is not None:
                path = fam.persisted_path(
                    self.plan_cache_dir, name, pattern, model, k=self.k,
                    quantize_cpt_bits=self.quantize_cpt_bits)
            if path is not None:
                prog = fam.load_persisted(path, model)
            if prog is None:
                prog = fam.compile(
                    model, pattern, k=self.k,
                    quantize_cpt_bits=self.quantize_cpt_bits)
                if path is not None:
                    fam.save_persisted(path, prog)
            runner = fam.make_runner(
                prog, sweeps_per_round=self.sweeps_per_round,
                thin=self.thin, use_iu=self.use_iu,
                sampler=self.sampler, mesh=self.mesh)
            return prog, runner

        (prog, runner), hit = self.cache.get(
            self._plan_key(name, pattern), build)
        return prog, runner, hit

    # -- observability -----------------------------------------------------
    def stats(self) -> dict:
        """One JSON-able snapshot of everything the engine already
        counts: the plan cache's :class:`repro.serve.plan_cache.
        CacheStats`, the attached admission queue's :class:`repro.serve.
        queue.QueueStats` (``None`` when no queue owns this engine), and
        — when a live recorder is installed — the telemetry metrics
        snapshot.  Safe to call at any time, including before any
        traffic (hit rate reads 0.0, not a division error)."""
        s = self.cache.stats
        out: dict = {
            "plan_cache": {
                "hits": s.hits, "misses": s.misses,
                "evictions": s.evictions, "hit_rate": s.hit_rate,
                "size": len(self.cache), "capacity": self.cache.capacity,
            },
            "queue": (None if self._attached_queue is None
                      else self._attached_queue.stats.snapshot()),
        }
        if self.telemetry.enabled:
            out["metrics"] = self.telemetry.metrics_snapshot()
        return out

    # -- serving -----------------------------------------------------------
    def normalize(self, query: Request):
        """Resolve a query against its model: ``(model, evidence-by-flat-
        id, query-var ids, evidence pattern)``.  Raises on unknown
        models, bad evidence, or query vars that are observed — the
        admission queue calls this at submit time so bad requests fail
        fast."""
        model = self._network(query.network)
        ev, qvars, pattern = family_of(model).normalize(model, query)
        return model, ev, qvars, pattern

    def answer(self, query: Request) -> Result:
        return self.answer_batch([query])[0]

    def answer_batch(self, queries: "list[Request]") -> list[Result]:
        """Answer a batch; compatible queries share one jitted sweep.

        Groups are keyed (network, evidence pattern, mode): marginal and
        MAP queries never mix lanes — MAP groups run the annealed
        (traced-beta) round program, marginal groups the plain one.
        Both modes of one pattern still share a single plan-cache entry
        (the mode is not part of the plan key)."""
        groups: dict[tuple, list[GroupEntry]] = {}
        entries = []
        for q in queries:
            _, ev, qvars, pattern = self.normalize(q)
            e = GroupEntry(q, ev, qvars)
            entries.append(e)
            groups.setdefault(
                (q.network, pattern, getattr(q, "mode", "marginals")),
                []).append(e)
        for (name, pattern, _mode), group in groups.items():
            GroupRun(self, name, pattern, group).run_to_completion()
        return [e.result for e in entries]  # type: ignore[return-value]
