"""Compiled-plan cache keyed by (network, evidence-pattern).

The compiler chain (quantize → moralize+DSatur → gather plans → jit) is
the expensive, *reusable* part of answering a query: one compiled sweep
program serves every query that clamps the same set of nodes, whatever
the observed values, because values live in the state vector, not the
plan (see :class:`repro.pgm.compile.CompiledBN`).  Serving traffic is
heavily repetitive in its evidence patterns (the same sensors report
every time), so an LRU over patterns turns recompilation into a
cold-start-only cost — the warm path goes straight to the jitted sweep.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable


def plan_key(
    network: str,
    pattern: tuple[int, ...],
    *,
    k: int,
    use_iu: bool,
    quantize_cpt_bits: int | None,
    sweeps_per_round: int,
    thin: int,
    mesh_fingerprint=None,
) -> tuple:
    """Canonical cache key of one compiled (plan, round-runner) pair.

    Everything a runner's compiled HLO depends on must appear here.  In
    particular ``mesh_fingerprint`` ((shape, axis names, device ids), or
    None for the single-device path): a runner jitted with sharding
    constraints for one mesh layout — or placed on one set of devices —
    must never be served to an engine on another; see
    ``repro.launch.mesh.mesh_fingerprint``.
    """
    return (network, pattern, k, use_iu, quantize_cpt_bits,
            sweeps_per_round, thin, mesh_fingerprint)


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclass
class PlanCache:
    """LRU cache of compiled sweep programs (and their jitted runners).

    Entries are built on demand by the ``build`` thunk passed to
    :meth:`get`, so the cache stays agnostic of what a "plan" is — the
    engine stores (CompiledBN, round-runner) pairs, tests can store
    sentinels.
    """

    capacity: int = 128
    stats: CacheStats = field(default_factory=CacheStats)
    _entries: OrderedDict = field(default_factory=OrderedDict, repr=False)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def get(self, key: Hashable, build: Callable[[], Any]) -> tuple[Any, bool]:
        """Return ``(entry, was_hit)``; builds and inserts on miss."""
        if key in self._entries:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return self._entries[key], True
        self.stats.misses += 1
        entry = self._entries[key] = build()
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return entry, False

    def invalidate(self, predicate: Callable[[Hashable], bool]) -> int:
        """Drop entries whose key matches; returns how many were dropped."""
        stale = [k for k in self._entries if predicate(k)]
        for k in stale:
            del self._entries[k]
        return len(stale)

    def clear(self) -> None:
        self._entries.clear()
        self.stats = CacheStats()
