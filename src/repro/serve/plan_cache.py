"""Compiled-plan cache keyed by (network, evidence-pattern).

The compiler chain (quantize → moralize+DSatur → gather plans → jit) is
the expensive, *reusable* part of answering a query: one compiled sweep
program serves every query that clamps the same set of nodes, whatever
the observed values, because values live in the state vector, not the
plan (see :class:`repro.pgm.compile.CompiledBN`).  Serving traffic is
heavily repetitive in its evidence patterns (the same sensors report
every time), so an LRU over patterns turns recompilation into a
cold-start-only cost — the warm path goes straight to the jitted sweep.

Plans also persist across *processes*: a :class:`CompiledBN` is nothing
but plain numpy tensors (the flat log-CPT bank plus per-color int32
gather plans), so :func:`save_compiled` / :func:`load_compiled` round-
trip one through an ``.npz`` per plan-key and a warm process start skips
the compiler chain entirely (XLA still jits the round runner on first
use — only the HLO is rebuilt, not the plans).  Files are keyed by a
content fingerprint of the network (structure + CPT bytes), so a stale
cache directory can never serve plans for a renamed or retrained net.
"""
from __future__ import annotations

import hashlib
import os
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable

import numpy as np


# Patterns longer than this are folded into a sha1 digest inside the
# cache key: an MRF scribble mask can clamp thousands of pixels, and a
# kilo-int tuple makes a poor dict key (hash cost on every bucket/cache
# lookup) while the digest is exact enough — collisions are sha1-rare.
_PATTERN_HASH_LEN = 32


def pattern_key(pattern: tuple[int, ...]):
    """Hashable, bounded-size identity of an evidence pattern (BN node
    ids or MRF flat pixel indices) — the "mask-pattern hash"."""
    if len(pattern) <= _PATTERN_HASH_LEN:
        return pattern
    digest = hashlib.sha1(
        np.asarray(pattern, np.int64).tobytes()).hexdigest()
    return ("sha1", len(pattern), digest)


def plan_key(
    network: str,
    pattern: tuple[int, ...],
    *,
    k: int,
    use_iu: bool,
    quantize_cpt_bits: int | None,
    sweeps_per_round: int,
    thin: int,
    sampler: str = "xla",
    mesh_fingerprint=None,
    model_salt=None,
) -> tuple:
    """Canonical cache key of one compiled (plan, round-runner) pair.

    Everything a runner's compiled HLO depends on must appear here.  In
    particular ``mesh_fingerprint`` ((shape, axis names, device ids), or
    None for the single-device path): a runner jitted with sharding
    constraints for one mesh layout — or placed on one set of devices —
    must never be served to an engine on another; see
    ``repro.launch.mesh.mesh_fingerprint``.  Long patterns (pixel
    masks) are folded to their :func:`pattern_key` digest.

    ``model_salt`` folds in a *content* identity where the name alone is
    too weak: sparse factor graphs compile to plans shaped by the graph
    structure itself (degree buckets, coloring), so a re-registered
    graph under the same name must miss — pass
    :func:`graph_fingerprint` there.  Families whose plans depend only
    on (name, pattern, knobs) leave it None.
    """
    return (network, pattern_key(pattern), k, use_iu, sampler,
            quantize_cpt_bits, sweeps_per_round, thin, mesh_fingerprint,
            model_salt)


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclass
class PlanCache:
    """LRU cache of compiled sweep programs (and their jitted runners).

    Entries are built on demand by the ``build`` thunk passed to
    :meth:`get`, so the cache stays agnostic of what a "plan" is — the
    engine stores (CompiledBN, round-runner) pairs, tests can store
    sentinels.
    """

    capacity: int = 128
    stats: CacheStats = field(default_factory=CacheStats)
    _entries: OrderedDict = field(default_factory=OrderedDict, repr=False)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def get(self, key: Hashable, build: Callable[[], Any]) -> tuple[Any, bool]:
        """Return ``(entry, was_hit)``; builds and inserts on miss."""
        if key in self._entries:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            return self._entries[key], True
        self.stats.misses += 1
        entry = self._entries[key] = build()
        if len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1
        return entry, False

    def invalidate(self, predicate: Callable[[Hashable], bool]) -> int:
        """Drop entries whose key matches; returns how many were dropped."""
        stale = [k for k in self._entries if predicate(k)]
        for k in stale:
            del self._entries[k]
        return len(stale)

    def clear(self) -> None:
        self._entries.clear()
        self.stats = CacheStats()


# -- on-disk persistence ---------------------------------------------------
_PLAN_FIELDS = (
    "nodes", "card", "self_base_off", "self_pa", "self_pa_stride",
    "ch_off", "ch_vstride", "ch_self", "ch_self_stride", "ch_pa",
    "ch_pa_stride")
_FORMAT_VERSION = 1


def network_fingerprint(bn) -> str:
    """Content hash of a BayesNet: structure (cards, parents) + CPT
    bytes.  Two nets with the same fingerprint compile to identical
    plans, so it is the only identity a persisted plan needs."""
    h = hashlib.sha1()
    h.update(repr((int(bn.n_nodes), tuple(int(c) for c in bn.card),
                   tuple(tuple(p) for p in bn.parents))).encode())
    for t in bn.cpt:
        h.update(np.ascontiguousarray(t, np.float64).tobytes())
    return h.hexdigest()


def graph_fingerprint(model) -> str:
    """Content hash of a sparse model (FactorGraph or IsingModel).

    Duck-typed: anything with a ``pair`` attribute hashes like a factor
    graph (cards + edges + energy tables); an Ising model hashes its
    couplings/fields directly — cheaper than lowering a million-spin
    model to (E, 2, 2) tables just to fingerprint it.
    """
    h = hashlib.sha1()
    if hasattr(model, "pair"):
        h.update(repr((int(model.n_vars),
                       tuple(int(c) for c in model.card))).encode())
        h.update(np.ascontiguousarray(model.edges, np.int64).tobytes())
        h.update(np.ascontiguousarray(model.unary, np.float64).tobytes())
        h.update(np.ascontiguousarray(model.pair, np.float64).tobytes())
    else:
        h.update(repr(("ising", int(model.n))).encode())
        h.update(np.ascontiguousarray(model.edges, np.int64).tobytes())
        h.update(np.ascontiguousarray(model.j, np.float64).tobytes())
        h.update(np.ascontiguousarray(model.h, np.float64).tobytes())
    return h.hexdigest()


def persisted_plan_path(directory: str, network: str,
                        pattern: tuple[int, ...], bn, *,
                        k: int, quantize_cpt_bits: int | None) -> str:
    """``.npz`` path of one persisted plan.  The filename folds in every
    input of the compiler chain — pattern, fixed-point precision,
    quantization, and the network's content fingerprint — but *not*
    runner parameters (sweeps_per_round, thin, mesh): those shape the
    jitted HLO, which is rebuilt per process anyway."""
    tag = hashlib.sha1(repr(
        (network, tuple(pattern), k, quantize_cpt_bits,
         network_fingerprint(bn), _FORMAT_VERSION)).encode()).hexdigest()[:16]
    return os.path.join(directory, f"plan_{network}_{tag}.npz")


def save_compiled(path: str, prog) -> None:
    """Serialize a CompiledBN's tensors (log-CPT bank + ColorPlans) to
    ``path``.  Written atomically (tmp + rename) so a crashed writer
    never leaves a half-file for the next process to trip over."""
    payload = {
        "version": np.int64(_FORMAT_VERSION),
        "log_cpt": prog.log_cpt,
        "max_card": np.int64(prog.max_card),
        "k": np.int64(prog.k),
        "observed": np.asarray(prog.observed, np.int32),
        "n_plans": np.int64(len(prog.plans)),
    }
    for i, plan in enumerate(prog.plans):
        for f in _PLAN_FIELDS:
            payload[f"plan{i}_{f}"] = getattr(plan, f)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as fh:
        np.savez_compressed(fh, **payload)
    os.replace(tmp, path)


def load_compiled(path: str, bn):
    """Rebuild a CompiledBN from ``path``; None if absent or unreadable
    (a corrupt file degrades to a recompile, never an error)."""
    import zipfile

    from repro.pgm.compile import ColorPlan, CompiledBN
    try:
        with np.load(path) as z:
            if int(z["version"]) != _FORMAT_VERSION:
                return None
            plans = tuple(
                ColorPlan(**{f: z[f"plan{i}_{f}"] for f in _PLAN_FIELDS})
                for i in range(int(z["n_plans"])))
            return CompiledBN(
                bn=bn, log_cpt=z["log_cpt"], plans=plans,
                max_card=int(z["max_card"]), k=int(z["k"]),
                observed=tuple(int(v) for v in z["observed"]))
    except (OSError, KeyError, ValueError, zipfile.BadZipFile):
        return None
