"""Asyncio HTTP/WebSocket front end over the worker pool — the "host".

The scale-out entry point the ROADMAP calls the missing RISC-V host:
remote callers speak the strict v2 JSON wire schema
(:mod:`repro.serve.protocol`) to a :class:`ServeFrontEnd`, which admits
(or sheds) each query, routes it through the pool's consistent-hash
ring (:mod:`repro.serve.worker`), bridges the worker's thread-side
:class:`repro.serve.query.QueryHandle` onto the event loop, and streams
results back.  Pure stdlib: ``asyncio`` sockets, a minimal HTTP/1.1
parser, and an RFC 6455 WebSocket endpoint — no framework dependency to
gate on.

Endpoints
---------
* ``POST /v2/query`` — one wire request; the response is the wire
  result (or an error body with a non-2xx status).
* ``POST /v2/batch`` — ``{"v": 2, "requests": [...]}``; the whole list
  is routed to ONE worker and admitted atomically in list order
  (:meth:`repro.serve.queue.AdmissionQueue.submit_many`), then flushed —
  which is exactly the in-process ``answer_batch`` grouping, so served
  batch results are bitwise-identical to a same-seed ``answer_batch``.
  Responses come back in request order.
* ``GET /v2/stream`` (WebSocket) — each text frame is one wire request;
  result frames come back in *completion* order carrying the request's
  ``"id"``.  The temporal-filtering client: ``stream_id`` queries stay
  pinned to one worker across frames.
* ``GET /healthz`` — liveness + per-worker up/down.
* ``GET /stats`` — pool stats JSON (engine/plan-cache/queue counters).
* ``GET /metrics`` — Prometheus text: front-end admission metrics plus
  every live worker's engine telemetry.
* ``POST /v2/flush`` — make everything pending dispatchable now.

Load shedding
-------------
Admission control runs *before* a query touches any queue:

* **per-tenant token bucket** (``quota_qps``/``quota_burst``, keyed by
  the request's ``tenant`` field) — over-quota requests get **429**
  with a ``Retry-After`` header telling the client when a token will
  exist.  Shedding at the front door is the overload story: the
  admitted subset keeps bounded latency instead of every caller
  timing out in a collapsing queue (``bench_serve.run_overload``
  measures p50/p99/shed-rate at 2x capacity).
* **backpressure** (``max_pending``) — a hard cap on queries admitted
  but unresolved across the pool; beyond it requests get **503** +
  ``Retry-After`` regardless of tenant.

Worker death: a query whose worker dies before dispatch
(``WorkerDied.resubmit``) is transparently resubmitted to the next
live worker on the ring; death mid-group fails the request loudly with
a 500 error body naming the worker.
"""
from __future__ import annotations

import asyncio
import base64
import hashlib
import json
import struct
import threading

from repro.serve.protocol import (
    WIRE_VERSION, WireError, error_body, parse_wire_request,
    result_to_wire)
from repro.serve.query import QueryStatus
from repro.serve.sched import TokenBucket
from repro.serve.worker import WorkerDied, WorkerPool

__all__ = ["ServeFrontEnd", "start_in_thread"]

_WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"
_MAX_BODY = 64 << 20        # 64 MiB: MRF masks are big, DoS bodies bigger
_MAX_HEADERS = 100


class _Shed(Exception):
    """Internal: request shed at admission (quota or backpressure)."""

    def __init__(self, code: int, reason: str, retry_after: float):
        super().__init__(reason)
        self.code = code
        self.reason = reason
        self.retry_after = retry_after


class ServeFrontEnd:
    """The serving front end; see the module docstring.

    ``quota_qps=None`` disables per-tenant quotas (every request is
    admitted up to ``max_pending``).  ``port=0`` binds an ephemeral
    port — read it back from :attr:`port` after :meth:`start`.
    """

    def __init__(self, pool: WorkerPool, *, host: str = "127.0.0.1",
                 port: int = 8080, quota_qps: float | None = None,
                 quota_burst: float | None = None, max_pending: int = 256):
        self.pool = pool
        self.host, self._port_arg = host, int(port)
        self.quota_qps = quota_qps
        self.quota_burst = quota_burst if quota_burst is not None else \
            max(1.0, quota_qps or 0.0)
        self.max_pending = int(max_pending)
        self._buckets: dict[str, TokenBucket] = {}
        self._pending = 0
        self.shed = {"quota": 0, "backpressure": 0}
        self.served = 0
        self._server: asyncio.base_events.Server | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._stopping: asyncio.Event | None = None

    # -- lifecycle ---------------------------------------------------------
    @property
    def port(self) -> int:
        if self._server is None:
            return self._port_arg
        return self._server.sockets[0].getsockname()[1]

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stopping = asyncio.Event()
        self._server = await asyncio.start_server(
            self._on_connection, self.host, self._port_arg)

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._stopping is not None:
            self._stopping.set()

    # -- admission ---------------------------------------------------------
    def _bucket(self, tenant: str) -> TokenBucket:
        b = self._buckets.get(tenant)
        if b is None:
            b = self._buckets[tenant] = TokenBucket(
                rate=self.quota_qps, burst=self.quota_burst)
        return b

    def _admit(self, query, n: float = 1.0) -> None:
        """Charge admission for ``n`` queries or shed (raises _Shed)."""
        if self._pending + n > self.max_pending:
            self.shed["backpressure"] += int(n)
            raise _Shed(503, "backpressure: too many queries in flight",
                        retry_after=0.5)
        if self.quota_qps is not None:
            tenant = getattr(query, "tenant", None) or "default"
            retry = self._bucket(tenant).try_take(n)
            if retry > 0:
                self.shed["quota"] += int(n)
                raise _Shed(
                    429, f"tenant {tenant!r} is over quota "
                    f"({self.quota_qps}/s)", retry_after=retry)

    # -- handle bridging ---------------------------------------------------
    def _bridge(self, handle) -> asyncio.Future:
        """A thread-side QueryHandle as an awaitable resolving to the
        handle itself once terminal (never raising — the caller reads
        status/error off the handle)."""
        loop = self._loop
        fut = loop.create_future()

        def done(h, fut=fut, loop=loop):
            def resolve():
                if not fut.done():
                    fut.set_result(h)
            try:
                loop.call_soon_threadsafe(resolve)
            except RuntimeError:
                pass  # loop already closed — server shutting down
        handle.add_done_callback(done)
        return fut

    async def _run_query(self, query):
        """Route, submit, await; resubmits across workers while the
        failure says it is safe to.  Returns the terminal handle."""
        exclude: set[str] = set()
        while True:
            worker, handle = self.pool.submit(query, exclude=exclude)
            h = await self._bridge(handle)
            err = h._error
            if (h.status is QueryStatus.FAILED
                    and isinstance(err, WorkerDied) and err.resubmit
                    and len(exclude) + 1 < len(self.pool.workers)):
                exclude.add(worker.name)
                continue
            return h

    @staticmethod
    def _handle_to_wire(h, rid) -> tuple[int, dict]:
        if h.status is QueryStatus.DONE:
            return 200, result_to_wire(h._result, id=rid)
        if h.status is QueryStatus.CANCELLED:
            body = {"error": "query cancelled", "v": WIRE_VERSION}
        else:
            body = error_body(h._error)
        if rid is not None:
            body["id"] = rid
        return 500, body

    async def _serve_one(self, obj) -> tuple[int, dict, dict]:
        try:
            query, rid = parse_wire_request(obj)
            self._admit(query)
        except WireError as exc:
            return exc.code, exc.body, {}
        except _Shed as exc:
            return exc.code, {
                "error": str(exc), "v": WIRE_VERSION,
                "retry_after_s": exc.retry_after,
            }, {"Retry-After": f"{max(exc.retry_after, 0.001):.3f}"}
        self._pending += 1
        try:
            h = await self._run_query(query)
        except (KeyError, ValueError) as exc:
            # the wire schema can't know model internals: an unknown
            # network/node only surfaces when routing normalizes the
            # query against the registry — still the client's fault
            body = error_body(exc)
            if rid is not None:
                body["id"] = rid
            return 400, body, {}
        finally:
            self._pending -= 1
        code, body = self._handle_to_wire(h, rid)
        if code == 200:
            self.served += 1
        return code, body, {}

    async def _serve_batch(self, obj) -> tuple[int, dict, dict]:
        if (not isinstance(obj, dict) or obj.get("v") != WIRE_VERSION
                or not isinstance(obj.get("requests"), list)):
            return 400, {"error": 'batch body must be {"v": 2, '
                         '"requests": [...]}', "v": WIRE_VERSION}, {}
        try:
            parsed = [parse_wire_request(r) for r in obj["requests"]]
        except WireError as exc:
            return exc.code, exc.body, {}
        if not parsed:
            return 200, {"v": WIRE_VERSION, "results": []}, {}
        try:
            self._admit(parsed[0][0], n=len(parsed))
        except _Shed as exc:
            return exc.code, {
                "error": str(exc), "v": WIRE_VERSION,
                "retry_after_s": exc.retry_after,
            }, {"Retry-After": f"{max(exc.retry_after, 0.001):.3f}"}
        self._pending += len(parsed)
        try:
            # one worker, atomic list-order admission, then flush: the
            # bitwise answer_batch-identity contract (module docstring)
            worker = self.pool.worker_for(parsed[0][0])
            handles = worker.queue.submit_many([q for q, _ in parsed])
            worker.queue.flush()
            hs = [await self._bridge(h) for h in handles]
        except (KeyError, ValueError) as exc:
            # unknown network/node surfaced by routing normalization
            return 400, error_body(exc), {}
        finally:
            self._pending -= len(parsed)
        results = []
        for h, (_, rid) in zip(hs, parsed):
            code, body = self._handle_to_wire(h, rid)
            if code == 200:
                self.served += 1
            results.append(body)
        return 200, {"v": WIRE_VERSION, "results": results}, {}

    # -- plain endpoints ---------------------------------------------------
    def _healthz(self) -> tuple[int, dict, dict]:
        up = {n: not w.dead for n, w in self.pool.workers.items()}
        code = 200 if any(up.values()) else 503
        return code, {"ok": any(up.values()), "workers": up,
                      "pending": self._pending}, {}

    def _stats(self) -> tuple[int, dict, dict]:
        return 200, {
            "v": WIRE_VERSION, "pending": self._pending,
            "served": self.served, "shed": dict(self.shed),
            "workers": self.pool.stats()}, {}

    def _metrics_text(self) -> str:
        lines = [
            "# TYPE serve_front_pending gauge",
            f"serve_front_pending {self._pending}",
            "# TYPE serve_front_served_total counter",
            f"serve_front_served_total {self.served}",
            "# TYPE serve_front_shed_total counter",
        ]
        lines += [f'serve_front_shed_total{{reason="{r}"}} {n}'
                  for r, n in sorted(self.shed.items())]
        for w in self.pool.workers.values():
            if not w.dead:
                text = w.engine.telemetry.prometheus()
                if text:
                    lines.append(text.rstrip("\n"))
        return "\n".join(lines) + "\n"

    # -- HTTP plumbing -----------------------------------------------------
    async def _on_connection(self, reader, writer) -> None:
        try:
            while True:
                head = await self._read_head(reader)
                if head is None:
                    break
                method, path, headers = head
                if (path == "/v2/stream"
                        and "websocket" in headers.get(
                            "upgrade", "").lower()):
                    await self._websocket(reader, writer, headers)
                    return
                body = b""
                n = int(headers.get("content-length", 0))
                if n:
                    if n > _MAX_BODY:
                        await self._respond(writer, 413, {
                            "error": "body too large", "v": WIRE_VERSION})
                        break
                    body = await reader.readexactly(n)
                keep = headers.get("connection", "").lower() != "close"
                code, payload, extra = await self._route(
                    method, path, body)
                await self._respond(writer, code, payload, extra,
                                    keep_alive=keep)
                if not keep:
                    break
        except (asyncio.IncompleteReadError, ConnectionError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _read_head(self, reader):
        line = await reader.readline()
        if not line:
            return None
        try:
            method, path, _version = line.decode("latin1").split()
        except ValueError:
            raise ConnectionError("malformed request line")
        headers: dict[str, str] = {}
        for _ in range(_MAX_HEADERS):
            raw = await reader.readline()
            if raw in (b"\r\n", b"\n", b""):
                break
            name, _, value = raw.decode("latin1").partition(":")
            headers[name.strip().lower()] = value.strip()
        else:
            raise ConnectionError("too many headers")
        return method, path.split("?", 1)[0], headers

    async def _route(self, method: str, path: str,
                     body: bytes) -> tuple[int, object, dict]:
        if path == "/healthz":
            return self._healthz()
        if path == "/stats":
            return self._stats()
        if path == "/metrics":
            return 200, self._metrics_text(), {}
        if method != "POST":
            return 405, {"error": f"{method} {path} not supported",
                         "v": WIRE_VERSION}, {}
        try:
            obj = json.loads(body.decode() or "null")
        except (ValueError, UnicodeDecodeError):
            return 400, {"error": "body is not valid JSON",
                         "v": WIRE_VERSION}, {}
        try:
            if path == "/v2/query":
                return await self._serve_one(obj)
            if path == "/v2/batch":
                return await self._serve_batch(obj)
            if path == "/v2/flush":
                self.pool.flush()
                return 200, {"v": WIRE_VERSION, "flushed": True}, {}
        except Exception as exc:
            # last-resort containment: a handler bug must produce a 500
            # body, never a silently dropped connection
            return 500, error_body(exc), {}
        return 404, {"error": f"no such endpoint {path!r}",
                     "v": WIRE_VERSION}, {}

    async def _respond(self, writer, code: int, payload, extra=None, *,
                       keep_alive: bool = True) -> None:
        if isinstance(payload, str):
            data, ctype = payload.encode(), "text/plain; version=0.0.4"
        else:
            data = json.dumps(payload).encode()
            ctype = "application/json"
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  405: "Method Not Allowed", 413: "Payload Too Large",
                  429: "Too Many Requests", 500: "Internal Server Error",
                  503: "Service Unavailable"}.get(code, "Status")
        head = [f"HTTP/1.1 {code} {reason}",
                f"Content-Type: {ctype}",
                f"Content-Length: {len(data)}",
                f"Connection: {'keep-alive' if keep_alive else 'close'}"]
        head += [f"{k}: {v}" for k, v in (extra or {}).items()]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + data)
        await writer.drain()

    # -- WebSocket (RFC 6455) ----------------------------------------------
    async def _websocket(self, reader, writer, headers) -> None:
        key = headers.get("sec-websocket-key")
        if not key:
            await self._respond(writer, 400, {
                "error": "missing Sec-WebSocket-Key", "v": WIRE_VERSION})
            return
        accept = base64.b64encode(hashlib.sha1(
            (key + _WS_GUID).encode()).digest()).decode()
        writer.write((
            "HTTP/1.1 101 Switching Protocols\r\n"
            "Upgrade: websocket\r\nConnection: Upgrade\r\n"
            f"Sec-WebSocket-Accept: {accept}\r\n\r\n").encode())
        await writer.drain()
        send_lock = asyncio.Lock()
        tasks: set[asyncio.Task] = set()

        async def send_json(obj) -> None:
            async with send_lock:
                await self._ws_send(writer, json.dumps(obj).encode())

        async def serve(obj) -> None:
            try:
                code, body, extra = await self._serve_one(obj)
            except Exception as exc:
                # a handler bug must still answer this frame's id —
                # dropping it would hang the client's collect loop
                code, body, extra = 500, error_body(exc), {}
            if extra.get("Retry-After"):
                body.setdefault("retry_after_s",
                                float(extra["Retry-After"]))
            if isinstance(obj, dict) and "id" in obj:
                body.setdefault("id", obj["id"])
            body.setdefault("status", code)
            await send_json(body)

        try:
            while True:
                frame = await self._ws_recv(reader)
                if frame is None:          # close frame or EOF
                    break
                try:
                    obj = json.loads(frame.decode())
                except (ValueError, UnicodeDecodeError):
                    await send_json({"error": "frame is not valid JSON",
                                     "v": WIRE_VERSION, "status": 400})
                    continue
                t = asyncio.ensure_future(serve(obj))
                tasks.add(t)
                t.add_done_callback(tasks.discard)
            if tasks:                      # drain in-flight before close
                await asyncio.gather(*tasks, return_exceptions=True)
            async with send_lock:
                await self._ws_send(writer, b"", opcode=0x8)
        except (asyncio.IncompleteReadError, ConnectionError):
            for t in tasks:
                t.cancel()

    @staticmethod
    async def _ws_recv(reader) -> bytes | None:
        """One complete message (handles continuation frames); None on
        close/EOF.  Client frames must be masked (RFC 6455 §5.1)."""
        message = b""
        while True:
            try:
                b0, b1 = await reader.readexactly(2)
            except asyncio.IncompleteReadError:
                return None
            opcode, fin = b0 & 0x0F, b0 & 0x80
            masked, length = b1 & 0x80, b1 & 0x7F
            if length == 126:
                (length,) = struct.unpack(
                    ">H", await reader.readexactly(2))
            elif length == 127:
                (length,) = struct.unpack(
                    ">Q", await reader.readexactly(8))
            mask = await reader.readexactly(4) if masked else b""
            payload = await reader.readexactly(length)
            if mask:
                payload = bytes(
                    c ^ mask[i % 4] for i, c in enumerate(payload))
            if opcode == 0x8:              # close
                return None
            if opcode == 0x9:              # ping — unanswered pings are
                continue                   # fine for a localhost bench
            message += payload
            if fin:
                return message

    @staticmethod
    async def _ws_send(writer, payload: bytes, *, opcode: int = 0x1) -> None:
        head = bytes([0x80 | opcode])
        n = len(payload)
        if n < 126:
            head += bytes([n])
        elif n < (1 << 16):
            head += bytes([126]) + struct.pack(">H", n)
        else:
            head += bytes([127]) + struct.pack(">Q", n)
        writer.write(head + payload)
        await writer.drain()


def start_in_thread(pool: WorkerPool, **kwargs) -> ServeFrontEnd:
    """Run a :class:`ServeFrontEnd` on a daemon-thread event loop;
    returns once the socket is listening (read :attr:`ServeFrontEnd.
    port` for the bound port).  Stop it with ``fe.stop_thread()``.
    The in-process form used by tests and ``bench_serve`` — the CLI's
    ``--serve`` runs the loop in the main thread instead."""
    fe = ServeFrontEnd(pool, **kwargs)
    started = threading.Event()

    async def main() -> None:
        await fe.start()
        started.set()
        await fe._stopping.wait()
        await fe.stop()

    thread = threading.Thread(
        target=lambda: asyncio.run(main()), name="serve-front-end",
        daemon=True)
    thread.start()
    if not started.wait(timeout=30):
        raise RuntimeError("server failed to start within 30s")

    def stop_thread(timeout: float | None = 30) -> None:
        loop = fe._loop
        if loop is not None and not loop.is_closed():
            try:
                loop.call_soon_threadsafe(fe._stopping.set)
            except RuntimeError:
                pass
        thread.join(timeout)

    fe.stop_thread = stop_thread  # type: ignore[attr-defined]
    return fe
