"""Multi-worker topology: engines behind a consistent-hash router.

One :class:`Worker` owns one :class:`repro.serve.engine.PosteriorEngine`
plus its :class:`repro.serve.queue.AdmissionQueue` — the analogue of one
AIA chip (16-core mesh + host scheduler); a :class:`WorkerPool` is the
rack.  Routing is a consistent-hash ring over the **plan key**
``(network, evidence-pattern, mode)``:

* queries sharing a plan land on the same worker, so its in-memory plan
  cache and XLA jit cache stay hot (the whole point of plan caching —
  spraying a pattern across workers would compile it everywhere);
* ``stream_id`` queries are pinned by ``(network, stream_id)`` instead —
  slice ``t+1`` must find slice ``t``'s retained chains, which live in
  worker-local memory;
* adding/removing a worker only remaps ~1/N of the key space (virtual
  nodes keep the split even), so a rolling restart doesn't flush every
  cache at once.

Workers can share a *persisted* plan-cache directory
(``plan_cache_dir``): compiles are written atomically
(tmp + ``os.replace``), so the first worker to compile a plan persists
it for everyone and a worker taking over a remapped key usually
warm-starts from disk.

Fault injection: :meth:`Worker.kill` makes the worker unroutable and
aborts its queue — pending (never-dispatched) queries fail with
:class:`WorkerDied` (``resubmit=True``: safe to replay on another
worker), in-flight ones with ``resubmit=False`` (they fail loudly; the
front end reports the death instead of silently re-running work that
may have streamed partial effects).  Either way no ``QueryHandle`` is
left hanging.  :meth:`WorkerPool.submit` resubmits the resubmittable
kind automatically.
"""
from __future__ import annotations

import bisect
import hashlib
import threading

from repro.serve.queue import AdmissionQueue
from repro.serve.query import QueryHandle, Request

__all__ = ["HashRing", "Worker", "WorkerDied", "WorkerPool"]


class WorkerDied(RuntimeError):
    """A worker died with queries on it.  ``resubmit`` says whether the
    query is safe to replay on another worker (True for queries that
    never left the dead worker's buckets)."""

    def __init__(self, message: str, *, resubmit: bool = False):
        super().__init__(message)
        self.resubmit = resubmit


class HashRing:
    """Consistent-hash ring with virtual nodes (sha1 keyed).

    >>> ring = HashRing(["w0", "w1", "w2"])
    >>> ring.lookup(("asia", (1, 2), "marginals")) in {"w0", "w1", "w2"}
    True
    >>> ring.lookup("k") == ring.lookup("k")      # deterministic
    True
    >>> # skipping a dead member walks to the next point, same ring
    >>> alive = [n for n in ["w0", "w1", "w2"]
    ...          if n != ring.lookup("k")]
    >>> ring.lookup("k", accept=alive.__contains__) in alive
    True
    """

    def __init__(self, members: list[str], *, replicas: int = 64):
        if not members:
            raise ValueError("empty ring")
        self._points: list[tuple[int, str]] = sorted(
            (self._hash(f"{name}#{i}"), name)
            for name in members for i in range(replicas))

    @staticmethod
    def _hash(key) -> int:
        return int.from_bytes(
            hashlib.sha1(repr(key).encode()).digest()[:8], "big")

    def lookup(self, key, *, accept=None) -> str:
        """Ring member owning ``key``; with ``accept``, the first owner
        (walking clockwise) that ``accept(name)`` approves — how the
        pool skips dead or excluded workers without re-hashing."""
        h = self._hash(key)
        i = bisect.bisect_right(self._points, (h, ""))
        seen: set[str] = set()
        for j in range(len(self._points)):
            _, name = self._points[(i + j) % len(self._points)]
            if name in seen:
                continue
            if accept is None or accept(name):
                return name
            seen.add(name)
        raise WorkerDied("no live worker accepts this key", resubmit=True)


class Worker:
    """One engine + admission queue, addressable by name."""

    def __init__(self, name: str, engine, *, queue_kwargs: dict | None = None):
        self.name = name
        self.engine = engine
        self.queue = AdmissionQueue(engine, **(queue_kwargs or {}))
        self.dead = False

    def submit(self, query: Request) -> QueryHandle:
        if self.dead:
            raise WorkerDied(f"worker {self.name} is dead", resubmit=True)
        return self.queue.submit(query)

    def kill(self, reason: str = "killed", *,
             timeout: float | None = 60.0) -> None:
        """Fault injection / hard shutdown: stop routing to this worker
        and abort its queue (see module docstring for who gets which
        error).  Idempotent."""
        if self.dead:
            return
        self.dead = True
        self.queue.abort(
            WorkerDied(f"worker {self.name} died before dispatching the "
                       f"query ({reason}); resubmit it", resubmit=True),
            inflight_error=WorkerDied(
                f"worker {self.name} died mid-group ({reason})",
                resubmit=False),
            timeout=timeout)

    def close(self, *, drain: bool = True,
              timeout: float | None = None) -> None:
        if not self.dead:
            self.queue.close(drain=drain, timeout=timeout)
            self.dead = True


class WorkerPool:
    """N workers behind the consistent-hash router.

    ``engine_factory(name) -> PosteriorEngine`` builds each worker's
    engine — every engine must register the same model names (routing
    normalizes queries against whichever live engine it asks first).
    ``queue_kwargs`` are forwarded to every worker's
    :class:`AdmissionQueue` (e.g. ``{"scheduler": "deadline"}``).

    >>> # doctest-light: routing math only, no engines
    >>> WorkerPool.plan_route_key  # doctest: +ELLIPSIS
    <function WorkerPool.plan_route_key at ...>
    """

    def __init__(self, engine_factory, n_workers: int = 2, *,
                 queue_kwargs: dict | None = None):
        if n_workers < 1:
            raise ValueError("need at least one worker")
        names = [f"w{i}" for i in range(n_workers)]
        self.workers = {
            name: Worker(name, engine_factory(name),
                         queue_kwargs=queue_kwargs)
            for name in names}
        self.ring = HashRing(names)
        self._lock = threading.Lock()

    # -- routing -----------------------------------------------------------
    @staticmethod
    def plan_route_key(query: Request, engine) -> tuple:
        """The ring key of a query: ``("stream", network, stream_id)``
        for temporal streams (pinned where the retained chains live),
        else the plan key ``(network, pattern, mode)`` (pinned where the
        compiled plan is warm)."""
        sid = getattr(query, "stream_id", None)
        if sid is not None:
            return ("stream", query.network, sid)
        _, _, _, pattern = engine.normalize(query)
        return (query.network, pattern, getattr(query, "mode", "marginals"))

    def _live(self) -> list[Worker]:
        return [w for w in self.workers.values() if not w.dead]

    def worker_for(self, query: Request, *, exclude=frozenset()) -> Worker:
        live = self._live()
        if not live:
            raise WorkerDied("no live workers", resubmit=False)
        key = self.plan_route_key(query, live[0].engine)
        name = self.ring.lookup(
            key, accept=lambda n: (not self.workers[n].dead
                                   and n not in exclude))
        return self.workers[name]

    def submit(self, query: Request, *,
               exclude=frozenset()) -> tuple[Worker, QueryHandle]:
        """Route and submit; retries on a worker that dies in the
        submit race (its pending queries are resubmittable by
        definition).  Returns ``(worker, handle)`` so the caller can
        watch for that worker's death."""
        tried = set(exclude)
        while True:
            w = self.worker_for(query, exclude=tried)
            try:
                return w, w.submit(query)
            except (WorkerDied, RuntimeError):
                # died (or closed its queue) between lookup and submit
                tried.add(w.name)

    # -- lifecycle ---------------------------------------------------------
    def kill(self, name: str, reason: str = "killed", *,
             timeout: float | None = 60.0) -> None:
        self.workers[name].kill(reason, timeout=timeout)

    def flush(self) -> None:
        for w in self._live():
            w.queue.flush()

    def close(self, *, drain: bool = True,
              timeout: float | None = None) -> None:
        for w in self.workers.values():
            w.close(drain=drain, timeout=timeout)

    def stats(self) -> dict:
        return {name: {"dead": w.dead,
                       **({} if w.dead else w.engine.stats())}
                for name, w in self.workers.items()}
