"""Async admission queue: micro-batching under *streaming* traffic.

``answer_batch`` exploits chain-lane packing only when the caller hands
it a pre-assembled batch; real serving traffic arrives one query at a
time from many clients.  :class:`AdmissionQueue` closes that gap — the
serving analogue of AIA's compiler keeping 16 cores busy from a stream
of independent programs (paper §III): incoming queries accumulate in
per-``(network, evidence-pattern, mode)`` buckets (marginal and MAP
groups run different round programs, so they never share lanes), and a
bucket dispatches as one packed :class:`repro.serve.engine.GroupRun`
when either

* a **deadline** fires — the bucket's oldest query has waited
  ``max_wait_ms`` (bounds tail latency under trickle traffic), or
* a **size trigger** fires — the bucket can fill ``max_group_lanes``
  chain lanes (defaults to a multiple of the mesh's
  ``serve_lane_multiple``, so a full group shards without padding).

Each ``submit`` returns a :class:`repro.serve.query.QueryHandle`
supporting blocking ``result()`` and per-query ``cancel()`` — honoured
immediately pre-dispatch, and at the next round boundary mid-flight.
Because the engine retires queries individually on convergence (the
rank-normalized R̂ + ESS rule by default — see
:mod:`repro.pgm.diagnostics`), a converged (or cancelled) query frees
its chain lanes mid-flight and the queue *backfills* them with waiting
queries of the same plan — lanes stay hot instead of idling until the
slowest group member converges.

Temporal filtering (``Request.stream_id``) adds one scheduling rule:
slices of the same stream are *serialized* — a dispatch (or backfill)
never takes a stream's next slice while an earlier slice of that stream
is still queued in the same batch or running, because slice ``t+1``
warm-starts from slice ``t``'s retained chains and must therefore
observe its retirement.  Distinct streams still pack together freely.

Single dispatcher thread; the queue owns the engine while open (do not
call ``answer_batch`` on the same engine concurrently).  Buckets are
served FIFO by their oldest arrival, so no evidence pattern starves.

Two schedulers (the ``scheduler`` parameter): ``"fifo"`` is the
arrival-order policy above; ``"deadline"`` is earliest-deadline-first
over queries carrying ``Request.deadline_ms`` — they order dispatch and
backfill ahead of best-effort traffic (which keeps FIFO fairness among
itself), a bucket holding an SLO query ripens early enough to start it,
and a running group whose ESS trajectory says it still needs service is
*preempted* (unfinished queries re-queued, progress discarded) when a
strictly more urgent deadline is waiting.  ``abort(error)`` is the
worker-death path: everything pending or in-flight fails loudly with
``error`` instead of hanging its ``QueryHandle`` forever.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from dataclasses import dataclass, field

from repro.serve.engine import GroupEntry, GroupRun, PosteriorEngine
from repro.serve.query import (  # noqa: F401
    MrfQuery, Query, QueryHandle, QueryStatus, Request)
from repro.serve.sched import deadline_order
from repro.serve.telemetry import monotonic
from repro.sharding.specs import serve_lane_multiple

SCHEDULERS = ("fifo", "deadline")

# Default size trigger, in queries, per dispatch group (scaled by the
# mesh width so a full group's lane count is shard-aligned).
DEFAULT_GROUP_QUERIES = 8

# dispatch_log is a diagnostics ring, not an audit trail — bounded so a
# long-lived queue doesn't leak one tuple per group forever
DISPATCH_LOG_MAXLEN = 256


@dataclass
class QueueStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    cancelled_pending: int = 0
    cancelled_in_flight: int = 0
    dispatched_groups: int = 0
    backfilled: int = 0
    preempted: int = 0
    # (network, pattern, n_queries) of recent dispatched groups, in order
    dispatch_log: deque = field(
        default_factory=lambda: deque(maxlen=DISPATCH_LOG_MAXLEN))

    def snapshot(self) -> dict:
        """JSON-able dump (the dispatch ring becomes a plain list of
        ``[network, n_queries]`` pairs — patterns can be kilo-int pixel
        masks, too bulky for a stats snapshot)."""
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "cancelled_pending": self.cancelled_pending,
            "cancelled_in_flight": self.cancelled_in_flight,
            "dispatched_groups": self.dispatched_groups,
            "backfilled": self.backfilled,
            "preempted": self.preempted,
            "dispatch_log": [[name, n] for name, _, n in self.dispatch_log],
        }


class AdmissionQueue:
    """Streaming front door of a :class:`PosteriorEngine`.

    Parameters
    ----------
    max_wait_ms:
        Deadline trigger — a bucket flushes once its oldest query has
        waited this long (the latency/batching trade-off knob).
    max_group_lanes:
        Size trigger — a bucket flushes as soon as its queries fill
        this many chain lanes.  Defaults to ``DEFAULT_GROUP_QUERIES *
        chains_per_query * serve_lane_multiple(mesh)``.
    backfill:
        Re-use the lanes of retired (converged/cancelled) queries for
        waiting queries of the same plan mid-flight.
    scheduler:
        ``"fifo"`` (arrival order, the default) or ``"deadline"``
        (earliest-deadline-first over ``Request.deadline_ms``, with
        ESS-trajectory-driven preemption — see the module docstring).

    Example::

        queue = AdmissionQueue(engine, max_wait_ms=20.0)
        handle = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",)))
        handle.result(timeout=60).marginal("rain")
        queue.close()
    """

    def __init__(self, engine: PosteriorEngine, *, max_wait_ms: float = 10.0,
                 max_group_lanes: int | None = None, backfill: bool = True,
                 scheduler: str = "fifo"):
        if scheduler not in SCHEDULERS:
            raise ValueError(f"scheduler {scheduler!r} not in {SCHEDULERS}")
        self.engine = engine
        self.scheduler = scheduler
        self.max_wait_s = float(max_wait_ms) / 1e3
        c = engine.chains_per_query
        if max_group_lanes is None:
            max_group_lanes = (
                DEFAULT_GROUP_QUERIES * c * serve_lane_multiple(engine.mesh))
        self.max_group_queries = max(1, int(max_group_lanes) // c)
        self.backfill = bool(backfill)
        self.stats = QueueStats()
        self.tel = engine.telemetry
        engine._attached_queue = self  # PosteriorEngine.stats() snapshot
        self._buckets: dict[tuple, deque[GroupEntry]] = {}
        self._cv = threading.Condition()
        self._closed = False
        self._abort_exc: BaseException | None = None
        self._flush_before = -1.0  # flush(): entries at/before this are ripe
        self._inflight: list[GroupEntry] = []  # current group, under _cv
        self._thread = threading.Thread(
            target=self._run, name="admission-queue", daemon=True)
        self._thread.start()

    # -- client side -------------------------------------------------------
    def submit(self, query: Request) -> QueryHandle:
        """Admit one query; returns its future.  Raises immediately on
        malformed queries (unknown network, bad evidence, observed
        query vars) — validation must not wait for the dispatcher."""
        _, ev, qvars, pattern = self.engine.normalize(query)
        handle = QueryHandle(query, on_cancel=self._cancel_pending)
        entry = GroupEntry(query, ev, qvars, handle=handle)
        tel = self.tel
        if tel.enabled:
            entry.tel_tid = tel.track(
                f"query#{next(self.engine._query_seq)} {query.network}")
        with self._cv:
            if self._closed:
                raise RuntimeError("queue is closed")
            self._buckets.setdefault(
                (query.network, pattern,
                 getattr(query, "mode", "marginals")),
                deque()).append(entry)
            self.stats.submitted += 1
            depth = sum(len(d) for d in self._buckets.values())
            self._cv.notify_all()
        if tel.enabled:
            tel.instant("submit", entry.tel_tid, network=query.network)
            tel.count("serve_queries_submitted_total",
                      help="queries admitted to the queue")
            tel.gauge_set("serve_queue_depth", depth,
                          help="queries waiting in dispatch buckets")
            tel.sample("queue_depth", depth)
        return handle

    def submit_many(self, queries: "list[Request]") -> list[QueryHandle]:
        """Admit a list atomically: every query enters its bucket under
        one lock hold (the condition lock is reentrant), so the
        dispatcher cannot wake mid-list and split the batch into
        different groups than ``answer_batch``'s insertion-order
        grouping would form — the served-vs-in-process bitwise-identity
        contract of the HTTP ``/v2/batch`` endpoint."""
        with self._cv:
            return [self.submit(q) for q in queries]

    def pending(self) -> int:
        with self._cv:
            return sum(len(d) for d in self._buckets.values())

    def warm(self, traffic: list) -> None:
        """Pre-compile, off the serving clock, every (plan, lane-shape)
        combination streamed dispatch of ``traffic`` can produce: one
        query per distinct (network, evidence-pattern), answered at each
        pow2 group size up to this queue's size trigger.  Call before
        the first ``submit`` — it drives the engine from the caller's
        thread, which is only safe while the dispatcher is idle."""
        seen: dict[tuple, object] = {}
        for q in traffic:
            _, _, _, pattern = self.engine.normalize(q)
            # mode keys the probe too: MAP groups trace the annealed
            # (4-arg) round program, a distinct XLA build per plan
            seen.setdefault(
                (q.network, pattern, getattr(q, "mode", "marginals")), q)
        for q in seen.values():
            # minimal-budget probe: compiling the (plan, shape) is the
            # point — n_samples=1 clamps each rung to min_rounds instead
            # of sampling the caller's full budget per shape.  replace()
            # keeps this family-agnostic (Query and MrfQuery alike).
            # stream_id is stripped: a probe must not retain chains that
            # would warm-start the stream's real first slice off-protocol.
            probe = dataclasses.replace(q, n_samples=1, stream_id=None)
            n = 1
            while True:
                # a full pop of max_group_queries pads to the pow2 above
                # it, so the ladder must cover that ceiling too (e.g.
                # max 24 -> shapes 1,2,4,8,16 and 32-via-24)
                self.engine.answer_batch(
                    [probe] * min(n, self.max_group_queries))
                if n >= self.max_group_queries:
                    break
                n *= 2

    def flush(self) -> None:
        """Make everything currently pending dispatchable now, ignoring
        deadlines (queries submitted *after* the flush keep theirs)."""
        with self._cv:
            self._flush_before = monotonic()
            self._cv.notify_all()

    def close(self, *, drain: bool = True, timeout: float | None = None):
        """Stop accepting queries.  ``drain=True`` dispatches everything
        still pending first; ``drain=False`` cancels pending *and*
        in-flight queries (the dispatcher honours the in-flight
        cancellations at the next round boundary, so close does not
        block on a slow-converging group running out its cap)."""
        with self._cv:
            self._closed = True
            if not drain:
                for dq in self._buckets.values():
                    for e in dq:
                        e.handle._finish(QueryStatus.CANCELLED)
                        self.stats.cancelled_pending += 1
                        self._tel_done(e, "cancelled")
                self._buckets.clear()
                for e in self._inflight:
                    e.handle.cancel_requested = True
            self._cv.notify_all()
        self._thread.join(timeout)

    def abort(self, error: BaseException, *,
              inflight_error: BaseException | None = None,
              timeout: float | None = None) -> None:
        """Fail everything loudly — the worker-death path.  Pending
        queries resolve FAILED with ``error`` immediately; the in-flight
        group observes the abort at its next round boundary and fails
        its unresolved queries with ``inflight_error`` (default: the
        same ``error`` — the split lets a worker mark pending queries
        as safely resubmittable while in-flight ones are not).  No
        ``QueryHandle`` is ever left hanging.  The queue is closed
        afterwards."""
        with self._cv:
            self._closed = True
            self._abort_exc = inflight_error if inflight_error is not None \
                else error
            for dq in self._buckets.values():
                for e in dq:
                    e.handle._finish(QueryStatus.FAILED, error=error)
                    self.stats.failed += 1
                    self._tel_done(e, "failed")
            self._buckets.clear()
            self._cv.notify_all()
        self._thread.join(timeout)

    def __enter__(self) -> "AdmissionQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    def _tel_done(self, e: GroupEntry, status: str) -> None:
        """Delivery-side telemetry for one resolved entry: the finished
        counter (by status), the end-to-end latency histogram, and a
        ``deliver`` instant on the query's trace track."""
        tel = self.tel
        if not tel.enabled:
            return
        tel.count("serve_queries_finished_total",
                  help="queries resolved, by final status", status=status)
        h = e.handle
        if h.t_done is not None:
            tel.observe("serve_e2e_seconds", h.t_done - h.t_submit,
                        help="submit-to-delivery seconds per query")
        tel.instant("deliver", e.tel_tid, status=status)

    # -- cancellation ------------------------------------------------------
    def _cancel_pending(self, handle: QueryHandle) -> None:
        """Pre-dispatch path of ``handle.cancel()``: unlink from the
        bucket and resolve now.  If the query already left its bucket,
        the dispatcher honours ``cancel_requested`` at the next round
        boundary instead."""
        with self._cv:
            for key, dq in self._buckets.items():
                for e in dq:
                    if e.handle is handle:
                        dq.remove(e)
                        if not dq:
                            del self._buckets[key]
                        handle._finish(QueryStatus.CANCELLED)
                        self.stats.cancelled_pending += 1
                        self._tel_done(e, "cancelled")
                        return

    # -- dispatcher --------------------------------------------------------
    def _bucket_wake(self, dq: deque) -> float:
        """Absolute clock time at which this bucket ripens by waiting:
        the oldest arrival plus ``max_wait_ms`` — and, under the
        deadline scheduler, early enough before the bucket's most
        urgent SLO deadline that the query can still start on time."""
        wake = dq[0].handle.t_submit + self.max_wait_s
        if self.scheduler == "deadline":
            for e in dq:
                d = e.handle.deadline
                if d is not None:
                    wake = min(wake, d - self.max_wait_s)
        return wake

    def _ripe(self, dq: deque, now: float) -> bool:
        return (len(dq) >= self.max_group_queries
                or now >= self._bucket_wake(dq)
                or dq[0].handle.t_submit <= self._flush_before
                or self._closed)

    def _select_locked(self, dq: deque, n: int,
                       exclude_streams=frozenset()):
        """Up to ``n`` dispatchable entries of one bucket (in dispatch
        order), plus the entries left behind (in arrival order).

        Same-stream serialization: at most one slice per ``stream_id``
        is taken — and only the stream's *earliest-arrival* pending
        slice, so EDF reordering can never dispatch slice ``t+1``
        before slice ``t`` (it warm-starts from ``t``'s retired
        chains).  Later slices stay queued in order.

        Under the deadline scheduler the take order is earliest-
        deadline-first (:func:`repro.serve.sched.deadline_order`);
        best-effort entries keep arrival order behind SLO ones."""
        order = list(dq)
        first: dict[str, int] = {}
        for e in order:
            sid = getattr(e.query, "stream_id", None)
            if sid is not None and sid not in first:
                first[sid] = id(e)
        if self.scheduler == "deadline":
            pos = {id(e): i for i, e in enumerate(order)}
            order.sort(key=lambda e: (deadline_order(e.handle), pos[id(e)]))
        batch: list[GroupEntry] = []
        taken: set[int] = set()
        streams: set[str] = set(exclude_streams)
        for e in order:
            if len(batch) >= n:
                break
            sid = getattr(e.query, "stream_id", None)
            if sid is not None and (sid in streams or first[sid] != id(e)):
                continue
            if sid is not None:
                streams.add(sid)
            batch.append(e)
            taken.add(id(e))
        held = [e for e in dq if id(e) not in taken]
        return batch, held

    def _bucket_urgency(self, dq: deque, exclude_streams=frozenset()):
        """EDF rank of a bucket: the most urgent entry that could
        actually dispatch right now — a stream's non-first pending slice
        (or a slice of a stream in ``exclude_streams``) is *blocked*
        behind its predecessor, so its deadline must not drive bucket
        choice or preemption (ranking on a blocked slice livelocks: the
        bucket keeps winning the pop, keeps dispatching only its
        best-effort head, and keeps being preempted for the urgent
        slice that still cannot run).  None if every entry is blocked."""
        best = None
        seen: set[str] = set()
        for e in dq:
            sid = getattr(e.query, "stream_id", None)
            if sid is not None:
                blocked = sid in seen or sid in exclude_streams
                seen.add(sid)
                if blocked:
                    continue
            d = deadline_order(e.handle)
            if best is None or d < best:
                best = d
        return best

    def _pop_ready_locked(self):
        """A ripe bucket popped up to the size trigger; None if nothing
        is ripe.  Bucket choice is FIFO by oldest arrival (no evidence
        pattern starves) — or, under the deadline scheduler, the bucket
        holding the most urgent *dispatchable* entry (EDF across
        patterns)."""
        now = monotonic()
        ready = [key for key, dq in self._buckets.items()
                 if self._ripe(dq, now)]
        if not ready:
            return None
        if self.scheduler == "deadline":
            key = min(ready, key=lambda k: self._bucket_urgency(
                self._buckets[k]) or (2, 0.0))
        else:
            key = min(ready,
                      key=lambda k: self._buckets[k][0].handle.t_submit)
        dq = self._buckets[key]
        batch, held = self._select_locked(dq, self.max_group_queries)
        if held:
            self._buckets[key] = deque(held)
        else:
            del self._buckets[key]
        return key, batch

    def _next_deadline_locked(self) -> float | None:
        if not self._buckets:
            return None
        wake = min(self._bucket_wake(dq) for dq in self._buckets.values())
        return max(0.0, wake - monotonic())

    def _other_bucket_ripe(self, key: tuple) -> bool:
        """True if some *other* plan's bucket is already dispatchable —
        backfill yields to it so one hot pattern cannot starve the rest
        (FIFO fairness across evidence patterns)."""
        now = monotonic()
        with self._cv:
            return any(k != key and self._ripe(dq, now)
                       for k, dq in self._buckets.items())

    def _take_pending(self, key: tuple, n: int,
                      exclude_streams=frozenset()) -> list[GroupEntry]:
        """Up to ``n`` waiting entries of one plan bucket, for backfill.

        ``exclude_streams`` holds the stream ids still running in the
        dispatching group: their next slices are left queued (in order)
        until the running slice retires and retains its chains.  Under
        the deadline scheduler the backfill order is EDF, same as
        dispatch."""
        with self._cv:
            dq = self._buckets.get(key)
            if not dq:
                return []
            alive = deque()
            for e in dq:
                if e.handle.cancel_requested:
                    e.handle._finish(QueryStatus.CANCELLED)
                    self.stats.cancelled_pending += 1
                    self._tel_done(e, "cancelled")
                else:
                    alive.append(e)
            out, held = self._select_locked(alive, n, exclude_streams)
            if held:
                self._buckets[key] = deque(held)
            else:
                del self._buckets[key]
        return out

    def _run(self) -> None:
        while True:
            with self._cv:
                item = self._pop_ready_locked()
                while item is None:
                    if self._closed and not self._buckets:
                        return
                    self._cv.wait(self._next_deadline_locked())
                    item = self._pop_ready_locked()
                # registered under the SAME lock hold that popped the
                # batch: a close(drain=False) can never observe queries
                # that left their bucket but aren't in-flight yet
                self._inflight = list(item[1])
            key, batch = item
            self._dispatch(key, batch)

    def _dispatch(self, key: tuple, batch: list[GroupEntry]) -> None:
        name, pattern = key[0], key[1]
        for e in batch:
            e.handle._mark_running()
        try:
            self._dispatch_run(key, name, pattern, batch)
        finally:
            with self._cv:
                self._inflight = []

    def _group_run(self, name, pattern, batch) -> GroupRun:
        """Group-run factory — the test seam: fault-injection and
        property tests substitute a fake run (same step/cancel/admit
        surface) so scheduling invariants are checked without paying
        for real compilation/sampling."""
        return GroupRun(self.engine, name, pattern, batch)

    def _preempt_run(self, key: tuple, run) -> bool:
        """EDF preemption (deadline scheduler only): when some *other*
        ripe bucket holds an SLO deadline strictly more urgent than
        anything still running in this group, and the group's ESS
        trajectory says it still needs service, re-queue the group's
        unfinished queries (status back to QUEUED, progress discarded)
        and yield the lanes.  Returns True when the run was vacated."""
        if self.scheduler != "deadline":
            return False
        now = monotonic()
        with self._cv:
            live = [s.entry for s in run.slots
                    if not s.done and s.entry is not None]
            busy = {sid for sid in (
                getattr(e.query, "stream_id", None) for e in live)
                if sid is not None}
            best = None
            for k, dq in self._buckets.items():
                if k == key or not self._ripe(dq, now):
                    continue
                # rank on dispatchable entries only: a slice blocked
                # behind this very group cannot start even if we yield
                d = self._bucket_urgency(dq, exclude_streams=busy)
                if d is not None and (best is None or d < best):
                    best = d
            if best is None or best[0] == 1:
                return False  # nothing urgent waiting elsewhere
            run_d = min((deadline_order(e.handle) for e in live),
                        default=(1, 0.0))
            if run_d <= best or run.predicted_remaining_rounds() <= 0:
                return False
            dq = self._buckets.setdefault(key, deque())
            # front-load in arrival order so the bucket stays
            # FIFO-consistent for the entries behind them
            for e in sorted(live, key=lambda e: e.handle.t_submit,
                            reverse=True):
                if e.handle.cancel_requested:
                    e.handle._finish(QueryStatus.CANCELLED)
                    self.stats.cancelled_in_flight += 1
                    self._tel_done(e, "cancelled")
                    continue
                e.handle._requeue()
                dq.appendleft(e)
                self.stats.preempted += 1
                if self.tel.enabled:
                    self.tel.instant("preempt", e.tel_tid)
            if not dq:
                del self._buckets[key]
            if self.tel.enabled:
                self.tel.count("serve_preempted_total",
                               help="queries re-queued by EDF preemption")
            self._cv.notify_all()
        return True

    def _dispatch_run(self, key, name, pattern, batch) -> None:
        try:
            run = self._group_run(name, pattern, batch)
        except BaseException as exc:
            for e in batch:
                e.handle._finish(QueryStatus.FAILED, error=exc)
                self.stats.failed += 1
                self._tel_done(e, "failed")
            return
        self.stats.dispatched_groups += 1
        self.stats.dispatch_log.append((name, pattern, len(batch)))
        try:
            while run.active:
                # a worker abort outranks everything: fail the group's
                # unresolved queries loudly at this round boundary
                if self._abort_exc is not None:
                    raise self._abort_exc
                # mid-flight cancellations, honoured at round boundaries
                for s in run.slots:
                    if (not s.done and s.entry.handle.cancel_requested
                            and run.cancel(s.entry)):
                        s.entry.handle._finish(QueryStatus.CANCELLED)
                        self.stats.cancelled_in_flight += 1
                        self._tel_done(s.entry, "cancelled")
                if not run.active:
                    break
                if self._preempt_run(key, run):
                    return
                for e in run.step():
                    # a cancel() that already promised "no result" wins
                    # over the retirement (resolved atomically in _finish)
                    final = e.handle._finish(QueryStatus.DONE, result=e.result)
                    if final is QueryStatus.CANCELLED:
                        self.stats.cancelled_in_flight += 1
                        self._tel_done(e, "cancelled")
                    elif final is not None:
                        self.stats.completed += 1
                        self._tel_done(e, "completed")
                if (self.backfill and run.active and run.free_slots()
                        and not self._other_bucket_ripe(key)):
                    busy_streams = set()
                    for s in run.slots:
                        if not s.done and s.entry is not None:
                            sid = getattr(s.entry.query, "stream_id", None)
                            if sid is not None:
                                busy_streams.add(sid)
                    for e in self._take_pending(key, run.free_slots(),
                                                busy_streams):
                        with self._cv:
                            self._inflight.append(e)
                        e.handle._mark_running()
                        run.admit(e)
                        self.stats.backfilled += 1
        except BaseException as exc:
            for s in run.slots:
                if s.entry is not None and not s.entry.handle.done():
                    s.entry.handle._finish(QueryStatus.FAILED, error=exc)
                    self.stats.failed += 1
                    self._tel_done(s.entry, "failed")
