"""Async admission queue: micro-batching under *streaming* traffic.

``answer_batch`` exploits chain-lane packing only when the caller hands
it a pre-assembled batch; real serving traffic arrives one query at a
time from many clients.  :class:`AdmissionQueue` closes that gap — the
serving analogue of AIA's compiler keeping 16 cores busy from a stream
of independent programs (paper §III): incoming queries accumulate in
per-``(network, evidence-pattern, mode)`` buckets (marginal and MAP
groups run different round programs, so they never share lanes), and a
bucket dispatches as one packed :class:`repro.serve.engine.GroupRun`
when either

* a **deadline** fires — the bucket's oldest query has waited
  ``max_wait_ms`` (bounds tail latency under trickle traffic), or
* a **size trigger** fires — the bucket can fill ``max_group_lanes``
  chain lanes (defaults to a multiple of the mesh's
  ``serve_lane_multiple``, so a full group shards without padding).

Each ``submit`` returns a :class:`repro.serve.query.QueryHandle`
supporting blocking ``result()`` and per-query ``cancel()`` — honoured
immediately pre-dispatch, and at the next round boundary mid-flight.
Because the engine retires queries individually on convergence (the
rank-normalized R̂ + ESS rule by default — see
:mod:`repro.pgm.diagnostics`), a converged (or cancelled) query frees
its chain lanes mid-flight and the queue *backfills* them with waiting
queries of the same plan — lanes stay hot instead of idling until the
slowest group member converges.

Temporal filtering (``Request.stream_id``) adds one scheduling rule:
slices of the same stream are *serialized* — a dispatch (or backfill)
never takes a stream's next slice while an earlier slice of that stream
is still queued in the same batch or running, because slice ``t+1``
warm-starts from slice ``t``'s retained chains and must therefore
observe its retirement.  Distinct streams still pack together freely.

Single dispatcher thread; the queue owns the engine while open (do not
call ``answer_batch`` on the same engine concurrently).  Buckets are
served FIFO by their oldest arrival, so no evidence pattern starves.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from dataclasses import dataclass, field

from repro.serve.engine import GroupEntry, GroupRun, PosteriorEngine
from repro.serve.query import (  # noqa: F401
    MrfQuery, Query, QueryHandle, QueryStatus, Request)
from repro.serve.telemetry import monotonic
from repro.sharding.specs import serve_lane_multiple

# Default size trigger, in queries, per dispatch group (scaled by the
# mesh width so a full group's lane count is shard-aligned).
DEFAULT_GROUP_QUERIES = 8

# dispatch_log is a diagnostics ring, not an audit trail — bounded so a
# long-lived queue doesn't leak one tuple per group forever
DISPATCH_LOG_MAXLEN = 256


@dataclass
class QueueStats:
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    cancelled_pending: int = 0
    cancelled_in_flight: int = 0
    dispatched_groups: int = 0
    backfilled: int = 0
    # (network, pattern, n_queries) of recent dispatched groups, in order
    dispatch_log: deque = field(
        default_factory=lambda: deque(maxlen=DISPATCH_LOG_MAXLEN))

    def snapshot(self) -> dict:
        """JSON-able dump (the dispatch ring becomes a plain list of
        ``[network, n_queries]`` pairs — patterns can be kilo-int pixel
        masks, too bulky for a stats snapshot)."""
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "cancelled_pending": self.cancelled_pending,
            "cancelled_in_flight": self.cancelled_in_flight,
            "dispatched_groups": self.dispatched_groups,
            "backfilled": self.backfilled,
            "dispatch_log": [[name, n] for name, _, n in self.dispatch_log],
        }


class AdmissionQueue:
    """Streaming front door of a :class:`PosteriorEngine`.

    Parameters
    ----------
    max_wait_ms:
        Deadline trigger — a bucket flushes once its oldest query has
        waited this long (the latency/batching trade-off knob).
    max_group_lanes:
        Size trigger — a bucket flushes as soon as its queries fill
        this many chain lanes.  Defaults to ``DEFAULT_GROUP_QUERIES *
        chains_per_query * serve_lane_multiple(mesh)``.
    backfill:
        Re-use the lanes of retired (converged/cancelled) queries for
        waiting queries of the same plan mid-flight.

    Example::

        queue = AdmissionQueue(engine, max_wait_ms=20.0)
        handle = queue.submit(Query("sprinkler", {"wetgrass": 1}, ("rain",)))
        handle.result(timeout=60).marginal("rain")
        queue.close()
    """

    def __init__(self, engine: PosteriorEngine, *, max_wait_ms: float = 10.0,
                 max_group_lanes: int | None = None, backfill: bool = True):
        self.engine = engine
        self.max_wait_s = float(max_wait_ms) / 1e3
        c = engine.chains_per_query
        if max_group_lanes is None:
            max_group_lanes = (
                DEFAULT_GROUP_QUERIES * c * serve_lane_multiple(engine.mesh))
        self.max_group_queries = max(1, int(max_group_lanes) // c)
        self.backfill = bool(backfill)
        self.stats = QueueStats()
        self.tel = engine.telemetry
        engine._attached_queue = self  # PosteriorEngine.stats() snapshot
        self._buckets: dict[tuple, deque[GroupEntry]] = {}
        self._cv = threading.Condition()
        self._closed = False
        self._flush_before = -1.0  # flush(): entries at/before this are ripe
        self._inflight: list[GroupEntry] = []  # current group, under _cv
        self._thread = threading.Thread(
            target=self._run, name="admission-queue", daemon=True)
        self._thread.start()

    # -- client side -------------------------------------------------------
    def submit(self, query: Request) -> QueryHandle:
        """Admit one query; returns its future.  Raises immediately on
        malformed queries (unknown network, bad evidence, observed
        query vars) — validation must not wait for the dispatcher."""
        _, ev, qvars, pattern = self.engine.normalize(query)
        handle = QueryHandle(query, on_cancel=self._cancel_pending)
        entry = GroupEntry(query, ev, qvars, handle=handle)
        tel = self.tel
        if tel.enabled:
            entry.tel_tid = tel.track(
                f"query#{next(self.engine._query_seq)} {query.network}")
        with self._cv:
            if self._closed:
                raise RuntimeError("queue is closed")
            self._buckets.setdefault(
                (query.network, pattern,
                 getattr(query, "mode", "marginals")),
                deque()).append(entry)
            self.stats.submitted += 1
            depth = sum(len(d) for d in self._buckets.values())
            self._cv.notify_all()
        if tel.enabled:
            tel.instant("submit", entry.tel_tid, network=query.network)
            tel.count("serve_queries_submitted_total",
                      help="queries admitted to the queue")
            tel.gauge_set("serve_queue_depth", depth,
                          help="queries waiting in dispatch buckets")
            tel.sample("queue_depth", depth)
        return handle

    def pending(self) -> int:
        with self._cv:
            return sum(len(d) for d in self._buckets.values())

    def warm(self, traffic: list) -> None:
        """Pre-compile, off the serving clock, every (plan, lane-shape)
        combination streamed dispatch of ``traffic`` can produce: one
        query per distinct (network, evidence-pattern), answered at each
        pow2 group size up to this queue's size trigger.  Call before
        the first ``submit`` — it drives the engine from the caller's
        thread, which is only safe while the dispatcher is idle."""
        seen: dict[tuple, object] = {}
        for q in traffic:
            _, _, _, pattern = self.engine.normalize(q)
            # mode keys the probe too: MAP groups trace the annealed
            # (4-arg) round program, a distinct XLA build per plan
            seen.setdefault(
                (q.network, pattern, getattr(q, "mode", "marginals")), q)
        for q in seen.values():
            # minimal-budget probe: compiling the (plan, shape) is the
            # point — n_samples=1 clamps each rung to min_rounds instead
            # of sampling the caller's full budget per shape.  replace()
            # keeps this family-agnostic (Query and MrfQuery alike).
            # stream_id is stripped: a probe must not retain chains that
            # would warm-start the stream's real first slice off-protocol.
            probe = dataclasses.replace(q, n_samples=1, stream_id=None)
            n = 1
            while True:
                # a full pop of max_group_queries pads to the pow2 above
                # it, so the ladder must cover that ceiling too (e.g.
                # max 24 -> shapes 1,2,4,8,16 and 32-via-24)
                self.engine.answer_batch(
                    [probe] * min(n, self.max_group_queries))
                if n >= self.max_group_queries:
                    break
                n *= 2

    def flush(self) -> None:
        """Make everything currently pending dispatchable now, ignoring
        deadlines (queries submitted *after* the flush keep theirs)."""
        with self._cv:
            self._flush_before = monotonic()
            self._cv.notify_all()

    def close(self, *, drain: bool = True, timeout: float | None = None):
        """Stop accepting queries.  ``drain=True`` dispatches everything
        still pending first; ``drain=False`` cancels pending *and*
        in-flight queries (the dispatcher honours the in-flight
        cancellations at the next round boundary, so close does not
        block on a slow-converging group running out its cap)."""
        with self._cv:
            self._closed = True
            if not drain:
                for dq in self._buckets.values():
                    for e in dq:
                        e.handle._finish(QueryStatus.CANCELLED)
                        self.stats.cancelled_pending += 1
                        self._tel_done(e, "cancelled")
                self._buckets.clear()
                for e in self._inflight:
                    e.handle.cancel_requested = True
            self._cv.notify_all()
        self._thread.join(timeout)

    def __enter__(self) -> "AdmissionQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    def _tel_done(self, e: GroupEntry, status: str) -> None:
        """Delivery-side telemetry for one resolved entry: the finished
        counter (by status), the end-to-end latency histogram, and a
        ``deliver`` instant on the query's trace track."""
        tel = self.tel
        if not tel.enabled:
            return
        tel.count("serve_queries_finished_total",
                  help="queries resolved, by final status", status=status)
        h = e.handle
        if h.t_done is not None:
            tel.observe("serve_e2e_seconds", h.t_done - h.t_submit,
                        help="submit-to-delivery seconds per query")
        tel.instant("deliver", e.tel_tid, status=status)

    # -- cancellation ------------------------------------------------------
    def _cancel_pending(self, handle: QueryHandle) -> None:
        """Pre-dispatch path of ``handle.cancel()``: unlink from the
        bucket and resolve now.  If the query already left its bucket,
        the dispatcher honours ``cancel_requested`` at the next round
        boundary instead."""
        with self._cv:
            for key, dq in self._buckets.items():
                for e in dq:
                    if e.handle is handle:
                        dq.remove(e)
                        if not dq:
                            del self._buckets[key]
                        handle._finish(QueryStatus.CANCELLED)
                        self.stats.cancelled_pending += 1
                        self._tel_done(e, "cancelled")
                        return

    # -- dispatcher --------------------------------------------------------
    def _ripe(self, dq: deque, now: float) -> bool:
        return (len(dq) >= self.max_group_queries
                or now - dq[0].handle.t_submit >= self.max_wait_s
                or dq[0].handle.t_submit <= self._flush_before
                or self._closed)

    def _pop_ready_locked(self):
        """Oldest-arrival ripe bucket (FIFO across evidence patterns),
        popped up to the size trigger; None if nothing is ripe.

        Same-stream serialization: at most one slice per ``stream_id``
        leaves the bucket per dispatch — later slices of a stream
        already in the batch are held back (in order) so they can
        warm-start from the earlier slice's retired chains."""
        now = monotonic()
        ready = [(dq[0].handle.t_submit, key)
                 for key, dq in self._buckets.items() if self._ripe(dq, now)]
        if not ready:
            return None
        _, key = min(ready)
        dq = self._buckets[key]
        batch: list[GroupEntry] = []
        held: list[GroupEntry] = []
        streams: set[str] = set()
        while dq and len(batch) < self.max_group_queries:
            e = dq.popleft()
            sid = getattr(e.query, "stream_id", None)
            if sid is not None and sid in streams:
                held.append(e)
                continue
            if sid is not None:
                streams.add(sid)
            batch.append(e)
        held.extend(dq)
        if held:
            self._buckets[key] = deque(held)
        else:
            del self._buckets[key]
        return key, batch

    def _next_deadline_locked(self) -> float | None:
        if not self._buckets:
            return None
        oldest = min(dq[0].handle.t_submit for dq in self._buckets.values())
        return max(0.0, oldest + self.max_wait_s - monotonic())

    def _other_bucket_ripe(self, key: tuple) -> bool:
        """True if some *other* plan's bucket is already dispatchable —
        backfill yields to it so one hot pattern cannot starve the rest
        (FIFO fairness across evidence patterns)."""
        now = monotonic()
        with self._cv:
            return any(k != key and self._ripe(dq, now)
                       for k, dq in self._buckets.items())

    def _take_pending(self, key: tuple, n: int,
                      exclude_streams=frozenset()) -> list[GroupEntry]:
        """Up to ``n`` waiting entries of one plan bucket, for backfill.

        ``exclude_streams`` holds the stream ids still running in the
        dispatching group: their next slices are left queued (in order)
        until the running slice retires and retains its chains."""
        out: list[GroupEntry] = []
        held: list[GroupEntry] = []
        streams: set[str] = set(exclude_streams)
        with self._cv:
            dq = self._buckets.get(key)
            while dq and len(out) < n:
                e = dq.popleft()
                if e.handle.cancel_requested:
                    e.handle._finish(QueryStatus.CANCELLED)
                    self.stats.cancelled_pending += 1
                    self._tel_done(e, "cancelled")
                    continue
                sid = getattr(e.query, "stream_id", None)
                if sid is not None and sid in streams:
                    held.append(e)
                    continue
                if sid is not None:
                    streams.add(sid)
                out.append(e)
            if dq is not None:
                if held:
                    dq.extendleft(reversed(held))
                if not dq:
                    del self._buckets[key]
        return out

    def _run(self) -> None:
        while True:
            with self._cv:
                item = self._pop_ready_locked()
                while item is None:
                    if self._closed and not self._buckets:
                        return
                    self._cv.wait(self._next_deadline_locked())
                    item = self._pop_ready_locked()
                # registered under the SAME lock hold that popped the
                # batch: a close(drain=False) can never observe queries
                # that left their bucket but aren't in-flight yet
                self._inflight = list(item[1])
            key, batch = item
            self._dispatch(key, batch)

    def _dispatch(self, key: tuple, batch: list[GroupEntry]) -> None:
        name, pattern = key[0], key[1]
        for e in batch:
            e.handle._mark_running()
        try:
            self._dispatch_run(key, name, pattern, batch)
        finally:
            with self._cv:
                self._inflight = []

    def _dispatch_run(self, key, name, pattern, batch) -> None:
        try:
            run = GroupRun(self.engine, name, pattern, batch)
        except BaseException as exc:
            for e in batch:
                e.handle._finish(QueryStatus.FAILED, error=exc)
                self.stats.failed += 1
                self._tel_done(e, "failed")
            return
        self.stats.dispatched_groups += 1
        self.stats.dispatch_log.append((name, pattern, len(batch)))
        try:
            while run.active:
                # mid-flight cancellations, honoured at round boundaries
                for s in run.slots:
                    if (not s.done and s.entry.handle.cancel_requested
                            and run.cancel(s.entry)):
                        s.entry.handle._finish(QueryStatus.CANCELLED)
                        self.stats.cancelled_in_flight += 1
                        self._tel_done(s.entry, "cancelled")
                if not run.active:
                    break
                for e in run.step():
                    # a cancel() that already promised "no result" wins
                    # over the retirement (resolved atomically in _finish)
                    final = e.handle._finish(QueryStatus.DONE, result=e.result)
                    if final is QueryStatus.CANCELLED:
                        self.stats.cancelled_in_flight += 1
                        self._tel_done(e, "cancelled")
                    elif final is not None:
                        self.stats.completed += 1
                        self._tel_done(e, "completed")
                if (self.backfill and run.active and run.free_slots()
                        and not self._other_bucket_ripe(key)):
                    busy_streams = set()
                    for s in run.slots:
                        if not s.done and s.entry is not None:
                            sid = getattr(s.entry.query, "stream_id", None)
                            if sid is not None:
                                busy_streams.add(sid)
                    for e in self._take_pending(key, run.free_slots(),
                                                busy_streams):
                        with self._cv:
                            self._inflight.append(e)
                        e.handle._mark_running()
                        run.admit(e)
                        self.stats.backfilled += 1
        except BaseException as exc:
            for s in run.slots:
                if s.entry is not None and not s.entry.handle.done():
                    s.entry.handle._finish(QueryStatus.FAILED, error=exc)
                    self.stats.failed += 1
                    self._tel_done(s.entry, "failed")
