"""Pallas TPU flash-attention kernel for the prefill hot-spot.

The lax-native blockwise attention in ``repro.models.attention`` is the
portable implementation every backend can compile (and what the dry-run
lowers); this kernel is the TPU-tuned variant of the same online-softmax
math: q/k/v tiles staged through VMEM with explicit BlockSpecs, the MXU
driving the (q_block × kv_block) score and (prob × v) matmuls, and the
running (m, l, acc) state held in VMEM scratch across the kv grid axis.

Grid: (batch·heads, n_q_blocks, n_kv_blocks) — the kv axis is innermost
so the scratch accumulator carries across it; causal masking is applied
from absolute positions.  Validated in interpret mode against
``ref.py::mha_ref`` over shape/dtype sweeps (see tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, q_block: int,
                  kv_block: int, n_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                      # (q_block, dh)
    k = k_ref[0]                      # (kv_block, dh)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        pos_q = iq * q_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 0)
        pos_k = ik * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1)
        s = jnp.where(pos_k > pos_q, _NEG, s)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_block", "kv_block", "interpret"))
def flash_attention(
    q: jax.Array,       # (BH, S, dh) — batch·heads flattened
    k: jax.Array,       # (BH, S, dh)
    v: jax.Array,
    *,
    causal: bool = True,
    q_block: int = 256,
    kv_block: int = 256,
    interpret: bool = True,
) -> jax.Array:
    bh, s, dh = q.shape
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    assert s % q_block == 0 and s % kv_block == 0
    nq, nk = s // q_block, s // kv_block
    scale = dh ** -0.5
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, q_block=q_block,
        kv_block=kv_block, n_kv=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu_or_generic((q_block, 1), jnp.float32),
            pltpu_or_generic((q_block, 1), jnp.float32),
            pltpu_or_generic((q_block, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def pltpu_or_generic(shape, dtype):
    """VMEM scratch on TPU; generic scratch in interpret mode."""
    import jax.experimental.pallas.tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def flash_mha(q, k, v, *, causal=True, q_block=256, kv_block=256,
              interpret=True):
    """(B, S, H, dh) GQA-aware wrapper: expands kv heads, flattens B·H."""
    b, s, h, dh = q.shape
    kv_heads = k.shape[2]
    if kv_heads != h:
        k = jnp.repeat(k, h // kv_heads, axis=2)
        v = jnp.repeat(v, h // kv_heads, axis=2)
    fq = jnp.moveaxis(q, 2, 1).reshape(b * h, s, dh)
    fk = jnp.moveaxis(k, 2, 1).reshape(b * h, s, dh)
    fv = jnp.moveaxis(v, 2, 1).reshape(b * h, s, dh)
    out = flash_attention(fq, fk, fv, causal=causal, q_block=q_block,
                          kv_block=kv_block, interpret=interpret)
    return jnp.moveaxis(out.reshape(b, h, s, dh), 1, 2)
