"""Pallas TPU kernel for batched non-normalized Knuth-Yao sampling.

TPU mapping of the AIA sampler unit (DESIGN.md §2):

* the (block_b, n) int32 weight tile is resident in VMEM — the analogue
  of the distribution sitting in the AC register file;
* per DDG level the bit-plane column is extracted with shift/mask (the
  column-wise RF read port) and reduced with a lane-dim cumsum;
* all lanes of the block walk levels in lock-step inside a
  ``lax.while_loop``; finished lanes idle, rejected lanes restart — the
  loop exits as soon as the whole block is done, so the expected trip
  count is ≈ entropy + 2 (× <2 attempts), not the worst-case budget.

Random bits: the kernel consumes bit position ``it`` of every lane's
pre-generated uint32 word stream at iteration ``it`` (a *global* bit
cursor).  This keeps the per-iteration bit fetch a scalar-indexed VMEM
slice instead of a per-lane gather; lanes see iid bits either way.
``ref.py::ky_ref`` mirrors these exact semantics for bit-exact testing.

Bit-stream contract (docs/kernels.md): the global cursor makes this
kernel bit-comparable with ``ref.py::ky_ref`` only.  The engine-facing
fused sweep kernel (``fused_sweep.py``) instead embeds
``core/ky.py::ky_walk`` and its **per-lane** cursor — the discipline
``core.ky.ky_sample`` uses — because its contract is bitwise identity
with the ``sampler="xla"`` serving path.  The two cursor disciplines
consume different bit positions and are *not* bit-comparable with each
other; this module is the standalone kernel/oracle pair, not the hot
path behind the engine's ``sampler=`` flag.

Block shape: ``(block_b, n_pad)`` with ``n_pad`` a multiple of 128 (VPU
lane width); zero-padded outcomes contribute empty bit columns and can
never be selected.  ``interpret=True`` (the default here; tests run on
CPU) routes through the Pallas interpreter — the same escape hatch
``fused_sweep.py`` and ``interp_lut.py`` expose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ky_kernel(w_ref, words_ref, klvl_ref, rej_ref, out_ref, bits_ref, ok_ref, *, budget: int):
    w = w_ref[...]            # (bb, n) int32 weights
    klvl = klvl_ref[...]      # (bb, 1) int32 per-lane DDG depth K
    rej = rej_ref[...]        # (bb, 1) int32 rejection pad mass
    bb, n = w.shape

    def cond(st):
        it, done = st[0], st[1]
        return (it < budget) & (~jnp.all(done))

    def body(st):
        it, done, d, c, res, bits = st
        active = ~done
        # --- fetch one random bit per lane (scalar-indexed word column) ---
        word = words_ref[:, pl.ds(it // 32, 1)]          # (bb, 1) uint32
        b = ((word >> (it % 32).astype(jnp.uint32)) & 1).astype(jnp.int32)
        d2 = 2 * d + (1 - b)
        # --- bit-plane column at level c (column-wise RF read) ---
        shift = klvl - 1 - c                              # (bb, 1)
        col = jnp.where(shift >= 0, (w >> shift) & 1, 0)  # (bb, n)
        rcol = jnp.where(shift >= 0, (rej >> shift) & 1, 0)
        cum = jnp.cumsum(col, axis=1)
        colsum = cum[:, -1:] + rcol                       # (bb, 1)
        hit = d2 < colsum
        ge = cum >= (d2 + 1)                              # (bb, n)
        has_real = jnp.any(ge, axis=1)[:, None]
        sel = jnp.argmax(ge, axis=1).astype(jnp.int32)[:, None]
        finish = hit & has_real & active
        restart = ((hit & ~has_real) | ((~hit) & (c + 1 >= klvl))) & active
        res2 = jnp.where(finish, sel, res)
        done2 = done | finish
        d3 = jnp.where(restart, 0, jnp.where(hit, d, d2 - colsum))
        c2 = jnp.where(restart, 0, jnp.where(hit, c, c + 1))
        bits2 = bits + active.astype(jnp.int32)
        return it + 1, done2, d3, c2, res2, bits2

    # deterministic-row bypass: p = 1.0 has no fractional DDG expansion
    total = jnp.sum(w, axis=1)[:, None]
    amax = jnp.argmax(w, axis=1).astype(jnp.int32)[:, None]
    det = jnp.max(w, axis=1)[:, None] == total

    z = jnp.zeros((bb, 1), jnp.int32)
    st = (jnp.int32(0), det, z, z, jnp.where(det, amax, 0), z)
    _, done, _, _, res, bits = jax.lax.while_loop(cond, body, st)
    # fallback (budget exhausted; prob < 2**-32): argmax outcome
    out_ref[...] = jnp.where(done, res, amax)
    bits_ref[...] = bits
    ok_ref[...] = done


@functools.partial(jax.jit, static_argnames=("block_b", "budget", "interpret"))
def ky_sampler_pallas(
    weights: jax.Array,     # (B, n_pad) int32, n_pad % 128 == 0
    words: jax.Array,       # (B, W) uint32 random bit words
    klvl: jax.Array,        # (B, 1) int32
    rej: jax.Array,         # (B, 1) int32
    *,
    block_b: int = 256,
    budget: int | None = None,
    interpret: bool = True,
):
    b, n = weights.shape
    w_words = words.shape[-1]
    budget = budget if budget is not None else w_words * 32
    grid = (b // block_b,)
    kernel = functools.partial(_ky_kernel, budget=budget)
    out, bits, ok = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((block_b, w_words), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.bool_),
        ],
        interpret=interpret,
    )(weights, words, klvl, rej)
    return out, bits, ok
