"""Fused Pallas sweep kernel: the Gibbs sampling hot path in one kernel.

The paper's 1277 MSample/s headline comes from fusing the per-site
update — distribution generation through the IU and non-normalized KY
sampling — into one unit that keeps the distribution resident in the AC
register file.  This kernel is the software analogue: for each color
(BN / sparse factor graph) or checkerboard phase (MRF) the XLA-side plan
gather produces a (lanes, L) log-weight tile, and everything downstream
runs inside a single ``pallas_call`` with the tile resident in VMEM
end-to-end:

    label mask → max-subtract → IU-exp LUT interpolation
    → fixed-point floor (k-bit int32 weights) → per-lane KY DDG walk

No intermediate (weights, klvl, rej) tensors ever round-trip through HBM
— the fusion the ``sampler="pallas"`` engine flag buys.

Bitwise contract (docs/kernels.md): the kernel body calls the *same*
functions the XLA path uses — ``core.interp.masked_exp_weights`` for the
distribution-generation tail and ``core.ky.ky_walk`` for the DDG walk —
on bit words pre-generated outside the kernel by the same
``core.rng.random_bit_words(key, (b,), 992)`` call that
``core.ky.ky_sample`` makes internally.  ``sampler="pallas"`` is
therefore bitwise-identical to ``sampler="xla"`` (same samples, same
bits_used, same attempts) by construction, for every family.  The bit
stream uses the per-lane cursor of ``core/ky.py``; the standalone
``kernels/ky_sampler.py`` / ``ref.py::ky_ref`` pair instead shares a
global bit cursor and is *not* bit-comparable with this kernel.

Two deliberate deviations from perfect equivalence, both unreachable in
practice (asserted or noted):

* masked / lane-padding labels must quantize to weight 0, which holds
  for ``k <= 23`` (``exp(-16) * (2**23 - 1) < 1``); the wrapper rejects
  larger ``k``.
* the while-loop early-exit is per block rather than per batch, which
  can only diverge if some lane exhausts its 992-bit budget
  (probability < 2**-496).

``interpret=True`` (the default on non-TPU backends) runs the kernel
through the Pallas interpreter — the CPU/CI escape hatch shared with
``kernels/interp_lut.py`` and ``kernels/ky_sampler.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import interp as interp_lib
from repro.core import rng as rng_lib
from repro.core.ky import KYResult, ky_walk

# masked-label log-weight floor — see core.interp.MASK_NEG
MASK_NEG = interp_lib.MASK_NEG

# largest fixed-point width for which masked labels quantize to weight 0:
# floor(exp(lo) * (2**k - 1)) == 0 with the exp LUT's lo = -16
MAX_FUSED_K = 23


def _resolve_interpret(interpret: bool | None) -> bool:
    """Default to the interpreter off-TPU (CPU CI), compiled on TPU."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _fused_kernel(logw_ref, card_ref, words_ref, tab_ref,
                  s_ref, bits_ref, att_ref, ok_ref,
                  *, k: int, use_iu: bool, lo: float, hi: float, m: int,
                  mask_value: float):
    """One (block_b, n_pad) tile: weights never leave VMEM.

    The body is just the two shared helpers — ``masked_exp_weights``
    builds the int32 weight tile in registers/VMEM, ``ky_walk`` samples
    from it in place.  The LUT block is pinned (index map (0, 0)), the
    analogue of the IU's dedicated table registers.
    """
    table = interp_lib.InterpTable(
        table=tab_ref[...][0], lo=lo, hi=hi, m=m)
    w = interp_lib.masked_exp_weights(
        logw_ref[...], card_ref[...][:, 0], k,
        use_iu=use_iu, table=table, mask_value=mask_value)
    r = ky_walk(w, words_ref[...])
    s_ref[...] = r.sample[:, None]
    bits_ref[...] = r.bits_used[:, None]
    att_ref[...] = r.attempts[:, None]
    ok_ref[...] = r.ok[:, None]


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(
    jax.jit,
    static_argnames=("k", "use_iu", "mask_value", "max_attempts", "block_b",
                     "interpret"))
def fused_gibbs_sample(
    key: jax.Array,
    logw: jax.Array,        # (b, n) float32 gathered log-weights
    card: jax.Array,        # (b,) int32 per-lane cardinality (or scalar)
    *,
    k: int,
    use_iu: bool = True,
    table: interp_lib.InterpTable | None = None,
    mask_value: float = MASK_NEG,
    max_attempts: int = 32,
    block_b: int = 256,
    interpret: bool | None = None,
) -> KYResult:
    """Fused distribution-generation + KY sampling, one lane per row.

    Drop-in replacement for the two-stage XLA path

        ``ky_sample(key, masked_exp_weights(logw, card, k, ...))``

    with identical results bit for bit (same ``key`` ⇒ same sample,
    bits_used, attempts, ok) — the invariant the round-runner bitwise
    tests pin.  Returns a :class:`KYResult` with (b,) fields.

    ``interpret=None`` auto-selects the Pallas interpreter off-TPU.
    """
    if k > MAX_FUSED_K:
        raise ValueError(
            f"fused sampler requires k <= {MAX_FUSED_K} so masked labels "
            f"quantize to weight 0 (got k={k})")
    logw = jnp.asarray(logw, jnp.float32)
    b, n = logw.shape
    card = jnp.broadcast_to(jnp.asarray(card, jnp.int32), (b,))
    table = table or interp_lib._EXP_DEFAULT

    # Bit words are generated OUTSIDE the kernel at the true lane count —
    # the exact stream ky_sample(key, ...) would draw.  (Generating at the
    # padded count would change every word: threefry pairs counters by
    # total count.)  Padding lanes get zero words; they are deterministic
    # rows and never read a bit.
    words = rng_lib.random_bit_words(key, (b,), 31 * max_attempts)

    block_b = max(8, int(block_b))
    b_pad = _pad_up(b, block_b)
    n_pad = _pad_up(n, 128)             # VPU lane width
    logw_p = jnp.pad(logw, ((0, b_pad - b), (0, n_pad - n)),
                     constant_values=mask_value)
    if b_pad > b:
        # padding lanes: all mass on outcome 0 -> deterministic bypass,
        # zero bits consumed, no effect on the block's while_loop trips
        logw_p = logw_p.at[b:, 0].set(0.0)
    card_p = jnp.pad(card, (0, b_pad - b), constant_values=1)[:, None]
    words_p = jnp.pad(words, ((0, b_pad - b), (0, 0)))

    tab = table.table
    t_pad = _pad_up(int(tab.shape[0]), 128)
    tab2d = jnp.pad(tab, (0, t_pad - int(tab.shape[0])))[None, :]

    n_words = int(words.shape[-1])
    grid = (b_pad // block_b,)
    kernel = functools.partial(
        _fused_kernel, k=k, use_iu=use_iu,
        lo=table.lo, hi=table.hi, m=table.m, mask_value=float(mask_value))
    block = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
    s, bits, att, ok = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_b, n_words), lambda i: (i, 0)),
            pl.BlockSpec((1, t_pad), lambda i: (0, 0)),
        ],
        out_specs=[block, block, block, block],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((b_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((b_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((b_pad, 1), jnp.bool_),
        ],
        interpret=_resolve_interpret(interpret),
    )(logw_p, card_p, words_p, tab2d)
    return KYResult(sample=s[:b, 0], bits_used=bits[:b, 0],
                    attempts=att[:b, 0], ok=ok[:b, 0])


def fused_gibbs_sample_ref(
    key: jax.Array,
    logw: jax.Array,
    card: jax.Array,
    *,
    k: int,
    use_iu: bool = True,
    table: interp_lib.InterpTable | None = None,
    mask_value: float = MASK_NEG,
    max_attempts: int = 32,
) -> KYResult:
    """Pure-XLA twin of :func:`fused_gibbs_sample` (no ``pallas_call``).

    Runs the identical shared helpers on the unpadded arrays — the
    three-way anchor of the bitwise tests: kernel ≡ this ref ≡ the
    engine's two-stage ``sampler="xla"`` path.
    """
    logw = jnp.asarray(logw, jnp.float32)
    b = logw.shape[0]
    card = jnp.broadcast_to(jnp.asarray(card, jnp.int32), (b,))
    w = interp_lib.masked_exp_weights(
        logw, card, k, use_iu=use_iu, table=table, mask_value=mask_value)
    words = rng_lib.random_bit_words(key, (b,), 31 * max_attempts)
    return ky_walk(w, words)
