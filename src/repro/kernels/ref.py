"""Pure-jnp oracles for the Pallas kernels (bit-exact semantics).

``ky_ref`` mirrors the *kernel's* global-bit-cursor semantics (every lane
consumes bit position ``it`` of its own stream at iteration ``it``),
which differs from ``core.ky.ky_sample``'s per-lane cursor only in which
iid bits get used — identical distribution, different stream positions.
Tests check the kernel against this oracle bit-exactly, and both against
``core.ky`` distributionally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import ceil_log2


def ky_prep(weights: jax.Array):
    """Compute (klvl, rej) columns the kernel consumes, from (B, n) weights."""
    w = jnp.asarray(weights, jnp.int32)
    total = jnp.maximum(jnp.sum(w, axis=-1), 1)
    klvl = jnp.maximum(ceil_log2(total), 1)
    rej = (jnp.int32(1) << klvl) - total
    return klvl[:, None], rej[:, None]


def ky_ref(weights: jax.Array, words: jax.Array, budget: int | None = None):
    """jnp oracle with kernel semantics. Returns (sample, bits, ok), (B,1)."""
    w = jnp.asarray(weights, jnp.int32)
    b, n = w.shape
    klvl, rej = ky_prep(w)
    budget = budget if budget is not None else int(words.shape[-1]) * 32

    def body(st, it):
        done, d, c, res, bits = st
        active = ~done
        word = jnp.take_along_axis(words, jnp.full((b, 1), it // 32, jnp.int32), axis=1)
        bit = ((word >> jnp.uint32(it % 32)) & 1).astype(jnp.int32)
        d2 = 2 * d + (1 - bit)
        shift = klvl - 1 - c
        col = jnp.where(shift >= 0, (w >> shift) & 1, 0)
        rcol = jnp.where(shift >= 0, (rej >> shift) & 1, 0)
        cum = jnp.cumsum(col, axis=1)
        colsum = cum[:, -1:] + rcol
        hit = d2 < colsum
        ge = cum >= (d2 + 1)
        has_real = jnp.any(ge, axis=1)[:, None]
        sel = jnp.argmax(ge, axis=1).astype(jnp.int32)[:, None]
        finish = hit & has_real & active
        restart = ((hit & ~has_real) | ((~hit) & (c + 1 >= klvl))) & active
        res2 = jnp.where(finish, sel, res)
        done2 = done | finish
        d3 = jnp.where(restart, 0, jnp.where(hit, d, d2 - colsum))
        c2 = jnp.where(restart, 0, jnp.where(hit, c, c + 1))
        bits2 = bits + active.astype(jnp.int32)
        return (done2, d3, c2, res2, bits2), None

    total = jnp.sum(w, axis=1)[:, None]
    amax = jnp.argmax(w, axis=1).astype(jnp.int32)[:, None]
    det = jnp.max(w, axis=1)[:, None] == total  # deterministic-row bypass

    z = jnp.zeros((b, 1), jnp.int32)
    st = (det, z, z, jnp.where(det, amax, 0), z)
    (done, _, _, res, bits), _ = jax.lax.scan(body, st, jnp.arange(budget))
    return jnp.where(done, res, amax), bits, done


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True) -> jax.Array:
    """Dense-softmax oracle for the flash-attention kernel.
    q/k/v: (BH, S, dh)."""
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * q.shape[-1] ** -0.5
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, n), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def interp_ref(x: jax.Array, table: jax.Array, lo: float, hi: float) -> jax.Array:
    """jnp oracle for the interpolation kernel."""
    n_seg = int(table.shape[-1]) - 1
    scale = n_seg / (hi - lo)
    t = jnp.clip((jnp.asarray(x, jnp.float32) - lo) * scale, 0.0, float(n_seg))
    idx = jnp.minimum(t.astype(jnp.int32), n_seg - 1)
    frac = t - idx.astype(jnp.float32)
    y0 = jnp.take(table, idx, mode="clip")
    y1 = jnp.take(table, idx + 1, mode="clip")
    return y0 + frac * (y1 - y0)
