"""jit'd public wrappers around the Pallas kernels.

Handle padding (outcome dim → ×128 lanes, batch → ×block), bit-word
generation, and expose the same KYResult-style interface as ``core.ky``.
``interpret`` defaults to True (CPU container); on TPU pass False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rng as rng_lib
from repro.core.ky import KYResult
from repro.kernels import ref as ref_lib
from repro.kernels.interp_lut import interp_pallas
from repro.kernels.ky_sampler import ky_sampler_pallas


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("max_attempts", "block_b", "interpret"))
def ky_sample_kernel(
    key: jax.Array,
    weights: jax.Array,
    *,
    max_attempts: int = 32,
    block_b: int = 256,
    interpret: bool = True,
) -> KYResult:
    """Pallas-kernel version of ``core.ky.ky_sample`` for (..., n) weights."""
    w = jnp.asarray(weights, jnp.int32)
    batch_shape = w.shape[:-1]
    n = w.shape[-1]
    flat = w.reshape((-1, n))
    b = flat.shape[0]
    total = jnp.sum(flat, axis=-1)
    flat = jnp.where(
        (total == 0)[:, None] & (jnp.arange(n) == 0)[None, :], 1, flat
    )
    klvl, rej = ref_lib.ky_prep(flat)
    budget = 31 * max_attempts
    words = rng_lib.random_bit_words(key, (b,), budget)

    # pad batch to a block multiple, outcomes to a lane multiple
    flat_p = _pad_to(_pad_to(flat, 1, 128), 0, block_b)
    # padded rows must be valid distributions: give them weight-1 outcome 0
    bpad = flat_p.shape[0] - b
    if bpad:
        filler = jnp.zeros((bpad, flat_p.shape[1]), jnp.int32).at[:, 0].set(1)
        flat_p = flat_p.at[b:].set(filler)
        kl_f, rj_f = ref_lib.ky_prep(filler)
        klvl = jnp.concatenate([klvl, kl_f])
        rej = jnp.concatenate([rej, rj_f])
        words = jnp.concatenate(
            [words, jnp.zeros((bpad, words.shape[1]), jnp.uint32)]
        )
    out, bits, ok = ky_sampler_pallas(
        flat_p, words, klvl, rej,
        block_b=block_b, budget=budget, interpret=interpret,
    )
    return KYResult(
        sample=out[:b, 0].reshape(batch_shape),
        bits_used=bits[:b, 0].reshape(batch_shape),
        attempts=jnp.ones(batch_shape, jnp.int32),  # not tracked in-kernel
        ok=ok[:b, 0].reshape(batch_shape),
    )


@functools.partial(jax.jit, static_argnames=("lo", "hi", "interpret"))
def interp_kernel(
    x: jax.Array,
    table: jax.Array,
    *,
    lo: float,
    hi: float,
    interpret: bool = True,
) -> jax.Array:
    """Pallas-kernel version of ``core.interp.InterpTable.__call__``."""
    x = jnp.asarray(x, jnp.float32)
    shape = x.shape
    flat = x.reshape((1, -1)) if x.ndim == 1 else x.reshape((-1, shape[-1]))
    b, n = flat.shape
    bb = 256 if b % 256 == 0 else (b if b <= 256 else 1)
    bn = 512 if n % 512 == 0 else n
    flat = _pad_to(_pad_to(flat, 0, bb), 1, bn)
    y = interp_pallas(
        flat, jnp.asarray(table, jnp.float32),
        lo=lo, hi=hi, block_b=bb, block_n=bn, interpret=interpret,
    )
    return y[:b, :n].reshape(shape)
