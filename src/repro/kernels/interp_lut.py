"""Pallas TPU kernel for the LUT interpolation unit (IU, paper §II-B).

The 2**m+1-entry table is pinned in VMEM for every block (BlockSpec index
map returns block 0 — the analogue of the IU's dedicated LUT registers),
inputs stream through in (block_b, block_n) tiles, and each element costs
one index split (shift/scale), two table reads, and one FMA:

    y = LUT[idx] + frac * (LUT[idx+1] - LUT[idx])

The table read is expressed with ``jnp.take``; on hardware Mosaic lowers
small-table gathers directly (a one-hot-matmul fallback would also keep
it on the MXU).  ``ref.py::interp_ref`` is the jnp oracle.

This standalone kernel demonstrates the IU in isolation; the serving
hot path instead runs the same LUT lookup *inside* the fused sweep
kernel (``fused_sweep.py``), where ``core.interp.InterpTable.__call__``
executes on a VMEM-pinned table between the energy gather and the KY
walk — see docs/kernels.md for the fused dataflow.  ``interpret=True``
(default; tests run on CPU) routes through the Pallas interpreter, the
CPU/CI escape hatch shared by every kernel in this package.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interp_kernel(x_ref, tab_ref, y_ref, *, lo: float, hi: float, n_seg: int):
    x = x_ref[...]
    tab = tab_ref[...][0]  # (T+1,) table row
    scale = n_seg / (hi - lo)
    t = jnp.clip((x - lo) * scale, 0.0, float(n_seg))
    idx = jnp.minimum(t.astype(jnp.int32), n_seg - 1)
    frac = t - idx.astype(jnp.float32)
    y0 = jnp.take(tab, idx, mode="clip")
    y1 = jnp.take(tab, idx + 1, mode="clip")
    y_ref[...] = y0 + frac * (y1 - y0)


@functools.partial(
    jax.jit, static_argnames=("lo", "hi", "block_b", "block_n", "interpret")
)
def interp_pallas(
    x: jax.Array,        # (B, N) float32
    table: jax.Array,    # (T+1,) float32, T = 2**m segments
    *,
    lo: float,
    hi: float,
    block_b: int = 256,
    block_n: int = 512,
    interpret: bool = True,
):
    b, n = x.shape
    n_seg = int(table.shape[-1]) - 1
    tab2d = table[None, :]  # (1, T+1) — 2D for TPU layout
    grid = (b // block_b, n // block_n)
    kernel = functools.partial(_interp_kernel, lo=lo, hi=hi, n_seg=n_seg)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, n_seg + 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x, tab2d)
