"""Vectorized MCMC kernels: checkerboard Gibbs for MRF grids.

Distribution generation follows the AIA pipeline end-to-end: per-site
energies (fixed function units) → max-subtracted ``exp`` through the IU
LUT (C2) → fixed-point integer weights → non-normalized Knuth-Yao sample
(C1).  No per-site normalization sum is ever computed.

The lattice analogue of a "core" here is a VPU lane: all sites of one
checkerboard color across all chains are updated in one vector op.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import DEFAULT_K
from repro.core.interp import InterpTable, exp_table
from repro.core.ky import ky_sample
from repro.kernels.fused_sweep import fused_gibbs_sample
from repro.pgm.graph import MRFGrid


class SweepStats(NamedTuple):
    bits_used: jax.Array   # scalar int32: random bits consumed this sweep
    attempts: jax.Array    # scalar int32


def neighbor_pair_energy(labels: jax.Array, pairwise: jax.Array) -> jax.Array:
    """(B, H, W, L) energy of each candidate label vs the 4 neighbors.

    Edge sites see only their in-grid neighbors (free boundary).
    """
    pw = pairwise  # (L, L); pw[l, m] = energy of candidate l next to m
    e = jnp.zeros(labels.shape + (pairwise.shape[0],), jnp.float32)
    h, w = labels.shape[-2:]

    def nbr(shift, axis):
        rolled = jnp.roll(labels, shift, axis=axis)
        contrib = jnp.take(pw.T, rolled, axis=0)  # (B, H, W, L): pw[l, rolled]
        # mask out the wrapped edge
        idx = jnp.arange(labels.shape[axis])
        if shift == 1:
            valid = idx > 0
        else:
            valid = idx < labels.shape[axis] - 1
        shape = [1] * labels.ndim
        shape[axis] = labels.shape[axis]
        return contrib * valid.reshape(shape)[..., None]

    e = e + nbr(1, -2) + nbr(-1, -2) + nbr(1, -1) + nbr(-1, -1)
    return e


def _weights_from_energies(
    energies: jax.Array,
    *,
    k: int = DEFAULT_K,
    table: InterpTable | None = None,
    use_iu: bool = True,
) -> jax.Array:
    """(..., L) energies → int32 non-normalized KY weights."""
    z = energies - jnp.min(energies, axis=-1, keepdims=True)  # best label → 0
    if use_iu:
        table = table or _EXP
        y = table(-z)  # exp(-z) via the IU LUT (z >= 0, clamped at 16)
    else:
        y = jnp.exp(-z)
    return jnp.floor(y * (2.0 ** k - 1.0)).astype(jnp.int32)


def site_weights(
    labels: jax.Array,
    unary: jax.Array,
    pairwise: jax.Array,
    *,
    k: int = DEFAULT_K,
    table: InterpTable | None = None,
    use_iu: bool = True,
) -> jax.Array:
    """(B, H, W, L) int32 non-normalized KY weights for every site."""
    energies = unary[None] + neighbor_pair_energy(labels, pairwise)
    return _weights_from_energies(energies, k=k, table=table, use_iu=use_iu)


@partial(jax.jit, static_argnames=("k", "use_iu", "sampler"))
def checkerboard_halfstep(
    key: jax.Array,
    labels: jax.Array,          # (B, H, W) int32
    unary: jax.Array,           # (H, W, L)
    pairwise: jax.Array,        # (L, L)
    parity: jax.Array,          # scalar int32 0/1
    *,
    clamp: jax.Array | None = None,   # (H, W) or (B, H, W) bool, True = frozen
    k: int = DEFAULT_K,
    use_iu: bool = True,
    sampler: str = "xla",
    beta: jax.Array | None = None,    # traced inverse temperature, (B,) or scalar
) -> tuple[jax.Array, SweepStats]:
    """Resample all sites of one checkerboard color, all chains at once.

    ``clamp`` marks evidence (observed-pixel) sites: they are skipped by
    the update and by the bit accounting, but their *fixed* labels still
    sit in ``labels`` and therefore keep contributing pairwise energy to
    their neighbours — exactly CPT conditioning, lattice edition.

    ``beta`` scales the site energies (traced, never a static argument):
    weights become ``exp(-β·(e - min e))``, the simulated-annealing
    sharpening the MAP mode drives; per-lane (B,) values anneal each
    chain on its own schedule.  None / 1.0 is ordinary Gibbs.  The scale
    is applied before the sampler branch, so the XLA and Pallas paths
    stay bitwise-interchangeable at every β.

    ``sampler="pallas"`` routes the distribution-generation tail and the
    KY walk through the fused kernel (``kernels/fused_sweep.py``): the
    per-site energies become negated log-weights (negation is exact, so
    ``-(e - min e)`` and ``(-e) - max(-e)`` feed the exp LUT the same
    floats) and the result is bitwise-identical to the XLA path.
    """
    b, h, w = labels.shape
    l = unary.shape[-1]
    if beta is None:
        energies = None  # keep the β-free trace byte-identical to the old one
    else:
        energies = unary[None] + neighbor_pair_energy(labels, pairwise)
        bb = jnp.asarray(beta, energies.dtype)
        energies = energies * (bb[:, None, None, None] if bb.ndim == 1 else bb)
    if sampler == "pallas":
        if energies is None:
            energies = unary[None] + neighbor_pair_energy(labels, pairwise)
        res = fused_gibbs_sample(
            key, (-energies).reshape((-1, l)), l, k=k, use_iu=use_iu,
            table=_EXP)
    else:
        if energies is None:
            wts = site_weights(labels, unary, pairwise, k=k, use_iu=use_iu)
        else:
            wts = _weights_from_energies(energies, k=k, use_iu=use_iu)
        res = ky_sample(key, wts.reshape((-1, l)))
    new = res.sample.reshape((b, h, w))
    mask = (((jnp.arange(h)[:, None] + jnp.arange(w)[None, :]) % 2) == parity)[None]
    if clamp is not None:
        mask = mask & ~(clamp if clamp.ndim == 3 else clamp[None])
    labels = jnp.where(mask, new, labels)
    zero = jnp.zeros((), jnp.int32)
    stats = SweepStats(
        bits_used=jnp.sum(jnp.where(mask, res.bits_used.reshape(labels.shape), zero)),
        attempts=jnp.sum(jnp.where(mask, res.attempts.reshape(labels.shape), zero)),
    )
    return labels, stats


@partial(jax.jit, static_argnames=("n_sweeps", "k", "use_iu", "sampler"))
def mrf_gibbs(
    key: jax.Array,
    labels0: jax.Array,
    unary: jax.Array,
    pairwise: jax.Array,
    *,
    n_sweeps: int,
    clamp: jax.Array | None = None,
    k: int = DEFAULT_K,
    use_iu: bool = True,
    sampler: str = "xla",
) -> tuple[jax.Array, SweepStats]:
    """n_sweeps full checkerboard sweeps (2 half-steps each).

    ``clamp`` ((H, W) or (B, H, W) bool) freezes evidence sites for the
    whole run — pin their labels in ``labels0`` first (see
    :func:`clamp_labels`); clamped sites never resample but stay visible
    to their neighbours' energies.
    """

    def sweep(carry, i):
        labels, key = carry
        key, k0, k1 = jax.random.split(key, 3)
        labels, s0 = checkerboard_halfstep(
            k0, labels, unary, pairwise, jnp.int32(0), clamp=clamp,
            k=k, use_iu=use_iu, sampler=sampler)
        labels, s1 = checkerboard_halfstep(
            k1, labels, unary, pairwise, jnp.int32(1), clamp=clamp,
            k=k, use_iu=use_iu, sampler=sampler)
        return (labels, key), SweepStats(
            bits_used=s0.bits_used + s1.bits_used,
            attempts=s0.attempts + s1.attempts,
        )

    (labels, _), stats = jax.lax.scan(
        sweep, (labels0, key), jnp.arange(n_sweeps))
    return labels, SweepStats(
        bits_used=jnp.sum(stats.bits_used), attempts=jnp.sum(stats.attempts))


def clamp_labels(labels: jax.Array, clamp: jax.Array,
                 values: jax.Array) -> jax.Array:
    """Pin clamped sites of a (B, H, W) label field to their observed
    values ((H, W) or (B, H, W)); the companion of ``mrf_gibbs(clamp=)``."""
    clamp = jnp.asarray(clamp, bool)
    values = jnp.asarray(values, labels.dtype)
    if clamp.ndim == 2:
        clamp = clamp[None]
    if values.ndim == 2:
        values = values[None]
    return jnp.where(clamp, values, labels)


def init_labels(key: jax.Array, mrf: MRFGrid, n_chains: int) -> jax.Array:
    h, w = mrf.shape
    return jax.random.randint(key, (n_chains, h, w), 0, mrf.n_labels, jnp.int32)


_EXP = exp_table()
