"""Distributed checkerboard Gibbs: inter-core register sharing on TPU (C3).

The AIA mesh lets a core read its N/E/S/W neighbours' shared registers in
one cycle instead of bouncing through the global buffer.  The TPU-native
analogue (DESIGN.md §2): shard the MRF lattice into per-device tiles over
a 2D `("row", "col")` device mesh and exchange **one-site halos** with the
four neighbours via `jax.lax.ppermute` (nearest-neighbour ICI collective-
permute) before each checkerboard half-step.

The "global buffer" baseline the paper compares against is also provided:
`all_gather` the full label field every half-step.  Per half-step and
device, halo exchange moves `2·(ht+wt)·4B` over nearest-neighbour links,
the baseline moves `(H·W − ht·wt)·4B` through the all-gather — the
benchmark reports the measured HLO collective bytes for both (the 3×
memory-read reduction analogue of Fig. 3b).

Grids whose H or W is not a tile multiple are padded; pad sites are
pinned to label 0 by their unary term *and* masked out of their real
neighbours' pairwise sums via the validity mask that `pad_mrf` /
`shard_mrf` produce (see `pad_mrf` for why the mask is load-bearing).

Devices: this module is mesh-agnostic; tests exercise it in a subprocess
with `--xla_force_host_platform_device_count`.  `shard_map` is resolved
from `jax.shard_map` with a fallback to `jax.experimental.shard_map` so
the module also runs on older jax (0.4.x) installs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fixedpoint import DEFAULT_K
from repro.core.interp import exp_table
from repro.core.ky import ky_sample
from repro.kernels.fused_sweep import fused_gibbs_sample
from repro.pgm.graph import MRFGrid

try:
    _shard_map = jax.shard_map
    _SHARD_MAP_KW: dict = {}
except AttributeError:  # pragma: no cover - jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map
    # the old replication checker has no rule for while_loop (ky_sample)
    _SHARD_MAP_KW = {"check_rep": False}

_EXP = exp_table()


class MeshMRF(NamedTuple):
    unary: jax.Array      # (H, W, L) sharded P("row", "col", None)
    pairwise: jax.Array   # (L, L) replicated
    h: int
    w: int


def pad_mrf(
    mrf: MRFGrid, nr: int, nc: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int, int]:
    """Pad unary to tile multiples; returns (unary, pairwise, valid, H', W').

    Pad (dummy) sites are pinned to label 0 by a huge unary penalty on
    every other label, and ``valid`` — True exactly on the true H×W
    extent — masks them out of their neighbours' pairwise sums.  The
    pinning alone is NOT enough: pad sites sit adjacent to real boundary
    sites, so without the mask they inject label-0 pairwise energy into
    rows h-1 / cols w-1 and bias the marginals whenever H or W is not a
    tile multiple.
    """
    h, w = mrf.shape
    hp, wp = -h % nr, -w % nc
    unary = np.pad(mrf.unary, ((0, hp), (0, wp), (0, 0)))
    if hp or wp:
        unary[h:, :, 1:] = 1e6
        unary[:, w:, 1:] = 1e6
    valid = np.zeros((h + hp, w + wp), bool)
    valid[:h, :w] = True
    return unary, mrf.pairwise, valid, h + hp, w + wp


def _halo_exchange(tile: jax.Array, row_axis: str, col_axis: str,
                   nr: int, nc: int) -> jax.Array:
    """Collect N/S/E/W one-site halos of a (B, ht, wt) int32 tile.

    ``nr``/``nc`` are the static mesh axis sizes (the ppermute pairs need
    concrete indices; ``jax.lax.axis_size`` is also absent on jax 0.4.x).
    Returns the padded (B, ht+2, wt+2) labels; which halo entries are
    *meaningful* is the caller's precomputed validity mask's business
    (:func:`blocked_validity` covers both the global boundary and pad
    sites).
    """

    def shift(x, axis_name, n, d):
        # receive from neighbour at index (i - d); edge devices get zeros
        perm = [(i, i + d) for i in range(n) if 0 <= i + d < n]
        return jax.lax.ppermute(x, axis_name, perm)

    north = shift(tile[:, -1:, :], row_axis, nr, +1)   # north nbr's last row
    south = shift(tile[:, :1, :], row_axis, nr, -1)    # south nbr's first row
    west = shift(tile[:, :, -1:], col_axis, nc, +1)
    east = shift(tile[:, :, :1], col_axis, nc, -1)

    b, ht, wt = tile.shape
    padded = jnp.zeros((b, ht + 2, wt + 2), tile.dtype)
    padded = padded.at[:, 1:-1, 1:-1].set(tile)
    padded = padded.at[:, 0, 1:-1].set(north[:, 0])
    padded = padded.at[:, -1, 1:-1].set(south[:, 0])
    padded = padded.at[:, 1:-1, 0].set(west[:, :, 0])
    padded = padded.at[:, 1:-1, -1].set(east[:, :, 0])
    return padded


def _tile_energies(padded, valid, unary_tile, pairwise):
    """(B, ht, wt, L) candidate-label energies from padded labels."""
    pwt = pairwise.T  # pw[l, m] -> row per neighbour label m
    ht, wt = unary_tile.shape[:2]

    def contrib(sl_r, sl_c):
        nbr = padded[:, sl_r, sl_c]
        v = valid[sl_r, sl_c]
        return jnp.take(pwt, nbr, axis=0) * v[None, :, :, None]

    inner_r, inner_c = slice(1, ht + 1), slice(1, wt + 1)
    e = unary_tile[None]
    e = e + contrib(slice(0, ht), inner_c)        # north
    e = e + contrib(slice(2, ht + 2), inner_c)    # south
    e = e + contrib(inner_r, slice(0, wt))        # west
    e = e + contrib(inner_r, slice(2, wt + 2))    # east
    return e


def make_mesh_gibbs_step(
    mesh: Mesh,
    *,
    row_axis: str = "row",
    col_axis: str = "col",
    k: int = DEFAULT_K,
    use_iu: bool = True,
    sampler: str = "xla",
    comm: str = "halo",  # "halo" (C3) | "allgather" (global-buffer baseline)
    clamped: bool = False,
):
    """Build the jitted distributed full-sweep fn.

    Signature: ``(key, labels, unary, pairwise, valid) -> (labels, bits)``
    with ``valid`` the *blocked padded* validity mask from
    :func:`shard_mrf`: each device's (ht+2, wt+2) tile already combines
    the global-boundary halo ring with the true-H×W extent, precomputed
    host-side — it is static data, so it costs no per-sweep collective.
    ``bits`` is a per-device (nr, nc) int32 grid of random bits spent by
    *real* (non-pad) sites this sweep — sum it host-side in int64
    (``np.asarray(bits, np.int64).sum()``); the old cross-mesh int32
    ``psum`` silently wrapped on large grids / long accumulations.

    With ``clamped=True`` the signature grows a trailing ``clamp``
    operand — an (H', W') bool field (True = observed pixel, sharded
    like the lattice; see :func:`shard_clamp`).  Clamped sites are
    excluded from the checkerboard update and the bit accounting but,
    unlike pad sites, stay *inside* the validity mask: their fixed
    labels keep feeding pairwise energy to their neighbours, which is
    what makes this evidence conditioning rather than lattice surgery.
    """
    nr, nc = mesh.shape[row_axis], mesh.shape[col_axis]

    def body(key, labels, unary_tile, pairwise, pvalid, *rest):
        r = jax.lax.axis_index(row_axis)
        c = jax.lax.axis_index(col_axis)
        key = jax.random.fold_in(key, r * nc + c)
        b, ht, wt = labels.shape
        l = unary_tile.shape[-1]
        row0, col0 = r * ht, c * wt
        # Neighbour validity masks pad sites out of real boundary sites'
        # pairwise sums (see pad_mrf); its interior is the tile's own
        # update/stats mask.
        valid_tile = pvalid[1:-1, 1:-1]
        if clamped:
            valid_tile = valid_tile & ~rest[0]

        def gather(tile):
            """(B, ht, wt) tile -> halo-padded (B, ht+2, wt+2) labels."""
            if comm == "halo":
                return _halo_exchange(tile, row_axis, col_axis, nr, nc)
            full = jax.lax.all_gather(tile, row_axis, axis=1, tiled=True)
            full = jax.lax.all_gather(full, col_axis, axis=2, tiled=True)
            hg, wg = nr * ht, nc * wt
            padded = jnp.zeros((tile.shape[0], hg + 2, wg + 2), tile.dtype)
            padded = padded.at[:, 1:-1, 1:-1].set(full)
            return jax.lax.dynamic_slice(
                padded, (0, row0, col0), (tile.shape[0], ht + 2, wt + 2))

        def halfstep(labels, parity, subkey):
            padded = gather(labels)
            e = _tile_energies(padded, pvalid, unary_tile, pairwise)
            if sampler == "pallas":
                # negation is exact, so (-e) - max(-e) == -(e - min e):
                # the fused kernel sees the same floats as the XLA tail
                res = fused_gibbs_sample(
                    subkey, (-e).reshape((-1, l)), l, k=k, use_iu=use_iu,
                    table=_EXP)
            else:
                z = e - jnp.min(e, axis=-1, keepdims=True)
                y = _EXP(-z) if use_iu else jnp.exp(-z)
                wts = jnp.floor(y * (2.0 ** k - 1.0)).astype(jnp.int32)
                res = ky_sample(subkey, wts.reshape((-1, l)))
            new = res.sample.reshape((b, ht, wt))
            gi = row0 + jnp.arange(ht)[:, None]
            gj = col0 + jnp.arange(wt)[None, :]
            # pad sites neither update nor count toward bit accounting
            mask = (((gi + gj) % 2) == parity) & valid_tile
            return jnp.where(mask[None], new, labels), jnp.sum(
                jnp.where(mask[None], res.bits_used.reshape((b, ht, wt)), 0))

        k0, k1 = jax.random.split(key)
        labels, bits0 = halfstep(labels, 0, k0)
        labels, bits1 = halfstep(labels, 1, k1)
        # per-device int32 is tile-local and safe; the global total is the
        # caller's int64 sum of the (nr, nc) grid
        return labels, (bits0 + bits1).reshape(1, 1)

    in_specs = (P(), P(None, row_axis, col_axis),
                P(row_axis, col_axis, None), P(), P(row_axis, col_axis))
    if clamped:
        in_specs = in_specs + (P(row_axis, col_axis),)
    mapped = _shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(None, row_axis, col_axis), P(row_axis, col_axis)),
        **_SHARD_MAP_KW,
    )
    return jax.jit(mapped)


def blocked_validity(valid: np.ndarray, nr: int, nc: int) -> np.ndarray:
    """Per-tile padded validity masks, blocked for P(row, col) sharding.

    From the (H', W') extent mask, build a (nr*(ht+2), nc*(wt+2)) array
    whose (r, c) block is tile (r, c)'s halo-padded mask: the tile's own
    sites plus its one-site neighbour ring, False outside the global
    lattice and on pad sites.  Static data — computing it here (host,
    once) keeps the per-sweep step free of a mask exchange collective.
    """
    hp, wp = valid.shape
    ht, wt = hp // nr, wp // nc
    g = np.zeros((hp + 2, wp + 2), bool)
    g[1:-1, 1:-1] = valid
    out = np.zeros((nr * (ht + 2), nc * (wt + 2)), bool)
    for r in range(nr):
        for c in range(nc):
            out[r * (ht + 2):(r + 1) * (ht + 2),
                c * (wt + 2):(c + 1) * (wt + 2)] = (
                g[r * ht:r * ht + ht + 2, c * wt:c * wt + wt + 2])
    return out


def shard_mrf(mesh: Mesh, mrf: MRFGrid, n_chains: int, key: jax.Array,
              row_axis: str = "row", col_axis: str = "col"):
    """Pad + device_put the MRF, its validity mask, and an initial label
    field onto the mesh; returns ``(labels, unary, pairwise, valid, (H', W'))``.

    ``valid`` is the blocked per-tile padded mask from
    :func:`blocked_validity` — pass it straight to the step function
    from :func:`make_mesh_gibbs_step`.
    """
    nr, nc = mesh.shape[row_axis], mesh.shape[col_axis]
    unary, pairwise, valid, hp, wp = pad_mrf(mrf, nr, nc)
    labels0 = jax.random.randint(key, (n_chains, hp, wp), 0, mrf.n_labels, jnp.int32)
    labels0 = jnp.where(jnp.asarray(valid)[None], labels0, 0)  # pin pad sites
    u = jax.device_put(jnp.asarray(unary),
                       NamedSharding(mesh, P(row_axis, col_axis, None)))
    lab = jax.device_put(labels0,
                         NamedSharding(mesh, P(None, row_axis, col_axis)))
    pw = jax.device_put(jnp.asarray(pairwise), NamedSharding(mesh, P()))
    v = jax.device_put(jnp.asarray(blocked_validity(valid, nr, nc)),
                       NamedSharding(mesh, P(row_axis, col_axis)))
    return lab, u, pw, v, (hp, wp)


def shard_clamp(mesh: Mesh, clamp: np.ndarray, values: np.ndarray,
                labels: jax.Array, row_axis: str = "row",
                col_axis: str = "col") -> tuple[jax.Array, jax.Array]:
    """Pad + place a pixel-evidence mask for the clamped mesh step.

    ``clamp``/``values`` are (H, W) over the *true* lattice; the label
    field ``labels`` is the padded (B, H', W') one from :func:`shard_mrf`.
    Returns ``(labels, clamp_dev)``: labels with every clamped site
    pinned to its observed value, and the (H', W') device mask to pass
    as the trailing operand of ``make_mesh_gibbs_step(clamped=True)``.
    Pad sites stay unclamped — the validity mask already freezes them.
    """
    b, hp, wp = labels.shape
    h, w = np.asarray(clamp).shape
    pc = np.zeros((hp, wp), bool)
    pc[:h, :w] = np.asarray(clamp, bool)
    pv = np.zeros((hp, wp), np.int32)
    pv[:h, :w] = np.where(np.asarray(clamp, bool),
                          np.asarray(values, np.int32), 0)
    labels = jnp.where(jnp.asarray(pc)[None], jnp.asarray(pv)[None], labels)
    labels = jax.device_put(
        labels, NamedSharding(mesh, P(None, row_axis, col_axis)))
    clamp_dev = jax.device_put(
        jnp.asarray(pc), NamedSharding(mesh, P(row_axis, col_axis)))
    return labels, clamp_dev
