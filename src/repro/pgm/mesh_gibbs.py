"""Distributed checkerboard Gibbs: inter-core register sharing on TPU (C3).

The AIA mesh lets a core read its N/E/S/W neighbours' shared registers in
one cycle instead of bouncing through the global buffer.  The TPU-native
analogue (DESIGN.md §2): shard the MRF lattice into per-device tiles over
a 2D `("row", "col")` device mesh and exchange **one-site halos** with the
four neighbours via `jax.lax.ppermute` (nearest-neighbour ICI collective-
permute) before each checkerboard half-step.

The "global buffer" baseline the paper compares against is also provided:
`all_gather` the full label field every half-step.  Per half-step and
device, halo exchange moves `2·(ht+wt)·4B` over nearest-neighbour links,
the baseline moves `(H·W − ht·wt)·4B` through the all-gather — the
benchmark reports the measured HLO collective bytes for both (the 3×
memory-read reduction analogue of Fig. 3b).

Devices: this module is mesh-agnostic; tests exercise it in a subprocess
with `--xla_force_host_platform_device_count`.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fixedpoint import DEFAULT_K
from repro.core.interp import exp_table
from repro.core.ky import ky_sample
from repro.pgm.graph import MRFGrid

_EXP = exp_table()


class MeshMRF(NamedTuple):
    unary: jax.Array      # (H, W, L) sharded P("row", "col", None)
    pairwise: jax.Array   # (L, L) replicated
    h: int
    w: int


def pad_mrf(mrf: MRFGrid, nr: int, nc: int) -> tuple[np.ndarray, np.ndarray, int, int]:
    """Pad unary to tile multiples with huge label-0 preference (dummy sites
    pinned to label 0 contribute a constant factor and never flip)."""
    h, w = mrf.shape
    hp, wp = -h % nr, -w % nc
    unary = np.pad(mrf.unary, ((0, hp), (0, wp), (0, 0)))
    if hp or wp:
        unary[h:, :, 1:] = 1e6
        unary[:, w:, 1:] = 1e6
    return unary, mrf.pairwise, h + hp, w + wp


def _halo_exchange(tile: jax.Array, row_axis: str, col_axis: str):
    """Collect N/S/E/W one-site halos of a (B, ht, wt) int32 tile.

    Returns padded (B, ht+2, wt+2) labels and a validity mask for the
    halo ring (False at the global boundary).
    """
    nr = jax.lax.axis_size(row_axis)
    nc = jax.lax.axis_size(col_axis)
    r = jax.lax.axis_index(row_axis)
    c = jax.lax.axis_index(col_axis)

    def shift(x, axis_name, n, d):
        # receive from neighbour at index (i - d); edge devices get zeros
        perm = [(i, i + d) for i in range(n) if 0 <= i + d < n]
        return jax.lax.ppermute(x, axis_name, perm)

    north = shift(tile[:, -1:, :], row_axis, nr, +1)   # north nbr's last row
    south = shift(tile[:, :1, :], row_axis, nr, -1)    # south nbr's first row
    west = shift(tile[:, :, -1:], col_axis, nc, +1)
    east = shift(tile[:, :, :1], col_axis, nc, -1)

    b, ht, wt = tile.shape
    padded = jnp.zeros((b, ht + 2, wt + 2), tile.dtype)
    padded = padded.at[:, 1:-1, 1:-1].set(tile)
    padded = padded.at[:, 0, 1:-1].set(north[:, 0])
    padded = padded.at[:, -1, 1:-1].set(south[:, 0])
    padded = padded.at[:, 1:-1, 0].set(west[:, :, 0])
    padded = padded.at[:, 1:-1, -1].set(east[:, :, 0])

    valid = jnp.ones((ht + 2, wt + 2), bool)
    valid = valid.at[0, :].set(r > 0)
    valid = valid.at[-1, :].set(r < nr - 1)
    valid = valid.at[:, 0].set(c > 0)
    valid = valid.at[:, -1].set(c < nc - 1)
    valid = valid.at[0, 0].set(False).at[0, -1].set(False)
    valid = valid.at[-1, 0].set(False).at[-1, -1].set(False)
    return padded, valid


def _tile_energies(padded, valid, unary_tile, pairwise):
    """(B, ht, wt, L) candidate-label energies from padded labels."""
    pwt = pairwise.T  # pw[l, m] -> row per neighbour label m
    ht, wt = unary_tile.shape[:2]

    def contrib(sl_r, sl_c):
        nbr = padded[:, sl_r, sl_c]
        v = valid[sl_r, sl_c]
        return jnp.take(pwt, nbr, axis=0) * v[None, :, :, None]

    inner_r, inner_c = slice(1, ht + 1), slice(1, wt + 1)
    e = unary_tile[None]
    e = e + contrib(slice(0, ht), inner_c)        # north
    e = e + contrib(slice(2, ht + 2), inner_c)    # south
    e = e + contrib(inner_r, slice(0, wt))        # west
    e = e + contrib(inner_r, slice(2, wt + 2))    # east
    return e


def make_mesh_gibbs_step(
    mesh: Mesh,
    *,
    row_axis: str = "row",
    col_axis: str = "col",
    k: int = DEFAULT_K,
    use_iu: bool = True,
    comm: str = "halo",  # "halo" (C3) | "allgather" (global-buffer baseline)
):
    """Build the jitted distributed full-sweep fn (key, labels, unary, pw)."""
    nr, nc = mesh.shape[row_axis], mesh.shape[col_axis]

    def body(key, labels, unary_tile, pairwise):
        r = jax.lax.axis_index(row_axis)
        c = jax.lax.axis_index(col_axis)
        key = jax.random.fold_in(key, r * nc + c)
        b, ht, wt = labels.shape
        l = unary_tile.shape[-1]
        row0, col0 = r * ht, c * wt

        def halfstep(labels, parity, subkey):
            if comm == "halo":
                padded, valid = _halo_exchange(labels, row_axis, col_axis)
            else:
                full = jax.lax.all_gather(labels, row_axis, axis=1, tiled=True)
                full = jax.lax.all_gather(full, col_axis, axis=2, tiled=True)
                hg, wg = nr * ht, nc * wt
                padded = jnp.zeros((b, hg + 2, wg + 2), labels.dtype)
                padded = padded.at[:, 1:-1, 1:-1].set(full)
                padded = jax.lax.dynamic_slice(
                    padded, (0, row0, col0), (b, ht + 2, wt + 2))
                valid = jnp.ones((ht + 2, wt + 2), bool)
                valid = valid.at[0, :].set(r > 0).at[-1, :].set(r < nr - 1)
                vc = valid[:, 0] & (c > 0)
                valid = valid.at[:, 0].set(vc)
                valid = valid.at[:, -1].set(valid[:, -1] & (c < nc - 1))
            e = _tile_energies(padded, valid, unary_tile, pairwise)
            z = e - jnp.min(e, axis=-1, keepdims=True)
            y = _EXP(-z) if use_iu else jnp.exp(-z)
            wts = jnp.floor(y * (2.0 ** k - 1.0)).astype(jnp.int32)
            res = ky_sample(subkey, wts.reshape((-1, l)))
            new = res.sample.reshape((b, ht, wt))
            gi = row0 + jnp.arange(ht)[:, None]
            gj = col0 + jnp.arange(wt)[None, :]
            mask = ((gi + gj) % 2) == parity
            return jnp.where(mask[None], new, labels), jnp.sum(
                jnp.where(mask[None], res.bits_used.reshape((b, ht, wt)), 0))

        k0, k1 = jax.random.split(key)
        labels, bits0 = halfstep(labels, 0, k0)
        labels, bits1 = halfstep(labels, 1, k1)
        bits = jax.lax.psum(bits0 + bits1, (row_axis, col_axis))
        return labels, bits

    mapped = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(None, row_axis, col_axis), P(row_axis, col_axis, None), P()),
        out_specs=(P(None, row_axis, col_axis), P()),
    )
    return jax.jit(mapped)


def shard_mrf(mesh: Mesh, mrf: MRFGrid, n_chains: int, key: jax.Array,
              row_axis: str = "row", col_axis: str = "col"):
    """Pad + device_put the MRF and an initial label field onto the mesh."""
    nr, nc = mesh.shape[row_axis], mesh.shape[col_axis]
    unary, pairwise, hp, wp = pad_mrf(mrf, nr, nc)
    labels0 = jax.random.randint(key, (n_chains, hp, wp), 0, mrf.n_labels, jnp.int32)
    u = jax.device_put(jnp.asarray(unary),
                       NamedSharding(mesh, P(row_axis, col_axis, None)))
    lab = jax.device_put(labels0,
                         NamedSharding(mesh, P(None, row_axis, col_axis)))
    pw = jax.device_put(jnp.asarray(pairwise), NamedSharding(mesh, P()))
    return lab, u, pw, (hp, wp)
