"""Sparse-graph compile layer: chromatic Gibbs on arbitrary factor graphs.

The unified back half of the compiler chain.  Where
:mod:`repro.pgm.compile` gathers CPT rows and
:mod:`repro.pgm.mrf_compile` freezes a checkerboard, this module takes
*any* pairwise :class:`~repro.pgm.graph.FactorGraph` (or
:class:`~repro.pgm.graph.IsingModel`) and lowers it to the same
IU-exp → fixed-point → non-normalized-KY sweep substrate:

1. **color** the interaction graph (:func:`repro.pgm.coloring.color_graph`
   — DSatur for small graphs, iterated MIS for huge ones) so each phase
   updates a conditionally-independent node set;
2. **pack** each color's neighbour lists into padded CSR-style gather
   plans, bucketed by ceil-power-of-two degree so one ``(G, D)`` gather
   serves all nodes of similar degree with bounded padding waste.
   Padded slots point at a **zero sentinel table**, so they contribute
   an exact ``+0.0`` to the energy — no runtime validity mask on the hot
   path;
3. **sweep**: per color, gather neighbour labels, accumulate pairwise
   energies table-by-table, add unaries, and feed the shared
   :func:`repro.pgm.compile.ky_weights` tail into one
   :func:`~repro.core.ky.ky_sample` call over every node of the color.

The dense checkerboard is the degenerate case — 2 colors, degree
bucket D=4, one shared table — and
:func:`repro.pgm.mrf_compile.sparse_plan` lowers a compiled grid onto
it with a per-site neighbour order chosen so the energies (and hence
the int32 KY weights) are **bitwise identical** to the dense
:func:`repro.pgm.gibbs.site_weights` path; tests regression-check that.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import DEFAULT_K
from repro.core.ky import ky_sample
from repro.kernels.fused_sweep import fused_gibbs_sample
from repro.pgm.coloring import color_graph
from repro.pgm.compile import BNSweepStats, ky_weights, sum_sweep_stats
from repro.pgm.graph import FactorGraph, IsingModel

# Neighbour accumulation is a short unrolled chain of adds below this
# degree cap (keeps the grid lowering's left-to-right float association
# explicit); above it one 4-D gather + sum wins.
_UNROLL_DEGREE = 8


@dataclass(frozen=True, eq=False)
class DegreeBucket:
    """All nodes of one color whose degree rounds up to the same D.

    ``nodes``: (G,) node ids.  ``nbr``: (G, D) neighbour ids (padded
    slots point at node 0 — harmless, their table is the sentinel).
    ``tab``: (G, D) directed-table ids into the compiled table bank;
    padded slots carry the all-zero sentinel id.  ``valid``: (G, D)
    bool, True where a real edge sits — not consumed by the sweep (the
    sentinel already zeroes the padding) but kept for introspection and
    the Metropolis path.
    """

    nodes: np.ndarray
    nbr: np.ndarray
    tab: np.ndarray
    valid: np.ndarray


@dataclass(frozen=True, eq=False)
class SparsePlan:
    """One color phase: degree buckets + the concatenated node order.

    ``nodes`` is exactly ``concat(b.nodes for b in buckets)`` — the
    order energies/samples come out of the bucket loop, used for the
    scatter back into the state vector.
    """

    buckets: tuple[DegreeBucket, ...]
    nodes: np.ndarray


@dataclass(frozen=True, eq=False)
class CompiledFactorGraph:
    """A compiled sparse sweep program (hashable by identity, like
    :class:`repro.pgm.compile.CompiledBN` — usable as a jit static arg).

    ``tables``: (T + 1, L, L) directed energy-table bank; the last entry
    is the all-zero padding sentinel.  ``plans``: one
    :class:`SparsePlan` per color.  ``observed``: sorted clamped node
    ids (the evidence *pattern* — values arrive at init time).
    """

    fg: FactorGraph
    unary: np.ndarray
    tables: np.ndarray
    plans: tuple[SparsePlan, ...]
    max_card: int
    k: int
    observed: tuple[int, ...] = ()

    @property
    def n_vars(self) -> int:
        return self.fg.n_vars

    @property
    def n_colors(self) -> int:
        return len(self.plans)

    @property
    def n_free(self) -> int:
        return self.n_vars - len(self.observed)

    @property
    def free_nodes(self) -> np.ndarray:
        mask = np.ones(self.n_vars, bool)
        if self.observed:
            mask[list(self.observed)] = False
        return np.flatnonzero(mask).astype(np.int32)


def _ceil_pow2(deg: np.ndarray) -> np.ndarray:
    """Elementwise smallest power of two >= max(deg, 1)."""
    caps = np.ones(len(deg), np.int64)
    m = np.maximum(np.asarray(deg, np.int64), 1)
    while (caps < m).any():
        caps = np.where(caps < m, caps * 2, caps)
    return caps


def _pack_plans(n: int, groups, dir_src, dir_dst, dir_tab,
                sentinel: int) -> tuple[SparsePlan, ...]:
    """Directed adjacency arrays → per-color degree-bucketed gather plans.

    The stable sort by source preserves the *given* per-source order of
    directed entries — the hook the grid lowering uses to pin its
    up/down/left/right accumulation order (and with it, bitwise energy
    equality against the dense path).
    """
    order = np.argsort(dir_src, kind="stable")
    s_dst = dir_dst[order]
    s_tab = dir_tab[order]
    counts = np.bincount(dir_src, minlength=n).astype(np.int64)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    plans = []
    for grp in groups:
        grp = np.asarray(grp, np.int64)
        deg = counts[grp]
        caps = _ceil_pow2(deg)
        buckets = []
        for cap in np.unique(caps):
            d = int(cap)
            sel = grp[caps == cap]
            degs = counts[sel]
            ar = np.arange(d)
            valid = ar[None, :] < degs[:, None]
            idx = np.where(valid, offsets[sel][:, None] + ar[None, :], 0)
            if len(s_dst):
                nbr = np.where(valid, s_dst[idx], 0)
                tab = np.where(valid, s_tab[idx], sentinel)
            else:
                nbr = np.zeros_like(idx)
                tab = np.full_like(idx, sentinel)
            buckets.append(DegreeBucket(
                nodes=sel.astype(np.int32), nbr=nbr.astype(np.int32),
                tab=tab.astype(np.int32), valid=valid))
        plans.append(SparsePlan(
            buckets=tuple(buckets),
            nodes=np.concatenate([b.nodes for b in buckets])))
    return tuple(plans)


def compile_factor_graph(
    model: FactorGraph | IsingModel,
    *,
    k: int = DEFAULT_K,
    observed=(),
    method: str = "auto",
    validate: bool = False,
    directed=None,
    groups=None,
) -> CompiledFactorGraph:
    """Lower a sparse model onto colored degree-bucketed gather plans.

    ``observed``: node ids to clamp (the evidence pattern; values are
    supplied at init time, so one program serves any values over its
    pattern).  ``method``/``validate`` pass through to
    :func:`~repro.pgm.coloring.color_graph`.

    ``directed``/``groups`` are lowering overrides for callers that
    already know the plan structure (the dense-grid path): ``directed``
    is ``(src, dst, tab_ids, table_bank)`` with per-source entry order
    preserved into the packed plans; ``groups`` is the per-color node
    partition.  Default lowering derives both from the graph: each
    undirected edge becomes two directed entries (the reverse direction
    sees the transposed table), the table bank is deduplicated, and
    entries are ordered by (src, dst).
    """
    fg = model.to_factor_graph() if isinstance(model, IsingModel) else model
    n = fg.n_vars
    L = fg.max_card
    observed = tuple(sorted({fg.index(v) for v in observed}))
    if len(observed) == n:
        raise ValueError("all variables clamped — nothing to infer")

    if directed is not None:
        dir_src, dir_dst, dir_tab, bank = directed
        dir_src = np.asarray(dir_src, np.int64)
        dir_dst = np.asarray(dir_dst, np.int64)
        dir_tab = np.asarray(dir_tab, np.int64)
        bank = np.asarray(bank, np.float32).reshape(-1, L, L)
    elif len(fg.edges):
        src = np.concatenate([fg.edges[:, 0], fg.edges[:, 1]]).astype(np.int64)
        dst = np.concatenate([fg.edges[:, 1], fg.edges[:, 0]]).astype(np.int64)
        both = np.concatenate([fg.pair, fg.pair.transpose(0, 2, 1)])
        bank, inv = np.unique(both.reshape(len(src), L * L), axis=0,
                              return_inverse=True)
        bank = bank.reshape(-1, L, L)
        order = np.lexsort((dst, src))
        dir_src, dir_dst = src[order], dst[order]
        dir_tab = inv.reshape(-1)[order].astype(np.int64)
    else:
        dir_src = dir_dst = dir_tab = np.zeros(0, np.int64)
        bank = np.zeros((0, L, L), np.float32)

    sentinel = len(bank)
    tables = np.concatenate(
        [bank, np.zeros((1, L, L), np.float32)]).astype(np.float32)

    if groups is None:
        groups = color_graph(n, fg.edges, skip=set(observed),
                             method=method, validate=validate)
    plans = _pack_plans(n, groups, dir_src, dir_dst, dir_tab, sentinel)
    return CompiledFactorGraph(
        fg=fg, unary=np.asarray(fg.unary, np.float32), tables=tables,
        plans=plans, max_card=L, k=k, observed=observed)


# ---------------------------------------------------------------------------
# sweep execution
# ---------------------------------------------------------------------------

def _plan_energies(x: jax.Array, plan: SparsePlan, unary: jax.Array,
                   tables_flat: jax.Array, max_card: int) -> jax.Array:
    """(B, N_color, L) candidate-label energies for one color phase.

    Pairwise contributions accumulate from an exact-zero init in the
    packed neighbour order, then unaries are added — the float
    association the dense grid path uses, which is what makes the
    degenerate 2-color lowering bitwise-equal to
    :func:`repro.pgm.gibbs.site_weights`.
    """
    L = max_card
    ls = jnp.arange(L, dtype=jnp.int32)
    parts = []
    for bk in plan.buckets:
        nbr = jnp.asarray(bk.nbr)                    # (G, D)
        tab = jnp.asarray(bk.tab)                    # (G, D)
        xn = x[:, nbr]                               # (B, G, D)
        g, d = bk.nbr.shape
        e = jnp.zeros((x.shape[0], g, L), jnp.float32)
        if d <= _UNROLL_DEGREE:
            for j in range(d):
                idx = (tab[:, j][None, :, None] * (L * L)
                       + ls[None, None, :] * L
                       + xn[:, :, j][:, :, None])    # (B, G, L)
                e = e + jnp.take(tables_flat, idx)
        else:
            idx = (tab[None, :, :, None] * (L * L)
                   + ls[None, None, None, :] * L
                   + xn[..., None])                  # (B, G, D, L)
            e = e + jnp.sum(jnp.take(tables_flat, idx), axis=-2)
        parts.append(e)
    e = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    nodes = jnp.asarray(plan.nodes)
    return unary[nodes][None] + e


def _sparse_color_update(
    key: jax.Array,
    x: jax.Array,               # (B, n) int32 current states
    plan: SparsePlan,
    unary: jax.Array,
    tables_flat: jax.Array,
    card: jax.Array,
    max_card: int,
    k: int,
    use_iu: bool,
    sampler: str = "xla",
    beta: jax.Array | None = None,   # traced inverse temperature, (B,) or scalar
) -> tuple[jax.Array, BNSweepStats]:
    """Resample every node of one color, all lanes at once.

    ``beta`` scales the candidate energies before the sampler branch
    (traced, per-lane (B,) or scalar) — the simulated-annealing hook of
    the MAP mode; None / 1.0 is ordinary Gibbs.  Both sampler branches
    see the scaled energies, so they stay bitwise-interchangeable at
    every β.

    ``sampler="pallas"`` hands the negated energies straight to the fused
    kernel (``kernels/fused_sweep.py``) — ``-energies`` is exactly the
    log-weight tensor ``ky_weights`` receives, so the fused path is
    bitwise-identical to the XLA path by construction.
    """
    nodes = jnp.asarray(plan.nodes)
    energies = _plan_energies(x, plan, unary, tables_flat, max_card)
    if beta is not None:
        bb = jnp.asarray(beta, energies.dtype)
        energies = energies * (bb[:, None, None] if bb.ndim == 1 else bb)
    if sampler == "pallas":
        lane_card = jnp.broadcast_to(
            card[nodes][None], energies.shape[:-1]).reshape(-1)
        res = fused_gibbs_sample(
            key, (-energies).reshape((-1, max_card)), lane_card,
            k=k, use_iu=use_iu)
    else:
        wts = ky_weights(-energies, card[nodes], k, use_iu)
        res = ky_sample(key, wts.reshape((-1, max_card)))
    new = res.sample.reshape(energies.shape[:-1]).astype(jnp.int32)
    x = x.at[:, nodes].set(new)
    return x, BNSweepStats(jnp.sum(res.bits_used), jnp.sum(res.attempts))


def site_weights_sparse(
    prog: CompiledFactorGraph, x: jax.Array, *, use_iu: bool = True
) -> jax.Array:
    """(B, n, L) int32 KY weights of every planned node given states ``x``.

    Debug/regression probe (clamped nodes report zero weights): the
    grid-lowering tests compare this bitwise against the dense
    :func:`repro.pgm.gibbs.site_weights`.
    """
    unary = jnp.asarray(prog.unary)
    tables_flat = jnp.asarray(prog.tables).reshape(-1)
    card = jnp.asarray(prog.fg.card, jnp.int32)
    out = jnp.zeros(x.shape[:1] + (prog.n_vars, prog.max_card), jnp.int32)
    for plan in prog.plans:
        energies = _plan_energies(x, plan, unary, tables_flat, prog.max_card)
        wts = ky_weights(-energies, card[jnp.asarray(plan.nodes)],
                         prog.k, use_iu)
        out = out.at[:, jnp.asarray(plan.nodes)].set(wts)
    return out


def make_fg_sweep(prog: CompiledFactorGraph, *, use_iu: bool = True,
                  sampler: str = "xla"):
    """Build the jitted one-sweep function: (key, x) -> (x', stats)."""
    unary = jnp.asarray(prog.unary)
    tables_flat = jnp.asarray(prog.tables).reshape(-1)
    card = jnp.asarray(prog.fg.card, jnp.int32)

    def sweep(key: jax.Array, x: jax.Array):
        bits = jnp.int32(0)
        att = jnp.int32(0)
        for plan in prog.plans:
            key, sub = jax.random.split(key)
            x, st = _sparse_color_update(
                sub, x, plan, unary, tables_flat, card, prog.max_card,
                prog.k, use_iu, sampler)
            bits, att = bits + st.bits_used, att + st.attempts
        return x, BNSweepStats(bits, att)

    return jax.jit(sweep)


def init_fg_states(
    key: jax.Array,
    prog: CompiledFactorGraph,
    n_lanes: int,
    evidence_values: jax.Array | None = None,
) -> jax.Array:
    """Random (B, n) initial states with evidence columns clamped.

    ``evidence_values`` aligns with ``prog.observed``: either (O,)
    shared across lanes or (B, O) per-lane — the serve engine packs
    different queries' clamp values into different lanes of one jitted
    sweep, exactly like BN evidence columns.
    """
    card = jnp.asarray(prog.fg.card, jnp.int32)
    u = jax.random.uniform(key, (n_lanes, prog.n_vars))
    x0 = (u * card[None]).astype(jnp.int32)
    if prog.observed:
        if evidence_values is None:
            raise ValueError(
                f"program clamps nodes {prog.observed} but no evidence given")
        ev = jnp.asarray(evidence_values, jnp.int32)
        if ev.ndim == 1:
            ev = jnp.broadcast_to(ev[None], (n_lanes, len(prog.observed)))
        x0 = x0.at[:, jnp.asarray(prog.observed, jnp.int32)].set(ev)
    return x0


@partial(jax.jit, static_argnames=(
    "prog", "n_sweeps", "n_chains", "burn_in", "use_iu", "sampler"))
def _run_fg_gibbs_device(
    key: jax.Array,
    prog: CompiledFactorGraph,
    *,
    n_chains: int,
    n_sweeps: int,
    burn_in: int,
    use_iu: bool = True,
    sampler: str = "xla",
    evidence=None,
    x0=None,
):
    """Jitted sparse-Gibbs scan; stats are per-sweep (n_sweeps,) int32."""
    key, init_key = jax.random.split(key)
    if x0 is None:
        x0 = init_fg_states(
            init_key, prog, n_chains,
            None if evidence is None else jnp.asarray(evidence, jnp.int32))
    unary = jnp.asarray(prog.unary)
    tables_flat = jnp.asarray(prog.tables).reshape(-1)
    card = jnp.asarray(prog.fg.card, jnp.int32)

    def body(carry, i):
        key, x, counts = carry
        key, sub = jax.random.split(key)
        bits, att = jnp.int32(0), jnp.int32(0)
        for plan in prog.plans:
            sub, s2 = jax.random.split(sub)
            x, st = _sparse_color_update(
                s2, x, plan, unary, tables_flat, card, prog.max_card,
                prog.k, use_iu, sampler)
            bits, att = bits + st.bits_used, att + st.attempts
        onehot = (x[..., None]
                  == jnp.arange(prog.max_card)[None, None]).astype(jnp.int32)
        counts = counts + jnp.where(i >= burn_in, jnp.sum(onehot, axis=0), 0)
        return (key, x, counts), BNSweepStats(bits, att)

    counts0 = jnp.zeros((prog.n_vars, prog.max_card), jnp.int32)
    (key, x, counts), per_sweep = jax.lax.scan(
        body, (key, x0, counts0), jnp.arange(n_sweeps))
    return x, counts, per_sweep


def run_fg_gibbs(
    key: jax.Array,
    prog: CompiledFactorGraph,
    *,
    n_chains: int,
    n_sweeps: int,
    burn_in: int,
    use_iu: bool = True,
    sampler: str = "xla",
    evidence=None,
    x0=None,
):
    """Run sparse chromatic Gibbs; returns (states, counts, stats).

    ``counts``: (n_vars, max_card) int32 accumulated after burn-in,
    summed over chains.  ``evidence``: values for ``prog.observed``
    (same order) — a *traced* argument, so one compiled program serves
    any values over its pattern without retracing.  ``x0`` optionally
    overrides the random init (e.g. the all-up start the ferromagnet
    tests use below the critical temperature).
    """
    x, counts, per_sweep = _run_fg_gibbs_device(
        key, prog, n_chains=n_chains, n_sweeps=n_sweeps, burn_in=burn_in,
        use_iu=use_iu, sampler=sampler, evidence=evidence,
        x0=None if x0 is None else jnp.asarray(x0, jnp.int32))
    return x, counts, sum_sweep_stats(per_sweep)
