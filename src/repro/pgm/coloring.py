"""Graph coloring stage of the AIA compiler chain (paper §III).

Splits model variables into conditionally-independent sets ("colors")
that can be updated in parallel.  MRF lattices get the closed-form
2-color checkerboard (block Gibbs); irregular models (Bayesian networks)
are colored with the DSatur heuristic on the moralized graph — the exact
combination the paper uses (aGrUM moralization + NetworkX DSatur [13]).
"""
from __future__ import annotations

import networkx as nx
import numpy as np

from repro.pgm.graph import BayesNet


def checkerboard(h: int, w: int) -> np.ndarray:
    """(H, W) int array of 2 colors — the MRF block-Gibbs pattern."""
    return ((np.arange(h)[:, None] + np.arange(w)[None, :]) % 2).astype(np.int32)


def dsatur(graph: nx.Graph) -> dict[int, int]:
    """DSatur coloring; returns node -> color (0-based)."""
    return nx.coloring.greedy_color(graph, strategy="saturation_largest_first")


def color_bayesnet(
    bn: BayesNet, skip: frozenset[int] | set[int] = frozenset()
) -> list[np.ndarray]:
    """Color the moral graph; returns per-color arrays of node ids.

    Invariant (checked): no two nodes in one color share an edge in the
    moral graph, i.e. they are conditionally independent given the rest —
    safe to Gibbs-update in parallel.

    ``skip``: evidence-clamped nodes.  They are excluded from the coloring
    entirely (they never get resampled), but the marriage edges they induce
    between free co-parents stay — two free parents of an observed child
    remain coupled through that child's CPT, so they must not share a
    color.  Dropping the observed nodes typically *reduces* the color
    count, which is exactly the paper's point about evidence shrinking the
    sweep critical path.
    """
    g = bn.moralized()
    if skip:
        g = g.subgraph([v for v in g.nodes if v not in skip])
    coloring = dsatur(g)
    if not coloring:
        return []
    n_colors = max(coloring.values()) + 1
    groups = [
        np.array(sorted(v for v, c in coloring.items() if c == col), np.int32)
        for col in range(n_colors)
    ]
    for grp in groups:  # validate the independence invariant
        s = set(grp.tolist())
        for v in grp:
            if s & set(g.neighbors(int(v))):
                raise AssertionError("coloring violates independence")
    return groups


def verify_coloring(graph: nx.Graph, groups: list[np.ndarray]) -> bool:
    seen: set[int] = set()
    for grp in groups:
        s = set(int(x) for x in grp)
        for v in s:
            if set(graph.neighbors(v)) & s:
                return False
        seen |= s
    return seen == set(graph.nodes)
