"""Graph coloring stage of the AIA compiler chain (paper §III).

Splits model variables into conditionally-independent sets ("colors")
that can be updated in parallel.  MRF lattices get the closed-form
2-color checkerboard (block Gibbs); irregular models are colored on
their interaction graph — DSatur for Bayesian networks and small sparse
graphs (the exact combination the paper uses: aGrUM moralization +
NetworkX DSatur [13]), and an iterated maximal-independent-set pass
(Luby-style) for huge sparse graphs where DSatur's sequential scan is
the bottleneck.  :func:`color_graph` is the one entry point the sparse
compile layer calls; both methods guarantee ≤ maxdeg + 1 colors.
"""
from __future__ import annotations

import networkx as nx
import numpy as np

from repro.pgm.graph import BayesNet

# DSatur walks nodes one at a time with a heap of saturation degrees —
# great colorings, serial time.  Past this many nodes the iterated-MIS
# pass wins by orders of magnitude and the (slightly) higher color count
# costs only a few extra sweep phases.
_PARALLEL_THRESHOLD = 20_000


def checkerboard(h: int, w: int) -> np.ndarray:
    """(H, W) int array of 2 colors — the MRF block-Gibbs pattern."""
    return ((np.arange(h)[:, None] + np.arange(w)[None, :]) % 2).astype(np.int32)


def dsatur(graph: nx.Graph) -> dict[int, int]:
    """DSatur coloring; returns node -> color (0-based)."""
    return nx.coloring.greedy_color(graph, strategy="saturation_largest_first")


def _groups_of(coloring: dict[int, int]) -> list[np.ndarray]:
    """node -> color mapping to sorted per-color id arrays."""
    if not coloring:
        return []
    n_colors = max(coloring.values()) + 1
    return [
        np.array(sorted(v for v, c in coloring.items() if c == col), np.int32)
        for col in range(n_colors)
    ]


def _mis_groups(n_vars: int, src: np.ndarray, dst: np.ndarray,
                active: np.ndarray) -> list[np.ndarray]:
    """Iterated-MIS coloring on (possibly masked) nodes, vectorized.

    Each outer round extracts one maximal independent set via Luby's
    algorithm (random priorities; a node wins when it beats every active
    neighbour) and assigns it the next color.  Any node left uncolored
    after a round had at least one neighbour colored in it, so the loop
    runs at most maxdeg + 1 rounds — the same bound greedy coloring has.
    ``src``/``dst`` must list each undirected edge in both directions.
    """
    rng = np.random.default_rng(0)  # deterministic plans: fixed priorities
    p = rng.permutation(n_vars).astype(np.int64) + 1  # 0 = "no neighbour"
    active = active.copy()
    groups: list[np.ndarray] = []
    while active.any():
        in_mis = np.zeros(n_vars, bool)
        cand = active.copy()
        live = cand[src] & cand[dst]
        s, d = src[live], dst[live]
        while cand.any():
            best = np.zeros(n_vars, np.int64)
            np.maximum.at(best, s, np.where(cand[d], p[d], 0))
            winners = cand & (p > best)
            if not winners.any():  # isolated remnants all win at once
                winners = cand.copy()
            in_mis |= winners
            # winners and their neighbours leave this round's candidacy
            out = winners.copy()
            np.logical_or.at(out, s, winners[d])
            cand &= ~out
            keep = cand[s] & cand[d]
            s, d = s[keep], d[keep]
        groups.append(np.flatnonzero(in_mis).astype(np.int32))
        active &= ~in_mis
    return groups


def color_graph(n_vars: int, edges: np.ndarray, *,
                skip: frozenset[int] | set[int] = frozenset(),
                method: str = "auto",
                validate: bool = False) -> list[np.ndarray]:
    """Color an undirected graph given as an (E, 2) edge list.

    Returns per-color sorted arrays of node ids covering every node not
    in ``skip`` (clamped nodes are never resampled, so they need no
    color — but edges into them are the caller's business, not ours: the
    compile layer keeps them as energy contributions).

    ``method``: ``"dsatur"`` (best color counts, serial),
    ``"parallel"`` (iterated MIS, for huge graphs), or ``"auto"``
    (DSatur below ~20k nodes).  ``validate=True`` re-checks the
    independence invariant with :func:`verify_coloring` — off by
    default so the serving hot path doesn't pay O(E) per compile.
    """
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    active = np.ones(n_vars, bool)
    if skip:
        active[np.fromiter(skip, np.int64, len(skip))] = False
    if method == "auto":
        method = "parallel" if n_vars > _PARALLEL_THRESHOLD else "dsatur"
    if method == "dsatur":
        g = nx.Graph()
        g.add_nodes_from(np.flatnonzero(active).tolist())
        keep = active[edges[:, 0]] & active[edges[:, 1]]
        g.add_edges_from(edges[keep].tolist())
        groups = _groups_of(dsatur(g))
    elif method == "parallel":
        src = np.concatenate([edges[:, 0], edges[:, 1]])
        dst = np.concatenate([edges[:, 1], edges[:, 0]])
        groups = _mis_groups(n_vars, src, dst, active)
        groups = [g for g in groups if len(g)]
    else:
        raise ValueError(f"unknown coloring method {method!r}")
    if validate:
        g = nx.Graph()
        g.add_nodes_from(np.flatnonzero(active).tolist())
        keep = active[edges[:, 0]] & active[edges[:, 1]]
        g.add_edges_from(edges[keep].tolist())
        if not verify_coloring(g, groups):
            raise AssertionError("coloring violates independence")
    return groups


def color_bayesnet(
    bn: BayesNet, skip: frozenset[int] | set[int] = frozenset(), *,
    validate: bool = False
) -> list[np.ndarray]:
    """Color the moral graph; returns per-color arrays of node ids.

    Invariant (checked under ``validate=True`` via
    :func:`verify_coloring`): no two nodes in one color share an edge in
    the moral graph, i.e. they are conditionally independent given the
    rest — safe to Gibbs-update in parallel.

    ``skip``: evidence-clamped nodes.  They are excluded from the coloring
    entirely (they never get resampled), but the marriage edges they induce
    between free co-parents stay — two free parents of an observed child
    remain coupled through that child's CPT, so they must not share a
    color.  Dropping the observed nodes typically *reduces* the color
    count, which is exactly the paper's point about evidence shrinking the
    sweep critical path.
    """
    g = bn.moralized()
    if skip:
        g = g.subgraph([v for v in g.nodes if v not in skip])
    groups = _groups_of(dsatur(g))
    if validate and not verify_coloring(g, groups):
        raise AssertionError("coloring violates independence")
    return groups


def verify_coloring(graph: nx.Graph, groups: list[np.ndarray]) -> bool:
    seen: set[int] = set()
    for grp in groups:
        s = set(int(x) for x in grp)
        for v in s:
            if set(graph.neighbors(v)) & s:
                return False
        seen |= s
    return seen == set(graph.nodes)
