"""Benchmark models: bnlearn-repository networks and the paper's MRF tasks.

* :func:`asia` — the classic 8-node chest-clinic net with its published
  CPTs (deterministic OR softened to 1e-3 so Gibbs stays ergodic — the
  standard treatment for MCMC over logic CPTs).
* :func:`sprinkler` — 4-node classic.
* :func:`random_bayesnet` — random-DAG nets with Dirichlet CPTs, used at
  child-scale (20 nodes) and alarm-scale (37 nodes) to match the paper's
  Fig. 7 workload sizes (exact repository CPTs are not redistributable
  in-source; scale and topology statistics are matched instead).
* :func:`penguin_task` / :func:`art_task` — the two MRF benchmarks of
  [MSSE, Tambe et al.]: binary image segmentation (Penguin, 500×333,
  L=2, Potts) and stereo matching (Art, 384×288, L=16, truncated
  linear), built synthetically at the same sizes.
"""
from __future__ import annotations

import numpy as np

from repro.pgm.graph import BayesNet, IsingModel, MRFGrid

_EPS = 1e-3  # determinism softening for ergodic Gibbs


def _cpt(rows) -> np.ndarray:
    a = np.asarray(rows, np.float64)
    return (a / a.sum(axis=-1, keepdims=True)).astype(np.float64)


def asia() -> BayesNet:
    """Chest clinic. States: 0 = no, 1 = yes. Nodes:
    0 asia, 1 tub, 2 smoke, 3 lung, 4 bronc, 5 either, 6 xray, 7 dysp."""
    e = _EPS
    cpts = [
        _cpt([0.99, 0.01]),                                   # asia
        _cpt([[0.99, 0.01], [0.95, 0.05]]),                   # tub | asia
        _cpt([0.5, 0.5]),                                     # smoke
        _cpt([[0.99, 0.01], [0.90, 0.10]]),                   # lung | smoke
        _cpt([[0.70, 0.30], [0.40, 0.60]]),                   # bronc | smoke
        _cpt([[[1 - e, e], [e, 1 - e]],                       # either | tub, lung
              [[e, 1 - e], [e, 1 - e]]]),
        _cpt([[0.95, 0.05], [0.02, 0.98]]),                   # xray | either
        _cpt([[[0.90, 0.10], [0.30, 0.70]],                   # dysp | bronc, either
              [[0.20, 0.80], [0.10, 0.90]]]),
    ]
    parents = [(), (0,), (), (2,), (2,), (1, 3), (5,), (4, 5)]
    names = ["asia", "tub", "smoke", "lung", "bronc", "either", "xray", "dysp"]
    return BayesNet([2] * 8, parents, cpts, names)


def sprinkler() -> BayesNet:
    """0 cloudy, 1 sprinkler, 2 rain, 3 wetgrass."""
    e = _EPS
    cpts = [
        _cpt([0.5, 0.5]),
        _cpt([[0.5, 0.5], [0.9, 0.1]]),
        _cpt([[0.8, 0.2], [0.2, 0.8]]),
        _cpt([[[1 - e, e], [0.1, 0.9]], [[0.1, 0.9], [0.01, 0.99]]]),
    ]
    return BayesNet([2] * 4, [(), (0,), (0,), (1, 2)], cpts,
                    ["cloudy", "sprinkler", "rain", "wetgrass"])


def random_bayesnet(
    n_nodes: int,
    *,
    max_parents: int = 3,
    max_card: int = 4,
    seed: int = 0,
    alpha: float = 1.0,
) -> BayesNet:
    """Random DAG + Dirichlet CPTs (topologically ordered node ids)."""
    rng = np.random.default_rng(seed)
    card = rng.integers(2, max_card + 1, n_nodes).tolist()
    parents: list[tuple[int, ...]] = []
    cpts: list[np.ndarray] = []
    for v in range(n_nodes):
        k = int(rng.integers(0, min(max_parents, v) + 1))
        ps = tuple(sorted(rng.choice(v, size=k, replace=False).tolist())) if k else ()
        parents.append(ps)
        shape = tuple(card[p] for p in ps) + (card[v],)
        cpts.append(rng.dirichlet([alpha] * card[v], size=shape[:-1]).reshape(shape))
    return BayesNet(card, parents, cpts)


def child_scale(seed: int = 1) -> BayesNet:
    """20-node net, cardinalities 2-6 — CHILD-repository scale."""
    return random_bayesnet(20, max_parents=3, max_card=6, seed=seed)


def alarm_scale(seed: int = 2) -> BayesNet:
    """37-node net, cardinalities 2-4 — ALARM-repository scale."""
    return random_bayesnet(37, max_parents=4, max_card=4, seed=seed)


def hailfinder_scale(seed: int = 3) -> BayesNet:
    """56-node net — HAILFINDER-repository scale."""
    return random_bayesnet(56, max_parents=4, max_card=5, seed=seed)


# ---------------------------------------------------------------------------
# MRF benchmark tasks (paper Fig. 7 workloads, at the published sizes)
# ---------------------------------------------------------------------------

def penguin_task(h: int = 500, w: int = 333, *, beta: float = 2.0, seed: int = 0,
                 noise: float = 0.6) -> tuple[MRFGrid, np.ndarray]:
    """Binary segmentation at the Penguin size (500×333, L=2).

    Synthesizes a blob ground truth, adds Gaussian noise, builds Gaussian
    unaries. Returns (mrf, ground_truth_labels).
    """
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w]
    cy, cx = h * 0.55, w * 0.5
    blob = (((yy - cy) / (0.33 * h)) ** 2 + ((xx - cx) / (0.28 * w)) ** 2) < 1.0
    blob |= (((yy - h * 0.25) / (0.12 * h)) ** 2 + ((xx - cx) / (0.10 * w)) ** 2) < 1.0
    truth = blob.astype(np.int32)
    img = truth + rng.normal(0, noise, (h, w))
    means = np.array([0.0, 1.0])
    unary = ((img[..., None] - means[None, None, :]) ** 2 / (2 * noise ** 2)).astype(np.float32)
    return MRFGrid.potts(unary, beta), truth


def art_task(h: int = 288, w: int = 384, *, n_labels: int = 16, beta: float = 1.0,
             tau: int = 4, seed: int = 0, noise: float = 1.5) -> tuple[MRFGrid, np.ndarray]:
    """Stereo-matching at the Art size (384×288, L=16, truncated linear).

    Synthesizes a piecewise-smooth disparity map, noisy matching costs.
    """
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w]
    truth = (
        (n_labels - 1)
        * (0.5 + 0.5 * np.sin(3 * np.pi * xx / w) * np.cos(2 * np.pi * yy / h))
    )
    truth = np.clip(np.round(truth), 0, n_labels - 1).astype(np.int32)
    obs = truth + rng.normal(0, noise, (h, w))
    unary = (np.abs(obs[..., None] - np.arange(n_labels)[None, None, :]) ** 2
             / (2 * noise ** 2)).astype(np.float32)
    return MRFGrid.truncated_linear(unary, beta, tau), truth


# ---------------------------------------------------------------------------
# Sparse Ising workloads (the sparse-Ising-machine family)
# ---------------------------------------------------------------------------

def ising_torus(side: int, *, beta: float = 0.4, j: float = 1.0,
                h: float = 0.0) -> IsingModel:
    """Ferromagnet on a ``side × side`` periodic lattice.

    The inverse temperature is folded into the couplings/fields
    (``J = beta * j``, ``h_v = beta * h``), so the model samples from
    ``P(s) ∝ exp(beta * (j Σ s_i s_j + h Σ s_v))``.  At ``h = 0`` the
    infinite-lattice magnetization is Onsager's
    ``M = (1 - sinh(2βj)^-4)^(1/8)`` for ``βj > βc ≈ 0.4407`` — the
    exactness oracle the sparse-path tests check against.
    """
    if side < 3:
        # side == 2 would duplicate edges (right and left neighbours
        # coincide under wraparound); the torus needs side >= 3.
        raise ValueError("ising_torus needs side >= 3")
    idx = np.arange(side * side).reshape(side, side)
    right = np.stack([idx, np.roll(idx, -1, axis=1)], axis=-1)
    down = np.stack([idx, np.roll(idx, -1, axis=0)], axis=-1)
    edges = np.concatenate([right.reshape(-1, 2), down.reshape(-1, 2)])
    return IsingModel(n=side * side, edges=edges,
                      j=np.full(len(edges), beta * j),
                      h=np.full(side * side, beta * h))


def random_sparse_ising(n: int, *, avg_degree: float = 3.0, beta: float = 0.3,
                        seed: int = 0, field: float = 0.1) -> IsingModel:
    """Random sparse spin glass: ~``n * avg_degree / 2`` unique edges,
    Gaussian couplings and fields scaled by ``beta`` — the irregular-
    graph workload that exercises degree-bucketed plans."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    pairs = rng.integers(0, n, size=(int(m * 1.5) + 8, 2))
    pairs = pairs[pairs[:, 0] != pairs[:, 1]]
    pairs = np.sort(pairs, axis=1)
    pairs = np.unique(pairs, axis=0)[:m]
    return IsingModel(n=n, edges=pairs,
                      j=beta * rng.normal(1.0, 0.5, len(pairs)),
                      h=beta * field * rng.normal(0.0, 1.0, n))
