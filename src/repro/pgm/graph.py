"""Probabilistic graphical model IR: MRF grids and Bayesian networks.

The AIA compiler front-end (paper §III) consumes PPL-described models
(aGrUM); here the IR is constructed directly in Python.  Two families —
the two the paper benchmarks:

* :class:`MRFGrid` — pairwise MRF on an H×W lattice (image segmentation,
  stereo matching), energies ``E(x) = Σ unary_s(x_s) + Σ_st V(x_s,x_t)``.
* :class:`BayesNet` — discrete BN with CPTs; Gibbs conditionals read the
  Markov blanket ``P(v|MB) ∝ P(v|pa(v)) Π_c P(c|pa(c))``.
* :class:`FactorGraph` — pairwise MRF on an *arbitrary* sparse graph
  (edge list + per-edge energy tables), the unified IR the sparse
  compile layer (:mod:`repro.pgm.sparse_compile`) consumes.  Both
  lattice grids and moralized BNs lower onto it.
* :class:`IsingModel` — spins on a sparse graph (couplings + fields),
  the paper-adjacent sparse-Ising-machine workload; a thin constructor
  over :class:`FactorGraph` with spin (±1) evidence conventions.

Classic bnlearn-repository networks (asia, sprinkler, child-like, random
DAGs) are in :mod:`repro.pgm.networks`, alongside the Ising lattices
(:func:`repro.pgm.networks.ising_torus`).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import networkx as nx
import numpy as np


@dataclass
class MRFGrid:
    """Pairwise MRF on an H×W lattice with L labels.

    ``unary``: (H, W, L) float32 energies (lower = more likely).
    ``pairwise``: (L, L) float32 compatibility energies; Potts is
    ``beta * (1 - I)``, truncated-linear (stereo) is
    ``min(|i-j|, tau) * beta``.
    """

    unary: np.ndarray
    pairwise: np.ndarray

    def __post_init__(self):
        self.unary = np.asarray(self.unary, np.float32)
        self.pairwise = np.asarray(self.pairwise, np.float32)
        if self.unary.ndim != 3:
            raise ValueError("unary must be (H, W, L)")
        l = self.unary.shape[-1]
        if self.pairwise.shape != (l, l):
            raise ValueError("pairwise must be (L, L)")

    @property
    def shape(self) -> tuple[int, int]:
        return self.unary.shape[:2]

    @property
    def n_labels(self) -> int:
        return self.unary.shape[-1]

    @staticmethod
    def potts(unary: np.ndarray, beta: float) -> "MRFGrid":
        l = unary.shape[-1]
        return MRFGrid(unary, beta * (1.0 - np.eye(l, dtype=np.float32)))

    @staticmethod
    def truncated_linear(unary: np.ndarray, beta: float, tau: int) -> "MRFGrid":
        l = unary.shape[-1]
        d = np.abs(np.arange(l)[:, None] - np.arange(l)[None, :])
        return MRFGrid(unary, (beta * np.minimum(d, tau)).astype(np.float32))

    def energy(self, labels: np.ndarray) -> float:
        """Total energy of a labeling (H, W) — the Gibbs invariant probe."""
        h, w = self.shape
        lab = np.asarray(labels)
        e = float(np.take_along_axis(self.unary, lab[..., None], axis=-1).sum())
        e += float(self.pairwise[lab[:, :-1], lab[:, 1:]].sum())
        e += float(self.pairwise[lab[:-1, :], lab[1:, :]].sum())
        return e


@dataclass
class BayesNet:
    """Discrete Bayesian network.

    ``card[v]``: cardinality of node v (nodes are 0..n-1, topologically
    sortable).  ``parents[v]``: tuple of parent ids.  ``cpt[v]``: ndarray
    of shape ``(*[card[p] for p in parents[v]], card[v])``, rows summing
    to 1.
    """

    card: list[int]
    parents: list[tuple[int, ...]]
    cpt: list[np.ndarray]
    names: list[str] = field(default_factory=list)

    def __post_init__(self):
        n = len(self.card)
        if not self.names:
            self.names = [f"x{i}" for i in range(n)]
        for v in range(n):
            want = tuple(self.card[p] for p in self.parents[v]) + (self.card[v],)
            got = tuple(self.cpt[v].shape)
            if want != got:
                raise ValueError(f"CPT shape mismatch at node {v}: {got} != {want}")
            s = self.cpt[v].sum(axis=-1)
            if not np.allclose(s, 1.0, atol=1e-5):
                raise ValueError(f"CPT rows of node {v} do not sum to 1")

    @property
    def n_nodes(self) -> int:
        return len(self.card)

    def index(self, node: int | str) -> int:
        """Resolve a node given by id or name to its id."""
        if isinstance(node, str):
            try:
                return self.names.index(node)
            except ValueError:
                raise KeyError(f"unknown node name {node!r}") from None
        v = int(node)
        if not 0 <= v < self.n_nodes:
            raise KeyError(f"node id {v} out of range")
        return v

    def normalize_evidence(self, evidence) -> dict[int, int]:
        """Map an {id-or-name: value} evidence dict to {id: value}, with
        range checks — the canonical form the compiler/serve layers use."""
        out: dict[int, int] = {}
        for node, val in dict(evidence or {}).items():
            v = self.index(node)
            val = int(val)
            if not 0 <= val < self.card[v]:
                raise ValueError(
                    f"evidence {self.names[v]}={val} outside card {self.card[v]}")
            if v in out and out[v] != val:
                raise ValueError(f"conflicting evidence for {self.names[v]}")
            out[v] = val
        return out

    def children(self, v: int) -> list[int]:
        return [c for c in range(self.n_nodes) if v in self.parents[c]]

    def markov_blanket(self, v: int) -> set[int]:
        mb = set(self.parents[v])
        for c in self.children(v):
            mb.add(c)
            mb |= set(self.parents[c])
        mb.discard(v)
        return mb

    def dag(self) -> nx.DiGraph:
        g = nx.DiGraph()
        g.add_nodes_from(range(self.n_nodes))
        for v in range(self.n_nodes):
            for p in self.parents[v]:
                g.add_edge(p, v)
        return g

    def moralized(self) -> nx.Graph:
        """Moral graph — the interaction graph Gibbs coloring runs on.

        (aGrUM's DAG→factor-graph step followed by variable-interaction
        extraction reduces to moralization for Gibbs scheduling.)
        """
        g = nx.Graph()
        g.add_nodes_from(range(self.n_nodes))
        for v in range(self.n_nodes):
            ps = self.parents[v]
            for p in ps:
                g.add_edge(p, v)
            for i in range(len(ps)):          # marry the parents
                for j in range(i + 1, len(ps)):
                    g.add_edge(ps[i], ps[j])
        return g

    def topo_order(self) -> list[int]:
        return list(nx.topological_sort(self.dag()))

    def logp(self, assignment: np.ndarray) -> float:
        """Joint log-probability of full assignment(s) (..., n_nodes)."""
        a = np.asarray(assignment)
        out = np.zeros(a.shape[:-1], np.float64)
        for v in range(self.n_nodes):
            idx = tuple(a[..., p] for p in self.parents[v]) + (a[..., v],)
            out += np.log(np.clip(self.cpt[v][idx], 1e-30, None))
        return out

    def sample_forward(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Ancestral samples (n, n_nodes) — ground truth for tests."""
        out = np.zeros((n, self.n_nodes), np.int64)
        for v in self.topo_order():
            rows = self.cpt[v][tuple(out[:, p] for p in self.parents[v])]
            u = rng.random((n, 1))
            out[:, v] = (rows.cumsum(axis=-1) < u).sum(axis=-1)
        return out

    def marginals_exact(self, evidence=None) -> list[np.ndarray]:
        """Brute-force (posterior) marginals — the test oracle.

        With ``evidence`` ({id-or-name: value}), enumerates only the
        assignments consistent with the observations and renormalizes,
        i.e. returns ``P(v | e)`` for every node (a delta at the observed
        value for evidence nodes).  Only for small nets.
        """
        total = math.prod(self.card)  # python ints: np.prod would overflow
        if total > 2_000_000:
            raise ValueError("net too large for brute force")
        grids = np.indices(tuple(self.card)).reshape(self.n_nodes, -1).T
        ev = self.normalize_evidence(evidence)
        for v, val in ev.items():
            grids = grids[grids[:, v] == val]
        lp = self.logp(grids)
        p = np.exp(lp - lp.max())
        z = p.sum()
        if not z > 0:
            raise ValueError("evidence has zero probability")
        p /= z
        return [
            np.bincount(grids[:, v], weights=p, minlength=self.card[v])
            for v in range(self.n_nodes)
        ]


def _canonical_edges(edges: np.ndarray,
                     pair: np.ndarray | None = None) -> tuple[np.ndarray, np.ndarray | None]:
    """Canonicalize an undirected edge list to unique (i < j) rows.

    Swapped rows transpose their energy table (``V(a, b)`` read from the
    other endpoint is ``V(b, a)``); duplicate edges are an error rather
    than silently merged — the caller's energies would double-count.
    """
    e = np.asarray(edges, np.int64).reshape(-1, 2)
    if e.size and (e[:, 0] == e[:, 1]).any():
        raise ValueError("self-loop in edge list")
    flip = e[:, 0] > e[:, 1]
    e = np.where(flip[:, None], e[:, ::-1], e)
    if pair is not None:
        pair = np.where(flip[:, None, None], pair.transpose(0, 2, 1), pair)
    if e.size:
        uniq = np.unique(e, axis=0)
        if len(uniq) != len(e):
            raise ValueError("duplicate edges in edge list")
    return e.astype(np.int32), pair


@dataclass
class FactorGraph:
    """Pairwise MRF over an arbitrary sparse graph — the unified sparse IR.

    ``card[v]``: cardinality of variable v (variables are 0..n-1).
    ``unary``: (n, L) float energies, L = max cardinality (entries past a
    variable's card are ignored — masked at compile time).
    ``edges``: (E, 2) int endpoints, canonicalized to unique i < j rows.
    ``pair``: (E, L, L) float energies; ``pair[e, a, b]`` is the energy of
    ``x[edges[e,0]] = a, x[edges[e,1]] = b`` (tables given against a
    swapped edge are transposed during canonicalization).

    The distribution is ``P(x) ∝ exp(-E(x))`` with
    ``E(x) = Σ_v unary[v, x_v] + Σ_e pair[e, x_i, x_j]`` — the same
    energy convention as :class:`MRFGrid`.

    Evidence values may use ``-1`` as an alias for label 0 on binary
    variables (spin-down, the Ising ±1 convention).
    """

    card: np.ndarray
    unary: np.ndarray
    edges: np.ndarray
    pair: np.ndarray
    names: list[str] = field(default_factory=list)

    def __post_init__(self):
        self.card = np.asarray(self.card, np.int32).reshape(-1)
        n = len(self.card)
        if n == 0:
            raise ValueError("empty factor graph")
        if (self.card < 1).any():
            raise ValueError("cardinalities must be >= 1")
        L = int(self.card.max())
        self.unary = np.asarray(self.unary, np.float32)
        if self.unary.shape != (n, L):
            raise ValueError(
                f"unary must be (n, max_card) = {(n, L)}, got {self.unary.shape}")
        pair = np.asarray(self.pair, np.float32).reshape(-1, L, L)
        self.edges, pair = _canonical_edges(
            np.asarray(self.edges, np.int64).reshape(-1, 2), pair)
        self.pair = np.ascontiguousarray(pair, np.float32)
        if self.edges.size and not (
                (0 <= self.edges) & (self.edges < n)).all():
            raise ValueError("edge endpoint outside [0, n)")
        if len(self.pair) != len(self.edges):
            raise ValueError("one (L, L) table per edge required")
        if self.names and len(self.names) != n:
            raise ValueError("names must cover every variable")

    @property
    def n_vars(self) -> int:
        return len(self.card)

    @property
    def max_card(self) -> int:
        return int(self.card.max())

    def var_name(self, v: int) -> str:
        """Display name of variable v (``names[v]`` or ``s<v>``).  Kept
        lazy — a million-spin graph never materializes a name list."""
        return self.names[v] if self.names else f"s{v}"

    def index(self, node: int | str) -> int:
        """Resolve a variable given by id, name, or ``"s<id>"``."""
        if isinstance(node, str):
            if self.names:
                try:
                    return self.names.index(node)
                except ValueError:
                    pass
            if node.startswith("s") and node[1:].isdigit():
                v = int(node[1:])
                if 0 <= v < self.n_vars:
                    return v
            raise KeyError(f"unknown variable name {node!r}")
        v = int(node)
        if not 0 <= v < self.n_vars:
            raise KeyError(f"variable id {v} out of range")
        return v

    def normalize_evidence(self, evidence) -> dict[int, int]:
        """{id-or-name: label} → {id: label}, with range/conflict checks.
        ``-1`` aliases label 0 on binary variables (spin-down)."""
        out: dict[int, int] = {}
        for node, val in dict(evidence or {}).items():
            v = self.index(node)
            val = int(val)
            if val == -1 and self.card[v] == 2:
                val = 0
            if not 0 <= val < self.card[v]:
                raise ValueError(
                    f"evidence {self.var_name(v)}={val} outside card "
                    f"{self.card[v]}")
            if v in out and out[v] != val:
                raise ValueError(f"conflicting evidence for {self.var_name(v)}")
            out[v] = val
        return out

    def energy(self, x: np.ndarray) -> np.ndarray:
        """Total energy of assignment(s) (..., n) — the Gibbs probe."""
        a = np.asarray(x, np.int64)
        u = self.unary.astype(np.float64)
        e = u[np.arange(self.n_vars), a].sum(axis=-1)
        if len(self.edges):
            i, j = self.edges[:, 0], self.edges[:, 1]
            e = e + self.pair.astype(np.float64)[
                np.arange(len(self.edges)), a[..., i], a[..., j]].sum(axis=-1)
        return e

    def marginals_exact(self, evidence=None) -> list[np.ndarray]:
        """Brute-force posterior marginals ``P(v | e)`` — the test
        oracle.  Only for small graphs (state count capped)."""
        total = math.prod(int(c) for c in self.card)
        if total > 2_000_000:
            raise ValueError("graph too large for brute force")
        grids = np.indices(tuple(int(c) for c in self.card))
        grids = grids.reshape(self.n_vars, -1).T
        ev = self.normalize_evidence(evidence)
        for v, val in ev.items():
            grids = grids[grids[:, v] == val]
        le = -self.energy(grids)
        p = np.exp(le - le.max())
        z = p.sum()
        if not z > 0:
            raise ValueError("evidence has zero probability")
        p /= z
        return [
            np.bincount(grids[:, v], weights=p, minlength=int(self.card[v]))
            for v in range(self.n_vars)
        ]


@dataclass
class IsingModel:
    """Spins on a sparse graph: ``E(s) = -Σ_e J_e s_i s_j - Σ_v h_v s_v``
    with ``s ∈ {-1, +1}`` and ``P(s) ∝ exp(-E(s))`` (couplings carry any
    inverse temperature — fold β into ``j``/``h``).

    Label convention on the sampling substrate: label ``l ∈ {0, 1}``
    maps to spin ``s = 2l - 1``; evidence may clamp with ``±1`` spins or
    ``{0, 1}`` labels interchangeably.  :meth:`to_factor_graph` lowers
    onto :class:`FactorGraph` (cached — the (E, 2, 2) tables are built
    once per model, which matters at a million spins).
    """

    n: int
    edges: np.ndarray
    j: np.ndarray
    h: np.ndarray

    def __post_init__(self):
        self.n = int(self.n)
        if self.n < 1:
            raise ValueError("need at least one spin")
        edges = np.asarray(self.edges, np.int64).reshape(-1, 2)
        self.edges, _ = _canonical_edges(edges)  # J is symmetric: no table flip
        if self.edges.size and not (
                (0 <= self.edges) & (self.edges < self.n)).all():
            raise ValueError("edge endpoint outside [0, n)")
        self.j = np.broadcast_to(
            np.asarray(self.j, np.float64), (len(self.edges),)).copy()
        self.h = np.broadcast_to(
            np.asarray(self.h, np.float64), (self.n,)).copy()
        self._fg: FactorGraph | None = None

    @property
    def n_vars(self) -> int:
        return self.n

    @property
    def max_card(self) -> int:
        return 2

    def var_name(self, v: int) -> str:
        return f"s{v}"

    def index(self, node: int | str) -> int:
        if isinstance(node, str):
            if node.startswith("s") and node[1:].isdigit():
                node = int(node[1:])
            else:
                raise KeyError(f"unknown spin name {node!r}")
        v = int(node)
        if not 0 <= v < self.n:
            raise KeyError(f"spin id {v} out of range")
        return v

    def normalize_evidence(self, evidence) -> dict[int, int]:
        """{id-or-name: spin-or-label} → {id: label}; ``-1`` means
        spin-down (label 0), ``+1``/``1`` means spin-up (label 1)."""
        out: dict[int, int] = {}
        for node, val in dict(evidence or {}).items():
            v = self.index(node)
            val = int(val)
            if val == -1:
                val = 0
            if val not in (0, 1):
                raise ValueError(
                    f"spin evidence s{v}={val}: expected -1/+1 or 0/1")
            if v in out and out[v] != val:
                raise ValueError(f"conflicting evidence for spin {v}")
            out[v] = val
        return out

    def to_factor_graph(self) -> FactorGraph:
        """Lower to the unified sparse IR: ``unary[v] = [h_v, -h_v]``,
        ``pair[e] = [[-J, J], [J, -J]]`` (label l ↔ spin 2l - 1)."""
        if self._fg is None:
            jj = self.j.astype(np.float32)
            hh = self.h.astype(np.float32)
            pair = np.empty((len(self.edges), 2, 2), np.float32)
            pair[:, 0, 0] = pair[:, 1, 1] = -jj
            pair[:, 0, 1] = pair[:, 1, 0] = jj
            unary = np.stack([hh, -hh], axis=1)
            self._fg = FactorGraph(
                card=np.full(self.n, 2, np.int32), unary=unary,
                edges=self.edges, pair=pair)
        return self._fg

    def magnetization(self, marginals: list[np.ndarray]) -> float:
        """Mean spin ⟨s⟩ from per-site label marginals (tests/benches)."""
        p_up = np.array([m[1] / max(m.sum(), 1e-30) for m in marginals])
        return float(np.mean(2.0 * p_up - 1.0))
