"""Probabilistic graphical model IR: MRF grids and Bayesian networks.

The AIA compiler front-end (paper §III) consumes PPL-described models
(aGrUM); here the IR is constructed directly in Python.  Two families —
the two the paper benchmarks:

* :class:`MRFGrid` — pairwise MRF on an H×W lattice (image segmentation,
  stereo matching), energies ``E(x) = Σ unary_s(x_s) + Σ_st V(x_s,x_t)``.
* :class:`BayesNet` — discrete BN with CPTs; Gibbs conditionals read the
  Markov blanket ``P(v|MB) ∝ P(v|pa(v)) Π_c P(c|pa(c))``.

Classic bnlearn-repository networks (asia, sprinkler, child-like, random
DAGs) are in :mod:`repro.pgm.networks`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import networkx as nx
import numpy as np


@dataclass
class MRFGrid:
    """Pairwise MRF on an H×W lattice with L labels.

    ``unary``: (H, W, L) float32 energies (lower = more likely).
    ``pairwise``: (L, L) float32 compatibility energies; Potts is
    ``beta * (1 - I)``, truncated-linear (stereo) is
    ``min(|i-j|, tau) * beta``.
    """

    unary: np.ndarray
    pairwise: np.ndarray

    def __post_init__(self):
        self.unary = np.asarray(self.unary, np.float32)
        self.pairwise = np.asarray(self.pairwise, np.float32)
        if self.unary.ndim != 3:
            raise ValueError("unary must be (H, W, L)")
        l = self.unary.shape[-1]
        if self.pairwise.shape != (l, l):
            raise ValueError("pairwise must be (L, L)")

    @property
    def shape(self) -> tuple[int, int]:
        return self.unary.shape[:2]

    @property
    def n_labels(self) -> int:
        return self.unary.shape[-1]

    @staticmethod
    def potts(unary: np.ndarray, beta: float) -> "MRFGrid":
        l = unary.shape[-1]
        return MRFGrid(unary, beta * (1.0 - np.eye(l, dtype=np.float32)))

    @staticmethod
    def truncated_linear(unary: np.ndarray, beta: float, tau: int) -> "MRFGrid":
        l = unary.shape[-1]
        d = np.abs(np.arange(l)[:, None] - np.arange(l)[None, :])
        return MRFGrid(unary, (beta * np.minimum(d, tau)).astype(np.float32))

    def energy(self, labels: np.ndarray) -> float:
        """Total energy of a labeling (H, W) — the Gibbs invariant probe."""
        h, w = self.shape
        lab = np.asarray(labels)
        e = float(np.take_along_axis(self.unary, lab[..., None], axis=-1).sum())
        e += float(self.pairwise[lab[:, :-1], lab[:, 1:]].sum())
        e += float(self.pairwise[lab[:-1, :], lab[1:, :]].sum())
        return e


@dataclass
class BayesNet:
    """Discrete Bayesian network.

    ``card[v]``: cardinality of node v (nodes are 0..n-1, topologically
    sortable).  ``parents[v]``: tuple of parent ids.  ``cpt[v]``: ndarray
    of shape ``(*[card[p] for p in parents[v]], card[v])``, rows summing
    to 1.
    """

    card: list[int]
    parents: list[tuple[int, ...]]
    cpt: list[np.ndarray]
    names: list[str] = field(default_factory=list)

    def __post_init__(self):
        n = len(self.card)
        if not self.names:
            self.names = [f"x{i}" for i in range(n)]
        for v in range(n):
            want = tuple(self.card[p] for p in self.parents[v]) + (self.card[v],)
            got = tuple(self.cpt[v].shape)
            if want != got:
                raise ValueError(f"CPT shape mismatch at node {v}: {got} != {want}")
            s = self.cpt[v].sum(axis=-1)
            if not np.allclose(s, 1.0, atol=1e-5):
                raise ValueError(f"CPT rows of node {v} do not sum to 1")

    @property
    def n_nodes(self) -> int:
        return len(self.card)

    def index(self, node: int | str) -> int:
        """Resolve a node given by id or name to its id."""
        if isinstance(node, str):
            try:
                return self.names.index(node)
            except ValueError:
                raise KeyError(f"unknown node name {node!r}") from None
        v = int(node)
        if not 0 <= v < self.n_nodes:
            raise KeyError(f"node id {v} out of range")
        return v

    def normalize_evidence(self, evidence) -> dict[int, int]:
        """Map an {id-or-name: value} evidence dict to {id: value}, with
        range checks — the canonical form the compiler/serve layers use."""
        out: dict[int, int] = {}
        for node, val in dict(evidence or {}).items():
            v = self.index(node)
            val = int(val)
            if not 0 <= val < self.card[v]:
                raise ValueError(
                    f"evidence {self.names[v]}={val} outside card {self.card[v]}")
            if v in out and out[v] != val:
                raise ValueError(f"conflicting evidence for {self.names[v]}")
            out[v] = val
        return out

    def children(self, v: int) -> list[int]:
        return [c for c in range(self.n_nodes) if v in self.parents[c]]

    def markov_blanket(self, v: int) -> set[int]:
        mb = set(self.parents[v])
        for c in self.children(v):
            mb.add(c)
            mb |= set(self.parents[c])
        mb.discard(v)
        return mb

    def dag(self) -> nx.DiGraph:
        g = nx.DiGraph()
        g.add_nodes_from(range(self.n_nodes))
        for v in range(self.n_nodes):
            for p in self.parents[v]:
                g.add_edge(p, v)
        return g

    def moralized(self) -> nx.Graph:
        """Moral graph — the interaction graph Gibbs coloring runs on.

        (aGrUM's DAG→factor-graph step followed by variable-interaction
        extraction reduces to moralization for Gibbs scheduling.)
        """
        g = nx.Graph()
        g.add_nodes_from(range(self.n_nodes))
        for v in range(self.n_nodes):
            ps = self.parents[v]
            for p in ps:
                g.add_edge(p, v)
            for i in range(len(ps)):          # marry the parents
                for j in range(i + 1, len(ps)):
                    g.add_edge(ps[i], ps[j])
        return g

    def topo_order(self) -> list[int]:
        return list(nx.topological_sort(self.dag()))

    def logp(self, assignment: np.ndarray) -> float:
        """Joint log-probability of full assignment(s) (..., n_nodes)."""
        a = np.asarray(assignment)
        out = np.zeros(a.shape[:-1], np.float64)
        for v in range(self.n_nodes):
            idx = tuple(a[..., p] for p in self.parents[v]) + (a[..., v],)
            out += np.log(np.clip(self.cpt[v][idx], 1e-30, None))
        return out

    def sample_forward(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Ancestral samples (n, n_nodes) — ground truth for tests."""
        out = np.zeros((n, self.n_nodes), np.int64)
        for v in self.topo_order():
            rows = self.cpt[v][tuple(out[:, p] for p in self.parents[v])]
            u = rng.random((n, 1))
            out[:, v] = (rows.cumsum(axis=-1) < u).sum(axis=-1)
        return out

    def marginals_exact(self, evidence=None) -> list[np.ndarray]:
        """Brute-force (posterior) marginals — the test oracle.

        With ``evidence`` ({id-or-name: value}), enumerates only the
        assignments consistent with the observations and renormalizes,
        i.e. returns ``P(v | e)`` for every node (a delta at the observed
        value for evidence nodes).  Only for small nets.
        """
        total = math.prod(self.card)  # python ints: np.prod would overflow
        if total > 2_000_000:
            raise ValueError("net too large for brute force")
        grids = np.indices(tuple(self.card)).reshape(self.n_nodes, -1).T
        ev = self.normalize_evidence(evidence)
        for v, val in ev.items():
            grids = grids[grids[:, v] == val]
        lp = self.logp(grids)
        p = np.exp(lp - lp.max())
        z = p.sum()
        if not z > 0:
            raise ValueError("evidence has zero probability")
        p /= z
        return [
            np.bincount(grids[:, v], weights=p, minlength=self.card[v])
            for v in range(self.n_nodes)
        ]
