"""PGM substrate: model IR, coloring, compiler chain, Gibbs engines."""
from repro.pgm.coloring import checkerboard, color_bayesnet, dsatur, verify_coloring
from repro.pgm.compile import (
    BNSweepStats, CompiledBN, compile_bayesnet, init_states, make_sweep,
    run_gibbs, sum_sweep_stats)
from repro.pgm.diagnostics import (
    Diagnostics, RunningDiagnostics, compute_diagnostics, ess_bulk,
    ess_tail, folded_rank_rhat, rank_normalize, rank_rhat, split_rhat)
from repro.pgm.gibbs import (
    checkerboard_halfstep, clamp_labels, init_labels, mrf_gibbs)
from repro.pgm.graph import BayesNet, MRFGrid
from repro.pgm.mesh_gibbs import (
    make_mesh_gibbs_step, pad_mrf, shard_clamp, shard_mrf)
from repro.pgm.mrf_compile import (
    CompiledMRF, compile_mrf, init_mrf_states, mask_of)
from repro.pgm import networks

__all__ = [
    "checkerboard", "color_bayesnet", "dsatur", "verify_coloring",
    "BNSweepStats", "CompiledBN", "compile_bayesnet", "init_states",
    "make_sweep", "run_gibbs", "sum_sweep_stats",
    "Diagnostics", "RunningDiagnostics", "compute_diagnostics",
    "ess_bulk", "ess_tail", "folded_rank_rhat", "rank_normalize",
    "rank_rhat", "split_rhat",
    "checkerboard_halfstep", "clamp_labels", "init_labels", "mrf_gibbs",
    "CompiledMRF", "compile_mrf", "init_mrf_states", "mask_of",
    "BayesNet", "MRFGrid", "make_mesh_gibbs_step", "pad_mrf",
    "shard_clamp", "shard_mrf",
    "networks",
]
