"""PGM substrate: model IR, coloring, compiler chain, Gibbs engines."""
from repro.pgm.coloring import (
    checkerboard, color_bayesnet, color_graph, dsatur, verify_coloring)
from repro.pgm.compile import (
    BNSweepStats, CompiledBN, compile_bayesnet, init_states, ky_weights,
    make_sweep, run_gibbs, sum_sweep_stats)
from repro.pgm.diagnostics import (
    Diagnostics, RunningDiagnostics, compute_diagnostics, ess_bulk,
    ess_tail, folded_rank_rhat, rank_normalize, rank_rhat, split_rhat)
from repro.pgm.gibbs import (
    checkerboard_halfstep, clamp_labels, init_labels, mrf_gibbs)
from repro.pgm.graph import BayesNet, FactorGraph, IsingModel, MRFGrid
from repro.pgm.mesh_gibbs import (
    make_mesh_gibbs_step, pad_mrf, shard_clamp, shard_mrf)
from repro.pgm.metropolis import MHStats, fg_metropolis, mrf_metropolis
from repro.pgm.mrf_compile import (
    CompiledMRF, compile_mrf, init_mrf_states, mask_of, mrf_factor_graph,
    sparse_plan)
from repro.pgm.sparse_compile import (
    CompiledFactorGraph, DegreeBucket, SparsePlan, compile_factor_graph,
    init_fg_states, make_fg_sweep, run_fg_gibbs, site_weights_sparse)
from repro.pgm import networks

__all__ = [
    "checkerboard", "color_bayesnet", "color_graph", "dsatur",
    "verify_coloring",
    "BNSweepStats", "CompiledBN", "compile_bayesnet", "init_states",
    "ky_weights", "make_sweep", "run_gibbs", "sum_sweep_stats",
    "Diagnostics", "RunningDiagnostics", "compute_diagnostics",
    "ess_bulk", "ess_tail", "folded_rank_rhat", "rank_normalize",
    "rank_rhat", "split_rhat",
    "checkerboard_halfstep", "clamp_labels", "init_labels", "mrf_gibbs",
    "CompiledMRF", "compile_mrf", "init_mrf_states", "mask_of",
    "mrf_factor_graph", "sparse_plan",
    "CompiledFactorGraph", "DegreeBucket", "SparsePlan",
    "compile_factor_graph", "init_fg_states", "make_fg_sweep",
    "run_fg_gibbs", "site_weights_sparse",
    "MHStats", "fg_metropolis", "mrf_metropolis",
    "BayesNet", "FactorGraph", "IsingModel", "MRFGrid",
    "make_mesh_gibbs_step", "pad_mrf", "shard_clamp", "shard_mrf",
    "networks",
]
