"""Convergence diagnostics: rank-normalized split-R̂ and ESS estimates.

The serving engine retires a posterior query the moment its chains are
*statistically sufficient* — the software analogue of AIA squeezing
maximum useful samples per joule out of its 16 Gibbs cores.  Plain
split-R̂ over round means (:func:`split_rhat`, the PR-3 retirement rule)
is known to miss slow-mixing chains: a near-deterministic node (asia's
OR gate) leaves every chain's round-mean sequence almost constant, so
within- and between-chain variances both vanish and R̂ reads 1.0 long
before the rare mode has ever been visited at the right rate.  This
module implements the Vehtari et al. (2021) rank-normalized family of
diagnostics, computed host-side from the per-round statistics
:class:`repro.serve.engine.GroupRun` already accumulates:

* :func:`rank_rhat` — split-R̂ of the rank → normal-quantile transform
  of the pooled draws.  Rank normalization makes the diagnostic
  invariant to monotone transforms and robust to heavy tails; constant-
  per-chain-but-different-across-chains sequences (the stuck-chain
  signature) rank far apart and blow the statistic up.
* :func:`folded_rank_rhat` — the same statistic on ``|x - median(x)|``,
  sensitive to chains that agree in location but not in scale (tail
  behaviour).
* :func:`ess_bulk` / :func:`ess_tail` — effective sample size via
  per-chain autocovariance with Geyer's initial-monotone-sequence
  truncation; bulk on the rank-normal draws, tail as the worst ESS of
  the 5%/95% quantile indicators.

Everything is NumPy (no jax): inputs are small host-side ``(chains,
rounds)`` statistic matrices, not device draws.  The per-round inputs
are *round means* — averages over ``sweeps_per_round`` sweeps — so raw
autocovariance ESS comes out in round units.  Given the per-round
second moments the runners also emit (``sqs``), :func:`compute_diagnostics`
rescales to sweep (draw) units via the batch-means identity
``ESS_draws = λ · ESS_rounds / Var⁺(round means)`` where ``λ`` is the
pooled per-draw marginal variance: iid draws recover ``ESS ≈ total
sweeps``, perfectly correlated rounds collapse to ``ESS = ESS_rounds``.

:class:`RunningDiagnostics` is the incremental front end the engine
uses: feed it one round of per-chain statistics at a time and
``compute()`` matches a one-shot computation on the pooled history
exactly (tested in ``tests/test_diagnostics.py``).

Doctest-checked walkthroughs live in ``docs/diagnostics.md``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

__all__ = [
    "Diagnostics", "RunningDiagnostics", "compute_diagnostics",
    "ess_bulk", "ess_mean", "ess_tail", "folded_rank_rhat",
    "normal_quantile", "rank_normalize", "rank_rhat", "split_chains",
    "split_rhat",
]


# -- primitives ------------------------------------------------------------
def normal_quantile(p: np.ndarray) -> np.ndarray:
    """Inverse standard-normal CDF Φ⁻¹(p), vectorized (Acklam's rational
    approximation, |relative error| < 1.15e-9 — plenty for rank z-scores,
    and keeps this module scipy-free)."""
    p = np.asarray(p, np.float64)
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    out = np.full(p.shape, np.nan)
    plow, phigh = 0.02425, 1 - 0.02425

    lo = (p > 0) & (p < plow)
    q = np.sqrt(-2 * np.log(np.where(lo, p, 0.5)))
    out = np.where(
        lo,
        (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5])
        / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1),
        out)
    hi = (p > phigh) & (p < 1)
    q = np.sqrt(-2 * np.log1p(-np.where(hi, p, 0.5)))
    out = np.where(
        hi,
        -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5])
        / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1),
        out)
    mid = (p >= plow) & (p <= phigh)
    q = np.where(mid, p, 0.5) - 0.5
    r = q * q
    out = np.where(
        mid,
        (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5])
        * q
        / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1),
        out)
    out = np.where(p == 0, -np.inf, out)
    out = np.where(p == 1, np.inf, out)
    return out


def _rankdata(x: np.ndarray) -> np.ndarray:
    """Average ranks (1-based) of a flat array, ties sharing their mean
    rank — scipy's ``rankdata(method='average')`` without scipy."""
    order = np.argsort(x, kind="stable")
    sx = x[order]
    # group boundaries of tied runs
    boundary = np.empty(len(sx), bool)
    boundary[0] = True
    boundary[1:] = sx[1:] != sx[:-1]
    group = np.cumsum(boundary) - 1
    starts = np.flatnonzero(boundary)
    ends = np.append(starts[1:], len(sx))
    avg = (starts + ends - 1) / 2.0 + 1.0   # mean of 1-based ranks per run
    ranks = np.empty(len(sx))
    ranks[order] = avg[group]
    return ranks


def rank_normalize(draws: np.ndarray) -> np.ndarray:
    """Rank → normal-quantile transform of pooled per-chain draws.

    Ranks are taken over *all* chains' draws pooled together (average
    ranks on ties), mapped through the fractional offset ``(rank − 3/8)
    / (S + 1/4)`` and Φ⁻¹ — the z-scale transform of Vehtari et al.
    (2021).  Shape-preserving: ``(chains, rounds) -> (chains, rounds)``.
    """
    draws = np.asarray(draws, np.float64)
    s = draws.size
    ranks = _rankdata(draws.ravel()).reshape(draws.shape)
    return normal_quantile((ranks - 0.375) / (s + 0.25))


def split_chains(draws: np.ndarray) -> np.ndarray:
    """Split each chain's sequence in half (dropping the odd trailing
    round) and stack the halves as separate chains:
    ``(c, r) -> (2c, r // 2)``."""
    draws = np.asarray(draws, np.float64)
    half = draws.shape[1] // 2
    return np.concatenate(
        [draws[:, :half], draws[:, half:2 * half]], axis=0)


def split_rhat(draws: np.ndarray) -> float:
    """Plain split-R̂ of per-chain draw sequences ``(chains, rounds)``.

    Each chain's sequence is split in half and the halves treated as
    separate chains — the standard Gelman–Rubin split variant (this is
    the ``retirement="legacy"`` rule, kept for baseline comparability).
    Returns 1.0 for degenerate (constant) statistics, inf when
    between-chain variance dominates a vanishing within-chain variance.
    """
    draws = np.asarray(draws, np.float64)
    c, r = draws.shape
    half = r // 2
    if c < 2 or half < 2:
        return float("inf")  # not enough draws to judge — keep sampling
    seqs = split_chains(draws)
    w = float(seqs.var(axis=1, ddof=1).mean())
    b = float(half * seqs.mean(axis=1).var(ddof=1))
    if w < 1e-12:
        return 1.0 if b < 1e-12 else float("inf")
    var_plus = (half - 1) / half * w + b / half
    return float(np.sqrt(var_plus / w))


def rank_rhat(draws: np.ndarray) -> float:
    """Rank-normalized split-R̂ (Vehtari et al. 2021, "bulk" R̂).

    The pooled draws are rank-normalized (:func:`rank_normalize`), then
    the ordinary split-R̂ is taken on the z-scores.  Detects stuck
    chains that plain split-R̂ misses: a chain frozen at a different
    level than its peers contributes near-zero within-chain variance on
    the raw scale (R̂ → 1 for near-constant statistics) but its ranks
    concentrate far from the other chains', inflating between-chain
    variance on the z-scale.
    """
    draws = np.asarray(draws, np.float64)
    if draws.shape[0] < 2 or draws.shape[1] // 2 < 2:
        return float("inf")
    if np.ptp(draws) == 0:           # every draw identical — no signal
        return 1.0
    return split_rhat(rank_normalize(draws))


def folded_rank_rhat(draws: np.ndarray) -> float:
    """Rank-normalized split-R̂ of the *folded* draws ``|x − median|``.

    Location-blind: chains that agree in mean but disagree in spread
    (one chain stuck in a mode, another oscillating across two) fold to
    visibly different magnitude distributions.  Vehtari et al. recommend
    reporting ``max(rank_rhat, folded_rank_rhat)``; the engine's rank
    retirement rule does exactly that.
    """
    draws = np.asarray(draws, np.float64)
    return rank_rhat(np.abs(draws - np.median(draws)))


# -- effective sample size -------------------------------------------------
def ess_mean(draws: np.ndarray) -> float:
    """ESS of the mean estimator over ``(chains, rounds)`` sequences.

    Splits each chain in half, estimates per-chain autocovariances
    directly (rounds are short — O(r²) beats FFT bookkeeping here),
    combines them with the between-chain variance a la BDA3/Stan, and
    truncates the autocorrelation sum with Geyer's initial positive +
    monotone sequence.  Returns the ESS **in units of the input draws**
    (so at most ``chains * rounds``, the iid count — antithetic chains
    are clipped to that instead of claiming super-efficiency), or 0.0
    when there are too few rounds to estimate anything (< 4 per split
    half the caller should keep sampling, not retire).
    """
    draws = np.asarray(draws, np.float64)
    total = draws.size
    seqs = split_chains(draws)
    m, n = seqs.shape
    if m < 2 or n < 2:
        return 0.0
    if np.ptp(seqs) == 0:            # constant — iid-equivalent by fiat
        return float(total)
    centered = seqs - seqs.mean(axis=1, keepdims=True)
    # acov[t, j] = (1/n) sum_i centered[j, i] centered[j, i+t]
    acov = np.stack([
        (centered[:, : n - t] * centered[:, t:]).sum(axis=1) / n
        for t in range(n)])
    mean_var = float(acov[0].mean()) * n / (n - 1)
    var_plus = mean_var * (n - 1) / n + float(seqs.mean(axis=1).var(ddof=1))
    if var_plus <= 0:
        return float(total)
    rho = 1.0 - (mean_var - acov.mean(axis=1)) / var_plus
    rho[0] = 1.0

    # Geyer initial positive sequence: keep whole (even, odd) lag pairs
    # (1,2), (3,4), ... while their sums stay positive — the first
    # negative pair truncates the autocorrelation sum (Geyer 1992 /
    # Stan).  rho[0] pairs with rho[1] conceptually, so walk from t=1.
    kept = np.zeros(n)
    kept[0] = 1.0
    if n > 1:
        kept[1] = rho[1]
    t = 1
    while t + 2 < n and rho[t + 1] + rho[t + 2] > 0:
        kept[t + 1] = rho[t + 1]
        kept[t + 2] = rho[t + 2]
        t += 2
    max_t = t
    # initial monotone sequence: each pair sum Γ_m = rho[2m] + rho[2m+1]
    # may not exceed the previous one (clips noise spikes in the acf tail)
    prev = kept[0] + kept[1] if n > 1 else kept[0]
    for i in range(2, max_t, 2):
        cur = kept[i] + kept[i + 1]
        if cur > prev:
            cur = prev
            kept[i] = kept[i + 1] = cur / 2.0
        prev = cur
    tau = -1.0 + 2.0 * float(kept[:max_t + 1].sum())
    tau = max(tau, 1.0 / math.log10(max(total, 10)))
    return float(min(total, total / tau))


def ess_bulk(draws: np.ndarray) -> float:
    """Bulk-ESS: :func:`ess_mean` of the rank-normalized draws — the
    effective count behind posterior-mean/central-interval estimates."""
    draws = np.asarray(draws, np.float64)
    if np.ptp(draws) == 0:
        return float(draws.size)
    return ess_mean(rank_normalize(draws))


def ess_tail(draws: np.ndarray) -> float:
    """Tail-ESS: worst ESS of the 5% / 95% quantile indicator chains
    (rank-normalized) — the effective count behind tail-probability
    estimates, which mix slower than the bulk."""
    draws = np.asarray(draws, np.float64)
    if np.ptp(draws) == 0:
        return float(draws.size)
    out = float(draws.size)
    for q in (0.05, 0.95):
        ind = (draws <= np.quantile(draws, q)).astype(np.float64)
        if np.ptp(ind) == 0:
            continue                 # indicator constant — no tail signal
        out = min(out, ess_mean(rank_normalize(ind)))
    return out


# -- engine-facing payload -------------------------------------------------
@dataclass
class Diagnostics:
    """Convergence payload attached to every :class:`repro.serve.query.
    Result`.

    ``rhat`` is the legacy plain split-R̂ (kept in both retirement modes
    so perf baselines stay comparable); ``rank_rhat``/``folded_rhat``
    and the ESS pair are the rank-normalized family this module exists
    for.  ESS values are in **sweep (draw) units** when the engine's
    runners supplied second moments, else in round units.
    ``sweeps_used`` is the total sweeps spent on the query including
    burn-in — ``ess_bulk / wall_s`` is the honest throughput analogue
    of the paper's MSample/s.
    """

    rhat: float = float("inf")
    rank_rhat: float = float("inf")
    folded_rhat: float = float("inf")
    ess_bulk: float = 0.0
    ess_tail: float = 0.0
    sweeps_used: int = 0

    @property
    def worst_rank_rhat(self) -> float:
        """max(rank_rhat, folded_rhat) — the quantity the engine's rank
        retirement rule thresholds."""
        return max(self.rank_rhat, self.folded_rhat)

    @property
    def min_ess(self) -> float:
        """min(ess_bulk, ess_tail) — the quantity the engine's rank
        retirement rule requires to exceed ``ess_target``."""
        return min(self.ess_bulk, self.ess_tail)


def _sweep_scale(means: np.ndarray, sqs: np.ndarray | None,
                 sweeps_per_round: int) -> float:
    """Round-units → sweep-units ESS factor via the batch-means identity.

    ``λ / Var⁺(round means)`` where λ is the pooled per-draw marginal
    variance recovered from the per-round second moments: iid sweeps
    give ≈ ``sweeps_per_round``, perfectly correlated sweeps give ≈ 1.
    Clipped to that range so a noisy estimate can never claim more than
    one effective draw per sweep.
    """
    if sqs is None or sweeps_per_round <= 1:
        return 1.0
    means = np.asarray(means, np.float64)
    lam = float(np.mean(sqs) - np.mean(means) ** 2)
    seqs = split_chains(means)
    half = seqs.shape[1]
    if half < 2:
        return 1.0
    w = float(seqs.var(axis=1, ddof=1).mean())
    b = float(half * seqs.mean(axis=1).var(ddof=1))
    var_plus = (half - 1) / half * w + b / half
    if var_plus <= 0 or lam <= 0:
        return 1.0
    return float(np.clip(lam / var_plus, 1.0, sweeps_per_round))


def compute_diagnostics(means: np.ndarray, sqs: np.ndarray | None = None,
                        *, sweeps_per_round: int = 1) -> Diagnostics:
    """One-shot diagnostics over pooled per-round statistics.

    ``means``: ``(chains, rounds)`` per-round mean statistics; ``sqs``:
    matching per-round means of x² (optional — enables the sweep-unit
    ESS rescale, see :func:`_sweep_scale`).  This is the reference the
    incremental :class:`RunningDiagnostics` is tested against.
    """
    means = np.asarray(means, np.float64)
    total_rounds = means.size
    scale = _sweep_scale(means, sqs, sweeps_per_round)
    cap = float(total_rounds * sweeps_per_round)
    if means.shape[0] < 2 or means.shape[1] < 4:
        return Diagnostics()         # not enough rounds: keep sampling
    return Diagnostics(
        rhat=split_rhat(means),
        rank_rhat=rank_rhat(means),
        folded_rhat=folded_rank_rhat(means),
        ess_bulk=min(cap, scale * ess_bulk(means)),
        ess_tail=min(cap, scale * ess_tail(means)),
    )


class RunningDiagnostics:
    """Incremental per-variable diagnostics, fed one round at a time.

    The engine calls :meth:`update` with the round's per-chain mean (and
    mean-square) statistic — the host-side copy it already makes for
    retirement checks — and :meth:`compute` whenever it needs a verdict.
    ``compute()`` over rounds ``1..r`` equals
    :func:`compute_diagnostics` over the pooled ``(chains, r)`` history
    exactly (the estimators are O(r²) on ≤ max_rounds ≤ ~64 round
    statistics, so recomputing from the accumulated buffer *is* the
    incremental algorithm — no approximation drift between the streamed
    and one-shot paths).  Results are cached per round count: repeated
    ``compute()`` calls between updates are free.
    """

    def __init__(self, sweeps_per_round: int = 1):
        self.spr = int(sweeps_per_round)
        self._means: list[np.ndarray] = []
        self._sqs: list[np.ndarray] = []
        self._cache: tuple[int, Diagnostics] | None = None
        self._gate_cache: tuple[int, float] | None = None

    @property
    def rounds(self) -> int:
        return len(self._means)

    def update(self, mean_c: np.ndarray, sq_c: np.ndarray | None = None):
        """Append one round: ``mean_c`` (chains,) round-mean statistic,
        ``sq_c`` (chains,) round mean of x² (optional but either always
        or never — mixing forms would silently corrupt the sweep
        rescale, so both transitions raise)."""
        if (sq_c is None) != (not self._sqs) and self._means:
            raise ValueError(
                "sq_c must be given on every round or none "
                f"(got sq_c={'set' if sq_c is not None else 'None'} after "
                f"{len(self._sqs)} sq rounds of {len(self._means)})")
        self._means.append(np.asarray(mean_c, np.float64).copy())
        if sq_c is not None:
            self._sqs.append(np.asarray(sq_c, np.float64).copy())
        self._cache = self._gate_cache = None

    def legacy_rhat(self) -> float:
        """Plain split-R̂ over the accumulated round means — the cheap
        per-round check of the engine's ``retirement="legacy"`` mode
        (skips the rank/ESS machinery on the hot path)."""
        if not self._means:
            return float("inf")
        return split_rhat(np.stack(self._means, axis=1))

    def rank_gate(self) -> float:
        """``max(rank_rhat, folded_rank_rhat)`` over the accumulated
        rounds — the cheap half of the rank retirement rule.  The
        engine checks this first and skips the O(rounds²) ESS
        estimators entirely while R̂ still fails (cached per round)."""
        if self._gate_cache is not None and self._gate_cache[0] == self.rounds:
            return self._gate_cache[1]
        if self._cache is not None and self._cache[0] == self.rounds:
            g = self._cache[1].worst_rank_rhat  # full payload already paid
        elif len(self._means) < 4:
            g = float("inf")
        else:
            means = np.stack(self._means, axis=1)
            g = max(rank_rhat(means), folded_rank_rhat(means))
        self._gate_cache = (self.rounds, g)
        return g

    def cached(self) -> Diagnostics | None:
        """The current round's full payload if (and only if) something
        already paid for it — the free read the telemetry recorder uses
        to put the ESS trajectory on round spans without ever adding an
        O(rounds²) estimator call to the hot path."""
        if self._cache is not None and self._cache[0] == self.rounds:
            return self._cache[1]
        return None

    def compute(self) -> Diagnostics:
        """Diagnostics over everything fed so far (cached per round)."""
        if self._cache is not None and self._cache[0] == self.rounds:
            return self._cache[1]
        means = np.stack(self._means, axis=1) if self._means else \
            np.zeros((0, 0))
        sqs = np.stack(self._sqs, axis=1) if self._sqs else None
        d = compute_diagnostics(means, sqs, sweeps_per_round=self.spr)
        self._cache = (self.rounds, d)
        # the gate is a projection of the payload — seed its cache so a
        # gate-then-compute round never ranks the same draws twice
        self._gate_cache = (self.rounds, d.worst_rank_rhat)
        return d
