"""The AIA compiler chain for Bayesian networks (paper §III, C4).

Pipeline (mirrors Fig. 5):

  BayesNet (PPL IR) → fixed-point CPT quantization → moralize + DSatur
  coloring → per-color *gather plans* (static index/stride tensors) →
  jitted sweep program.

A gather plan is the TPU analogue of AIA's per-core binaries: for every
node of a color it precomputes, at compile time, the flat-CPT offsets and
strides needed to evaluate the Gibbs conditional

    P(v=l | MB) ∝ CPT_v[pa(v), l] · Π_{c ∈ ch(v)} CPT_c[pa(c)|v=l, x_c]

so the runtime inner loop is pure vector gathers + adds over the log-CPT
bank, followed by the IU-exp → KY-sample pipeline.  All nodes of a color
update in parallel (vector lanes ≙ AIA cores), chains batch on top.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import DEFAULT_K
from repro.core.interp import exp_table, masked_exp_weights
from repro.core.ky import ky_sample
from repro.kernels.fused_sweep import fused_gibbs_sample
from repro.pgm.coloring import color_bayesnet
from repro.pgm.graph import BayesNet

_NEG = -60.0  # log-domain floor (exp() underflows the k<=24 grid anyway)


@dataclass(frozen=True, eq=False)
class ColorPlan:
    """Static gather plan for one color group (all arrays np.int32)."""

    nodes: np.ndarray            # (G,) node ids
    card: np.ndarray             # (G,)
    self_base_off: np.ndarray    # (G,) CPT offset of node's own table
    self_pa: np.ndarray          # (G, P) parent ids (pad: 0)
    self_pa_stride: np.ndarray   # (G, P) strides    (pad: 0)
    ch_off: np.ndarray           # (G, C) child CPT offsets (pad: sentinel)
    ch_vstride: np.ndarray       # (G, C) stride of v in child's CPT (pad: 0)
    ch_self: np.ndarray          # (G, C) child ids (pad: 0)
    ch_self_stride: np.ndarray   # (G, C) stride of child's own dim (pad: 0)
    ch_pa: np.ndarray            # (G, C, P) other-parent ids (pad: 0)
    ch_pa_stride: np.ndarray     # (G, C, P) strides (pad: 0)


@dataclass(frozen=True, eq=False)
class CompiledBN:
    """Output of the compiler chain; consumed by ``make_sweep``.

    ``observed`` lists evidence-clamped node ids (the *evidence pattern*):
    those nodes appear in no gather plan, so a sweep never resamples them —
    their values are read straight out of the state vector by their
    children's gathers, which is exactly CPT conditioning on the clamp.
    One compiled program therefore serves *any* evidence values over the
    same pattern, which is what makes plan caching by pattern sound.
    """

    bn: BayesNet
    log_cpt: np.ndarray          # flat log-CPT bank (+ sentinel 0.0 at end)
    plans: tuple[ColorPlan, ...]
    max_card: int
    k: int                       # fixed-point weight precision
    observed: tuple[int, ...] = ()

    @property
    def n_colors(self) -> int:
        return len(self.plans)

    @property
    def free_nodes(self) -> tuple[int, ...]:
        obs = set(self.observed)
        return tuple(v for v in range(self.bn.n_nodes) if v not in obs)


def compile_bayesnet(
    bn: BayesNet,
    *,
    k: int = DEFAULT_K,
    quantize_cpt_bits: int | None = 16,
    observed=(),
) -> CompiledBN:
    """Run the full compiler chain on a BayesNet.

    ``observed``: evidence pattern — node ids (or names) to clamp.  Values
    are supplied at run time (``run_gibbs(evidence=...)`` or per-lane via
    the serve engine), so the compiled program is reusable across queries
    sharing the pattern.
    """
    observed = tuple(sorted({bn.index(v) for v in observed}))
    if len(observed) == bn.n_nodes:
        raise ValueError("all nodes observed — nothing to infer")
    # ---- stage 1: fixed-point quantization of the log-CPT bank ----------
    banks, offsets = [], {}
    pos = 0
    for v in range(bn.n_nodes):
        t = np.log(np.clip(bn.cpt[v].astype(np.float64), 1e-26, None))
        banks.append(np.maximum(t, _NEG).ravel())
        offsets[v] = pos
        pos += banks[-1].size
    flat = np.concatenate(banks + [np.zeros(1)])  # sentinel 0.0 at index pos
    sentinel = pos
    if quantize_cpt_bits is not None:
        # Qm.f fixed point over [_NEG, 0]: simulate by grid rounding.
        scale = (2 ** (quantize_cpt_bits - 7))  # ~7 integer bits for [-60,0]
        flat = np.round(flat * scale) / scale
    flat = flat.astype(np.float32)

    # ---- stage 2: coloring (moralize + DSatur), evidence nodes skipped ---
    groups = color_bayesnet(bn, skip=frozenset(observed))

    # ---- stage 3: gather plans -------------------------------------------
    def strides(v: int) -> np.ndarray:
        shape = bn.cpt[v].shape
        return np.array(
            [int(np.prod(shape[i + 1:])) for i in range(len(shape))], np.int64
        )

    max_pa = max((len(p) for p in bn.parents), default=0)
    max_ch = max((len(bn.children(v)) for v in range(bn.n_nodes)), default=0)
    p_pad, c_pad = max(max_pa, 1), max(max_ch, 1)

    plans = []
    for grp in groups:
        g = len(grp)
        plan = dict(
            nodes=np.asarray(grp, np.int32),
            card=np.array([bn.card[v] for v in grp], np.int32),
            self_base_off=np.array([offsets[v] for v in grp], np.int32),
            self_pa=np.zeros((g, p_pad), np.int32),
            self_pa_stride=np.zeros((g, p_pad), np.int32),
            ch_off=np.full((g, c_pad), sentinel, np.int32),
            ch_vstride=np.zeros((g, c_pad), np.int32),
            ch_self=np.zeros((g, c_pad), np.int32),
            ch_self_stride=np.zeros((g, c_pad), np.int32),
            ch_pa=np.zeros((g, c_pad, p_pad), np.int32),
            ch_pa_stride=np.zeros((g, c_pad, p_pad), np.int32),
        )
        for gi, v in enumerate(grp):
            v = int(v)
            st_v = strides(v)
            for j, p in enumerate(bn.parents[v]):
                plan["self_pa"][gi, j] = p
                plan["self_pa_stride"][gi, j] = st_v[j]
            for ci, c in enumerate(bn.children(v)):
                st_c = strides(c)
                plan["ch_off"][gi, ci] = offsets[c]
                plan["ch_self"][gi, ci] = c
                plan["ch_self_stride"][gi, ci] = st_c[-1]  # == 1
                for j, p in enumerate(bn.parents[c]):
                    if p == v:
                        plan["ch_vstride"][gi, ci] = st_c[j]
                    else:
                        # pack into the next free other-parent slot
                        slot = next(
                            s for s in range(p_pad)
                            if plan["ch_pa_stride"][gi, ci, s] == 0
                            and (plan["ch_pa"][gi, ci, s] == 0)
                        )
                        plan["ch_pa"][gi, ci, slot] = p
                        plan["ch_pa_stride"][gi, ci, slot] = st_c[j]
        plans.append(ColorPlan(**plan))

    return CompiledBN(
        bn=bn,
        log_cpt=flat,
        plans=tuple(plans),
        max_card=int(max(bn.card)),
        k=k,
        observed=observed,
    )


class BNSweepStats(NamedTuple):
    """Random-bit accounting of a sweep program.

    Device code only ever holds *per-sweep* int32 values (a single sweep
    cannot overflow int32 for any realistic lane count); totals across
    sweeps are accumulated host-side in int64 via :func:`sum_sweep_stats`
    — int32 carries silently wrapped on long runs, yielding negative
    bits-per-sample in benchmarks.
    """

    bits_used: jax.Array
    attempts: jax.Array


def sum_sweep_stats(stats: "BNSweepStats") -> "BNSweepStats":
    """Overflow-safe host-side total of per-sweep stats arrays.

    Sums in np.int64, so totals beyond 2**31 (trivially reached by
    lanes × nodes × sweeps × ~5 bits on long runs) stay exact.
    """
    return BNSweepStats(
        bits_used=np.asarray(stats.bits_used, np.int64).sum(),
        attempts=np.asarray(stats.attempts, np.int64).sum(),
    )


def ky_weights(logw: jax.Array, card: jax.Array, k: int,
               use_iu: bool) -> jax.Array:
    """Shared sampler tail: masked log-weights → int32 KY weights.

    ``logw``: (..., G, L) unnormalized log-probabilities; ``card``: (G,)
    per-variable cardinalities (labels past them are floored to an
    impossible weight).  This is the IU-exp → fixed-point stage every
    compiled family (BN gather plans, dense grids lowered to sparse
    plans, arbitrary factor graphs) funnels through — max-subtract,
    LUT exp, ``floor(y * (2^k - 1))`` — so the KY front-end sees one
    weight format regardless of how the energies were gathered.

    Thin wrapper over :func:`repro.core.interp.masked_exp_weights` — the
    same function the fused Pallas kernel runs *inside* its kernel body,
    which is what keeps ``sampler="pallas"`` bitwise-comparable.
    """
    return masked_exp_weights(logw, card, k, use_iu=use_iu, table=_EXP,
                              mask_value=_NEG * 4)


def _color_update(
    key: jax.Array,
    x: jax.Array,               # (B, n) int32 current states
    plan: ColorPlan,
    log_cpt: jax.Array,
    max_card: int,
    k: int,
    use_iu: bool,
    sampler: str = "xla",
    beta: jax.Array | None = None,   # traced inverse temperature, (B,) or scalar
) -> tuple[jax.Array, BNSweepStats]:
    ls = jnp.arange(max_card, dtype=jnp.int32)            # (L,)
    nodes = jnp.asarray(plan.nodes)
    card = jnp.asarray(plan.card)                          # (G,)

    # --- own CPT row: offset + Σ stride_j * x[pa_j] + l -------------------
    pa_states = x[:, jnp.asarray(plan.self_pa)]            # (B, G, P)
    base = jnp.asarray(plan.self_base_off)[None] + jnp.sum(
        jnp.asarray(plan.self_pa_stride)[None] * pa_states, axis=-1
    )                                                      # (B, G)
    logw = jnp.take(log_cpt, base[..., None] + ls, mode="clip")  # (B, G, L)

    # --- children likelihood terms ---------------------------------------
    ch_pa_states = x[:, jnp.asarray(plan.ch_pa)]           # (B, G, C, P)
    ch_base = (
        jnp.asarray(plan.ch_off)[None]
        + jnp.sum(jnp.asarray(plan.ch_pa_stride)[None] * ch_pa_states, axis=-1)
        + jnp.asarray(plan.ch_self_stride)[None] * x[:, jnp.asarray(plan.ch_self)]
    )                                                      # (B, G, C)
    ch_idx = ch_base[..., None] + jnp.asarray(plan.ch_vstride)[None, ..., None] * ls
    logw = logw + jnp.sum(jnp.take(log_cpt, ch_idx, mode="clip"), axis=-2)

    # --- annealing: scale log-weights by the inverse temperature ----------
    # Applied before the sampler branch, so the XLA and Pallas paths see
    # the same floats and stay bitwise-interchangeable at every β.  β > 1
    # sharpens the conditional toward its argmax (simulated annealing for
    # MAP/MPE); β = 1 (or None) is ordinary Gibbs.  Per-lane (B,) values
    # let one jitted sweep mix annealed and unannealed chains.  The valid-
    # label max is subtracted *before* scaling so the best label pins at
    # 0 whatever β is — an unbounded β can then never push every valid
    # label under the mask floor ``ky_weights`` applies.
    if beta is not None:
        b = jnp.asarray(beta, logw.dtype)
        b = b[:, None, None] if b.ndim == 1 else b
        valid = ls[None, None, :] < card[None, :, None]
        m = jnp.max(jnp.where(valid, logw, -jnp.inf), axis=-1, keepdims=True)
        logw = (logw - m) * b

    # --- IU-exp → fixed point → KY sample ---------------------------------
    # sampler="pallas": mask → LUT-exp → floor → KY walk fused in one
    # Pallas kernel, weight tile resident in VMEM (kernels/fused_sweep.py);
    # bitwise-identical to the two-stage XLA path below by construction.
    if sampler == "pallas":
        lane_card = jnp.broadcast_to(
            card[None], logw.shape[:-1]).reshape(-1)
        res = fused_gibbs_sample(
            key, logw.reshape((-1, max_card)), lane_card,
            k=k, use_iu=use_iu, table=_EXP)
    else:
        wts = ky_weights(logw, card, k, use_iu)
        res = ky_sample(key, wts.reshape((-1, max_card)))
    new = res.sample.reshape(logw.shape[:-1]).astype(jnp.int32)  # (B, G)
    x = x.at[:, nodes].set(new)
    return x, BNSweepStats(jnp.sum(res.bits_used), jnp.sum(res.attempts))


def make_sweep(prog: CompiledBN, *, use_iu: bool = True,
               sampler: str = "xla"):
    """Build the jitted one-sweep function: (key, x) -> (x', stats)."""
    log_cpt = jnp.asarray(prog.log_cpt)

    def sweep(key: jax.Array, x: jax.Array):
        bits = jnp.int32(0)
        att = jnp.int32(0)
        for i, plan in enumerate(prog.plans):
            key, sub = jax.random.split(key)
            x, st = _color_update(
                sub, x, plan, log_cpt, prog.max_card, prog.k, use_iu,
                sampler)
            bits, att = bits + st.bits_used, att + st.attempts
        return x, BNSweepStats(bits, att)

    return jax.jit(sweep)


def init_states(
    key: jax.Array,
    prog: CompiledBN,
    n_chains: int,
    evidence_values: jax.Array | None = None,
) -> jax.Array:
    """Random (B, n) initial states with evidence columns clamped.

    ``evidence_values`` aligns with ``prog.observed``: either (O,) shared
    across chains or (B, O) per-lane — the serve engine packs different
    queries' values into different lanes of one jitted sweep.
    """
    n = prog.bn.n_nodes
    card = jnp.asarray(prog.bn.card, jnp.int32)
    u = jax.random.uniform(key, (n_chains, n))
    x0 = (u * card[None]).astype(jnp.int32)
    if prog.observed:
        if evidence_values is None:
            raise ValueError(
                f"program clamps nodes {prog.observed} but no evidence given")
        ev = jnp.asarray(evidence_values, jnp.int32)
        if ev.ndim == 1:
            ev = jnp.broadcast_to(ev[None], (n_chains, len(prog.observed)))
        x0 = x0.at[:, jnp.asarray(prog.observed, jnp.int32)].set(ev)
    return x0


@partial(jax.jit, static_argnames=(
    "prog", "n_sweeps", "n_chains", "burn_in", "use_iu", "sampler"))
def _run_gibbs_device(
    key: jax.Array,
    prog: CompiledBN,
    *,
    n_chains: int,
    n_sweeps: int,
    burn_in: int,
    use_iu: bool = True,
    sampler: str = "xla",
    evidence=None,
):
    """Jitted Gibbs scan; stats are *per-sweep* (n_sweeps,) int32 arrays.

    The scan carry deliberately does not accumulate bits/attempts: an
    int32 running total wraps on long runs (see :class:`BNSweepStats`).
    Each sweep's contribution is emitted as a scan output instead and
    totalled host-side by :func:`run_gibbs`.
    """
    n = prog.bn.n_nodes
    key, init_key = jax.random.split(key)
    x0 = init_states(
        init_key, prog, n_chains,
        None if evidence is None else jnp.asarray(evidence, jnp.int32))
    log_cpt = jnp.asarray(prog.log_cpt)

    def body(carry, i):
        key, x, counts = carry
        key, sub = jax.random.split(key)
        bits, att = jnp.int32(0), jnp.int32(0)
        for plan in prog.plans:
            sub, s2 = jax.random.split(sub)
            x, st = _color_update(
                s2, x, plan, log_cpt, prog.max_card, prog.k, use_iu,
                sampler)
            bits, att = bits + st.bits_used, att + st.attempts
        onehot = (x[..., None] == jnp.arange(prog.max_card)[None, None]).astype(jnp.int32)
        counts = counts + jnp.where(i >= burn_in, jnp.sum(onehot, axis=0), 0)
        return (key, x, counts), BNSweepStats(bits, att)

    counts0 = jnp.zeros((n, prog.max_card), jnp.int32)
    (key, x, counts), per_sweep = jax.lax.scan(
        body, (key, x0, counts0), jnp.arange(n_sweeps))
    return x, counts, per_sweep


def run_gibbs(
    key: jax.Array,
    prog: CompiledBN,
    *,
    n_chains: int,
    n_sweeps: int,
    burn_in: int,
    use_iu: bool = True,
    sampler: str = "xla",
    evidence=None,
):
    """Run BN Gibbs; returns (final_states, marginal_counts, stats).

    marginal_counts: (n_nodes, max_card) int32 accumulated after burn-in.
    ``stats``: int64 host scalars (per-sweep device stats summed without
    int32 wraparound).  ``evidence``: values for ``prog.observed`` (same
    order); required iff the program was compiled with an evidence
    pattern.  Deliberately a *traced* argument of the underlying jit: one
    compiled program serves any values over its pattern — changing them
    must not retrace.  Because totals materialize on the host, wrap this
    function's *device* half (``_run_gibbs_device``) if you need to call
    it under an outer ``jax.jit``.
    """
    x, counts, per_sweep = _run_gibbs_device(
        key, prog, n_chains=n_chains, n_sweeps=n_sweeps, burn_in=burn_in,
        use_iu=use_iu, sampler=sampler, evidence=evidence)
    return x, counts, sum_sweep_stats(per_sweep)


_EXP = exp_table()
