"""Compiled sweep programs for served MRF grids (pixel-mask evidence).

The MRF analogue of :mod:`repro.pgm.compile`: where a Bayesian network's
evidence *pattern* is the tuple of clamped node ids, an MRF's is the
tuple of clamped **flat site indices** (``r * W + c``) — the sorted,
hashable identity of a scribble/pixel mask.  One compiled program serves
*any* observed labels over the same mask: values live in the label
field, not the program, exactly as BN evidence values live in the state
vector.  That is what makes plan caching (and lane packing of queries
that share a mask) sound for grids too.

There is no gather-plan stage here — the lattice's "plan" is the
checkerboard itself (2 colors, fixed neighbourhood), so compiling is
just freezing the (grid, mask, precision) triple.  The per-round runner
lives in :mod:`repro.serve.families` next to its BN sibling.

:func:`sparse_plan` lowers a compiled grid onto the unified sparse
layer (:mod:`repro.pgm.sparse_compile`): checkerboard parity becomes a
2-color partition, the 4-neighbourhood becomes one degree-4 bucket per
color, and the per-site neighbour order is pinned to the dense kernel's
up/down/left/right accumulation so the resulting KY weights are bitwise
identical to :func:`repro.pgm.gibbs.site_weights` — the regression that
lets the dense path remain the serving default while the sparse path
generalizes it.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import DEFAULT_K
from repro.pgm.graph import FactorGraph, MRFGrid


@dataclass(frozen=True, eq=False)
class CompiledMRF:
    """A served MRF sweep program: grid + clamp pattern + precision.

    ``observed`` lists evidence-clamped flat site indices (sorted).  A
    clamped site is skipped by the checkerboard update but its fixed
    label keeps contributing pairwise energy to its neighbours — see
    ``repro.pgm.gibbs.checkerboard_halfstep(clamp=...)``.
    """

    mrf: MRFGrid
    k: int
    observed: tuple[int, ...] = ()

    @property
    def shape(self) -> tuple[int, int]:
        return self.mrf.shape

    @property
    def n_labels(self) -> int:
        return self.mrf.n_labels

    @property
    def n_sites(self) -> int:
        h, w = self.mrf.shape
        return h * w

    @property
    def n_free(self) -> int:
        return self.n_sites - len(self.observed)


def compile_mrf(mrf: MRFGrid, *, k: int = DEFAULT_K,
                observed=()) -> CompiledMRF:
    """Freeze a (grid, mask-pattern, precision) sweep program.

    ``observed``: flat site indices (``r * W + c``) to clamp; values are
    supplied at run time, so the program is reusable across queries
    sharing the mask pattern.
    """
    n = mrf.shape[0] * mrf.shape[1]
    observed = tuple(sorted({int(v) for v in observed}))
    if observed and not (0 <= observed[0] and observed[-1] < n):
        raise ValueError(
            f"clamped site index outside the {mrf.shape} lattice")
    if len(observed) == n:
        raise ValueError("all sites clamped — nothing to infer")
    return CompiledMRF(mrf=mrf, k=k, observed=observed)


def mask_of(prog: CompiledMRF) -> np.ndarray:
    """(H, W) bool clamp mask of a compiled program (True = observed)."""
    m = np.zeros(prog.n_sites, bool)
    if prog.observed:
        m[list(prog.observed)] = True
    return m.reshape(prog.shape)


def init_mrf_states(
    key: jax.Array,
    prog: CompiledMRF,
    n_lanes: int,
    evidence_values: jax.Array | None = None,
) -> jax.Array:
    """Random (B, H, W) initial labels with evidence sites pinned.

    ``evidence_values`` aligns with ``prog.observed``: either (O,)
    shared across lanes or (B, O) per-lane — the serve engine packs
    different queries' scribble labels into different lanes of one
    jitted sweep, exactly like BN evidence columns.
    """
    h, w = prog.shape
    labels = jax.random.randint(
        key, (n_lanes, h, w), 0, prog.n_labels, jnp.int32)
    if prog.observed:
        if evidence_values is None:
            raise ValueError(
                f"program clamps {len(prog.observed)} sites but no "
                f"evidence values given")
        ev = jnp.asarray(evidence_values, jnp.int32)
        if ev.ndim == 1:
            ev = jnp.broadcast_to(ev[None], (n_lanes, len(prog.observed)))
        flat = labels.reshape(n_lanes, h * w)
        flat = flat.at[:, jnp.asarray(prog.observed, jnp.int32)].set(ev)
        labels = flat.reshape(n_lanes, h, w)
    return labels


# ---------------------------------------------------------------------------
# lowering onto the unified sparse layer
# ---------------------------------------------------------------------------

def mrf_factor_graph(mrf: MRFGrid) -> FactorGraph:
    """Free-boundary lattice as a :class:`FactorGraph` (right+down edges,
    every edge sharing the grid's one (L, L) pairwise table)."""
    h, w = mrf.shape
    sites = np.arange(h * w).reshape(h, w)
    right = np.stack([sites[:, :-1], sites[:, 1:]], axis=-1).reshape(-1, 2)
    down = np.stack([sites[:-1, :], sites[1:, :]], axis=-1).reshape(-1, 2)
    edges = np.concatenate([right, down])
    pair = np.broadcast_to(
        np.asarray(mrf.pairwise, np.float32)[None], (len(edges),) + mrf.pairwise.shape)
    return FactorGraph(
        card=np.full(h * w, mrf.n_labels, np.int32),
        unary=np.asarray(mrf.unary, np.float32).reshape(h * w, mrf.n_labels),
        edges=edges, pair=pair)


def sparse_plan(prog: CompiledMRF):
    """Lower a compiled dense grid to a degenerate 2-color sparse plan.

    The lowering pins two things the default sparse path would choose
    differently, to stay bitwise-equal to the dense kernel:

    * the **table bank** is the single shared pairwise table (the dense
      kernel applies ``pw[l, m]`` in all four directions — it relies on
      the symmetric tables Potts/truncated-linear produce), not a
      per-direction dedup;
    * the **per-site neighbour order** is up, down, left, right — the
      dense kernel's accumulation order (:func:`repro.pgm.gibbs
      .neighbor_pair_energy`), preserved through the packer's stable
      sort, so float addition associates identically.

    Returns a :class:`repro.pgm.sparse_compile.CompiledFactorGraph` over
    the same clamp pattern and precision.
    """
    from repro.pgm.sparse_compile import compile_factor_graph

    h, w = prog.shape
    sites = np.arange(h * w).reshape(h, w)
    # directed entries in the dense kernel's per-site order: the stable
    # sort inside the packer keeps up-entries before down- before left-
    # before right- for every source site.
    up = (sites[1:, :], sites[:-1, :])
    down = (sites[:-1, :], sites[1:, :])
    left = (sites[:, 1:], sites[:, :-1])
    right = (sites[:, :-1], sites[:, 1:])
    dir_src = np.concatenate([s.ravel() for s, _ in (up, down, left, right)])
    dir_dst = np.concatenate([d.ravel() for _, d in (up, down, left, right)])
    dir_tab = np.zeros(len(dir_src), np.int64)
    bank = np.asarray(prog.mrf.pairwise, np.float32)[None]

    parity = (sites // w + sites % w) % 2
    free = np.ones(h * w, bool)
    if prog.observed:
        free[list(prog.observed)] = False
    groups = [
        np.flatnonzero(free & (parity.ravel() == c)).astype(np.int32)
        for c in (0, 1)
    ]
    groups = [g for g in groups if len(g)]
    return compile_factor_graph(
        mrf_factor_graph(prog.mrf), k=prog.k, observed=prog.observed,
        directed=(dir_src, dir_dst, dir_tab, bank), groups=groups)
