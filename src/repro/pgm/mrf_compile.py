"""Compiled sweep programs for served MRF grids (pixel-mask evidence).

The MRF analogue of :mod:`repro.pgm.compile`: where a Bayesian network's
evidence *pattern* is the tuple of clamped node ids, an MRF's is the
tuple of clamped **flat site indices** (``r * W + c``) — the sorted,
hashable identity of a scribble/pixel mask.  One compiled program serves
*any* observed labels over the same mask: values live in the label
field, not the program, exactly as BN evidence values live in the state
vector.  That is what makes plan caching (and lane packing of queries
that share a mask) sound for grids too.

There is no gather-plan stage here — the lattice's "plan" is the
checkerboard itself (2 colors, fixed neighbourhood), so compiling is
just freezing the (grid, mask, precision) triple.  The per-round runner
lives in :mod:`repro.serve.families` next to its BN sibling.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import DEFAULT_K
from repro.pgm.graph import MRFGrid


@dataclass(frozen=True, eq=False)
class CompiledMRF:
    """A served MRF sweep program: grid + clamp pattern + precision.

    ``observed`` lists evidence-clamped flat site indices (sorted).  A
    clamped site is skipped by the checkerboard update but its fixed
    label keeps contributing pairwise energy to its neighbours — see
    ``repro.pgm.gibbs.checkerboard_halfstep(clamp=...)``.
    """

    mrf: MRFGrid
    k: int
    observed: tuple[int, ...] = ()

    @property
    def shape(self) -> tuple[int, int]:
        return self.mrf.shape

    @property
    def n_labels(self) -> int:
        return self.mrf.n_labels

    @property
    def n_sites(self) -> int:
        h, w = self.mrf.shape
        return h * w

    @property
    def n_free(self) -> int:
        return self.n_sites - len(self.observed)


def compile_mrf(mrf: MRFGrid, *, k: int = DEFAULT_K,
                observed=()) -> CompiledMRF:
    """Freeze a (grid, mask-pattern, precision) sweep program.

    ``observed``: flat site indices (``r * W + c``) to clamp; values are
    supplied at run time, so the program is reusable across queries
    sharing the mask pattern.
    """
    n = mrf.shape[0] * mrf.shape[1]
    observed = tuple(sorted({int(v) for v in observed}))
    if observed and not (0 <= observed[0] and observed[-1] < n):
        raise ValueError(
            f"clamped site index outside the {mrf.shape} lattice")
    if len(observed) == n:
        raise ValueError("all sites clamped — nothing to infer")
    return CompiledMRF(mrf=mrf, k=k, observed=observed)


def mask_of(prog: CompiledMRF) -> np.ndarray:
    """(H, W) bool clamp mask of a compiled program (True = observed)."""
    m = np.zeros(prog.n_sites, bool)
    if prog.observed:
        m[list(prog.observed)] = True
    return m.reshape(prog.shape)


def init_mrf_states(
    key: jax.Array,
    prog: CompiledMRF,
    n_lanes: int,
    evidence_values: jax.Array | None = None,
) -> jax.Array:
    """Random (B, H, W) initial labels with evidence sites pinned.

    ``evidence_values`` aligns with ``prog.observed``: either (O,)
    shared across lanes or (B, O) per-lane — the serve engine packs
    different queries' scribble labels into different lanes of one
    jitted sweep, exactly like BN evidence columns.
    """
    h, w = prog.shape
    labels = jax.random.randint(
        key, (n_lanes, h, w), 0, prog.n_labels, jnp.int32)
    if prog.observed:
        if evidence_values is None:
            raise ValueError(
                f"program clamps {len(prog.observed)} sites but no "
                f"evidence values given")
        ev = jnp.asarray(evidence_values, jnp.int32)
        if ev.ndim == 1:
            ev = jnp.broadcast_to(ev[None], (n_lanes, len(prog.observed)))
        flat = labels.reshape(n_lanes, h * w)
        flat = flat.at[:, jnp.asarray(prog.observed, jnp.int32)].set(ev)
        labels = flat.reshape(n_lanes, h, w)
    return labels
