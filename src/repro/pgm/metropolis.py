"""Metropolis-Hastings over colored proposals: grids and sparse graphs.

The paper positions AIA as accelerating *any* discrete MCMC ("Gibbs, MH,
etc."): the MH acceptance test maps onto the same fixed-point pipeline —
``accept iff u < exp(-ΔE)`` becomes an integer comparison between a
16-bit uniform and the IU-exp of the (fixed-point) energy delta, i.e.
the degenerate two-outcome case of the non-normalized sampler.

Coloring keeps simultaneous proposals independent (the same argument as
block Gibbs): :func:`mrf_metropolis` uses the checkerboard on dense
grids, and :func:`fg_metropolis` runs the identical acceptance rule
per color phase of a compiled sparse plan
(:class:`repro.pgm.sparse_compile.CompiledFactorGraph`) — the
energies come from the plan's degree-bucketed gathers, so MH and Gibbs
share one compiled program per model.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.interp import exp_table
from repro.pgm.gibbs import neighbor_pair_energy

_EXP = exp_table()
_ACC_BITS = 16


class MHStats(NamedTuple):
    accept_rate: jax.Array
    bits_used: jax.Array


@partial(jax.jit, static_argnames=("n_sweeps", "use_iu"))
def mrf_metropolis(
    key: jax.Array,
    labels0: jax.Array,          # (B, H, W) int32
    unary: jax.Array,            # (H, W, L)
    pairwise: jax.Array,         # (L, L)
    *,
    n_sweeps: int,
    use_iu: bool = True,
    beta: jax.Array | None = None,   # traced inverse temperature, (B,) or scalar
) -> tuple[jax.Array, MHStats]:
    b, h, w = labels0.shape
    l = unary.shape[-1]

    def halfstep(carry, parity, key):
        labels = carry
        k1, k2 = jax.random.split(key)
        # uniform proposal per site
        prop = jax.random.randint(k1, labels.shape, 0, l, jnp.int32)
        e = neighbor_pair_energy(labels, pairwise) + unary[None]
        e_cur = jnp.take_along_axis(e, labels[..., None], axis=-1)[..., 0]
        e_new = jnp.take_along_axis(e, prop[..., None], axis=-1)[..., 0]
        de = (e_new - e_cur).astype(jnp.float32)
        if beta is not None:
            # annealing: accept iff u < exp(-β·ΔE) — ΔE scales, the
            # fixed-point acceptance circuit below is untouched
            bb = jnp.asarray(beta, de.dtype)
            de = de * (bb[:, None, None] if bb.ndim == 1 else bb)
        # fixed-point acceptance: u16 < floor(exp(-max(dE,0)) * 2^16)
        p_acc = _EXP(-jnp.clip(de, 0.0, 16.0)) if use_iu else jnp.exp(
            -jnp.clip(de, 0.0, 16.0))
        thresh = jnp.floor(p_acc * (2.0 ** _ACC_BITS)).astype(jnp.int32)
        u = (jax.random.bits(k2, labels.shape, dtype=jnp.uint32)
             >> jnp.uint32(32 - _ACC_BITS)).astype(jnp.int32)
        accept = (u < thresh) | (de <= 0)
        mask = ((jnp.arange(h)[:, None] + jnp.arange(w)[None, :]) % 2
                == parity)[None]
        take = accept & mask
        return (jnp.where(take, prop, labels), jnp.sum(take),
                b * jnp.sum(mask))  # proposals = chains × parity sites

    def sweep(carry, i):
        labels, key, acc, tot = carry
        key, ka, kb = jax.random.split(key, 3)
        labels, a0, t0 = halfstep(labels, 0, ka)
        labels, a1, t1 = halfstep(labels, 1, kb)
        return (labels, key, acc + a0 + a1, tot + t0 + t1), None

    (labels, _, acc, tot), _ = jax.lax.scan(
        sweep, (labels0, key, jnp.int32(0), jnp.int32(0)),
        jnp.arange(n_sweeps))
    bits = tot * _ACC_BITS  # one 16-bit uniform per proposal
    return labels, MHStats(accept_rate=acc / jnp.maximum(tot, 1),
                           bits_used=bits)


@partial(jax.jit, static_argnames=("prog", "n_sweeps", "use_iu"))
def fg_metropolis(
    key: jax.Array,
    x0: jax.Array,               # (B, n) int32 initial states
    prog,                        # CompiledFactorGraph (static)
    *,
    n_sweeps: int,
    use_iu: bool = True,
    beta: jax.Array | None = None,   # traced inverse temperature, (B,) or scalar
) -> tuple[jax.Array, MHStats]:
    """MH-within-colors on a compiled sparse plan.

    One proposal per planned node per color phase; clamped (observed)
    nodes are never in any plan, so evidence holds automatically.  Uses
    the plan's candidate-label energies — the same gathers the Gibbs
    sweep runs — and the fixed-point 16-bit acceptance rule above.
    ``beta`` anneals the acceptance (``u < exp(-β·ΔE)``), the MH face of
    the same simulated-annealing hook the Gibbs sweeps carry.
    """
    from repro.pgm.sparse_compile import _plan_energies

    unary = jnp.asarray(prog.unary)
    tables_flat = jnp.asarray(prog.tables).reshape(-1)
    card = jnp.asarray(prog.fg.card, jnp.int32)
    b = x0.shape[0]

    def phase(x, plan, key):
        nodes = jnp.asarray(plan.nodes)
        k1, k2 = jax.random.split(key)
        cur = x[:, nodes]                                    # (B, N)
        u01 = jax.random.uniform(k1, cur.shape)
        prop = (u01 * card[nodes][None]).astype(jnp.int32)   # per-card uniform
        e = _plan_energies(x, plan, unary, tables_flat, prog.max_card)
        e_cur = jnp.take_along_axis(e, cur[..., None], axis=-1)[..., 0]
        e_new = jnp.take_along_axis(e, prop[..., None], axis=-1)[..., 0]
        de = (e_new - e_cur).astype(jnp.float32)
        if beta is not None:
            bb = jnp.asarray(beta, de.dtype)
            de = de * (bb[:, None] if bb.ndim == 1 else bb)
        p_acc = _EXP(-jnp.clip(de, 0.0, 16.0)) if use_iu else jnp.exp(
            -jnp.clip(de, 0.0, 16.0))
        thresh = jnp.floor(p_acc * (2.0 ** _ACC_BITS)).astype(jnp.int32)
        u = (jax.random.bits(k2, cur.shape, dtype=jnp.uint32)
             >> jnp.uint32(32 - _ACC_BITS)).astype(jnp.int32)
        accept = (u < thresh) | (de <= 0)
        x = x.at[:, nodes].set(jnp.where(accept, prop, cur))
        return x, jnp.sum(accept), jnp.int32(b * len(plan.nodes))

    def sweep(carry, i):
        x, key, acc, tot = carry
        for plan in prog.plans:
            key, kp = jax.random.split(key)
            x, a, t = phase(x, plan, kp)
            acc, tot = acc + a, tot + t
        return (x, key, acc, tot), None

    (x, _, acc, tot), _ = jax.lax.scan(
        sweep, (x0, key, jnp.int32(0), jnp.int32(0)), jnp.arange(n_sweeps))
    bits = tot * _ACC_BITS
    return x, MHStats(accept_rate=acc / jnp.maximum(tot, 1), bits_used=bits)
