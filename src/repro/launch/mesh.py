"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single pod: (16, 16) = 256 chips as
("data", "model"); multi-pod: (2, 16, 16) = 512 chips with the leading
"pod" axis carrying only data parallelism (cross-pod traffic = one
gradient all-reduce per step — DESIGN.md §6).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return jax.make_mesh(
        shape, axes, devices=devices[:n],
        axis_types=(AxisType.Auto,) * len(axes),
    )


def make_pgm_mesh(rows: int = 4, cols: int = 4) -> Mesh:
    """The AIA-analogue 2D core mesh for distributed MRF Gibbs (C3)."""
    n = rows * cols
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"pgm mesh needs {n} devices, have {len(devices)}")
    return jax.make_mesh((rows, cols), ("row", "col"),
                         devices=devices[:n],
                         axis_types=(AxisType.Auto, AxisType.Auto))
