"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single pod: (16, 16) = 256 chips as
("data", "model"); multi-pod: (2, 16, 16) = 512 chips with the leading
"pod" axis carrying only data parallelism (cross-pod traffic = one
gradient all-reduce per step — DESIGN.md §6).

``make_serve_mesh`` builds the 1D/2D mesh the posterior query service
(:mod:`repro.serve`) shards its chain-lane batches over; it sticks to
the version-portable ``jax.sharding.Mesh`` constructor so the serve path
also runs on jax installs without the explicit-mesh API.
"""
from __future__ import annotations

import os

import jax
import numpy as np
from jax.sharding import Mesh

try:  # explicit-mesh API (jax >= 0.6); training meshes require it
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax
    AxisType = None

SERVE_AXES = ("batch", "model")


def parse_mesh_shape(spec: str) -> tuple[int, ...]:
    """Parse a CLI mesh shape: ``"4"`` -> (4,), ``"2x2"`` -> (2, 2)."""
    try:
        shape = tuple(int(s) for s in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"bad mesh shape {spec!r}: expected N or RxC") from None
    if not 1 <= len(shape) <= 2 or any(s < 1 for s in shape):
        raise ValueError(f"bad mesh shape {spec!r}: expected N or RxC")
    return shape


def force_host_devices(n: int, env: dict | None = None) -> None:
    """Make the CPU backend present ``n`` fake devices.

    Appends ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``
    in ``env`` (default ``os.environ``), preserving any flags already
    set.  The device count is fixed at backend init, so this must run
    before the target process's first jax *use* — importing jax (or this
    module) is fine, creating an array is not.
    """
    env = os.environ if env is None else env
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}").strip()


def make_serve_mesh(shape: tuple[int, ...] | None = None, *,
                    devices=None) -> Mesh:
    """1D ``("batch",)`` or 2D ``("batch", "model")`` mesh for repro.serve.

    The leading "batch" axis carries the engine's chain-lane axis
    (n_queries * chains_per_query); an optional trailing "model" axis
    lets very large flat log-CPT banks shard instead of replicate (see
    ``repro.sharding.specs.serve_cpt_spec``).  Defaults to all visible
    devices on a 1D batch mesh.
    """
    devices = list(jax.devices() if devices is None else devices)
    if shape is None:
        shape = (len(devices),)
    if not 1 <= len(shape) <= 2:
        raise ValueError(f"serve mesh must be 1D or 2D, got {shape}")
    n = int(np.prod(shape))
    if len(devices) < n:
        raise RuntimeError(
            f"serve mesh {shape} needs {n} devices, have {len(devices)} — "
            f"on CPU run under XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n}")
    return Mesh(np.asarray(devices[:n]).reshape(shape),
                SERVE_AXES[:len(shape)])


def mesh_fingerprint(mesh: Mesh | None):
    """Hashable identity of a mesh for plan-cache keys: (shape, axes,
    device ids).

    ``None`` for the single-device (no-mesh) path, so single-device plans
    and sharded plans can never collide in one cache — a runner compiled
    with sharding constraints for one mesh layout is wrong for another.
    Device ids matter too: same-shape meshes over *different* devices
    must not share a runner (its closed-over CPT bank and constraints
    are pinned to the mesh it was built for).
    """
    if mesh is None:
        return None
    return (tuple(mesh.devices.shape), tuple(mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return jax.make_mesh(
        shape, axes, devices=devices[:n],
        axis_types=(AxisType.Auto,) * len(axes),
    )


def make_pgm_mesh(rows: int = 4, cols: int = 4) -> Mesh:
    """The AIA-analogue 2D core mesh for distributed MRF Gibbs (C3)."""
    n = rows * cols
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"pgm mesh needs {n} devices, have {len(devices)}")
    if AxisType is None:  # pragma: no cover - older jax
        return Mesh(np.asarray(devices[:n]).reshape(rows, cols),
                    ("row", "col"))
    return jax.make_mesh((rows, cols), ("row", "col"),
                         devices=devices[:n],
                         axis_types=(AxisType.Auto, AxisType.Auto))
