"""Step builders shared by the dry-run, trainer and server.

Each builder returns ``(fn, args_sds, in_shardings, out_shardings)``
where ``args_sds`` are ShapeDtypeStruct pytrees (no allocation — the
full-size configs are only ever lowered, never materialized).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg
from repro.configs import input_specs
from repro.core.token_sampler import ky_sample_tokens
from repro.models.transformer import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_model,
)
from repro.models.layers import unembed
from repro.sharding import ctx as shard_ctx
from repro.sharding.specs import (
    batch_spec_axis,
    batch_specs,
    cache_specs,
    named,
    param_specs,
    zero_extend,
)
from repro.training.optimizer import make_optimizer
from repro.training.train_step import TrainState, make_train_step


def _params_sds(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((), jax.eval_shape(jax.random.key, 0).dtype)
    return jax.eval_shape(lambda k: init_model(k, cfg), key)


def _opt_specs(opt_state, pspecs, mesh):
    """Optimizer-state specs: mirror param specs where shapes match
    (AdamW m/v), ZeRO-extend over "data"; replicate factored leaves."""
    import jax.tree_util as jtu

    out = {}
    for f in opt_state._fields:
        sub = getattr(opt_state, f)
        if f == "step":
            out[f] = P()
        else:
            out[f] = jtu.tree_map_with_path(
                lambda pth, lf, _f=f: _match_spec(pspecs, pth, lf, mesh, _f),
                sub)
    return type(opt_state)(**out)


def _match_spec(pspecs, path, leaf, mesh, field=""):
    node = pspecs
    for pk in path:
        key = getattr(pk, "key", getattr(pk, "name", None))
        if isinstance(node, dict) and key in node:
            node = node[key]
    if not isinstance(node, P):
        return P()
    parts = tuple(node) + (None,) * 8
    nd = len(leaf.shape)
    if field == "vr" and nd >= 1:       # param spec minus last dim
        parts = parts[: max(nd, 1)] if nd < len(tuple(node)) else parts
        cand = P(*parts[:nd])
    elif field == "vc" and nd >= 1:     # param spec minus second-to-last
        full = tuple(node) + (None,) * max(0, nd + 1 - len(tuple(node)))
        cand = P(*(full[: nd - 1] + (full[nd],))) if nd >= 1 else P()
    else:
        if len(tuple(node)) > nd:
            return P()
        cand = P(*parts[:nd])
    # validate divisibility of the candidate spec against the leaf shape
    out = []
    for i, ax in enumerate(tuple(cand)):
        if ax is None:
            out.append(None)
            continue
        size = mesh.shape[ax] if isinstance(ax, str) else 1
        out.append(ax if leaf.shape[i] % max(size, 1) == 0 else None)
    return zero_extend(P(*out), leaf.shape, mesh)


def _act_specs(cfg: ModelConfig, mesh: Mesh, bdim, seq_len: int) -> dict:
    """Activation constraints: sequence-parallel residual storage +
    head-TP pinning for attention tensors (DESIGN.md §6)."""
    tp = mesh.shape["model"]
    specs: dict = {"residual": None, "attn_q": None, "attn_kv": None}
    if seq_len % tp == 0:
        specs["residual"] = NamedSharding(mesh, P(bdim, "model", None))
    if cfg.n_heads and cfg.n_heads % tp == 0:
        specs["attn_q"] = NamedSharding(mesh, P(bdim, None, "model", None))
        if cfg.n_kv % tp == 0:
            specs["attn_kv"] = NamedSharding(mesh, P(bdim, None, "model", None))
    return specs


def build_train(cfg: ModelConfig, mesh: Mesh, shape: ShapeCfg):
    params_sds = _params_sds(cfg)
    opt = make_optimizer(cfg)
    opt_sds = jax.eval_shape(opt.init, params_sds)
    state_sds = TrainState(params=params_sds, opt=opt_sds,
                           step=jax.ShapeDtypeStruct((), jnp.int32))

    pspecs = param_specs(cfg, params_sds, mesh)
    ospecs = _opt_specs(opt_sds, pspecs, mesh)
    state_specs = TrainState(params=pspecs, opt=ospecs, step=P())

    batch_sds = input_specs(cfg, shape)
    bspecs = batch_specs(cfg, mesh, batch_sds)

    step_fn, _ = make_train_step(cfg)

    # sequence-parallel residual storage (trace-time context, ctx.py)
    nmb = max(cfg.microbatch, 1)
    mb_b = shape.global_batch // nmb
    bdim = batch_spec_axis(mesh, mb_b)
    act = _act_specs(cfg, mesh, bdim, shape.seq_len)

    def wrapped(state, batch):
        with shard_ctx.activation_specs(act):
            return step_fn(state, batch)

    in_sh = (named(mesh, state_specs), named(mesh, bspecs))
    out_sh = (named(mesh, state_specs), NamedSharding(mesh, P()))
    return wrapped, (state_sds, batch_sds), in_sh, out_sh, (0,)


def build_prefill(cfg: ModelConfig, mesh: Mesh, shape: ShapeCfg):
    params_sds = _params_sds(cfg)
    pspecs = param_specs(cfg, params_sds, mesh)
    batch_sds = input_specs(cfg, shape)
    bspecs = batch_specs(cfg, mesh, batch_sds)
    bdim = batch_spec_axis(mesh, shape.global_batch)

    act = _act_specs(cfg, mesh, bdim, shape.seq_len)

    def prefill_fn(params, batch):
        with shard_ctx.activation_specs(act):
            enc_out = None
            if cfg.family in ("encdec", "audio"):
                enc_out = encode(params, cfg,
                                 batch["src_embeds"].astype(cfg.dtype))
            x = forward(params, cfg, batch["tokens"],
                        frontend=batch.get("frontend"), enc_out=enc_out)
        logits = unembed(params["embed"], cfg, x[:, -1:, :])[:, 0, :]
        return logits.astype(jnp.float32)

    in_sh = (named(mesh, pspecs), named(mesh, bspecs))
    out_sh = NamedSharding(mesh, P(bdim, None))
    return prefill_fn, (params_sds, batch_sds), in_sh, out_sh, ()


def build_decode(cfg: ModelConfig, mesh: Mesh, shape: ShapeCfg,
                 *, sampler: str = "ky"):
    b, t = shape.global_batch, shape.seq_len
    params_sds = _params_sds(cfg)
    pspecs = param_specs(cfg, params_sds, mesh)
    cache_sds = jax.eval_shape(lambda: init_cache(cfg, b, t))
    cspecs = cache_specs(cfg, mesh, cache_sds, b)
    bdim = batch_spec_axis(mesh, b)

    key_sds = jax.ShapeDtypeStruct((), jax.eval_shape(jax.random.key, 0).dtype)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, key, token, pos, cache):
        logits, cache = decode_step(params, cfg, token, pos, cache)
        if sampler == "ky":
            out = ky_sample_tokens(key, logits.astype(jnp.float32))
            tok = out.token
        else:
            tok = jax.random.categorical(key, logits.astype(jnp.float32))
        return tok.astype(jnp.int32), cache

    in_sh = (
        named(mesh, pspecs),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P(bdim, None)),
        NamedSharding(mesh, P()),
        named(mesh, cspecs),
    )
    out_sh = (NamedSharding(mesh, P(bdim)), named(mesh, cspecs))
    args = (params_sds, key_sds, tok_sds, pos_sds, cache_sds)
    return decode_fn, args, in_sh, out_sh, (4,)  # donate the KV cache


def build_cell(cfg: ModelConfig, mesh: Mesh, shape: ShapeCfg):
    if shape.kind == "train":
        return build_train(cfg, mesh, shape)
    if shape.kind == "prefill":
        return build_prefill(cfg, mesh, shape)
    return build_decode(cfg, mesh, shape)
