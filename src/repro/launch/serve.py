"""Serving driver: batched autoregressive generation with the KY sampler.

  PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
      --smoke --batch 4 --prompt-len 16 --max-new 32 --sampler ky

``--serve [HOST:]PORT`` runs the posterior service as a network front
end (HTTP/WebSocket over a consistent-hash-routed worker pool — see
``docs/serving.md``) and ``--connect`` drives one as a client:

  PYTHONPATH=src python -m repro.launch.serve --serve :8080 --workers 2 \
      --scheduler deadline --quota-qps 50
  PYTHONPATH=src python -m repro.launch.serve --connect :8080 --stream \
      --network asia --queries 32

``--stream`` switches to the *posterior* streaming service instead:
for Bayesian networks the synthetic traffic becomes the streaming-
sensor scenario — ``--patterns`` sensor streams re-observed over
``--slices`` drifting time slices, each slice warm-starting from its
stream's retained chains (temporal filtering; see
``docs/inference_modes.md``) — replayed open-loop through the
admission queue.  Every other argument is forwarded to
``repro.serve.cli``, which owns the streaming flags — including
``--mode {marginals,map}`` (annealed MAP/MPE search), the
retirement-rule knobs ``--retirement {rank,legacy}`` /
``--ess-target`` (see ``docs/diagnostics.md``) and the telemetry
exports ``--trace-out`` / ``--metrics-json`` (see
``docs/observability.md``):

  PYTHONPATH=src python -m repro.launch.serve --stream --network asia \
      --rate 50 --max-wait-ms 20 --trace-out trace.json
"""
from __future__ import annotations

import argparse
import sys

from repro.serve.telemetry import monotonic


def main(argv: list[str] | None = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if any(a == "--stream" or a.split("=", 1)[0] in ("--serve", "--connect")
           for a in argv):
        # posterior streaming/service modes live in repro.serve.cli (jax
        # must not initialize before its --force-host-devices handling)
        from repro.serve.cli import main as serve_main
        serve_main(argv)
        return

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models.sampling import generate
    from repro.models.transformer import init_model

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--sampler", default="ky",
                    choices=("ky", "categorical", "greedy"))
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    extras = {}
    if cfg.family in ("encdec", "audio"):
        extras["src_embeds"] = jnp.zeros(
            (args.batch, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extras["frontend"] = jnp.zeros(
            (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.float32)

    t0 = monotonic()
    tokens, bits = generate(
        params, cfg, prompt, jax.random.PRNGKey(2),
        max_new=args.max_new, sampler=args.sampler,
        temperature=args.temperature,
        q_block=min(args.prompt_len, 512), **extras)
    tokens.block_until_ready()
    dt = monotonic() - t0
    n = args.batch * args.max_new
    print(f"sampler={args.sampler}: {n} tokens in {dt:.2f}s "
          f"({n/dt:.1f} tok/s incl. compile)")
    if args.sampler == "ky":
        print(f"random bits consumed: {int(bits)} "
              f"({int(bits)/n:.2f} bits/token — softmax-free KY decode)")
    print("sample tokens[0]:", np.asarray(tokens[0])[:16].tolist())


if __name__ == "__main__":
    main()
