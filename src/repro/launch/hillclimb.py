import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""§Perf hillclimb: hypothesis → change → re-lower → measure on the three
chosen cells (see EXPERIMENTS.md §Perf for the selection rationale):

  A. qwen1.5-32b  × decode_32k  — worst roofline fraction + most
     representative of the paper's technique (KY sampler in the loop;
     memory-bound on the MHA KV cache).
  B. qwen1.5-32b  × train_4k    — most collective-bound large cell
     (FSDP attention all-gathers × microbatches × remat passes).
  C. hymba-1.5b   × train_4k    — worst train-cell fraction; hybrid
     (paper-relevant: attention-free mixer sharding).

Each variant is a config delta; for every step we record the analytic
roofline terms AND the compiled dry-run evidence (memory_analysis +
collective schedule).  Results → reports/perf/<cell>.json.
"""
import json
import time

import jax

from repro.configs import get_config, shape_by_name
from repro.launch.builders import build_cell
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_cell

CELLS = {
    "A_qwen_decode32k": {
        "arch": "qwen1.5-32b",
        "shape": "decode_32k",
        "variants": [
            ("baseline", {}, "paper-faithful bf16 KV cache"),
            ("int8_kv", {"cache_dtype": "int8"},
             "HYPOTHESIS: decode is cache-bandwidth-bound (21.5 GB/chip "
             "read per token); int8 KV (+1/64 scale overhead) cuts the "
             "memory term ~1.94x and fits HBM."),
        ],
    },
    "B_qwen_train4k": {
        "arch": "qwen1.5-32b",
        "shape": "train_4k",
        "variants": [
            ("baseline", {}, "mb=8, remat=full"),
            ("mb4", {"microbatch": 4},
             "HYPOTHESIS: FSDP attention AG bytes scale with microbatch "
             "count (AG per use per microbatch); mb 8->4 halves them; "
             "seq-sharded residuals keep activations within budget."),
            ("mb4_dots", {"microbatch": 4, "remat": "dots"},
             "HYPOTHESIS: remat=dots removes the recompute fwd pass "
             "(3 passes -> 2), cutting AG traffic another 1.5x for "
             "+activation memory."),
            ("mb4_bf16p", {"microbatch": 4, "param_dtype": "bfloat16",
                           "accum_dtype": "bfloat16"},
             "HYPOTHESIS (after mb4_dots memory blow-up REFUTED dots): "
             "keep remat=full, recover the mb4 memory regression with "
             "bf16 param storage + bf16 grad accumulation (halves param "
             "+ accumulator bytes; AdamW_bf16 moments already set)."),
        ],
    },
    "C_hymba_train4k": {
        "arch": "hymba-1.5b",
        "shape": "train_4k",
        "variants": [
            ("baseline", {}, "fused ssm in_proj (FSDP-gathered)"),
            ("split_proj", {"ssm_split_proj": True},
             "HYPOTHESIS: splitting the fused in_proj into z/x/B/C/dt "
             "projections makes each tensor-parallel (d_inner, G*N "
             "divide 16), replacing per-pass FSDP all-gathers with one "
             "activation all-reduce per block."),
            ("split_mb2", {"ssm_split_proj": True, "microbatch": 2},
             "HYPOTHESIS: with the ssm AGs gone, the remaining FSDP-attn "
             "AG term still scales with nmb; mb 4->2 halves it within "
             "the freed memory budget."),
            ("fused_mb1", {"microbatch": 1},
             "HYPOTHESIS (after split_proj REFUTED — at d=1600 the "
             "per-block activation all-reduce costs more than gathering "
             "20MB of fused params): keep fused-FSDP ssm and instead "
             "drop to a single microbatch, dividing ALL param-AG "
             "traffic by 4; small model => activations still fit."),
            ("fused_mb2", {"microbatch": 2},
             "fallback if mb1 memory regresses"),
        ],
    },
}


def measure(arch, shape_name, overrides):
    cfg = get_config(arch).replace(**overrides)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh()
    rl = roofline_cell(cfg, shape)
    fn, args, in_sh, out_sh, donate = build_cell(cfg, mesh, shape)
    t0 = time.time()
    with jax.set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
        ma = compiled.memory_analysis()
        coll = parse_collectives(compiled.as_text())
    return {
        "roofline": rl.as_dict(),
        "mem_per_chip_gb": round(
            (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 1e9, 2),
        "collective_schedule": coll,
        "compile_s": round(time.time() - t0, 1),
    }


def main() -> None:
    os.makedirs("reports/perf", exist_ok=True)
    for cell, spec in CELLS.items():
        log = {"arch": spec["arch"], "shape": spec["shape"], "steps": []}
        print(f"\n=== {cell} ===", flush=True)
        for name, overrides, hypothesis in spec["variants"]:
            m = measure(spec["arch"], spec["shape"], overrides)
            rl = m["roofline"]
            entry = {"variant": name, "overrides": overrides,
                     "hypothesis": hypothesis, **m}
            log["steps"].append(entry)
            print(f"  {name:12s} bound={rl['bottleneck']:10s} "
                  f"frac={rl['roofline_fraction']:.3f} "
                  f"t_comp={rl['t_compute_s']:.3f}s "
                  f"t_mem={rl['t_memory_s']:.3f}s "
                  f"t_coll={rl['t_collective_s']:.3f}s "
                  f"mem={m['mem_per_chip_gb']}GB", flush=True)
        with open(f"reports/perf/{cell}.json", "w") as f:
            json.dump(log, f, indent=1)


if __name__ == "__main__":
    main()
