"""Analytic roofline model per (arch × shape × mesh) cell.

Methodology (EXPERIMENTS.md §Roofline): XLA's ``cost_analysis()`` counts
every ``lax.scan``/while body ONCE regardless of trip count (verified
empirically in this container), so compiled-artifact numbers cannot give
step totals for scanned programs.  The three roofline terms are therefore
derived from closed forms over the config (exact for FLOPs — the model
is matmul-dominated; documented coefficients for HBM traffic), while the
compiled dry-run provides (a) the proof of compilability + placement,
(b) ``memory_analysis`` per-device bytes (the "fits" check), and (c) the
HLO collective *schedule* (which collectives exist, at what shapes),
which validates the collective model below and catches redundant
collectives during §Perf iterations.

Hardware constants (TPU v5e class, per task spec):
  197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI.

Wire-byte conventions per chip: ring all-reduce of a Z-byte buffer over n
chips moves 2·Z·(n-1)/n; all-gather/reduce-scatter move Z·(n-1)/n;
all-to-all moves Z·(n-1)/n.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig, ShapeCfg

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / link

BF16 = 2
F32 = 4


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # totals (global, per step)
    useful_flops: float = 0.0      # MODEL_FLOPS = 6·N·D (train) / 2·N·D
    hlo_flops: float = 0.0         # analytic compiled flops (incl. waste)
    hbm_bytes: float = 0.0         # per-chip HBM traffic
    wire_bytes: float = 0.0        # per-chip ICI traffic
    breakdown: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def useful_ratio(self) -> float:
        return self.useful_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs throughput at the bound ÷ peak (the score)."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return (self.useful_flops / (self.chips * t_bound)) / PEAK_FLOPS

    def as_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "useful_flops": self.useful_flops, "hlo_flops": self.hlo_flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "wire_bytes_per_chip": self.wire_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "breakdown": self.breakdown,
        }


def _mlp_flops_tok(cfg: ModelConfig, d_ff: int) -> float:
    mults = 3 if cfg.act == "swiglu" else 2
    return 2.0 * cfg.d_model * d_ff * mults


def _attn_proj_flops_tok(cfg: ModelConfig) -> float:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    return 2.0 * d * (h + 2 * kv + h) * dh  # q,k,v,o


def _ssm_flops_tok(cfg: ModelConfig) -> float:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    p = di // h
    q = cfg.ssm_chunk
    zdim = 2 * di + 2 * g * n + h
    conv_dim = di + 2 * g * n
    intra = 2.0 * q * g * n + 2.0 * q * h * p        # CB + (w·x)
    inter = 2.0 * h * n * p * 2                       # states + y_inter
    return (2.0 * d * zdim + 2.0 * cfg.ssm_conv * conv_dim
            + intra + inter + 2.0 * di * d)


def _moe_flops_tok(cfg: ModelConfig, seq: int, useful: bool) -> float:
    e, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    expert = _mlp_flops_tok(cfg, cfg.expert_ff)
    if useful:
        return k * expert + 2.0 * cfg.d_model * e
    cap = cf * k * seq / e
    dispatch = 2.0 * e * cap * cfg.d_model * 2        # dispatch + combine
    return cf * k * expert + 2.0 * cfg.d_model * e + dispatch


def _layer_flops_tok(cfg: ModelConfig, seq: int, *, useful: bool,
                     ctx: float | None = None) -> float:
    """Forward flops per token per layer. ``ctx``: decode context length."""
    kind = cfg.family
    total = 0.0
    # attention
    if kind not in ("ssm",):
        total += _attn_proj_flops_tok(cfg)
        h, dh = cfg.n_heads, cfg.d_head
        if ctx is not None:                     # decode: attend over cache
            eff = ctx
            if cfg.sliding_window > 0:
                # all-but-global layers see only the window
                ge = cfg.global_layer_every or cfg.n_layers
                frac_global = 1.0 / ge
                eff = (frac_global * ctx
                       + (1 - frac_global) * min(cfg.sliding_window, ctx))
            total += 4.0 * h * dh * eff
        else:
            pairs = seq / 2 if useful else seq  # blockwise computes full S²
            if cfg.sliding_window > 0:
                ge = cfg.global_layer_every or cfg.n_layers
                frac_global = 1.0 / ge
                w = min(cfg.sliding_window, seq)
                pairs = frac_global * pairs + (1 - frac_global) * (
                    w if useful else w * 2)
            total += 4.0 * h * dh * pairs
    # mixer / mlp
    if kind == "ssm":
        total += _ssm_flops_tok(cfg)
    elif kind == "hybrid":
        total += _ssm_flops_tok(cfg) + _mlp_flops_tok(cfg, cfg.d_ff)
    elif kind == "moe":
        total += _moe_flops_tok(cfg, seq, useful)
    else:
        total += _mlp_flops_tok(cfg, cfg.d_ff)
    if cfg.family in ("encdec", "audio"):       # cross-attention
        total += _attn_proj_flops_tok(cfg)
        total += 4.0 * cfg.n_heads * cfg.d_head * (cfg.enc_seq_len / 1.0)
    return total


def _tp_sharded(cfg: ModelConfig, tp: int) -> dict:
    """Which blocks are TP vs FSDP under the rule engine (specs.py)."""
    return {
        "attn_tp": cfg.n_heads > 0 and cfg.n_heads % tp == 0,
        "mlp_tp": cfg.d_ff % tp == 0 if cfg.d_ff else False,
        "moe_ep": cfg.n_experts > 0 and cfg.n_experts % tp == 0,
        "moe_tp": cfg.n_experts > 0 and cfg.n_experts % tp != 0
                  and cfg.expert_ff % tp == 0,
        "vocab_tp": cfg.vocab % tp == 0,
    }


def roofline_cell(cfg: ModelConfig, shape: ShapeCfg, *,
                  multi_pod: bool = False) -> Roofline:
    chips = 512 if multi_pod else 256
    tp = 16
    dp = chips // tp
    mesh_name = "pod2x16x16" if multi_pod else "16x16"
    b, s = shape.global_batch, shape.seq_len
    l = cfg.n_layers
    n_params = cfg.param_count()
    r = Roofline(cfg.name, shape.name, mesh_name, chips)
    sh = _tp_sharded(cfg, tp)

    if shape.kind in ("train", "prefill"):
        tokens = float(b * s)
        fwd_useful = tokens * (
            l * _layer_flops_tok(cfg, s, useful=True)
            + 2.0 * cfg.d_model * cfg.vocab)
        fwd_hlo = tokens * (
            l * _layer_flops_tok(cfg, s, useful=False)
            + 2.0 * cfg.d_model * cfg.vocab)
        if cfg.family in ("encdec", "audio"):
            enc_tok = float(b * cfg.enc_seq_len)
            fwd_useful += enc_tok * cfg.enc_layers * (
                _attn_proj_flops_tok(cfg) + _mlp_flops_tok(cfg, cfg.d_ff)
                + 2.0 * cfg.n_heads * cfg.d_head * cfg.enc_seq_len)
            fwd_hlo += enc_tok * cfg.enc_layers * (
                _attn_proj_flops_tok(cfg) + _mlp_flops_tok(cfg, cfg.d_ff)
                + 4.0 * cfg.n_heads * cfg.d_head * cfg.enc_seq_len)
        if shape.kind == "train":
            remat_extra = 1.0 if cfg.remat == "full" else 0.0
            r.useful_flops = 3.0 * fwd_useful          # MODEL_FLOPS ≈ 6·N·D
            r.hlo_flops = (3.0 + remat_extra) * fwd_hlo
        else:
            r.useful_flops = fwd_useful
            r.hlo_flops = fwd_hlo

        # ---- HBM traffic per chip -------------------------------------
        nmb = max(cfg.microbatch, 1) if shape.kind == "train" else 1
        passes = (2 + (1 if cfg.remat == "full" else 0)) if shape.kind == "train" else 1
        p_local = n_params * BF16 / chips
        param_traffic = p_local * nmb * passes
        if shape.kind == "train":
            # grads f32 r/w + opt state r/w (adam: m,v r+w; adafactor ~0)
            opt_mult = 4 if cfg.optimizer.startswith("adamw") else 1
            param_traffic += n_params * F32 / chips * (2 + opt_mult)
        act = tokens / chips * l * cfg.d_model * BF16 * 12 * (
            3 if shape.kind == "train" else 1)
        r.hbm_bytes = param_traffic + act
        r.breakdown["param_traffic"] = param_traffic
        r.breakdown["act_traffic"] = act

        # ---- collective wire bytes per chip ---------------------------
        wire = 0.0
        z_act = tokens * cfg.d_model * BF16 / dp     # per-data-shard act
        ar = lambda z, n: 2.0 * z * (n - 1) / n
        ag = lambda z, n: z * (n - 1) / n
        bwd = 2.0 if shape.kind == "train" else 1.0
        if sh["attn_tp"]:
            wire += l * ar(z_act, tp) * bwd
        elif cfg.n_heads:  # FSDP attention: AG params per use, RS grads
            attn_param_bytes = (l * cfg.d_model
                                * (2 * cfg.n_heads + 2 * cfg.n_kv)
                                * cfg.d_head * BF16)
            wire += attn_param_bytes * passes * nmb * (dp - 1) / dp
            if shape.kind == "train":
                wire += attn_param_bytes * 2 * (dp - 1) / dp  # grad RS f32
        if cfg.d_ff and sh["mlp_tp"]:
            wire += l * ar(z_act, tp) * bwd
        if cfg.family in ("ssm", "hybrid"):
            zdim = 2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state \
                + cfg.n_ssm_heads
            if cfg.ssm_split_proj and cfg.d_inner % tp == 0:
                wire += l * ar(z_act, tp) * bwd      # TP AR per block
            else:  # fused in_proj: FSDP all-gather per pass + grad RS
                in_bytes = l * cfg.d_model * zdim * BF16
                wire += in_bytes * passes * nmb * (dp - 1) / dp
                if shape.kind == "train":
                    wire += in_bytes * 2 * (dp - 1) / dp
        if cfg.n_experts:
            if sh["moe_ep"]:   # token a2a there+back, fwd(+bwd)
                a2a = tokens * cfg.d_model * BF16 * cfg.top_k * cfg.capacity_factor / dp
                wire += l * 2 * a2a * (tp - 1) / tp * bwd
            elif sh["moe_tp"]:
                wire += l * ar(z_act, tp) * bwd
        if shape.kind == "train":
            # grad all-reduce over data of model-sharded grads (f32)
            g_local = n_params * F32 / tp
            wire += ar(g_local, dp)
            if multi_pod:
                r.breakdown["cross_pod_ar"] = ar(n_params * F32 / (16 * tp), 2)
        if sh["vocab_tp"]:
            # logits AR/AG at the loss (chunked): f32 chunk activations
            wire += ag(tokens * F32 / dp * 8, tp)  # lse/gold partials
        r.wire_bytes = wire

    else:  # ---- decode -------------------------------------------------
        tokens = float(b)
        ctx = float(s)
        r.useful_flops = tokens * (
            l * _layer_flops_tok(cfg, 1, useful=True, ctx=ctx)
            + 2.0 * cfg.d_model * cfg.vocab)
        r.hlo_flops = r.useful_flops  # decode: no blockwise waste
        p_local = n_params * BF16 / chips
        cache_bytes = 0.0
        if cfg.family not in ("ssm",):
            kv_ctx = ctx
            if cfg.sliding_window > 0:
                ge = cfg.global_layer_every or cfg.n_layers
                kv_ctx = (ctx / ge + (1 - 1 / ge) * min(cfg.sliding_window, ctx))
            cache_elt = (1.0 + 1.0 / cfg.d_head * 2  # int8 + bf16 scale
                         if cfg.cache_dtype == "int8" else BF16)
            cache_bytes = (2 * l * kv_ctx * cfg.n_kv * cfg.d_head * cache_elt
                           * b / chips)
        if cfg.family in ("ssm", "hybrid"):
            di = cfg.d_inner
            cache_bytes += (l * cfg.n_ssm_heads * cfg.ssm_state
                            * (di // cfg.n_ssm_heads) * F32 * b / chips)
        r.hbm_bytes = p_local + cache_bytes
        r.breakdown["cache_read"] = cache_bytes
        r.breakdown["param_read"] = p_local

        wire = 0.0
        ar = lambda z, n: 2.0 * z * (n - 1) / n
        bdim = min(b, dp)
        z_act = tokens * cfg.d_model * BF16 / bdim
        if sh["attn_tp"] or (cfg.n_kv and cfg.n_kv % tp != 0):
            # TP AR (heads) or seq-sharded partial-softmax AR per layer
            wire += l * ar(z_act, tp)
        if cfg.d_ff and sh["mlp_tp"]:
            wire += l * ar(z_act, tp)
        if sh["vocab_tp"]:
            wire += tokens / bdim * cfg.vocab * F32 * (tp - 1) / tp  # logits AG
        r.wire_bytes = wire

    return r
