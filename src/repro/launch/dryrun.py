import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the step fn (train_step / prefill / serve_step) with the full
     production config and ShapeDtypeStruct inputs (never allocating),
  2. ``jax.jit(...).lower(...)`` with explicit in/out shardings on the
     production mesh — 16×16 single-pod and 2×16×16 multi-pod,
  3. ``.compile()`` — sharding mismatches, unsupported collectives and
     compile-time OOM surface here as hard failures,
  4. records ``memory_analysis()`` (the per-chip fits proof),
     ``cost_analysis()`` raw numbers, the parsed HLO collective schedule,
     and the analytic roofline terms (launch/roofline.py),
  5. writes one JSON per cell under reports/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-32b \
      --shape train_4k --multi-pod
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import (ARCH_IDS, SHAPES, cell_runnable, get_config,
                           shape_by_name)
from repro.launch.builders import build_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_cell

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8,
                "s16": 2, "u16": 2}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collectives(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in the HLO.

    Note: ops inside while/scan bodies appear ONCE (XLA does not scale by
    trip count) — this is the collective *schedule* (kinds + shapes); the
    step-total collective bytes come from the analytic model.
    """
    out: dict[str, dict] = {}
    shape_re = re.compile(r"(f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = .*?(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", ls)
        if not m:
            continue
        kind = m.group(1)
        if "-done" in ls.split("=")[1].split("(")[0]:
            continue  # avoid double counting start/done pairs
        sm = shape_re.search(ls.split("=", 1)[1])
        if not sm:
            continue
        dt, dims = sm.groups()
        size = _DTYPE_BYTES[dt]
        for d in dims.split(","):
            if d:
                size *= int(d)
        slot = out.setdefault(kind, {"count": 0, "bytes": 0})
        slot["count"] += 1
        slot["bytes"] += size
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str) -> dict:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name}

    ok, why = cell_runnable(cfg, shape)
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args_sds, in_sh, out_sh, donate = build_cell(cfg, mesh, shape)
        with jax.set_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args_sds)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        rl = roofline_cell(cfg, shape, multi_pod=multi_pod)
        result.update(
            status="ok",
            t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            memory=dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                total_per_chip=int(ma.argument_size_in_bytes
                                   + ma.temp_size_in_bytes),
                fits_16gb=bool(ma.argument_size_in_bytes
                               + ma.temp_size_in_bytes < 16e9),
            ),
            cost_analysis_body={
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            },
            collectives=coll,
            roofline=rl.as_dict(),
        )
    except Exception as e:  # record, keep sweeping
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    os.makedirs(out_dir, exist_ok=True)
    fn_out = os.path.join(
        out_dir, f"{arch.replace('/', '_')}__{shape_name}__{mesh_name}.json")
    with open(fn_out, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_skip = n_err = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi_pod=mp, out_dir=args.out)
                tag = {"ok": "OK  ", "skipped": "SKIP", "error": "ERR "}[r["status"]]
                extra = ""
                if r["status"] == "ok":
                    mem = r["memory"]["total_per_chip"] / 1e9
                    rf = r["roofline"]["roofline_fraction"]
                    bn = r["roofline"]["bottleneck"]
                    extra = (f"mem/chip={mem:.2f}GB fits={r['memory']['fits_16gb']} "
                             f"roofline={rf:.3f} bound={bn} "
                             f"compile={r['t_compile_s']}s")
                    n_ok += 1
                elif r["status"] == "skipped":
                    extra = r["reason"]
                    n_skip += 1
                else:
                    extra = r["error"][:200]
                    n_err += 1
                mesh_name = "pod2x16x16" if mp else "16x16"
                print(f"[{tag}] {mesh_name:11s} {arch:24s} {shape:12s} {extra}",
                      flush=True)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
