"""End-to-end training driver.

Wires every substrate together: config → mesh → sharded init →
data pipeline → guarded train loop with straggler detection, async
checkpointing and crash recovery.  On this CPU container it runs the
smoke-scale configs (``--smoke``); on a real pod the same code path runs
the full configs.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-32b --smoke \
      --steps 20 --mesh 1x1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get_config
from repro.models.transformer import init_model
from repro.sharding.specs import named, param_specs
from repro.training import (
    AsyncCheckpointer,
    DataConfig,
    StepGuard,
    StragglerDetector,
    TokenDataset,
    latest_step,
    restore,
)
from repro.training.train_step import init_train_state, make_train_step


def make_mesh_arg(spec: str) -> Mesh:
    d, m = (int(x) for x in spec.split("x"))
    devs = jax.devices()[: d * m]
    return jax.make_mesh((d, m), ("data", "model"), devices=devs,
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_mesh_arg(args.mesh)

    key = jax.random.PRNGKey(0)
    with jax.set_mesh(mesh):
        params = init_model(key, cfg)
        pspecs = param_specs(cfg, params, mesh)
        params = jax.device_put(params, named(mesh, pspecs))
        state = init_train_state(cfg, params)
        step_fn, _ = make_train_step(cfg, q_block=min(args.seq_len, 512))
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

        start = 0
        if args.resume and latest_step(args.ckpt_dir) is not None:
            state, start = restore(args.ckpt_dir, state)
            print(f"resumed from step {start}")

        ds = TokenDataset(DataConfig(cfg.vocab, args.seq_len, args.batch))
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        strag = StragglerDetector()
        guard = StepGuard(reload_fn=lambda: restore(args.ckpt_dir, state)[0])

        for i in range(start, start + args.steps):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
            t0 = time.time()
            state, metrics = guard.run(step_fn, state, batch)
            dt = time.time() - t0
            flagged = strag.record(i, dt)
            if i % 5 == 0 or flagged:
                print(f"step {i}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={dt*1e3:.0f}ms{' STRAGGLER' if flagged else ''}",
                      flush=True)
            if (i + 1) % args.ckpt_every == 0:
                ckpt.save(i + 1, state)
        ckpt.wait()
        print("training done; retries:", guard.retries)


if __name__ == "__main__":
    main()
