"""MCMC driver — the paper's own workloads on the AIA-analogue pipeline.

  PYTHONPATH=src python -m repro.launch.run_mcmc --config aia-bn-asia
  PYTHONPATH=src python -m repro.launch.run_mcmc --config aia-bn-asia \
      --evidence smoke=1,dysp=1 --query lung,bronc   # posterior query
  PYTHONPATH=src python -m repro.launch.run_mcmc --config aia-mrf-penguin \
      --scale 0.2 --sweeps 30
  PYTHONPATH=src python -m repro.launch.run_mcmc --config aia-mrf-penguin \
      --mesh 2x2 --devices 4   # distributed halo-exchange Gibbs (C3)

Bayesian-network configs with ``--evidence`` route through the posterior
query engine (:mod:`repro.serve`): evidence nodes are clamped at compile
time, the sweep program comes from the plan cache, and sampling
early-stops on the rank-normalized R̂ + ESS retirement rule
(``docs/diagnostics.md``; the report prints both the legacy split-R̂
and the rank diagnostics).
"""
from __future__ import annotations

import argparse
import os

from repro.serve.telemetry import monotonic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", required=True)
    ap.add_argument("--sweeps", type=int, default=0)
    ap.add_argument("--chains", type=int, default=0)
    ap.add_argument("--scale", type=float, default=1.0,
                    help="scale MRF image size (CPU-friendly runs)")
    ap.add_argument("--mesh", default="",
                    help="e.g. 2x2 — run distributed halo-exchange Gibbs")
    ap.add_argument("--devices", type=int, default=0,
                    help="fake host devices for --mesh on CPU")
    ap.add_argument("--no-iu", action="store_true")
    ap.add_argument("--sampler", choices=("xla", "pallas"), default="xla",
                    help="sampling backend: two-stage XLA ops or the "
                         "fused Pallas sweep kernel (bitwise-identical; "
                         "interpreted off-TPU)")
    ap.add_argument("--evidence", default="",
                    help="BN only: observations, e.g. smoke=1,dysp=1 — "
                         "answers a posterior query via repro.serve")
    ap.add_argument("--query", default="",
                    help="BN only: comma-separated query variables "
                         "(default: all unobserved)")
    ap.add_argument("--mode", default="marginals",
                    choices=("marginals", "map"),
                    help="with --evidence: posterior marginals (default) "
                         "or annealed MAP/MPE search (reports the argmax "
                         "assignment + its energy; docs/inference_modes.md)")
    ap.add_argument("--trace-out", default="",
                    help="with --evidence: write a Chrome/Perfetto trace "
                         "of the query lifecycle here")
    ap.add_argument("--metrics-json", default="",
                    help="with --evidence: write the engine.stats() "
                         "snapshot here as JSON")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.aia_paper import MCMC_CONFIGS
    from repro.pgm import networks
    from repro.pgm.compile import compile_bayesnet, run_gibbs
    from repro.pgm.gibbs import init_labels, mrf_gibbs
    from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_mrf

    cfg = MCMC_CONFIGS[args.config]
    sweeps = args.sweeps or cfg.n_sweeps
    chains = args.chains or cfg.n_chains
    use_iu = not args.no_iu

    if cfg.kind == "bayesnet" and args.evidence:
        from repro.serve import PosteriorEngine, Query, Telemetry, \
            parse_evidence

        bn = getattr(networks, cfg.network)()
        evidence = parse_evidence(args.evidence)
        qvars = tuple(v.strip() for v in args.query.split(",") if v.strip())
        tel = (Telemetry() if (args.trace_out or args.metrics_json)
               else None)
        engine = PosteriorEngine(
            {cfg.network: bn}, chains_per_query=chains, k=cfg.k,
            use_iu=use_iu, sampler=args.sampler, burn_in=cfg.burn_in,
            telemetry=tel)
        budget = chains * max(sweeps - cfg.burn_in, 1)
        res = engine.answer(Query(cfg.network, evidence, qvars,
                                  n_samples=budget, mode=args.mode))
        n_q = (len(res.marginals) if res.map_assignment is None
               else len(res.map_assignment))
        print(f"{cfg.network}: evidence {evidence} -> {n_q} query vars "
              f"(mode={args.mode})")
        print(f"{res.n_node_samples} RV samples in {res.wall_s:.2f}s -> "
              f"{res.n_node_samples/res.wall_s/1e6:.2f} MSample/s (CPU), "
              f"{res.bits_per_sample:.2f} bits/sample")
        d = res.diagnostics
        print(f"split-Rhat={res.rhat:.3f} rank-Rhat={d.rank_rhat:.3f} "
              f"folded-Rhat={d.folded_rhat:.3f} "
              f"ESS bulk/tail={d.ess_bulk:.0f}/{d.ess_tail:.0f} "
              f"({d.min_ess/res.wall_s:.0f} ESS/s)")
        print(f"converged={res.converged} kept={res.n_samples} "
              f"sweeps={d.sweeps_used} plan_cache_hit={res.cache_hit}")
        if res.map_assignment is not None:
            print(f"  MAP assignment (energy {res.map_energy:.3f} nats):")
            for var, val in res.map_assignment.items():
                print(f"    {var} = {val}")
        for var, m in res.marginals.items():
            print(f"  P({var} | e) = {np.round(m, 3)}")
        if args.trace_out:
            engine.telemetry.write_trace(args.trace_out)
            print(f"trace written to {args.trace_out}")
        if args.metrics_json:
            import json
            with open(args.metrics_json, "w") as f:
                json.dump(engine.stats(), f, indent=2)
            print(f"metrics snapshot written to {args.metrics_json}")
        return

    if cfg.kind == "bayesnet":
        bn = getattr(networks, cfg.network)()
        prog = compile_bayesnet(bn, k=cfg.k)
        print(f"{cfg.network}: {bn.n_nodes} nodes, "
              f"{prog.n_colors} colors (DSatur)")
        t0 = monotonic()
        x, counts, stats = run_gibbs(
            jax.random.PRNGKey(0), prog, n_chains=chains, n_sweeps=sweeps,
            burn_in=cfg.burn_in, use_iu=use_iu, sampler=args.sampler)
        jax.block_until_ready(counts)
        dt = monotonic() - t0
        n_samples = chains * sweeps * bn.n_nodes
        print(f"{n_samples} RV samples in {dt:.2f}s -> "
              f"{n_samples/dt/1e6:.2f} MSample/s (CPU)")
        print(f"random bits/sample: {float(stats.bits_used)/n_samples:.2f}")
        marg = np.asarray(counts, np.float64)
        marg /= np.clip(marg.sum(-1, keepdims=True), 1, None)
        for v in range(min(bn.n_nodes, 10)):
            print(f"  P({bn.names[v]}) = {np.round(marg[v,:bn.card[v]], 3)}")
        return

    # ---- MRF ------------------------------------------------------------
    h = max(int(cfg.height * args.scale), 16)
    w = max(int(cfg.width * args.scale), 16)
    if cfg.pairwise == "potts":
        mrf, truth = networks.penguin_task(h, w, beta=cfg.beta)
    else:
        mrf, truth = networks.art_task(h, w, n_labels=cfg.n_labels,
                                       beta=cfg.beta, tau=cfg.tau)
    print(f"{cfg.name}: {h}x{w}, L={mrf.n_labels}")

    if args.mesh:
        from repro.launch.mesh import make_pgm_mesh

        rows, cols = (int(x) for x in args.mesh.split("x"))
        mesh = make_pgm_mesh(rows, cols)
        key = jax.random.PRNGKey(0)
        lab, u, pw, valid, _ = shard_mrf(mesh, mrf, n_chains=chains, key=key)
        step = make_mesh_gibbs_step(mesh, k=cfg.k, use_iu=use_iu,
                                    sampler=args.sampler)
        t0 = monotonic()
        bits = 0
        for i in range(sweeps):
            key, sub = jax.random.split(key)
            lab, bgrid = step(sub, lab, u, pw, valid)
            bits += int(np.asarray(bgrid, np.int64).sum())
        jax.block_until_ready(lab)
        dt = monotonic() - t0
        final = np.asarray(lab)[0][:h, :w]
    else:
        key = jax.random.PRNGKey(0)
        lab = init_labels(key, mrf, chains)
        t0 = monotonic()
        lab, stats = mrf_gibbs(
            jax.random.PRNGKey(1), lab, jnp.asarray(mrf.unary),
            jnp.asarray(mrf.pairwise), n_sweeps=sweeps, k=cfg.k,
            use_iu=use_iu, sampler=args.sampler)
        jax.block_until_ready(lab)
        dt = monotonic() - t0
        bits = int(stats.bits_used)
        final = np.asarray(lab)[0]

    n_samples = chains * sweeps * h * w
    acc = float((final == truth).mean())
    print(f"{n_samples} site samples in {dt:.2f}s -> "
          f"{n_samples/dt/1e6:.2f} MSample/s (CPU)")
    print(f"bits/sample: {bits/n_samples:.2f}  accuracy vs truth: {acc:.4f}")


if __name__ == "__main__":
    main()
