"""Serving: prefill + autoregressive decode with the KY token sampler.

The decode step ends in the paper's pipeline: logits → max-subtract →
IU/exact exp → fixed-point integer weights → hierarchical non-normalized
Knuth-Yao sample (``repro.core.token_sampler``).  No softmax
normalization over the vocabulary is computed during serving.
``sampler="categorical"`` switches to the conventional
``jax.random.categorical`` baseline for A/B comparison.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.token_sampler import categorical_baseline, ky_sample_tokens
from repro.models.transformer import (
    decode_step,
    encode,
    init_cache,
    prefill_cross_cache,
)


class GenState(NamedTuple):
    cache: dict
    tokens: jax.Array      # (B, T_out) generated so far
    last: jax.Array        # (B, 1) last token
    pos: jax.Array         # scalar
    key: jax.Array
    bits: jax.Array        # scalar int64-ish total random bits (KY metric)


def sample_logits(key, logits, *, sampler: str, temperature: float):
    if sampler == "ky":
        out = ky_sample_tokens(key, logits, temperature=temperature)
        return out.token, jnp.sum(out.bits_used)
    if sampler == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), jnp.int32(0)
    return (categorical_baseline(key, logits, temperature).astype(jnp.int32),
            jnp.int32(32) * logits.shape[0])


def prefill(params, cfg: ModelConfig, tokens, cache, *, frontend=None,
            src_embeds=None, q_block: int = 512):
    """Run the prompt through the model, filling the cache via per-token
    decode (cache-writing prefill). Returns (cache, last_logits)."""
    if cfg.family in ("encdec", "audio") and src_embeds is not None:
        enc_out = encode(params, cfg, src_embeds, q_block)
        cache = prefill_cross_cache(params, cfg, enc_out, cache)

    def body(carry, t):
        cache, _ = carry
        logits, cache = decode_step(params, cfg, tokens[:, t][:, None],
                                    t, cache)
        return (cache, logits), None

    b = tokens.shape[0]
    v = cfg.vocab
    (cache, logits), _ = jax.lax.scan(
        body, (cache, jnp.zeros((b, v), jnp.dtype(cfg.dtype))),
        jnp.arange(tokens.shape[1]))
    return cache, logits


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "max_new", "sampler", "temperature", "q_block"))
def generate(
    params,
    cfg: ModelConfig,
    prompt: jax.Array,            # (B, S_prompt)
    key: jax.Array,
    *,
    max_new: int,
    sampler: str = "ky",
    temperature: float = 1.0,
    q_block: int = 512,
    frontend: jax.Array | None = None,
    src_embeds: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Autoregressive generation; returns (tokens (B, max_new), total_bits)."""
    b, s = prompt.shape
    cache = init_cache(cfg, b, s + max_new)
    cache, logits = prefill(params, cfg, prompt, cache,
                            frontend=frontend, src_embeds=src_embeds,
                            q_block=q_block)
    key, sub = jax.random.split(key)
    tok, bits0 = sample_logits(sub, logits.astype(jnp.float32),
                               sampler=sampler, temperature=temperature)

    def body(st: GenState, i):
        logits, cache = decode_step(params, cfg, st.last, st.pos, st.cache)
        key, sub = jax.random.split(st.key)
        tok, nbits = sample_logits(sub, logits.astype(jnp.float32),
                                   sampler=sampler, temperature=temperature)
        toks = jax.lax.dynamic_update_slice(st.tokens, tok[:, None], (0, i))
        return GenState(cache, toks, tok[:, None], st.pos + 1, key,
                        st.bits + nbits), None

    toks0 = jnp.zeros((b, max_new), jnp.int32)
    toks0 = toks0.at[:, 0].set(tok)
    st = GenState(cache, toks0, tok[:, None], jnp.int32(s), key,
                  bits0.astype(jnp.int32))
    st, _ = jax.lax.scan(body, st, jnp.arange(1, max_new))
    return st.tokens, st.bits


def serve_step_fn(params, cfg: ModelConfig, *, sampler: str = "ky",
                  temperature: float = 1.0):
    """One batched serving step (the dry-run `serve_step` target):
    (key, token (B,1), pos, cache) -> (next_token, new_cache)."""

    def step(key, token, pos, cache):
        logits, cache = decode_step(params, cfg, token, pos, cache)
        tok, _ = sample_logits(key, logits.astype(jnp.float32),
                               sampler=sampler, temperature=temperature)
        return tok, cache

    return step
