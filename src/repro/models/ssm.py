"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Train/prefill use the chunked SSD algorithm: within a chunk the quadratic
"attention-like" form runs on the MXU; across chunks the SSM state is
carried by an (associative-scannable) linear recurrence.  Decode is the
O(1) recurrent update — the reason ``long_500k`` is runnable for the SSM
and hybrid architectures while pure full-attention archs skip it.

Layout: x is split into H heads of P dims (d_inner = H·P); B/C live in G
groups of N state dims.  A is a per-head negative scalar (scalar-identity
SSD restriction), dt a per-head softplus rate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def init_ssm(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    conv_dim = di + 2 * g * n
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    p = {
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
        * cfg.ssm_conv ** -0.5,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        # softplus(dt_bias) ~ [0.001, 0.1] (mamba2 init): softplus^-1(0.05)
        "dt_bias": jnp.full((h,), -3.0, jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d), jnp.float32) * di ** -0.5,
    }
    if cfg.ssm_split_proj:
        # per-stream projections — each tensor-parallel where divisible
        p["z_proj"] = jax.random.normal(ks[3], (d, di), jnp.float32) * s
        p["x_proj"] = jax.random.normal(ks[4], (d, di), jnp.float32) * s
        p["b_proj"] = jax.random.normal(ks[5], (d, g * n), jnp.float32) * s
        p["c_proj"] = jax.random.normal(ks[6], (d, g * n), jnp.float32) * s
        p["dt_proj"] = jax.random.normal(ks[7], (d, h), jnp.float32) * s
    else:
        # fused projection: z (gate), x, B, C, dt
        p["in_proj"] = jax.random.normal(
            ks[0], (d, 2 * di + 2 * g * n + h), jnp.float32) * s
    return p


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, kernel K. state: (B, K-1, C) carry for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(xbc[:, : k - 1])
        xp = jnp.concatenate([pad, xbc], axis=1)
    else:
        xp = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
    out = sum(xp[:, i : xp.shape[1] - (k - 1 - i)] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out + b), new_state


def ssd_chunked(x, dt, a, b_mat, c_mat, *, chunk: int):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) negative;
    b_mat/c_mat: (B, S, G, N).  Returns y: (B, S, H, P).
    """
    bs, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    nc = s // chunk
    assert s % chunk == 0
    rep = h // g

    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = b_mat.reshape(bs, nc, chunk, g, n)
    cc = c_mat.reshape(bs, nc, chunk, g, n)

    da = dtc * a  # (B, nc, Q, H) negative increments
    cum = jnp.cumsum(da, axis=2)                     # running log-decay
    seg_total = cum[:, :, -1]                        # (B, nc, H)

    # ---- intra-chunk (quadratic, MXU) --------------------------------
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H) i-j
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask BEFORE exp: exp of the masked (i<j, positive) entries would
    # overflow and poison gradients through the where.
    decay = jnp.exp(jnp.where(causal, li, -60.0)) * causal
    cb = jnp.einsum("bzqgn,bzsgn->bzqsg", cc, bc,
                    preferred_element_type=jnp.float32)
    cb = jnp.repeat(cb, rep, axis=-1)                    # groups -> heads
    w_ij = cb * decay * dtc[:, :, None, :, :]            # (B,nc,Q,S,H)
    y = jnp.einsum("bzqsh,bzshp->bzqhp", w_ij.astype(x.dtype), xc,
                   preferred_element_type=jnp.float32)

    # ---- chunk states + inter-chunk recurrence ------------------------
    dec_to_end = jnp.exp(seg_total[:, :, None, :] - cum)     # (B,nc,Q,H)
    xb = xc * (dtc * dec_to_end)[..., None]                  # weight each step
    # expand B groups to heads: (B,nc,Q,G,N) -> (B,nc,Q,H,N)
    bh = jnp.repeat(bc, rep, axis=3)
    states = jnp.einsum("bzqhn,bzqhp->bzhnp", bh.astype(x.dtype), xb,
                        preferred_element_type=jnp.float32)  # (B,nc,H,N,P)

    def scan_fn(h_prev, inp):
        st, tot = inp                                    # (B,H,N,P), (B,H)
        h_new = h_prev * jnp.exp(tot)[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((bs, h, n, p), jnp.float32)
    _, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(seg_total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (B,nc,H,N,P) state before chunk

    # ---- contribution of carried state to each position ---------------
    ch = jnp.repeat(cc, rep, axis=3)                     # (B,nc,Q,H,N)
    dec_from_start = jnp.exp(cum)                        # (B,nc,Q,H)
    y_inter = jnp.einsum("bzqhn,bzhnp->bzqhp", ch.astype(x.dtype),
                         h_prevs.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    y = y + y_inter * dec_from_start[..., None]
    return y.reshape(bs, s, h, p).astype(x.dtype)


def apply_ssm(
    params: dict,
    cfg: ModelConfig,
    u: jax.Array,                  # (B, S, D)
    *,
    state: dict | None = None,     # decode: {"h": (B,H,N,P), "conv": (B,K-1,C)}
) -> tuple[jax.Array, dict | None]:
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    p = di // h
    bsz, s, _ = u.shape
    dt_ = u.dtype

    if "in_proj" in params:
        zxbcdt = u @ params["in_proj"].astype(dt_)
        z = zxbcdt[..., :di]
        xbc = zxbcdt[..., di : 2 * di + 2 * g * n]
        dt_raw = zxbcdt[..., 2 * di + 2 * g * n :]
    else:  # split projections (ssm_split_proj)
        z = u @ params["z_proj"].astype(dt_)
        xbc = jnp.concatenate(
            [u @ params["x_proj"].astype(dt_),
             u @ params["b_proj"].astype(dt_),
             u @ params["c_proj"].astype(dt_)], axis=-1)
        dt_raw = u @ params["dt_proj"].astype(dt_)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])                       # (H,) negative

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(
        xbc, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_),
        conv_state)
    x = xbc[..., :di].reshape(bsz, s, h, p)
    b_mat = xbc[..., di : di + g * n].reshape(bsz, s, g, n)
    c_mat = xbc[..., di + g * n :].reshape(bsz, s, g, n)

    new_state = None
    if state is not None:  # ---- O(1) decode update ----------------------
        assert s == 1
        h_prev = state["h"]                              # (B,H,N,P) f32
        dt1 = dt[:, 0]                                   # (B,H)
        dec = jnp.exp(dt1 * a[None])                     # (B,H)
        bh = jnp.repeat(b_mat[:, 0], h // g, axis=1)     # (B,H,N)
        xh = x[:, 0] * dt1[..., None]                    # (B,H,P)
        h_new = h_prev * dec[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bh.astype(jnp.float32), xh.astype(jnp.float32))
        ch = jnp.repeat(c_mat[:, 0], h // g, axis=1)     # (B,H,N)
        y = jnp.einsum("bhn,bhnp->bhp", ch.astype(jnp.float32), h_new)
        y = y[:, None].astype(dt_)                       # (B,1,H,P)
        new_state = {"h": h_new, "conv": new_conv}
        y = y.reshape(bsz, 1, h, p)
    else:
        y = ssd_chunked(x, dt, a, b_mat, c_mat, chunk=min(cfg.ssm_chunk, s))

    y = y + x * params["d_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(bsz, s, di) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int) -> dict:
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    p = di // h
    conv_dim = di + 2 * g * n
    return {
        "h": jnp.zeros((batch, h, n, p), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), jnp.float32),
    }
