"""Model assembly for all assigned families, scan-over-layers throughout.

One compiled layer body per family (compile time independent of depth —
essential for lowering 96-layer models against 512 placeholder devices):

  dense/vlm      attn + MLP blocks (GQA, RoPE, optional QKV bias/softcap)
  moe            attn + MoE blocks (Switch capacity dispatch)
  ssm            Mamba-2 SSD blocks only (attention-free)
  hybrid         parallel attn(SWA)+SSM heads, then MLP  (hymba)
  encdec/audio   bidirectional encoder + causal decoder w/ cross-attn
  vlm/audio      stub frontends: precomputed patch/frame embeddings are
                 scattered into the first ``frontend_tokens`` positions

Public entry points: ``init_model``, ``forward`` (train/prefill),
``init_cache`` + ``decode_step`` (serving), ``loss_fn``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.sharding import ctx as shard_ctx
from repro.models.layers import (
    _norm_init,
    apply_mlp,
    chunked_xent,
    embed_tokens,
    init_embed,
    init_mlp,
    rmsnorm,
    rope_freqs,
    unembed,
)

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key: jax.Array, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    if kind == "ssm":
        return {"ln1": _norm_init(d), "ssm": ssm_lib.init_ssm(ks[0], cfg)}
    p: Params = {"ln1": _norm_init(d), "ln2": _norm_init(d)}
    if kind == "dense":
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["mlp"] = init_mlp(ks[1], cfg)
    elif kind == "moe":
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    elif kind == "hybrid":
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["ssm"] = ssm_lib.init_ssm(ks[1], cfg)
        p["mlp"] = init_mlp(ks[2], cfg)
    elif kind == "enc":
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["mlp"] = init_mlp(ks[1], cfg)
    elif kind == "dec":
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["cross"] = attn_lib.init_attention(ks[1], cfg, cross=True)
        p["lnx"] = _norm_init(d)
        p["mlp"] = init_mlp(ks[2], cfg)
    else:
        raise ValueError(kind)
    return p


def _layer_kind(cfg: ModelConfig) -> str:
    return {
        "dense": "dense", "vlm": "dense", "moe": "moe",
        "ssm": "ssm", "hybrid": "hybrid",
        "encdec": "dec", "audio": "dec",
    }[cfg.family]


def init_model(key: jax.Array, cfg: ModelConfig) -> Params:
    k_emb, k_layers, k_enc, k_fin = jax.random.split(key, 4)
    kind = _layer_kind(cfg)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, kind))(layer_keys)
    params: Params = {
        "embed": init_embed(k_emb, cfg),
        "layers": layers,
        "final_norm": _norm_init(cfg.d_model),
    }
    if cfg.family in ("encdec", "audio"):
        enc_keys = jax.random.split(k_enc, cfg.enc_layers)
        params["encoder"] = jax.vmap(lambda k: _init_layer(k, cfg, "enc"))(enc_keys)
        params["enc_norm"] = _norm_init(cfg.d_model)
    pd = jnp.dtype(cfg.param_dtype)
    if pd != jnp.float32:
        params = jax.tree.map(lambda p: p.astype(pd), params)
    return params


def layer_windows(cfg: ModelConfig) -> jax.Array:
    """Per-layer attention window (0 = full) — hybrid keeps every k-th
    layer global, first and last always global (hymba recipe)."""
    if cfg.sliding_window <= 0:
        return jnp.zeros((cfg.n_layers,), jnp.int32)
    w = jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    if cfg.global_layer_every > 0:
        idx = jnp.arange(cfg.n_layers)
        is_global = (idx % cfg.global_layer_every == 0) | (idx == cfg.n_layers - 1)
        w = jnp.where(is_global, 0, w)
    return w


# ---------------------------------------------------------------------------
# Forward (train / prefill): scan over layers
# ---------------------------------------------------------------------------

def _block(cfg: ModelConfig, kind: str, x, lp, window, freqs, q_block):
    zero = jnp.float32(0.0)
    if kind == "ssm":
        h, _ = ssm_lib.apply_ssm(lp["ssm"], cfg, rmsnorm(lp["ln1"], x))
        return x + h, zero
    if kind == "hybrid":
        hn = rmsnorm(lp["ln1"], x)
        a, _ = attn_lib.apply_attention(
            lp["attn"], cfg, hn, freqs=freqs, window=window, q_block=q_block)
        s, _ = ssm_lib.apply_ssm(lp["ssm"], cfg, hn)
        x = x + 0.5 * (a + s)
        return x + apply_mlp(lp["mlp"], cfg, rmsnorm(lp["ln2"], x)), zero
    a, _ = attn_lib.apply_attention(
        lp["attn"], cfg, rmsnorm(lp["ln1"], x),
        freqs=freqs, window=window, causal=(kind != "enc"), q_block=q_block)
    x = x + a
    if kind == "moe":
        m, aux = moe_lib.apply_moe(lp["moe"], cfg, rmsnorm(lp["ln2"], x))
        moe_loss = 0.01 * aux["load_balance"] + 0.001 * aux["router_z"]
        return x + m, moe_loss
    return x + apply_mlp(lp["mlp"], cfg, rmsnorm(lp["ln2"], x)), zero


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _stack_scan(cfg, kind, layers, x, windows, freqs, q_block,
                extra_block=None):
    body = extra_block or (lambda x, lp, w: _block(cfg, kind, x, lp, w, freqs, q_block))
    body = _remat(cfg, body)

    def step(carry, inp):
        x, aux = carry
        lp, w = inp
        out = body(x, lp, w)
        x2, a = out if isinstance(out, tuple) else (out, jnp.float32(0.0))
        # sequence-parallel storage of the saved residual (sharding/ctx.py)
        x2 = shard_ctx.constrain(x2, "residual")
        return (x2, aux + a), None

    x = shard_ctx.constrain(x, "residual")
    (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0.0)), (layers, windows))
    return x, aux


def encode(params: Params, cfg: ModelConfig, src_embeds: jax.Array,
           q_block: int = 512) -> jax.Array:
    """Bidirectional encoder over precomputed frontend embeddings."""
    freqs = rope_freqs(cfg)
    windows = jnp.zeros((cfg.enc_layers,), jnp.int32)
    x, _ = _stack_scan(cfg, "enc", params["encoder"], src_embeds, windows,
                       freqs, q_block)
    return rmsnorm(params["enc_norm"], x)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                      # (B, S)
    *,
    frontend: jax.Array | None = None,      # (B, F, D) vlm/audio stub
    enc_out: jax.Array | None = None,       # encdec: encoder output
    q_block: int = 512,
    return_aux: bool = False,
):
    """Returns final hidden states (B, S, D) — unembed via loss/logits."""
    dt = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"], cfg, tokens, dt)
    if frontend is not None and cfg.family == "vlm":
        f = frontend.astype(dt)
        x = jax.lax.dynamic_update_slice(x, f, (0, 0, 0))
    kind = _layer_kind(cfg)
    freqs = rope_freqs(cfg)
    windows = layer_windows(cfg)

    if kind == "dec":  # enc-dec family
        assert enc_out is not None

        def dec_block(x, lp, w):
            a, _ = attn_lib.apply_attention(
                lp["attn"], cfg, rmsnorm(lp["ln1"], x), freqs=freqs,
                window=w, q_block=q_block)
            x = x + a
            c, _ = attn_lib.apply_attention(
                lp["cross"], cfg, rmsnorm(lp["lnx"], x), freqs=None,
                causal=False, kv_source=enc_out, q_block=q_block)
            x = x + c
            return x + apply_mlp(lp["mlp"], cfg, rmsnorm(lp["ln2"], x))

        x, aux = _stack_scan(cfg, kind, params["layers"], x, windows, freqs,
                             q_block, extra_block=dec_block)
    else:
        x, aux = _stack_scan(cfg, kind, params["layers"], x, windows, freqs,
                             q_block)
    x = rmsnorm(params["final_norm"], x)
    return (x, aux) if return_aux else x


def loss_fn(
    params: Params,
    cfg: ModelConfig,
    batch: dict,
    q_block: int = 512,
) -> jax.Array:
    x, aux = forward(
        params, cfg, batch["tokens"],
        frontend=batch.get("frontend"),
        enc_out=(
            encode(params, cfg, batch["src_embeds"], q_block)
            if cfg.family in ("encdec", "audio") else None),
        q_block=q_block,
        return_aux=True,
    )
    xent = chunked_xent(x, params["embed"], cfg, batch["labels"],
                        batch.get("mask"))
    return xent + aux


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, single-token decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    kind = _layer_kind(cfg)
    dt = jnp.dtype(cfg.dtype)
    l = cfg.n_layers
    cache: dict = {}
    if kind in ("dense", "moe", "hybrid", "dec"):
        kv_shape = (l, batch, max_len, cfg.n_kv, cfg.d_head)
        if cfg.cache_dtype == "int8":
            # quantized KV: int8 payload + per-(token, kv-head) bf16 scale
            cache["k"] = jnp.zeros(kv_shape, jnp.int8)
            cache["v"] = jnp.zeros(kv_shape, jnp.int8)
            cache["k_scale"] = jnp.zeros(kv_shape[:-1], jnp.bfloat16)
            cache["v_scale"] = jnp.zeros(kv_shape[:-1], jnp.bfloat16)
        else:
            cache["k"] = jnp.zeros(kv_shape, dt)
            cache["v"] = jnp.zeros(kv_shape, dt)
    if kind in ("ssm", "hybrid"):
        di, g, n, h = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                       cfg.n_ssm_heads)
        p = di // h
        conv_dim = di + 2 * g * n
        cache["ssm_h"] = jnp.zeros((l, batch, h, n, p), jnp.float32)
        cache["ssm_conv"] = jnp.zeros((l, batch, cfg.ssm_conv - 1, conv_dim),
                                      jnp.float32)
    if kind == "dec":
        kv_shape = (l, batch, cfg.enc_seq_len, cfg.n_kv, cfg.d_head)
        cache["xk"] = jnp.zeros(kv_shape, dt)
        cache["xv"] = jnp.zeros(kv_shape, dt)
    return cache


def prefill_cross_cache(params: Params, cfg: ModelConfig,
                        enc_out: jax.Array, cache: dict) -> dict:
    """Precompute per-decoder-layer cross-attention KV from encoder out."""
    def one(lp):
        dt = enc_out.dtype
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"].astype(dt))
        return k, v

    xk, xv = jax.vmap(one)(params["layers"])
    return dict(cache, xk=xk, xv=xv)


def decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,        # (B, 1) int32 freshly sampled token
    pos: jax.Array,          # scalar int32 write position
    cache: dict,
) -> tuple[jax.Array, dict]:
    """One decoding step; returns (logits (B, V), new cache)."""
    dt = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"], cfg, token, dt)   # (B, 1, D)
    kind = _layer_kind(cfg)
    freqs = rope_freqs(cfg)
    windows = layer_windows(cfg)

    def step(x, inp):
        lp, w, cache_l = inp
        new_l = dict(cache_l)
        if kind == "ssm":
            h, st = ssm_lib.apply_ssm(
                lp["ssm"], cfg, rmsnorm(lp["ln1"], x),
                state={"h": cache_l["ssm_h"], "conv": cache_l["ssm_conv"]})
            x = x + h
            new_l["ssm_h"], new_l["ssm_conv"] = st["h"], st["conv"]
            return x, new_l
        kv_cache = {kk: cache_l[kk]
                    for kk in ("k", "v", "k_scale", "v_scale")
                    if kk in cache_l}
        if kind == "hybrid":
            hn = rmsnorm(lp["ln1"], x)
            a, kvc = attn_lib.apply_attention(
                lp["attn"], cfg, hn, freqs=freqs, window=w,
                cache=kv_cache, pos=pos)
            s, st = ssm_lib.apply_ssm(
                lp["ssm"], cfg, hn,
                state={"h": cache_l["ssm_h"], "conv": cache_l["ssm_conv"]})
            x = x + 0.5 * (a + s)
            x = x + apply_mlp(lp["mlp"], cfg, rmsnorm(lp["ln2"], x))
            new_l.update(kvc, ssm_h=st["h"], ssm_conv=st["conv"])
            return x, new_l
        a, kvc = attn_lib.apply_attention(
            lp["attn"], cfg, rmsnorm(lp["ln1"], x), freqs=freqs, window=w,
            cache=kv_cache, pos=pos)
        x = x + a
        new_l.update(kvc)
        if kind == "dec":
            c, _ = attn_lib.apply_attention(
                lp["cross"], cfg, rmsnorm(lp["lnx"], x), freqs=None,
                causal=False,
                cache={"k": cache_l["xk"], "v": cache_l["xv"]})
            x = x + c
        if kind == "moe":
            m, _ = moe_lib.apply_moe(lp["moe"], cfg, rmsnorm(lp["ln2"], x))
            x = x + m
        else:
            x = x + apply_mlp(lp["mlp"], cfg, rmsnorm(lp["ln2"], x))
        return x, new_l

    def scan_step(carry, inp):
        x, cache_all = carry
        lp, w, li = inp
        # slice layer li's cache, update, write back in place — the cache
        # stays a scan CARRY so XLA aliases it instead of double-buffering
        # a second (L, B, T, ...) copy (xs/ys pairs cannot alias).
        cache_l = jax.tree.map(lambda c: c[li], cache_all)
        x, new_l = step(x, (lp, w, cache_l))
        cache_all = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), li, 0),
            cache_all, new_l)
        return (x, cache_all), None

    (x, new_cache), _ = jax.lax.scan(
        scan_step, (x, cache),
        (params["layers"], windows, jnp.arange(cfg.n_layers)))
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], cfg, x)[:, 0, :]
    return logits, new_cache
