"""Layer primitives: norms, activations, RoPE, embeddings, MLP.

Pure-functional style: ``init_*`` returns a param dict; ``apply`` fns are
stateless.  Params keep semantic axes unflattened — attention weights are
``(d_model, heads, d_head)`` — so the sharding rule engine
(:mod:`repro.sharding.specs`) can target axes by name.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _norm_init(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * params["scale"]).astype(dt)


def act_fn(name: str, x: jax.Array, gate: jax.Array | None = None) -> jax.Array:
    if name == "swiglu":
        assert gate is not None
        return jax.nn.silu(gate) * x
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":  # nemotron squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale = d ** -0.5
    p = {
        "wi": jax.random.normal(k1, (d, ff), jnp.float32) * scale,
        "wo": jax.random.normal(k2, (ff, d), jnp.float32) * (ff ** -0.5),
    }
    if cfg.act == "swiglu":
        p["wg"] = jax.random.normal(k3, (d, ff), jnp.float32) * scale
    return p


def apply_mlp(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = x @ params["wi"].astype(dt)
    g = x @ params["wg"].astype(dt) if "wg" in params else None
    h = act_fn(cfg.act, h, g)
    return h @ params["wo"].astype(dt)


def init_embed(key: jax.Array, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), jnp.float32)}
    if not cfg.tie_embeddings:
        p["head"] = (
            jax.random.normal(k2, (cfg.d_model, cfg.vocab), jnp.float32)
            * cfg.d_model ** -0.5
        )
    return p


def embed_tokens(params: dict, cfg: ModelConfig, tokens: jax.Array,
                 dtype) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0).astype(dtype)


def unembed(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["tok"].T.astype(x.dtype)
    else:
        logits = x @ params["head"].astype(x.dtype)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c)
    return logits


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig) -> jax.Array:
    half = cfg.d_head // 2
    return cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, pos: jax.Array, freqs: jax.Array) -> jax.Array:
    """x: (B, S, H, dh); pos: (S,) or (B, S) int positions."""
    ang = pos[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    if ang.ndim == 2:  # (S, half) -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Chunked cross-entropy: never materializes the full (B, S, V) logits
# --------------------------------------------------------------------------

def chunked_xent(
    x: jax.Array,            # (B, S, D) final hidden states
    embed_params: dict,
    cfg: ModelConfig,
    labels: jax.Array,       # (B, S) int32
    mask: jax.Array | None = None,
    chunk: int = 512,
) -> jax.Array:
    b, s, d = x.shape
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    xc = x.reshape(b, n_chunks, chunk, d)
    lc = labels.reshape(b, n_chunks, chunk)
    mc = (mask.reshape(b, n_chunks, chunk) if mask is not None
          else jnp.ones_like(lc, jnp.float32))

    @jax.checkpoint  # recompute per-chunk logits in bwd: never stores (B,S,V)
    def chunk_loss(xi, li, mi):
        logits = unembed(embed_params, cfg, xi).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mi)

    def body(carry, inp):
        xi, li, mi = inp  # (B, chunk, D), (B, chunk)
        return carry + chunk_loss(xi, li, mi), None

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0), jnp.moveaxis(mc, 1, 0))
    total, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
    return total / jnp.maximum(jnp.sum(mc), 1.0)
