"""Attention: GQA/MHA/MQA with RoPE, sliding windows, KV caches.

Prefill/train use a lax-native blockwise (FlashAttention-style online-
softmax) formulation: O(S·block) memory, never materializing the full
(S, S) score matrix — required for the 32k prefill cells to fit HBM, and
compilable on any backend (the Pallas flash kernel in ``repro.kernels``
is the TPU-tuned variant of the same math).  Decode attends one query
against the cache densely.

GQA is computed with kv-heads kept unexpanded: q is viewed as
``(B, S, KV, G, dh)`` so no kv broadcast materializes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope
from repro.sharding import ctx as shard_ctx

_NEG_INF = -1e30


def init_attention(key: jax.Array, cfg: ModelConfig, *, cross: bool = False) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h, dh), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, kv, dh), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, kv, dh), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (h, dh, d), jnp.float32) * (h * dh) ** -0.5,
    }
    if cfg.attn_bias and not cross:
        p["bq"] = jnp.zeros((h, dh), jnp.float32)
        p["bk"] = jnp.zeros((kv, dh), jnp.float32)
        p["bv"] = jnp.zeros((kv, dh), jnp.float32)
    return p


def _mask(pos_q, pos_k, *, causal: bool, window: int, valid_k=None):
    """(..., Sq, Sk) additive mask from absolute positions."""
    m = jnp.zeros(pos_q.shape[-1:] + pos_k.shape[-1:], jnp.float32)
    dq = pos_q[:, None]
    dk = pos_k[None, :]
    if causal:
        m = jnp.where(dk > dq, _NEG_INF, m)
    if not (isinstance(window, int) and window == 0):
        w = jnp.asarray(window)  # may be a traced per-layer scalar (hymba)
        m = jnp.where((w > 0) & (dq - dk >= w), _NEG_INF, m)
    if valid_k is not None:
        m = jnp.where(valid_k[None, :], m, _NEG_INF)
    return m


def attend_blockwise(
    q: jax.Array,           # (B, Sq, H, dh)
    k: jax.Array,           # (B, Sk, KV, dh)
    v: jax.Array,           # (B, Sk, KV, dh)
    *,
    causal: bool = True,
    window: int | jax.Array = 0,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    """Online-softmax blockwise attention with FLAT heads.

    GQA kv-heads are expanded to full heads per kv-block (transient,
    one block at a time) so the head dim stays a single axis of size H —
    keeping tensor-parallel sharding clean (H | mesh) instead of the
    (KV, G) factorization that breaks divisibility (e.g. 96 = 8×12 where
    neither 8 nor 12 divides a 16-wide model axis).
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = dh ** -0.5
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    nq, nk = sq // q_block, sk // kv_block
    assert sq % q_block == 0 and sk % kv_block == 0

    qb = q.reshape(b, nq, q_block, h, dh)
    kb = k.reshape(b, nk, kv_block, kv, dh)
    vb = v.reshape(b, nk, kv_block, kv, dh)

    def q_step(_, qi):
        qblk, iq = qi                       # (B, qb, H, dh), scalar
        pos_q = iq * q_block + jnp.arange(q_block)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kblk, vblk, ik = ki
            if g > 1:  # expand kv -> flat heads for this block only
                kblk = jnp.repeat(kblk, g, axis=2)
                vblk = jnp.repeat(vblk, g, axis=2)
            pos_k = ik * kv_block + jnp.arange(kv_block)
            s = jnp.einsum(
                "bqhd,bshd->bhqs", qblk, kblk,
                preferred_element_type=jnp.float32) * scale
            s = s + _mask(pos_q, pos_k, causal=causal, window=window)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqs,bshd->bhqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, q_block), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        a0 = jnp.zeros((b, h, q_block, dh), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        out = jnp.moveaxis(out, 1, 2)       # (B, qb, H, dh)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(
        q_step, None, (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
    return jnp.moveaxis(blocks, 0, 1).reshape(b, sq, h, dh)


def attend_decode(
    q: jax.Array,           # (B, 1, H, dh)
    k_cache: jax.Array,     # (B, T, KV, dh)
    v_cache: jax.Array,
    pos: jax.Array,         # scalar int32: index of the new token
    *,
    window: int | jax.Array = 0,
) -> jax.Array:
    b, _, h, dh = q.shape
    t, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = dh ** -0.5
    qg = q.reshape(b, kv, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(t)
    valid = idx <= pos
    if not isinstance(window, int) or window > 0:
        w = jnp.asarray(window)
        valid &= jnp.where(w > 0, idx > pos - w, True)
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def apply_attention(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,                 # (B, S, D)
    *,
    freqs: jax.Array | None,
    pos0: jax.Array | int = 0,
    causal: bool = True,
    window: int | jax.Array = 0,
    cache: dict | None = None,    # {"k": (B,T,KV,dh), "v": ...} decode only
    pos: jax.Array | None = None, # decode write position (scalar)
    kv_source: jax.Array | None = None,  # cross-attention memory (B,Sm,D)
    q_block: int = 512,
) -> tuple[jax.Array, dict | None]:
    b, s, d = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    src = kv_source if kv_source is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", src, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = shard_ctx.constrain(q, "attn_q")
    k = shard_ctx.constrain(k, "attn_kv")
    v = shard_ctx.constrain(v, "attn_kv")
    if freqs is not None and kv_source is None:  # no RoPE on cross-attn
        if cache is not None and pos is not None:
            qpos = jnp.asarray(pos)[None] + jnp.zeros((s,), jnp.int32)
        else:
            qpos = jnp.asarray(pos0) + jnp.arange(s)
        q = apply_rope(q, qpos, freqs)
        k = apply_rope(k, qpos, freqs)

    new_cache = None
    if cache is not None and pos is not None and kv_source is None:
        # self-attention decode: write the fresh KV, attend over the cache
        if "k_scale" in cache:  # int8-quantized cache (per-token scales)
            ks = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1) / 127.0
            vs = jnp.max(jnp.abs(v.astype(jnp.float32)), axis=-1) / 127.0
            kq = jnp.round(k.astype(jnp.float32)
                           / jnp.maximum(ks[..., None], 1e-8)).astype(jnp.int8)
            vq = jnp.round(v.astype(jnp.float32)
                           / jnp.maximum(vs[..., None], 1e-8)).astype(jnp.int8)
            kc = jax.lax.dynamic_update_slice(cache["k"], kq, (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], vq, (0, pos, 0, 0))
            ksc = jax.lax.dynamic_update_slice(
                cache["k_scale"], ks.astype(cache["k_scale"].dtype),
                (0, pos, 0))
            vsc = jax.lax.dynamic_update_slice(
                cache["v_scale"], vs.astype(cache["v_scale"].dtype),
                (0, pos, 0))
            new_cache = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
            kd = kc.astype(dt) * ksc[..., None].astype(dt)
            vd = vc.astype(dt) * vsc[..., None].astype(dt)
            out = attend_decode(q, kd, vd, pos, window=window)
        else:
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            new_cache = {"k": kc, "v": vc}
            out = attend_decode(q, kc, vc, pos, window=window)
    elif cache is not None:
        # cross-attention over a precomputed (full, static) memory cache
        t = cache["k"].shape[1]
        out = attend_decode(q, cache["k"], cache["v"], jnp.int32(t - 1))
        new_cache = cache
    else:
        out = attend_blockwise(q, k, v, causal=causal, window=window,
                               q_block=q_block)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache
