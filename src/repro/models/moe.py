"""Mixture-of-Experts with Switch/T5X-style capacity dispatch.

Top-k routing with a *static* per-group capacity so every shape is known
at trace time (a hard requirement for the multi-pod dry-run).  Tokens are
grouped per sequence; overflow tokens are dropped (standard capacity-
factor semantics) and their residual stream passes through unchanged.

Sharding (see ``repro.sharding.specs``): expert-parallel — experts dim on
the "model" mesh axis when ``E % model == 0`` (llama4-scout: 16e on 16) —
otherwise tensor-parallel inside each expert on the ffn dim (grok-1: 8e,
ffn 32768 = 2048/device).  With EP, XLA inserts the token all-to-all on
the dispatch/combine einsums automatically under pjit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import act_fn


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.expert_ff
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s,
        "wi": jax.random.normal(ks[1], (e, d, ff), jnp.float32) * s,
        "wo": jax.random.normal(ks[2], (e, ff, d), jnp.float32) * ff ** -0.5,
    }
    if cfg.act == "swiglu":
        p["wg"] = jax.random.normal(ks[3], (e, d, ff), jnp.float32) * s
    return p


def moe_capacity(cfg: ModelConfig, group_size: int) -> int:
    c = int(cfg.capacity_factor * group_size * cfg.top_k / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def apply_moe(params: dict, cfg: ModelConfig, x: jax.Array
              ) -> tuple[jax.Array, dict]:
    """x: (B, S, D) -> (out, aux) with load-balance/z losses in aux."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(cfg, s)
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(dt)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- top-k choice + position within expert (per group = per sequence)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (B, S, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # renorm top-k
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (B,S,k,E)
    # priority: earlier tokens (and lower k-slot) first, per sequence
    flat = onehot.reshape(b, s * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat)        # (B, S*k, E)
    pos_in_expert = pos_in_expert.reshape(b, s, k, e)
    within_cap = pos_in_expert < cap
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)           # (B, S, k)
    keep = jnp.sum(within_cap * onehot, axis=-1) > 0         # (B, S, k)

    # --- dispatch/combine tensors --------------------------------------
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)     # (B, S, k, C)
    disp = jnp.einsum("bske,bskc->bsec", onehot * keep[..., None], pos_oh)
    comb = jnp.einsum("bske,bskc,bsk->bsec",
                      onehot, pos_oh, gate_vals * keep)

    xe = jnp.einsum("bsd,bsec->becd", x, disp.astype(dt))    # (B, E, C, D)
    h = jnp.einsum("becd,edf->becf", xe, params["wi"].astype(dt))
    if "wg" in params:
        g = jnp.einsum("becd,edf->becf", xe, params["wg"].astype(dt))
        h = act_fn(cfg.act, h, g)
    else:
        h = act_fn(cfg.act, h)
    ye = jnp.einsum("becf,efd->becd", h, params["wo"].astype(dt))
    y = jnp.einsum("becd,bsec->bsd", ye, comb.astype(dt))

    # --- aux losses (Switch §2.2) ---------------------------------------
    me = jnp.mean(onehot[:, :, 0, :], axis=(0, 1))           # router top-1 frac
    ce = jnp.mean(probs, axis=(0, 1))
    aux = {
        "load_balance": e * jnp.sum(me * ce),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux
