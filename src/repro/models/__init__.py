"""LM model substrate for the 10 assigned architectures."""
