"""Sharding rule engine: param/activation/cache PartitionSpecs with
divisibility-aware fallbacks.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Policy (DESIGN.md §6):

* tensor-parallel ("model") on a semantic axis when it divides the mesh
  axis — attention heads, kv heads, ffn, experts, vocab;
* otherwise FSDP over "data": the weight is stored sharded on its largest
  data-divisible dim and all-gathered at use (XLA SPMD does this from the
  sharding alone).  This covers head counts like qwen's 40 or hymba's 25
  that don't divide a 16-wide model axis *without* padding the model;
* DP batch over ("pod","data") — cross-pod traffic is only the gradient
  all-reduce;
* KV caches: kv-heads on "model" when divisible, else the *sequence* dim
  (memory-balanced decode; XLA partitions the softmax reductions), else
  replicated.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def batch_spec_axis(mesh: Mesh, batch: int):
    """Largest dp prefix that divides the batch (pods first)."""
    axes = dp_axes(mesh)
    full = dp_size(mesh)
    if batch % full == 0:
        return axes if len(axes) > 1 else axes[0]
    if "data" in axes and batch % mesh.shape["data"] == 0:
        return "data"
    return None


def _fsdp_dim(shape, mesh: Mesh, skip: set[int]) -> int | None:
    d = _axis(mesh, "data")
    if d == 1:
        return None
    best = None
    for i, s in enumerate(shape):
        if i in skip or s % d != 0:
            continue
        if best is None or s > shape[best]:
            best = i
    return best


# Leaves at or above this many elements additionally shard over "data"
# (FSDP×TP hybrid) — per-layer all-gather cost is negligible vs their
# memory footprint; smaller leaves stay TP-only/replicated.
FSDP_THRESHOLD = 1 << 22


def _spec(shape, mesh: Mesh, tp_dim_candidates, *, layer_stacked: bool) -> P:
    """TP on the first candidate dim that divides "model"; large leaves
    are additionally FSDP-sharded over "data" on a free dim."""
    tp = _axis(mesh, "model")
    out = [None] * len(shape)
    skip = {0} if layer_stacked else set()
    placed_tp = False
    for dim in tp_dim_candidates:
        if dim < len(shape) and dim not in skip and shape[dim] % tp == 0 and tp > 1:
            out[dim] = "model"
            placed_tp = True
            break
    big = int(np.prod(shape)) >= FSDP_THRESHOLD
    if placed_tp and big:
        d = _axis(mesh, "data")
        for i, s in enumerate(shape):
            if i in skip or out[i] is not None:
                continue
            if d > 1 and s % d == 0 and s >= d:
                out[i] = "data"
                break
    if not placed_tp:
        f = _fsdp_dim(shape, mesh, skip)
        if f is not None:
            out[f] = "data"
    return P(*out)


def param_specs(cfg: ModelConfig, params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching a param pytree from ``init_model``."""

    def rule(path, leaf) -> P:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        stacked = "layers" in keys or "encoder" in keys
        off = 1 if stacked else 0
        shp = leaf.shape

        if name in ("scale",):                       # norms
            return P()
        if name == "tok":                            # (V, D)
            return _spec(shp, mesh, (0, 1), layer_stacked=False)
        if name == "head":                           # (D, V)
            return _spec(shp, mesh, (1,), layer_stacked=False)
        if name == "wq":                             # (L, D, H, dh)
            return _spec(shp, mesh, (off + 1,), layer_stacked=stacked)
        if name in ("wk", "wv"):                     # (L, D, KV, dh)
            return _spec(shp, mesh, (off + 1,), layer_stacked=stacked)
        if name == "wo" and len(shp) == off + 3:     # attn out (L, H, dh, D)
            return _spec(shp, mesh, (off + 0,), layer_stacked=stacked)
        if name in ("bq", "bk", "bv"):               # (L, H, dh)
            return _spec(shp, mesh, (off + 0,), layer_stacked=stacked)
        if name in ("wi", "wg") and len(shp) == off + 2:   # mlp (L, D, F)
            return _spec(shp, mesh, (off + 1,), layer_stacked=stacked)
        if name == "wo" and len(shp) == off + 2:     # mlp out (L, F, D)
            return _spec(shp, mesh, (off + 0,), layer_stacked=stacked)
        if name in ("wi", "wg") and len(shp) == off + 3:   # moe (L, E, D, F)
            return _spec(shp, mesh, (off + 0, off + 2), layer_stacked=stacked)
        if name == "wo" and len(shp) == off + 3 and "moe" in keys:
            return _spec(shp, mesh, (off + 0, off + 1), layer_stacked=stacked)
        if name == "router":
            return P()
        if name == "in_proj":                        # ssm (L, D, Z)
            return _spec(shp, mesh, (off + 1,), layer_stacked=stacked)
        if name in ("z_proj", "x_proj", "b_proj", "c_proj", "dt_proj"):
            return _spec(shp, mesh, (off + 1,), layer_stacked=stacked)
        if name == "out_proj":                       # ssm (L, di, D)
            return _spec(shp, mesh, (off + 0,), layer_stacked=stacked)
        if name in ("conv_w", "conv_b", "a_log", "dt_bias", "d_skip"):
            return P()
        return P()

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: dict) -> dict:
    out = {}
    for k, v in batch.items():
        bdim = batch_spec_axis(mesh, v.shape[0])
        out[k] = P(bdim, *([None] * (v.ndim - 1)))
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, cache: dict, batch: int) -> dict:
    tp = _axis(mesh, "model")
    bdim = batch_spec_axis(mesh, batch)
    out = {}
    for name, v in cache.items():
        if name in ("k", "v", "xk", "xv"):           # (L, B, T, KV, dh)
            _, _, t, kv, _ = v.shape
            if tp > 1 and kv % tp == 0:
                out[name] = P(None, bdim, None, "model", None)
            elif tp > 1 and t % tp == 0:
                out[name] = P(None, bdim, "model", None, None)
            else:
                out[name] = P(None, bdim, None, None, None)
        elif name in ("k_scale", "v_scale"):          # (L, B, T, KV)
            _, _, t, kv = v.shape
            if tp > 1 and kv % tp == 0:
                out[name] = P(None, bdim, None, "model")
            elif tp > 1 and t % tp == 0:
                out[name] = P(None, bdim, "model", None)
            else:
                out[name] = P(None, bdim, None, None)
        elif name == "ssm_h":                         # (L, B, H, N, P)
            h = v.shape[2]
            if tp > 1 and h % tp == 0:
                out[name] = P(None, bdim, "model", None, None)
            else:
                out[name] = P(None, bdim, None, None, None)
        elif name == "ssm_conv":                      # (L, B, K-1, C)
            c = v.shape[-1]
            if tp > 1 and c % tp == 0:
                out[name] = P(None, bdim, None, "model")
            else:
                out[name] = P(None, bdim, None, None)
        else:
            out[name] = P(*([None] * v.ndim))
    return out


# -- posterior query service (repro.serve) --------------------------------
# The engine's state tensor is (n_queries * chains_per_query, n_nodes):
# pure chain-lane parallelism, so the lane axis shards over the serve
# mesh's leading "batch" axis and every _color_update gather stays
# device-local.  The flat log-CPT bank is replicated by default (it is
# the gather operand — replication keeps the inner loop collective-free);
# banks at/above SERVE_CPT_SHARD_ELEMS shard over a trailing "model"
# axis instead, trading an all-gather at use for at-rest memory.
SERVE_CPT_SHARD_ELEMS = 1 << 22


def serve_batch_axis(mesh: Mesh) -> str:
    """The serve mesh axis carrying the chain-lane batch (leading axis)."""
    return mesh.axis_names[0]


def serve_state_spec(mesh: Mesh) -> P:
    """PartitionSpec of the (lanes, n_nodes) engine state / count tensors."""
    return P(serve_batch_axis(mesh), None)


def serve_mrf_state_spec(mesh: Mesh) -> P:
    """PartitionSpec of the (lanes, H, W) MRF label field.

    Served MRF groups shard the chain-lane axis exactly like BN groups
    — every lane holds a full grid, so the checkerboard update stays
    device-local (the 2D halo-exchange decomposition in
    ``repro.pgm.mesh_gibbs`` is the single-big-grid training tool, not
    the many-small-queries serving layout)."""
    return P(serve_batch_axis(mesh), None, None)


def serve_cpt_spec(mesh: Mesh, n_elems: int) -> P:
    """PartitionSpec of the flat log-CPT bank (1D, sentinel included)."""
    m = _axis(mesh, "model")
    if m > 1 and n_elems >= SERVE_CPT_SHARD_ELEMS and n_elems % m == 0:
        return P("model")
    return P()


# Sparse factor-graph state crosses this many sites before the site axis
# is worth sharding: below it (every BN and small-Ising group) the
# all-to-all a sharded neighbour gather implies costs more than the
# memory it saves; above it (million-spin graphs) a lane-replicated
# state tensor stops fitting comfortably and the XLA SPMD partitioner
# turns the plan gathers into collectives instead.
SERVE_SITE_SHARD_ELEMS = 1 << 20


def serve_fg_state_spec(mesh: Mesh, n_sites: int | None = None) -> P:
    """PartitionSpec of the (lanes, n_sites) sparse factor-graph state.

    Lane axis shards over the leading "batch" axis like every served
    family.  Irregular site counts additionally shard the site axis over
    a trailing "model" axis once they pass
    ``SERVE_SITE_SHARD_ELEMS`` (and divide evenly) — the million-spin
    regime, where chain-lane parallelism alone can't spread one graph's
    state across the mesh."""
    if n_sites is not None:
        m = _axis(mesh, "model")
        if m > 1 and n_sites >= SERVE_SITE_SHARD_ELEMS and n_sites % m == 0:
            return P(serve_batch_axis(mesh), "model")
    return P(serve_batch_axis(mesh), None)


def serve_lane_multiple(mesh: Mesh | None) -> int:
    """Lane-count divisibility the engine must pad micro-batches to."""
    return 1 if mesh is None else mesh.shape[serve_batch_axis(mesh)]


def zero_extend(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO: additionally shard optimizer state over "data" on a free dim."""
    d = _axis(mesh, "data")
    if d == 1 or "data" in spec:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, s in enumerate(shape):
        if parts[i] is None and s % d == 0 and s >= d:
            parts[i] = "data"
            return P(*parts)
    return spec


def named(mesh: Mesh, tree_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
