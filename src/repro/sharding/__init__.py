from repro.sharding.specs import (
    batch_spec_axis,
    batch_specs,
    cache_specs,
    dp_axes,
    dp_size,
    named,
    param_specs,
    zero_extend,
)

__all__ = [
    "batch_spec_axis", "batch_specs", "cache_specs", "dp_axes", "dp_size",
    "named", "param_specs", "zero_extend",
]
