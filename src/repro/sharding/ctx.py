"""Activation-sharding context: constraint injection without polluting
model signatures.

Builders set a spec map before tracing; the model calls
``constrain(x, "residual")`` at the layer-scan carry.  When no context is
active (single-device smoke tests) it is the identity.

The "residual" constraint implements Megatron-style sequence parallelism
for *storage*: the per-layer saved carries of the backward pass are
sharded over ("model" × seq), cutting saved-activation HBM by the TP
width; XLA inserts the all-gather before attention/MLP and the
reduce-scatter after, overlappable with compute on TPU.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar

import jax

_SPECS: ContextVar[dict | None] = ContextVar("act_specs", default=None)


@contextlib.contextmanager
def activation_specs(specs: dict):
    tok = _SPECS.set(specs)
    try:
        yield
    finally:
        _SPECS.reset(tok)


def constrain(x: jax.Array, name: str) -> jax.Array:
    specs = _SPECS.get()
    if specs is None or name not in specs or specs[name] is None:
        return x
    spec = specs[name]
    ndim = getattr(getattr(spec, "spec", spec), "__len__", lambda: 0)()
    if ndim > x.ndim:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):
        return x
