"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, llama-arch, code.  [arXiv:2405.04324; hf]

MQA: the single kv head is replicated across the model axis (57 MB/layer
— negligible); q heads 48 = 3·16 → tensor-parallel.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "granite-20b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_head=128,
        d_ff=24576, vocab=49152, act="swiglu",
        rope_theta=10_000.0, microbatch=4,
        supports_long=False,
        notes="MQA kv=1 (replicated kv projections).",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=1, d_head=32, d_ff=256,
        vocab=512, microbatch=0, dtype="float32")
