"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206, enc-dec, multimodal.  [arXiv:2308.11596; hf]

Per the assignment the speech frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, enc_seq_len, d_model) consumed
by the bidirectional encoder; the causal decoder cross-attends.  Enc-dec
(not encoder-only) → decode shapes RUN; long_500k skipped (full attn).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "seamless-m4t-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        n_layers=12, d_model=1024, n_heads=16, n_kv=16, d_head=64,
        d_ff=4096, vocab=256206, act="gelu",
        enc_layers=12, enc_seq_len=1024,
        rope_theta=10_000.0,
        supports_long=False,
        notes="enc-dec; stub speech frontend (precomputed frames).",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=4, d_head=32, d_ff=256,
        vocab=512, enc_layers=2, enc_seq_len=16, microbatch=0,
        dtype="float32")
