"""Model / run configuration schema for the 10 assigned architectures.

One frozen dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM /
audio families; family-specific fields default to "off".  Exact per-arch
values live in ``repro/configs/<id>.py``; every config also provides a
``smoke()`` reduction (same family, tiny dims) used by the CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeCfg:
    """One (input-shape × step-kind) cell of the assigned grid."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = (
    ShapeCfg("train_4k", 4_096, 256, "train"),
    ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    ShapeCfg("decode_32k", 32_768, 128, "decode"),
    ShapeCfg("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCfg:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "swiglu"          # swiglu | gelu | relu2
    attn_bias: bool = False
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0            # expert hidden dim (defaults to d_ff)
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    # split the fused in_proj into per-stream projections so each becomes
    # tensor-parallel where divisible (z/x: d_inner, B/C: G·N) instead of
    # one FSDP-gathered fused matrix (§Perf hillclimb)
    ssm_split_proj: bool = False
    # hybrid (hymba): sliding-window attn with periodic global layers
    sliding_window: int = 0      # 0 = full attention everywhere
    global_layer_every: int = 0  # every k-th layer is full-attention
    # enc-dec
    enc_layers: int = 0
    enc_seq_len: int = 0
    # multimodal stub frontend (precomputed patch/frame embeddings)
    frontend_tokens: int = 0
    # numerics / training
    dtype: str = "bfloat16"      # compute dtype
    cache_dtype: str = "bfloat16"  # KV cache: bfloat16 | int8 (quantized)
    param_dtype: str = "float32"  # storage dtype (bf16 for >=100B configs)
    accum_dtype: str = "float32"  # grad-accumulation dtype
    optimizer: str = "adamw"     # adamw | adamw_bf16 | adafactor
    remat: str = "full"          # full | dots | none
    microbatch: int = 0          # 0 = no gradient accumulation
    # applicability notes (DESIGN.md §4)
    supports_long: bool = False  # sub-quadratic — long_500k runs
    notes: str = ""

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or max(self.d_inner // self.ssm_head_dim, 1)

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- parameter count (for MODEL_FLOPS = 6·N·D roofline term) ---------
    def param_count(self, active_only: bool = False) -> int:
        d, h, kv, dh, ff, v = (self.d_model, self.n_heads, self.n_kv,
                               self.d_head, self.d_ff, self.vocab)
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.attn_bias:
            attn += (h + 2 * kv) * dh
        if self.act == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.n_experts:
            eff = self.expert_ff
            per_expert = 3 * d * eff if self.act == "swiglu" else 2 * d * eff
            n_exp = self.top_k if active_only else self.n_experts
            mlp = per_expert * n_exp + d * self.n_experts  # + router
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, g, n, hh = self.d_inner, self.ssm_groups, self.ssm_state, self.n_ssm_heads
            ssm = d * (2 * di + 2 * g * n + hh) + di * d + di * self.ssm_conv + 2 * hh
        per_layer = mlp + 2 * d
        if self.family == "ssm":
            per_layer = ssm + 2 * d
        elif self.family == "hybrid":
            per_layer = attn + ssm + mlp + 3 * d
        else:
            per_layer += attn
        total = self.n_layers * per_layer + v * d + d
        if not self.tie_embeddings:
            total += d * v
        if self.family == "encdec":
            enc_per = attn + mlp + 2 * d
            cross = d * h * dh + 2 * d * kv * dh + h * dh * d
            total += self.enc_layers * enc_per + self.n_layers * cross
        return int(total)
