"""The paper's own workload configs (AIA chip benchmarks, Fig. 7).

Selectable via ``--arch aia-mrf-penguin`` etc. in ``launch/run_mcmc.py``.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class MCMCConfig:
    name: str
    kind: str              # "mrf" | "bayesnet"
    # mrf
    height: int = 0
    width: int = 0
    n_labels: int = 0
    beta: float = 2.0
    tau: int = 4
    pairwise: str = "potts"     # potts | truncated_linear
    # bayesnet
    network: str = ""           # asia | sprinkler | child_scale | ...
    # common
    n_chains: int = 16
    n_sweeps: int = 1000
    burn_in: int = 200
    k: int = 14                 # fixed-point weight precision
    use_iu: bool = True


PENGUIN = MCMCConfig(
    name="aia-mrf-penguin", kind="mrf", height=500, width=333, n_labels=2,
    beta=2.0, pairwise="potts")

ART = MCMCConfig(
    name="aia-mrf-art", kind="mrf", height=288, width=384, n_labels=16,
    beta=1.0, tau=4, pairwise="truncated_linear")

BAYESNETS = {
    "aia-bn-asia": MCMCConfig(name="aia-bn-asia", kind="bayesnet",
                              network="asia", n_chains=256),
    "aia-bn-child": MCMCConfig(name="aia-bn-child", kind="bayesnet",
                               network="child_scale", n_chains=256),
    "aia-bn-alarm": MCMCConfig(name="aia-bn-alarm", kind="bayesnet",
                               network="alarm_scale", n_chains=256),
    "aia-bn-hailfinder": MCMCConfig(name="aia-bn-hailfinder", kind="bayesnet",
                                    network="hailfinder_scale", n_chains=128),
}

MCMC_CONFIGS = {PENGUIN.name: PENGUIN, ART.name: ART, **BAYESNETS}
