"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE SwiGLU GQA, tied embeddings.  [arXiv:2412.08905; hf]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "phi4-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv=8, d_head=128,
        d_ff=8192, vocab=200064, act="swiglu", tie_embeddings=True,
        rope_theta=10_000.0, microbatch=2,
        supports_long=False,
        notes="tied embeddings; heads=24 -> FSDP attention fallback.",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=2, d_head=32, d_ff=256,
        vocab=512, microbatch=0, dtype="float32")
