"""qwen1.5-32b [dense] — 64L d_model=5120 40H (MHA kv=40) d_ff=27392
vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]

Sharding note: 40 heads do not divide the 16-wide model axis → attention
weights fall back to FSDP-over-data (DESIGN.md §6); d_ff 27392 = 16·1712
keeps the MLP tensor-parallel.  long_500k skipped (pure full attention).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen1.5-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_head=128,
        d_ff=27392, vocab=152064, act="swiglu", attn_bias=True,
        rope_theta=1_000_000.0, microbatch=8, optimizer="adamw_bf16",
        cache_dtype="int8",  # §Perf A: 1.94x decode memory-term win
        supports_long=False,
        notes="MHA with QKV bias; heads=40 -> FSDP attention fallback.",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=4, d_head=32, d_ff=256,
        vocab=512, microbatch=0, dtype="float32")
