"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]

Per the assignment the ViT frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, frontend_tokens, d_model) which are
early-fused into the first positions of the sequence.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "pixtral-12b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_head=128,
        d_ff=14336, vocab=131072, act="swiglu",
        rope_theta=1_000_000.0, frontend_tokens=256, microbatch=4,
        supports_long=False,
        notes="stub ViT frontend (precomputed patch embeddings).",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=2, d_head=32, d_ff=256,
        vocab=512, frontend_tokens=8, microbatch=0, dtype="float32")
