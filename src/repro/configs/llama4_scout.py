"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

16 experts = model axis width → clean expert parallelism (one expert per
model rank); heads=40 → FSDP attention fallback.  long_500k skipped
(full attention modeled; iRoPE chunked attention not modeled — noted in
DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

ARCH_ID = "llama4-scout-17b-a16e"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_head=128,
        d_ff=8192, vocab=202048, act="swiglu",
        n_experts=16, top_k=1, capacity_factor=1.25, moe_d_ff=8192,
        rope_theta=500_000.0, microbatch=8,
        supports_long=False,
        notes="EP 16e/16 ranks; top-1 routing (Switch-style).",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=2, d_head=32, d_ff=256,
        vocab=512, n_experts=4, top_k=1, moe_d_ff=128, microbatch=0,
        dtype="float32")
