"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU.  [arXiv:2402.16819; unverified]

Memory note: 340B params cannot hold fp32 Adam moments at 256 chips
(21 GB/chip) — config pins Adafactor (factored second moment), the
standard ≥100B choice.  96 heads = 6·16 → fully tensor-parallel attention.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "nemotron-4-340b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv=8, d_head=192,
        d_ff=73728, vocab=256000, act="relu2",
        rope_theta=10_000.0, microbatch=16, optimizer="adafactor",
        param_dtype="bfloat16", accum_dtype="bfloat16", cache_dtype="int8",
        supports_long=False,
        notes="squared-ReLU MLP; GQA kv=8; Adafactor for state fit.",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv=2, d_head=16, d_ff=512,
        vocab=512, microbatch=0, dtype="float32")
