"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2, logit softcap 30.
[hf:xai-org/grok-1; unverified]

8 experts do not divide the 16-wide model axis → tensor parallelism
*inside* each expert on the ffn dim (32768 = 16·2048) instead of EP.
bf16 Adam moments keep optimizer state at 256 chips under HBM.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "grok-1-314b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv=8, d_head=128,
        d_ff=32768, vocab=131072, act="gelu",
        n_experts=8, top_k=2, capacity_factor=1.25, moe_d_ff=32768,
        logit_softcap=30.0, rope_theta=10_000.0, microbatch=16,
        optimizer="adafactor", param_dtype="bfloat16", accum_dtype="bfloat16",
        supports_long=False,
        notes="8e top-2; TP-inside-expert (E%16!=0); adafactor.",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=2, d_head=32, d_ff=256,
        vocab=512, n_experts=2, top_k=2, moe_d_ff=128, microbatch=0,
        dtype="float32")
