"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attn+mamba heads.
[arXiv:2411.13676; hf]

Hybrid block: attention and SSM heads read the same normed input in
parallel, outputs averaged (the Hymba recipe).  Sliding-window attention
everywhere except every 16th layer + the last (global) — with the SSM
state carrying long-range context, long_500k RUNS for this arch.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv=5, d_head=64,
        d_ff=5504, vocab=32001, act="swiglu",
        ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
        sliding_window=1024, global_layer_every=16, ssm_chunk=128, microbatch=2,  # §Perf C: fused_mb2 winner
        rope_theta=10_000.0,
        supports_long=True,
        notes="parallel attn+SSM heads; SWA + periodic global layers.",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv=2, d_head=32, d_ff=256,
        vocab=512, ssm_state=16, ssm_head_dim=32, sliding_window=8,
        global_layer_every=2, microbatch=0, dtype="float32")
