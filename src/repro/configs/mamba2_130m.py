"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060; unverified]

Attention-free: the paper's KY sampler still applies (token sampling),
but attention-sharding rules are vacuous (DESIGN.md §4).  O(1) decode
state → long_500k RUNS.  d_inner=1536, 24 SSD heads of dim 64.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "mamba2-130m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv=0, d_head=0,
        d_ff=0, vocab=50280, tie_embeddings=True,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
        ssm_chunk=128, microbatch=8,
        supports_long=True,
        notes="attention-free SSD; O(1) decode state.",
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, vocab=512, ssm_state=16, ssm_head_dim=32,
        microbatch=0, dtype="float32")
