"""Config registry: ``--arch <id>`` resolution + dry-run input specs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import (
    granite_20b,
    grok1_314b,
    hymba_1_5b,
    llama4_scout,
    mamba2_130m,
    nemotron4_340b,
    phi4_mini,
    pixtral_12b,
    qwen15_32b,
    seamless_m4t_medium,
)
from repro.configs.aia_paper import MCMC_CONFIGS
from repro.configs.base import SHAPES, ModelConfig, ShapeCfg, shape_by_name

_MODULES = (
    qwen15_32b, nemotron4_340b, phi4_mini, granite_20b, pixtral_12b,
    hymba_1_5b, llama4_scout, grok1_314b, seamless_m4t_medium, mamba2_130m,
)

ARCHS = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS = tuple(ARCHS.keys())


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    m = ARCHS[arch_id]
    return m.smoke() if smoke else m.config()


def cell_runnable(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Whether an (arch × shape) cell runs, per DESIGN.md §4."""
    if shape.name == "long_500k" and not cfg.supports_long:
        return False, "long_500k skipped: pure full-attention arch"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train/prefill: token batches (+ stub frontend embeddings);
    decode: last token + position (cache specs come from ``init_cache``
    via ``jax.eval_shape`` in the launcher).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        out = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
    elif shape.kind == "prefill":
        out = {"tokens": sds((b, s), i32)}
    else:  # decode: one new token against a seq_len cache
        out = {"tokens": sds((b, 1), i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        out["frontend"] = sds((b, cfg.frontend_tokens, cfg.d_model), f32)
    if cfg.family in ("encdec", "audio"):
        out["src_embeds"] = sds((b, cfg.enc_seq_len, cfg.d_model), f32)
    return out


__all__ = [
    "ARCHS", "ARCH_IDS", "MCMC_CONFIGS", "SHAPES", "ModelConfig", "ShapeCfg",
    "cell_runnable", "get_config", "input_specs", "shape_by_name",
]
