"""LM serving with the KY token sampler (the paper's technique as a
first-class decode feature): KY vs categorical vs greedy on a smoke
model — tokens/s and random-bit economy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.configs import get_config
from repro.models.sampling import generate
from repro.models.transformer import init_model


def main(report=print):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jnp.ones((8, 8), jnp.int32)
    max_new = 32
    for sampler in ("ky", "categorical", "greedy"):
        fn = jax.jit(lambda p, pr, k: generate(
            p, cfg, pr, k, max_new=max_new, sampler=sampler, q_block=8),
            static_argnames=())
        dt = time_call(fn, params, prompt, jax.random.PRNGKey(1),
                       warmup=1, iters=3)
        toks, bits = fn(params, prompt, jax.random.PRNGKey(1))
        n = prompt.shape[0] * max_new
        extra = (f";bits/token={int(bits)/n:.2f}" if sampler == "ky" else "")
        report(row(f"lm_decode_{sampler}", dt / n * 1e6,
                   f"tok/s={n/dt:.0f}{extra}"))


if __name__ == "__main__":
    main()
