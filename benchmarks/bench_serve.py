"""Posterior query service under synthetic traffic (repro.serve).

Measures what a serving stack cares about: queries/s and MSample/s for a
cold plan cache (compiler chain + XLA compile on the critical path) vs a
warm one (pure sampling), plus the cache hit rate.  Traffic cycles a
small set of evidence patterns, as repeat sensor traffic does — the
regime the (network, evidence-pattern) plan cache is designed for.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.pgm import networks
from repro.serve.cli import synthetic_traffic
from repro.serve.engine import PosteriorEngine


def _pass(engine, traffic):
    t0 = time.perf_counter()
    results = engine.answer_batch(traffic)
    dt = time.perf_counter() - t0
    samples = sum(r.n_node_samples for r in results)
    return dt, samples, results


def run(name, network, *, n_queries=32, n_patterns=3, budget=2048,
        chains=16, report=print):
    bn = getattr(networks, network)()
    traffic = synthetic_traffic(
        bn, network, n_queries, n_patterns, np.random.default_rng(0), budget)
    engine = PosteriorEngine({network: bn}, chains_per_query=chains,
                             burn_in=32)
    cold_dt, cold_samples, _ = _pass(engine, traffic)
    warm_dt, warm_samples, results = _pass(engine, traffic)
    conv = sum(r.converged for r in results)
    s = engine.cache.stats
    report(row(
        f"serve_{name}_cold", cold_dt / n_queries * 1e6,
        f"qps={n_queries/cold_dt:.2f};MSample/s={cold_samples/cold_dt/1e6:.3f}"))
    report(row(
        f"serve_{name}_warm", warm_dt / n_queries * 1e6,
        f"qps={n_queries/warm_dt:.2f};MSample/s={warm_samples/warm_dt/1e6:.3f};"
        f"speedup={cold_dt/warm_dt:.1f}x;hit_rate={s.hit_rate:.2f};"
        f"converged={conv}/{n_queries}"))


def main(report=print):
    run("asia_8n", "asia", report=report)
    run("child_scale_20n", "child_scale", n_queries=16, report=report)


if __name__ == "__main__":
    main()
