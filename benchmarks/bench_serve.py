"""Posterior query service under synthetic traffic (repro.serve).

Measures what a serving stack cares about: queries/s and MSample/s for a
cold plan cache (compiler chain + XLA compile on the critical path) vs a
warm one (pure sampling), plus bits/sample and the cache hit rate.
Traffic cycles a small set of evidence patterns, as repeat sensor
traffic does — the regime the (network, evidence-pattern) plan cache is
designed for.  Both served families are covered: Bayesian networks
(:func:`run`) and masked MRF grids — scribble pixel-mask evidence —
(:func:`run_mrf`, which also checks queued-vs-``answer_batch``
bit-identity for the MRF path).

Invocation forms:

  PYTHONPATH=src:. python -m benchmarks.bench_serve                # CSV rows
  PYTHONPATH=src:. python -m benchmarks.bench_serve --smoke --stream \\
      --json BENCH_serve.json                                      # CI smoke
  PYTHONPATH=src:. python -m benchmarks.bench_serve \\
      --force-host-devices 4 --mesh-shape 4                        # sharded
  PYTHONPATH=src:. python -m benchmarks.bench_serve --scaling 1,2,4,8 \\
      --json BENCH_serve.json                  # device-scaling subprocesses

Every report also carries a ``map`` section (annealed MAP/MPE queries/s
under assignment-stability retirement, :func:`run_map`) and a
``filtering`` section (temporal dynamic-BN filtering: per-slice latency
of warm-started streaming-sensor slices vs cold re-solves, with the
per-slice plan-cache hit rate the gate holds at 100% after slice 0,
:func:`run_filtering`) — see ``docs/inference_modes.md``.

``--stream`` adds the open-loop streaming benchmark: traffic arrives at
a fixed rate (default 4x the measured synchronous rate), is served
through the admission queue (:mod:`repro.serve.queue`), and reported as
queries/s plus p50/p99 latency against a one-query-at-a-time
synchronous baseline — with a bitwise identity check of queued vs
``answer_batch`` results for the same traffic.

``--json`` emits a machine-readable report (queries/s, MSample/s,
**ESS/s** — effective samples per second, the honest analogue of the
paper's MSample/s — bits/sample, cold/warm, stream metrics, and — with
``--scaling`` — per-device-count throughput from forced-host
subprocesses) so CI can track the perf trajectory;
``benchmarks/check_serve_regression.py`` gates CI on it against
``benchmarks/baselines/BENCH_serve.json``.  The report carries the
engine's ``retirement`` mode so the gate can refuse to compare a
rank-mode run against a legacy-mode baseline.  ``-`` writes it to
stdout.

``--overload`` adds the scale-out front-end overload benchmark
(:func:`run_overload`): a fresh HTTP server (``repro.serve.server``)
first proves served results **bitwise identical** to an in-process
``answer_batch`` on the same seed, then its sustained closed-loop
capacity is measured, and traffic is offered open-loop at 2x that
capacity against a per-tenant token-bucket quota set to capacity — the
report carries served p50/p99 latency and the shed rate (429/503), and
``check_serve_regression`` holds the shed-rate floor plus a bounded
p99 (shedding at the front door instead of queue collapse).

``--diagnostics-json`` additionally runs the same traffic under both
retirement rules (``legacy`` plain split-R̂ vs ``rank`` rank-R̂ + ESS)
and writes a ``BENCH_diagnostics.json`` artifact with per-mode
sweeps-to-retirement and ESS/s — the latency/statistical-quality
trade-off the diagnostics subsystem exists to expose.

Telemetry (``repro.serve.telemetry``, see ``docs/observability.md``):
the ``--stream`` run records with a live recorder, so its report
section carries a ``latency_breakdown`` (wait/plan/service from the
lifecycle spans) and a metrics-registry snapshot; ``--trace-out`` /
``--metrics-json`` write the Perfetto trace and ``engine.stats()``
snapshot as CI artifacts.  Every report also carries a
``telemetry_overhead`` section (null vs live recorder ESS/s on
identical traffic, self-relative) which
``benchmarks/check_serve_regression.py`` gates at ≤ 5%.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import row

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _pass(engine, traffic):
    t0 = time.perf_counter()
    results = engine.answer_batch(traffic)
    dt = time.perf_counter() - t0
    samples = sum(r.n_node_samples for r in results)
    return dt, samples, results


def _ess(results) -> float:
    """Total worst-case ESS over a pass (see repro.serve.cli.ess_total)."""
    from repro.serve.cli import ess_total
    return ess_total(results)


def run(name, network, *, n_queries=32, n_patterns=3, budget=2048,
        chains=16, mesh=None, report=print):
    """Cold + warm pass over one network's traffic; returns metrics."""
    from repro.pgm import networks
    from repro.serve.cli import synthetic_traffic
    from repro.serve.engine import PosteriorEngine

    bn = getattr(networks, network)()
    traffic = synthetic_traffic(
        bn, network, n_queries, n_patterns, np.random.default_rng(0), budget)
    engine = PosteriorEngine({network: bn}, chains_per_query=chains,
                             burn_in=32, mesh=mesh)
    cold_dt, cold_samples, cold_results = _pass(engine, traffic)
    warm_dt, warm_samples, results = _pass(engine, traffic)
    conv = sum(r.converged for r in results)
    bits = float(np.mean([r.bits_per_sample for r in results]))
    s = engine.cache.stats
    report(row(
        f"serve_{name}_cold", cold_dt / n_queries * 1e6,
        f"qps={n_queries/cold_dt:.2f};MSample/s={cold_samples/cold_dt/1e6:.3f}"))
    report(row(
        f"serve_{name}_warm", warm_dt / n_queries * 1e6,
        f"qps={n_queries/warm_dt:.2f};MSample/s={warm_samples/warm_dt/1e6:.3f};"
        f"ESS/s={_ess(results)/warm_dt:.1f};"
        f"speedup={cold_dt/warm_dt:.1f}x;hit_rate={s.hit_rate:.2f};"
        f"converged={conv}/{n_queries}"))
    return {
        "name": name,
        "network": network,
        "n_queries": n_queries,
        "retirement": engine.retirement,
        "cold": {"wall_s": cold_dt, "queries_per_s": n_queries / cold_dt,
                 "msample_per_s": cold_samples / cold_dt / 1e6,
                 "ess_per_s": _ess(cold_results) / cold_dt},
        "warm": {"wall_s": warm_dt, "queries_per_s": n_queries / warm_dt,
                 "msample_per_s": warm_samples / warm_dt / 1e6,
                 "ess_per_s": _ess(results) / warm_dt},
        "bits_per_sample": bits,
        "cache_hit_rate": s.hit_rate,
        "converged": conv,
    }


def _identical(a, b) -> bool:
    return (a.n_samples == b.n_samples
            and (a.rhat == b.rhat
                 or (np.isnan(a.rhat) and np.isnan(b.rhat)))
            and set(a.marginals) == set(b.marginals)
            and all(np.array_equal(a.marginals[k], b.marginals[k])
                    for k in a.marginals)
            and a.map_assignment == b.map_assignment
            and a.map_energy == b.map_energy)


def run_mrf(name, *, h=16, w=16, n_queries=12, n_patterns=2, budget=1024,
            chains=8, mesh=None, report=print):
    """Masked-MRF serving benchmark: cold + warm qps for scribble-mask
    traffic over a Potts grid, plus the queued-vs-``answer_batch``
    identity bit — the pixel-evidence twin of :func:`run`."""
    from repro.pgm.networks import penguin_task
    from repro.serve.cli import synthetic_mrf_traffic
    from repro.serve.engine import PosteriorEngine
    from repro.serve.queue import AdmissionQueue

    network = "mrf_penguin"
    mrf, _ = penguin_task(h=h, w=w)
    traffic = synthetic_mrf_traffic(
        mrf, network, n_queries, n_patterns, np.random.default_rng(0), budget)
    kw = dict(chains_per_query=chains, burn_in=32, mesh=mesh)
    engine = PosteriorEngine({network: mrf}, **kw)
    cold_dt, cold_samples, cold_results = _pass(engine, traffic)
    warm_dt, warm_samples, results = _pass(engine, traffic)
    conv = sum(r.converged for r in results)
    bits = float(np.mean([r.bits_per_sample for r in results]))
    s = engine.cache.stats

    # identity: same traffic, same seeds -> queued == caller-batched
    eng_a = PosteriorEngine({network: mrf}, **kw, seed=7)
    ref = eng_a.answer_batch(traffic)
    eng_b = PosteriorEngine({network: mrf}, **kw, seed=7)
    queue_b = AdmissionQueue(eng_b, max_wait_ms=3_600_000.0,
                             max_group_lanes=n_queries * chains)
    try:
        handles = [queue_b.submit(q) for q in traffic]
        queue_b.flush()
        streamed = [hd.result(timeout=600) for hd in handles]
    finally:
        queue_b.close()
    identical = all(_identical(a, b) for a, b in zip(ref, streamed))

    report(row(
        f"serve_{name}_cold", cold_dt / n_queries * 1e6,
        f"qps={n_queries/cold_dt:.2f};MSample/s={cold_samples/cold_dt/1e6:.3f}"))
    report(row(
        f"serve_{name}_warm", warm_dt / n_queries * 1e6,
        f"qps={n_queries/warm_dt:.2f};MSample/s={warm_samples/warm_dt/1e6:.3f};"
        f"ESS/s={_ess(results)/warm_dt:.1f};"
        f"speedup={cold_dt/warm_dt:.1f}x;hit_rate={s.hit_rate:.2f};"
        f"converged={conv}/{n_queries};identical={identical}"))
    return {
        "name": name,
        "network": network,
        "grid": [h, w],
        "n_queries": n_queries,
        "retirement": engine.retirement,
        "cold": {"wall_s": cold_dt, "queries_per_s": n_queries / cold_dt,
                 "msample_per_s": cold_samples / cold_dt / 1e6,
                 "ess_per_s": _ess(cold_results) / cold_dt},
        "warm": {"wall_s": warm_dt, "queries_per_s": n_queries / warm_dt,
                 "msample_per_s": warm_samples / warm_dt / 1e6,
                 "ess_per_s": _ess(results) / warm_dt},
        "bits_per_sample": bits,
        "cache_hit_rate": s.hit_rate,
        "converged": conv,
        "identical": bool(identical),
    }


def run_ising(name, *, side=16, beta=0.35, n_queries=12, n_patterns=2,
              budget=1024, chains=8, mesh=None, report=print):
    """Sparse-Ising serving benchmark: cold + warm qps for spin-clamp
    traffic over a 2D-torus ferromagnet, plus the queued-vs-
    ``answer_batch`` identity bit — the sparse-graph twin of
    :func:`run_mrf`."""
    from repro.pgm.networks import ising_torus
    from repro.serve.cli import synthetic_ising_traffic
    from repro.serve.engine import PosteriorEngine
    from repro.serve.queue import AdmissionQueue

    network = "ising_torus"
    model = ising_torus(side, beta=beta)
    traffic = synthetic_ising_traffic(
        model, network, n_queries, n_patterns, np.random.default_rng(0),
        budget)
    kw = dict(chains_per_query=chains, burn_in=32, mesh=mesh)
    engine = PosteriorEngine({network: model}, **kw)
    cold_dt, cold_samples, cold_results = _pass(engine, traffic)
    warm_dt, warm_samples, results = _pass(engine, traffic)
    conv = sum(r.converged for r in results)
    bits = float(np.mean([r.bits_per_sample for r in results]))
    s = engine.cache.stats

    # identity: same traffic, same seeds -> queued == caller-batched
    eng_a = PosteriorEngine({network: model}, **kw, seed=7)
    ref = eng_a.answer_batch(traffic)
    eng_b = PosteriorEngine({network: model}, **kw, seed=7)
    queue_b = AdmissionQueue(eng_b, max_wait_ms=3_600_000.0,
                             max_group_lanes=n_queries * chains)
    try:
        handles = [queue_b.submit(q) for q in traffic]
        queue_b.flush()
        streamed = [hd.result(timeout=600) for hd in handles]
    finally:
        queue_b.close()
    identical = all(_identical(a, b) for a, b in zip(ref, streamed))

    report(row(
        f"serve_{name}_cold", cold_dt / n_queries * 1e6,
        f"qps={n_queries/cold_dt:.2f};MSample/s={cold_samples/cold_dt/1e6:.3f}"))
    report(row(
        f"serve_{name}_warm", warm_dt / n_queries * 1e6,
        f"qps={n_queries/warm_dt:.2f};MSample/s={warm_samples/warm_dt/1e6:.3f};"
        f"ESS/s={_ess(results)/warm_dt:.1f};"
        f"speedup={cold_dt/warm_dt:.1f}x;hit_rate={s.hit_rate:.2f};"
        f"converged={conv}/{n_queries};identical={identical}"))
    return {
        "name": name,
        "network": network,
        "side": side,
        "n_queries": n_queries,
        "retirement": engine.retirement,
        "cold": {"wall_s": cold_dt, "queries_per_s": n_queries / cold_dt,
                 "msample_per_s": cold_samples / cold_dt / 1e6,
                 "ess_per_s": _ess(cold_results) / cold_dt},
        "warm": {"wall_s": warm_dt, "queries_per_s": n_queries / warm_dt,
                 "msample_per_s": warm_samples / warm_dt / 1e6,
                 "ess_per_s": _ess(results) / warm_dt},
        "bits_per_sample": bits,
        "cache_hit_rate": s.hit_rate,
        "converged": conv,
        "identical": bool(identical),
    }


def run_million_spin(*, side=1024, beta=0.3, chains=2, sweeps=4,
                     report=print):
    """Million-spin capacity datapoint (weekly CI, not the push gate):
    compile a ``side x side`` torus (~``side**2`` spins) through the
    sparse chain — parallel MIS coloring + degree-bucketed plans — and
    measure compile wall plus steady-state spin-updates/s of the fused
    sweep.  Returns a JSON-able dict; correctness is covered by the
    tier-1 Onsager test, this row tracks *scale*."""
    import time as _time

    import jax

    from repro.pgm.networks import ising_torus
    from repro.pgm.sparse_compile import (
        compile_factor_graph, init_fg_states, make_fg_sweep)

    model = ising_torus(side, beta=beta)
    t0 = _time.perf_counter()
    prog = compile_factor_graph(model)
    compile_s = _time.perf_counter() - t0

    sweep = make_fg_sweep(prog)
    key = jax.random.PRNGKey(0)
    x = init_fg_states(key, prog, chains)
    # one warm-up sweep pays the jit; then time the steady state
    x, _ = sweep(key, x)
    x.block_until_ready()
    t0 = _time.perf_counter()
    for i in range(sweeps):
        key, sub = jax.random.split(key)
        x, _ = sweep(sub, x)
    x.block_until_ready()
    sweep_s = (_time.perf_counter() - t0) / sweeps
    updates_per_s = chains * model.n / sweep_s
    report(row("serve_million_spin_sweep", sweep_s * 1e6,
               f"spins={model.n};colors={prog.n_colors};chains={chains};"
               f"compile_s={compile_s:.1f};"
               f"Mupdates/s={updates_per_s/1e6:.2f}"))
    return {
        "side": side,
        "n_spins": int(model.n),
        "n_colors": int(prog.n_colors),
        "chains": chains,
        "compile_s": compile_s,
        "sweep_s": sweep_s,
        "mupdates_per_s": updates_per_s / 1e6,
    }


def run_stream(name, network, *, n_queries=32, n_patterns=2, budget=2048,
               chains=16, rate_qps=0.0, max_wait_ms=250.0, mesh=None,
               trace_out="", metrics_out="", report=print):
    """Open-loop streaming benchmark: queued admission vs one-query-at-a-
    time synchronous serving over the same traffic, plus a bitwise
    identity check of queued vs ``answer_batch`` results.

    The queued engine runs with a live telemetry recorder, so the
    returned metrics carry a ``latency_breakdown`` (wait / plan /
    service from the lifecycle spans) and a ``metrics`` registry
    snapshot; ``trace_out`` / ``metrics_out`` additionally write the
    Perfetto trace and the ``engine.stats()`` snapshot as artifacts.
    The synchronous baseline engine stays on the no-op recorder so the
    speedup denominator is a telemetry-free number."""
    from repro.pgm import networks
    from repro.serve.cli import measure_stream, synthetic_traffic
    from repro.serve.engine import PosteriorEngine
    from repro.serve.queue import AdmissionQueue
    from repro.serve.telemetry import Telemetry

    bn = getattr(networks, network)()
    traffic = synthetic_traffic(
        bn, network, n_queries, n_patterns, np.random.default_rng(0), budget)
    kw = dict(chains_per_query=chains, burn_in=32, mesh=mesh)

    # shared protocol (repro.serve.cli.measure_stream): sync baseline +
    # open-loop queued replay.  The 8x multiplier keeps the admission
    # window full — far above what one-at-a-time serving sustains, which
    # is the regime the queue exists for (machine-relative, CI-stable).
    stream_engine = PosteriorEngine({network: bn}, **kw,
                                    telemetry=Telemetry())
    metrics, _ = measure_stream(
        stream_engine,
        PosteriorEngine({network: bn}, **kw),
        traffic, rate_qps=rate_qps, rate_multiplier=8.0,
        max_wait_ms=max_wait_ms)

    # identity: same traffic, same seeds -> queued == caller-batched, bitwise
    eng_a = PosteriorEngine({network: bn}, **kw, seed=7)
    ref = eng_a.answer_batch(traffic)
    eng_b = PosteriorEngine({network: bn}, **kw, seed=7)
    queue_b = AdmissionQueue(eng_b, max_wait_ms=3_600_000.0,
                             max_group_lanes=n_queries * chains)
    try:
        handles = [queue_b.submit(q) for q in traffic]
        queue_b.flush()
        streamed = [h.result(timeout=600) for h in handles]
    finally:
        queue_b.close()
    identical = all(_identical(a, b) for a, b in zip(ref, streamed))

    bd = metrics.get("latency_breakdown", {})
    report(row(
        f"serve_{name}_stream",
        1e6 / max(metrics["queries_per_s"], 1e-9),
        f"qps={metrics['queries_per_s']:.2f};"
        f"sync_qps={metrics['sync_queries_per_s']:.2f};"
        f"speedup={metrics['speedup']:.2f}x;"
        f"MSample/s={metrics['msample_per_s']:.3f};"
        f"ESS/s={metrics['ess_per_s']:.1f};"
        f"p50_ms={metrics['p50_ms']:.1f};p99_ms={metrics['p99_ms']:.1f};"
        + "".join(f"{p}_p50_ms={bd[p]['p50_ms']:.1f};"
                  for p in ("wait", "plan", "service") if p in bd)
        + f"groups={metrics['dispatched_groups']};"
        f"backfilled={metrics['backfilled']};identical={identical}"))
    if trace_out:
        stream_engine.telemetry.write_trace(trace_out)
        report(f"# wrote {trace_out}")
    if metrics_out:
        with open(metrics_out, "w") as f:
            json.dump(stream_engine.stats(), f, indent=2)
        report(f"# wrote {metrics_out}")
    return {"name": name, "network": network,
            "retirement": stream_engine.retirement,
            **{k: v for k, v in metrics.items() if k != "submitted"},
            "metrics": stream_engine.telemetry.metrics_snapshot(),
            "identical": bool(identical)}


def run_overload(network="asia", *, n_queries=6, n_patterns=2, budget=256,
                 chains=8, overload_factor=2.0, capacity_passes=3,
                 n_offered=None, report=print):
    """Scale-out front-end overload benchmark (SLO serving under 2x
    offered load) — three phases against one HTTP server process:

    1. **identity** — a fresh single-worker server serves the traffic
       via ``/v2/batch``; marginals must come back bitwise identical to
       a fresh in-process ``answer_batch`` on the same seed (floats
       survive JSON exactly; the engine PRNG advances with traffic, so
       only the *first* batch on a fresh server can be compared);
    2. **capacity** — closed-loop sequential serving over the now-warm
       plans measures the sustained queries/s one worker holds;
    3. **overload** — a second front end over the same warm pool gets a
       per-tenant token bucket at exactly that capacity (small burst)
       and is offered open-loop traffic at ``overload_factor`` times
       capacity.  Over-quota requests shed with 429 (+ Retry-After)
       at the front door, so the admitted subset keeps bounded latency
       instead of every caller timing out in a collapsing queue.

    Reported: capacity/offered qps, shed rate, served p50/p99 ms and
    ``mean_service_ms`` (1000/capacity) — the self-relative yardstick
    ``check_serve_regression`` holds p99 against."""
    import threading

    from repro.pgm import networks
    from repro.serve.cli import synthetic_traffic
    from repro.serve.client import ServeClient, ServeHTTPError
    from repro.serve.engine import PosteriorEngine
    from repro.serve.protocol import wire_marginals
    from repro.serve.server import start_in_thread
    from repro.serve.worker import WorkerPool

    bn = getattr(networks, network)()
    registry = {network: bn}
    traffic = synthetic_traffic(
        bn, network, n_queries, n_patterns, np.random.default_rng(0), budget)
    kw = dict(chains_per_query=chains, burn_in=32, seed=7)
    pool = WorkerPool(lambda name: PosteriorEngine(registry, **kw), 1,
                      queue_kwargs={"max_wait_ms": 5.0})
    fe = start_in_thread(pool, port=0)
    overload_fe = None
    try:
        client = ServeClient("127.0.0.1", fe.port)
        # -- phase 1: bitwise identity (fresh server, first batch) ----
        wire = client.query_batch(traffic)
        ref = PosteriorEngine(registry, **kw).answer_batch(traffic)
        identical = all(
            set(wire_marginals(w)) == {str(k) for k in r.marginals}
            and all(np.array_equal(wire_marginals(w)[str(k)],
                                   np.asarray(m, np.float64))
                    for k, m in r.marginals.items())
            for w, r in zip(wire, ref))

        # -- phase 2: closed-loop capacity on warm plans --------------
        n_cap = len(traffic) * capacity_passes
        t0 = time.perf_counter()
        for i in range(n_cap):
            client.query(traffic[i % len(traffic)])
        capacity_qps = n_cap / (time.perf_counter() - t0)

        # -- phase 3: open-loop overload at 2x capacity ---------------
        offered_qps = overload_factor * capacity_qps
        if n_offered is None:  # ~2s of offered traffic, bounded
            n_offered = int(min(200, max(32, 2 * offered_qps)))
        overload_fe = start_in_thread(
            pool, port=0, quota_qps=capacity_qps, quota_burst=2.0)
        oclient = ServeClient("127.0.0.1", overload_fe.port)
        lock = threading.Lock()
        outcomes: list[tuple[str, float]] = []

        def _one(i: int) -> None:
            t = time.perf_counter()
            try:
                oclient.query(traffic[i % len(traffic)])
                kind = "served"
            except ServeHTTPError as exc:
                kind = "shed" if exc.status in (429, 503) else "error"
            except Exception:
                kind = "error"
            with lock:
                outcomes.append((kind, (time.perf_counter() - t) * 1e3))

        threads = []
        t_start = time.perf_counter()
        for i in range(n_offered):
            due = t_start + i / offered_qps
            while True:
                dt = due - time.perf_counter()
                if dt <= 0:
                    break
                time.sleep(min(dt, 0.01))
            th = threading.Thread(target=_one, args=(i,), daemon=True)
            th.start()
            threads.append(th)
        for th in threads:
            th.join(timeout=300)
        wall = time.perf_counter() - t_start
    finally:
        if overload_fe is not None:
            overload_fe.stop_thread()
        fe.stop_thread()
        pool.close(drain=False, timeout=30.0)

    served = [ms for kind, ms in outcomes if kind == "served"]
    shed = sum(1 for kind, _ in outcomes if kind == "shed")
    errors = sum(1 for kind, _ in outcomes if kind == "error")
    p50 = float(np.percentile(served, 50)) if served else float("nan")
    p99 = float(np.percentile(served, 99)) if served else float("nan")
    out = {
        "network": network,
        "n_queries": len(traffic),
        "identical": bool(identical),
        "capacity_qps": capacity_qps,
        "overload_factor": overload_factor,
        "offered_qps": offered_qps,
        "n_offered": int(n_offered),
        "served": len(served),
        "shed": int(shed),
        "errors": int(errors),
        "shed_rate": shed / max(n_offered, 1),
        "served_qps": len(served) / max(wall, 1e-9),
        "p50_ms": p50,
        "p99_ms": p99,
        "mean_service_ms": 1e3 / max(capacity_qps, 1e-9),
    }
    report(row(
        "serve_overload", p99 * 1e3,
        f"capacity_qps={capacity_qps:.2f};offered_qps={offered_qps:.2f};"
        f"shed_rate={out['shed_rate']:.2f};p50_ms={p50:.1f};"
        f"p99_ms={p99:.1f};errors={errors};identical={identical}"))
    return out


def run_map(name, network, *, n_queries=16, n_patterns=2, budget=1024,
            chains=16, mesh=None, report=print):
    """Annealed MAP/MPE serving benchmark: cold + warm qps for
    ``mode="map"`` traffic (simulated-annealing β schedule on the IU-exp
    weight path, assignment-stability retirement — see
    ``docs/inference_modes.md``).  The MAP rows live in their own report
    section rather than ``runs`` because ESS/s is not a meaningful
    throughput for annealed (deliberately non-mixing) chains; the gate
    compares warm queries/s only.  ``assignments_agree`` reports whether
    the cold and warm passes decoded the same argmax per query —
    informational (the passes consume different key-stream positions, so
    a near-tie can legitimately flip)."""
    import dataclasses

    from repro.pgm import networks
    from repro.serve.cli import synthetic_traffic
    from repro.serve.engine import PosteriorEngine

    bn = getattr(networks, network)()
    traffic = [dataclasses.replace(q, mode="map") for q in synthetic_traffic(
        bn, network, n_queries, n_patterns, np.random.default_rng(0), budget)]
    engine = PosteriorEngine({network: bn}, chains_per_query=chains,
                             burn_in=32, mesh=mesh)
    cold_dt, _, cold_results = _pass(engine, traffic)
    warm_dt, _, results = _pass(engine, traffic)
    stable = sum(r.converged for r in results)
    agree = sum(a.map_assignment == b.map_assignment
                for a, b in zip(cold_results, results))
    energy = float(np.mean([r.map_energy for r in results]))
    s = engine.cache.stats
    report(row(
        f"serve_{name}_cold", cold_dt / n_queries * 1e6,
        f"qps={n_queries/cold_dt:.2f};mode=map"))
    report(row(
        f"serve_{name}_warm", warm_dt / n_queries * 1e6,
        f"qps={n_queries/warm_dt:.2f};speedup={cold_dt/warm_dt:.1f}x;"
        f"hit_rate={s.hit_rate:.2f};map_stable={stable}/{n_queries};"
        f"agree={agree}/{n_queries};mean_energy={energy:.2f}"))
    return {
        "name": name,
        "network": network,
        "n_queries": n_queries,
        "retirement": engine.retirement,
        "cold": {"wall_s": cold_dt, "queries_per_s": n_queries / cold_dt},
        "warm": {"wall_s": warm_dt, "queries_per_s": n_queries / warm_dt},
        "map_stable": int(stable),
        "assignments_agree": int(agree),
        "mean_map_energy": energy,
        "cache_hit_rate": s.hit_rate,
    }


def run_filtering(name, network, *, n_streams=4, n_slices=6, budget=1024,
                  chains=8, burn_in=128, drift=0.25, mesh=None,
                  report=print):
    """Temporal filtering benchmark: per-slice latency for streaming-
    sensor traffic served *warm* (``stream_id`` set — each slice
    warm-starts from its stream's retained chains and skips burn-in) vs
    *cold* (identical traffic with ``stream_id`` stripped — every slice
    pays burn-in from scratch).  Both passes run through one engine, so
    everything after the cold pass's first slice is plan-cache-hot and
    the cold/warm latency ratio isolates the warm-start mechanism.

    Reported per the acceptance bar of ``docs/inference_modes.md``: the
    warm pass's per-slice plan-cache hit rate (must be 100% after slice
    0 — the gate fails otherwise), warm-started query counts per slice,
    and the cold/warm per-slice latency ratio
    ``benchmarks/check_serve_regression.py`` holds above
    ``--min-filtering-speedup``."""
    import dataclasses

    from repro.pgm import networks
    from repro.serve.cli import synthetic_stream_traffic
    from repro.serve.engine import PosteriorEngine

    bn = getattr(networks, network)()
    traffic = synthetic_stream_traffic(
        bn, network, n_streams, n_slices, np.random.default_rng(0), budget,
        drift=drift)
    slices = [traffic[i * n_streams:(i + 1) * n_streams]
              for i in range(n_slices)]
    engine = PosteriorEngine({network: bn}, chains_per_query=chains,
                             burn_in=burn_in, mesh=mesh)

    def _slice_pass(strip):
        times, hit_rates, warm = [], [], 0
        for sl in slices:
            qs = ([dataclasses.replace(q, stream_id=None) for q in sl]
                  if strip else sl)
            h0, m0 = engine.cache.stats.hits, engine.cache.stats.misses
            t0 = time.perf_counter()
            results = engine.answer_batch(qs)
            times.append(time.perf_counter() - t0)
            dh = engine.cache.stats.hits - h0
            dm = engine.cache.stats.misses - m0
            hit_rates.append(dh / max(dh + dm, 1))
            warm += sum(r.warm_start for r in results)
        return times, hit_rates, warm

    cold_times, _, _ = _slice_pass(strip=True)       # also warms the plans
    warm_times, warm_hits, warm_started = _slice_pass(strip=False)

    cold_ms = float(np.mean(cold_times[1:])) * 1e3
    warm_ms = float(np.mean(warm_times[1:])) * 1e3
    speedup = cold_ms / max(warm_ms, 1e-9)
    hit_after_0 = float(min(warm_hits[1:]))
    expected_warm = n_streams * (n_slices - 1)
    report(row(
        f"serve_{name}", warm_ms * 1e3,
        f"warm_slice_ms={warm_ms:.1f};cold_slice_ms={cold_ms:.1f};"
        f"speedup={speedup:.2f}x;hit_rate_after_slice0={hit_after_0:.2f};"
        f"warm_started={warm_started}/{expected_warm}"))
    return {
        "name": name,
        "network": network,
        "n_streams": n_streams,
        "n_slices": n_slices,
        "burn_in": burn_in,
        "retirement": engine.retirement,
        "cold_slice_ms": cold_ms,
        "warm_slice_ms": warm_ms,
        "slices_per_s_warm": 1e3 / max(warm_ms, 1e-9),
        "speedup": speedup,
        "warm_hit_rate_after_slice0": hit_after_0,
        "warm_started": int(warm_started),
        "expected_warm": int(expected_warm),
    }


def run_telemetry_overhead(network="asia", *, n_queries=16, n_patterns=2,
                           budget=2048, chains=16, repeats=8, report=print):
    """Null-recorder vs live-recorder warm throughput on identical
    traffic — the number the CI overhead gate holds at ≤ 5%.

    Protocol: warm both engines off the clock (plan-cache fill + XLA
    compile), then run ``repeats`` *interleaved* timed warm
    ``answer_batch`` passes per recorder, GC disabled.  Both engines
    share one seed, so pass *k* does bitwise-identical sampling on both
    sides — the ESS cancels exactly and the honest comparison is a pure
    time ratio on identical work.  ``ratio`` (what the gate holds
    ≥ 1 − tolerance) is the max of two robust estimators of that time
    ratio — the timeit-style min-time ratio, a trimmed-sum ratio, and
    the median of adjacent-pair ratios — because individual warm passes
    jitter ±10% on shared CI runners while the estimators stay centred;
    interleaving makes slow machine
    drift hit both sides equally, and the comparison is *self-relative*
    (both sides measured in this process, this run) so the gate is
    immune to runner speed-class drift.  ``ess_per_s_*`` report each
    side's throughput at its fastest pass."""
    import gc

    from repro.pgm import networks
    from repro.serve.cli import synthetic_traffic
    from repro.serve.engine import PosteriorEngine
    from repro.serve.telemetry import Telemetry

    bn = getattr(networks, network)()
    traffic = synthetic_traffic(
        bn, network, n_queries, n_patterns, np.random.default_rng(0), budget)
    engines = {}
    for label, tel in (("null", None), ("enabled", Telemetry())):
        engines[label] = PosteriorEngine(
            {network: bn}, chains_per_query=chains, burn_in=32,
            telemetry=tel)
        _pass(engines[label], traffic)       # warm the plan cache
    dts: dict[str, list[float]] = {"null": [], "enabled": []}
    ess: dict[str, list[float]] = {"null": [], "enabled": []}
    gc.collect()
    gc.disable()       # GC pauses are the dominant asymmetric jitter
    try:
        for _ in range(repeats):
            for label, engine in engines.items():
                dt, _, results = _pass(engine, traffic)
                dts[label].append(dt)
                ess[label].append(_ess(results))
    finally:
        gc.enable()
    ess_per_s = {}
    for label in ("null", "enabled"):
        k = min(range(repeats), key=dts[label].__getitem__)
        ess_per_s[label] = ess[label][k] / dts[label][k]

    # Three robust estimators of the same (work-identical) time ratio;
    # all are central, so their max keeps full sensitivity to a real
    # overhead regression (a true 10% cost drags every estimator to
    # ~0.90) while cutting the false-failure rate from runner timing
    # bursts that hit only one side's passes.
    def _trimmed(xs: list[float]) -> float:
        return sum(sorted(xs)[:-1]) if len(xs) > 1 else xs[0]

    pair_ratios = sorted(n / e for n, e in zip(dts["null"], dts["enabled"]))
    ratio = max(
        min(dts["null"]) / max(min(dts["enabled"]), 1e-12),
        _trimmed(dts["null"]) / max(_trimmed(dts["enabled"]), 1e-12),
        pair_ratios[len(pair_ratios) // 2])
    report(row("serve_telemetry_overhead",
               1e6 / max(ess_per_s["enabled"], 1e-9),
               f"ESS/s_null={ess_per_s['null']:.1f};"
               f"ESS/s_enabled={ess_per_s['enabled']:.1f};"
               f"ratio={ratio:.3f}"))
    return {"network": network, "n_queries": n_queries, "repeats": repeats,
            "ess_per_s_null": ess_per_s["null"],
            "ess_per_s_enabled": ess_per_s["enabled"],
            "ratio": ratio}


def run_sampler_compare(network="asia", *, n_queries=8, n_patterns=2,
                        budget=512, chains=8, report=print):
    """``sampler="xla"`` vs ``sampler="pallas"`` engine backends on
    identical traffic: warm MSample/s for both plus the bitwise-identity
    bit.  The regression gate holds ``identical`` unconditionally; the
    speedup is only meaningful off-CPU (on CPU the fused kernel runs
    through the Pallas *interpreter*), so the report carries the
    ``platform`` for the gate to condition on.

    The traffic covers *both* inference modes: the marginal queries get
    MAP-mode twins appended, so the one matrix row also pins the
    annealed (β-scaled) weight path to xla/pallas bitwise identity."""
    import dataclasses

    import jax

    from repro.pgm import networks
    from repro.serve.cli import synthetic_traffic
    from repro.serve.engine import PosteriorEngine

    bn = getattr(networks, network)()
    traffic = synthetic_traffic(
        bn, network, n_queries, n_patterns, np.random.default_rng(0), budget)
    traffic = traffic + [dataclasses.replace(q, mode="map")
                         for q in traffic[:max(n_patterns, 2)]]
    n_queries = len(traffic)
    out = {"network": network, "platform": jax.default_backend(),
           "n_queries": n_queries}
    results = {}
    for sampler in ("xla", "pallas"):
        engine = PosteriorEngine({network: bn}, chains_per_query=chains,
                                 burn_in=32, sampler=sampler, seed=7)
        _pass(engine, traffic)                       # warm the plan cache
        dt, samples, res = _pass(engine, traffic)
        results[sampler] = res
        # ESS is a mixing metric — meaningless for the annealed MAP
        # twins, so the throughput row counts only the marginal queries
        out[sampler] = {"wall_s": dt, "queries_per_s": n_queries / dt,
                        "msample_per_s": samples / dt / 1e6,
                        "ess_per_s": _ess(
                            [r for r in res if r.map_assignment is None]
                        ) / dt}
        report(row(f"serve_sampler_{sampler}", dt / n_queries * 1e6,
                   f"MSample/s={out[sampler]['msample_per_s']:.3f};"
                   f"platform={out['platform']}"))
    identical = all(_identical(a, b)
                    for a, b in zip(results["xla"], results["pallas"]))
    out["identical"] = bool(identical)
    out["speedup"] = (out["pallas"]["msample_per_s"]
                      / max(out["xla"]["msample_per_s"], 1e-12))
    report(row("serve_sampler_identity", 0.0,
               f"identical={identical};"
               f"speedup_pallas={out['speedup']:.2f}x"))
    return out


def run_diagnostics_compare(network="asia", *, n_queries=16, n_patterns=2,
                            budget=2048, chains=16, rhat_target=1.05,
                            ess_target=100.0, report=print):
    """Legacy vs rank retirement over identical traffic: per-mode mean
    sweeps-to-retirement, converged counts and ESS/s — the artifact
    (``BENCH_diagnostics.json``) CI uploads so the latency/statistical-
    quality trade-off of the retirement rule is tracked per commit."""
    from repro.pgm import networks
    from repro.serve.cli import synthetic_traffic
    from repro.serve.engine import PosteriorEngine

    bn = getattr(networks, network)()
    traffic = synthetic_traffic(
        bn, network, n_queries, n_patterns, np.random.default_rng(0), budget)
    out = {"suite": "serve_diagnostics", "network": network,
           "n_queries": n_queries, "rhat_target": rhat_target,
           "ess_target": ess_target, "modes": {}}
    for mode in ("legacy", "rank"):
        engine = PosteriorEngine(
            {network: bn}, chains_per_query=chains, burn_in=32,
            retirement=mode, rhat_target=rhat_target, ess_target=ess_target)
        _pass(engine, traffic)                       # warm the plan cache
        dt, samples, results = _pass(engine, traffic)
        sweeps = [r.n_sweeps for r in results]
        ess = _ess(results)
        out["modes"][mode] = {
            "wall_s": dt,
            "queries_per_s": n_queries / dt,
            "mean_sweeps_to_retirement": float(np.mean(sweeps)),
            "max_sweeps_to_retirement": int(max(sweeps)),
            "converged": int(sum(r.converged for r in results)),
            "msample_per_s": samples / dt / 1e6,
            "ess_per_s": ess / dt,
            "mean_min_ess": ess / n_queries,
        }
        m = out["modes"][mode]
        report(row(
            f"serve_diag_{mode}", dt / n_queries * 1e6,
            f"sweeps={m['mean_sweeps_to_retirement']:.0f};"
            f"MSample/s={m['msample_per_s']:.3f};"
            f"ESS/s={m['ess_per_s']:.1f};"
            f"converged={m['converged']}/{n_queries}"))
    return out


def main(report=print, *, smoke=False, stream=False, mesh_shape=None,
         trace_out="", metrics_out=""):
    """Benchmark-harness entry point; returns the JSON-able report."""
    mesh = None
    n_devices = 1
    if mesh_shape is not None:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(mesh_shape)
        n_devices = int(mesh.devices.size)
        report(f"# serve mesh {dict(mesh.shape)} over {n_devices} devices")
    kw = dict(mesh=mesh, report=report)
    if smoke:
        runs = [run("asia_8n", "asia", n_queries=8, budget=512, chains=8,
                    **kw),
                run_mrf("mrf_12x12", h=12, w=12, n_queries=8, budget=256,
                        **kw),
                run_ising("ising_16", side=16, n_queries=8, budget=256,
                          **kw)]
    else:
        runs = [run("asia_8n", "asia", **kw),
                run("child_scale_20n", "child_scale", n_queries=16, **kw),
                run_mrf("mrf_24x24", h=24, w=24, n_queries=16, **kw),
                run_ising("ising_32", side=32, n_queries=12, **kw)]
    # the retirement mode the runs actually used (each run records its
    # engine's) — the regression gate refuses to diff reports across
    # different modes, so a half-converted report must fail loudly here
    # rather than mislabel itself
    modes = {r.pop("retirement") for r in runs}
    if len(modes) != 1:
        raise RuntimeError(f"runs used mixed retirement modes: {modes}")
    rep = {"suite": "serve", "n_devices": n_devices,
           "retirement": modes.pop(),
           "mesh_shape": None if mesh_shape is None else list(mesh_shape),
           "runs": runs}
    # MAP qps + temporal-filtering rows (docs/inference_modes.md): their
    # own sections — ESS/s is not meaningful for annealed chains, and
    # the filtering row is per-slice latency, not per-query throughput
    if smoke:
        rep["map"] = run_map("asia_map", "asia", n_queries=8, budget=512,
                             chains=8, **kw)
        rep["filtering"] = run_filtering(
            "asia_filtering", "asia", n_streams=3, n_slices=4, budget=512,
            **kw)
    else:
        rep["map"] = run_map("asia_map", "asia", **kw)
        rep["filtering"] = run_filtering("asia_filtering", "asia", **kw)
    for section in ("map", "filtering"):
        if rep[section].pop("retirement") != rep["retirement"]:
            raise RuntimeError(
                f"{section} run used a different retirement mode")
    if stream:
        stream_kw = dict(kw, trace_out=trace_out, metrics_out=metrics_out)
        if smoke:
            rep["stream"] = run_stream(
                "asia_8n", "asia", n_queries=32, n_patterns=2, budget=512,
                chains=8, **stream_kw)
        else:
            rep["stream"] = run_stream("asia_8n", "asia", **stream_kw)
        if rep["stream"].pop("retirement") != rep["retirement"]:
            raise RuntimeError("stream run used a different retirement mode")
    # telemetry overhead: null vs live recorder on identical traffic —
    # self-relative, so the CI gate needs no baseline entry for it
    rep["telemetry_overhead"] = run_telemetry_overhead(report=report)
    # fused-pallas vs xla sampler backends: identity is gated always,
    # speedup only where the kernel compiles (non-CPU) — smoke-sized in
    # every mode, it is a correctness/tracking row, not a throughput one
    rep["sampler_pallas"] = run_sampler_compare(report=report)
    return rep


def scaling(device_counts, *, smoke=True, report=print):
    """Device-scaling report: re-run this module in forced-host
    subprocesses (XLA device count is fixed at backend init, so each
    point needs a fresh interpreter) and collect queries/s + MSample/s
    per device count."""
    out = []
    from repro.launch.mesh import force_host_devices

    for n in device_counts:
        env = dict(os.environ)
        env["PYTHONPATH"] = (
            _REPO + os.pathsep + os.path.join(_REPO, "src") + os.pathsep
            + env.get("PYTHONPATH", ""))
        force_host_devices(n, env)
        cmd = [sys.executable, "-m", "benchmarks.bench_serve",
               "--mesh-shape", str(n), "--json", "-"]
        if smoke:
            cmd.append("--smoke")
        p = subprocess.run(cmd, env=env, cwd=_REPO, capture_output=True,
                           text=True, timeout=1800)
        if p.returncode != 0:
            raise RuntimeError(f"scaling point n={n} failed:\n{p.stderr}")
        rep = json.loads(
            [ln for ln in p.stdout.splitlines() if ln.startswith("{")][-1])
        warm = rep["runs"][0]["warm"]
        out.append({"devices": n,
                    "queries_per_s": warm["queries_per_s"],
                    "msample_per_s": warm["msample_per_s"]})
        report(row(f"serve_scaling_{n}dev", 1e6 / max(warm["queries_per_s"], 1e-9),
                   f"qps={warm['queries_per_s']:.2f};"
                   f"MSample/s={warm['msample_per_s']:.3f}"))
    return out


def _cli(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="single small network (fast CI datapoint)")
    ap.add_argument("--stream", action="store_true",
                    help="add the open-loop streaming benchmark (admission "
                         "queue vs one-query-at-a-time synchronous serving)")
    ap.add_argument("--json", default="",
                    help="write a machine-readable report here ('-' = stdout)")
    ap.add_argument("--diagnostics-json", default="",
                    help="run legacy-vs-rank retirement over identical "
                         "traffic and write the comparison here "
                         "(sweeps-to-retirement, ESS/s per mode)")
    ap.add_argument("--mesh-shape", default="",
                    help="serve mesh, e.g. 4 or 2x2")
    ap.add_argument("--scaling", default="",
                    help="comma-separated forced-host device counts, "
                         "e.g. 1,2,4,8 — runs one subprocess per count")
    ap.add_argument("--overload", action="store_true",
                    help="add the HTTP front-end overload benchmark: "
                         "bitwise served-vs-answer_batch identity, then "
                         "p50/p99 + shed rate at 2x measured capacity")
    ap.add_argument("--million-spin", action="store_true",
                    help="add the million-spin torus capacity datapoint "
                         "(compile wall + spin-updates/s; weekly CI)")
    ap.add_argument("--million-spin-side", type=int, default=1024,
                    help="torus side for --million-spin (side**2 spins)")
    ap.add_argument("--force-host-devices", type=int, default=0)
    ap.add_argument("--trace-out", default="",
                    help="with --stream: write the queued engine's "
                         "Chrome/Perfetto trace here (CI artifact)")
    ap.add_argument("--metrics-json", default="",
                    help="with --stream: write the queued engine's "
                         "stats()/metrics snapshot here (CI artifact)")
    args = ap.parse_args(argv)

    if args.force_host_devices:
        from repro.launch.mesh import force_host_devices
        force_host_devices(args.force_host_devices)

    mesh_shape = None
    if args.mesh_shape:
        from repro.launch.mesh import parse_mesh_shape
        mesh_shape = parse_mesh_shape(args.mesh_shape)

    rep = main(smoke=args.smoke, stream=args.stream, mesh_shape=mesh_shape,
               trace_out=args.trace_out, metrics_out=args.metrics_json)
    if args.diagnostics_json:
        diag_kw = (dict(n_queries=8, budget=512, chains=8)
                   if args.smoke else {})
        diag = run_diagnostics_compare(**diag_kw)
        with open(args.diagnostics_json, "w") as f:
            json.dump(diag, f, indent=2)
        print(f"# wrote {args.diagnostics_json}")
    if args.overload:
        rep["overload"] = run_overload()
    if args.million_spin:
        rep["million_spin"] = run_million_spin(side=args.million_spin_side)
    if args.scaling:
        counts = [int(s) for s in args.scaling.split(",") if s]
        # scaling points are always smoke-sized: one datapoint per device
        # count, each paying its own interpreter + XLA compile
        rep["scaling"] = scaling(counts, smoke=True)
    if args.json == "-":
        print(json.dumps(rep))
    elif args.json:
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    _cli()
