"""Fig. 7 Bayesian-network workloads: asia (exact CPTs) + repository-
scale random nets (child/alarm/hailfinder sizes). Reports MSample/s,
bits/sample, DSatur color count, and marginal error vs oracle."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import row, time_call
from repro.pgm import networks
from repro.pgm.compile import (
    _run_gibbs_device, compile_bayesnet, sum_sweep_stats)


def run(name, bn, chains=128, sweeps=150, burn=50, oracle=None, report=print):
    prog = compile_bayesnet(bn)
    fn = jax.jit(lambda k: _run_gibbs_device(k, prog, n_chains=chains,
                                             n_sweeps=sweeps, burn_in=burn))
    dt = time_call(fn, jax.random.PRNGKey(0), warmup=1, iters=3)
    _, counts, per_sweep = fn(jax.random.PRNGKey(0))
    stats = sum_sweep_stats(per_sweep)
    n_samples = chains * sweeps * bn.n_nodes
    bits = float(stats.bits_used) / n_samples
    err = ""
    if oracle is not None:
        marg = np.asarray(counts, np.float64)
        marg /= np.clip(marg.sum(-1, keepdims=True), 1, None)
        errs = [np.abs(marg[v, : bn.card[v]] - oracle[v] / oracle[v].sum()).max()
                for v in range(bn.n_nodes)]
        err = f";marg_err={max(errs):.3f}"
    report(row(name, dt / n_samples * 1e6,
               f"MSample/s={n_samples/dt/1e6:.3f};bits={bits:.2f};"
               f"colors={prog.n_colors}{err}"))


def main(report=print):
    bn = networks.asia()
    run("bn_asia_8n", bn, sweeps=400, burn=100,
        oracle=bn.marginals_exact(), report=report)
    run("bn_child_scale_20n", networks.child_scale(), report=report)
    run("bn_alarm_scale_37n", networks.alarm_scale(), report=report)
    run("bn_hailfinder_scale_56n", networks.hailfinder_scale(),
        chains=64, report=report)


if __name__ == "__main__":
    main()
