"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (per repo convention).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run --only mrf # substring filter
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    bench_bayesnet,
    bench_halo,
    bench_interp,
    bench_ky_vs_cdf,
    bench_lm_decode,
    bench_mrf,
    bench_roofline,
    bench_schmoo,
    bench_serve,
    bench_sota_table,
)

SUITES = [
    ("schmoo", bench_schmoo),          # Fig. 6
    ("ky_vs_cdf", bench_ky_vs_cdf),    # §II-B 3x claim
    ("interp", bench_interp),          # §II-B IU claim
    ("mrf", bench_mrf),                # Fig. 7 (MRF)
    ("bayesnet", bench_bayesnet),      # Fig. 7 (BN)
    ("serve", bench_serve),            # ours: posterior query service
    ("halo", bench_halo),              # §II-A / Fig. 3b
    ("lm_decode", bench_lm_decode),    # ours: KY as LM token sampler
    ("sota_table", bench_sota_table),  # Table II
    ("roofline", bench_roofline),      # §Roofline table
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in SUITES:
        if args.only and args.only not in name:
            continue
        try:
            mod.main(report=print)
        except Exception as e:
            failures += 1
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
