"""§II-B claim: the KY sampler vs the CDF sampler (paper: 3× runtime
reduction, ~3 random bits/sample vs a full-width uniform per sample).

On vector hardware the honest comparison has two axes: random-bit
economy (HW-independent — KY wins by construction) and wall time
(platform-dependent: on serial HW the CDF accumulation loop dominates;
on vector units the CDF cumsum is one pass while KY walks ≈H+2 bit-plane
passes).  Both are reported; EXPERIMENTS.md discusses where the paper's
3× holds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core import cdf_sample, entropy_bits, ky_sample, quantize_probs


def main(report=print):
    batch = 65536
    for n, alpha in ((4, 0.3), (16, 0.3), (64, 0.3)):
        p = jax.random.dirichlet(jax.random.PRNGKey(n), jnp.full((n,), alpha),
                                 (batch,))
        w = quantize_probs(p, 12)
        key = jax.random.PRNGKey(0)
        ky = jax.jit(lambda k, w: ky_sample(k, w))
        cdf = jax.jit(lambda k, w: cdf_sample(k, w))
        t_ky = time_call(ky, key, w)
        t_cdf = time_call(cdf, key, w)
        bits_ky = float(ky(key, w).bits_used.mean())
        h = float(jnp.mean(entropy_bits(p)))
        report(row(f"ky_n{n}", t_ky / batch * 1e6,
                   f"bits={bits_ky:.2f};H={h:.2f}"))
        report(row(f"cdf_n{n}", t_cdf / batch * 1e6,
                   f"bits=32.00;speedup_ky={t_cdf / t_ky:.2f}x;"
                   f"bit_economy={32 / bits_ky:.1f}x"))


if __name__ == "__main__":
    main()
