"""§II-B claim: the KY sampler vs the CDF sampler (paper: 3× runtime
reduction, ~3 random bits/sample vs a full-width uniform per sample).

On vector hardware the honest comparison has two axes: random-bit
economy (HW-independent — KY wins by construction) and wall time
(platform-dependent: on serial HW the CDF accumulation loop dominates;
on vector units the CDF cumsum is one pass while KY walks ≈H+2 bit-plane
passes).  Both are reported; EXPERIMENTS.md discusses where the paper's
3× holds.

The ``fused_pallas`` rows time the full Gibbs distribution-generation
tail (log-weights → IU exp → fixed-point → KY) as the engine runs it
under ``sampler="pallas"`` — one fused kernel — against the identical
two-stage XLA path, and assert the results match bitwise.  Off-TPU the
kernel runs through the Pallas *interpreter*, so its wall time there
measures correctness plumbing, not the fusion win; the ``backend=``
field in the row keeps that honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core import cdf_sample, entropy_bits, ky_sample, quantize_probs
from repro.core.fixedpoint import DEFAULT_K
from repro.core.interp import masked_exp_weights
from repro.kernels.fused_sweep import fused_gibbs_sample


def main(report=print):
    batch = 65536
    for n, alpha in ((4, 0.3), (16, 0.3), (64, 0.3)):
        p = jax.random.dirichlet(jax.random.PRNGKey(n), jnp.full((n,), alpha),
                                 (batch,))
        w = quantize_probs(p, 12)
        key = jax.random.PRNGKey(0)
        ky = jax.jit(lambda k, w: ky_sample(k, w))
        cdf = jax.jit(lambda k, w: cdf_sample(k, w))
        t_ky = time_call(ky, key, w)
        t_cdf = time_call(cdf, key, w)
        bits_ky = float(ky(key, w).bits_used.mean())
        h = float(jnp.mean(entropy_bits(p)))
        report(row(f"ky_n{n}", t_ky / batch * 1e6,
                   f"bits={bits_ky:.2f};H={h:.2f}"))
        report(row(f"cdf_n{n}", t_cdf / batch * 1e6,
                   f"bits=32.00;speedup_ky={t_cdf / t_ky:.2f}x;"
                   f"bit_economy={32 / bits_ky:.1f}x"))

    # fused sweep kernel vs the two-stage XLA tail, same logw inputs
    backend = jax.default_backend()
    fused_batch = 4096 if backend == "cpu" else batch  # interpreter is slow
    for n in (4, 16):
        p = jax.random.dirichlet(jax.random.PRNGKey(n), jnp.full((n,), 0.3),
                                 (fused_batch,))
        logw = jnp.log(jnp.clip(p, 1e-7, None)).astype(jnp.float32)
        key = jax.random.PRNGKey(0)
        two_stage = jax.jit(lambda k, lw: ky_sample(
            k, masked_exp_weights(lw, jnp.int32(n), DEFAULT_K)))
        fused = jax.jit(lambda k, lw: fused_gibbs_sample(
            k, lw, n, k=DEFAULT_K))
        t_xla = time_call(two_stage, key, logw)
        t_pl = time_call(fused, key, logw)
        rx, rp = two_stage(key, logw), fused(key, logw)
        identical = all(bool(jnp.array_equal(a, b))
                        for a, b in zip(rx, rp))
        report(row(f"xla_two_stage_n{n}", t_xla / fused_batch * 1e6,
                   f"backend={backend}"))
        report(row(f"fused_pallas_n{n}", t_pl / fused_batch * 1e6,
                   f"backend={backend};identical={identical};"
                   f"speedup_fused={t_xla / t_pl:.2f}x"))


if __name__ == "__main__":
    main()
