"""§Roofline: render the 40-cell roofline table from the dry-run JSONs
(falls back to analytic-only if reports/dryrun is absent)."""
from __future__ import annotations

import json
import os

from benchmarks.common import row
from repro.configs import ARCH_IDS, SHAPES, cell_runnable, get_config
from repro.launch.roofline import roofline_cell


def main(report=print):
    rep_dir = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "reports", "dryrun")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_runnable(cfg, shape)
            if not ok:
                report(row(f"roofline_{arch}_{shape.name}", 0.0, "skipped"))
                continue
            rl = roofline_cell(cfg, shape)
            mem = ""
            fn = os.path.join(rep_dir, f"{arch}__{shape.name}__16x16.json")
            if os.path.exists(fn):
                with open(fn) as f:
                    r = json.load(f)
                if r.get("status") == "ok":
                    mem = f";mem/chip={r['memory']['total_per_chip']/1e9:.2f}GB"
            report(row(
                f"roofline_{arch}_{shape.name}",
                max(rl.t_compute, rl.t_memory, rl.t_collective) * 1e6,
                f"bound={rl.bottleneck};frac={rl.roofline_fraction:.3f};"
                f"useful_ratio={rl.useful_ratio:.2f}{mem}"))


if __name__ == "__main__":
    main()
