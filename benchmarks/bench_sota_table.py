"""Table II reproduction: this work vs MSSE vs SPU.

Silicon numbers come from the paper (cited); our implementation
contributes (a) CPU-measured MSample/s for the same kernel, and (b) the
TPU-v5e modeled sampler throughput from the roofline terms of the KY
kernel (bit-plane cumsum passes at VPU width — the per-sample cost model
is documented inline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core import entropy_bits, ky_sample, quantize_probs

# Table II (from the paper text — cited, not measured here)
PAPER = {
    "AIA_16nm": dict(tech="16nm", sram="960KB", su=16, fmax="300MHz",
                     peak_gsps=1.27, peak_gsps_w=20.0, sampler="KY"),
    "MSSE": dict(tech="16nm", sram="103KB", su=12, fmax="651MHz",
                 peak_gsps=0.372, peak_gsps_w=17.6, sampler="CDF"),
    "SPU": dict(tech="FPGA", sram="4MB", su=32, fmax="146MHz",
                peak_gsps=4.67, peak_gsps_w=float("nan"), sampler="CDF"),
}

# TPU v5e model: per DDG level the (8,128)-lane VPU retires one
# bit-plane cumsum pass over n outcomes for 1024 lanes; levels/sample
# ≈ H+2 (×<2 attempts). At 940 MHz VPU clock and n=4 outcomes a sample
# costs ≈ (H+2)·ceil(n/128)·~4 ops/lane-pass.
def modeled_tpu_gsps(n: int, h: float, clock: float = 0.94e9,
                     lanes: int = 8 * 128) -> float:
    levels = (h + 2.0) * 1.5
    ops_per_level = max(n / 128, 1.0) * 4.0
    samples_per_s = clock * lanes / (levels * ops_per_level * 128)
    return samples_per_s / 1e9


def main(report=print):
    for name, d in PAPER.items():
        report(row(f"tableII_{name}", 0.0,
                   f"peak_GS/s={d['peak_gsps']};GS/s/W={d['peak_gsps_w']};"
                   f"sampler={d['sampler']};source=paper"))
    # our measured CPU number on the paper's 4-outcome regime
    batch, n = 262_144, 4
    p = jax.random.dirichlet(jax.random.PRNGKey(0), jnp.full((n,), 0.4),
                             (batch,))
    w = quantize_probs(p, 12)
    fn = jax.jit(lambda k: ky_sample(k, w))
    dt = time_call(fn, jax.random.PRNGKey(1))
    h = float(jnp.mean(entropy_bits(p)))
    report(row("tableII_this_jax_cpu", dt / batch * 1e6,
               f"GS/s={batch/dt/1e9:.4f};host=1xCPU-core"))
    report(row("tableII_this_tpu_modeled", 0.0,
               f"GS/s={modeled_tpu_gsps(n, h):.2f};basis=VPU-bitplane-model;"
               f"paper_chip=1.27"))


if __name__ == "__main__":
    main()
